"""Fallback used when `hypothesis` is not installed (offline image).

Property-based tests are skipped with a clear reason; example-based tests
in the same module still run. Mirrors exactly the subset of the
hypothesis API these tests use (`given`, `settings`, and strategy
constructors, which are only ever evaluated at decoration time).
"""

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed in this image")(fn)

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _Strategies:
    """Any strategy constructor returns an inert placeholder."""

    def __getattr__(self, _name):
        def anything(*_args, **_kwargs):
            return None

        return anything


st = _Strategies()
