"""L2 correctness: model shapes, loss decrease under the posit train step,
and AOT manifest consistency."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from compile import model


def synthetic_batch(seed, batch=model.BATCH):
    """Blob-classification batch matching rust/src/dnn/dataset.rs."""
    rng = np.random.default_rng(seed)
    classes = 10
    xs = np.zeros((batch, 784), np.float32)
    ys = rng.integers(0, classes, batch)
    yy, xx = np.mgrid[0:28, 0:28]
    for i, label in enumerate(ys):
        ang = label / classes * 2 * np.pi
        cy, cx = 14 + 7 * np.sin(ang), 14 + 7 * np.cos(ang)
        img = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 9.0))
        img += 0.08 * rng.normal(size=(28, 28))
        xs[i] = np.clip(img, 0, 1).ravel()
    return jnp.asarray(xs), jnp.asarray(ys.astype(np.int32))


class TestForward:
    def test_param_shapes_and_count(self):
        params = model.init_params(0)
        assert len(params) == 6
        assert params[0].shape == (784, 256)
        assert params[5].shape == (10,)
        # 784·256 + 256 + 256·128 + 128 + 128·10 + 10 = 235,146
        assert model.param_count(params) == 235_146

    def test_logits_shape(self):
        params = model.init_params(0)
        x, _ = synthetic_batch(1)
        (logits,) = model.mlp_infer(*params, x)
        assert logits.shape == (model.BATCH, 10)
        assert bool(jnp.isfinite(logits).all())

    def test_forward_is_quantized(self):
        # the posit path must differ from an unquantized f32 MLP
        params = model.init_params(0)
        x, _ = synthetic_batch(2)
        (logits,) = model.mlp_infer(*params, x)
        h = x
        for li in range(3):
            w, b = params[2 * li], params[2 * li + 1]
            h = h @ w + b[None, :]
            if li < 2:
                h = jax.nn.relu(h)
        assert not np.allclose(np.asarray(logits), np.asarray(h), rtol=1e-6)
        # …but should be close (P(13/16,2) keeps ~3 decimal digits)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(h), rtol=0.1, atol=0.05)


class TestTraining:
    def test_loss_decreases(self):
        params = model.init_params(0)
        losses = []
        for step in range(30):
            x, y = synthetic_batch(step)
            *params, loss = model.mlp_train_step(*params, x, y)
            params = list(params)
            losses.append(float(loss))
        assert losses[0] > 2.0, f"init loss ≈ ln(10): {losses[0]}"
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, f"no learning: {losses}"

    def test_train_step_outputs_match_param_structure(self):
        params = model.init_params(0)
        x, y = synthetic_batch(0)
        out = model.mlp_train_step(*params, x, y)
        assert len(out) == len(params) + 1
        for p, o in zip(params, out[:-1]):
            assert p.shape == o.shape
        assert out[-1].shape == ()


class TestGemmEntry:
    def test_gemm_shapes(self):
        a = jnp.ones((128, 128), jnp.float32)
        b = jnp.ones((128, 128), jnp.float32) * 0.5
        (c,) = model.posit_gemm(a, b)
        assert c.shape == (128, 128)
        # 128 × (1 · 0.5) = 64, exactly representable
        np.testing.assert_allclose(np.asarray(c), 64.0)


class TestAotLowering:
    @pytest.mark.slow
    def test_all_entries_lower_to_hlo_text(self):
        from compile.aot import to_hlo_text

        text = to_hlo_text(model.posit_gemm, model.gemm_example_args(32, 32, 32))
        assert "HloModule" in text
        text = to_hlo_text(model.mlp_infer, model.infer_example_args(8))
        assert "HloModule" in text
        text = to_hlo_text(model.mlp_train_step, model.train_example_args(8))
        assert "HloModule" in text
