"""L1 correctness: the Pallas posit-matmul kernel vs the pure-jnp oracle —
the CORE correctness signal of the Python layers. Hypothesis sweeps
shapes and posit formats."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: run example tests, skip property tests
    from _hypothesis_stub import given, settings, st

from compile.kernels.posit_dot import (
    mxu_utilization_estimate,
    posit_matmul,
    vmem_footprint_bytes,
)
from compile.kernels.ref import posit_matmul_ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


class TestKernelVsRef:
    def test_single_block(self):
        a, b = rand((32, 32), 1), rand((32, 32), 2)
        out = posit_matmul(a, b)
        ref = posit_matmul_ref(a, b)
        np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)

    def test_multi_block_k(self):
        # K-blocked accumulation reassociates f32 adds; after the final
        # P(16,2) rounding the results must still agree to ≤ 1 output ulp.
        a, b = rand((32, 128), 3), rand((128, 32), 4)
        out = np.asarray(posit_matmul(a, b))
        ref = np.asarray(posit_matmul_ref(a, b))
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=1e-6)
        # and the vast majority agree exactly (same posit value)
        exact = (out == ref).mean()
        assert exact > 0.95, f"only {exact:.2%} bit-identical"

    def test_multi_block_all_dims(self):
        a, b = rand((64, 96), 5), rand((96, 64), 6)
        out = posit_matmul(a, b)
        ref = posit_matmul_ref(a, b)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=1e-6)

    @given(
        mi=st.integers(1, 3),
        ki=st.integers(1, 4),
        ni=st.integers(1, 3),
        seed=st.integers(0, 2**16),
        fmt=st.sampled_from([(8, 16, 2), (13, 16, 2), (16, 16, 2), (10, 16, 2)]),
    )
    @settings(max_examples=12, deadline=None)
    def test_shape_format_sweep(self, mi, ki, ni, seed, fmt):
        n_in, n_out, es = fmt
        m, k, n = 32 * mi, 32 * ki, 32 * ni
        a, b = rand((m, k), seed), rand((k, n), seed + 1)
        out = posit_matmul(a, b, n_in=n_in, es=es, n_out=n_out)
        ref = posit_matmul_ref(a, b, n_in=n_in, es=es, n_out=n_out)
        np.testing.assert_allclose(out, ref, rtol=3e-3, atol=1e-6)

    def test_output_values_are_posits(self):
        # every output must be idempotent under re-quantization
        from compile.posit_emu import quantize_posit

        a, b = rand((32, 64), 9), rand((64, 32), 10)
        out = posit_matmul(a, b, n_in=13, es=2, n_out=16)
        np.testing.assert_array_equal(out, quantize_posit(out, 16, 2))

    def test_shape_mismatch_raises(self):
        a, b = rand((32, 32), 1), rand((64, 32), 2)
        with pytest.raises(AssertionError):
            posit_matmul(a, b)

    def test_non_divisible_shapes_fit_smaller_blocks(self):
        # blocks auto-fit to the largest divisor ≤ requested (perf pass
        # made the API shape-flexible); odd shapes still compute correctly
        a, b = rand((33, 32), 1), rand((32, 32), 2)
        out = posit_matmul(a, b)
        ref = posit_matmul_ref(a, b)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=1e-6)

    def test_quantization_actually_applied(self):
        # with aggressive P(8,2) inputs the kernel must differ from a plain
        # f32 matmul (sanity that Q_in isn't optimized away)
        a, b = rand((32, 32), 11), rand((32, 32), 12)
        out = posit_matmul(a, b, n_in=8, es=2, n_out=16)
        plain = jnp.dot(a, b)
        assert not np.allclose(out, plain, rtol=1e-6)


class TestPerfEstimators:
    def test_vmem_footprint(self):
        # 32³ f32 blocks: 3 × 4 KiB
        assert vmem_footprint_bytes(32, 32, 32) == 3 * 32 * 32 * 4
        # 128³ tiles stay far under 16 MiB VMEM
        assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20

    def test_mxu_utilization(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(32, 32, 32) == pytest.approx((32 / 128) ** 3)
        assert mxu_utilization_estimate(256, 128, 128) == 1.0
