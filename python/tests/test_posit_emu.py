"""Properties of the jnp posit quantizer, pinned against known posit
values and (when the Rust binary has been built) against the bit-exact
Rust implementation."""

import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: run example tests, skip property tests
    from _hypothesis_stub import given, settings, st

from compile.posit_emu import maxpos, minpos, quantize_posit

FORMATS = [(8, 0), (8, 2), (10, 2), (13, 2), (16, 1), (16, 2), (32, 2)]


def q(x, n, es):
    return np.asarray(quantize_posit(jnp.asarray(x, dtype=jnp.float32), n, es))


class TestKnownValues:
    def test_exact_values_preserved(self):
        # values exactly representable in every listed format
        for n, es in FORMATS:
            for v in [0.0, 1.0, -1.0, 2.0, 0.5, -4.0]:
                assert q(v, n, es) == v, f"P({n},{es}) {v}"

    def test_paper_fig2_value(self):
        # 11 = 2^3·1.375 is exactly representable in P(8,2)
        assert q(11.0, 8, 2) == 11.0
        assert q(-11.0, 8, 2) == -11.0

    def test_rounding_p8_2_near_one(self):
        # P(8,2) near 1.0 has 3 fraction bits: step 0.125
        assert q(1.06, 8, 2) == 1.0
        assert q(1.07, 8, 2) == 1.125
        # RNE at the midpoint 1.0625 → even significand (1.0)
        assert q(1.0625, 8, 2) == 1.0

    def test_saturation(self):
        # 1e38 / 1e-38 are beyond maxpos/minpos of every listed format
        # (largest maxpos is P(32,2) = 2^120 ≈ 1.33e36) yet inside the float32 NORMAL range (subnormals are flushed by CPU XLA)
        for n, es in FORMATS:
            assert q(1e38, n, es) == pytest.approx(maxpos(n, es))
            assert q(-1e38, n, es) == pytest.approx(-maxpos(n, es))
            got = q(1e-37, n, es)
            assert got == pytest.approx(minpos(n, es))
            assert got > 0, "posit never underflows to zero"

    def test_nonfinite_saturate(self):
        assert q(np.inf, 16, 2) == maxpos(16, 2)
        assert q(-np.inf, 16, 2) == -maxpos(16, 2)


class TestProperties:
    @given(
        st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1, max_size=64),
        st.sampled_from(FORMATS),
    )
    @settings(max_examples=200, deadline=None)
    def test_idempotent(self, xs, fmt):
        n, es = fmt
        q1 = q(np.array(xs, dtype=np.float32), n, es)
        q2 = q(q1, n, es)
        np.testing.assert_array_equal(q1, q2)

    @given(
        st.floats(1e-6, 1e6, allow_nan=False),
        st.sampled_from(FORMATS),
    )
    @settings(max_examples=200, deadline=None)
    def test_sign_symmetry(self, x, fmt):
        n, es = fmt
        assert q(-x, n, es) == -q(x, n, es)

    @given(st.sampled_from(FORMATS), st.integers(-20, 20))
    @settings(max_examples=100, deadline=None)
    def test_powers_of_two_exact(self, fmt, e):
        # 2^e is representable only while the regime leaves all es exponent
        # bits in the word; at the extremes the exponent field truncates
        # and scales coarsen to multiples of 2^(missing bits).
        n, es = fmt
        k = e >> es  # floor division (arithmetic shift)
        rl = k + 2 if k >= 0 else -k + 1
        if rl + es <= n - 1:
            assert q(float(2.0**e), n, es) == 2.0**e

    @given(
        st.lists(st.floats(0.01, 100.0), min_size=2, max_size=32),
        st.sampled_from([(8, 2), (13, 2), (16, 2)]),
    )
    @settings(max_examples=100, deadline=None)
    def test_monotone(self, xs, fmt):
        n, es = fmt
        xs = np.sort(np.array(xs, dtype=np.float32))
        qs = q(xs, n, es)
        assert (np.diff(qs) >= 0).all(), f"quantizer must be monotone: {xs} -> {qs}"

    @given(
        st.floats(0.01, 100.0),
        st.sampled_from([(13, 2), (16, 2)]),
    )
    @settings(max_examples=200, deadline=None)
    def test_relative_error_bounded(self, x, fmt):
        n, es = fmt
        # central region: relative error ≤ 2^-(frac_bits_min) where at
        # least n-3-es-3 fraction bits are live for |x| in [0.01, 100]
        got = float(q(x, n, es))
        rel = abs(got - x) / x
        assert rel < 2.0 ** -(n - 9), f"P({n},{es}) {x} -> {got} rel {rel}"

    def test_narrower_format_coarser(self):
        rng = np.random.default_rng(0)
        xs = rng.normal(0, 2, size=500).astype(np.float32)
        errs = {}
        for n in [8, 10, 13, 16]:
            errs[n] = np.abs(q(xs, n, 2) - xs).mean()
        assert errs[8] > errs[10] > errs[13] > errs[16]


@pytest.mark.skipif(
    not (
        shutil.which("cargo")
        and os.path.exists(os.path.join(os.path.dirname(__file__), "../../target/release/pdpu"))
    ),
    reason="rust CLI not built",
)
class TestCrossLayerAgreement:
    """The jnp quantizer vs the bit-exact Rust posit library, via the
    ``pdpu quantize`` CLI. Value-level agreement within 1 ulp everywhere,
    exact agreement away from tie points."""

    def test_against_rust(self):
        binary = os.path.join(os.path.dirname(__file__), "../../target/release/pdpu")
        rng = np.random.default_rng(7)
        xs = np.concatenate(
            [
                rng.normal(0, 1, 50),
                rng.normal(0, 100, 20),
                np.exp(rng.uniform(-20, 20, 30)) * rng.choice([-1, 1], 30),
            ]
        ).astype(np.float32)
        for n, es in [(8, 2), (13, 2), (16, 2)]:
            out = subprocess.run(
                [binary, "quantize", f"--format={n},{es}"]
                + [repr(float(v)) for v in xs],
                capture_output=True,
                text=True,
                check=True,
            )
            rust_vals = np.array([float(t) for t in out.stdout.split()])
            py_vals = q(xs, n, es).astype(np.float64)
            # agreement within one quantizer step of each other
            for x, rv, pv in zip(xs, rust_vals, py_vals):
                if rv == pv:
                    continue
                # ≤ 1-ulp disagreement allowed at tie/boundary points
                step = abs(rv) * 2.0 ** -(n - 3 - es) + 1e-300
                assert abs(rv - pv) <= 2 * step, f"P({n},{es}) x={x}: rust {rv} vs py {pv}"
