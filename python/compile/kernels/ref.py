"""Pure-jnp oracle for the L1 Pallas kernel.

Computes the same value as ``posit_dot.posit_matmul`` with no Pallas, no
tiling, no tricks: quantize inputs, one f32 matmul, quantize the output.
Bit-for-bit agreement with the kernel is the core L1 correctness signal
(``python/tests/test_kernel.py``) — the kernel's K-blocked accumulation
order must not change the result beyond f32 reassociation, which the
tests bound tightly.
"""

import jax.numpy as jnp

from ..posit_emu import quantize_posit

__all__ = ["posit_matmul_ref"]


def posit_matmul_ref(a, b, *, n_in=13, es=2, n_out=16):
    """Reference ``C = Q_out(Q_in(A) @ Q_in(B))`` with a single f32 GEMM."""
    aq = quantize_posit(a.astype(jnp.float32), n_in, es)
    bq = quantize_posit(b.astype(jnp.float32), n_in, es)
    c = jnp.dot(aq, bq, preferred_element_type=jnp.float32)
    return quantize_posit(c, n_out, es)
