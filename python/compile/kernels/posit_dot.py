"""L1 — the PDPU dot-product hot-spot as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's N-wide
fused MAC datapath becomes, on TPU-class hardware, a tiled matmul whose

* **input decode (S1)** happens on the HBM→VMEM path: each A/B tile is
  quantized to the P(n_in, es) grid as it enters the kernel;
* **wide accumulation (S3–S4, the Wm register)** is the float32 output
  tile resident in VMEM across the K grid dimension, feeding the MXU;
* **single output rounding (S6)** is the P(n_out, es) quantization applied
  exactly once, when the K loop finishes.

So the kernel computes ``Q_out( Σ_k Q_in(A)·Q_in(B) )`` — PDPU's fused
rounding discipline: one rounding at the end, none in between.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the BlockSpec
footprint (see ``vmem_footprint_bytes`` and EXPERIMENTS.md §Perf).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..posit_emu import quantize_posit

__all__ = ["posit_matmul", "vmem_footprint_bytes", "mxu_utilization_estimate"]


def _kernel(a_ref, b_ref, o_ref, *, n_out, es, k_steps):
    """One (i, j, k) grid step of the blocked posit matmul.

    The output tile o_ref is revisited across the K grid dimension (its
    index map ignores k), so it doubles as the wide accumulator — the Wm
    register of the paper.

    PERF (EXPERIMENTS.md §Perf, L1 iteration 1): the input quantization
    Q_in is hoisted OUT of the kernel into the surrounding graph. Inside
    the kernel each A tile would be re-quantized N/bn times and each B
    tile M/bm times; hoisting makes Q_in exactly-once per element (and it
    is the hardware-faithful reading anyway: operands *stored* in posit are
    already on the grid when DMA'd into VMEM).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # S2–S4: exact products, wide (f32) accumulation
    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _round():
        # S6: the single output rounding
        o_ref[...] = quantize_posit(o_ref[...], n_out, es)


@partial(jax.jit, static_argnames=("n_in", "es", "n_out", "bm", "bn", "bk"))
def posit_matmul(a, b, *, n_in=13, es=2, n_out=16, bm=32, bn=64, bk=64):
    """Posit-quantized matmul ``C = Q_out(Q_in(A) @ Q_in(B))``.

    ``a``: [M, K] float32, ``b``: [K, N] float32. M, N, K must be
    divisible by the block sizes (the L2 model pads to multiples).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    # fit blocks to the problem: the largest divisor of each dim that does
    # not exceed the requested block (small matrices → one tile per dim;
    # 96-wide dims → 32-wide blocks; trace-time only)
    def _fit(dim, want):
        for cand in range(min(want, dim), 0, -1):
            if dim % cand == 0:
                return cand
        return 1

    bm, bn, bk = _fit(m, bm), _fit(n, bn), _fit(k, bk)
    k_steps = k // bk
    # S1 decode: quantize operands to the input grid once, in the graph
    a = quantize_posit(a, n_in, es)
    b = quantize_posit(b, n_in, es)
    return pl.pallas_call(
        partial(_kernel, n_out=n_out, es=es, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM bytes held live per grid step: A tile + B tile + f32 out/acc
    tile (double-buffered inputs would 2× the first two terms)."""
    return bm * bk * dtype_bytes + bk * bn * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of the MXU systolic array a (bm×bk)·(bk×bn) tile keeps
    busy (dimension-granularity model: each dimension occupies
    min(dim, mxu)/mxu of the array)."""
    return (min(bm, mxu) / mxu) * (min(bn, mxu) / mxu) * (min(bk, mxu) / mxu)
