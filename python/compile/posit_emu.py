"""Posit quantization in pure jnp — the numeric twin of ``rust/src/posit``.

Used by the L1 Pallas kernel and the L2 model to express PDPU's rounding
discipline (quantize operands to P(n_in, es) on ingest, accumulate wide,
round the result once to P(n_out, es)) inside a jittable JAX graph.

The emulation is value-level, not bit-level: it rounds a float to the
nearest posit *value* using arithmetic round-half-to-even on the fraction
grid. This matches the bit-exact Rust implementation everywhere except
(a) ties that fall across regime/exponent boundaries (bit-field RNE picks
the even *pattern*) and (b) sub-fraction exponent rounding in the extreme
regimes — both ≤ 1-ulp effects at the far tails; the Rust side remains the
ground truth, and ``python/tests/test_posit_emu.py`` pins the agreement.

All functions are shape-polymorphic and dtype-preserving; computation is
in float32 unless the input is float64.
"""

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "max_scale",
    "minpos",
    "maxpos",
    "quantize_posit",
    "PositSpec",
]


def max_scale(n: int, es: int) -> int:
    """Scale (base-2 exponent) of maxpos for P(n, es)."""
    return (n - 2) * (1 << es)


def minpos(n: int, es: int) -> float:
    return 2.0 ** (-max_scale(n, es))


def maxpos(n: int, es: int) -> float:
    return 2.0 ** max_scale(n, es)


class PositSpec:
    """A (n, es) pair with derived constants, hashable for jit closure."""

    def __init__(self, n: int, es: int):
        assert 3 <= n <= 32, f"n={n} out of range"
        assert 0 <= es <= 4, f"es={es} out of range"
        self.n = n
        self.es = es
        self.max_scale = max_scale(n, es)

    def __repr__(self):
        return f"P({self.n},{self.es})"

    def __eq__(self, other):
        return (self.n, self.es) == (other.n, other.es)

    def __hash__(self):
        return hash((self.n, self.es))


@partial(jax.jit, static_argnums=(1, 2))
def quantize_posit(x: jax.Array, n: int, es: int) -> jax.Array:
    """Round every element of ``x`` to the nearest P(n, es) posit value.

    Zero maps to zero; non-finite values saturate to ±maxpos (posit has no
    ±inf; NaR handling is done on the Rust side — a jitted DNN graph never
    produces NaN on valid data). Saturation: |x| above maxpos clamps to
    maxpos, below minpos clamps to minpos (posits never underflow to zero).
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32) if x.dtype not in (jnp.float32, jnp.float64) else x

    # jnp.sign / log2 flush f32 subnormals to zero on CPU XLA — use an
    # explicit comparison for the sign and clamp magnitudes into the f32
    # normal range (every supported posit's minpos/maxpos lies inside it)
    sign = jnp.where(xf < 0, -1.0, 1.0).astype(xf.dtype)
    mag = jnp.abs(xf)
    safe = jnp.clip(jnp.where(mag > 0, mag, 1.0), 1.2e-38, 3.0e38)

    # exact scale/significand split via frexp (bit manipulation — XLA's
    # f32 log2/exp2 are 1-2 ulp approximations and would corrupt exact
    # powers of two)
    m_, e_ = jnp.frexp(safe)  # safe = m·2^e with m ∈ [0.5, 1)
    scale = (e_ - 1).astype(jnp.float32)

    useed_pow = float(1 << es)
    k = jnp.floor(scale / useed_pow)
    # regime length: k >= 0 → k+2 ; k < 0 → -k+1
    rl = jnp.where(k >= 0, k + 2.0, -k + 1.0)
    # fraction bits left after sign, regime, exponent
    fb = jnp.clip(float(n - 1) - rl - float(es), 0.0, None)

    # quantize the significand 1.f on a 2^fb grid, round-half-to-even.
    # f32 significands carry 23 fraction bits, so any grid with fb ≥ 23 is
    # at least as fine as the input itself — quantization is the identity
    # there (and the arithmetic below would lose precision), hence the cap.
    sig = m_ * 2.0  # in [1, 2), exact
    fb = jnp.minimum(fb, 23.0)
    step = jnp.ldexp(jnp.ones_like(sig), fb.astype(jnp.int32))  # exact 2^fb
    sig_q = jnp.round((sig - 1.0) * step) / step + 1.0  # jnp.round is RNE
    # carry: significand rounded up to 2.0 → bump the scale
    carried = sig_q >= 2.0
    sig_q = jnp.where(carried, 1.0, sig_q)
    scale_q = scale + carried.astype(scale.dtype)

    # When fb == 0 the exponent bits may also be truncated and the grid
    # coarsens to scale steps of 2^(es − avail). The posit bit field below
    # the regime orders values as (exponent, fraction), so round the pair
    # jointly (rounding sig first and then the scale would double-round,
    # e.g. 2^21.6 in P(8,2) must go to 2^20, not 2^24).
    eb_avail = jnp.clip(float(n - 1) - rl, 0.0, float(es))
    escale = jnp.ldexp(jnp.ones_like(sig), (float(es) - eb_avail).astype(jnp.int32))  # exact 2^(es−avail)
    e_off = scale - k * useed_pow  # exponent field value ∈ [0, 2^es)
    field = e_off + (sig - 1.0)  # (e, fraction) as one ordered coordinate
    e_q = jnp.round(field / escale) * escale
    scale_q = jnp.where(fb > 0.0, scale_q, k * useed_pow + e_q)
    sig_q = jnp.where(fb > 0.0, sig_q, 1.0)

    # exact power-of-two scaling (ldexp manipulates the exponent field)
    q = jnp.ldexp(sig_q, scale_q.astype(jnp.int32))

    # saturation
    mx = float(2.0 ** max_scale(n, es))
    mn = float(2.0 ** (-max_scale(n, es)))
    q = jnp.clip(q, mn, mx)
    q = jnp.where(jnp.isfinite(mag), q, mx)

    out = sign * jnp.where(mag > 0, q, 0.0)
    return out.astype(dtype)
