"""AOT lowering: JAX entry points → HLO **text** artifacts for the Rust
runtime.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  mlp_infer.hlo.txt       serving forward pass       (B=32)
  mlp_train_step.hlo.txt  SGD step returning (params', loss)
  posit_gemm.hlo.txt      raw 128×128×128 posit GEMM service
  params_init.bin         initial MLP parameters, little-endian f32,
                          concatenated in argument order
  manifest.json           shapes/dtypes/offsets for the Rust loader

Python runs ONCE, at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
import struct

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_entry(s: jax.ShapeDtypeStruct):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=model.BATCH)
    ap.add_argument("--gemm", type=int, nargs=3, default=[128, 128, 128], metavar=("M", "K", "N"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = {}

    # --- the three entry points -----------------------------------------
    infer_args = model.infer_example_args(args.batch)
    text = to_hlo_text(model.mlp_infer, infer_args)
    with open(os.path.join(args.out_dir, "mlp_infer.hlo.txt"), "w") as f:
        f.write(text)
    entries["mlp_infer"] = {
        "file": "mlp_infer.hlo.txt",
        "args": [shape_entry(s) for s in infer_args],
        "outputs": 1,
    }
    print(f"mlp_infer: {len(text)} chars")

    train_args = model.train_example_args(args.batch)
    text = to_hlo_text(model.mlp_train_step, train_args)
    with open(os.path.join(args.out_dir, "mlp_train_step.hlo.txt"), "w") as f:
        f.write(text)
    entries["mlp_train_step"] = {
        "file": "mlp_train_step.hlo.txt",
        "args": [shape_entry(s) for s in train_args],
        "outputs": len(train_args) - 2 + 1,  # params' + loss
    }
    print(f"mlp_train_step: {len(text)} chars")

    m, k, n = args.gemm
    gemm_args = model.gemm_example_args(m, k, n)
    text = to_hlo_text(model.posit_gemm, gemm_args)
    with open(os.path.join(args.out_dir, "posit_gemm.hlo.txt"), "w") as f:
        f.write(text)
    entries["posit_gemm"] = {
        "file": "posit_gemm.hlo.txt",
        "args": [shape_entry(s) for s in gemm_args],
        "outputs": 1,
    }
    print(f"posit_gemm ({m}x{k}x{n}): {len(text)} chars")

    # --- initial parameters ----------------------------------------------
    params = model.init_params(args.seed)
    blob = bytearray()
    offsets = []
    for p in params:
        import numpy as np

        arr = np.asarray(p, dtype="<f4")
        offsets.append({"offset": len(blob), "shape": list(arr.shape)})
        blob.extend(arr.tobytes())
    with open(os.path.join(args.out_dir, "params_init.bin"), "wb") as f:
        f.write(bytes(blob))
    print(f"params_init.bin: {len(blob)} bytes, {model.param_count(params)} parameters")

    manifest = {
        "format": {"n_in": model.N_IN, "n_out": model.N_OUT, "es": model.ES},
        "batch": args.batch,
        "layer_sizes": model.LAYER_SIZES,
        "gemm": {"m": m, "k": k, "n": n},
        "params_bin": {"file": "params_init.bin", "tensors": offsets},
        "entries": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("manifest.json written")
    # struct import kept for documentation of the raw-f32 layout
    _ = struct


if __name__ == "__main__":
    main()
