"""L2 — the JAX model: a posit-quantized MLP classifier whose every matmul
routes through the L1 Pallas kernel.

This is the "deep learning application" layer of the paper: DNN compute
expressed over PDPU-semantics dot products. Entry points (all AOT-lowered
to HLO text by ``aot.py``, executed from Rust via PJRT — Python never runs
at request time):

* ``mlp_infer(params…, x)``         → logits              (serving path)
* ``mlp_train_step(params…, x, y)`` → (params…, loss)     (e2e training)
* ``posit_gemm(a, b)``              → c                   (raw GEMM service)

Architecture: 784 → 256 → 128 → 10 MLP with ReLU, ~235k parameters.
Quantization: inputs/weights P(N_IN, ES), accumulations f32 (the Wm-wide
register), layer outputs P(N_OUT, ES) — the mixed-precision operating
point of Table I. Gradients flow through the quantizers with a
straight-through estimator so the same graph trains.
"""

import jax
import jax.numpy as jnp

from .kernels.posit_dot import posit_matmul
from .posit_emu import quantize_posit

# The paper's flagship mixed-precision configuration.
N_IN, N_OUT, ES = 13, 16, 2

# MLP shape; padded to kernel blocks inside posit_linear.
LAYER_SIZES = [784, 256, 128, 10]
BATCH = 32
# PERF (EXPERIMENTS.md §Perf, L2 iteration 2): 64-wide K/N blocks halve
# the interpret-mode grid-step count per layer vs 32³ (grid overhead
# dominates on the CPU interpreter; on TPU the same change lifts the MXU
# dimension-utilization estimate from 0.25³ to 0.5²·0.25).
_BM = 32
_BK = 64
_BN = 64


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _ste(x_sur, q):
    """Straight-through estimator: forward = q, gradient flows via x_sur."""
    return x_sur + jax.lax.stop_gradient(q - x_sur)


def posit_linear(x, w, use_kernel=True):
    """``x[B, I] @ w[I, O]`` with PDPU semantics.

    ``use_kernel=True`` routes through the Pallas kernel (padded to
    blocks) — the serving path. ``use_kernel=False`` uses the numerically
    equivalent single-GEMM formulation (``kernels.ref``); the training
    artifact uses it because ``pallas_call`` cannot be traced under
    ``value_and_grad`` in this JAX version, and ``test_kernel.py`` pins
    kernel ≡ ref. Differentiable either way: the forward value is the
    quantized result, the gradient flows through a plain f32 surrogate
    (straight-through estimator).
    """
    b, _ = x.shape
    o = w.shape[1]
    if use_kernel:
        xp = _pad_to(_pad_to(x, _BM, 0), _BK, 1)
        wp = _pad_to(_pad_to(w, _BK, 0), _BN, 1)
        y = posit_matmul(xp, wp, n_in=N_IN, es=ES, n_out=N_OUT, bm=_BM, bn=_BN, bk=_BK)
        y = y[:b, :o]
    else:
        from .kernels.ref import posit_matmul_ref

        y = posit_matmul_ref(x, w, n_in=N_IN, es=ES, n_out=N_OUT)
    y_sur = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return _ste(y_sur, jax.lax.stop_gradient(y))


def init_params(seed: int = 0):
    """He-initialized weights + zero biases as a flat list of arrays (the
    Rust runtime passes them positionally)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for d_in, d_out in zip(LAYER_SIZES[:-1], LAYER_SIZES[1:]):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (d_in, d_out), jnp.float32) * jnp.sqrt(2.0 / d_in)
        params += [w, jnp.zeros((d_out,), jnp.float32)]
    return params


def param_count(params) -> int:
    return sum(int(p.size) for p in params)


def mlp_logits(params, x, use_kernel=True):
    """Forward pass: every matmul with PDPU semantics."""
    h = x
    n_layers = len(params) // 2
    for li in range(n_layers):
        w, b = params[2 * li], params[2 * li + 1]
        h = posit_linear(h, w, use_kernel=use_kernel) + b[None, :]
        if li < n_layers - 1:
            h = jax.nn.relu(h)
            # activations re-enter the next layer in the narrow format
            h = _ste(h, quantize_posit(h, N_IN, ES))
    return h


def mlp_infer(*args):
    """AOT entry: (w0,b0,w1,b1,w2,b2, x[B,784]) → (logits[B,10],)."""
    params, x = list(args[:-1]), args[-1]
    return (mlp_logits(params, x),)


def _loss(params, x, y):
    # ref formulation: traceable under value_and_grad (see posit_linear)
    logits = mlp_logits(params, x, use_kernel=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()


def mlp_train_step(*args, lr: float = 0.05):
    """AOT entry: (w0,b0,…, x[B,784], y[B] i32) → (w0',b0',…, loss)."""
    params, x, y = list(args[:-2]), args[-2], args[-1]
    loss, grads = jax.value_and_grad(_loss)(params, x, y)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return (*new_params, loss)


def posit_gemm(a, b):
    """AOT entry: raw posit GEMM service (shapes fixed at lowering).

    PERF (§Perf, L1 iteration 3): 128-wide N/K tiles — at 128³ the whole
    GEMM runs in a 4-step grid and each tile occupies a full MXU dimension
    (mxu_utilization_estimate(32,128,128) = 0.25 vs 0.0625 at 64-blocks).
    VMEM: 32·128·4 + 128·128·4 + 32·128·4 B ≈ 96 KiB ≪ 16 MiB.
    """
    return (posit_matmul(a, b, n_in=N_IN, es=ES, n_out=N_OUT, bm=_BM, bn=128, bk=128),)


def infer_example_args(batch: int = BATCH):
    """ShapeDtypeStructs for lowering ``mlp_infer``."""
    params = [
        jax.ShapeDtypeStruct(s, jnp.float32)
        for d_in, d_out in zip(LAYER_SIZES[:-1], LAYER_SIZES[1:])
        for s in [(d_in, d_out), (d_out,)]
    ]
    return params + [jax.ShapeDtypeStruct((batch, LAYER_SIZES[0]), jnp.float32)]


def train_example_args(batch: int = BATCH):
    return infer_example_args(batch) + [jax.ShapeDtypeStruct((batch,), jnp.int32)]


def gemm_example_args(m: int = 128, k: int = 128, n: int = 128):
    return [
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ]
