//! The paper's accuracy experiment as a standalone study: run the
//! ResNet18-conv1-like workload through every Table I architecture plus a
//! Wm sweep, reporting the accuracy/cost frontier — the analysis a user
//! would run to pick a PDPU configuration for their own network.
//!
//! Run: `cargo run --release --example conv1_accuracy [-- --hw 32 --oc 8]`

use pdpu::baselines::{table1_units, PdpuArch};
use pdpu::cost::{synthesize_combinational, PdpuParams, Tech};
use pdpu::dnn::dataset::conv1_workload;
use pdpu::dnn::layers::{conv2d, conv2d_f64};
use pdpu::dnn::metrics::{mean_relative_accuracy, rmse, sqnr_db};
use pdpu::pdpu::PdpuConfig;
use pdpu::posit::PositFormat;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let hw = get("--hw", 32);
    let oc = get("--oc", 8);

    println!("synthetic ResNet18-conv1 workload: {hw}x{hw} input, {oc} output channels, K = 147\n");
    let wl = conv1_workload(2023, hw, oc);
    let reference = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);

    println!("{:<30} {:>10} {:>12} {:>10}", "architecture", "accuracy", "rmse", "SQNR(dB)");
    for unit in table1_units() {
        let out = conv2d(unit.as_ref(), &wl.image, &wl.weights, wl.stride, wl.pad);
        println!(
            "{:<30} {:>9.2}% {:>12.3e} {:>10.1}",
            unit.name(),
            100.0 * mean_relative_accuracy(out.data(), reference.data()),
            rmse(out.data(), reference.data()),
            sqnr_db(out.data(), reference.data()),
        );
    }

    // Wm frontier: accuracy vs area for the flagship format
    println!("\nWm frontier, P(13/16,2) N=4 (pick the knee for your accuracy target):");
    println!("{:<10} {:>10} {:>12} {:>10}", "Wm", "accuracy", "area(um2)", "power(mW)");
    let tech = Tech::default();
    for wm in [6u32, 8, 10, 12, 14, 16, 20, 26] {
        let cfg = PdpuConfig::mixed(13, 16, 2, 4, wm).unwrap();
        let out = conv2d(&PdpuArch::new(cfg), &wl.image, &wl.weights, wl.stride, wl.pad);
        let acc = mean_relative_accuracy(out.data(), reference.data());
        let nl = pdpu::cost::netlists::pdpu(PdpuParams {
            in_fmt: PositFormat::p(13, 2),
            out_fmt: PositFormat::p(16, 2),
            n: 4,
            wm,
        });
        let r = synthesize_combinational(&nl, &tech);
        println!("{:<10} {:>9.2}% {:>12.0} {:>10.2}", wm, 100.0 * acc, r.area_um2, r.power_mw);
    }

    println!("\n(absolute percentages depend on the synthetic data; orderings and the");
    println!(" Wm knee reproduce the paper — see EXPERIMENTS.md §T1 for the comparison)");
}
