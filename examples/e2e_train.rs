//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload: train the posit-quantized MLP (L2 JAX graph calling the L1
//! Pallas posit kernel, AOT-compiled to HLO) for a few hundred steps from
//! the Rust L3 coordinator via PJRT, on a synthetic MNIST-like dataset;
//! then evaluate with the serving (inference) artifact and report the
//! loss curve, accuracy and throughput.
//!
//! Python does not run here — only the artifacts built by `make artifacts`.
//!
//! Run: `cargo run --release --example e2e_train [-- --steps 300]`

use std::time::Instant;

use pdpu::coordinator::ServiceHandle;
use pdpu::dnn::dataset::mnist_like;
use pdpu::dnn::metrics::top1;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    println!("=== PDPU end-to-end: posit-quantized MLP training through the full stack ===\n");
    let engine = ServiceHandle::start("artifacts")
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    let info = engine.info().clone();
    println!(
        "model: 784-256-128-10 MLP (235k params), P({}/{},{}) posit arithmetic, batch {}",
        info.n_in, info.n_out, info.es, info.batch
    );

    // datasets (generated in rust — same generator family as dnn::dataset)
    let train = mnist_like(7, 4096, info.classes);
    let test = mnist_like(8, 512, info.classes);
    let to_f32 = |img: &Vec<f64>| -> Vec<f32> { img.iter().map(|&v| v as f32).collect() };

    // --- training loop: the AOT train-step artifact, driven from rust ----
    println!("\ntraining {steps} steps (SGD lr=0.05, through the AOT posit train step)…");
    let mut losses: Vec<f32> = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for step in 0..steps {
        let mut images = Vec::with_capacity(info.batch);
        let mut labels = Vec::with_capacity(info.batch);
        for i in 0..info.batch {
            let idx = (step * info.batch + i) % train.images.len();
            images.push(to_f32(&train.images[idx]));
            labels.push(train.labels[idx] as u32);
        }
        let loss = engine.train_step(images, labels).map_err(|e| anyhow::anyhow!(e))?;
        losses.push(loss);
        if step == 0 || (step + 1) % 50 == 0 {
            let recent: f32 = losses.iter().rev().take(20).sum::<f32>() / losses.len().min(20) as f32;
            println!("  step {:>4}  loss {:.4}  (avg last 20: {:.4})", step + 1, loss, recent);
        }
    }
    let train_time = t0.elapsed();
    let steps_per_s = steps as f64 / train_time.as_secs_f64();
    println!(
        "training done in {:.1}s — {:.1} steps/s, {:.0} samples/s",
        train_time.as_secs_f64(),
        steps_per_s,
        steps_per_s * info.batch as f64
    );

    // --- evaluation through the serving artifact -------------------------
    println!("\nevaluating on {} held-out samples via the inference artifact…", test.images.len());
    let t1 = Instant::now();
    let mut all_logits: Vec<Vec<f64>> = Vec::with_capacity(test.images.len());
    for chunk in test.images.chunks(info.batch) {
        let images: Vec<Vec<f32>> = chunk.iter().map(to_f32).collect();
        let out = engine.infer_batch(images).map_err(|e| anyhow::anyhow!(e))?;
        all_logits.extend(out.into_iter().map(|l| l.into_iter().map(|v| v as f64).collect::<Vec<f64>>()));
    }
    let eval_time = t1.elapsed();
    let acc = top1(&all_logits, &test.labels);
    println!(
        "test top-1 accuracy: {:.1}%   (inference {:.0} samples/s)",
        100.0 * acc,
        test.images.len() as f64 / eval_time.as_secs_f64()
    );

    // --- verdicts ---------------------------------------------------------
    let first = losses[..20.min(losses.len())].iter().sum::<f32>() / 20f32.min(losses.len() as f32);
    let last = losses[losses.len().saturating_sub(20)..].iter().sum::<f32>() / 20f32.min(losses.len() as f32);
    println!("\nloss {:.3} → {:.3}  ({} steps)", first, last, steps);

    // write the loss curve for EXPERIMENTS.md
    std::fs::create_dir_all("results").ok();
    let mut csv = String::from("step,loss\n");
    for (i, l) in losses.iter().enumerate() {
        csv.push_str(&format!("{},{}\n", i + 1, l));
    }
    std::fs::write("results/e2e_train_loss.csv", csv)?;
    println!("loss curve written to results/e2e_train_loss.csv");

    anyhow::ensure!(last < first * 0.7, "training failed to reduce the loss");
    anyhow::ensure!(acc > 0.6, "test accuracy too low: {acc}");
    println!("\nE2E OK: L1 Pallas kernel ∘ L2 JAX graph ∘ L3 rust coordinator all compose.");
    engine.shutdown();
    Ok(())
}
