//! Serving demo — start the coordinator's TCP server, drive it with
//! concurrent clients, and report the latency/throughput profile with and
//! without dynamic batching pressure.
//!
//! Run: `cargo run --release --example serve_inference [-- --clients 8 --requests 64]`

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use pdpu::coordinator::{json, Metrics, Server, ServiceHandle};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let clients = get("--clients", 8);
    let requests = get("--requests", 64);

    println!("starting coordinator (engine thread + dynamic batcher + TCP front end)…");
    let engine = ServiceHandle::start("artifacts")
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", engine.clone(), metrics.clone())?;
    let addr = server.addr;
    println!("listening on {addr}\n");

    // --- warm: a single sequential client (no batching pressure) ---------
    println!("phase 1: one sequential client, {requests} requests (batch size ≈ 1)");
    let t0 = Instant::now();
    run_client(addr, 0, requests)?;
    let solo = t0.elapsed();
    let solo_snapshot = metrics.snapshot();
    println!(
        "  {:.1} req/s, mean latency {:.2} ms, mean batch {:.2}",
        requests as f64 / solo.as_secs_f64(),
        solo_snapshot.mean_latency_us / 1e3,
        solo_snapshot.mean_batch_size
    );

    // --- loaded: concurrent clients (batching kicks in) ------------------
    println!("\nphase 2: {clients} concurrent clients × {requests} requests");
    let t1 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| std::thread::spawn(move || run_client(addr, c as u64 + 1, requests)))
        .collect();
    for h in handles {
        h.join().unwrap()?;
    }
    let loaded = t1.elapsed();
    let s = metrics.snapshot();
    let loaded_reqs = (clients * requests) as f64;
    println!("  {:.1} req/s aggregate", loaded_reqs / loaded.as_secs_f64());
    println!(
        "  mean latency {:.2} ms   p95 {:.2} ms   mean batch {:.2} (batching amortizes PJRT dispatch)",
        s.mean_latency_us / 1e3,
        s.p95_latency_us as f64 / 1e3,
        s.mean_batch_size
    );
    println!(
        "\ntotals: {} requests, {} responses, {} errors, {} batches",
        s.requests, s.responses, s.errors, s.batches
    );
    anyhow::ensure!(s.errors == 0, "serving errors occurred");
    println!("serving demo OK");
    Ok(())
}

fn run_client(addr: std::net::SocketAddr, seed: u64, requests: usize) -> anyhow::Result<()> {
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut rng = pdpu::testing::Rng::seeded(seed);
    for _ in 0..requests {
        let img: Vec<f64> = (0..784).map(|_| rng.unit()).collect();
        let req = json::Json::obj(vec![
            ("op", json::Json::Str("infer".into())),
            ("image", json::Json::arr_f64(&img)),
        ]);
        writer.write_all((req.to_string() + "\n").as_bytes())?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let v = json::parse(&line).map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(v.get("ok") == Some(&json::Json::Bool(true)), "bad response: {line}");
    }
    Ok(())
}
