//! Quickstart — the public API in five minutes:
//! posit values, one fused PDPU dot product, its exact/discrete
//! comparisons, and the synthesized cost of the unit you just used.
//!
//! Run: `cargo run --release --example quickstart`

use pdpu::baselines::{DotArch, MulAddTreeDpu, PdpuArch, PositArith};
use pdpu::cost::{synthesize_combinational, PdpuParams, Tech};
use pdpu::pdpu::{Pdpu, PdpuConfig};
use pdpu::posit::{quire::exact_dot, Posit, PositFormat};

fn main() -> anyhow::Result<()> {
    // --- 1. posit values --------------------------------------------------
    let p8 = PositFormat::p(8, 2);
    let x = Posit::from_f64(11.0, p8);
    println!("posit P(8,2) of 11.0 : bits {:#010b}  value {}", x.bits(), x.to_f64());
    println!("maxpos / minpos      : {} / {}", Posit::maxpos(p8).to_f64(), Posit::minpos(p8).to_f64());
    println!("nearest to 1.06      : {}  (3 fraction bits near 1.0)", Posit::from_f64(1.06, p8).to_f64());

    // --- 2. one fused dot product (the paper's Eq. 2) --------------------
    let cfg = PdpuConfig::paper_default(); // P(13/16,2), N=4, Wm=14
    let unit = Pdpu::new(cfg);
    let in_fmt = cfg.in_fmt;
    let a: Vec<Posit> = [1.5, -2.25, 0.4, 3.0].iter().map(|&v| Posit::from_f64(v, in_fmt)).collect();
    let b: Vec<Posit> = [2.0, 0.5, -8.0, 0.125].iter().map(|&v| Posit::from_f64(v, in_fmt)).collect();
    let acc = Posit::from_f64(0.25, cfg.out_fmt);
    let out = unit.dot(acc, &a, &b);
    println!("\nPDPU {} :", cfg.label());
    println!("  acc + Va·Vb = {}   (fp64 would be {})", out.to_f64(), 0.25 + 3.0 - 1.125 - 3.2 + 0.375);

    // exact (quire) reference — the fused unit is ≤ (N+1) grid-ulps away
    let exact = exact_dot(acc, &a, &b, cfg.out_fmt);
    println!("  quire-exact        = {}", exact.to_f64());

    // --- 3. the same dot on a discrete architecture ----------------------
    let discrete = MulAddTreeDpu::new(
        PositArith { in_fmt, out_fmt: cfg.out_fmt },
        4,
        "discrete",
    );
    let av: Vec<f64> = a.iter().map(|p| p.to_f64()).collect();
    let bv: Vec<f64> = b.iter().map(|p| p.to_f64()).collect();
    println!("  discrete mul+add   = {}   (rounds after every op)", discrete.dot_f64(0.25, &av, &bv));

    // --- 4. long-vector chunked accumulation ----------------------------
    let arch = PdpuArch::new(cfg);
    let long_a: Vec<f64> = (0..147).map(|i| ((i * 37) % 19) as f64 / 19.0 - 0.5).collect();
    let long_b: Vec<f64> = (0..147).map(|i| ((i * 53) % 23) as f64 / 23.0 - 0.5).collect();
    let got = arch.dot_f64(0.0, &long_a, &long_b);
    let reference: f64 = long_a.iter().zip(&long_b).map(|(x, y)| x * y).sum();
    println!("\nconv1-length dot (K=147, chunked by N=4):");
    println!("  PDPU {:.6}  vs fp64 {:.6}  (rel err {:.2e})", got, reference, ((got - reference) / reference).abs());

    // --- 5. what does this unit cost in silicon? -------------------------
    let nl = pdpu::cost::netlists::pdpu(PdpuParams::from_config(&cfg));
    let r = synthesize_combinational(&nl, &Tech::default());
    println!("\nsynthesized (28 nm-class structural model):");
    println!("  area  {:.0} um²   delay {:.2} ns   power {:.2} mW", r.area_um2, r.delay_ns, r.power_mw);
    println!("  perf  {:.2} GOPS   {:.0} GOPS/mm²   {:.0} GOPS/W", r.perf_gops(), r.area_eff(), r.energy_eff());
    Ok(())
}
