//! `pdpu` — leader entrypoint: CLI over the full reproduction stack.
//! See `pdpu help` (or [`pdpu::cli::USAGE`]).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match pdpu::cli::run(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
