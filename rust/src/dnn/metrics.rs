//! Accuracy metrics for comparing a unit's outputs against the FP64
//! reference — including the Table I "Accuracy" column metric.

/// The Table I accuracy metric: **mean clipped relative accuracy**,
///
/// ```text
/// acc = mean_i max(0, 1 − |y_i − ŷ_i| / max(|ŷ_i|, ε))
/// ```
///
/// with ŷ the FP64 reference. The paper does not print its formula; this
/// choice reproduces its orderings (FP32 ≈ 100 %, P(16,2) ≈ 99 %,
/// FP16 ≈ 91 % on cancellation-heavy conv sums) — see DESIGN.md. NaN/∞
/// outputs (FP16 overflow) count as zero accuracy for that element, which
/// is how FP16's limited dynamic range hurts it in this metric.
pub fn mean_relative_accuracy(outputs: &[f64], reference: &[f64]) -> f64 {
    assert_eq!(outputs.len(), reference.len());
    assert!(!outputs.is_empty());
    let mut total = 0.0;
    for (&y, &r) in outputs.iter().zip(reference) {
        if !y.is_finite() {
            continue; // contributes 0
        }
        let rel = crate::obs::errstats::relative_error(r, y);
        total += (1.0 - rel).max(0.0);
    }
    total / outputs.len() as f64
}

/// Root-mean-square error.
pub fn rmse(outputs: &[f64], reference: &[f64]) -> f64 {
    assert_eq!(outputs.len(), reference.len());
    let s: f64 = outputs
        .iter()
        .zip(reference)
        .map(|(&y, &r)| {
            let d = if y.is_finite() { y - r } else { r };
            d * d
        })
        .sum();
    (s / outputs.len() as f64).sqrt()
}

/// Signal-to-quantization-noise ratio in dB (common in posit literature).
pub fn sqnr_db(outputs: &[f64], reference: &[f64]) -> f64 {
    assert_eq!(outputs.len(), reference.len());
    let sig: f64 = reference.iter().map(|r| r * r).sum();
    let noise: f64 = outputs
        .iter()
        .zip(reference)
        .map(|(&y, &r)| {
            let d = if y.is_finite() { y - r } else { r };
            d * d
        })
        .sum();
    if noise == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / noise).log10()
    }
}

/// Top-1 classification accuracy.
pub fn top1(logits: &[Vec<f64>], labels: &[usize]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    let correct = logits
        .iter()
        .zip(labels)
        .filter(|(row, &l)| {
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(usize::MAX);
            arg == l
        })
        .count();
    correct as f64 / labels.len().max(1) as f64
}

/// Decimal accuracy of a representation at value `x`: −log₁₀ of the
/// relative error when rounding `x` to the format (Gustafson's metric,
/// the y-axis of Fig. 3).
pub fn decimal_accuracy(x: f64, quantize: impl Fn(f64) -> f64) -> f64 {
    crate::obs::errstats::decimal_accuracy(x, quantize(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_outputs_score_one() {
        let r = vec![1.0, -2.0, 3.0];
        assert_eq!(mean_relative_accuracy(&r, &r), 1.0);
        assert_eq!(rmse(&r, &r), 0.0);
        assert_eq!(sqnr_db(&r, &r), f64::INFINITY);
    }

    #[test]
    fn relative_accuracy_scales() {
        // 1% error everywhere → 0.99
        let r = vec![1.0, 10.0, -5.0];
        let y: Vec<f64> = r.iter().map(|v| v * 1.01).collect();
        let a = mean_relative_accuracy(&y, &r);
        assert!((a - 0.99).abs() < 1e-12, "{a}");
    }

    #[test]
    fn infinite_outputs_score_zero() {
        let r = vec![1.0, 1.0];
        let y = vec![1.0, f64::INFINITY];
        assert!((mean_relative_accuracy(&y, &r) - 0.5).abs() < 1e-12);
        let y = vec![1.0, f64::NAN];
        assert!((mean_relative_accuracy(&y, &r) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn huge_errors_clip_at_zero() {
        let r = vec![1.0];
        let y = vec![-100.0];
        assert_eq!(mean_relative_accuracy(&y, &r), 0.0);
    }

    #[test]
    fn top1_counts_argmax() {
        let logits = vec![vec![0.1, 0.9], vec![0.8, 0.2], vec![0.4, 0.6]];
        let labels = vec![1, 0, 0];
        assert!((top1(&logits, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn decimal_accuracy_of_identity_is_infinite() {
        assert_eq!(decimal_accuracy(1.0, |x| x), f64::INFINITY);
        // 0.1% rounding error ≈ 3 decimal digits
        let d = decimal_accuracy(1.0, |x| x * 1.001);
        assert!((d - 3.0).abs() < 0.01, "{d}");
    }

    #[test]
    fn sqnr_reasonable() {
        let r = vec![1.0, 1.0, 1.0, 1.0];
        let y = vec![1.01, 0.99, 1.01, 0.99]; // 1% noise → ~40 dB
        let s = sqnr_db(&y, &r);
        assert!((s - 40.0).abs() < 0.5, "{s}");
    }
}
