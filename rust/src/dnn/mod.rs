//! The deep-learning workload substrate: tensors, layers that compute
//! through any [`crate::baselines::DotArch`], posit/IEEE quantization, the
//! synthetic datasets standing in for the paper's ResNet18-conv1
//! extraction, and the accuracy metrics of Table I / Fig. 3.

pub mod dataset;
pub mod layers;
pub mod metrics;
pub mod quantize;
pub mod tensor;

pub use dataset::{conv1_workload, mnist_like, ConvWorkload, Dataset};
pub use metrics::{mean_relative_accuracy, rmse, sqnr_db, top1};
pub use tensor::Tensor;
