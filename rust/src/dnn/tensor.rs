//! A minimal row-major f64 tensor — just enough substrate for the paper's
//! DNN workloads (conv/fc layers over chunked dot products). FP64 is the
//! reference representation, exactly as the paper extracts its conv1
//! tensors in FP64.

/// Row-major dense tensor of up to 4 dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let len = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; len] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Flat index of a 4-D coordinate (unused trailing dims must be 0).
    #[inline]
    pub fn idx4(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((a * self.shape[1] + b) * self.shape[2] + c) * self.shape[3] + d
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f64 {
        self.data[self.idx4(a, b, c, d)]
    }

    #[inline]
    pub fn idx3(&self, a: usize, b: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        (a * self.shape[1] + b) * self.shape[2] + c
    }

    #[inline]
    pub fn at3(&self, a: usize, b: usize, c: usize) -> f64 {
        self.data[self.idx3(a, b, c)]
    }

    #[inline]
    pub fn idx2(&self, a: usize, b: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 2);
        a * self.shape[1] + b
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map.
    pub fn map(mut self, f: impl Fn(f64) -> f64) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Max absolute difference to another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(&other.data).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
    }
}

/// im2col for a single-image CHW tensor: extract the patch feeding output
/// position (oy, ox) as a flat vector (channel-major, then ky, kx) —
/// the dot-product layout both the reference and the hardware paths share.
pub fn im2col_patch(
    img: &Tensor, // [C, H, W]
    oy: usize,
    ox: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f64>,
) {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    out.clear();
    for ch in 0..c {
        for ky in 0..kh {
            for kx in 0..kw {
                let iy = (oy * stride + ky) as isize - pad as isize;
                let ix = (ox * stride + kx) as isize - pad as isize;
                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                    out.push(0.0);
                } else {
                    out.push(img.at3(ch, iy as usize, ix as usize));
                }
            }
        }
    }
}

/// Full im2col: the patch matrix `[oh·ow, klen]` whose row `oy·ow + ox`
/// is exactly `im2col_patch(img, oy, ox, ..)`. Building it once per
/// (layer, image) lets the batched GEMM engine treat a convolution as one
/// `weights [oc, klen] × patchesᵀ` tile instead of oh·ow·oc scalar calls.
pub fn im2col_matrix(
    img: &Tensor, // [C, H, W]
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (c, h, w) = (img.shape()[0], img.shape()[1], img.shape()[2]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let klen = c * kh * kw;
    let mut data = Vec::with_capacity(oh * ow * klen);
    let mut patch = Vec::with_capacity(klen);
    for oy in 0..oh {
        for ox in 0..ow {
            im2col_patch(img, oy, ox, kh, kw, stride, pad, &mut patch);
            data.extend_from_slice(&patch);
        }
    }
    Tensor::from_vec(&[oh * ow, klen], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f64).collect());
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data()[t.idx2(1, 2)], 5.0);
        let t = t.reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn map_and_diff() {
        let a = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, -4.0]);
        let b = a.clone().map(|v| v.max(0.0)); // relu
        assert_eq!(b.data(), &[1.0, 0.0, 3.0, 0.0]);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1 channel 3x3 image, 1x1 kernel: patch == pixel
        let img = Tensor::from_vec(&[1, 3, 3], (0..9).map(|i| i as f64).collect());
        let mut patch = Vec::new();
        im2col_patch(&img, 1, 2, 1, 1, 1, 0, &mut patch);
        assert_eq!(patch, vec![5.0]);
    }

    #[test]
    fn im2col_padding_zeroes() {
        let img = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut patch = Vec::new();
        // 3x3 kernel at (0,0) with pad 1: top-left corner patch
        im2col_patch(&img, 0, 0, 3, 3, 1, 1, &mut patch);
        assert_eq!(patch.len(), 9);
        assert_eq!(patch, vec![0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn im2col_channel_major_order() {
        // 2 channels, 2x2 image, 2x2 kernel at origin: all of ch0 then ch1
        let img = Tensor::from_vec(&[2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let mut patch = Vec::new();
        im2col_patch(&img, 0, 0, 2, 2, 1, 0, &mut patch);
        assert_eq!(patch, vec![1., 2., 3., 4., 10., 20., 30., 40.]);
    }

    #[test]
    fn im2col_stride() {
        let img = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f64).collect());
        let mut patch = Vec::new();
        im2col_patch(&img, 1, 1, 2, 2, 2, 0, &mut patch);
        // stride-2 position (1,1) → rows 2..3, cols 2..3
        assert_eq!(patch, vec![10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn im2col_matrix_rows_equal_patches() {
        let img = Tensor::from_vec(&[2, 5, 5], (0..50).map(|i| (i as f64).sin()).collect());
        let (kh, kw, stride, pad) = (3, 3, 2, 1);
        let m = im2col_matrix(&img, kh, kw, stride, pad);
        let (oh, ow) = ((5 + 2 * pad - kh) / stride + 1, (5 + 2 * pad - kw) / stride + 1);
        let klen = 2 * kh * kw;
        assert_eq!(m.shape(), &[oh * ow, klen]);
        let mut patch = Vec::new();
        for oy in 0..oh {
            for ox in 0..ow {
                im2col_patch(&img, oy, ox, kh, kw, stride, pad, &mut patch);
                let row = &m.data()[(oy * ow + ox) * klen..(oy * ow + ox + 1) * klen];
                assert_eq!(row, &patch[..], "row ({oy},{ox})");
            }
        }
    }
}
