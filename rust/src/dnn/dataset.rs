//! Synthetic workloads standing in for the paper's proprietary data
//! extractions (DESIGN.md §Substitution log).
//!
//! The paper evaluates accuracy on "the activations, weights and outputs
//! of the first convolution layer of ResNet18 extracted in FP64". We have
//! no ImageNet tensors in this image, so [`conv1_workload`] synthesizes a
//! workload with the same distributional properties that drive the
//! experiment:
//!
//! * activations: per-channel-normalized natural-image-like values
//!   (smooth spatial structure, roughly zero-mean unit-variance after
//!   normalization, range ≈ ±2.6 — ImageNet normalization statistics);
//! * weights: zero-mean Gaussian with He scaling (σ = √(2/fan_in)), the
//!   initialization/trained-magnitude regime of ResNet conv1;
//! * dot products: K = 7·7·3 = 147 MACs with heavy sign cancellation —
//!   the property that separates the formats in Table I.
//!
//! [`mnist_like`] generates the small-classifier dataset used by the
//! end-to-end training example (a blob-classification task with the same
//! 28×28 shape as MNIST).

use super::tensor::Tensor;
use crate::testing::Rng;

/// A synthetic "ResNet18 conv1" workload instance.
#[derive(Clone, Debug)]
pub struct ConvWorkload {
    /// input image, CHW
    pub image: Tensor,
    /// weights, [out_ch, in_ch, kh, kw]
    pub weights: Tensor,
    pub stride: usize,
    pub pad: usize,
}

impl ConvWorkload {
    pub fn out_channels(&self) -> usize {
        self.weights.shape()[0]
    }

    pub fn kernel(&self) -> (usize, usize) {
        (self.weights.shape()[2], self.weights.shape()[3])
    }

    /// Output spatial size for the stored image.
    pub fn out_hw(&self) -> (usize, usize) {
        let (h, w) = (self.image.shape()[1], self.image.shape()[2]);
        let (kh, kw) = self.kernel();
        (
            (h + 2 * self.pad - kh) / self.stride + 1,
            (w + 2 * self.pad - kw) / self.stride + 1,
        )
    }

    /// Dot-product length of one output (the paper's K = 147 for conv1).
    pub fn dot_len(&self) -> usize {
        let (kh, kw) = self.kernel();
        self.weights.shape()[1] * kh * kw
    }
}

/// Synthesize a conv1-like workload. `hw` is the input spatial size
/// (ResNet uses 224; the experiments default to a smaller window to keep
/// bit-level simulation fast — the dot-product *length* is what matters
/// and stays at 147).
pub fn conv1_workload(seed: u64, hw: usize, out_channels: usize) -> ConvWorkload {
    let mut rng = Rng::seeded(seed);
    let (c, kh, kw) = (3usize, 7usize, 7usize);

    // Natural-image-like activations: dominated by SMOOTH structure
    // (gradients + low-frequency waves) with only faint texture noise.
    // Smoothness is the property that matters: conv outputs are
    // Σ wᵢ·xᵢ with zero-mean weights over a nearly-constant patch, so
    // they cancel heavily (|out| ≪ Σ|w·x|) — the high condition numbers
    // that separate the formats in Table I, exactly as flat regions of
    // real ImageNet images do.
    let mut image = Tensor::zeros(&[c, hw, hw]);
    for ch in 0..c {
        let (gx, gy) = (rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0));
        let bias = rng.uniform(-0.3, 0.3);
        let tex = 0.02 + 0.05 * rng.unit();
        let (fx, fy) = (rng.uniform(0.4, 1.4), rng.uniform(0.4, 1.4));
        let (px, py) = (rng.uniform(0.0, 6.28), rng.uniform(0.0, 6.28));
        let wave_amp = rng.uniform(0.3, 0.9);
        // smooth log-amplitude envelope: natural images mix bright,
        // high-contrast regions with near-black low-contrast ones, so the
        // *local* signal amplitude spans decades — posit's tapered
        // accuracy absorbs this, FP16's fixed band does not
        let (ax, ay) = (rng.uniform(0.3, 1.0), rng.uniform(0.3, 1.0));
        let (qx, qy) = (rng.uniform(0.0, 6.28), rng.uniform(0.0, 6.28));
        let env_strength = rng.uniform(2.0, 3.5);
        let mut vals = Vec::with_capacity(hw * hw);
        for y in 0..hw {
            for x in 0..hw {
                let (u, v) = (x as f64 / hw as f64, y as f64 / hw as f64);
                let smooth = gx * (u - 0.5) + gy * (v - 0.5);
                let wave = wave_amp * (6.28 * (fx * u + px)).sin() * (6.28 * (fy * v + py)).cos();
                let env =
                    (env_strength * ((6.28 * (ax * u + qx)).sin() + (6.28 * (ay * v + qy)).cos() - 1.2) / 2.0).exp();
                vals.push(env * (bias + smooth + wave + tex * rng.normal()));
            }
        }
        // per-channel standardization (the ImageNet preprocessing role)
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
        let std = var.sqrt().max(1e-9);
        for (i, v) in vals.iter().enumerate() {
            let y = i / hw;
            let x = i % hw;
            image.data_mut()[(ch * hw + y) * hw + x] = ((v - mean) / std).clamp(-2.64, 2.64);
        }
    }

    // Trained-like weights: He-scaled, heavy-tailed (Laplacian — trained
    // conv kernels have many near-zero taps), and zero-DC per
    // (filter, channel) block — first-layer filters are band-pass edge /
    // texture detectors, which is what makes conv1 outputs cancel heavily
    // on smooth patches.
    let fan_in = (c * kh * kw) as f64;
    let sigma = (2.0 / fan_in).sqrt();
    let laplace = |rng: &mut Rng| {
        let u: f64 = rng.unit().max(1e-12);
        let mag = -(u).ln() * sigma / std::f64::consts::SQRT_2;
        if rng.flip() {
            mag
        } else {
            -mag
        }
    };
    let mut wdata: Vec<f64> = (0..out_channels * c * kh * kw).map(|_| laplace(&mut rng)).collect();
    let block = kh * kw;
    for b in 0..out_channels * c {
        let s: f64 = wdata[b * block..(b + 1) * block].iter().sum();
        let mean = s / block as f64;
        for v in &mut wdata[b * block..(b + 1) * block] {
            *v -= mean;
        }
    }
    let weights = Tensor::from_vec(&[out_channels, c, kh, kw], wdata);

    ConvWorkload { image, weights, stride: 2, pad: 3 }
}

/// A tiny labelled classification dataset with MNIST's shape: `k` classes
/// of Gaussian blobs at class-specific positions on a 28×28 canvas.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// flattened images, [n, 784]
    pub images: Vec<Vec<f64>>,
    pub labels: Vec<usize>,
    pub classes: usize,
}

pub fn mnist_like(seed: u64, n: usize, classes: usize) -> Dataset {
    let mut rng = Rng::seeded(seed);
    let side = 28usize;
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let label = rng.below(classes as u64) as usize;
        // class-specific blob center on a ring
        let ang = label as f64 / classes as f64 * std::f64::consts::TAU;
        let (cy, cx) = (14.0 + 7.0 * ang.sin(), 14.0 + 7.0 * ang.cos());
        // jitter + per-sample blob width
        let (jy, jx) = (rng.normal_ms(0.0, 1.2), rng.normal_ms(0.0, 1.2));
        let w = 2.0 + rng.unit() * 1.5;
        let mut img = Vec::with_capacity(side * side);
        for y in 0..side {
            for x in 0..side {
                let d2 = ((y as f64 - cy - jy).powi(2) + (x as f64 - cx - jx).powi(2)) / (w * w);
                let v = (-d2).exp() + 0.08 * rng.normal();
                img.push(v.clamp(0.0, 1.0));
            }
        }
        images.push(img);
        labels.push(label);
    }
    Dataset { images, labels, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_shapes_match_resnet18() {
        let w = conv1_workload(1, 32, 8);
        assert_eq!(w.dot_len(), 147, "conv1 dot-product length is 7·7·3");
        assert_eq!(w.kernel(), (7, 7));
        assert_eq!(w.out_channels(), 8);
        let (oh, ow) = w.out_hw();
        assert_eq!((oh, ow), (16, 16)); // stride-2, pad-3 halves the size
    }

    #[test]
    fn activations_standardized() {
        let w = conv1_workload(2, 48, 4);
        let d = w.image.data();
        let mean = d.iter().sum::<f64>() / d.len() as f64;
        let var = d.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d.len() as f64;
        assert!(mean.abs() < 0.15, "mean {mean}");
        assert!((0.5..1.5).contains(&var), "var {var}");
        assert!(d.iter().all(|v| v.abs() <= 2.64 + 1e-12));
    }

    #[test]
    fn weights_he_scaled() {
        let w = conv1_workload(3, 16, 32);
        let d = w.weights.data();
        let var = d.iter().map(|v| v * v).sum::<f64>() / d.len() as f64;
        let expect = 2.0 / 147.0;
        assert!((var / expect - 1.0).abs() < 0.2, "weight var {var} vs He {expect}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = conv1_workload(7, 16, 4);
        let b = conv1_workload(7, 16, 4);
        assert_eq!(a.image, b.image);
        assert_eq!(a.weights, b.weights);
        let c = conv1_workload(8, 16, 4);
        assert_ne!(a.image, c.image);
    }

    #[test]
    fn mnist_like_separable() {
        // blobs of different classes occupy different positions: nearest-
        // centroid on raw pixels must beat chance comfortably
        let train = mnist_like(1, 400, 4);
        let test = mnist_like(2, 200, 4);
        // centroid per class
        let mut centroids = vec![vec![0.0; 784]; 4];
        let mut counts = [0usize; 4];
        for (img, &l) in train.images.iter().zip(&train.labels) {
            counts[l] += 1;
            for (c, &v) in centroids[l].iter_mut().zip(img) {
                *c += v;
            }
        }
        for (c, &n) in centroids.iter_mut().zip(&counts) {
            for v in c {
                *v /= n.max(1) as f64;
            }
        }
        let mut correct = 0;
        for (img, &l) in test.images.iter().zip(&test.labels) {
            let best = (0..4)
                .min_by(|&i, &j| {
                    let di: f64 = centroids[i].iter().zip(img).map(|(c, v)| (c - v) * (c - v)).sum();
                    let dj: f64 = centroids[j].iter().zip(img).map(|(c, v)| (c - v) * (c - v)).sum();
                    di.partial_cmp(&dj).unwrap()
                })
                .unwrap();
            if best == l {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.labels.len() as f64;
        assert!(acc > 0.8, "nearest-centroid accuracy {acc}");
    }

    #[test]
    fn labels_in_range_and_balancedish() {
        let d = mnist_like(5, 1000, 10);
        assert!(d.labels.iter().all(|&l| l < 10));
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c > 50), "{counts:?}");
    }
}
