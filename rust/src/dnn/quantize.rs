//! Tensor-level posit/IEEE quantization helpers and error statistics —
//! the bridge between FP64 workloads and the hardware formats.

use crate::baselines::ieee::{fp_from_f64, fp_to_f64, IeeeFormat};
use crate::obs::errstats::ErrStats;
use crate::posit::{Posit, PositFormat};

/// Round every element to the nearest posit of `fmt` and back to f64
/// (exact round-trip: posits are a subset of f64).
pub fn quantize_posit(data: &[f64], fmt: PositFormat) -> Vec<f64> {
    data.iter().map(|&v| Posit::from_f64(v, fmt).to_f64()).collect()
}

/// Round every element to the nearest IEEE value of `fmt` and back.
pub fn quantize_ieee(data: &[f64], fmt: IeeeFormat) -> Vec<f64> {
    data.iter().map(|&v| fp_to_f64(fp_from_f64(v, fmt), fmt)).collect()
}

/// Quantization error statistics over a tensor.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantStats {
    pub max_abs_err: f64,
    pub mean_abs_err: f64,
    pub mean_rel_err: f64,
    /// fraction of elements that became ±∞ or NaR (dynamic-range loss)
    pub overflow_frac: f64,
}

/// Error statistics of `quantized` against `original`, accumulated through
/// the shared [`ErrStats`] — the same arithmetic the FP64 shadow executor
/// uses live, so experiment sweeps and the numerics observatory report
/// identical numbers for identical errors.
pub fn quant_stats(original: &[f64], quantized: &[f64]) -> QuantStats {
    assert_eq!(original.len(), quantized.len());
    assert!(!original.is_empty());
    let mut s = ErrStats::default();
    for (&o, &q) in original.iter().zip(quantized) {
        s.observe(o, q);
    }
    QuantStats {
        max_abs_err: s.max_abs_err(),
        mean_abs_err: s.mean_abs_err(),
        mean_rel_err: s.mean_rel_err(),
        overflow_frac: s.overflow_frac(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn quantize_is_idempotent() {
        let fmt = PositFormat::p(13, 2);
        let mut rng = Rng::seeded(11);
        let data: Vec<f64> = (0..200).map(|_| rng.normal_ms(0.0, 3.0)).collect();
        let q1 = quantize_posit(&data, fmt);
        let q2 = quantize_posit(&q1, fmt);
        assert_eq!(q1, q2);
        let h = IeeeFormat::fp16();
        let q1 = quantize_ieee(&data, h);
        let q2 = quantize_ieee(&q1, h);
        assert_eq!(q1, q2);
    }

    #[test]
    fn posit_beats_fp16_near_one() {
        // the tapered-accuracy story: around |x| ≈ 1 a P(16,2) grid is
        // finer than FP16's
        let mut rng = Rng::seeded(12);
        let data: Vec<f64> = (0..2000).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let sp = quant_stats(&data, &quantize_posit(&data, PositFormat::p(16, 2)));
        let sf = quant_stats(&data, &quantize_ieee(&data, IeeeFormat::fp16()));
        // P(16,2) carries 12 significant bits in (1,2) vs FP16's 11, and 13
        // in (0.25,1): expect ~1.9× lower mean relative error on ±2 data
        assert!(sp.mean_rel_err < sf.mean_rel_err / 1.5, "posit {0} vs fp16 {1}", sp.mean_rel_err, sf.mean_rel_err);
    }

    #[test]
    fn fp16_overflows_where_posit_saturates() {
        let data = vec![1e6, -1e6];
        let sf = quant_stats(&data, &quantize_ieee(&data, IeeeFormat::fp16()));
        assert_eq!(sf.overflow_frac, 1.0);
        let sp = quant_stats(&data, &quantize_posit(&data, PositFormat::p(16, 2)));
        assert_eq!(sp.overflow_frac, 0.0, "posit saturates to maxpos instead");
    }

    #[test]
    fn stats_on_exact_data_are_zero() {
        let data = vec![0.5, 1.0, 2.0, -4.0];
        let s = quant_stats(&data, &quantize_posit(&data, PositFormat::p(16, 2)));
        assert_eq!(s.max_abs_err, 0.0);
        assert_eq!(s.mean_abs_err, 0.0);
        assert_eq!(s.overflow_frac, 0.0);
    }
}
