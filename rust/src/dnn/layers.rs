//! DNN layers computed through a dot-product architecture.
//!
//! Every layer takes a [`DotArch`] and routes its long dot products
//! through the unit's chunked datapath — so running a conv layer "on"
//! PDPU vs. a discrete DPU exercises exactly the hardware difference the
//! paper measures. `conv2d_f64`/`linear_f64` are the FP64 references.

use super::tensor::{im2col_matrix, Tensor};
use crate::baselines::DotArch;

/// Run `f` over a zero accumulator-seed slice of length `len`, reusing one
/// thread-local buffer instead of allocating a fresh `vec![0.0; len]` per
/// call — the hot layers ([`conv2d`], the training backward kernels, the
/// serving GEMM) all seed `dot_batch` with zeros on every invocation.
///
/// The buffer only ever holds zeros (callers receive `&[f64]`), so growth
/// is the only mutation. Re-entrant calls (e.g. `f` itself running a
/// layer) fall back to a fresh allocation rather than aliasing.
pub(crate) fn with_zero_seeds<R>(len: usize, f: impl FnOnce(&[f64]) -> R) -> R {
    use std::cell::RefCell;
    thread_local! {
        static ZERO_SEEDS: RefCell<Vec<f64>> = RefCell::new(Vec::new());
    }
    ZERO_SEEDS.with(|cell| {
        let mut buf = cell.replace(Vec::new());
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        let out = f(&buf[..len]);
        cell.replace(buf);
        out
    })
}

/// 2-D convolution of a CHW image with OIHW weights on `unit`.
/// Returns [out_ch, oh, ow].
///
/// Routed through [`DotArch::dot_batch`] over the im2col patch matrix:
/// one GEMM tile of `oc` weight rows × `oh·ow` patch columns. For
/// architectures with a batched override (the PDPU engine) the weight
/// tensor is quantized and decoded once per layer instead of once per
/// output pixel; for everything else the defaulted `dot_batch` reproduces
/// the scalar loop bit-for-bit.
pub fn conv2d(
    unit: &dyn DotArch,
    img: &Tensor,
    weights: &Tensor,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (oc, _ic, kh, kw) = (
        weights.shape()[0],
        weights.shape()[1],
        weights.shape()[2],
        weights.shape()[3],
    );
    let (h, w) = (img.shape()[1], img.shape()[2]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let klen = weights.shape()[1] * kh * kw;

    let patches = im2col_matrix(img, kh, kw, stride, pad);
    debug_assert_eq!(patches.shape(), &[oh * ow, klen]);
    // out[o·(oh·ow) + p] = dot(W[o,:], patch[p,:]) — already the [oc, oh, ow]
    // row-major layout.
    let out = with_zero_seeds(oc, |seeds| unit.dot_batch(seeds, weights.data(), patches.data(), klen));
    Tensor::from_vec(&[oc, oh, ow], out)
}

/// FP64 reference convolution (the paper's baseline representation).
pub fn conv2d_f64(img: &Tensor, weights: &Tensor, stride: usize, pad: usize) -> Tensor {
    struct F64Ref;
    impl DotArch for F64Ref {
        fn name(&self) -> String {
            "FP64 reference".into()
        }
        fn chunk(&self) -> usize {
            usize::MAX
        }
        fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
            acc + a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>()
        }
    }
    conv2d(&F64Ref, img, weights, stride, pad)
}

/// Fully-connected layer `y = W·x + b` on `unit`; `w` is [out, in].
/// One-column [`DotArch::dot_batch`] call (bit-identical to the scalar
/// per-row loop).
pub fn linear(unit: &dyn DotArch, x: &[f64], w: &Tensor, b: &[f64]) -> Vec<f64> {
    let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.len(), in_dim);
    assert_eq!(b.len(), out_dim);
    unit.dot_batch(b, w.data(), x, in_dim)
}

/// Batched fully-connected layer: `xs` is a [batch, in] activation matrix
/// (row-major); returns [batch, out]. The whole batch runs as one
/// [`DotArch::dot_batch`] tile — the serving-path entry point.
pub fn linear_batch(unit: &dyn DotArch, xs: &Tensor, w: &Tensor, b: &[f64]) -> Tensor {
    let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
    let batch = xs.shape()[0];
    assert_eq!(xs.shape()[1], in_dim);
    assert_eq!(b.len(), out_dim);
    // dot_batch yields [out, batch]; transpose into [batch, out]
    let ob = unit.dot_batch(b, w.data(), xs.data(), in_dim);
    let mut out = Tensor::zeros(&[batch, out_dim]);
    for o in 0..out_dim {
        for i in 0..batch {
            out.data_mut()[i * out_dim + o] = ob[o * batch + i];
        }
    }
    out
}

/// FP64 reference fully-connected layer.
pub fn linear_f64(x: &[f64], w: &Tensor, b: &[f64]) -> Vec<f64> {
    let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
    (0..out_dim)
        .map(|o| b[o] + w.data()[o * in_dim..(o + 1) * in_dim].iter().zip(x).map(|(wi, xi)| wi * xi).sum::<f64>())
        .collect()
}

/// ReLU in place.
pub fn relu(x: &mut [f64]) {
    for v in x {
        *v = v.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{PdpuArch, QuirePdpuArch};
    use crate::dnn::dataset::conv1_workload;
    use crate::dnn::metrics::mean_relative_accuracy;
    use crate::pdpu::PdpuConfig;
    use crate::posit::PositFormat;

    #[test]
    fn conv_identity_kernel_passthrough() {
        // 1x1 kernel with weight 1.0 reproduces the image
        let img = Tensor::from_vec(&[1, 3, 3], (0..9).map(|i| i as f64 / 4.0).collect());
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let out = conv2d_f64(&img, &w, 1, 0);
        assert_eq!(out.data(), img.data());
        // and through PDPU (values exactly representable)
        let unit = PdpuArch::new(PdpuConfig::paper_default());
        let out = conv2d(&unit, &img, &w, 1, 0);
        assert_eq!(out.data(), img.data());
    }

    #[test]
    fn conv_shapes() {
        let wl = conv1_workload(1, 16, 4);
        let out = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);
        let (oh, ow) = wl.out_hw();
        assert_eq!(out.shape(), &[4, oh, ow]);
    }

    #[test]
    fn known_small_convolution() {
        // 2x2 image, 2x2 kernel, no pad: single output = dot(img, kernel)
        let img = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 1, 2, 2], vec![0.5, -1.0, 2.0, 0.25]);
        let out = conv2d_f64(&img, &w, 1, 0);
        assert_eq!(out.data(), &[0.5 - 2.0 + 6.0 + 1.0]);
    }

    #[test]
    fn pdpu_conv_tracks_reference_closely() {
        let wl = conv1_workload(42, 16, 4);
        let reference = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);
        let unit = PdpuArch::new(PdpuConfig::mixed(16, 16, 2, 4, 20).unwrap());
        let out = conv2d(&unit, &wl.image, &wl.weights, wl.stride, wl.pad);
        let acc = mean_relative_accuracy(out.data(), reference.data());
        assert!(acc > 0.97, "P(16,2) Wm=20 conv accuracy {acc}");
    }

    #[test]
    fn quire_at_least_as_accurate_as_pdpu() {
        let wl = conv1_workload(43, 12, 3);
        let reference = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);
        let pdpu = PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap());
        let quire = QuirePdpuArch::new(PositFormat::p(13, 2), PositFormat::p(16, 2), 4);
        let conv_p = conv2d(&pdpu, &wl.image, &wl.weights, wl.stride, wl.pad);
        let conv_q = conv2d(&quire, &wl.image, &wl.weights, wl.stride, wl.pad);
        let a_p = mean_relative_accuracy(conv_p.data(), reference.data());
        let a_q = mean_relative_accuracy(conv_q.data(), reference.data());
        // Both units share the dominant error source (input quantization
        // to P(13,2)), so against the *unquantized* FP64 reference the gap
        // is small and either can be marginally ahead; quire must not be
        // meaningfully worse. The strict ulp-level ordering vs the
        // quantized-input exact value is covered in baselines::fused.
        assert!(a_q >= a_p - 2e-3, "quire {a_q} vs pdpu {a_p}");
    }

    #[test]
    fn linear_matches_reference_on_exact_data() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 0.5, -1.0, 2.0, 0.25, 0.0]);
        let x = [2.0, 4.0, 1.0];
        let b = [0.5, -1.0];
        let want = linear_f64(&x, &w, &b);
        assert_eq!(want, vec![2.0 + 2.0 - 1.0 + 0.5, 4.0 + 1.0 - 1.0]);
        let unit = PdpuArch::new(PdpuConfig::paper_default());
        assert_eq!(linear(&unit, &x, &w, &b), want);
    }

    #[test]
    fn zero_seed_reuse_survives_interleaved_sizes() {
        // grow the thread-local buffer, then reuse a shorter prefix, then
        // grow again: every conv must still match the per-call-alloc oracle
        let unit = PdpuArch::new(PdpuConfig::paper_default());
        let wl_big = conv1_workload(9, 12, 6);
        let wl_small = conv1_workload(10, 8, 2);
        for wl in [&wl_big, &wl_small, &wl_big] {
            let got = conv2d(&unit, &wl.image, &wl.weights, wl.stride, wl.pad);
            let klen = wl.dot_len();
            let oc = wl.out_channels();
            let patches = im2col_matrix(&wl.image, wl.kernel().0, wl.kernel().1, wl.stride, wl.pad);
            let oracle = unit.dot_batch(&vec![0.0; oc], wl.weights.data(), patches.data(), klen);
            assert_eq!(got.data(), &oracle[..]);
        }
        // nested use must not corrupt the outer borrow
        let v = with_zero_seeds(4, |outer| {
            let inner = with_zero_seeds(2, |s| s.to_vec());
            assert_eq!(inner, vec![0.0; 2]);
            outer.to_vec()
        });
        assert_eq!(v, vec![0.0; 4]);
    }

    #[test]
    fn relu_clamps() {
        let mut v = [1.0, -1.0, 0.0, -0.5];
        relu(&mut v);
        assert_eq!(v, [1.0, 0.0, 0.0, 0.0]);
    }
}
