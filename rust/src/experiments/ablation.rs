//! Experiment A2 — the design-space ablations §III-C motivates: sweep the
//! generator's three knobs (input format, dot-product size N, alignment
//! width Wm) and report the accuracy ↔ cost trade-off each one buys.
//! The paper's observation that "inappropriate data formats or alignment
//! width may result in 10 % higher computational loss of accuracy" falls
//! out of these sweeps.

use crate::baselines::PdpuArch;
use crate::cost::{synthesize_combinational, PdpuParams, Tech};
use crate::dnn::dataset::conv1_workload;
use crate::pdpu::PdpuConfig;
use crate::posit::PositFormat;

use super::table1::unit_accuracy_on;

/// One point of a sweep.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub label: String,
    pub accuracy: f64,
    pub area_um2: f64,
    pub power_mw: f64,
    pub delay_ns: f64,
}

fn eval(in_n: u32, out_n: u32, n: usize, wm: u32, tech: &Tech, hw: usize, oc: usize) -> AblationPoint {
    let cfg = PdpuConfig::mixed(in_n, out_n, 2, n, wm).expect("valid sweep point");
    let wl = conv1_workload(2023, hw, oc);
    let accuracy = unit_accuracy_on(&PdpuArch::new(cfg), &wl);
    let nl = crate::cost::netlists::pdpu(PdpuParams {
        in_fmt: PositFormat::p(in_n, 2),
        out_fmt: PositFormat::p(out_n, 2),
        n: n as u32,
        wm,
    });
    let r = synthesize_combinational(&nl, tech);
    AblationPoint {
        label: format!("P({in_n}/{out_n},2) N={n} Wm={wm}"),
        accuracy,
        area_um2: r.area_um2,
        power_mw: r.power_mw,
        delay_ns: r.delay_ns,
    }
}

/// Sweep the alignment width Wm at the paper's flagship format.
pub fn wm_sweep(wms: &[u32], tech: &Tech, hw: usize, oc: usize) -> Vec<AblationPoint> {
    wms.iter().map(|&wm| eval(13, 16, 4, wm, tech, hw, oc)).collect()
}

/// Sweep the input word size at fixed output format.
pub fn format_sweep(in_ns: &[u32], tech: &Tech, hw: usize, oc: usize) -> Vec<AblationPoint> {
    in_ns.iter().map(|&n| eval(n, 16, 4, 14, tech, hw, oc)).collect()
}

/// Sweep the dot-product size N.
pub fn n_sweep(ns: &[usize], tech: &Tech, hw: usize, oc: usize) -> Vec<AblationPoint> {
    ns.iter().map(|&n| eval(13, 16, n, 14, tech, hw, oc)).collect()
}

pub fn render(title: &str, pts: &[AblationPoint]) -> String {
    let mut s = format!(
        "{title}\n{:<24} {:>9} {:>10} {:>8} {:>7}\n",
        "config", "accuracy", "area(um2)", "power", "delay"
    );
    for p in pts {
        s.push_str(&format!(
            "{:<24} {:>8.2}% {:>10.0} {:>8.2} {:>7.2}\n",
            p.label,
            100.0 * p.accuracy,
            p.area_um2,
            p.power_mw,
            p.delay_ns
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const HW: usize = 12;
    const OC: usize = 3;

    #[test]
    fn wm_trades_accuracy_for_area() {
        let pts = wm_sweep(&[6, 10, 14, 20], &Tech::default(), HW, OC);
        // accuracy non-decreasing in Wm (allowing metric noise)
        for w in pts.windows(2) {
            assert!(w[1].accuracy >= w[0].accuracy - 5e-3, "{:?}", w);
            assert!(w[1].area_um2 > w[0].area_um2, "area must grow with Wm");
        }
        // the paper's "inappropriate alignment width" cliff: Wm=6 loses
        // several points of accuracy vs Wm=14
        let (w6, w14) = (&pts[0], &pts[2]);
        assert!(w14.accuracy - w6.accuracy > 0.02, "wm6 {:.4} vs wm14 {:.4}", w6.accuracy, w14.accuracy);
    }

    #[test]
    fn input_format_trades_accuracy_for_area() {
        let pts = format_sweep(&[8, 10, 13, 16], &Tech::default(), HW, OC);
        for w in pts.windows(2) {
            assert!(w[1].accuracy >= w[0].accuracy - 5e-3, "{:?}", w);
            assert!(w[1].area_um2 > w[0].area_um2);
        }
        // P(8) inputs crater accuracy (paper: "may result in 10% higher loss")
        assert!(pts[3].accuracy - pts[0].accuracy > 0.05);
    }

    #[test]
    fn n_scales_area_roughly_linearly() {
        let pts = n_sweep(&[2, 4, 8], &Tech::default(), HW, OC);
        let ratio = pts[2].area_um2 / pts[0].area_um2;
        assert!((2.0..5.0).contains(&ratio), "area N=8/N=2 ratio {ratio}");
        // accuracy roughly flat in N (chunking changes rounding slightly)
        for w in pts.windows(2) {
            assert!((w[1].accuracy - w[0].accuracy).abs() < 0.02, "{:?}", w);
        }
    }

    #[test]
    fn render_contains_rows() {
        let s = render("wm sweep", &wm_sweep(&[10, 14], &Tech::default(), HW, OC));
        assert!(s.contains("Wm=10") && s.contains("Wm=14"));
    }
}
