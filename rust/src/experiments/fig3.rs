//! Experiment F3 — regenerate **Fig. 3**: the tapered decimal accuracy of
//! posit vs the uniform accuracy of IEEE FP across the dynamic range,
//! overlaid with the distribution of conv1 activations — the "posit fits
//! the DNN data distribution" argument.

use crate::baselines::ieee::{fp_from_f64, fp_to_f64, IeeeFormat};
use crate::dnn::dataset::conv1_workload;
use crate::dnn::metrics::decimal_accuracy;
use crate::posit::{Posit, PositFormat};

/// One sample of the Fig. 3 curves.
#[derive(Clone, Copy, Debug)]
pub struct AccuracyPoint {
    /// log₂ of the probed magnitude
    pub log2_x: f64,
    /// decimal accuracy of P(16,2) at that magnitude
    pub posit16: f64,
    /// decimal accuracy of FP16
    pub fp16: f64,
}

/// Sweep decimal accuracy across magnitudes 2^lo … 2^hi. At each
/// magnitude several mantissa phases are probed and averaged (accuracy
/// varies within a binade).
pub fn accuracy_curves(log2_lo: i32, log2_hi: i32, per_binade: usize) -> Vec<AccuracyPoint> {
    let p16 = PositFormat::p(16, 2);
    let h = IeeeFormat::fp16();
    let mut out = Vec::new();
    for e in log2_lo..=log2_hi {
        let mut acc_p = 0.0;
        let mut acc_f = 0.0;
        let mut n = 0.0;
        for k in 0..per_binade {
            // golden-ratio phases: equidistributed in the binade AND in
            // every power-of-two ulp cell (a uniform stride would alias
            // against both grids and fake equal accuracy)
            let frac = 1.0 + ((k as f64 + 1.0) * 0.618_033_988_749_894_8) % 1.0;
            let x = frac * 2f64.powi(e);
            let dp = decimal_accuracy(x, |v| Posit::from_f64(v, p16).to_f64());
            let df = decimal_accuracy(x, |v| fp_to_f64(fp_from_f64(v, h), h));
            if dp.is_finite() && df.is_finite() {
                acc_p += dp;
                acc_f += df;
                n += 1.0;
            } else {
                // exact hit: probe a nudged point instead
                let x = x * (1.0 + 1e-7);
                acc_p += decimal_accuracy(x, |v| Posit::from_f64(v, p16).to_f64()).min(12.0);
                acc_f += decimal_accuracy(x, |v| fp_to_f64(fp_from_f64(v, h), h)).min(12.0);
                n += 1.0;
            }
        }
        out.push(AccuracyPoint { log2_x: e as f64, posit16: acc_p / n, fp16: acc_f / n });
    }
    out
}

/// Histogram of log₂|activations| of the conv1 workload (the data overlay
/// of Fig. 3): (bin center in log₂, fraction of data).
pub fn activation_histogram(seed: u64, hw: usize, bins_lo: i32, bins_hi: i32) -> Vec<(f64, f64)> {
    let wl = conv1_workload(seed, hw, 4);
    let mut counts = vec![0usize; (bins_hi - bins_lo + 1) as usize];
    let mut total = 0usize;
    for &v in wl.image.data() {
        if v == 0.0 {
            continue;
        }
        let b = v.abs().log2().floor() as i32;
        if (bins_lo..=bins_hi).contains(&b) {
            counts[(b - bins_lo) as usize] += 1;
        }
        total += 1;
    }
    counts
        .iter()
        .enumerate()
        .map(|(i, &c)| ((bins_lo + i as i32) as f64, c as f64 / total.max(1) as f64))
        .collect()
}

/// Render both series as aligned text columns (and CSV-ready rows).
pub fn render(points: &[AccuracyPoint], hist: &[(f64, f64)]) -> String {
    let mut s = String::from("log2(x)  P(16,2) dec.acc  FP16 dec.acc\n");
    for p in points {
        s.push_str(&format!("{:>7.0}  {:>15.2}  {:>12.2}\n", p.log2_x, p.posit16, p.fp16));
    }
    s.push_str("\nlog2|activation|  fraction\n");
    for (b, f) in hist {
        s.push_str(&format!("{:>16.0}  {:>8.4}\n", b, f));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posit_peaks_at_center_tapers_at_edges() {
        let pts = accuracy_curves(-16, 16, 8);
        let at = |e: i32| pts.iter().find(|p| p.log2_x == e as f64).unwrap();
        // tapered: the center (|x| ≈ 1) beats the extremes by ≥ 1 decimal
        assert!(at(0).posit16 > at(14).posit16 + 0.8, "{:?} vs {:?}", at(0), at(14));
        assert!(at(0).posit16 > at(-14).posit16 + 0.8);
        // symmetry of the taper
        assert!((at(10).posit16 - at(-11).posit16).abs() < 0.6);
    }

    #[test]
    fn fp16_flat_inside_normal_range() {
        let pts = accuracy_curves(-10, 10, 8);
        let accs: Vec<f64> = pts.iter().map(|p| p.fp16).collect();
        let max = accs.iter().cloned().fold(f64::MIN, f64::max);
        let min = accs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min < 0.4, "FP16 accuracy must be ~flat in its normal range: {min}..{max}");
    }

    #[test]
    fn posit_beats_fp16_near_one_loses_at_edges_of_fp16_range() {
        // enough phases per binade that the 2× finer posit grid shows up
        // in the average and not just in expectation
        let pts = accuracy_curves(-14, 14, 64);
        let at = |e: i32| pts.iter().find(|p| p.log2_x == e as f64).unwrap();
        // paper Fig. 3: "posits have better decimal accuracy on the
        // majority of calculations" — the center of the range. The win
        // region is [2^-4, 2^4): regime k=−1 costs 2 bits (11-bit frac,
        // beats FP16's 10) while k=+1 costs 3 (10-bit, ties FP16).
        for e in -4..=3 {
            assert!(at(e).posit16 > at(e).fp16, "posit must win at 2^{e}");
        }
        // far from 1.0 the taper drops below FP16's flat line
        assert!(at(-14).posit16 < at(-14).fp16 + 0.2);
    }

    #[test]
    fn posit_dynamic_range_extends_past_fp16() {
        // beyond FP16's normal range (|x| > 65504 ≈ 2^16) FP16 is useless
        // while P(16,2) still carries information
        let p16 = PositFormat::p(16, 2);
        let h = IeeeFormat::fp16();
        let x = 2f64.powi(20);
        let dp = decimal_accuracy(x * 1.01, |v| Posit::from_f64(v, p16).to_f64());
        let df = decimal_accuracy(x * 1.01, |v| fp_to_f64(fp_from_f64(v, h), h));
        assert!(dp > 1.0, "posit at 2^20: {dp}");
        assert!(df <= 0.0 || !df.is_finite(), "fp16 overflows at 2^20: {df}");
    }

    #[test]
    fn histogram_mass_concentrated_near_unity() {
        // standardized activations: most mass within 2^-3..2^2 — exactly
        // the region where posit accuracy peaks (the Fig. 3 argument)
        let hist = activation_histogram(1, 32, -12, 4);
        let central: f64 =
            hist.iter().filter(|(b, _)| (-3.0..=2.0).contains(b)).map(|(_, f)| f).sum();
        assert!(central > 0.7, "central mass {central}");
        let total: f64 = hist.iter().map(|(_, f)| f).sum();
        assert!((0.9..=1.0).contains(&total), "histogram covers the data: {total}");
    }

    #[test]
    fn render_has_both_sections() {
        let s = render(&accuracy_curves(-2, 2, 4), &activation_histogram(1, 16, -4, 2));
        assert!(s.contains("P(16,2)"));
        assert!(s.contains("fraction"));
    }
}
