//! Experiment drivers — one module per paper artifact (see DESIGN.md
//! §Experiment index):
//!
//! * [`table1`] — Table I (T1) and the §IV-A claims (A1)
//! * [`fig3`] — Fig. 3 tapered-accuracy-vs-distribution (F3)
//! * [`fig6`] — Fig. 6 pipeline breakdown (F6)
//! * [`ablation`] — the §III-C design-space sweeps (A2)
//!
//! Each module exposes `build`/`render` pairs used by the `pdpu exp …` CLI
//! and by the `cargo bench` harnesses.

pub mod ablation;
pub mod fig3;
pub mod fig6;
pub mod table1;
