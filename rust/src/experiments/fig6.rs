//! Experiment F6 — regenerate **Fig. 6**: the 6-stage pipeline breakdown
//! of PDPU (per-stage latency and area), the balanced-critical-path claim,
//! the ~2.7 GHz fmax claim, and the throughput speedup over the
//! combinational implementation, for N ∈ {4, 8, 16} at P(13/16,2) Wm=14.

use crate::cost::{synthesize_combinational, synthesize_pipelined, PdpuParams, PipelineReport, Tech};
use crate::posit::PositFormat;

/// The Fig. 6 data for one N.
#[derive(Clone, Debug)]
pub struct Fig6Entry {
    pub n: u32,
    pub report: PipelineReport,
    pub comb_delay_ns: f64,
}

/// Build the Fig. 6 sweep (paper: P(13/16,2), Wm=14).
pub fn build(ns: &[u32], tech: &Tech) -> Vec<Fig6Entry> {
    ns.iter()
        .map(|&n| {
            let params = PdpuParams {
                in_fmt: PositFormat::p(13, 2),
                out_fmt: PositFormat::p(16, 2),
                n,
                wm: 14,
            };
            let nl = crate::cost::netlists::pdpu(params);
            let comb = synthesize_combinational(&nl, tech);
            Fig6Entry { n, report: synthesize_pipelined(&nl, tech), comb_delay_ns: comb.delay_ns }
        })
        .collect()
}

/// Render the per-stage rings of Fig. 6 as a table.
pub fn render(entries: &[Fig6Entry]) -> String {
    let mut s = String::new();
    for e in entries {
        s.push_str(&format!(
            "PDPU P(13/16,2) Wm=14 N={}  (clock {:.3} ns, fmax {:.2} GHz, pipeline speedup {:.1}x)\n",
            e.n, e.report.clock_ns, e.report.fmax_ghz, e.report.speedup
        ));
        s.push_str(&format!("  {:<15} {:>11} {:>11}\n", "stage", "latency(ns)", "area(um2)"));
        for st in &e.report.stages {
            s.push_str(&format!("  {:<15} {:>11.3} {:>11.0}\n", st.name, st.delay_ns, st.area_um2));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries() -> Vec<Fig6Entry> {
        build(&[4, 8, 16], &Tech::default())
    }

    #[test]
    fn six_stages_everywhere() {
        for e in entries() {
            assert_eq!(e.report.stages.len(), 6, "N={}", e.n);
        }
    }

    /// Paper: "the worst latency of the 6-stage pipeline PDPU is merely
    /// about 0.37 ns, and thus, it can operate up to 2.7 GHz".
    #[test]
    fn fmax_in_multi_ghz_range() {
        let es = entries();
        let n4 = &es[0];
        assert!(
            (0.25..0.55).contains(&n4.report.clock_ns),
            "N=4 clock {:.3} ns (paper ≈ 0.37)",
            n4.report.clock_ns
        );
        assert!(n4.report.fmax_ghz > 1.8, "fmax {:.2} GHz (paper 2.7)", n4.report.fmax_ghz);
    }

    /// Paper: pipelining improves throughput by 4.4× / 4.6× — i.e. the
    /// speedup is between ~4 and 6 for these configs.
    #[test]
    fn speedup_matches_paper_band() {
        for e in entries() {
            assert!(
                (3.0..6.5).contains(&e.report.speedup),
                "N={} speedup {:.2} (paper ~4.4-4.6)",
                e.n,
                e.report.speedup
            );
        }
    }

    /// Paper: S2 and S4 latency grows quickly with N (deeper trees).
    #[test]
    fn s2_s4_grow_with_n() {
        let es = entries();
        let stage = |e: &Fig6Entry, i: usize| e.report.stages[i].delay_ns;
        assert!(stage(&es[2], 1) > stage(&es[0], 1), "S2 grows with N");
        assert!(stage(&es[2], 3) > stage(&es[0], 3), "S4 grows with N");
        // S6 (encoder) does not depend on N
        assert!((stage(&es[2], 5) - stage(&es[0], 5)).abs() < 1e-12);
    }

    /// Paper: S1's parallel decoders occupy a relatively large area share.
    #[test]
    fn s1_area_share_is_largest() {
        for e in entries() {
            let s1 = e.report.stages[0].area_um2;
            for st in &e.report.stages[1..] {
                assert!(s1 >= st.area_um2, "N={}: {} ({:.0}) > S1 ({:.0})", e.n, st.name, st.area_um2, s1);
            }
            let total: f64 = e.report.stages.iter().map(|s| s.area_um2).sum();
            assert!(s1 / total > 0.25, "N={}: S1 share {:.2}", e.n, s1 / total);
        }
    }

    /// Comparison anchor from §IV-B: the 5-stage posit MAC of [19] has a
    /// 0.8 ns worst stage in the same 28 nm node — PDPU's must be well
    /// under that.
    #[test]
    fn beats_crespo_mac_stage_latency() {
        let es = entries();
        assert!(es[0].report.clock_ns < 0.8 * 0.8, "{:.3}", es[0].report.clock_ns);
    }

    #[test]
    fn render_mentions_all_stages() {
        let s = render(&entries());
        for name in ["S1 Decode", "S2 Multiply", "S3 Align", "S4 Accumulate", "S5 Normalize", "S6 Encode"] {
            assert!(s.contains(name), "{name}");
        }
    }
}
