//! Experiment T1 — regenerate **Table I**: accuracy (bit-exact simulation
//! on the conv1-like workload) joined with area/delay/power (structural
//! cost model) and the derived Perf / Area-eff / Energy-eff columns, for
//! all twelve rows; plus the §IV-A headline claims (experiment A1).

use crate::baselines::{table1_units, DotArch};
use crate::cost::{table1_reports, Report, Tech};
use crate::dnn::dataset::{conv1_workload, ConvWorkload};
use crate::dnn::layers::{conv2d, conv2d_f64};
use crate::dnn::metrics::mean_relative_accuracy;

/// One assembled Table I row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub label: String,
    pub accuracy: f64,
    pub area_um2: f64,
    pub delay_ns: f64,
    pub power_mw: f64,
    pub perf_gops: f64,
    pub area_eff: f64,
    pub energy_eff: f64,
}

/// Workload parameters for the accuracy column.
#[derive(Clone, Copy, Debug)]
pub struct Table1Params {
    pub seed: u64,
    /// input spatial size of the synthetic conv1 image
    pub hw: usize,
    /// output channels evaluated
    pub out_channels: usize,
}

impl Default for Table1Params {
    fn default() -> Self {
        Self { seed: 2023, hw: 32, out_channels: 8 }
    }
}

/// Compute the accuracy column: run every unit over the same conv1-like
/// workload and compare against the FP64 reference.
pub fn accuracy_column(params: &Table1Params) -> Vec<(String, f64)> {
    let wl = conv1_workload(params.seed, params.hw, params.out_channels);
    let reference = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);
    table1_units()
        .iter()
        .map(|u| {
            let out = conv2d(u.as_ref(), &wl.image, &wl.weights, wl.stride, wl.pad);
            (u.name(), mean_relative_accuracy(out.data(), reference.data()))
        })
        .collect()
}

/// Accuracy of one specific unit on the standard workload (used by
/// ablations and tests).
pub fn unit_accuracy(unit: &dyn DotArch, params: &Table1Params) -> f64 {
    let wl = conv1_workload(params.seed, params.hw, params.out_channels);
    unit_accuracy_on(unit, &wl)
}

pub fn unit_accuracy_on(unit: &dyn DotArch, wl: &ConvWorkload) -> f64 {
    let reference = conv2d_f64(&wl.image, &wl.weights, wl.stride, wl.pad);
    let out = conv2d(unit, &wl.image, &wl.weights, wl.stride, wl.pad);
    mean_relative_accuracy(out.data(), reference.data())
}

/// Assemble the full table: accuracy column + cost columns. Row order and
/// labels follow the paper's Table I.
pub fn build(params: &Table1Params, tech: &Tech) -> Vec<Table1Row> {
    let acc = accuracy_column(params);
    let cost: Vec<Report> = table1_reports(tech);
    assert_eq!(acc.len(), cost.len(), "accuracy and cost row counts must match");
    acc.into_iter()
        .zip(cost)
        .map(|((label, accuracy), r)| Table1Row {
            label,
            accuracy,
            area_um2: r.area_um2,
            delay_ns: r.delay_ns,
            power_mw: r.power_mw,
            perf_gops: r.perf_gops(),
            area_eff: r.area_eff(),
            energy_eff: r.energy_eff(),
        })
        .collect()
}

/// The §IV-A headline claims derived from the table (experiment A1).
#[derive(Clone, Debug)]
pub struct Claims {
    /// vs PACoGen DPU (paper: 0.43 / 0.64 / 0.70)
    pub area_saving_vs_pacogen: f64,
    pub delay_saving_vs_pacogen: f64,
    pub power_saving_vs_pacogen: f64,
    /// vs quire PDPU (paper: 5.0× / 2.1×)
    pub area_eff_gain_vs_quire: f64,
    pub energy_eff_gain_vs_quire: f64,
    /// vs posit FMA (paper: 3.1× / 3.5×)
    pub area_eff_gain_vs_posit_fma: f64,
    pub energy_eff_gain_vs_posit_fma: f64,
}

pub fn claims(rows: &[Table1Row]) -> Claims {
    let find = |frag: &str| {
        rows.iter().find(|r| r.label.contains(frag)).unwrap_or_else(|| panic!("missing row {frag}"))
    };
    let pdpu = find("PDPU P(13/16,2) N=4");
    let paco = find("PACoGen");
    let quire = find("Quire");
    let pfma = find("Posit FMA");
    Claims {
        area_saving_vs_pacogen: 1.0 - pdpu.area_um2 / paco.area_um2,
        delay_saving_vs_pacogen: 1.0 - pdpu.delay_ns / paco.delay_ns,
        power_saving_vs_pacogen: 1.0 - pdpu.power_mw / paco.power_mw,
        area_eff_gain_vs_quire: pdpu.area_eff / quire.area_eff,
        energy_eff_gain_vs_quire: pdpu.energy_eff / quire.energy_eff,
        area_eff_gain_vs_posit_fma: pdpu.area_eff / pfma.area_eff,
        energy_eff_gain_vs_posit_fma: pdpu.energy_eff / pfma.energy_eff,
    }
}

/// Render the table in the paper's column layout.
pub fn render(rows: &[Table1Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28} {:>9} {:>10} {:>7} {:>8} {:>7} {:>12} {:>10}\n",
        "Architecture", "Accuracy", "Area(um2)", "Delay", "Power", "Perf", "AreaEff", "EnergyEff"
    ));
    s.push_str(&format!(
        "{:<28} {:>9} {:>10} {:>7} {:>8} {:>7} {:>12} {:>10}\n",
        "", "(%)", "", "(ns)", "(mW)", "(GOPS)", "(GOPS/mm2)", "(GOPS/W)"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<28} {:>8.2}% {:>10.0} {:>7.2} {:>8.2} {:>7.2} {:>12.1} {:>10.1}\n",
            r.label,
            100.0 * r.accuracy,
            r.area_um2,
            r.delay_ns,
            r.power_mw,
            r.perf_gops,
            r.area_eff,
            r.energy_eff
        ));
    }
    s
}

/// Paper values for the same table (for EXPERIMENTS.md side-by-side).
pub const PAPER_ROWS: &[(&str, f64, f64, f64, f64)] = &[
    // (label fragment, accuracy %, area um2, delay ns, power mW)
    ("FPnew DPU FP32", 100.0, 28563.19, 3.45, 7.60),
    ("FPnew DPU FP16", 91.21, 13448.99, 2.75, 4.29),
    ("PACoGen DPU", 98.86, 13433.11, 4.45, 12.21),
    ("PDPU P(16/16,2) N=4", 99.10, 9579.15, 1.62, 4.49),
    ("PDPU P(13/16,2) N=4", 98.69, 7694.82, 1.60, 3.66),
    ("PDPU P(13/16,2) N=8 Wm=14", 98.68, 13560.37, 1.69, 5.80),
    ("PDPU P(10/16,2) N=8", 89.58, 10006.42, 1.70, 4.24),
    ("PDPU P(13/16,2) N=8 Wm=10", 88.90, 12157.11, 1.66, 5.06),
    ("Quire PDPU", 98.79, 29209.45, 2.10, 5.87),
    ("FPnew FMA FP32", 100.0, 6668.17, 1.20, 3.97),
    ("FPnew FMA FP16", 92.93, 3713.72, 1.00, 2.51),
    ("Posit FMA", 99.23, 7035.34, 1.35, 3.79),
];

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Table1Params {
        // smaller workload for test speed; orderings are robust to size
        Table1Params { seed: 2023, hw: 16, out_channels: 4 }
    }

    #[test]
    fn accuracy_orderings_match_paper() {
        let acc = accuracy_column(&small_params());
        let get = |frag: &str| {
            acc.iter().find(|(l, _)| l.contains(frag)).map(|(_, a)| *a).unwrap_or_else(|| panic!("{frag}"))
        };
        let fp32 = get("FPnew DPU FP32");
        let fp16 = get("FPnew DPU FP16");
        let pacogen = get("PACoGen");
        let pdpu16 = get("PDPU P(16/16,2) N=4");
        let pdpu13 = get("PDPU P(13/16,2) N=4");
        let pdpu10 = get("PDPU P(10/16,2)");
        let quire = get("Quire");

        // FP32 is (essentially) the reference
        assert!(fp32 > 0.999, "fp32 {fp32}");
        // 16-bit posit beats FP16 at equal word size (the paper's central
        // accuracy claim, rows PACoGen/PDPU-16 vs FPnew-FP16)
        for (name, v) in [("pacogen", pacogen), ("pdpu16", pdpu16)] {
            assert!(v > fp16, "{name} ({v}) must beat FP16 ({fp16})");
        }
        // NOTE: the paper's FP16 row drops all the way to 91.21 % — below
        // even the 13-bit-input PDPU — on the authors' (unpublished)
        // ImageNet conv1 tensor + metric. Our synthetic workload
        // reproduces every ordering except that absolute magnitude; see
        // EXPERIMENTS.md §T1 for the divergence note.
        // P(10) inputs cost real accuracy vs P(13) (paper: 98.68 → 89.58)
        assert!(pdpu10 < pdpu13 - 0.01, "p10 {pdpu10} vs p13 {pdpu13}");
        // quire ≈ pdpu13 (negligible loss from Wm=14: paper 98.79 vs 98.69)
        assert!((quire - pdpu13).abs() < 0.02, "quire {quire} pdpu13 {pdpu13}");
        // mixed precision costs a little accuracy vs uniform P(16,2)
        // (paper: 99.10 → 98.69)
        assert!(pdpu13 < pdpu16, "pdpu13 {pdpu13} vs pdpu16 {pdpu16}");
        // everything sane
        for (l, a) in &acc {
            assert!((0.0..=1.0).contains(a), "{l}: {a}");
        }
    }

    #[test]
    fn full_table_assembles() {
        let rows = build(&small_params(), &Tech::default());
        assert_eq!(rows.len(), 12);
        let rendered = render(&rows);
        assert!(rendered.contains("PACoGen"));
        assert!(rendered.lines().count() >= 14);
    }

    #[test]
    fn claims_directions_match_paper() {
        let rows = build(&small_params(), &Tech::default());
        let c = claims(&rows);
        // paper: 43% / 64% / 70% savings — require the direction plus
        // at least half the magnitude from the structural model
        assert!(c.area_saving_vs_pacogen > 0.25, "{c:?}");
        assert!(c.delay_saving_vs_pacogen > 0.40, "{c:?}");
        assert!(c.power_saving_vs_pacogen > 0.40, "{c:?}");
        // paper: 5.0× / 2.1× vs quire
        assert!(c.area_eff_gain_vs_quire > 2.5, "{c:?}");
        assert!(c.energy_eff_gain_vs_quire > 1.5, "{c:?}");
        // paper: 3.1× / 3.5× vs posit FMA
        assert!(c.area_eff_gain_vs_posit_fma > 1.8, "{c:?}");
        assert!(c.energy_eff_gain_vs_posit_fma > 1.8, "{c:?}");
    }

    #[test]
    fn paper_reference_rows_complete() {
        assert_eq!(PAPER_ROWS.len(), 12);
    }
}
