//! PDPU-array scheduler — maps DNN dot-product workloads onto an array of
//! 6-stage-pipelined PDPUs, cycle-accurately.
//!
//! The scheduling problem the paper's pipeline creates: chunk-based
//! accumulation makes chunk k+1 of one output RAW-dependent on chunk k
//! (6-cycle latency), so a single output pixel cannot keep one unit busy.
//! The scheduler interleaves *independent* outputs (different pixels /
//! channels) across each unit's pipeline — the same trick systolic
//! accelerators use — recovering ~1 MAC-chunk per unit per cycle.
//!
//! Used by the Fig. 6-derived throughput analyses, the serving examples
//! and `cargo bench --bench bench_schedule`.

use crate::pdpu::pipeline::{Pipeline, STAGES};

/// One dot-product job: `dot_len` MACs chunked into ⌈dot_len/n⌉ dependent
/// pipeline operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DotJob {
    /// Caller-chosen job identity (carried through for bookkeeping).
    pub id: u64,
    /// Dot-product length in MACs.
    pub dot_len: usize,
}

/// Array-level schedule outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleReport {
    /// PDPU units in the array.
    pub units: usize,
    /// Chunk size N of each unit.
    pub n: usize,
    /// Jobs scheduled.
    pub jobs: usize,
    /// Pipeline operations issued (chunks across all jobs).
    pub total_chunks: u64,
    /// Cycles until the last chunk retired.
    pub cycles: u64,
    /// chunks retired per unit-cycle (1.0 = perfect)
    pub utilization: f64,
    /// MACs per cycle across the array
    pub macs_per_cycle: f64,
}

/// Per-unit work queue state.
struct UnitState {
    pipe: Pipeline,
    /// (job, chunks_remaining, last_issued_op) chains assigned to this unit
    chains: Vec<(u64, u64, Option<u64>)>,
    rr: usize,
    next_op: u64,
}

/// Schedule `jobs` across `units` PDPUs with chunk size `n`; each unit
/// interleaves up to `interleave` independent accumulation chains.
pub fn schedule(jobs: &[DotJob], units: usize, n: usize, interleave: usize) -> ScheduleReport {
    assert!(units >= 1 && n >= 1 && interleave >= 1);
    let mut queues: Vec<Vec<(u64, u64)>> = vec![Vec::new(); units];
    let mut total_chunks = 0u64;
    for (i, j) in jobs.iter().enumerate() {
        let chunks = j.dot_len.div_ceil(n) as u64;
        total_chunks += chunks;
        queues[i % units].push((j.id, chunks));
    }

    let mut states: Vec<UnitState> = queues
        .iter()
        .enumerate()
        .map(|(u, _)| UnitState {
            pipe: Pipeline::new(),
            chains: Vec::new(),
            rr: 0,
            next_op: (u as u64) << 40,
        })
        .collect();
    // reverse so pop() takes jobs in order
    for q in &mut queues {
        q.reverse();
    }

    let mut cycles = 0u64;
    loop {
        let mut all_done = true;
        for (u, st) in states.iter_mut().enumerate() {
            // top up interleaved chains
            while st.chains.len() < interleave {
                match queues[u].pop() {
                    Some((id, chunks)) => st.chains.push((id, chunks, None)),
                    None => break,
                }
            }
            if !st.chains.is_empty() || !st.pipe.is_empty() || !queues[u].is_empty() {
                all_done = false;
            }
            // pick an issuable chain round-robin
            let mut offer = None;
            for k in 0..st.chains.len() {
                let idx = (st.rr + k) % st.chains.len();
                let (_, _, dep) = st.chains[idx];
                if st.pipe.can_issue(dep) {
                    offer = Some(idx);
                    break;
                }
            }
            let tick = match offer {
                Some(idx) => {
                    let op = st.next_op;
                    st.next_op += 1;
                    let dep = st.chains[idx].2;
                    let r = st.pipe.tick(Some((op, dep)));
                    if r.stalled.is_none() {
                        let chain = &mut st.chains[idx];
                        chain.1 -= 1;
                        chain.2 = Some(op);
                        if chain.1 == 0 {
                            st.chains.remove(idx);
                        }
                        st.rr = st.rr.wrapping_add(1);
                    } else {
                        st.next_op -= 1; // op not accepted; reuse the id
                    }
                    r
                }
                None => st.pipe.tick(None),
            };
            let _ = tick;
        }
        if all_done {
            break;
        }
        cycles += 1;
        // safety valve for bugs: no schedule needs more than
        // chunks·STAGES + jobs·STAGES cycles even fully serialized
        assert!(
            cycles <= (total_chunks + jobs.len() as u64 + 1) * STAGES as u64 + 100,
            "scheduler failed to converge"
        );
    }

    let retired: u64 = states.iter().map(|s| s.pipe.stats().retired).sum();
    debug_assert_eq!(retired, total_chunks);
    let util = if cycles == 0 { 0.0 } else { total_chunks as f64 / (cycles * units as u64) as f64 };
    ScheduleReport {
        units,
        n,
        jobs: jobs.len(),
        total_chunks,
        cycles,
        utilization: util,
        macs_per_cycle: util * n as f64 * units as f64,
    }
}

/// Convenience: the jobs of one conv layer (every output position ×
/// channel is an independent dot product of length `dot_len`).
pub fn conv_jobs(outputs: usize, dot_len: usize) -> Vec<DotJob> {
    (0..outputs as u64).map(|id| DotJob { id, dot_len }).collect()
}

/// Coalesce the job lists of several queued launches into one launch —
/// the array-level counterpart of [`super::fusion`]: a fused request
/// queue presents the scheduler with one job pool instead of a sequence
/// of per-request pools separated by pipeline drains.
pub fn fuse_launches(launches: &[Vec<DotJob>]) -> Vec<DotJob> {
    launches.iter().flat_map(|l| l.iter().copied()).collect()
}

/// Schedule a sequence of launches **without** fusion: each launch runs
/// to completion (full pipeline drain) before the next starts — the
/// unfused serving path's cost model. Compare against
/// `schedule(&fuse_launches(..), ..)` to quantify what cross-request
/// fusion recovers: the drained-pipeline and ragged-tail cycles at every
/// launch boundary.
pub fn schedule_launches(
    launches: &[Vec<DotJob>],
    units: usize,
    n: usize,
    interleave: usize,
) -> ScheduleReport {
    let mut cycles = 0u64;
    let mut total_chunks = 0u64;
    let mut jobs = 0usize;
    for l in launches {
        let r = schedule(l, units, n, interleave);
        cycles += r.cycles;
        total_chunks += r.total_chunks;
        jobs += r.jobs;
    }
    let util = if cycles == 0 { 0.0 } else { total_chunks as f64 / (cycles * units as u64) as f64 };
    ScheduleReport {
        units,
        n,
        jobs,
        total_chunks,
        cycles,
        utilization: util,
        macs_per_cycle: util * n as f64 * units as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_job_fully_serialized() {
        // one length-147 output on one N=4 unit: 37 chunks, each waiting
        // 6 cycles for its predecessor → ~222 cycles, utilization ≈ 1/6
        let r = schedule(&conv_jobs(1, 147), 1, 4, 1);
        assert_eq!(r.total_chunks, 37);
        assert!(r.cycles >= 37 * 6, "RAW chain must serialize: {r:?}");
        assert!(r.utilization < 0.2);
    }

    #[test]
    fn interleaving_recovers_throughput() {
        // 64 independent outputs, interleave 6 chains: pipeline stays full
        let serial = schedule(&conv_jobs(64, 147), 1, 4, 1);
        let inter = schedule(&conv_jobs(64, 147), 1, 4, STAGES);
        assert!(inter.cycles < serial.cycles / 4, "serial {} vs interleaved {}", serial.cycles, inter.cycles);
        assert!(inter.utilization > 0.9, "{inter:?}");
    }

    #[test]
    fn utilization_bounded_by_one() {
        for (jobs, units, n, il) in [(10usize, 2usize, 4usize, 6usize), (100, 4, 8, 6), (3, 8, 4, 2)] {
            let r = schedule(&conv_jobs(jobs, 147), units, n, il);
            assert!(r.utilization <= 1.0 + 1e-9, "{r:?}");
            assert!(r.macs_per_cycle <= (n * units) as f64 + 1e-9);
        }
    }

    #[test]
    fn all_chunks_retire() {
        let r = schedule(&conv_jobs(33, 100), 3, 8, 4);
        assert_eq!(r.total_chunks, 33 * 13);
    }

    #[test]
    fn more_units_scale_throughput() {
        let one = schedule(&conv_jobs(256, 147), 1, 4, STAGES);
        let four = schedule(&conv_jobs(256, 147), 4, 4, STAGES);
        let speedup = one.cycles as f64 / four.cycles as f64;
        assert!(speedup > 3.0, "4 units speedup {speedup}");
    }

    #[test]
    fn bigger_n_fewer_chunks() {
        let n4 = schedule(&conv_jobs(64, 147), 1, 4, STAGES);
        let n8 = schedule(&conv_jobs(64, 147), 1, 8, STAGES);
        assert!(n8.total_chunks < n4.total_chunks);
        assert!(n8.cycles < n4.cycles);
        // MACs/cycle roughly doubles with N at high utilization
        assert!(n8.macs_per_cycle > 1.6 * n4.macs_per_cycle);
    }

    #[test]
    fn empty_jobs_zero_cycles() {
        let r = schedule(&[], 2, 4, 4);
        assert_eq!(r.cycles, 0);
        assert_eq!(r.total_chunks, 0);
    }

    #[test]
    fn fused_launches_beat_serial_launches() {
        // 8 queued requests of 8 outputs each: running them back-to-back
        // drains the pipeline 8 times; fusing them into one job pool keeps
        // it full. Work (chunks) is identical, cycles strictly fewer.
        let launches: Vec<Vec<DotJob>> = (0..8).map(|_| conv_jobs(8, 147)).collect();
        let serial = schedule_launches(&launches, 2, 4, STAGES);
        let fused = schedule(&fuse_launches(&launches), 2, 4, STAGES);
        assert_eq!(serial.total_chunks, fused.total_chunks);
        assert_eq!(serial.jobs, fused.jobs);
        assert!(
            fused.cycles < serial.cycles,
            "fused {} vs serial {}",
            fused.cycles,
            serial.cycles
        );
        assert!(fused.utilization > serial.utilization);
    }

    #[test]
    fn single_launch_fusion_is_identity() {
        let launches = vec![conv_jobs(16, 64)];
        let serial = schedule_launches(&launches, 2, 4, STAGES);
        let fused = schedule(&fuse_launches(&launches), 2, 4, STAGES);
        assert_eq!(serial, fused);
    }

    #[test]
    fn empty_launch_sequence_is_zero() {
        let r = schedule_launches(&[], 2, 4, 4);
        assert_eq!(r.cycles, 0);
        assert_eq!(r.total_chunks, 0);
        assert_eq!(r.jobs, 0);
    }
}
