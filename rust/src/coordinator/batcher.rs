//! Dynamic batcher — the core serving-efficiency mechanism of the L3
//! coordinator. Callers submit single items and block on their own reply
//! channel; a dedicated executor thread forms batches under a
//! size-or-deadline policy (vLLM-router-style) and runs them through the
//! backend in one PJRT invocation.
//!
//! Invariants (property-tested below):
//! * every submitted item gets exactly one reply (response or error);
//! * batches never exceed `max_batch`;
//! * an item waits at most ~`max_wait` before its batch is launched;
//! * replies match their requests (no cross-wiring), in any interleaving.
//!
//! Telemetry: each batcher is bound to one [`OpKind`] — latencies land in
//! that op's histogram, the queue-depth gauge tracks waiting items, and
//! the batch-wait gauge records the oldest item's wait at each batch
//! formation. Sampled requests (see [`crate::obs::trace`]) carry a
//! [`TraceCtx`] through the queue: the batcher emits a `queue_wait` and a
//! `batch_exec` span per sampled item and hands the batch's first sampled
//! context to the backend so engine-side spans parent under the request.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::lock_unpoisoned;
use super::metrics::{Metrics, OpKind};
use crate::obs::trace::TraceCtx;

/// Batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many items are queued
    pub max_batch: usize,
    /// …or when the oldest queued item has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Processes one formed batch. Must return exactly one output per input.
pub trait BatchBackend<I: Send, O: Send>: Send {
    /// Execute the batch, one result per item, in item order. `ctx` is
    /// the first sampled request's trace context (if any) so backend-side
    /// spans can parent under it.
    fn run(&mut self, items: Vec<I>, ctx: Option<TraceCtx>) -> Vec<Result<O, String>>;
}

impl<I: Send, O: Send, F: FnMut(Vec<I>, Option<TraceCtx>) -> Vec<Result<O, String>> + Send> BatchBackend<I, O> for F {
    fn run(&mut self, items: Vec<I>, ctx: Option<TraceCtx>) -> Vec<Result<O, String>> {
        self(items, ctx)
    }
}

struct Pending<I, O> {
    item: I,
    reply: Sender<Result<O, String>>,
    enqueued: Instant,
    ctx: Option<TraceCtx>,
}

/// Shared handle for submitting work.
pub struct Batcher<I: Send, O: Send> {
    queue: Arc<Mutex<Vec<Pending<I, O>>>>,
    metrics: Arc<Metrics>,
    kind: OpKind,
    shutdown: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl<I: Send + 'static, O: Send + 'static> Batcher<I, O> {
    /// Spawn the executor thread over `backend`, recording telemetry
    /// under `kind`.
    pub fn spawn(
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        kind: OpKind,
        mut backend: impl BatchBackend<I, O> + 'static,
    ) -> Self {
        let queue: Arc<Mutex<Vec<Pending<I, O>>>> = Arc::new(Mutex::new(Vec::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (q, m, sd) = (queue.clone(), metrics.clone(), shutdown.clone());
        let worker = std::thread::spawn(move || loop {
            // form a batch under the policy
            let batch: Vec<Pending<I, O>> = {
                let mut guard = lock_unpoisoned(&q);
                let ready = guard.len() >= policy.max_batch
                    || guard.first().is_some_and(|p| p.enqueued.elapsed() >= policy.max_wait);
                if ready {
                    let take = guard.len().min(policy.max_batch);
                    guard.drain(..take).collect()
                } else {
                    Vec::new()
                }
            };
            if batch.is_empty() {
                if sd.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_micros(100));
                continue;
            }
            m.record_batch(batch.len());
            m.queue_leave(kind, batch.len());
            if let Some(oldest) = batch.first() {
                m.record_batch_wait(kind, oldest.enqueued.elapsed());
            }
            // queue-wait spans for sampled items; the first sampled item's
            // context rides along to the backend as the batch's parent
            let mut batch_ctx: Option<TraceCtx> = None;
            for p in &batch {
                if let Some(c) = p.ctx {
                    if batch_ctx.is_none() {
                        batch_ctx = Some(c);
                    }
                    let waited_ns = p.enqueued.elapsed().as_nanos() as u64;
                    crate::obs::trace::record_ending_now("queue_wait", Some(c), waited_ns);
                }
            }
            let started: Vec<Instant> = batch.iter().map(|p| p.enqueued).collect();
            let ctxs: Vec<Option<TraceCtx>> = batch.iter().map(|p| p.ctx).collect();
            let (items, replies): (Vec<I>, Vec<Sender<Result<O, String>>>) =
                batch.into_iter().map(|p| (p.item, p.reply)).unzip();
            let n = items.len();
            let exec0 = crate::obs::clock::now();
            let mut results = backend.run(items, batch_ctx);
            let exec_ns = exec0.elapsed().as_nanos() as u64;
            if results.len() != n {
                let msg = format!("backend returned {} results for {} items", results.len(), n);
                results = (0..n).map(|_| Err(msg.clone())).collect();
            }
            for (((r, tx), t0), ctx) in results.into_iter().zip(replies).zip(started).zip(ctxs) {
                crate::obs::trace::record_ending_now("batch_exec", ctx, exec_ns);
                // observed for successes AND errors — the per-op histogram
                // carries its own count, so this cannot skew the mean
                m.observe_latency(kind, t0.elapsed());
                if r.is_ok() {
                    m.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                } else {
                    m.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                let _ = tx.send(r); // receiver may have given up; fine
            }
        });
        Self { queue, metrics, kind, shutdown, worker: Some(worker) }
    }

    /// Submit one item and get the receiver for its reply.
    pub fn submit(&self, item: I) -> Receiver<Result<O, String>> {
        self.submit_traced(item, None)
    }

    /// Submit one item carrying a trace context (sampled requests).
    pub fn submit_traced(&self, item: I, ctx: Option<TraceCtx>) -> Receiver<Result<O, String>> {
        self.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.queue_enter(self.kind);
        let (tx, rx) = channel();
        lock_unpoisoned(&self.queue).push(Pending { item, reply: tx, enqueued: crate::obs::clock::now(), ctx });
        rx
    }

    /// Submit and block for the reply.
    pub fn call(&self, item: I) -> Result<O, String> {
        self.call_traced(item, None)
    }

    /// Submit with a trace context and block for the reply.
    pub fn call_traced(&self, item: I, ctx: Option<TraceCtx>) -> Result<O, String> {
        self.submit_traced(item, ctx).recv().map_err(|_| "batcher shut down".to_string())?
    }
}

impl<I: Send, O: Send> Drop for Batcher<I, O> {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn echo_backend() -> impl BatchBackend<u64, u64> {
        |items: Vec<u64>, _ctx: Option<TraceCtx>| items.into_iter().map(|v| Ok(v * 2)).collect::<Vec<_>>()
    }

    #[test]
    fn single_item_roundtrip() {
        let b = Batcher::spawn(BatchPolicy::default(), Arc::new(Metrics::new()), OpKind::Infer, echo_backend());
        assert_eq!(b.call(21), Ok(42));
    }

    #[test]
    fn batches_respect_max_size() {
        let m = Arc::new(Metrics::new());
        let seen = Arc::new(Mutex::new(Vec::<usize>::new()));
        let seen2 = seen.clone();
        let backend = move |items: Vec<u64>, _ctx: Option<TraceCtx>| {
            seen2.lock().unwrap().push(items.len());
            items.into_iter().map(Ok).collect::<Vec<_>>()
        };
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
            m,
            OpKind::Infer,
            backend,
        );
        // submit 10 quickly from this thread, then drain
        let rxs: Vec<_> = (0..10).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let sizes = seen.lock().unwrap().clone();
        assert!(sizes.iter().all(|&s| s <= 4), "{sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 10);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(5) },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            echo_backend(),
        );
        let t0 = Instant::now();
        assert_eq!(b.call(5), Ok(10));
        assert!(t0.elapsed() < Duration::from_millis(200), "timeout flush too slow");
    }

    #[test]
    fn replies_match_requests_under_concurrency() {
        let b = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            echo_backend(),
        ));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seeded(t);
                for _ in 0..50 {
                    let v = rng.next_u64() % 1_000_000;
                    assert_eq!(b.call(v), Ok(v * 2));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn backend_errors_propagate() {
        let backend = |items: Vec<u64>, _ctx: Option<TraceCtx>| {
            items.into_iter().map(|v| if v % 2 == 0 { Ok(v) } else { Err("odd".to_string()) }).collect::<Vec<_>>()
        };
        let m = Arc::new(Metrics::new());
        let b = Batcher::spawn(BatchPolicy::default(), m.clone(), OpKind::Infer, backend);
        assert_eq!(b.call(2), Ok(2));
        assert_eq!(b.call(3), Err("odd".to_string()));
        let s = m.snapshot();
        assert_eq!(s.errors, 1);
        // the error reply's latency was observed in the op histogram too
        assert_eq!(s.infer.latency.count, 2);
    }

    #[test]
    fn wrong_cardinality_backend_errors_everyone() {
        let backend = |_items: Vec<u64>, _ctx: Option<TraceCtx>| vec![Ok(1u64)]; // always 1 result
        let b = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            backend,
        ));
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // either lone items succeeded (batch of 1) or mismatches errored;
        // nobody hangs and cardinality is preserved
        assert_eq!(results.len(), 4);
    }

    /// The serving configuration end-to-end: a Batcher whose backend is
    /// the engine-thread handle over the software (batched PDPU GEMM)
    /// service — formed batches run as one engine call, not scalar loops.
    #[test]
    fn batches_run_through_software_engine() {
        use super::super::engine::ServiceHandle;
        use crate::pdpu::PdpuConfig;
        let svc =
            ServiceHandle::start_software(PdpuConfig::paper_default(), vec![6, 3], 8, (2, 2, 2), 1).unwrap();
        let m = Arc::new(Metrics::new());
        let backend_svc = svc.clone();
        let b: Batcher<Vec<f32>, Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            m.clone(),
            OpKind::Infer,
            move |images: Vec<Vec<f32>>, ctx: Option<TraceCtx>| {
                let n = images.len();
                match backend_svc.infer_batch_traced(images, ctx) {
                    Ok(outs) => outs.into_iter().map(Ok).collect::<Vec<_>>(),
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                }
            },
        );
        let rxs: Vec<_> = (0..8).map(|i| b.submit(vec![i as f32 / 8.0; 6])).collect();
        for rx in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), 3);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert!(m.snapshot().batches >= 1);
        svc.shutdown();
    }

    /// The fused GEMM serving configuration end-to-end: a Batcher whose
    /// backend runs formed batches through `SoftwareService::gemm_batch`
    /// (cross-request fusion). Under concurrent submission in any
    /// interleaving, every reply must be bit-identical to that request's
    /// own unfused `gemm` — fusion must never cross-wire or renumber
    /// responses.
    #[test]
    fn fused_gemm_replies_match_requests_under_concurrency() {
        use super::super::service::SoftwareService;
        use crate::pdpu::PdpuConfig;
        let svc = Arc::new(SoftwareService::new(PdpuConfig::paper_default(), &[4, 3], 4, (3, 4, 2), 0xFEE1).unwrap());
        let (m, k, n) = svc.gemm_mkn();
        let backend_svc = svc.clone();
        let b: Arc<Batcher<(Vec<f32>, Vec<f32>), Vec<f32>>> = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            Arc::new(Metrics::new()),
            OpKind::Gemm,
            move |reqs: Vec<(Vec<f32>, Vec<f32>)>, _ctx: Option<TraceCtx>| backend_svc.gemm_batch(&reqs).0,
        ));
        // a few shared left planes so formed batches really fuse
        let planes: Vec<Vec<f32>> = (0..2)
            .map(|p| (0..m * k).map(|i| ((i + p) as f32 * 0.31).sin()).collect())
            .collect();
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let b = b.clone();
            let svc = svc.clone();
            let planes = planes.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seeded(0x6E44 ^ t);
                for _ in 0..20 {
                    let a = planes[rng.below(planes.len() as u64) as usize].clone();
                    let bm: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                    let got = b.call((a.clone(), bm.clone())).unwrap();
                    let want = svc.gemm(&a, &bm).unwrap();
                    assert_eq!(
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn metrics_track_batching() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
            m.clone(),
            OpKind::Infer,
            echo_backend(),
        );
        let rxs: Vec<_> = (0..6).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.responses, 6);
        assert!(s.batches >= 3);
        // every latency landed in this batcher's op histogram, and the
        // queue gauge returned to zero once everything drained
        assert_eq!(s.infer.latency.count, 6);
        assert_eq!(s.infer.queue_depth, 0);
    }
}
