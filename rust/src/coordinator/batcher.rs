//! Dynamic batcher — the core serving-efficiency mechanism of the L3
//! coordinator. Callers submit single items and block on their own reply
//! channel; a dedicated executor thread forms batches under a
//! size-or-deadline policy (vLLM-router-style) and runs them through the
//! backend in one PJRT invocation.
//!
//! Batch formation is **condvar-driven**: the worker blocks on the queue
//! condvar and times out exactly at the oldest item's deadline, so an
//! idle batcher burns no CPU (the original worker slept/polled every
//! 100µs) and new work is picked up without polling latency. The queue is
//! **bounded** for wire callers: [`Batcher::try_submit_traced`] refuses
//! beyond `max_queue` so the serving tier can shed under overload.
//!
//! Invariants (property-tested below):
//! * every submitted item gets exactly one reply (response or error);
//! * batches never exceed `max_batch`;
//! * an item waits at most ~`max_wait` before its batch is launched;
//! * replies match their requests (no cross-wiring), in any interleaving.
//!
//! Telemetry: each batcher is bound to one [`OpKind`] — latencies land in
//! that op's histogram, the queue-depth gauge tracks waiting items, and
//! the batch-wait gauge records the oldest item's wait at each batch
//! formation. Sampled requests (see [`crate::obs::trace`]) carry a
//! [`TraceCtx`] through the queue: the batcher emits a `queue_wait` and a
//! `batch_exec` span per sampled item and hands the batch's first sampled
//! context to the backend so engine-side spans parent under the request.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::lock_unpoisoned;
use super::metrics::{Metrics, OpKind};
use crate::obs::trace::TraceCtx;

/// Batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// launch as soon as this many items are queued
    pub max_batch: usize,
    /// …or when the oldest queued item has waited this long
    pub max_wait: Duration,
    /// bound on queued (not yet batched) items; [`Batcher::try_submit_traced`]
    /// refuses beyond it so the serving tier can shed instead of queueing
    /// without limit (the trusting [`Batcher::submit`] path ignores it)
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_millis(2), max_queue: 1024 }
    }
}

/// Processes one formed batch. Must return exactly one output per input.
pub trait BatchBackend<I: Send, O: Send>: Send {
    /// Execute the batch, one result per item, in item order. `ctx` is
    /// the first sampled request's trace context (if any) so backend-side
    /// spans can parent under it.
    fn run(&mut self, items: Vec<I>, ctx: Option<TraceCtx>) -> Vec<Result<O, String>>;
}

impl<I: Send, O: Send, F: FnMut(Vec<I>, Option<TraceCtx>) -> Vec<Result<O, String>> + Send> BatchBackend<I, O> for F {
    fn run(&mut self, items: Vec<I>, ctx: Option<TraceCtx>) -> Vec<Result<O, String>> {
        self(items, ctx)
    }
}

struct Pending<I, O> {
    item: I,
    reply: Sender<Result<O, String>>,
    enqueued: Instant,
    ctx: Option<TraceCtx>,
}

/// The condvar-protected batcher state: the pending queue plus the
/// shutdown flag, under one mutex so wakeups can never be missed.
struct Queue<I, O> {
    items: Vec<Pending<I, O>>,
    shutdown: bool,
}

/// Shared handle for submitting work.
pub struct Batcher<I: Send, O: Send> {
    q: Arc<(Mutex<Queue<I, O>>, Condvar)>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    kind: OpKind,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl<I: Send + 'static, O: Send + 'static> Batcher<I, O> {
    /// Spawn the executor thread over `backend`, recording telemetry
    /// under `kind`.
    ///
    /// Batch formation is condvar-driven: the worker sleeps on the queue's
    /// condvar (timing out exactly at the oldest item's deadline) instead
    /// of polling on a 100µs sleep, so an idle batcher costs nothing and a
    /// submitted item is noticed immediately.
    pub fn spawn(
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        kind: OpKind,
        mut backend: impl BatchBackend<I, O> + 'static,
    ) -> Self {
        let q: Arc<(Mutex<Queue<I, O>>, Condvar)> =
            Arc::new((Mutex::new(Queue { items: Vec::new(), shutdown: false }), Condvar::new()));
        let (qw, m) = (q.clone(), metrics.clone());
        let worker = std::thread::spawn(move || {
            let (lock, cv) = &*qw;
            let mut guard = lock_unpoisoned(lock);
            loop {
                // form a batch under the policy; a shutdown with queued
                // items still drains them so no caller is left hanging
                let ready = guard.items.len() >= policy.max_batch
                    || (guard.shutdown && !guard.items.is_empty())
                    || guard.items.first().is_some_and(|p| p.enqueued.elapsed() >= policy.max_wait);
                if !ready {
                    if guard.shutdown {
                        return;
                    }
                    guard = match guard
                        .items
                        .first()
                        .map(|p| policy.max_wait.saturating_sub(p.enqueued.elapsed()))
                    {
                        // oldest item pending: sleep exactly until its deadline
                        Some(remaining) => {
                            cv.wait_timeout(guard, remaining).unwrap_or_else(|e| e.into_inner()).0
                        }
                        // empty queue: sleep until a submit or shutdown wakes us
                        None => cv.wait(guard).unwrap_or_else(|e| e.into_inner()),
                    };
                    continue;
                }
                let take = guard.items.len().min(policy.max_batch);
                let batch: Vec<Pending<I, O>> = guard.items.drain(..take).collect();
                drop(guard); // run the backend without holding the queue lock
                m.record_batch(batch.len());
                m.queue_leave(kind, batch.len());
                if let Some(oldest) = batch.first() {
                    m.record_batch_wait(kind, oldest.enqueued.elapsed());
                }
                // queue-wait spans for sampled items; the first sampled item's
                // context rides along to the backend as the batch's parent
                let mut batch_ctx: Option<TraceCtx> = None;
                for p in &batch {
                    if let Some(c) = p.ctx {
                        if batch_ctx.is_none() {
                            batch_ctx = Some(c);
                        }
                        let waited_ns = p.enqueued.elapsed().as_nanos() as u64;
                        crate::obs::trace::record_ending_now("queue_wait", Some(c), waited_ns);
                    }
                }
                let started: Vec<Instant> = batch.iter().map(|p| p.enqueued).collect();
                let ctxs: Vec<Option<TraceCtx>> = batch.iter().map(|p| p.ctx).collect();
                let (items, replies): (Vec<I>, Vec<Sender<Result<O, String>>>) =
                    batch.into_iter().map(|p| (p.item, p.reply)).unzip();
                let n = items.len();
                let exec0 = crate::obs::clock::now();
                let mut results = backend.run(items, batch_ctx);
                let exec_ns = exec0.elapsed().as_nanos() as u64;
                if results.len() != n {
                    let msg = format!("backend returned {} results for {} items", results.len(), n);
                    results = (0..n).map(|_| Err(msg.clone())).collect();
                }
                for (((r, tx), t0), ctx) in results.into_iter().zip(replies).zip(started).zip(ctxs) {
                    crate::obs::trace::record_ending_now("batch_exec", ctx, exec_ns);
                    // observed for successes AND errors — the per-op histogram
                    // carries its own count, so this cannot skew the mean
                    m.observe_latency(kind, t0.elapsed());
                    if r.is_ok() {
                        m.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    } else {
                        m.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    let _ = tx.send(r); // receiver may have given up; fine
                }
                guard = lock_unpoisoned(lock);
            }
        });
        Self { q, policy, metrics, kind, worker: Some(worker) }
    }

    /// Submit one item and get the receiver for its reply.
    pub fn submit(&self, item: I) -> Receiver<Result<O, String>> {
        self.submit_traced(item, None)
    }

    /// Submit one item carrying a trace context (sampled requests). This
    /// trusting path never sheds — it is for in-process callers; the wire
    /// front end goes through [`Batcher::try_submit_traced`].
    pub fn submit_traced(&self, item: I, ctx: Option<TraceCtx>) -> Receiver<Result<O, String>> {
        self.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.queue_enter(self.kind);
        let (tx, rx) = channel();
        let (lock, cv) = &*self.q;
        lock_unpoisoned(lock).items.push(Pending {
            item,
            reply: tx,
            enqueued: crate::obs::clock::now(),
            ctx,
        });
        cv.notify_one();
        rx
    }

    /// Bounded submit: refuses (returning `None`, touching no counters)
    /// when the queue already holds `max_queue` items, so the caller can
    /// shed the request instead of queueing without limit. Shed
    /// accounting belongs to the caller ([`Metrics::record_shed`]).
    pub fn try_submit_traced(&self, item: I, ctx: Option<TraceCtx>) -> Option<Receiver<Result<O, String>>> {
        let (tx, rx) = channel();
        {
            let (lock, cv) = &*self.q;
            let mut queue = lock_unpoisoned(lock);
            if queue.items.len() >= self.policy.max_queue {
                return None;
            }
            self.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.metrics.queue_enter(self.kind);
            queue.items.push(Pending { item, reply: tx, enqueued: crate::obs::clock::now(), ctx });
            cv.notify_one();
        }
        Some(rx)
    }

    /// Submit and block for the reply.
    pub fn call(&self, item: I) -> Result<O, String> {
        self.call_traced(item, None)
    }

    /// Submit with a trace context and block for the reply.
    pub fn call_traced(&self, item: I, ctx: Option<TraceCtx>) -> Result<O, String> {
        self.submit_traced(item, ctx).recv().map_err(|_| "batcher shut down".to_string())?
    }

    /// Bounded submit-and-block: `None` means the queue was full and the
    /// item was never enqueued (shed it); `Some` carries the reply.
    pub fn try_call_traced(&self, item: I, ctx: Option<TraceCtx>) -> Option<Result<O, String>> {
        let rx = self.try_submit_traced(item, ctx)?;
        Some(rx.recv().unwrap_or_else(|_| Err("batcher shut down".to_string())))
    }
}

impl<I: Send, O: Send> Drop for Batcher<I, O> {
    fn drop(&mut self) {
        let (lock, cv) = &*self.q;
        lock_unpoisoned(lock).shutdown = true;
        cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn echo_backend() -> impl BatchBackend<u64, u64> {
        |items: Vec<u64>, _ctx: Option<TraceCtx>| items.into_iter().map(|v| Ok(v * 2)).collect::<Vec<_>>()
    }

    #[test]
    fn single_item_roundtrip() {
        let b = Batcher::spawn(BatchPolicy::default(), Arc::new(Metrics::new()), OpKind::Infer, echo_backend());
        assert_eq!(b.call(21), Ok(42));
    }

    #[test]
    fn batches_respect_max_size() {
        let m = Arc::new(Metrics::new());
        let seen = Arc::new(Mutex::new(Vec::<usize>::new()));
        let seen2 = seen.clone();
        let backend = move |items: Vec<u64>, _ctx: Option<TraceCtx>| {
            seen2.lock().unwrap().push(items.len());
            items.into_iter().map(Ok).collect::<Vec<_>>()
        };
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50), ..BatchPolicy::default() },
            m,
            OpKind::Infer,
            backend,
        );
        // submit 10 quickly from this thread, then drain
        let rxs: Vec<_> = (0..10).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let sizes = seen.lock().unwrap().clone();
        assert!(sizes.iter().all(|&s| s <= 4), "{sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 10);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(5), ..BatchPolicy::default() },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            echo_backend(),
        );
        let t0 = Instant::now();
        assert_eq!(b.call(5), Ok(10));
        assert!(t0.elapsed() < Duration::from_millis(200), "timeout flush too slow");
    }

    #[test]
    fn replies_match_requests_under_concurrency() {
        let b = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            echo_backend(),
        ));
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seeded(t);
                for _ in 0..50 {
                    let v = rng.next_u64() % 1_000_000;
                    assert_eq!(b.call(v), Ok(v * 2));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn backend_errors_propagate() {
        let backend = |items: Vec<u64>, _ctx: Option<TraceCtx>| {
            items.into_iter().map(|v| if v % 2 == 0 { Ok(v) } else { Err("odd".to_string()) }).collect::<Vec<_>>()
        };
        let m = Arc::new(Metrics::new());
        let b = Batcher::spawn(BatchPolicy::default(), m.clone(), OpKind::Infer, backend);
        assert_eq!(b.call(2), Ok(2));
        assert_eq!(b.call(3), Err("odd".to_string()));
        let s = m.snapshot();
        assert_eq!(s.errors, 1);
        // the error reply's latency was observed in the op histogram too
        assert_eq!(s.infer.latency.count, 2);
    }

    #[test]
    fn wrong_cardinality_backend_errors_everyone() {
        let backend = |_items: Vec<u64>, _ctx: Option<TraceCtx>| vec![Ok(1u64)]; // always 1 result
        let b = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
            Arc::new(Metrics::new()),
            OpKind::Infer,
            backend,
        ));
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // either lone items succeeded (batch of 1) or mismatches errored;
        // nobody hangs and cardinality is preserved
        assert_eq!(results.len(), 4);
    }

    /// The serving configuration end-to-end: a Batcher whose backend is
    /// the engine-thread handle over the software (batched PDPU GEMM)
    /// service — formed batches run as one engine call, not scalar loops.
    #[test]
    fn batches_run_through_software_engine() {
        use super::super::engine::ServiceHandle;
        use crate::pdpu::PdpuConfig;
        let svc =
            ServiceHandle::start_software(PdpuConfig::paper_default(), vec![6, 3], 8, (2, 2, 2), 1).unwrap();
        let m = Arc::new(Metrics::new());
        let backend_svc = svc.clone();
        let b: Batcher<Vec<f32>, Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
            m.clone(),
            OpKind::Infer,
            move |images: Vec<Vec<f32>>, ctx: Option<TraceCtx>| {
                let n = images.len();
                match backend_svc.infer_batch_traced(images, ctx) {
                    Ok(outs) => outs.into_iter().map(Ok).collect::<Vec<_>>(),
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                }
            },
        );
        let rxs: Vec<_> = (0..8).map(|i| b.submit(vec![i as f32 / 8.0; 6])).collect();
        for rx in rxs {
            let logits = rx.recv().unwrap().unwrap();
            assert_eq!(logits.len(), 3);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert!(m.snapshot().batches >= 1);
        svc.shutdown();
    }

    /// The fused GEMM serving configuration end-to-end: a Batcher whose
    /// backend runs formed batches through `SoftwareService::gemm_batch`
    /// (cross-request fusion). Under concurrent submission in any
    /// interleaving, every reply must be bit-identical to that request's
    /// own unfused `gemm` — fusion must never cross-wire or renumber
    /// responses.
    #[test]
    fn fused_gemm_replies_match_requests_under_concurrency() {
        use super::super::service::SoftwareService;
        use crate::pdpu::PdpuConfig;
        let svc = Arc::new(SoftwareService::new(PdpuConfig::paper_default(), &[4, 3], 4, (3, 4, 2), 0xFEE1).unwrap());
        let (m, k, n) = svc.gemm_mkn();
        let backend_svc = svc.clone();
        let b: Arc<Batcher<(Vec<f32>, Vec<f32>), Vec<f32>>> = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
            Arc::new(Metrics::new()),
            OpKind::Gemm,
            move |reqs: Vec<(Vec<f32>, Vec<f32>)>, _ctx: Option<TraceCtx>| backend_svc.gemm_batch(&reqs).0,
        ));
        // a few shared left planes so formed batches really fuse
        let planes: Vec<Vec<f32>> = (0..2)
            .map(|p| (0..m * k).map(|i| ((i + p) as f32 * 0.31).sin()).collect())
            .collect();
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let b = b.clone();
            let svc = svc.clone();
            let planes = planes.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seeded(0x6E44 ^ t);
                for _ in 0..20 {
                    let a = planes[rng.below(planes.len() as u64) as usize].clone();
                    let bm: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                    let got = b.call((a.clone(), bm.clone())).unwrap();
                    let want = svc.gemm(&a, &bm).unwrap();
                    assert_eq!(
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    /// The bounded-submit contract, deterministically: with `max_queue: 1`
    /// and the backend parked on a gate, one item can be in flight and one
    /// queued; a third `try_submit` must refuse without touching counters,
    /// and releasing the gate drains the admitted two normally.
    #[test]
    fn bounded_queue_sheds_beyond_max_queue() {
        let m = Arc::new(Metrics::new());
        let (started_tx, started_rx) = channel::<()>();
        let (gate_tx, gate_rx) = channel::<()>();
        let backend = move |items: Vec<u64>, _ctx: Option<TraceCtx>| {
            let _ = started_tx.send(());
            let _ = gate_rx.recv(); // hold the batch until the test releases it
            items.into_iter().map(Ok).collect::<Vec<_>>()
        };
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), max_queue: 1 },
            m.clone(),
            OpKind::Gemm,
            backend,
        );
        let rx_a = b.try_submit_traced(10, None).expect("first submit admitted");
        started_rx.recv().unwrap(); // A drained into the backend; queue empty
        let rx_b = b.try_submit_traced(20, None).expect("second submit queued");
        assert!(b.try_submit_traced(30, None).is_none(), "queue full: must refuse");
        assert_eq!(m.requests.load(std::sync::atomic::Ordering::Relaxed), 2, "refusal counts nothing");
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(rx_a.recv().unwrap(), Ok(10));
        assert_eq!(rx_b.recv().unwrap(), Ok(20));
        let s = m.snapshot();
        assert_eq!((s.requests, s.responses, s.errors), (2, 2, 0));
        assert_eq!(s.gemm.queue_depth, 0);
    }

    #[test]
    fn metrics_track_batching() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::spawn(
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
            m.clone(),
            OpKind::Infer,
            echo_backend(),
        );
        let rxs: Vec<_> = (0..6).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.responses, 6);
        assert!(s.batches >= 3);
        // every latency landed in this batcher's op histogram, and the
        // queue gauge returned to zero once everything drained
        assert_eq!(s.infer.latency.count, 6);
        assert_eq!(s.infer.queue_depth, 0);
    }
}
