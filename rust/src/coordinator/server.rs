//! TCP JSON-lines server — the outward face of the L3 coordinator.
//!
//! Protocol (one JSON object per line, response per line):
//!   {"op":"ping"}                        → {"ok":true,"pong":true}
//!   {"op":"infer","image":[784 floats]}  → {"ok":true,"logits":[10]}
//!   {"op":"stats"}                       → {"ok":true, …counters…}
//!
//! Requests from all connections funnel through one [`Batcher`], so
//! concurrent clients get batched into single PJRT invocations — the
//! serving pattern of vLLM-style routers, at MLP scale.
//!
//! std::net + threads (no tokio in the offline image): one reader thread
//! per connection, one batch-executor thread overall.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::ServiceHandle;
use super::json::{parse, Json};
use super::metrics::Metrics;

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `service` forever (until
    /// the handle is dropped).
    pub fn start(addr: &str, service: ServiceHandle, metrics: Arc<Metrics>) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let svc = service.clone();
        let batcher: Arc<Batcher<Vec<f32>, Vec<f32>>> = Arc::new(Batcher::spawn(
            BatchPolicy { max_batch: service.info().batch, max_wait: std::time::Duration::from_millis(2) },
            metrics.clone(),
            move |images: Vec<Vec<f32>>| {
                let n = images.len();
                match svc.infer_batch(images) {
                    Ok(outs) => outs.into_iter().map(Ok).collect(),
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                }
            },
        ));

        let sd = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let b = batcher.clone();
                        let m = metrics.clone();
                        let svc = service.clone();
                        std::thread::spawn(move || handle_conn(s, b, m, svc));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(Server { addr: local, accept_thread: Some(accept_thread), shutdown })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, std::sync::atomic::Ordering::Relaxed);
        // accept loop wakes on its polling interval
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    batcher: Arc<Batcher<Vec<f32>, Vec<f32>>>,
    metrics: Arc<Metrics>,
    service: ServiceHandle,
) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(&line, &batcher, &metrics, &service);
        if writer.write_all((resp.to_string() + "\n").as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

fn handle_request(
    line: &str,
    batcher: &Batcher<Vec<f32>, Vec<f32>>,
    metrics: &Metrics,
    service: &ServiceHandle,
) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("infer") => {
            let Some(img) = req.get("image").and_then(Json::as_f64_vec) else {
                return err("infer needs 'image': [f64]");
            };
            if img.len() != service.info().input_dim {
                return err(format!("image must have {} pixels", service.info().input_dim));
            }
            let img: Vec<f32> = img.into_iter().map(|v| v as f32).collect();
            match batcher.call(img) {
                Ok(logits) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("logits", Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                Err(e) => err(e),
            }
        }
        Some("stats") => {
            let s = metrics.snapshot();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("requests", Json::Num(s.requests as f64)),
                ("responses", Json::Num(s.responses as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("mean_batch_size", Json::Num(s.mean_batch_size)),
                ("mean_latency_us", Json::Num(s.mean_latency_us)),
                ("p95_latency_us", Json::Num(s.p95_latency_us as f64)),
            ])
        }
        Some(op) => err(format!("unknown op '{op}'")),
        None => err("missing 'op'"),
    }
}
