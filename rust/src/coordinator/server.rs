//! TCP JSON-lines server — the outward face of the L3 coordinator.
//!
//! Protocol (one JSON object per line, response per line):
//!   {"op":"ping"}                        → {"ok":true,"pong":true}
//!   {"op":"infer","image":[784 floats]}  → {"ok":true,"logits":[10]}
//!   {"op":"gemm","a":[M·K],"b":[K·N]}    → {"ok":true,"c":[M·N]}
//!   {"op":"train","images":[[784]…],"labels":[ints]}
//!                                        → {"ok":true,"loss":L}
//!   {"op":"stats"}                       → {"ok":true, …counters…}
//!
//! Requests from all connections funnel through per-op [`Batcher`]s, so
//! concurrent clients get batched into single backend invocations — the
//! serving pattern of vLLM-style routers, at MLP scale. Queued GEMM
//! requests additionally go through **cross-request fusion**
//! ([`super::fusion`]): compatible tiles in one formed batch share a
//! single engine launch, bit-identically to running them one at a time.
//! Train steps bypass the batchers on purpose: SGD mutates the served
//! parameters, so steps execute in arrival order on the engine thread
//! (which already serializes them), one step per request.
//!
//! std::net + threads (no tokio in the offline image): one reader thread
//! per connection, one batch-executor thread per batcher.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::ServiceHandle;
use super::json::{parse, Json};
use super::metrics::Metrics;

/// Serving knobs beyond the batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct ServerPolicy {
    /// Coalesce compatible queued GEMM tiles into fused engine launches.
    /// Off = one launch per request (the A/B baseline); outputs are
    /// bit-identical either way.
    pub fuse_gemm: bool,
}

impl Default for ServerPolicy {
    fn default() -> Self {
        Self { fuse_gemm: true }
    }
}

/// Everything one connection handler needs, shared across connections.
struct Shared {
    infer: Batcher<Vec<f32>, Vec<f32>>,
    gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>>,
    metrics: Arc<Metrics>,
    service: ServiceHandle,
}

/// Running server handle.
pub struct Server {
    /// The bound local address (useful with `"127.0.0.1:0"` binds).
    pub addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `service` with the
    /// default policy (GEMM fusion on) until the handle is dropped.
    pub fn start(addr: &str, service: ServiceHandle, metrics: Arc<Metrics>) -> anyhow::Result<Server> {
        Self::start_with(addr, service, metrics, ServerPolicy::default())
    }

    /// Like [`Self::start`] with an explicit [`ServerPolicy`].
    pub fn start_with(
        addr: &str,
        service: ServiceHandle,
        metrics: Arc<Metrics>,
        policy: ServerPolicy,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let svc = service.clone();
        let infer: Batcher<Vec<f32>, Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: service.info().batch, max_wait: std::time::Duration::from_millis(2) },
            metrics.clone(),
            move |images: Vec<Vec<f32>>| {
                let n = images.len();
                match svc.infer_batch(images) {
                    Ok(outs) => outs.into_iter().map(Ok).collect(),
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                }
            },
        );

        let gsvc = service.clone();
        let gmetrics = metrics.clone();
        let fuse = policy.fuse_gemm;
        let gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_millis(2) },
            metrics.clone(),
            move |reqs: Vec<(Vec<f32>, Vec<f32>)>| {
                let n = reqs.len();
                gmetrics.gemm_requests.fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
                if fuse {
                    match gsvc.gemm_batch(reqs) {
                        Ok((results, stats)) => {
                            gmetrics.record_fusion(stats.launches, stats.fused_tiles);
                            results
                        }
                        Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                    }
                } else {
                    gmetrics.record_fusion(n as u64, 0);
                    reqs.into_iter().map(|(a, b)| gsvc.gemm(a, b)).collect()
                }
            },
        );

        let shared = Arc::new(Shared { infer, gemm, metrics, service });
        let sd = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let sh = shared.clone();
                        std::thread::spawn(move || handle_conn(s, sh));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(Server { addr: local, accept_thread: Some(accept_thread), shutdown })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, std::sync::atomic::Ordering::Relaxed);
        // accept loop wakes on its polling interval
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(&line, &shared);
        if writer.write_all((resp.to_string() + "\n").as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

fn handle_request(line: &str, shared: &Shared) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("infer") => {
            let Some(img) = req.get("image").and_then(Json::as_f64_vec) else {
                return err("infer needs 'image': [f64]");
            };
            if img.len() != shared.service.info().input_dim {
                return err(format!("image must have {} pixels", shared.service.info().input_dim));
            }
            let img: Vec<f32> = img.into_iter().map(|v| v as f32).collect();
            match shared.infer.call(img) {
                Ok(logits) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("logits", Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                Err(e) => err(e),
            }
        }
        Some("gemm") => {
            let (m, k, n) = shared.service.info().gemm_mkn;
            let Some(a) = req.get("a").and_then(Json::as_f64_vec) else {
                return err("gemm needs 'a': [f64]");
            };
            let Some(b) = req.get("b").and_then(Json::as_f64_vec) else {
                return err("gemm needs 'b': [f64]");
            };
            if a.len() != m * k {
                return err(format!("A must be {m}x{k}"));
            }
            if b.len() != k * n {
                return err(format!("B must be {k}x{n}"));
            }
            let a: Vec<f32> = a.into_iter().map(|v| v as f32).collect();
            let b: Vec<f32> = b.into_iter().map(|v| v as f32).collect();
            match shared.gemm.call((a, b)) {
                Ok(c) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("c", Json::arr_f64(&c.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                Err(e) => err(e),
            }
        }
        Some("train") => {
            let info = shared.service.info();
            let Some(rows) = req.get("images").and_then(Json::as_arr) else {
                return err("train needs 'images': [[f64]]");
            };
            let Some(labels) = req.get("labels").and_then(Json::as_f64_vec) else {
                return err("train needs 'labels': [int]");
            };
            if rows.len() != labels.len() {
                return err(format!("{} labels for {} images", labels.len(), rows.len()));
            }
            let mut images: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let Some(img) = row.as_f64_vec() else {
                    return err(format!("images[{i}] must be [f64]"));
                };
                if img.len() != info.input_dim {
                    return err(format!("images[{i}] must have {} pixels", info.input_dim));
                }
                images.push(img.into_iter().map(|v| v as f32).collect());
            }
            let mut checked: Vec<u32> = Vec::with_capacity(labels.len());
            for (i, l) in labels.into_iter().enumerate() {
                if l.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&l) {
                    return err(format!("labels[{i}] must be a non-negative integer, got {l}"));
                }
                checked.push(l as u32);
            }
            let labels = checked;
            let n = images.len();
            let t0 = std::time::Instant::now();
            shared.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            match shared.service.train_step(images, labels) {
                Ok(loss) => {
                    shared.metrics.record_train_step(n);
                    shared.metrics.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    shared.metrics.observe_latency(t0.elapsed());
                    Json::obj(vec![("ok", Json::Bool(true)), ("loss", Json::Num(loss as f64))])
                }
                Err(e) => {
                    shared.metrics.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    err(e)
                }
            }
        }
        Some("stats") => {
            let s = shared.metrics.snapshot();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("requests", Json::Num(s.requests as f64)),
                ("responses", Json::Num(s.responses as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("mean_batch_size", Json::Num(s.mean_batch_size)),
                ("mean_latency_us", Json::Num(s.mean_latency_us)),
                ("p95_latency_us", Json::Num(s.p95_latency_us as f64)),
                ("gemm_requests", Json::Num(s.gemm_requests as f64)),
                ("fused_launches", Json::Num(s.fused_launches as f64)),
                ("fused_tiles", Json::Num(s.fused_tiles as f64)),
                ("train_steps", Json::Num(s.train_steps as f64)),
                ("train_examples", Json::Num(s.train_examples as f64)),
            ])
        }
        Some(op) => err(format!("unknown op '{op}'")),
        None => err("missing 'op'"),
    }
}
