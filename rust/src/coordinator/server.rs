//! TCP JSON-lines server — the outward face of the L3 coordinator.
//!
//! Protocol (one JSON object per line, response per line):
//!   {"op":"ping"}                        → {"ok":true,"pong":true}
//!   {"op":"infer","image":[784 floats]}  → {"ok":true,"logits":[10]}
//!   {"op":"gemm","a":[M·K],"b":[K·N]}    → {"ok":true,"c":[M·N]}
//!   {"op":"train","images":[[784]…],"labels":[ints]}
//!                                        → {"ok":true,"loss":L}
//!   {"op":"stats"}                       → {"ok":true, …counters…}
//!   {"op":"metrics"}                     → {"ok":true,"prometheus":"…"}
//!   {"op":"trace","sample":N?,"clear":bool?}
//!                                        → {"ok":true,"sampling":N,"events":[…]}
//!   {"op":"numerics","shadow":N?}        → {"ok":true,"shadow_sampling":N,
//!                                           "sites":[…],"advisor":[…]}
//!
//! Requests from all connections funnel through per-op [`Batcher`]s, so
//! concurrent clients get batched into single backend invocations — the
//! serving pattern of vLLM-style routers, at MLP scale. Queued GEMM
//! requests additionally go through **cross-request fusion**
//! ([`super::fusion`]): compatible tiles in one formed batch share a
//! single engine launch, bit-identically to running them one at a time.
//! Train steps bypass the batchers on purpose: SGD mutates the served
//! parameters, so steps execute in arrival order on the engine thread
//! (which already serializes them), one step per request.
//!
//! Sampled requests (see [`crate::obs::trace`]) open a root span named
//! after the op; the batcher, fusion planner, engine launch, and S1–S6
//! kernel stages hang child spans off it, so `{"op":"trace"}` exports one
//! request's whole lifecycle as Chrome-tracing events.
//!
//! std::net + threads (no tokio in the offline image): one reader thread
//! per connection, one batch-executor thread per batcher.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::ServiceHandle;
use super::json::{parse, Json};
use super::metrics::{Metrics, OpKind};
use crate::obs;
use crate::obs::trace::{self, ActiveSpan, Span};

/// Serving knobs beyond the batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct ServerPolicy {
    /// Coalesce compatible queued GEMM tiles into fused engine launches.
    /// Off = one launch per request (the A/B baseline); outputs are
    /// bit-identical either way.
    pub fuse_gemm: bool,
}

impl Default for ServerPolicy {
    fn default() -> Self {
        Self { fuse_gemm: true }
    }
}

/// Everything one connection handler needs, shared across connections.
struct Shared {
    infer: Batcher<Vec<f32>, Vec<f32>>,
    gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>>,
    metrics: Arc<Metrics>,
    service: ServiceHandle,
}

/// Running server handle.
pub struct Server {
    /// The bound local address (useful with `"127.0.0.1:0"` binds).
    pub addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `service` with the
    /// default policy (GEMM fusion on) until the handle is dropped.
    pub fn start(addr: &str, service: ServiceHandle, metrics: Arc<Metrics>) -> anyhow::Result<Server> {
        Self::start_with(addr, service, metrics, ServerPolicy::default())
    }

    /// Like [`Self::start`] with an explicit [`ServerPolicy`].
    pub fn start_with(
        addr: &str,
        service: ServiceHandle,
        metrics: Arc<Metrics>,
        policy: ServerPolicy,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let svc = service.clone();
        let imetrics = metrics.clone();
        let infer_macs = service.info().macs_per_example;
        let infer: Batcher<Vec<f32>, Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: service.info().batch, max_wait: std::time::Duration::from_millis(2) },
            metrics.clone(),
            OpKind::Infer,
            move |images: Vec<Vec<f32>>, ctx| {
                let n = images.len();
                match svc.infer_batch_traced(images, ctx) {
                    Ok(outs) => {
                        imetrics.record_macs(infer_macs * n as u64);
                        outs.into_iter().map(Ok).collect()
                    }
                    Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                }
            },
        );

        let gsvc = service.clone();
        let gmetrics = metrics.clone();
        let fuse = policy.fuse_gemm;
        let (gm, gk, gn) = service.info().gemm_mkn;
        let gemm_macs = (gm * gk * gn) as u64;
        let gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>> = Batcher::spawn(
            BatchPolicy { max_batch: 64, max_wait: std::time::Duration::from_millis(2) },
            metrics.clone(),
            OpKind::Gemm,
            move |reqs: Vec<(Vec<f32>, Vec<f32>)>, ctx| {
                let n = reqs.len();
                gmetrics.gemm_requests.fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
                let results: Vec<Result<Vec<f32>, String>> = if fuse {
                    match gsvc.gemm_batch_traced(reqs, ctx) {
                        Ok((results, stats)) => {
                            gmetrics.record_fusion(stats.launches, stats.fused_tiles);
                            results
                        }
                        Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                    }
                } else {
                    gmetrics.record_fusion(n as u64, 0);
                    reqs.into_iter().map(|(a, b)| gsvc.gemm(a, b)).collect()
                };
                let ok = results.iter().filter(|r| r.is_ok()).count() as u64;
                gmetrics.record_macs(gemm_macs * ok);
                results
            },
        );

        let shared = Arc::new(Shared { infer, gemm, metrics, service });
        let sd = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let sh = shared.clone();
                        std::thread::spawn(move || handle_conn(s, sh));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(Server { addr: local, accept_thread: Some(accept_thread), shutdown })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, std::sync::atomic::Ordering::Relaxed);
        // accept loop wakes on its polling interval
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(&line, &shared);
        if writer.write_all((resp.to_string() + "\n").as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// One completed span as a Chrome-tracing "X" (complete) event. The trace
/// id doubles as the `tid`, so chrome://tracing / Perfetto groups one
/// request's spans onto one timeline row.
fn span_to_chrome(s: &Span) -> Json {
    Json::obj(vec![
        ("name", Json::Str(s.name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(s.start_us as f64)),
        ("dur", Json::Num(s.dur_ns as f64 / 1000.0)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(s.trace as f64)),
        ("args", Json::obj(vec![("span", Json::Num(s.id as f64)), ("parent", Json::Num(s.parent as f64))])),
    ])
}

fn handle_request(line: &str, shared: &Shared) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("infer") => {
            let Some(img) = req.get("image").and_then(Json::as_f64_vec) else {
                return err("infer needs 'image': [f64]");
            };
            if img.len() != shared.service.info().input_dim {
                return err(format!("image must have {} pixels", shared.service.info().input_dim));
            }
            let img: Vec<f32> = img.into_iter().map(|v| v as f32).collect();
            let root = trace::start_root("infer");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let out = shared.infer.call_traced(img, ctx);
            trace::finish(root);
            match out {
                Ok(logits) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("logits", Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                Err(e) => err(e),
            }
        }
        Some("gemm") => {
            let (m, k, n) = shared.service.info().gemm_mkn;
            let Some(a) = req.get("a").and_then(Json::as_f64_vec) else {
                return err("gemm needs 'a': [f64]");
            };
            let Some(b) = req.get("b").and_then(Json::as_f64_vec) else {
                return err("gemm needs 'b': [f64]");
            };
            if a.len() != m * k {
                return err(format!("A must be {m}x{k}"));
            }
            if b.len() != k * n {
                return err(format!("B must be {k}x{n}"));
            }
            let a: Vec<f32> = a.into_iter().map(|v| v as f32).collect();
            let b: Vec<f32> = b.into_iter().map(|v| v as f32).collect();
            let root = trace::start_root("gemm");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let out = shared.gemm.call_traced((a, b), ctx);
            trace::finish(root);
            match out {
                Ok(c) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("c", Json::arr_f64(&c.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                Err(e) => err(e),
            }
        }
        Some("train") => {
            let info = shared.service.info();
            let Some(rows) = req.get("images").and_then(Json::as_arr) else {
                return err("train needs 'images': [[f64]]");
            };
            let Some(labels) = req.get("labels").and_then(Json::as_f64_vec) else {
                return err("train needs 'labels': [int]");
            };
            if rows.len() != labels.len() {
                return err(format!("{} labels for {} images", labels.len(), rows.len()));
            }
            let mut images: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let Some(img) = row.as_f64_vec() else {
                    return err(format!("images[{i}] must be [f64]"));
                };
                if img.len() != info.input_dim {
                    return err(format!("images[{i}] must have {} pixels", info.input_dim));
                }
                images.push(img.into_iter().map(|v| v as f32).collect());
            }
            let mut checked: Vec<u32> = Vec::with_capacity(labels.len());
            for (i, l) in labels.into_iter().enumerate() {
                if l.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&l) {
                    return err(format!("labels[{i}] must be a non-negative integer, got {l}"));
                }
                checked.push(l as u32);
            }
            let labels = checked;
            let n = images.len();
            let t0 = crate::obs::clock::now();
            shared.metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let root = trace::start_root("train");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let outcome = shared.service.train_step_traced(images, labels, ctx);
            trace::finish(root);
            shared.metrics.observe_latency(OpKind::Train, t0.elapsed());
            match outcome {
                Ok(loss) => {
                    shared.metrics.record_train_step(n);
                    // one step ≈ forward + two backward GEMM volumes per layer
                    shared.metrics.record_macs(3 * info.macs_per_example * n as u64);
                    shared.metrics.responses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    Json::obj(vec![("ok", Json::Bool(true)), ("loss", Json::Num(loss as f64))])
                }
                Err(e) => {
                    shared.metrics.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    err(e)
                }
            }
        }
        Some("stats") => {
            let s = shared.metrics.snapshot();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("requests", Json::Num(s.requests as f64)),
                ("responses", Json::Num(s.responses as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("mean_batch_size", Json::Num(s.mean_batch_size)),
                ("mean_latency_us", Json::Num(s.mean_latency_us)),
                ("p95_latency_us", Json::Num(s.p95_latency_us as f64)),
                ("macs", Json::Num(s.macs as f64)),
                ("gemm_requests", Json::Num(s.gemm_requests as f64)),
                ("fused_launches", Json::Num(s.fused_launches as f64)),
                ("fused_tiles", Json::Num(s.fused_tiles as f64)),
                ("train_steps", Json::Num(s.train_steps as f64)),
                ("train_examples", Json::Num(s.train_examples as f64)),
            ])
        }
        Some("metrics") => {
            let s = shared.metrics.snapshot();
            Json::obj(vec![("ok", Json::Bool(true)), ("prometheus", Json::Str(obs::prom::render(&s)))])
        }
        Some("trace") => {
            if matches!(req.get("clear"), Some(Json::Bool(true))) {
                trace::clear();
            }
            if let Some(every) = req.get("sample").and_then(Json::as_f64) {
                if every.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&every) {
                    return err(format!("'sample' must be a non-negative integer, got {every}"));
                }
                trace::set_sampling(every as u32);
            }
            let events: Vec<Json> = trace::events().iter().map(span_to_chrome).collect();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("sampling", Json::Num(trace::sampling() as f64)),
                ("events", Json::Arr(events)),
            ])
        }
        Some("numerics") => {
            if let Some(every) = req.get("shadow").and_then(Json::as_f64) {
                if every.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&every) {
                    return err(format!("'shadow' must be a non-negative integer, got {every}"));
                }
                crate::obs::shadow::set_sampling(every as u32);
            }
            numerics_report()
        }
        Some(op) => err(format!("unknown op '{op}'")),
        None => err("missing 'op'"),
    }
}

/// The `{"op":"numerics"}` response body: every registry site with its
/// tallies, scale histograms, and shadow error stats, plus the precision
/// advisor's per-site (n, es) recommendations.
fn numerics_report() -> Json {
    let sites: Vec<Json> = crate::obs::numerics::snapshot().iter().map(site_to_json).collect();
    let advisor: Vec<Json> = crate::obs::numerics::advise().iter().map(advice_to_json).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("shadow_sampling", Json::Num(crate::obs::shadow::sampling() as f64)),
        ("sites", Json::Arr(sites)),
        ("advisor", Json::Arr(advisor)),
    ])
}

fn opt_i32(v: Option<i32>) -> Json {
    match v {
        Some(x) => Json::Num(x as f64),
        None => Json::Null,
    }
}

fn hist_to_json(hist: &[u64]) -> Json {
    Json::Arr(hist.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn site_to_json(e: &crate::obs::numerics::SiteEntry) -> Json {
    let s = &e.stats;
    Json::obj(vec![
        ("site", Json::Str(e.site.label())),
        ("cfg", Json::Str(e.cfg.label())),
        ("launches", Json::Num(s.launches as f64)),
        ("outputs", Json::Num(s.outputs as f64)),
        ("sat_maxpos", Json::Num(s.sat_maxpos as f64)),
        ("sat_minpos", Json::Num(s.sat_minpos as f64)),
        ("nar", Json::Num(s.nar as f64)),
        ("quire_roundings", Json::Num(s.quire_roundings as f64)),
        ("grad_sat", Json::Num(s.grad_sat as f64)),
        ("grad_underflow", Json::Num(s.grad_underflow as f64)),
        ("min_scale", opt_i32(s.min_scale)),
        ("max_scale", opt_i32(s.max_scale)),
        ("quire_watermark_log2", opt_i32(s.quire_watermark_log2)),
        ("scale_bucket_lo", Json::Num(crate::obs::numerics::SCALE_BUCKET_LO as f64)),
        ("scale_bucket_width", Json::Num(crate::obs::numerics::SCALE_BUCKET_WIDTH as f64)),
        ("operand_scale_hist", hist_to_json(&s.operand_scale_hist)),
        ("output_scale_hist", hist_to_json(&s.output_scale_hist)),
        (
            "shadow",
            Json::obj(vec![
                ("samples", Json::Num(s.shadow.samples() as f64)),
                ("overflow_frac", Json::Num(s.shadow.overflow_frac())),
                ("max_abs_err", Json::Num(s.shadow.max_abs_err())),
                ("mean_rel_err", Json::Num(s.shadow.mean_rel_err())),
                ("mean_decimal_accuracy", Json::Num(s.shadow.mean_decimal_accuracy())),
            ]),
        ),
    ])
}

fn advice_to_json(a: &crate::obs::numerics::Advice) -> Json {
    Json::obj(vec![
        ("site", Json::Str(a.site.label())),
        ("cfg", Json::Str(a.cfg.label())),
        ("rec_n", Json::Num(a.rec_n as f64)),
        ("rec_es", Json::Num(a.rec_es as f64)),
        ("required_scale", Json::Num(a.required_scale as f64)),
        ("target_decimal_digits", Json::Num(a.target_decimal_digits)),
    ])
}
