//! TCP JSON-lines server — the outward face of the L3 coordinator, built
//! as a **sharded serving tier** with admission control and a cross-batch
//! plane cache.
//!
//! Protocol (one JSON object per line, response per line):
//!   {"op":"ping"}                        → {"ok":true,"pong":true}
//!   {"op":"infer","image":[784 floats]}  → {"ok":true,"logits":[10]}
//!   {"op":"gemm","a":[M·K],"b":[K·N]}    → {"ok":true,"c":[M·N]}
//!   {"op":"train","images":[[784]…],"labels":[ints]}
//!                                        → {"ok":true,"loss":L}
//!   {"op":"stats"}                       → {"ok":true, …counters…}
//!   {"op":"metrics"}                     → {"ok":true,"prometheus":"…"}
//!   {"op":"trace","sample":N?,"clear":bool?}
//!                                        → {"ok":true,"sampling":N,"events":[…]}
//!   {"op":"numerics","shadow":N?}        → {"ok":true,"shadow_sampling":N,
//!                                           "sites":[…],"advisor":[…]}
//!
//! **Sharding.** The tier runs N accept threads over one shared listening
//! socket ([`TcpListener::try_clone`]); each shard owns its own pair of
//! per-op [`Batcher`]s (condvar-driven, bounded queues), so batch
//! formation and — with the software backend, which dispatches on the
//! calling thread — engine execution proceed in parallel across shards.
//! A connection is pinned to the shard that accepted it.
//!
//! **Admission control.** Every compute request (infer/gemm/train) must
//! acquire a permit from a bounded in-flight budget; when the budget or a
//! shard's bounded queue is exhausted the request is **shed** with a
//! structured `{"ok":false,"shed":true}` reply and counted in
//! `shed_requests` — graceful backpressure instead of unbounded queueing.
//! Control ops (ping/stats/metrics/trace/numerics) bypass admission so
//! observability stays reachable under overload.
//!
//! **Plane cache.** Queued GEMM requests go through cross-request fusion
//! ([`super::fusion`]) *and* the service's persistent
//! [`super::plane_cache::PlaneCache`]: weight planes seen in earlier
//! batches skip quantization entirely, bit-identically (the `stats` op
//! reports hit/miss/eviction counters).
//!
//! Train steps bypass the batchers on purpose: SGD mutates the served
//! parameters, so steps serialize on the service's internal graph lock,
//! one step per request.
//!
//! Robustness (each regression-tested in `rust/tests/wire_robustness.rs`):
//! the accept loops retry transient `accept()` errors with bounded
//! backoff instead of dying (EMFILE under fd exhaustion is exactly the
//! overload regime this tier targets); request lines are read through a
//! **bounded** reader that rejects lines over `max_line_bytes` (a client
//! streaming bytes without a newline can no longer OOM the server); and
//! every parsed-or-rejected request is counted (`requests`/`errors`), so
//! `stats` no longer undercounts hostile or malformed traffic.
//!
//! Sampled requests (see [`crate::obs::trace`]) open a root span named
//! after the op; the batcher, fusion planner, engine launch, and S1–S6
//! kernel stages hang child spans off it, so `{"op":"trace"}` exports one
//! request's whole lifecycle as Chrome-tracing events.
//!
//! std::net + threads (no tokio in the offline image): N accept threads,
//! one reader thread per connection, one batch-executor thread per shard
//! per op — with compute multiplexed through the shards' bounded queues
//! and capped by the admission budget.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::ServiceHandle;
use super::json::{parse, Json};
use super::metrics::{Metrics, OpKind};
use super::plane_cache::PlaneCacheStats;
use crate::obs;
use crate::obs::trace::{self, ActiveSpan, Span};

/// Serving knobs beyond the batch-formation policy.
#[derive(Clone, Copy, Debug)]
pub struct ServerPolicy {
    /// Coalesce compatible queued GEMM tiles into fused engine launches.
    /// Off = one launch per request (the A/B baseline); outputs are
    /// bit-identical either way.
    pub fuse_gemm: bool,
    /// Accept/engine shards (each with its own batcher pair); clamped to
    /// at least 1.
    pub shards: usize,
    /// Admission budget: maximum compute requests in flight across all
    /// shards before new ones are shed. `0` = unlimited.
    pub max_inflight: usize,
    /// Per-shard, per-op bound on queued (not yet batched) requests;
    /// beyond it the request is shed.
    pub max_queue: usize,
    /// Maximum accepted request-line length in bytes; longer lines get an
    /// error reply and the connection is closed (OOM guard, the wire-level
    /// sibling of the JSON parser's depth guard).
    pub max_line_bytes: usize,
}

impl Default for ServerPolicy {
    fn default() -> Self {
        Self { fuse_gemm: true, shards: 2, max_inflight: 1024, max_queue: 512, max_line_bytes: 4 << 20 }
    }
}

/// Bounded in-flight budget shared by every shard: RAII permits over an
/// atomic counter. `limit == 0` disables the bound.
pub struct AdmissionBudget {
    limit: usize,
    inflight: Arc<AtomicUsize>,
}

impl AdmissionBudget {
    /// A budget admitting at most `limit` concurrent requests (0 = no cap).
    pub fn new(limit: usize) -> Self {
        Self { limit, inflight: Arc::new(AtomicUsize::new(0)) }
    }

    /// Try to admit one request; `None` means the budget is exhausted and
    /// the caller should shed. Dropping the permit releases the slot.
    pub fn try_acquire(&self) -> Option<AdmissionPermit> {
        if self.limit == 0 {
            return Some(AdmissionPermit { inflight: None });
        }
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return None;
            }
            match self.inflight.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Some(AdmissionPermit { inflight: Some(self.inflight.clone()) }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Requests currently holding permits.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

/// RAII admission slot; dropping it releases the budget.
pub struct AdmissionPermit {
    inflight: Option<Arc<AtomicUsize>>,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        if let Some(g) = &self.inflight {
            g.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// One shard's batcher pair.
struct Shard {
    infer: Batcher<Vec<f32>, Vec<f32>>,
    gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>>,
}

/// Outcome of a compute request routed through the tier.
#[derive(Debug, PartialEq, Eq)]
pub enum TierReply<T> {
    /// Served normally.
    Ok(T),
    /// The backend replied with an error.
    Err(String),
    /// Shed by admission control or a full shard queue (never enqueued).
    Shed,
}

/// The sharded serving tier: N batcher-pair shards over one service, one
/// admission budget, and the service's cross-batch plane cache. Usable
/// directly (benchmarks, tests) or behind [`Server`]'s TCP front end.
pub struct ServingTier {
    shards: Vec<Shard>,
    budget: AdmissionBudget,
    next: AtomicUsize,
    metrics: Arc<Metrics>,
    service: ServiceHandle,
    policy: ServerPolicy,
}

impl ServingTier {
    /// Build the tier: `policy.shards` batcher pairs (clamped ≥ 1), each
    /// with bounded queues, all backed by `service`.
    pub fn new(service: ServiceHandle, metrics: Arc<Metrics>, policy: ServerPolicy) -> ServingTier {
        let shard_count = policy.shards.max(1);
        let infer_policy = BatchPolicy {
            max_batch: service.info().batch,
            max_wait: Duration::from_millis(2),
            max_queue: policy.max_queue,
        };
        let gemm_policy =
            BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2), max_queue: policy.max_queue };
        let infer_macs = service.info().macs_per_example;
        let (gm, gk, gn) = service.info().gemm_mkn;
        let gemm_macs = (gm * gk * gn) as u64;
        let mut shards = Vec::with_capacity(shard_count);
        for _ in 0..shard_count {
            let svc = service.clone();
            let imetrics = metrics.clone();
            let infer: Batcher<Vec<f32>, Vec<f32>> = Batcher::spawn(
                infer_policy,
                metrics.clone(),
                OpKind::Infer,
                move |images: Vec<Vec<f32>>, ctx| {
                    let n = images.len();
                    match svc.infer_batch_traced(images, ctx) {
                        Ok(outs) => {
                            imetrics.record_macs(infer_macs * n as u64);
                            outs.into_iter().map(Ok).collect()
                        }
                        Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                    }
                },
            );
            let gsvc = service.clone();
            let gmetrics = metrics.clone();
            let fuse = policy.fuse_gemm;
            let gemm: Batcher<(Vec<f32>, Vec<f32>), Vec<f32>> = Batcher::spawn(
                gemm_policy,
                metrics.clone(),
                OpKind::Gemm,
                move |reqs: Vec<(Vec<f32>, Vec<f32>)>, ctx| {
                    let n = reqs.len();
                    gmetrics.gemm_requests.fetch_add(n as u64, Ordering::Relaxed);
                    let results: Vec<Result<Vec<f32>, String>> = if fuse {
                        match gsvc.gemm_batch_traced(reqs, ctx) {
                            Ok((results, stats)) => {
                                gmetrics.record_fusion(stats.launches, stats.fused_tiles);
                                results
                            }
                            Err(e) => (0..n).map(|_| Err(e.clone())).collect(),
                        }
                    } else {
                        gmetrics.record_fusion(n as u64, 0);
                        reqs.into_iter().map(|(a, b)| gsvc.gemm(a, b)).collect()
                    };
                    let ok = results.iter().filter(|r| r.is_ok()).count() as u64;
                    gmetrics.record_macs(gemm_macs * ok);
                    results
                },
            );
            shards.push(Shard { infer, gemm });
        }
        ServingTier {
            shards,
            budget: AdmissionBudget::new(policy.max_inflight),
            next: AtomicUsize::new(0),
            metrics,
            service,
            policy,
        }
    }

    /// The serving policy the tier was built with.
    pub fn policy(&self) -> &ServerPolicy {
        &self.policy
    }

    /// The shared metrics registry.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The backing service handle.
    pub fn service(&self) -> &ServiceHandle {
        &self.service
    }

    /// Number of shards (≥ 1).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Round-robin shard assignment for callers without an accept-time
    /// pinning (benchmarks, in-process clients).
    pub fn assign_shard(&self) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len()
    }

    /// Compute requests currently holding admission permits.
    pub fn in_flight(&self) -> usize {
        self.budget.in_flight()
    }

    /// Live counters of the service's cross-batch plane cache.
    pub fn plane_cache_stats(&self) -> PlaneCacheStats {
        self.service.plane_cache_stats()
    }

    /// Try to admit one compute request; records the shed on refusal so
    /// every caller's accounting is uniform.
    pub fn try_admit(&self) -> Option<AdmissionPermit> {
        let permit = self.budget.try_acquire();
        if permit.is_none() {
            self.metrics.record_shed();
        }
        permit
    }

    /// One inference through `shard`'s batcher, under admission control.
    pub fn infer(&self, shard: usize, image: Vec<f32>, ctx: Option<trace::TraceCtx>) -> TierReply<Vec<f32>> {
        let Some(_permit) = self.try_admit() else {
            return TierReply::Shed;
        };
        let Some(sh) = self.shards.get(shard % self.shards.len()) else {
            return TierReply::Err("no shards".to_string());
        };
        match sh.infer.try_call_traced(image, ctx) {
            None => {
                self.metrics.record_shed();
                TierReply::Shed
            }
            Some(Ok(v)) => TierReply::Ok(v),
            Some(Err(e)) => TierReply::Err(e),
        }
    }

    /// One GEMM through `shard`'s batcher, under admission control.
    pub fn gemm(
        &self,
        shard: usize,
        a: Vec<f32>,
        b: Vec<f32>,
        ctx: Option<trace::TraceCtx>,
    ) -> TierReply<Vec<f32>> {
        let Some(_permit) = self.try_admit() else {
            return TierReply::Shed;
        };
        let Some(sh) = self.shards.get(shard % self.shards.len()) else {
            return TierReply::Err("no shards".to_string());
        };
        match sh.gemm.try_call_traced((a, b), ctx) {
            None => {
                self.metrics.record_shed();
                TierReply::Shed
            }
            Some(Ok(v)) => TierReply::Ok(v),
            Some(Err(e)) => TierReply::Err(e),
        }
    }
}

/// Running server handle.
pub struct Server {
    /// The bound local address (useful with `"127.0.0.1:0"` binds).
    pub addr: std::net::SocketAddr,
    accept_threads: Vec<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    tier: Arc<ServingTier>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `service` with the
    /// default policy until the handle is dropped.
    pub fn start(addr: &str, service: ServiceHandle, metrics: Arc<Metrics>) -> anyhow::Result<Server> {
        Self::start_with(addr, service, metrics, ServerPolicy::default())
    }

    /// Like [`Self::start`] with an explicit [`ServerPolicy`]: builds the
    /// [`ServingTier`] and spawns one accept thread per shard over clones
    /// of the (nonblocking) listening socket.
    pub fn start_with(
        addr: &str,
        service: ServiceHandle,
        metrics: Arc<Metrics>,
        policy: ServerPolicy,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let tier = Arc::new(ServingTier::new(service, metrics, policy));
        let mut accept_threads = Vec::with_capacity(tier.shard_count());
        for shard in 0..tier.shard_count() {
            let l = listener.try_clone()?;
            let t = tier.clone();
            let sd = shutdown.clone();
            accept_threads.push(std::thread::spawn(move || accept_loop(l, t, shard, sd)));
        }
        Ok(Server { addr: local, accept_threads, shutdown, tier })
    }

    /// The serving tier behind this server (live metrics, policy, cache).
    pub fn tier(&self) -> &Arc<ServingTier> {
        &self.tier
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // accept loops wake on their polling interval
        for t in self.accept_threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Backoff before retrying a failed `accept()`: 5ms doubling per
/// consecutive failure, capped at 200ms. Transient error storms (EMFILE,
/// ECONNABORTED floods) slow the loop down instead of killing it.
fn accept_backoff(streak: u32) -> Duration {
    let shift = streak.saturating_sub(1).min(6);
    let ms = 5u64.saturating_mul(1u64 << shift);
    Duration::from_millis(ms.min(200))
}

/// One shard's accept loop. Transient `accept()` errors are retried with
/// [`accept_backoff`] — the loop only exits on shutdown. (The previous
/// implementation `break`ed on any non-WouldBlock error, permanently
/// killing the accept thread the first time the process ran out of fds.)
fn accept_loop(listener: TcpListener, tier: Arc<ServingTier>, shard: usize, shutdown: Arc<AtomicBool>) {
    let mut streak: u32 = 0;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                streak = 0;
                let t = tier.clone();
                std::thread::spawn(move || handle_conn(stream, t, shard));
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                tier.metrics().record_accept_retry();
                streak = streak.saturating_add(1);
                std::thread::sleep(accept_backoff(streak));
            }
        }
    }
}

/// Result of one bounded line read.
enum LineRead {
    /// A complete line (newline stripped, trailing `\r` trimmed).
    Line(String),
    /// The line exceeded the cap before a newline arrived.
    TooLong,
    /// The line's bytes were not valid UTF-8.
    NotUtf8,
    /// Clean end of stream with no pending bytes.
    Eof,
}

/// Read one `\n`-terminated line of at most `cap` bytes. Unlike
/// [`BufRead::read_line`], memory is bounded: accumulation stops at
/// `cap + one buffer chunk`. Every chunk is consumed from the reader
/// *before* the length check, so an over-cap verdict leaves no read-side
/// bytes pending (closing a socket with unread data would RST the error
/// reply away). EOF with pending bytes yields a final `Line`, matching
/// `BufRead::lines`.
fn read_bounded_line(r: &mut impl BufRead, cap: usize) -> io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (take, found_nl) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                break; // EOF
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    buf.extend_from_slice(&chunk[..i]);
                    (i + 1, true)
                }
                None => {
                    buf.extend_from_slice(chunk);
                    (chunk.len(), false)
                }
            }
        };
        r.consume(take);
        if buf.len() > cap {
            return Ok(LineRead::TooLong);
        }
        if found_nl {
            return Ok(finish_line(buf));
        }
    }
    if buf.is_empty() {
        Ok(LineRead::Eof)
    } else {
        Ok(finish_line(buf))
    }
}

/// Trim an optional trailing `\r` and validate UTF-8.
fn finish_line(mut buf: Vec<u8>) -> LineRead {
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    match String::from_utf8(buf) {
        Ok(s) => LineRead::Line(s),
        Err(_) => LineRead::NotUtf8,
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<ServingTier>, shard: usize) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let cap = shared.policy().max_line_bytes;
    let mut reader = BufReader::new(stream);
    loop {
        match read_bounded_line(&mut reader, cap) {
            Ok(LineRead::Line(line)) => {
                if line.trim().is_empty() {
                    continue;
                }
                let resp = handle_request(&line, &shared, shard);
                if writer.write_all((resp.to_string() + "\n").as_bytes()).is_err() {
                    break;
                }
            }
            Ok(LineRead::TooLong) => {
                // counted, answered, closed: the wire-level OOM guard
                shared.metrics().record_rejected();
                let resp = err(format!("request line exceeds {cap} bytes"));
                let _ = writer.write_all((resp.to_string() + "\n").as_bytes());
                break;
            }
            Ok(LineRead::NotUtf8) => {
                // counted but silently closed (matching the historical
                // BufRead::lines behavior clients already rely on)
                shared.metrics().record_rejected();
                break;
            }
            Ok(LineRead::Eof) | Err(_) => break,
        }
    }
}

fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Count a malformed request (it arrived *and* failed — `requests` and
/// `errors` both move, so `stats` sees hostile/broken traffic) and build
/// its error reply.
fn reject(shared: &ServingTier, msg: impl Into<String>) -> Json {
    shared.metrics().record_rejected();
    err(msg)
}

/// The structured overload reply: distinguishable from an error (`shed`
/// is only ever present-and-true here) so clients can back off and retry.
fn shed_reply() -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("shed", Json::Bool(true)),
        ("error", Json::Str("overloaded: admission budget exhausted".to_string())),
    ])
}

/// One completed span as a Chrome-tracing "X" (complete) event. The trace
/// id doubles as the `tid`, so chrome://tracing / Perfetto groups one
/// request's spans onto one timeline row.
fn span_to_chrome(s: &Span) -> Json {
    Json::obj(vec![
        ("name", Json::Str(s.name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(s.start_us as f64)),
        ("dur", Json::Num(s.dur_ns as f64 / 1000.0)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(s.trace as f64)),
        ("args", Json::obj(vec![("span", Json::Num(s.id as f64)), ("parent", Json::Num(s.parent as f64))])),
    ])
}

fn handle_request(line: &str, shared: &ServingTier, shard: usize) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return reject(shared, format!("bad json: {e}")),
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("infer") => {
            let Some(img) = req.get("image").and_then(Json::as_f64_vec) else {
                return reject(shared, "infer needs 'image': [f64]");
            };
            if img.len() != shared.service().info().input_dim {
                return reject(shared, format!("image must have {} pixels", shared.service().info().input_dim));
            }
            let img: Vec<f32> = img.into_iter().map(|v| v as f32).collect();
            let root = trace::start_root("infer");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let out = shared.infer(shard, img, ctx);
            trace::finish(root);
            match out {
                TierReply::Ok(logits) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("logits", Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                TierReply::Err(e) => err(e),
                TierReply::Shed => shed_reply(),
            }
        }
        Some("gemm") => {
            let (m, k, n) = shared.service().info().gemm_mkn;
            let Some(a) = req.get("a").and_then(Json::as_f64_vec) else {
                return reject(shared, "gemm needs 'a': [f64]");
            };
            let Some(b) = req.get("b").and_then(Json::as_f64_vec) else {
                return reject(shared, "gemm needs 'b': [f64]");
            };
            if a.len() != m * k {
                return reject(shared, format!("A must be {m}x{k}"));
            }
            if b.len() != k * n {
                return reject(shared, format!("B must be {k}x{n}"));
            }
            let a: Vec<f32> = a.into_iter().map(|v| v as f32).collect();
            let b: Vec<f32> = b.into_iter().map(|v| v as f32).collect();
            let root = trace::start_root("gemm");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let out = shared.gemm(shard, a, b, ctx);
            trace::finish(root);
            match out {
                TierReply::Ok(c) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("c", Json::arr_f64(&c.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ]),
                TierReply::Err(e) => err(e),
                TierReply::Shed => shed_reply(),
            }
        }
        Some("train") => {
            let info = shared.service().info();
            let Some(rows) = req.get("images").and_then(Json::as_arr) else {
                return reject(shared, "train needs 'images': [[f64]]");
            };
            let Some(labels) = req.get("labels").and_then(Json::as_f64_vec) else {
                return reject(shared, "train needs 'labels': [int]");
            };
            if rows.len() != labels.len() {
                return reject(shared, format!("{} labels for {} images", labels.len(), rows.len()));
            }
            let mut images: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for (i, row) in rows.iter().enumerate() {
                let Some(img) = row.as_f64_vec() else {
                    return reject(shared, format!("images[{i}] must be [f64]"));
                };
                if img.len() != info.input_dim {
                    return reject(shared, format!("images[{i}] must have {} pixels", info.input_dim));
                }
                images.push(img.into_iter().map(|v| v as f32).collect());
            }
            let mut checked: Vec<u32> = Vec::with_capacity(labels.len());
            for (i, l) in labels.into_iter().enumerate() {
                if l.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&l) {
                    return reject(shared, format!("labels[{i}] must be a non-negative integer, got {l}"));
                }
                checked.push(l as u32);
            }
            let labels = checked;
            let Some(_permit) = shared.try_admit() else {
                return shed_reply();
            };
            let n = images.len();
            let t0 = crate::obs::clock::now();
            shared.metrics().requests.fetch_add(1, Ordering::Relaxed);
            let root = trace::start_root("train");
            let ctx = root.as_ref().map(ActiveSpan::ctx);
            let outcome = shared.service().train_step_traced(images, labels, ctx);
            trace::finish(root);
            shared.metrics().observe_latency(OpKind::Train, t0.elapsed());
            match outcome {
                Ok(loss) => {
                    shared.metrics().record_train_step(n);
                    // one step ≈ forward + two backward GEMM volumes per layer
                    shared.metrics().record_macs(3 * info.macs_per_example * n as u64);
                    shared.metrics().responses.fetch_add(1, Ordering::Relaxed);
                    Json::obj(vec![("ok", Json::Bool(true)), ("loss", Json::Num(loss as f64))])
                }
                Err(e) => {
                    shared.metrics().errors.fetch_add(1, Ordering::Relaxed);
                    err(e)
                }
            }
        }
        Some("stats") => {
            let mut s = shared.metrics().snapshot();
            s.plane_cache = shared.plane_cache_stats();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("requests", Json::Num(s.requests as f64)),
                ("responses", Json::Num(s.responses as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("mean_batch_size", Json::Num(s.mean_batch_size)),
                ("mean_latency_us", Json::Num(s.mean_latency_us)),
                ("p95_latency_us", Json::Num(s.p95_latency_us as f64)),
                ("macs", Json::Num(s.macs as f64)),
                ("gemm_requests", Json::Num(s.gemm_requests as f64)),
                ("fused_launches", Json::Num(s.fused_launches as f64)),
                ("fused_tiles", Json::Num(s.fused_tiles as f64)),
                ("train_steps", Json::Num(s.train_steps as f64)),
                ("train_examples", Json::Num(s.train_examples as f64)),
                ("shed_requests", Json::Num(s.shed_requests as f64)),
                ("accept_retries", Json::Num(s.accept_retries as f64)),
                ("shards", Json::Num(shared.shard_count() as f64)),
                ("in_flight", Json::Num(shared.in_flight() as f64)),
                ("plane_cache_hits", Json::Num(s.plane_cache.hits as f64)),
                ("plane_cache_misses", Json::Num(s.plane_cache.misses as f64)),
                ("plane_cache_evictions", Json::Num(s.plane_cache.evictions as f64)),
                ("plane_cache_entries", Json::Num(s.plane_cache.entries as f64)),
            ])
        }
        Some("metrics") => {
            let mut s = shared.metrics().snapshot();
            s.plane_cache = shared.plane_cache_stats();
            Json::obj(vec![("ok", Json::Bool(true)), ("prometheus", Json::Str(obs::prom::render(&s)))])
        }
        Some("trace") => {
            if matches!(req.get("clear"), Some(Json::Bool(true))) {
                trace::clear();
            }
            if let Some(every) = req.get("sample").and_then(Json::as_f64) {
                if every.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&every) {
                    return reject(shared, format!("'sample' must be a non-negative integer, got {every}"));
                }
                trace::set_sampling(every as u32);
            }
            let events: Vec<Json> = trace::events().iter().map(span_to_chrome).collect();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("sampling", Json::Num(trace::sampling() as f64)),
                ("events", Json::Arr(events)),
            ])
        }
        Some("numerics") => {
            if let Some(every) = req.get("shadow").and_then(Json::as_f64) {
                if every.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&every) {
                    return reject(shared, format!("'shadow' must be a non-negative integer, got {every}"));
                }
                crate::obs::shadow::set_sampling(every as u32);
            }
            numerics_report()
        }
        Some(op) => reject(shared, format!("unknown op '{op}'")),
        None => reject(shared, "missing 'op'"),
    }
}

/// The `{"op":"numerics"}` response body: every registry site with its
/// tallies, scale histograms, and shadow error stats, plus the precision
/// advisor's per-site (n, es) recommendations.
fn numerics_report() -> Json {
    let sites: Vec<Json> = crate::obs::numerics::snapshot().iter().map(site_to_json).collect();
    let advisor: Vec<Json> = crate::obs::numerics::advise().iter().map(advice_to_json).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("shadow_sampling", Json::Num(crate::obs::shadow::sampling() as f64)),
        ("sites", Json::Arr(sites)),
        ("advisor", Json::Arr(advisor)),
    ])
}

fn opt_i32(v: Option<i32>) -> Json {
    match v {
        Some(x) => Json::Num(x as f64),
        None => Json::Null,
    }
}

fn hist_to_json(hist: &[u64]) -> Json {
    Json::Arr(hist.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn site_to_json(e: &crate::obs::numerics::SiteEntry) -> Json {
    let s = &e.stats;
    Json::obj(vec![
        ("site", Json::Str(e.site.label())),
        ("cfg", Json::Str(e.cfg.label())),
        ("launches", Json::Num(s.launches as f64)),
        ("outputs", Json::Num(s.outputs as f64)),
        ("sat_maxpos", Json::Num(s.sat_maxpos as f64)),
        ("sat_minpos", Json::Num(s.sat_minpos as f64)),
        ("nar", Json::Num(s.nar as f64)),
        ("quire_roundings", Json::Num(s.quire_roundings as f64)),
        ("grad_sat", Json::Num(s.grad_sat as f64)),
        ("grad_underflow", Json::Num(s.grad_underflow as f64)),
        ("min_scale", opt_i32(s.min_scale)),
        ("max_scale", opt_i32(s.max_scale)),
        ("quire_watermark_log2", opt_i32(s.quire_watermark_log2)),
        ("scale_bucket_lo", Json::Num(crate::obs::numerics::SCALE_BUCKET_LO as f64)),
        ("scale_bucket_width", Json::Num(crate::obs::numerics::SCALE_BUCKET_WIDTH as f64)),
        ("operand_scale_hist", hist_to_json(&s.operand_scale_hist)),
        ("output_scale_hist", hist_to_json(&s.output_scale_hist)),
        (
            "shadow",
            Json::obj(vec![
                ("samples", Json::Num(s.shadow.samples() as f64)),
                ("overflow_frac", Json::Num(s.shadow.overflow_frac())),
                ("max_abs_err", Json::Num(s.shadow.max_abs_err())),
                ("mean_rel_err", Json::Num(s.shadow.mean_rel_err())),
                ("mean_decimal_accuracy", Json::Num(s.shadow.mean_decimal_accuracy())),
            ]),
        ),
    ])
}

fn advice_to_json(a: &crate::obs::numerics::Advice) -> Json {
    Json::obj(vec![
        ("site", Json::Str(a.site.label())),
        ("cfg", Json::Str(a.cfg.label())),
        ("rec_n", Json::Num(a.rec_n as f64)),
        ("rec_es", Json::Num(a.rec_es as f64)),
        ("required_scale", Json::Num(a.required_scale as f64)),
        ("target_decimal_digits", Json::Num(a.target_decimal_digits)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_backoff_doubles_and_caps() {
        assert_eq!(accept_backoff(1), Duration::from_millis(5));
        assert_eq!(accept_backoff(2), Duration::from_millis(10));
        assert_eq!(accept_backoff(3), Duration::from_millis(20));
        assert_eq!(accept_backoff(6), Duration::from_millis(160));
        assert_eq!(accept_backoff(7), Duration::from_millis(200));
        assert_eq!(accept_backoff(u32::MAX), Duration::from_millis(200));
        // monotone non-decreasing
        let mut prev = Duration::ZERO;
        for streak in 1..40 {
            let d = accept_backoff(streak);
            assert!(d >= prev, "backoff regressed at streak {streak}");
            prev = d;
        }
    }

    fn read_all(input: &[u8], cap: usize) -> Vec<LineRead> {
        let mut r = std::io::Cursor::new(input.to_vec());
        let mut out = Vec::new();
        loop {
            let l = read_bounded_line(&mut r, cap).unwrap();
            let stop = matches!(l, LineRead::Eof | LineRead::TooLong | LineRead::NotUtf8);
            out.push(l);
            if stop {
                return out;
            }
        }
    }

    #[test]
    fn bounded_reader_reads_lines_and_strips_cr() {
        let got = read_all(b"hello\nworld\r\ntail", 64);
        let texts: Vec<&str> = got
            .iter()
            .filter_map(|l| match l {
                LineRead::Line(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        // the unterminated trailing line is still delivered, like lines()
        assert_eq!(texts, vec!["hello", "world", "tail"]);
        assert!(matches!(got.last(), Some(LineRead::Eof)));
    }

    #[test]
    fn bounded_reader_rejects_over_cap_lines() {
        // exactly cap is fine…
        let ok = read_all(format!("{}\n", "x".repeat(16)).as_bytes(), 16);
        assert!(matches!(ok.first(), Some(LineRead::Line(s)) if s.len() == 16));
        // …one byte over is not, with or without a newline ever arriving
        assert!(matches!(read_all("x".repeat(17).as_bytes(), 16).last(), Some(LineRead::TooLong)));
        assert!(matches!(
            read_all(format!("{}\nnext\n", "x".repeat(17)).as_bytes(), 16).first(),
            Some(LineRead::TooLong)
        ));
    }

    #[test]
    fn bounded_reader_flags_invalid_utf8() {
        let got = read_all(&[0xFF, 0xFE, 0x80, b'\n'], 64);
        assert!(matches!(got.first(), Some(LineRead::NotUtf8)));
    }

    #[test]
    fn bounded_reader_handles_empty_input() {
        assert!(matches!(read_all(b"", 8).first(), Some(LineRead::Eof)));
    }

    #[test]
    fn admission_budget_admits_releases_and_refuses() {
        let b = AdmissionBudget::new(2);
        let p1 = b.try_acquire().expect("slot 1");
        let p2 = b.try_acquire().expect("slot 2");
        assert_eq!(b.in_flight(), 2);
        assert!(b.try_acquire().is_none(), "budget exhausted");
        drop(p1);
        assert_eq!(b.in_flight(), 1);
        let p3 = b.try_acquire().expect("slot freed by drop");
        drop(p2);
        drop(p3);
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn admission_budget_zero_means_unlimited() {
        let b = AdmissionBudget::new(0);
        let permits: Vec<_> = (0..64).map(|_| b.try_acquire().expect("unlimited")).collect();
        assert_eq!(b.in_flight(), 0, "unlimited budget tracks nothing");
        drop(permits);
    }
}
