//! Coordinator metrics: lock-free counters, per-op latency histograms
//! (microseconds), and queue gauges. No external deps; snapshot-able for
//! the `stats` endpoint and renderable as Prometheus text exposition by
//! [`crate::obs::prom`].
//!
//! Latency is histogrammed **per op** (`infer` / `gemm` / `train` get
//! their own [`Histo`]), because blending a 100µs infer path with a
//! multi-ms train step produces a histogram that describes neither. The
//! blended `mean_latency_us` / `p95_latency_us` stats fields are derived
//! by merging the three histograms, and the mean divides by the
//! histogram's **own sample count** — error replies are observed too, so
//! dividing by `responses` (successes only) would skew the mean upward.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds (shared by the stats
/// endpoint and the Prometheus renderer's `le` labels).
pub const BUCKETS_US: [u64; 12] = [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000];

/// Which serving op a latency observation or queue event belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Dynamic-batched image inference.
    Infer,
    /// (Possibly fused) GEMM execution.
    Gemm,
    /// Served SGD steps.
    Train,
}

impl OpKind {
    /// Stable label used in Prometheus series and span names.
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Infer => "infer",
            OpKind::Gemm => "gemm",
            OpKind::Train => "train",
        }
    }
}

/// Lock-free fixed-bucket latency histogram with its own sample count.
#[derive(Debug, Default)]
struct Histo {
    buckets: [AtomicU64; 13],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histo {
    fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len());
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistoSnapshot {
        let mut buckets = [0u64; 13];
        for (dst, src) in buckets.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistoSnapshot { buckets, sum_us: self.sum_us.load(Ordering::Relaxed), count: self.count.load(Ordering::Relaxed) }
    }
}

/// Point-in-time copy of one latency histogram.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistoSnapshot {
    /// Per-bucket counts; index `i` pairs with `BUCKETS_US[i]`, the last
    /// slot is the overflow (+Inf) bucket.
    pub buckets: [u64; 13],
    /// Sum of observed latencies (µs).
    pub sum_us: u64,
    /// Number of observations (successes **and** error replies).
    pub count: u64,
}

impl HistoSnapshot {
    /// Mean latency in µs over everything this histogram observed.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Approximate latency quantile (bucket upper bound in µs).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Bucket-wise merge of two histograms (same fixed bounds).
    pub fn merge(&self, other: &HistoSnapshot) -> HistoSnapshot {
        let mut buckets = [0u64; 13];
        for ((dst, a), b) in buckets.iter_mut().zip(&self.buckets).zip(&other.buckets) {
            *dst = a + b;
        }
        HistoSnapshot { buckets, sum_us: self.sum_us + other.sum_us, count: self.count + other.count }
    }
}

/// Per-op telemetry: latency histogram plus queue gauges.
#[derive(Debug, Default)]
struct OpStats {
    latency: Histo,
    queue_depth: AtomicU64,
    last_batch_wait_us: AtomicU64,
}

impl OpStats {
    fn snapshot(&self) -> OpSnapshot {
        OpSnapshot {
            latency: self.latency.snapshot(),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            last_batch_wait_us: self.last_batch_wait_us.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one op's telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpSnapshot {
    /// Latency histogram for this op.
    pub latency: HistoSnapshot,
    /// Requests currently waiting in this op's batcher queue.
    pub queue_depth: u64,
    /// Oldest-item queue wait (µs) of the most recently formed batch.
    pub last_batch_wait_us: u64,
}

/// Shared metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Items submitted to any batcher.
    pub requests: AtomicU64,
    /// Successful replies delivered.
    pub responses: AtomicU64,
    /// Error replies delivered.
    pub errors: AtomicU64,
    /// Batches formed by the dynamic batchers.
    pub batches: AtomicU64,
    /// Total items across all formed batches.
    pub batched_items: AtomicU64,
    /// MACs executed (software GEMM/infer/train paths report them).
    pub macs: AtomicU64,
    /// GEMM requests that reached the serving path.
    pub gemm_requests: AtomicU64,
    /// Engine launches performed for GEMM traffic (fused: ≤ requests).
    pub fused_launches: AtomicU64,
    /// GEMM requests that shared a launch with at least one other request.
    pub fused_tiles: AtomicU64,
    /// SGD train steps served (software or PJRT backend).
    pub train_steps: AtomicU64,
    /// Labelled examples consumed by served train steps.
    pub train_examples: AtomicU64,
    /// Requests shed by admission control under overload (counted in
    /// `requests` too, but in neither `responses` nor `errors`).
    pub shed_requests: AtomicU64,
    /// Transient `accept()` failures survived by the accept loops.
    pub accept_retries: AtomicU64,
    infer: OpStats,
    gemm: OpStats,
    train: OpStats,
}

impl Metrics {
    /// Fresh all-zero registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn op(&self, kind: OpKind) -> &OpStats {
        match kind {
            OpKind::Infer => &self.infer,
            OpKind::Gemm => &self.gemm,
            OpKind::Train => &self.train,
        }
    }

    /// Record one end-to-end request latency into `kind`'s histogram.
    /// Observed for successes and error replies alike; the histogram
    /// carries its own count, so the mean stays honest either way.
    pub fn observe_latency(&self, kind: OpKind, d: Duration) {
        self.op(kind).latency.observe(d);
    }

    /// One request entered `kind`'s batcher queue.
    pub fn queue_enter(&self, kind: OpKind) {
        self.op(kind).queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests left `kind`'s batcher queue (drained into a batch).
    pub fn queue_leave(&self, kind: OpKind, n: usize) {
        let g = &self.op(kind).queue_depth;
        let mut cur = g.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n as u64);
            match g.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Record the oldest-item queue wait of a just-formed `kind` batch.
    pub fn record_batch_wait(&self, kind: OpKind, wait: Duration) {
        self.op(kind).last_batch_wait_us.store(wait.as_micros() as u64, Ordering::Relaxed);
    }

    /// Record one formed batch of `items` requests.
    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record MACs executed by the engine on behalf of served requests.
    pub fn record_macs(&self, macs: u64) {
        self.macs.fetch_add(macs, Ordering::Relaxed);
    }

    /// Record the outcome of one fused GEMM execution: how many engine
    /// launches served the queue slice and how many of its tiles shared a
    /// launch (see [`super::fusion::FusionStats`]).
    pub fn record_fusion(&self, launches: u64, fused_tiles: u64) {
        self.fused_launches.fetch_add(launches, Ordering::Relaxed);
        self.fused_tiles.fetch_add(fused_tiles, Ordering::Relaxed);
    }

    /// Record one served SGD step over `examples` labelled images.
    pub fn record_train_step(&self, examples: usize) {
        self.train_steps.fetch_add(1, Ordering::Relaxed);
        self.train_examples.fetch_add(examples as u64, Ordering::Relaxed);
    }

    /// Record one request shed by admission control: it arrived (so it
    /// counts as a request) but was neither served nor errored — the shed
    /// reply is a deliberate backpressure signal, not a failure.
    pub fn record_shed(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.shed_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request rejected before reaching any backend (bad JSON,
    /// wrong shapes, oversized line): it both arrived and failed, so the
    /// stats stop undercounting hostile/broken traffic.
    pub fn record_rejected(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one transient accept() failure that the accept loop
    /// retried instead of dying.
    pub fn record_accept_retry(&self) {
        self.accept_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Blended histogram across all ops (for the legacy stats fields).
    fn merged_latency(&self) -> HistoSnapshot {
        self.infer.latency.snapshot().merge(&self.gemm.latency.snapshot()).merge(&self.train.latency.snapshot())
    }

    /// Mean observed latency in µs across all ops, over every
    /// observation the histograms made (error replies included).
    pub fn mean_latency_us(&self) -> f64 {
        self.merged_latency().mean_us()
    }

    /// Approximate blended latency quantile (bucket upper bound, µs).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.merged_latency().quantile_us(q)
    }

    /// Mean items per formed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Consistent-enough point-in-time copy of every counter, gauge, and
    /// histogram, plus the process-wide posit numerics counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let blended = self.merged_latency();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_size: self.mean_batch_size(),
            mean_latency_us: blended.mean_us(),
            p95_latency_us: blended.quantile_us(0.95),
            macs: self.macs.load(Ordering::Relaxed),
            gemm_requests: self.gemm_requests.load(Ordering::Relaxed),
            fused_launches: self.fused_launches.load(Ordering::Relaxed),
            fused_tiles: self.fused_tiles.load(Ordering::Relaxed),
            train_steps: self.train_steps.load(Ordering::Relaxed),
            train_examples: self.train_examples.load(Ordering::Relaxed),
            shed_requests: self.shed_requests.load(Ordering::Relaxed),
            accept_retries: self.accept_retries.load(Ordering::Relaxed),
            infer: self.infer.snapshot(),
            gemm: self.gemm.snapshot(),
            train: self.train.snapshot(),
            // the registry does not own the plane cache; the serving tier
            // overlays the live cache stats before rendering
            plane_cache: super::plane_cache::PlaneCacheStats::default(),
            numerics: crate::obs::numerics(),
        }
    }
}

/// Point-in-time view for the stats/metrics endpoints.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Items submitted to any batcher.
    pub requests: u64,
    /// Successful replies delivered.
    pub responses: u64,
    /// Error replies delivered.
    pub errors: u64,
    /// Batches formed.
    pub batches: u64,
    /// Mean items per formed batch.
    pub mean_batch_size: f64,
    /// Mean end-to-end latency (µs), blended across ops, over every
    /// histogram observation (error replies included).
    pub mean_latency_us: f64,
    /// Approximate p95 latency (µs, histogram bucket bound), blended.
    pub p95_latency_us: u64,
    /// MACs executed.
    pub macs: u64,
    /// GEMM requests that reached the serving path.
    pub gemm_requests: u64,
    /// Engine launches performed for GEMM traffic.
    pub fused_launches: u64,
    /// GEMM requests that shared a launch with another request.
    pub fused_tiles: u64,
    /// SGD train steps served.
    pub train_steps: u64,
    /// Labelled examples consumed by served train steps.
    pub train_examples: u64,
    /// Requests shed by admission control (subset of `requests`; not in
    /// `responses` or `errors`).
    pub shed_requests: u64,
    /// Transient accept() failures survived by the accept loops.
    pub accept_retries: u64,
    /// Infer-path telemetry.
    pub infer: OpSnapshot,
    /// GEMM-path telemetry.
    pub gemm: OpSnapshot,
    /// Train-path telemetry.
    pub train: OpSnapshot,
    /// Cross-batch plane-cache counters (overlaid by the serving tier;
    /// all-zero in snapshots taken without a tier attached).
    pub plane_cache: super::plane_cache::PlaneCacheStats,
    /// Posit numerics counters (process-wide, from [`crate::obs`]).
    pub numerics: crate::obs::NumericsSnapshot,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.responses.fetch_add(2, Ordering::Relaxed);
        m.record_batch(8);
        m.record_batch(4);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch_size, 6.0);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for us in [10u64, 20, 30, 40, 60, 80, 200, 300, 400, 30_000] {
            m.observe_latency(OpKind::Infer, Duration::from_micros(us));
        }
        // 40% of samples ≤ 50us bucket
        assert_eq!(m.latency_quantile_us(0.4), 50);
        // p90 within 500us bucket, p100 in 50ms bucket
        assert!(m.latency_quantile_us(0.9) <= 500);
        assert_eq!(m.latency_quantile_us(1.0), 50_000);
    }

    #[test]
    fn mean_latency_counts_every_observation() {
        let m = Metrics::new();
        // one success, one error reply: both latencies are observed, and
        // the mean divides by the histogram's own count — not `responses`
        m.responses.fetch_add(1, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        m.observe_latency(OpKind::Infer, Duration::from_micros(100));
        m.observe_latency(OpKind::Infer, Duration::from_micros(300));
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.snapshot().infer.latency.count, 2);
    }

    #[test]
    fn per_op_histograms_are_separate_and_merge_for_blended_stats() {
        let m = Metrics::new();
        m.observe_latency(OpKind::Infer, Duration::from_micros(40));
        m.observe_latency(OpKind::Gemm, Duration::from_micros(400));
        m.observe_latency(OpKind::Train, Duration::from_micros(40_000));
        let s = m.snapshot();
        assert_eq!(s.infer.latency.count, 1);
        assert_eq!(s.gemm.latency.count, 1);
        assert_eq!(s.train.latency.count, 1);
        assert_eq!(s.infer.latency.quantile_us(1.0), 50);
        assert_eq!(s.gemm.latency.quantile_us(1.0), 500);
        assert_eq!(s.train.latency.quantile_us(1.0), 50_000);
        // blended fields merge all three
        assert_eq!(s.mean_latency_us, (40.0 + 400.0 + 40_000.0) / 3.0);
        assert_eq!(s.p95_latency_us, 50_000);
    }

    #[test]
    fn queue_gauges_track_depth_and_wait() {
        let m = Metrics::new();
        m.queue_enter(OpKind::Gemm);
        m.queue_enter(OpKind::Gemm);
        m.queue_enter(OpKind::Infer);
        m.queue_leave(OpKind::Gemm, 2);
        m.record_batch_wait(OpKind::Gemm, Duration::from_micros(750));
        let s = m.snapshot();
        assert_eq!(s.gemm.queue_depth, 0);
        assert_eq!(s.infer.queue_depth, 1);
        assert_eq!(s.gemm.last_batch_wait_us, 750);
        // leaving more than entered saturates at zero instead of wrapping
        m.queue_leave(OpKind::Infer, 5);
        assert_eq!(m.snapshot().infer.queue_depth, 0);
    }

    #[test]
    fn macs_accumulate() {
        let m = Metrics::new();
        m.record_macs(1_000);
        m.record_macs(24);
        assert_eq!(m.snapshot().macs, 1_024);
    }

    #[test]
    fn fusion_counters_accumulate() {
        let m = Metrics::new();
        m.gemm_requests.fetch_add(5, Ordering::Relaxed);
        m.record_fusion(2, 4);
        m.record_fusion(1, 0);
        let s = m.snapshot();
        assert_eq!(s.gemm_requests, 5);
        assert_eq!(s.fused_launches, 3);
        assert_eq!(s.fused_tiles, 4);
    }

    #[test]
    fn train_counters_accumulate() {
        let m = Metrics::new();
        m.record_train_step(32);
        m.record_train_step(8);
        let s = m.snapshot();
        assert_eq!(s.train_steps, 2);
        assert_eq!(s.train_examples, 40);
    }

    #[test]
    fn shed_and_rejected_count_as_requests_with_distinct_outcomes() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_rejected();
        m.record_accept_retry();
        let s = m.snapshot();
        // sheds arrive but are neither responses nor errors; rejections
        // arrive *and* error
        assert_eq!(s.requests, 3);
        assert_eq!(s.shed_requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.responses, 0);
        assert_eq!(s.accept_retries, 1);
        assert_eq!(s.plane_cache, super::super::plane_cache::PlaneCacheStats::default());
    }

    #[test]
    fn empty_metrics_are_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.p95_latency_us, 0);
        assert_eq!(s.train_steps, 0);
        assert_eq!(s.train_examples, 0);
        assert_eq!(s.infer.latency.count, 0);
        assert_eq!(s.gemm.queue_depth, 0);
    }
}
