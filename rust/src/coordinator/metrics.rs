//! Coordinator metrics: lock-free counters plus a fixed-bucket latency
//! histogram (microseconds). No external deps; snapshot-able for the
//! `stats` endpoint.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds.
const BUCKETS_US: [u64; 12] = [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000];

/// Shared metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Items submitted to any batcher.
    pub requests: AtomicU64,
    /// Successful replies delivered.
    pub responses: AtomicU64,
    /// Error replies delivered.
    pub errors: AtomicU64,
    /// Batches formed by the dynamic batchers.
    pub batches: AtomicU64,
    /// Total items across all formed batches.
    pub batched_items: AtomicU64,
    /// MACs executed (where the backend reports them).
    pub macs: AtomicU64,
    /// GEMM requests that reached the serving path.
    pub gemm_requests: AtomicU64,
    /// Engine launches performed for GEMM traffic (fused: ≤ requests).
    pub fused_launches: AtomicU64,
    /// GEMM requests that shared a launch with at least one other request.
    pub fused_tiles: AtomicU64,
    /// SGD train steps served (software or PJRT backend).
    pub train_steps: AtomicU64,
    /// Labelled examples consumed by served train steps.
    pub train_examples: AtomicU64,
    latency_buckets: [AtomicU64; 13],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    /// Fresh all-zero registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one end-to-end request latency into the histogram.
    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Record one formed batch of `items` requests.
    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record the outcome of one fused GEMM execution: how many engine
    /// launches served the queue slice and how many of its tiles shared a
    /// launch (see [`super::fusion::FusionStats`]).
    pub fn record_fusion(&self, launches: u64, fused_tiles: u64) {
        self.fused_launches.fetch_add(launches, Ordering::Relaxed);
        self.fused_tiles.fetch_add(fused_tiles, Ordering::Relaxed);
    }

    /// Record one served SGD step over `examples` labelled images.
    pub fn record_train_step(&self, examples: usize) {
        self.train_steps.fetch_add(1, Ordering::Relaxed);
        self.train_examples.fetch_add(examples as u64, Ordering::Relaxed);
    }

    /// Mean observed latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate latency quantile from the histogram (bucket upper bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Mean items per formed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Consistent-enough point-in-time copy of every counter.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_size: self.mean_batch_size(),
            mean_latency_us: self.mean_latency_us(),
            p95_latency_us: self.latency_quantile_us(0.95),
            macs: self.macs.load(Ordering::Relaxed),
            gemm_requests: self.gemm_requests.load(Ordering::Relaxed),
            fused_launches: self.fused_launches.load(Ordering::Relaxed),
            fused_tiles: self.fused_tiles.load(Ordering::Relaxed),
            train_steps: self.train_steps.load(Ordering::Relaxed),
            train_examples: self.train_examples.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view for the stats endpoint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Items submitted to any batcher.
    pub requests: u64,
    /// Successful replies delivered.
    pub responses: u64,
    /// Error replies delivered.
    pub errors: u64,
    /// Batches formed.
    pub batches: u64,
    /// Mean items per formed batch.
    pub mean_batch_size: f64,
    /// Mean end-to-end latency (µs).
    pub mean_latency_us: f64,
    /// Approximate p95 latency (µs, histogram bucket bound).
    pub p95_latency_us: u64,
    /// MACs executed.
    pub macs: u64,
    /// GEMM requests that reached the serving path.
    pub gemm_requests: u64,
    /// Engine launches performed for GEMM traffic.
    pub fused_launches: u64,
    /// GEMM requests that shared a launch with another request.
    pub fused_tiles: u64,
    /// SGD train steps served.
    pub train_steps: u64,
    /// Labelled examples consumed by served train steps.
    pub train_examples: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.responses.fetch_add(2, Ordering::Relaxed);
        m.record_batch(8);
        m.record_batch(4);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch_size, 6.0);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for us in [10u64, 20, 30, 40, 60, 80, 200, 300, 400, 30_000] {
            m.observe_latency(Duration::from_micros(us));
        }
        // 40% of samples ≤ 50us bucket
        assert_eq!(m.latency_quantile_us(0.4), 50);
        // p90 within 500us bucket, p100 in 50ms bucket
        assert!(m.latency_quantile_us(0.9) <= 500);
        assert_eq!(m.latency_quantile_us(1.0), 50_000);
    }

    #[test]
    fn mean_latency_uses_response_count() {
        let m = Metrics::new();
        m.responses.fetch_add(2, Ordering::Relaxed);
        m.observe_latency(Duration::from_micros(100));
        m.observe_latency(Duration::from_micros(300));
        assert_eq!(m.mean_latency_us(), 200.0);
    }

    #[test]
    fn fusion_counters_accumulate() {
        let m = Metrics::new();
        m.gemm_requests.fetch_add(5, Ordering::Relaxed);
        m.record_fusion(2, 4);
        m.record_fusion(1, 0);
        let s = m.snapshot();
        assert_eq!(s.gemm_requests, 5);
        assert_eq!(s.fused_launches, 3);
        assert_eq!(s.fused_tiles, 4);
    }

    #[test]
    fn train_counters_accumulate() {
        let m = Metrics::new();
        m.record_train_step(32);
        m.record_train_step(8);
        let s = m.snapshot();
        assert_eq!(s.train_steps, 2);
        assert_eq!(s.train_examples, 40);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.p95_latency_us, 0);
        assert_eq!(s.train_steps, 0);
        assert_eq!(s.train_examples, 0);
    }
}
