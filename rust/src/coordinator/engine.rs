//! Backend dispatch for the serving tier.
//!
//! Two backends serve the same [`ServiceHandle`] surface:
//!
//! * **PJRT** — the `xla` crate's client/executable handles are `!Send`
//!   (they hold `Rc`s over C++ objects), so the coordinator confines them
//!   to one dedicated engine thread and talks to it over channels.
//! * **Software** — the pure-Rust [`SoftwareService`] is `Send + Sync`
//!   (its mutable state is the train graph behind a mutex), so calls
//!   dispatch **directly on the caller's thread**. This is what lets the
//!   sharded serving tier actually run shards in parallel: N batcher
//!   workers execute GEMM/infer concurrently instead of serializing
//!   behind one engine-thread channel. Train steps still serialize on the
//!   service's internal graph lock, preserving SGD step atomicity.
//!
//! Requests can carry an optional [`TraceCtx`] (`*_traced` methods): the
//! software backend threads it into the service's span-emitting variants;
//! the PJRT backend ignores it (kernel time is opaque behind XLA). The
//! plain methods delegate with `None`, so existing callers are untouched.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use super::fusion::FusionStats;
use super::lock_unpoisoned;
use super::plane_cache::PlaneCacheStats;
use super::service::{PositService, SoftwareService};
use crate::obs::trace::TraceCtx;
use crate::pdpu::{ConfigError, PdpuConfig};

/// One result per queued GEMM request plus the fusion outcome counters.
pub type GemmBatchReply = (Vec<Result<Vec<f32>, String>>, FusionStats);

enum EngineReq {
    InferBatch(Vec<Vec<f32>>, Option<TraceCtx>, Sender<Result<Vec<Vec<f32>>, String>>),
    TrainStep(Vec<Vec<f32>>, Vec<u32>, Option<TraceCtx>, Sender<Result<f32, String>>),
    Gemm(Vec<f32>, Vec<f32>, Sender<Result<Vec<f32>, String>>),
    GemmBatch(Vec<(Vec<f32>, Vec<f32>)>, Option<TraceCtx>, Sender<GemmBatchReply>),
    Shutdown,
}

/// Static model facts the rest of the system needs without touching PJRT.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Compiled/configured maximum inference batch size.
    pub batch: usize,
    /// Input feature count per image.
    pub input_dim: usize,
    /// Output class count.
    pub classes: usize,
    /// Fixed GEMM shape (M, K, N).
    pub gemm_mkn: (usize, usize, usize),
    /// Posit input format width.
    pub n_in: u32,
    /// Posit output/accumulator format width.
    pub n_out: u32,
    /// Posit exponent-size parameter.
    pub es: u32,
    /// Multiply-accumulates one forward pass of one example costs (the
    /// sum of the model's weight-matrix sizes). The server's MAC counter
    /// multiplies this by examples served — and by 3 for train steps
    /// (forward + the two backward GEMMs per layer are each ≈ the same
    /// tile volume).
    pub macs_per_example: u64,
}

/// Sum of 2-D parameter-shape products: the per-example forward MAC cost
/// of a dense MLP described by its weight shapes.
fn macs_from_shapes<'a>(shapes: impl Iterator<Item = &'a Vec<usize>>) -> u64 {
    shapes.filter(|s| s.len() == 2).map(|s| s.iter().product::<usize>() as u64).sum()
}

/// Per-example forward MAC cost of an MLP given its layer widths.
fn macs_from_layers(layer_sizes: &[usize]) -> u64 {
    layer_sizes.windows(2).map(|w| w.iter().product::<usize>() as u64).sum()
}

/// Which execution backend a [`ServiceHandle`] routes to.
#[derive(Clone)]
enum Backend {
    /// Channel into the dedicated PJRT engine thread (the `!Send` state
    /// owner), plus the join handle for shutdown.
    Pjrt { tx: Sender<EngineReq>, joiner: Arc<Mutex<Option<std::thread::JoinHandle<()>>>> },
    /// Shared software service: thread-safe, called directly so shards
    /// execute in parallel.
    Software(Arc<SoftwareService>),
}

/// Cloneable, `Send + Sync` handle the batcher/server/examples use.
#[derive(Clone)]
pub struct ServiceHandle {
    backend: Backend,
    info: ModelInfo,
}

impl ServiceHandle {
    /// Spawn the PJRT engine thread, loading artifacts from `dir`.
    pub fn start(dir: impl Into<std::path::PathBuf>) -> anyhow::Result<ServiceHandle> {
        let dir = dir.into();
        let (tx, rx) = channel::<EngineReq>();
        let (info_tx, info_rx) = channel::<Result<ModelInfo, String>>();
        let joiner = std::thread::spawn(move || {
            let service = match PositService::load(&dir) {
                Ok(s) => {
                    let m = s.manifest();
                    let _ = info_tx.send(Ok(ModelInfo {
                        batch: m.batch,
                        input_dim: m.input_dim(),
                        classes: m.classes(),
                        gemm_mkn: m.gemm_mkn,
                        n_in: m.n_in,
                        n_out: m.n_out,
                        es: m.es,
                        macs_per_example: macs_from_shapes(m.param_shapes.iter()),
                    }));
                    s
                }
                Err(e) => {
                    let _ = info_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    EngineReq::InferBatch(images, _ctx, reply) => {
                        let _ = reply.send(service.infer_batch(&images).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::TrainStep(images, labels, _ctx, reply) => {
                        let _ = reply.send(service.train_step(&images, &labels).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::Gemm(a, b, reply) => {
                        let _ = reply.send(service.gemm(&a, &b).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::GemmBatch(reqs, _ctx, reply) => {
                        // PJRT executables are compiled at a fixed (M, K, N),
                        // so the AOT path runs the queue one launch per
                        // request; only the software engine fuses.
                        let n = reqs.len() as u64;
                        let results = reqs
                            .iter()
                            .map(|(a, b)| service.gemm(a, b).map_err(|e| format!("{e:#}")))
                            .collect();
                        let _ = reply.send((results, FusionStats { launches: n, fused_tiles: 0 }));
                    }
                    EngineReq::Shutdown => return,
                }
            }
        });
        let info = info_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(ServiceHandle { backend: Backend::Pjrt { tx, joiner: Arc::new(Mutex::new(Some(joiner))) }, info })
    }

    /// Wrap an already-constructed [`SoftwareService`] (letting the caller
    /// tune it first, e.g. [`SoftwareService::with_plane_cache_capacity`]).
    /// No thread is spawned: the software backend is `Send + Sync` and
    /// executes on whichever shard calls it.
    pub fn from_software(service: SoftwareService) -> ServiceHandle {
        let cfg = *service.config();
        let info = ModelInfo {
            batch: service.batch_size(),
            input_dim: service.input_dim(),
            classes: service.classes(),
            gemm_mkn: service.gemm_mkn(),
            n_in: cfg.in_fmt.n(),
            n_out: cfg.out_fmt.n(),
            es: cfg.in_fmt.es(),
            macs_per_example: macs_from_layers(service.layer_sizes()),
        };
        ServiceHandle { backend: Backend::Software(Arc::new(service)), info }
    }

    /// Construct and wrap the pure-Rust [`SoftwareService`]: the
    /// batched-PDPU-engine backend that needs neither artifacts nor PJRT.
    /// Inference, GEMM, and train steps are all served — training runs
    /// real posit SGD through the batched engine ([`crate::train`]), the
    /// same wire op the PJRT backend serves from its AOT artifact.
    ///
    /// The service's configuration is validated here, so an invalid
    /// configuration comes back as a typed [`ConfigError`] with its real
    /// message.
    pub fn start_software(
        cfg: PdpuConfig,
        layer_sizes: Vec<usize>,
        batch: usize,
        gemm_mkn: (usize, usize, usize),
        seed: u64,
    ) -> Result<ServiceHandle, ConfigError> {
        Ok(Self::from_software(SoftwareService::new(cfg, &layer_sizes, batch, gemm_mkn, seed)?))
    }

    /// Static model facts (shapes and posit formats).
    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Plane-cache counters of the software backend's cross-batch cache
    /// (all-zero for the PJRT backend, which has no such cache).
    pub fn plane_cache_stats(&self) -> PlaneCacheStats {
        match &self.backend {
            Backend::Pjrt { .. } => PlaneCacheStats::default(),
            Backend::Software(svc) => svc.plane_cache_stats(),
        }
    }

    /// Run one inference batch through the backend.
    pub fn infer_batch(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>, String> {
        self.infer_batch_traced(images, None)
    }

    /// [`Self::infer_batch`] carrying a sampled request's trace context
    /// to the backend (software backend emits engine-side spans).
    pub fn infer_batch_traced(
        &self,
        images: Vec<Vec<f32>>,
        ctx: Option<TraceCtx>,
    ) -> Result<Vec<Vec<f32>>, String> {
        match &self.backend {
            Backend::Pjrt { tx: sender, .. } => {
                let (tx, rx) = channel();
                sender.send(EngineReq::InferBatch(images, ctx, tx)).map_err(|_| "engine gone".to_string())?;
                rx.recv().map_err(|_| "engine gone".to_string())?
            }
            Backend::Software(svc) => svc.infer_batch_traced(&images, ctx),
        }
    }

    /// One SGD step on a labelled batch; updates the served parameters and
    /// returns the batch loss. The PJRT backend runs its AOT train-step
    /// artifact (full compiled batch required); the software backend runs
    /// posit SGD through the batched engine (any batch up to the
    /// configured size).
    pub fn train_step(&self, images: Vec<Vec<f32>>, labels: Vec<u32>) -> Result<f32, String> {
        self.train_step_traced(images, labels, None)
    }

    /// [`Self::train_step`] carrying a sampled request's trace context.
    pub fn train_step_traced(
        &self,
        images: Vec<Vec<f32>>,
        labels: Vec<u32>,
        ctx: Option<TraceCtx>,
    ) -> Result<f32, String> {
        match &self.backend {
            Backend::Pjrt { tx: sender, .. } => {
                let (tx, rx) = channel();
                sender
                    .send(EngineReq::TrainStep(images, labels, ctx, tx))
                    .map_err(|_| "engine gone".to_string())?;
                rx.recv().map_err(|_| "engine gone".to_string())?
            }
            Backend::Software(svc) => svc.train_step_traced(&images, &labels, ctx),
        }
    }

    /// One GEMM at the compiled/configured (M, K, N).
    pub fn gemm(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>, String> {
        match &self.backend {
            Backend::Pjrt { tx: sender, .. } => {
                let (tx, rx) = channel();
                sender.send(EngineReq::Gemm(a, b, tx)).map_err(|_| "engine gone".to_string())?;
                rx.recv().map_err(|_| "engine gone".to_string())?
            }
            Backend::Software(svc) => svc.gemm(&a, &b),
        }
    }

    /// A queue of GEMM requests executed in one backend call. The
    /// software backend coalesces compatible requests into fused launches
    /// ([`super::fusion`]) through the cross-batch plane cache; the PJRT
    /// backend runs one compiled launch per request. Either way the reply
    /// holds one result per request, in order, plus the launch counters.
    pub fn gemm_batch(&self, reqs: Vec<(Vec<f32>, Vec<f32>)>) -> Result<GemmBatchReply, String> {
        self.gemm_batch_traced(reqs, None)
    }

    /// [`Self::gemm_batch`] carrying a sampled request's trace context
    /// (software backend times `fusion_plan` / `engine_launch` under it).
    pub fn gemm_batch_traced(
        &self,
        reqs: Vec<(Vec<f32>, Vec<f32>)>,
        ctx: Option<TraceCtx>,
    ) -> Result<GemmBatchReply, String> {
        match &self.backend {
            Backend::Pjrt { tx: sender, .. } => {
                let (tx, rx) = channel();
                sender.send(EngineReq::GemmBatch(reqs, ctx, tx)).map_err(|_| "engine gone".to_string())?;
                rx.recv().map_err(|_| "engine gone".to_string())
            }
            Backend::Software(svc) => Ok(svc.gemm_batch_traced(&reqs, ctx)),
        }
    }

    /// Ask the PJRT engine thread to exit once current work drains (the
    /// software backend has no thread; dropping the handle suffices).
    pub fn shutdown(&self) {
        if let Backend::Pjrt { tx, joiner } = &self.backend {
            let _ = tx.send(EngineReq::Shutdown);
            if let Some(j) = lock_unpoisoned(joiner).take() {
                let _ = j.join();
            }
        }
    }
}
