//! Engine thread — the single owner of all PJRT state.
//!
//! The `xla` crate's client/executable handles are `!Send` (they hold
//! `Rc`s over C++ objects), so the coordinator confines them to one
//! dedicated thread and talks to it over channels. [`ServiceHandle`] is
//! the cloneable, `Send + Sync` face the batcher/server/examples use.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use super::fusion::FusionStats;
use super::lock_unpoisoned;
use super::service::{PositService, SoftwareService};
use crate::pdpu::{ConfigError, PdpuConfig};

/// One result per queued GEMM request plus the fusion outcome counters.
pub type GemmBatchReply = (Vec<Result<Vec<f32>, String>>, FusionStats);

enum EngineReq {
    InferBatch(Vec<Vec<f32>>, Sender<Result<Vec<Vec<f32>>, String>>),
    TrainStep(Vec<Vec<f32>>, Vec<u32>, Sender<Result<f32, String>>),
    Gemm(Vec<f32>, Vec<f32>, Sender<Result<Vec<f32>, String>>),
    GemmBatch(Vec<(Vec<f32>, Vec<f32>)>, Sender<GemmBatchReply>),
    Shutdown,
}

/// Static model facts the rest of the system needs without touching PJRT.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Compiled/configured maximum inference batch size.
    pub batch: usize,
    /// Input feature count per image.
    pub input_dim: usize,
    /// Output class count.
    pub classes: usize,
    /// Fixed GEMM shape (M, K, N).
    pub gemm_mkn: (usize, usize, usize),
    /// Posit input format width.
    pub n_in: u32,
    /// Posit output/accumulator format width.
    pub n_out: u32,
    /// Posit exponent-size parameter.
    pub es: u32,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<EngineReq>,
    info: ModelInfo,
    joiner: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl ServiceHandle {
    /// Spawn the engine thread, loading artifacts from `dir`.
    pub fn start(dir: impl Into<std::path::PathBuf>) -> anyhow::Result<ServiceHandle> {
        let dir = dir.into();
        let (tx, rx) = channel::<EngineReq>();
        let (info_tx, info_rx) = channel::<Result<ModelInfo, String>>();
        let joiner = std::thread::spawn(move || {
            let service = match PositService::load(&dir) {
                Ok(s) => {
                    let m = s.manifest();
                    let _ = info_tx.send(Ok(ModelInfo {
                        batch: m.batch,
                        input_dim: m.input_dim(),
                        classes: m.classes(),
                        gemm_mkn: m.gemm_mkn,
                        n_in: m.n_in,
                        n_out: m.n_out,
                        es: m.es,
                    }));
                    s
                }
                Err(e) => {
                    let _ = info_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    EngineReq::InferBatch(images, reply) => {
                        let _ = reply.send(service.infer_batch(&images).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::TrainStep(images, labels, reply) => {
                        let _ = reply.send(service.train_step(&images, &labels).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::Gemm(a, b, reply) => {
                        let _ = reply.send(service.gemm(&a, &b).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::GemmBatch(reqs, reply) => {
                        // PJRT executables are compiled at a fixed (M, K, N),
                        // so the AOT path runs the queue one launch per
                        // request; only the software engine fuses.
                        let n = reqs.len() as u64;
                        let results = reqs
                            .iter()
                            .map(|(a, b)| service.gemm(a, b).map_err(|e| format!("{e:#}")))
                            .collect();
                        let _ = reply.send((results, FusionStats { launches: n, fused_tiles: 0 }));
                    }
                    EngineReq::Shutdown => return,
                }
            }
        });
        let info = info_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(ServiceHandle { tx, info, joiner: Arc::new(Mutex::new(Some(joiner))) })
    }

    /// Spawn an engine thread over the pure-Rust [`SoftwareService`]: the
    /// batched-PDPU-engine backend that needs neither artifacts nor PJRT.
    /// Inference, GEMM, and train steps are all served — training runs
    /// real posit SGD through the batched engine ([`crate::train`]), the
    /// same wire op the PJRT backend serves from its AOT artifact.
    ///
    /// The service is constructed (and its configuration validated) on the
    /// caller's thread *before* the engine thread spawns, so an invalid
    /// configuration comes back as a typed [`ConfigError`] with its real
    /// message instead of killing the engine thread and turning every
    /// later request into an opaque "engine gone" error.
    pub fn start_software(
        cfg: PdpuConfig,
        layer_sizes: Vec<usize>,
        batch: usize,
        gemm_mkn: (usize, usize, usize),
        seed: u64,
    ) -> Result<ServiceHandle, ConfigError> {
        let service = SoftwareService::new(cfg, &layer_sizes, batch, gemm_mkn, seed)?;
        let info = ModelInfo {
            batch,
            input_dim: service.input_dim(),
            classes: service.classes(),
            gemm_mkn,
            n_in: cfg.in_fmt.n(),
            n_out: cfg.out_fmt.n(),
            es: cfg.in_fmt.es(),
        };
        let (tx, rx) = channel::<EngineReq>();
        let joiner = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    EngineReq::InferBatch(images, reply) => {
                        let _ = reply.send(service.infer_batch(&images));
                    }
                    EngineReq::TrainStep(images, labels, reply) => {
                        let _ = reply.send(service.train_step(&images, &labels));
                    }
                    EngineReq::Gemm(a, b, reply) => {
                        let _ = reply.send(service.gemm(&a, &b));
                    }
                    EngineReq::GemmBatch(reqs, reply) => {
                        let _ = reply.send(service.gemm_batch(&reqs));
                    }
                    EngineReq::Shutdown => return,
                }
            }
        });
        Ok(ServiceHandle { tx, info, joiner: Arc::new(Mutex::new(Some(joiner))) })
    }

    /// Static model facts (shapes and posit formats).
    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Run one inference batch through the backend.
    pub fn infer_batch(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::InferBatch(images, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// One SGD step on a labelled batch; updates the served parameters and
    /// returns the batch loss. The PJRT backend runs its AOT train-step
    /// artifact (full compiled batch required); the software backend runs
    /// posit SGD through the batched engine (any batch up to the
    /// configured size).
    pub fn train_step(&self, images: Vec<Vec<f32>>, labels: Vec<u32>) -> Result<f32, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::TrainStep(images, labels, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// One GEMM at the compiled/configured (M, K, N).
    pub fn gemm(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::Gemm(a, b, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// A queue of GEMM requests executed in one engine-thread round trip.
    /// The software backend coalesces compatible requests into fused
    /// launches ([`super::fusion`]); the PJRT backend runs one compiled
    /// launch per request. Either way the reply holds one result per
    /// request, in order, plus the launch counters.
    pub fn gemm_batch(&self, reqs: Vec<(Vec<f32>, Vec<f32>)>) -> Result<GemmBatchReply, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::GemmBatch(reqs, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())
    }

    /// Ask the engine to exit once current work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineReq::Shutdown);
        if let Some(j) = lock_unpoisoned(&self.joiner).take() {
            let _ = j.join();
        }
    }
}
