//! Engine thread — the single owner of all PJRT state.
//!
//! The `xla` crate's client/executable handles are `!Send` (they hold
//! `Rc`s over C++ objects), so the coordinator confines them to one
//! dedicated thread and talks to it over channels. [`ServiceHandle`] is
//! the cloneable, `Send + Sync` face the batcher/server/examples use.
//!
//! Requests can carry an optional [`TraceCtx`] (`*_traced` methods): the
//! software backend threads it into the service's span-emitting variants;
//! the PJRT backend ignores it (kernel time is opaque behind XLA). The
//! plain methods delegate with `None`, so existing callers are untouched.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use super::fusion::FusionStats;
use super::lock_unpoisoned;
use super::service::{PositService, SoftwareService};
use crate::obs::trace::TraceCtx;
use crate::pdpu::{ConfigError, PdpuConfig};

/// One result per queued GEMM request plus the fusion outcome counters.
pub type GemmBatchReply = (Vec<Result<Vec<f32>, String>>, FusionStats);

enum EngineReq {
    InferBatch(Vec<Vec<f32>>, Option<TraceCtx>, Sender<Result<Vec<Vec<f32>>, String>>),
    TrainStep(Vec<Vec<f32>>, Vec<u32>, Option<TraceCtx>, Sender<Result<f32, String>>),
    Gemm(Vec<f32>, Vec<f32>, Sender<Result<Vec<f32>, String>>),
    GemmBatch(Vec<(Vec<f32>, Vec<f32>)>, Option<TraceCtx>, Sender<GemmBatchReply>),
    Shutdown,
}

/// Static model facts the rest of the system needs without touching PJRT.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Compiled/configured maximum inference batch size.
    pub batch: usize,
    /// Input feature count per image.
    pub input_dim: usize,
    /// Output class count.
    pub classes: usize,
    /// Fixed GEMM shape (M, K, N).
    pub gemm_mkn: (usize, usize, usize),
    /// Posit input format width.
    pub n_in: u32,
    /// Posit output/accumulator format width.
    pub n_out: u32,
    /// Posit exponent-size parameter.
    pub es: u32,
    /// Multiply-accumulates one forward pass of one example costs (the
    /// sum of the model's weight-matrix sizes). The server's MAC counter
    /// multiplies this by examples served — and by 3 for train steps
    /// (forward + the two backward GEMMs per layer are each ≈ the same
    /// tile volume).
    pub macs_per_example: u64,
}

/// Sum of 2-D parameter-shape products: the per-example forward MAC cost
/// of a dense MLP described by its weight shapes.
fn macs_from_shapes<'a>(shapes: impl Iterator<Item = &'a Vec<usize>>) -> u64 {
    shapes.filter(|s| s.len() == 2).map(|s| s.iter().product::<usize>() as u64).sum()
}

/// Per-example forward MAC cost of an MLP given its layer widths.
fn macs_from_layers(layer_sizes: &[usize]) -> u64 {
    layer_sizes.windows(2).map(|w| w.iter().product::<usize>() as u64).sum()
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<EngineReq>,
    info: ModelInfo,
    joiner: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl ServiceHandle {
    /// Spawn the engine thread, loading artifacts from `dir`.
    pub fn start(dir: impl Into<std::path::PathBuf>) -> anyhow::Result<ServiceHandle> {
        let dir = dir.into();
        let (tx, rx) = channel::<EngineReq>();
        let (info_tx, info_rx) = channel::<Result<ModelInfo, String>>();
        let joiner = std::thread::spawn(move || {
            let service = match PositService::load(&dir) {
                Ok(s) => {
                    let m = s.manifest();
                    let _ = info_tx.send(Ok(ModelInfo {
                        batch: m.batch,
                        input_dim: m.input_dim(),
                        classes: m.classes(),
                        gemm_mkn: m.gemm_mkn,
                        n_in: m.n_in,
                        n_out: m.n_out,
                        es: m.es,
                        macs_per_example: macs_from_shapes(m.param_shapes.iter()),
                    }));
                    s
                }
                Err(e) => {
                    let _ = info_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    EngineReq::InferBatch(images, _ctx, reply) => {
                        let _ = reply.send(service.infer_batch(&images).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::TrainStep(images, labels, _ctx, reply) => {
                        let _ = reply.send(service.train_step(&images, &labels).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::Gemm(a, b, reply) => {
                        let _ = reply.send(service.gemm(&a, &b).map_err(|e| format!("{e:#}")));
                    }
                    EngineReq::GemmBatch(reqs, _ctx, reply) => {
                        // PJRT executables are compiled at a fixed (M, K, N),
                        // so the AOT path runs the queue one launch per
                        // request; only the software engine fuses.
                        let n = reqs.len() as u64;
                        let results = reqs
                            .iter()
                            .map(|(a, b)| service.gemm(a, b).map_err(|e| format!("{e:#}")))
                            .collect();
                        let _ = reply.send((results, FusionStats { launches: n, fused_tiles: 0 }));
                    }
                    EngineReq::Shutdown => return,
                }
            }
        });
        let info = info_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(ServiceHandle { tx, info, joiner: Arc::new(Mutex::new(Some(joiner))) })
    }

    /// Spawn an engine thread over the pure-Rust [`SoftwareService`]: the
    /// batched-PDPU-engine backend that needs neither artifacts nor PJRT.
    /// Inference, GEMM, and train steps are all served — training runs
    /// real posit SGD through the batched engine ([`crate::train`]), the
    /// same wire op the PJRT backend serves from its AOT artifact.
    ///
    /// The service is constructed (and its configuration validated) on the
    /// caller's thread *before* the engine thread spawns, so an invalid
    /// configuration comes back as a typed [`ConfigError`] with its real
    /// message instead of killing the engine thread and turning every
    /// later request into an opaque "engine gone" error.
    pub fn start_software(
        cfg: PdpuConfig,
        layer_sizes: Vec<usize>,
        batch: usize,
        gemm_mkn: (usize, usize, usize),
        seed: u64,
    ) -> Result<ServiceHandle, ConfigError> {
        let service = SoftwareService::new(cfg, &layer_sizes, batch, gemm_mkn, seed)?;
        let info = ModelInfo {
            batch,
            input_dim: service.input_dim(),
            classes: service.classes(),
            gemm_mkn,
            n_in: cfg.in_fmt.n(),
            n_out: cfg.out_fmt.n(),
            es: cfg.in_fmt.es(),
            macs_per_example: macs_from_layers(&layer_sizes),
        };
        let (tx, rx) = channel::<EngineReq>();
        let joiner = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                match req {
                    EngineReq::InferBatch(images, ctx, reply) => {
                        let _ = reply.send(service.infer_batch_traced(&images, ctx));
                    }
                    EngineReq::TrainStep(images, labels, ctx, reply) => {
                        let _ = reply.send(service.train_step_traced(&images, &labels, ctx));
                    }
                    EngineReq::Gemm(a, b, reply) => {
                        let _ = reply.send(service.gemm(&a, &b));
                    }
                    EngineReq::GemmBatch(reqs, ctx, reply) => {
                        let _ = reply.send(service.gemm_batch_traced(&reqs, ctx));
                    }
                    EngineReq::Shutdown => return,
                }
            }
        });
        Ok(ServiceHandle { tx, info, joiner: Arc::new(Mutex::new(Some(joiner))) })
    }

    /// Static model facts (shapes and posit formats).
    pub fn info(&self) -> &ModelInfo {
        &self.info
    }

    /// Run one inference batch through the backend.
    pub fn infer_batch(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>, String> {
        self.infer_batch_traced(images, None)
    }

    /// [`Self::infer_batch`] carrying a sampled request's trace context
    /// to the backend (software backend emits engine-side spans).
    pub fn infer_batch_traced(
        &self,
        images: Vec<Vec<f32>>,
        ctx: Option<TraceCtx>,
    ) -> Result<Vec<Vec<f32>>, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::InferBatch(images, ctx, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// One SGD step on a labelled batch; updates the served parameters and
    /// returns the batch loss. The PJRT backend runs its AOT train-step
    /// artifact (full compiled batch required); the software backend runs
    /// posit SGD through the batched engine (any batch up to the
    /// configured size).
    pub fn train_step(&self, images: Vec<Vec<f32>>, labels: Vec<u32>) -> Result<f32, String> {
        self.train_step_traced(images, labels, None)
    }

    /// [`Self::train_step`] carrying a sampled request's trace context.
    pub fn train_step_traced(
        &self,
        images: Vec<Vec<f32>>,
        labels: Vec<u32>,
        ctx: Option<TraceCtx>,
    ) -> Result<f32, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::TrainStep(images, labels, ctx, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// One GEMM at the compiled/configured (M, K, N).
    pub fn gemm(&self, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::Gemm(a, b, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())?
    }

    /// A queue of GEMM requests executed in one engine-thread round trip.
    /// The software backend coalesces compatible requests into fused
    /// launches ([`super::fusion`]); the PJRT backend runs one compiled
    /// launch per request. Either way the reply holds one result per
    /// request, in order, plus the launch counters.
    pub fn gemm_batch(&self, reqs: Vec<(Vec<f32>, Vec<f32>)>) -> Result<GemmBatchReply, String> {
        self.gemm_batch_traced(reqs, None)
    }

    /// [`Self::gemm_batch`] carrying a sampled request's trace context
    /// (software backend times `fusion_plan` / `engine_launch` under it).
    pub fn gemm_batch_traced(
        &self,
        reqs: Vec<(Vec<f32>, Vec<f32>)>,
        ctx: Option<TraceCtx>,
    ) -> Result<GemmBatchReply, String> {
        let (tx, rx) = channel();
        self.tx.send(EngineReq::GemmBatch(reqs, ctx, tx)).map_err(|_| "engine gone".to_string())?;
        rx.recv().map_err(|_| "engine gone".to_string())
    }

    /// Ask the engine to exit once current work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineReq::Shutdown);
        if let Some(j) = lock_unpoisoned(&self.joiner).take() {
            let _ = j.join();
        }
    }
}
