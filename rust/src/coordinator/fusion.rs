//! Cross-request GEMM fusion — coalescing compatible queued GEMM tiles
//! into single [`BatchEngine`] launches.
//!
//! The serving path already fuses *inside* one dot product (the PDPU
//! datapath) and *across images* of one inference batch (the dynamic
//! batcher). What it did not fuse, until this module, is across **queued
//! GEMM requests**: each request executed as its own engine launch even
//! when the queue held many requests multiplying the *same* left operand
//! plane (the canonical serving shape: one weight matrix, many activation
//! tiles).
//!
//! Fusion is a pure scheduling optimization with a hard invariant:
//! **bit-identical outputs and unchanged per-request response order**.
//! That holds by construction — quantization/pre-decode is per-value, and
//! every GEMM output element depends only on its own accumulator seed,
//! weight row, and right-hand vector — and it is property-tested in
//! `rust/tests/engine_equivalence.rs`.
//!
//! Eligibility: two tiles fuse only when they agree on the [`PdpuConfig`],
//! the inner dimension `k`, the accumulator seeds, and the shared left
//! operand plane (compared bit-for-bit as f64 patterns). Mixed-config
//! queues therefore never fuse (property-tested).
//!
//! Planning **interns planes by content hash**: each tile hashes its
//! accumulator seeds and left plane once (FNV-1a over the f64 bit
//! patterns) and only full-compares against group representatives inside
//! its own `(config, k, hash)` bucket. A tile therefore performs one
//! O(plane) hash plus, almost always, at most one O(plane) confirm —
//! instead of the pre-interning O(groups) bitwise compares per tile — and
//! the grouping decisions are provably unchanged (equal planes hash
//! equally, and the representative confirm rejects collisions;
//! property-tested against the linear-scan reference in
//! `rust/tests/engine_equivalence.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use super::plane_cache::PlaneCache;
use crate::engine::{BatchEngine, PreparedOperands};
use crate::pdpu::PdpuConfig;
use crate::posit::Posit;

/// One queued GEMM tile: compute `acc + a · bᵀ` through the batched PDPU
/// engine, where `a` is `m×k` row-major and `bt` holds the `n` right-hand
/// vectors contiguously (`n×k` row-major — the transposed right matrix,
/// i.e. the im2col layout the engine wants).
#[derive(Clone, Debug)]
pub struct GemmTile {
    /// PDPU configuration the tile must execute under.
    pub cfg: PdpuConfig,
    /// Inner (dot-product) dimension.
    pub k: usize,
    /// Accumulator seeds, one per output row (`m` values).
    pub acc: Vec<f64>,
    /// Left operand plane, `m×k` row-major — the fusion-sharing candidate.
    pub a: Vec<f64>,
    /// Transposed right operand, `n×k` row-major.
    pub bt: Vec<f64>,
}

impl GemmTile {
    /// Output rows (`a.len() / k`).
    pub fn m(&self) -> usize {
        self.a.len() / self.k
    }

    /// Output columns (`bt.len() / k`).
    pub fn n(&self) -> usize {
        self.bt.len() / self.k
    }

    fn assert_shapes(&self) {
        assert!(self.k > 0, "inner dimension k must be positive");
        assert_eq!(self.a.len() % self.k, 0, "a length not a multiple of k");
        assert_eq!(self.bt.len() % self.k, 0, "bt length not a multiple of k");
        assert_eq!(self.acc.len(), self.m(), "one accumulator seed per output row");
    }

    /// Fusion eligibility: same config, same `k`, and bit-identical
    /// accumulator and left-plane contents.
    fn fuses_with(&self, other: &GemmTile) -> bool {
        self.cfg == other.cfg
            && self.k == other.k
            && f64_bits_eq(&self.acc, &other.acc)
            && f64_bits_eq(&self.a, &other.a)
    }
}

/// Bitwise slice equality (f64 patterns, so `-0.0`/`NaN` never alias).
/// Shared with the [`super::plane_cache`] lookup confirm.
pub(crate) fn f64_bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

fn fnv_feed(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// FNV-1a over an f64 plane's bit patterns (length-seeded). This is the
/// hash the [`super::plane_cache`] keys on; equal planes hash equally and
/// every consumer confirms bitwise before trusting a match.
pub(crate) fn hash_f64_plane(vals: &[f64]) -> u64 {
    let mut h = fnv_feed(FNV_OFFSET, vals.len() as u64);
    for &v in vals {
        h = fnv_feed(h, v.to_bits());
    }
    h
}

/// FNV-1a over a tile's fusion-relevant content (accumulator seeds + left
/// plane, as f64 bit patterns). Tiles with bit-identical content hash
/// identically; a collision only costs one extra representative compare.
fn plane_hash(t: &GemmTile) -> u64 {
    let mut h = fnv_feed(FNV_OFFSET, t.acc.len() as u64);
    for &v in &t.acc {
        h = fnv_feed(h, v.to_bits());
    }
    for &v in &t.a {
        h = fnv_feed(h, v.to_bits());
    }
    h
}

/// Outcome counters of one fused execution, for the metrics endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FusionStats {
    /// Engine launches actually performed (= fusion groups).
    pub launches: u64,
    /// Tiles that shared a launch with at least one other tile.
    pub fused_tiles: u64,
}

/// Partition a request queue into fusion groups: each group is a list of
/// tile indices (in queue order) that are mutually fusion-eligible;
/// groups are ordered by their first member. Singleton groups are tiles
/// nothing else could join.
///
/// Groups are found through the interning map (`(config, k, plane hash)`
/// → candidate groups), so planning is O(plane) per tile instead of
/// O(groups · plane); the decisions are identical to a linear scan
/// because every group a tile could fuse with shares its key, and the
/// representative compare inside the bucket rejects hash collisions.
pub fn plan_fusion(tiles: &[GemmTile]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    // bucket entries carry (group index, representative tile index) so the
    // confirm compare needs no back-indexing into the groups themselves
    let mut interned: HashMap<(PdpuConfig, usize, u64), Vec<(usize, usize)>> = HashMap::new();
    for (i, t) in tiles.iter().enumerate() {
        t.assert_shapes();
        let bucket = interned.entry((t.cfg, t.k, plane_hash(t))).or_default();
        match bucket.iter().find(|&&(_, rep)| t.fuses_with(&tiles[rep])) {
            Some(&(g, _)) => groups[g].push(i),
            None => {
                bucket.push((groups.len(), i));
                groups.push(vec![i]);
            }
        }
    }
    groups
}

/// Execute a request queue with cross-request fusion: one engine launch
/// per fusion group, concatenating the member tiles' right-hand planes
/// into one prepared operand matrix. Returns one `m·n` row-major output
/// per tile, **in queue order**, bit-identical to [`execute_unfused`].
pub fn execute_fused(tiles: &[GemmTile]) -> (Vec<Vec<f64>>, FusionStats) {
    let groups = plan_fusion(tiles);
    execute_planned(tiles, &groups)
}

/// Execute a queue under an already-computed fusion plan (the `groups`
/// returned by [`plan_fusion`] for these exact `tiles`). Split out from
/// [`execute_fused`] so the serving path can time planning and launching
/// as separate trace spans without perturbing what either step does.
pub fn execute_planned(tiles: &[GemmTile], groups: &[Vec<usize>]) -> (Vec<Vec<f64>>, FusionStats) {
    execute_planned_cached(tiles, groups, None)
}

/// [`execute_planned`] with an optional cross-batch [`PlaneCache`]: when a
/// cache is supplied, each group's shared left plane is fetched through it
/// (quantizing only on first sight) instead of being re-prepared per
/// launch. Cached and uncached execution are bit-identical — quantization
/// is per-value and deterministic, and the cache confirms planes bitwise —
/// so this stays a pure scheduling/memoization optimization.
pub fn execute_planned_cached(
    tiles: &[GemmTile],
    groups: &[Vec<usize>],
    cache: Option<&PlaneCache>,
) -> (Vec<Vec<f64>>, FusionStats) {
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); tiles.len()];
    let mut stats = FusionStats::default();
    for g in groups {
        stats.launches += 1;
        if g.len() > 1 {
            stats.fused_tiles += g.len() as u64;
        }
        let Some(&first_idx) = g.first() else { continue };
        let first = &tiles[first_idx];
        let (cfg, k) = (first.cfg, first.k);
        let engine = BatchEngine::new(cfg);
        let wp: Arc<PreparedOperands> = match cache {
            Some(c) => c.get_or_prepare(&cfg, k, &first.a),
            None => Arc::new(PreparedOperands::quantize(cfg.in_fmt, &first.a, k)),
        };
        // shared plane prepared once; member right-hand planes concatenated
        // into one x matrix (quantization is per-value, so this equals the
        // per-tile quantization bit-for-bit)
        let cap: usize = g.iter().map(|&i| tiles[i].bt.len()).sum();
        let mut xcat = Vec::with_capacity(cap);
        for &i in g {
            xcat.extend_from_slice(&tiles[i].bt);
        }
        let xp = PreparedOperands::quantize(cfg.in_fmt, &xcat, k);
        let accp: Vec<Posit> = first.acc.iter().map(|&v| Posit::from_f64(v, cfg.out_fmt)).collect();
        let fused = engine.gemm_posit(&accp, &wp, &xp);
        // scatter the fused launch's columns back to the member tiles
        let (m, cols_total) = (wp.rows(), xp.rows());
        let mut off = 0usize;
        for &i in g {
            let n_i = tiles[i].n();
            let mut o = Vec::with_capacity(m * n_i);
            for r in 0..m {
                for c in 0..n_i {
                    o.push(fused[r * cols_total + off + c].to_f64());
                }
            }
            out[i] = o;
            off += n_i;
        }
    }
    (out, stats)
}

/// Execute a request queue without fusion: one engine launch per tile (the
/// pre-fusion serving path, kept as the A/B + equivalence baseline).
pub fn execute_unfused(tiles: &[GemmTile]) -> Vec<Vec<f64>> {
    tiles
        .iter()
        .map(|t| {
            t.assert_shapes();
            let engine = BatchEngine::new(t.cfg);
            let wp = PreparedOperands::quantize(t.cfg.in_fmt, &t.a, t.k);
            let xp = PreparedOperands::quantize(t.cfg.in_fmt, &t.bt, t.k);
            let accp: Vec<Posit> = t.acc.iter().map(|&v| Posit::from_f64(v, t.cfg.out_fmt)).collect();
            let outs = engine.gemm_posit(&accp, &wp, &xp);
            outs.iter().map(|p| p.to_f64()).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn tile(cfg: PdpuConfig, rng: &mut Rng, m: usize, k: usize, n: usize) -> GemmTile {
        GemmTile {
            cfg,
            k,
            acc: vec![0.0; m],
            a: (0..m * k).map(|_| rng.normal()).collect(),
            bt: (0..n * k).map(|_| rng.normal()).collect(),
        }
    }

    #[test]
    fn shared_plane_tiles_fuse_into_one_launch() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xF0);
        let base = tile(cfg, &mut rng, 3, 7, 4);
        let mut t2 = base.clone();
        t2.bt = (0..4 * 7).map(|_| rng.normal()).collect();
        let groups = plan_fusion(&[base.clone(), t2.clone()]);
        assert_eq!(groups, vec![vec![0, 1]]);
        let (outs, stats) = execute_fused(&[base, t2]);
        assert_eq!(stats, FusionStats { launches: 1, fused_tiles: 2 });
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().all(|o| o.len() == 3 * 4));
    }

    #[test]
    fn distinct_planes_stay_separate() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xF1);
        let t1 = tile(cfg, &mut rng, 2, 5, 3);
        let t2 = tile(cfg, &mut rng, 2, 5, 3);
        let groups = plan_fusion(&[t1, t2]);
        assert_eq!(groups, vec![vec![0], vec![1]]);
    }

    #[test]
    fn mixed_configs_never_fuse() {
        let cfg_a = PdpuConfig::paper_default();
        let cfg_b = PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap();
        let mut rng = Rng::seeded(0xF2);
        let t1 = tile(cfg_a, &mut rng, 2, 6, 3);
        let mut t2 = t1.clone();
        t2.cfg = cfg_b;
        let (outs, stats) = execute_fused(&[t1.clone(), t2.clone()]);
        assert_eq!(stats, FusionStats { launches: 2, fused_tiles: 0 });
        assert_eq!(outs, execute_unfused(&[t1, t2]));
    }

    #[test]
    fn fused_matches_unfused_bitwise() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xF3);
        let shared = tile(cfg, &mut rng, 4, 11, 2);
        let mut queue = Vec::new();
        for _ in 0..3 {
            let mut t = shared.clone();
            t.bt = (0..2 * 11).map(|_| rng.normal()).collect();
            queue.push(t);
        }
        queue.push(tile(cfg, &mut rng, 4, 11, 2)); // unique plane, won't fuse
        let (fused, stats) = execute_fused(&queue);
        let unfused = execute_unfused(&queue);
        assert_eq!(stats, FusionStats { launches: 2, fused_tiles: 3 });
        for (i, (f, u)) in fused.iter().zip(&unfused).enumerate() {
            assert_eq!(
                f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                u.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tile {i}"
            );
        }
    }

    /// The pre-interning linear-scan planner, kept as the grouping oracle
    /// for the interning equivalence property.
    fn plan_fusion_linear(tiles: &[GemmTile]) -> Vec<Vec<usize>> {
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, t) in tiles.iter().enumerate() {
            match groups.iter_mut().find(|g| t.fuses_with(&tiles[g[0]])) {
                Some(g) => g.push(i),
                None => groups.push(vec![i]),
            }
        }
        groups
    }

    #[test]
    fn interned_planning_matches_linear_scan() {
        let cfg_a = PdpuConfig::paper_default();
        let cfg_b = PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap();
        let mut rng = Rng::seeded(0x1A7E);
        for round in 0..50 {
            // a queue mixing shared planes, near-twins (same shape,
            // different bits), differing acc seeds, and two configs
            let (m, k) = (1 + rng.below(3) as usize, 1 + rng.below(6) as usize);
            let planes: Vec<Vec<f64>> = (0..2).map(|_| (0..m * k).map(|_| rng.normal()).collect()).collect();
            let tiles: Vec<GemmTile> = (0..(1 + rng.below(12) as usize))
                .map(|_| {
                    let mut a = planes[rng.below(2) as usize].clone();
                    if rng.below(4) == 0 {
                        // near-twin: flip one sign bit → must not fuse
                        let i = rng.below(a.len() as u64) as usize;
                        a[i] = -a[i];
                    }
                    GemmTile {
                        cfg: if rng.flip() { cfg_a } else { cfg_b },
                        k,
                        acc: if rng.below(4) == 0 { vec![1.0; m] } else { vec![0.0; m] },
                        a,
                        bt: (0..k).map(|_| rng.normal()).collect(),
                    }
                })
                .collect();
            assert_eq!(plan_fusion(&tiles), plan_fusion_linear(&tiles), "round {round}");
        }
    }

    #[test]
    fn negated_zero_plane_does_not_alias() {
        // 0.0 and -0.0 share a value but not a bit pattern: interning must
        // keep them apart exactly as the bitwise compare does
        let cfg = PdpuConfig::paper_default();
        let t1 = GemmTile { cfg, k: 2, acc: vec![0.0], a: vec![0.0, 1.0], bt: vec![1.0, 1.0] };
        let mut t2 = t1.clone();
        t2.a[0] = -0.0;
        assert_eq!(plan_fusion(&[t1, t2]).len(), 2);
    }

    #[test]
    fn differing_acc_seeds_block_fusion() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xF4);
        let t1 = tile(cfg, &mut rng, 2, 4, 2);
        let mut t2 = t1.clone();
        t2.acc = vec![1.0; 2];
        assert_eq!(plan_fusion(&[t1, t2]).len(), 2);
    }

    #[test]
    fn cached_execution_is_bit_identical_and_hits_on_repeat() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xF5);
        let shared = tile(cfg, &mut rng, 3, 6, 2);
        let mut queue = Vec::new();
        for _ in 0..3 {
            let mut t = shared.clone();
            t.bt = (0..2 * 6).map(|_| rng.normal()).collect();
            queue.push(t);
        }
        queue.push(tile(cfg, &mut rng, 3, 6, 2)); // unique plane
        let groups = plan_fusion(&queue);
        let cache = PlaneCache::new(8);
        let (cold, s_cold) = execute_planned_cached(&queue, &groups, Some(&cache));
        let (warm, s_warm) = execute_planned_cached(&queue, &groups, Some(&cache));
        let (plain, s_plain) = execute_planned(&queue, &groups);
        assert_eq!(s_cold, s_plain);
        assert_eq!(s_warm, s_plain);
        for (i, ((c, w), p)) in cold.iter().zip(&warm).zip(&plain).enumerate() {
            let bits = |v: &Vec<f64>| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(c), bits(p), "cold tile {i}");
            assert_eq!(bits(w), bits(p), "warm tile {i}");
        }
        let cs = cache.stats();
        // two planes entered cold (one shared + one unique); the warm pass
        // answered both from the cache
        assert_eq!((cs.misses, cs.hits, cs.entries), (2, 2, 2));
    }

    #[test]
    fn empty_queue_is_fine() {
        let (outs, stats) = execute_fused(&[]);
        assert!(outs.is_empty());
        assert_eq!(stats, FusionStats::default());
    }
}
