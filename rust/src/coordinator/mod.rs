//! L3 — the coordinator: the serving layer that turns the PDPU arithmetic
//! stack into a system.
//!
//! * [`json`] — wire format + manifest parsing (no serde offline).
//! * [`metrics`] — counters and latency histograms.
//! * [`batcher`] — dynamic batching (size-or-deadline policy) feeding one
//!   PJRT invocation per batch.
//! * [`scheduler`] — cycle-accurate PDPU-array scheduling with RAW-hazard
//!   interleaving (the chunked-accumulation pipeline problem).
//! * [`service`] — compiled artifacts + parameter state, typed batch ops.
//! * [`server`] — TCP JSON-lines front end (std::net + threads).

pub mod batcher;
pub mod engine;
pub mod json;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod service;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{ModelInfo, ServiceHandle};
pub use metrics::{Metrics, MetricsSnapshot};
pub use scheduler::{conv_jobs, schedule, DotJob, ScheduleReport};
pub use server::Server;
pub use service::{PositService, SoftwareService};
