//! L3 — the coordinator: the serving layer that turns the PDPU arithmetic
//! stack into a system.
//!
//! * [`json`] — wire format + manifest parsing (no serde offline).
//! * [`metrics`] — counters, per-op latency histograms, queue gauges
//!   (rendered as Prometheus text by [`crate::obs::prom`]).
//! * [`batcher`] — dynamic batching (size-or-deadline policy) feeding one
//!   backend invocation per batch.
//! * [`fusion`] — cross-request GEMM fusion: compatible queued tiles
//!   (same config, same shared operand plane) coalesce into one engine
//!   launch, bit-identically to running them one at a time.
//! * [`scheduler`] — cycle-accurate PDPU-array scheduling with RAW-hazard
//!   interleaving (the chunked-accumulation pipeline problem), including
//!   fused-vs-unfused launch-sequence modelling.
//! * [`plane_cache`] — cross-batch interning of quantized operand
//!   planes, keyed by `(config, k, plane hash)` with a bitwise confirm,
//!   so repeated weight planes skip quantization bit-identically.
//! * [`service`] — compiled artifacts + parameter state, typed batch ops.
//! * [`server`] — sharded TCP JSON-lines serving tier (std::net +
//!   threads): N accept/engine shards over bounded condvar queues, with
//!   admission control and structured overload shedding.

pub mod batcher;
pub mod engine;
pub mod fusion;
pub mod json;
pub mod metrics;
pub mod plane_cache;
pub mod scheduler;
pub mod server;
pub mod service;

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering from poisoning instead of panicking.
///
/// A panicking worker poisons every mutex it held; with `lock().unwrap()`
/// each later request touching that lock then panics too, turning one bad
/// request into a permanent denial of service. All coordinator state
/// guarded by these mutexes (queues, parameter tensors, counters) stays
/// structurally valid across a mid-update panic — updates are
/// whole-value swaps or monotonic counters — so recovering the guard is
/// sound, and the serving tier keeps answering.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

pub use batcher::{BatchPolicy, Batcher};
pub use engine::{ModelInfo, ServiceHandle};
pub use fusion::{
    execute_fused, execute_planned, execute_planned_cached, execute_unfused, plan_fusion, FusionStats,
    GemmTile,
};
pub use metrics::{Metrics, MetricsSnapshot, OpKind, OpSnapshot};
pub use plane_cache::{PlaneCache, PlaneCacheStats, DEFAULT_PLANE_CAPACITY};
pub use scheduler::{conv_jobs, fuse_launches, schedule, schedule_launches, DotJob, ScheduleReport};
pub use server::{AdmissionBudget, AdmissionPermit, Server, ServerPolicy, ServingTier, TierReply};
pub use service::{PositService, SoftwareService};
