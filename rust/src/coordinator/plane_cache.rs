//! Cross-batch weight-plane cache — persistent [`PreparedOperands`]
//! interning across batches and sessions.
//!
//! [`super::fusion::plan_fusion`] already coalesces tiles that share a
//! left-operand plane *within* one formed batch, but consecutive batches
//! re-quantized the same weight plane from scratch on every launch: the
//! canonical serving shape (one weight matrix, thousands of activation
//! tiles over the connection lifetime) paid the quantize/decode cost per
//! batch instead of per plane. This module keeps the prepared planes
//! alive across batches, keyed exactly the way fusion planning interns
//! tiles — `(config, k, FNV-1a hash of the f64 bit patterns)` with a
//! bitwise confirm against the stored plane, so `-0.0`/NaN patterns and
//! hash collisions can never alias (the same invariant `plan_fusion`
//! property-tests against its linear-scan oracle).
//!
//! Correctness invariant: a cache hit returns a [`PreparedOperands`]
//! whose lanes are **bit-identical** to a fresh
//! [`PreparedOperands::quantize`] of the same plane — quantization is
//! per-value and deterministic, so interning is pure deduplication and
//! the served outputs cannot change (property-tested in
//! `rust/tests/serving_tier.rs`).
//!
//! Eviction is deterministic: a logical tick counter (not a wall clock —
//! the serving lint bans raw clocks in the coordinator) orders entries by
//! last use, and the least-recently-used entry (ties broken by lowest
//! slot index) is evicted when the bounded capacity is reached.
//! Quantization happens **outside** the cache lock; a racing duplicate
//! insert is resolved by re-checking the bucket before publishing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::fusion::{f64_bits_eq, hash_f64_plane};
use super::lock_unpoisoned;
use crate::engine::PreparedOperands;
use crate::pdpu::PdpuConfig;

/// Default number of distinct planes a serving cache retains.
pub const DEFAULT_PLANE_CAPACITY: usize = 64;

/// Cache identity of a prepared plane: the quantization-relevant config,
/// the inner dimension, and the FNV-1a hash of the plane's f64 bits.
type PlaneKey = (PdpuConfig, usize, u64);

/// Point-in-time counters of one [`PlaneCache`], for `stats`/Prometheus.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlaneCacheStats {
    /// Lookups answered from the cache (quantize skipped).
    pub hits: u64,
    /// Lookups that had to quantize (including capacity-0 bypasses).
    pub misses: u64,
    /// Entries evicted to respect the capacity bound.
    pub evictions: u64,
    /// Planes currently resident.
    pub entries: u64,
    /// Configured capacity (0 = caching disabled).
    pub capacity: u64,
    /// Total packed lanes held by resident planes (memory proxy).
    pub resident_elems: u64,
}

struct Entry {
    key: PlaneKey,
    /// The raw plane, kept for the bitwise confirm on lookup.
    plane: Vec<f64>,
    prepared: Arc<PreparedOperands>,
    /// Logical tick of the last hit or insert (drives LRU eviction).
    last_used: u64,
}

/// Slot-addressed storage: bucket lists hold stable slot indices, so an
/// eviction only touches its own bucket instead of re-indexing the map.
#[derive(Default)]
struct Inner {
    buckets: HashMap<PlaneKey, Vec<usize>>,
    slots: Vec<Option<Entry>>,
    free: Vec<usize>,
    tick: u64,
    live: usize,
}

/// A bounded, thread-safe cache of quantized weight planes shared by
/// every shard of the serving tier. See the module docs for the keying
/// and eviction contract.
pub struct PlaneCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlaneCache {
    /// A cache retaining at most `capacity` distinct planes. Capacity 0
    /// disables caching (every lookup quantizes fresh).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Configured capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Planes currently resident.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).live
    }

    /// True when no plane is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Return the prepared form of `plane` under `(cfg, k)`, quantizing
    /// and publishing it on first sight. The returned value is
    /// bit-identical to `PreparedOperands::quantize(cfg.in_fmt, plane, k)`
    /// whether it came from the cache or not.
    pub fn get_or_prepare(&self, cfg: &PdpuConfig, k: usize, plane: &[f64]) -> Arc<PreparedOperands> {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Arc::new(PreparedOperands::quantize(cfg.in_fmt, plane, k));
        }
        let key: PlaneKey = (*cfg, k, hash_f64_plane(plane));
        if let Some(found) = self.lookup(&key, plane) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return found;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // quantize outside the lock: this is the expensive step the cache
        // exists to elide, and holding the lock across it would serialize
        // every shard on one plane's preparation
        let prepared = Arc::new(PreparedOperands::quantize(cfg.in_fmt, plane, k));
        self.insert(key, plane, prepared)
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> PlaneCacheStats {
        let inner = lock_unpoisoned(&self.inner);
        let resident_elems: u64 =
            inner.slots.iter().flatten().map(|e| e.prepared.elem_count() as u64).sum();
        PlaneCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: inner.live as u64,
            capacity: self.capacity as u64,
            resident_elems,
        }
    }

    /// Bucket scan with bitwise confirm; bumps the LRU tick on a hit.
    fn lookup(&self, key: &PlaneKey, plane: &[f64]) -> Option<Arc<PreparedOperands>> {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner
            .buckets
            .get(key)?
            .iter()
            .copied()
            .find(|&s| {
                matches!(inner.slots.get(s), Some(Some(e)) if f64_bits_eq(&e.plane, plane))
            })?;
        let entry = inner.slots.get_mut(slot).and_then(Option::as_mut)?;
        entry.last_used = tick;
        Some(Arc::clone(&entry.prepared))
    }

    /// Publish a freshly prepared plane, re-checking for a racing insert
    /// of the same plane and evicting the least-recently-used entry when
    /// the capacity bound is hit.
    fn insert(
        &self,
        key: PlaneKey,
        plane: &[f64],
        prepared: Arc<PreparedOperands>,
    ) -> Arc<PreparedOperands> {
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        // racing duplicate: another thread published this plane while we
        // quantized outside the lock — adopt theirs, drop ours
        if let Some(bucket) = inner.buckets.get(&key) {
            let existing = bucket.iter().copied().find(|&s| {
                matches!(inner.slots.get(s), Some(Some(e)) if f64_bits_eq(&e.plane, plane))
            });
            if let Some(slot) = existing {
                if let Some(entry) = inner.slots.get_mut(slot).and_then(Option::as_mut) {
                    entry.last_used = tick;
                    return Arc::clone(&entry.prepared);
                }
            }
        }
        while inner.live >= self.capacity {
            // LRU victim: smallest (last_used, slot) over live entries —
            // fully deterministic, no wall clock involved
            let victim = inner
                .slots
                .iter()
                .enumerate()
                .filter_map(|(s, e)| e.as_ref().map(|e| (e.last_used, s)))
                .min();
            let Some((_, slot)) = victim else { break };
            if let Some(evicted) = inner.slots.get_mut(slot).and_then(Option::take) {
                if let Some(bucket) = inner.buckets.get_mut(&evicted.key) {
                    bucket.retain(|&s| s != slot);
                    if bucket.is_empty() {
                        inner.buckets.remove(&evicted.key);
                    }
                }
                inner.free.push(slot);
                inner.live -= 1;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let entry = Entry { key, plane: plane.to_vec(), prepared: Arc::clone(&prepared), last_used: tick };
        let slot = match inner.free.pop() {
            Some(s) => {
                if let Some(cell) = inner.slots.get_mut(s) {
                    *cell = Some(entry);
                }
                s
            }
            None => {
                inner.slots.push(Some(entry));
                inner.slots.len() - 1
            }
        };
        inner.buckets.entry(key).or_default().push(slot);
        inner.live += 1;
        prepared
    }
}

impl std::fmt::Debug for PlaneCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PlaneCache")
            .field("capacity", &self.capacity)
            .field("entries", &s.entries)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .field("evictions", &s.evictions)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BatchEngine;
    use crate::posit::Posit;
    use crate::testing::Rng;

    fn plane(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn repeat_plane_hits_and_returns_the_same_allocation() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xCAC4E);
        let cache = PlaneCache::new(4);
        let p = plane(&mut rng, 3 * 5);
        let first = cache.get_or_prepare(&cfg, 5, &p);
        let second = cache.get_or_prepare(&cfg, 5, &p);
        assert!(Arc::ptr_eq(&first, &second), "hit must return the cached plane");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.resident_elems >= 15);
    }

    #[test]
    fn negated_zero_and_differing_k_do_not_alias() {
        let cfg = PdpuConfig::paper_default();
        let cache = PlaneCache::new(8);
        let p = vec![0.0, 1.0, 2.0, 3.0];
        let mut q = p.clone();
        if let Some(v) = q.first_mut() {
            *v = -0.0;
        }
        cache.get_or_prepare(&cfg, 2, &p);
        cache.get_or_prepare(&cfg, 2, &q); // -0.0 differs bitwise → miss
        cache.get_or_prepare(&cfg, 4, &p); // same bits, different k → miss
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 3, 3));
    }

    #[test]
    fn lru_eviction_is_deterministic_and_bounded() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0x10C0);
        let cache = PlaneCache::new(2);
        let (a, b, c) = (plane(&mut rng, 4), plane(&mut rng, 4), plane(&mut rng, 4));
        cache.get_or_prepare(&cfg, 2, &a);
        cache.get_or_prepare(&cfg, 2, &b);
        cache.get_or_prepare(&cfg, 2, &a); // touch a → b is now LRU
        cache.get_or_prepare(&cfg, 2, &c); // evicts b
        let s = cache.stats();
        assert_eq!((s.evictions, s.entries), (1, 2));
        assert_eq!(cache.len(), 2);
        // a survived (hit), b was evicted (miss → re-quantize)
        let before = cache.stats().hits;
        cache.get_or_prepare(&cfg, 2, &a);
        assert_eq!(cache.stats().hits, before + 1);
        let misses_before = cache.stats().misses;
        cache.get_or_prepare(&cfg, 2, &b);
        assert_eq!(cache.stats().misses, misses_before + 1);
    }

    #[test]
    fn capacity_zero_bypasses_without_retaining() {
        let cfg = PdpuConfig::paper_default();
        let cache = PlaneCache::new(0);
        let p = vec![1.0, 2.0];
        cache.get_or_prepare(&cfg, 1, &p);
        cache.get_or_prepare(&cfg, 1, &p);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 0));
        assert!(cache.is_empty());
    }

    #[test]
    fn cached_plane_computes_bit_identical_gemm_outputs() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0xB17);
        let (m, k, n) = (3usize, 7usize, 4usize);
        let w = plane(&mut rng, m * k);
        let x = plane(&mut rng, n * k);
        let cache = PlaneCache::new(4);
        cache.get_or_prepare(&cfg, k, &w); // warm
        let cached = cache.get_or_prepare(&cfg, k, &w); // served from cache
        assert_eq!(cache.stats().hits, 1);

        let engine = BatchEngine::new(cfg);
        let fresh = PreparedOperands::quantize(cfg.in_fmt, &w, k);
        let xp = PreparedOperands::quantize(cfg.in_fmt, &x, k);
        let acc: Vec<Posit> = (0..m).map(|_| Posit::from_f64(0.0, cfg.out_fmt)).collect();
        let out_cached = engine.gemm_posit(&acc, &cached, &xp);
        let out_fresh = engine.gemm_posit(&acc, &fresh, &xp);
        assert_eq!(out_cached.len(), out_fresh.len());
        for (c, f) in out_cached.iter().zip(&out_fresh) {
            assert_eq!(c.to_f64().to_bits(), f.to_f64().to_bits(), "cache changed output bits");
        }
    }
}
