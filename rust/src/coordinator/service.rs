//! The PJRT-backed model service: owns the compiled artifacts and the
//! mutable parameter state, and exposes typed batch operations. This is
//! the layer between the protocol/batching machinery and raw PJRT.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

use super::fusion::{self, FusionStats, GemmTile};
use super::lock_unpoisoned;
use super::plane_cache::{PlaneCache, PlaneCacheStats, DEFAULT_PLANE_CAPACITY};
use crate::baselines::{DotArch, PdpuArch};
use crate::dnn::layers::with_zero_seeds;
use crate::dnn::Tensor;
use crate::obs::trace::{ActiveSpan, TraceCtx};
use crate::pdpu::{validate_layer_sizes, ConfigError, PdpuConfig};
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, ArtifactManifest, LoadedModel, Runtime};
use crate::train::{softmax_xent_batch, Sgd, TrainGraph};

/// Loaded artifacts + parameter state.
pub struct PositService {
    manifest: ArtifactManifest,
    infer: LoadedModel,
    train: LoadedModel,
    gemm: LoadedModel,
    /// current MLP parameters (train steps update them in place)
    params: Mutex<Vec<Vec<f32>>>,
    param_shapes: Vec<Vec<usize>>,
}

impl PositService {
    /// Load and compile every entry point from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let manifest = ArtifactManifest::load(&dir)?;
        let infer = rt.load_hlo(&manifest.entry("mlp_infer")?.file)?;
        let train = rt.load_hlo(&manifest.entry("mlp_train_step")?.file)?;
        let gemm = rt.load_hlo(&manifest.entry("posit_gemm")?.file)?;
        let params = manifest.load_params()?;
        let param_shapes = manifest.param_shapes.clone();
        Ok(Self { manifest, infer, train, gemm, params: Mutex::new(params), param_shapes })
    }

    /// The loaded artifacts manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Compiled maximum batch size.
    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    /// Input feature count per image.
    pub fn input_dim(&self) -> usize {
        self.manifest.input_dim()
    }

    /// Output class count.
    pub fn classes(&self) -> usize {
        self.manifest.classes()
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        let params = lock_unpoisoned(&self.params);
        params
            .iter()
            .zip(&self.param_shapes)
            .map(|(p, s)| literal_f32(p, s))
            .collect()
    }

    /// Run a batch of images (≤ batch_size; padded internally) through the
    /// posit MLP. Returns one logits vector per input image.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        let d = self.input_dim();
        anyhow::ensure!(!images.is_empty() && images.len() <= b, "batch of {} exceeds compiled size {b}", images.len());
        let mut flat = vec![0f32; b * d];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == d, "image {} has {} pixels, want {d}", i, img.len());
            flat[i * d..(i + 1) * d].copy_from_slice(img);
        }
        let mut args = self.param_literals()?;
        args.push(literal_f32(&flat, &[b, d])?);
        let out = self.infer.execute(&args)?;
        let logits = to_vec_f32(out.first().context("infer produced no outputs")?)?;
        let c = self.classes();
        Ok(images.iter().enumerate().map(|(i, _)| logits[i * c..(i + 1) * c].to_vec()).collect())
    }

    /// One SGD step on a full batch; updates the parameter state and
    /// returns the loss.
    pub fn train_step(&self, images: &[Vec<f32>], labels: &[u32]) -> Result<f32> {
        let b = self.batch_size();
        let d = self.input_dim();
        anyhow::ensure!(images.len() == b && labels.len() == b, "train step needs a full batch of {b}");
        let mut flat = vec![0f32; b * d];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == d, "image {i} has wrong size");
            flat[i * d..(i + 1) * d].copy_from_slice(img);
        }
        let ys: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        let mut args = self.param_literals()?;
        args.push(literal_f32(&flat, &[b, d])?);
        args.push(literal_i32(&ys, &[b])?);
        let out = self.train.execute(&args)?;
        anyhow::ensure!(out.len() == self.param_shapes.len() + 1, "train step output arity");
        let mut params = lock_unpoisoned(&self.params);
        for (slot, lit) in params.iter_mut().zip(&out[..self.param_shapes.len()]) {
            *slot = to_vec_f32(lit)?;
        }
        let loss = to_vec_f32(&out[self.param_shapes.len()])?;
        loss.first().copied().context("train step produced an empty loss")
    }

    /// Raw posit GEMM at the compiled (M, K, N).
    pub fn gemm(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (m, k, n) = self.manifest.gemm_mkn;
        anyhow::ensure!(a.len() == m * k, "A must be {}x{}", m, k);
        anyhow::ensure!(b.len() == k * n, "B must be {}x{}", k, n);
        let out = self
            .gemm
            .execute(&[literal_f32(a, &[m, k])?, literal_f32(b, &[k, n])?])
            .context("gemm execute")?;
        to_vec_f32(out.first().context("gemm produced no outputs")?)
    }

    /// Snapshot of current parameters (for checkpoint-style inspection).
    pub fn params_snapshot(&self) -> Vec<Vec<f32>> {
        lock_unpoisoned(&self.params).clone()
    }
}

/// Default SGD learning rate of the software backend's train step (the
/// PJRT train artifact bakes its own; this is the software twin's knob,
/// overridable with [`SoftwareService::with_train_lr`]).
const SOFTWARE_TRAIN_LR: f64 = 0.05;

/// Pure-Rust fallback backend: a posit MLP with deterministic (seeded)
/// He-initialized weights plus a posit GEMM, both executed through the
/// batched PDPU engine ([`DotArch::dot_batch`] → [`crate::engine`]) — no
/// PJRT, no artifacts. This is what serves when the AOT artifacts or the
/// XLA runtime are unavailable (e.g. this offline build), and it is the
/// offline test surface for the batcher/server stack.
///
/// Batch ops run as whole GEMM tiles: one `dot_batch` call per layer for
/// an entire inference batch, one per GEMM request — never a scalar
/// per-output loop. The MLP is held as a [`TrainGraph`], so the backend
/// also serves real SGD train steps ([`Self::train_step`]) whose backward
/// passes ride the same batched engine.
pub struct SoftwareService {
    arch: PdpuArch,
    graph: Mutex<TrainGraph>,
    sgd: Sgd,
    layer_sizes: Vec<usize>,
    batch: usize,
    gemm_mkn: (usize, usize, usize),
    /// Cross-batch cache of prepared left-operand planes shared by every
    /// shard's fused GEMM launches (`None` = caching disabled).
    plane_cache: Option<PlaneCache>,
}

impl SoftwareService {
    /// Build a software model: `layer_sizes` = [input, hidden…, classes].
    /// The topology and batch size are validated here, once, so every
    /// request-path accessor below can assume a well-formed model.
    pub fn new(
        cfg: PdpuConfig,
        layer_sizes: &[usize],
        batch: usize,
        gemm_mkn: (usize, usize, usize),
        seed: u64,
    ) -> Result<Self, ConfigError> {
        validate_layer_sizes(layer_sizes)?;
        if batch == 0 {
            return Err(ConfigError::BadBatch);
        }
        Ok(Self {
            arch: PdpuArch::new(cfg),
            graph: Mutex::new(TrainGraph::new(cfg, layer_sizes, seed)),
            sgd: Sgd::new(SOFTWARE_TRAIN_LR, &cfg),
            layer_sizes: layer_sizes.to_vec(),
            batch,
            gemm_mkn,
            plane_cache: Some(PlaneCache::new(DEFAULT_PLANE_CAPACITY)),
        })
    }

    /// Override the train-step learning rate (builder style).
    pub fn with_train_lr(mut self, lr: f64) -> Self {
        self.sgd = Sgd::new(lr, self.arch.config());
        self
    }

    /// Override the cross-batch plane-cache capacity (builder style).
    /// `0` disables caching entirely — the cold/uncached A/B baseline.
    pub fn with_plane_cache_capacity(mut self, planes: usize) -> Self {
        self.plane_cache = (planes > 0).then(|| PlaneCache::new(planes));
        self
    }

    /// The PDPU configuration this service executes under.
    pub fn config(&self) -> &PdpuConfig {
        self.arch.config()
    }

    /// Plane-cache counters (all-zero when caching is disabled).
    pub fn plane_cache_stats(&self) -> PlaneCacheStats {
        self.plane_cache.as_ref().map(PlaneCache::stats).unwrap_or_default()
    }

    /// Input feature count per image. (`layer_sizes` was validated
    /// non-degenerate in [`Self::new`], so the fallback never fires.)
    pub fn input_dim(&self) -> usize {
        self.layer_sizes.first().copied().unwrap_or(0)
    }

    /// Output class count.
    pub fn classes(&self) -> usize {
        self.layer_sizes.last().copied().unwrap_or(0)
    }

    /// Configured maximum batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// MLP layer widths, input first.
    pub fn layer_sizes(&self) -> &[usize] {
        &self.layer_sizes
    }

    /// The configured GEMM shape (M, K, N).
    pub fn gemm_mkn(&self) -> (usize, usize, usize) {
        self.gemm_mkn
    }

    /// Validate a request batch and widen it into a `[b, d]` f64 tensor.
    fn images_tensor(&self, images: &[Vec<f32>]) -> std::result::Result<Tensor, String> {
        let d = self.input_dim();
        if images.is_empty() || images.len() > self.batch {
            return Err(format!("batch of {} exceeds configured size {}", images.len(), self.batch));
        }
        let b = images.len();
        let mut flat = Vec::with_capacity(b * d);
        for (i, img) in images.iter().enumerate() {
            if img.len() != d {
                return Err(format!("image {i} has {} pixels, want {d}", img.len()));
            }
            flat.extend(img.iter().map(|&v| v as f64));
        }
        Ok(Tensor::from_vec(&[b, d], flat))
    }

    /// Run a batch of images through the posit MLP: one batched GEMM per
    /// layer, ReLU between layers. Deterministic between train steps.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> std::result::Result<Vec<Vec<f32>>, String> {
        let xs = self.images_tensor(images)?;
        let b = images.len();
        let logits = lock_unpoisoned(&self.graph).infer(&xs);
        let c = self.classes();
        Ok((0..b)
            .map(|i| logits.data()[i * c..(i + 1) * c].iter().map(|&v| v as f32).collect())
            .collect())
    }

    /// [`Self::infer_batch`] wrapped in an `engine_launch` trace span
    /// (with S1–S6 stage-bin deltas as its children) when the batch
    /// carries a sampled request's context. `None` context costs nothing.
    pub fn infer_batch_traced(
        &self,
        images: &[Vec<f32>],
        ctx: Option<TraceCtx>,
    ) -> std::result::Result<Vec<Vec<f32>>, String> {
        let stages0 = crate::obs::stages::snapshot();
        let span = crate::obs::trace::start_child("engine_launch", ctx);
        let sctx = span.as_ref().map(ActiveSpan::ctx);
        let out = self.infer_batch(images);
        crate::obs::trace::finish(span);
        crate::obs::stages::emit_delta(sctx, &stages0);
        out
    }

    /// One SGD step on a batch of labelled images through the posit
    /// training graph: forward → softmax cross-entropy → backward GEMMs →
    /// quire-accumulated posit update ([`crate::train`]). Updates the
    /// served parameters in place and returns the batch loss — the
    /// software twin of [`PositService::train_step`], same wire op, no
    /// PJRT artifacts required.
    pub fn train_step(&self, images: &[Vec<f32>], labels: &[u32]) -> std::result::Result<f32, String> {
        if labels.len() != images.len() {
            return Err(format!("{} labels for {} images", labels.len(), images.len()));
        }
        let c = self.classes();
        if let Some(&bad) = labels.iter().find(|&&l| (l as usize) >= c) {
            return Err(format!("label {bad} out of range for {c} classes"));
        }
        let xs = self.images_tensor(images)?;
        let labels: Vec<usize> = labels.iter().map(|&l| l as usize).collect();
        let mut graph = lock_unpoisoned(&self.graph);
        let trace = graph.forward(&xs);
        let (loss, dlogits) = softmax_xent_batch(trace.logits(), &labels);
        let grads = graph.backward(&trace, &dlogits);
        self.sgd.step(&mut graph, &grads);
        Ok(loss as f32)
    }

    /// [`Self::train_step`] wrapped in a `train_step` trace span (with
    /// S1–S6 stage-bin deltas as its children) for sampled requests.
    pub fn train_step_traced(
        &self,
        images: &[Vec<f32>],
        labels: &[u32],
        ctx: Option<TraceCtx>,
    ) -> std::result::Result<f32, String> {
        let stages0 = crate::obs::stages::snapshot();
        let span = crate::obs::trace::start_child("train_step", ctx);
        let sctx = span.as_ref().map(ActiveSpan::ctx);
        let out = self.train_step(images, labels);
        crate::obs::trace::finish(span);
        crate::obs::stages::emit_delta(sctx, &stages0);
        out
    }

    /// Shared request validation for the single and batched GEMM paths:
    /// check shapes against the configured (M, K, N), widen A to f64, and
    /// transpose B so each right-hand vector is contiguous (the layout
    /// `dot_batch` wants).
    fn validate_and_transpose(&self, a: &[f32], b: &[f32]) -> std::result::Result<(Vec<f64>, Vec<f64>), String> {
        let (m, k, n) = self.gemm_mkn;
        if a.len() != m * k {
            return Err(format!("A must be {m}x{k}"));
        }
        if b.len() != k * n {
            return Err(format!("B must be {k}x{n}"));
        }
        let af: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let mut bt = vec![0.0f64; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j] as f64;
            }
        }
        Ok((af, bt))
    }

    /// Posit GEMM at the configured (M, K, N): quantize once per operand,
    /// run one batched tile. Deliberately **uncached and unfused** — this
    /// is the bit-identity oracle the fused/cached batch path is
    /// property-tested against.
    pub fn gemm(&self, a: &[f32], b: &[f32]) -> std::result::Result<Vec<f32>, String> {
        let _site = crate::obs::numerics::SiteGuard::enter(crate::obs::numerics::Site::gemm());
        let (m, k, _) = self.gemm_mkn;
        let (af, bt) = self.validate_and_transpose(a, b)?;
        let out = with_zero_seeds(m, |seeds| self.arch.dot_batch(seeds, &af, &bt, k));
        Ok(out.into_iter().map(|v| v as f32).collect())
    }

    /// A whole queue of GEMM requests at the configured (M, K, N), executed
    /// with **cross-request fusion**: requests whose left operand planes
    /// are bit-identical share one engine launch
    /// ([`fusion::execute_fused`]). Returns one result per request in
    /// submission order, each bit-identical to what [`Self::gemm`] would
    /// have produced for it alone; invalid requests get their own error
    /// without blocking the rest of the queue.
    pub fn gemm_batch(
        &self,
        reqs: &[(Vec<f32>, Vec<f32>)],
    ) -> (Vec<std::result::Result<Vec<f32>, String>>, FusionStats) {
        self.gemm_batch_traced(reqs, None)
    }

    /// [`Self::gemm_batch`] with request tracing: when `ctx` carries a
    /// sampled request's context, planning and launching are timed as
    /// separate `fusion_plan` / `engine_launch` spans, and the S1–S6
    /// stage-bin growth across the launch is emitted as the launch span's
    /// children. Identical outputs either way — the plan/execute split is
    /// [`fusion::plan_fusion`] + [`fusion::execute_planned_cached`] (fed
    /// the service's cross-batch plane cache, so repeat weight planes skip
    /// quantization across batches, not just within one).
    pub fn gemm_batch_traced(
        &self,
        reqs: &[(Vec<f32>, Vec<f32>)],
        ctx: Option<TraceCtx>,
    ) -> (Vec<std::result::Result<Vec<f32>, String>>, FusionStats) {
        // numerics attribution: fused launches run on this thread, so the
        // guard covers planning and execution for the whole queue
        let _site = crate::obs::numerics::SiteGuard::enter(crate::obs::numerics::Site::gemm());
        let (m, k, _) = self.gemm_mkn;
        let mut tiles: Vec<GemmTile> = Vec::new();
        // per-request slot: index into `tiles`, or the shape error
        let mut slots: Vec<std::result::Result<usize, String>> = Vec::with_capacity(reqs.len());
        for (a, b) in reqs {
            match self.validate_and_transpose(a, b) {
                Ok((af, bt)) => {
                    slots.push(Ok(tiles.len()));
                    tiles.push(GemmTile { cfg: *self.arch.config(), k, acc: vec![0.0; m], a: af, bt });
                }
                Err(e) => slots.push(Err(e)),
            }
        }
        let plan_span = crate::obs::trace::start_child("fusion_plan", ctx);
        let groups = fusion::plan_fusion(&tiles);
        crate::obs::trace::finish(plan_span);
        let stages0 = crate::obs::stages::snapshot();
        let launch_span = crate::obs::trace::start_child("engine_launch", ctx);
        let lctx = launch_span.as_ref().map(ActiveSpan::ctx);
        let (mut outs, stats) = fusion::execute_planned_cached(&tiles, &groups, self.plane_cache.as_ref());
        crate::obs::trace::finish(launch_span);
        crate::obs::stages::emit_delta(lctx, &stages0);
        let results = slots
            .into_iter()
            .map(|slot| {
                slot.map(|i| std::mem::take(&mut outs[i]).into_iter().map(|v| v as f32).collect())
            })
            .collect();
        (results, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> SoftwareService {
        SoftwareService::new(PdpuConfig::paper_default(), &[12, 8, 3], 4, (4, 6, 5), 0x5EED).unwrap()
    }

    #[test]
    fn construction_rejects_degenerate_models() {
        let cfg = PdpuConfig::paper_default();
        assert!(matches!(
            SoftwareService::new(cfg, &[], 4, (4, 6, 5), 1),
            Err(ConfigError::BadLayerCount(0))
        ));
        assert!(matches!(
            SoftwareService::new(cfg, &[12], 4, (4, 6, 5), 1),
            Err(ConfigError::BadLayerCount(1))
        ));
        assert!(matches!(
            SoftwareService::new(cfg, &[12, 0, 3], 4, (4, 6, 5), 1),
            Err(ConfigError::ZeroLayerWidth(1))
        ));
        assert!(matches!(SoftwareService::new(cfg, &[12, 3], 0, (4, 6, 5), 1), Err(ConfigError::BadBatch)));
    }

    #[test]
    fn software_infer_shapes_and_determinism() {
        let s = svc();
        let images: Vec<Vec<f32>> = (0..3).map(|i| vec![0.1 * (i + 1) as f32; 12]).collect();
        let out = s.infer_batch(&images).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|l| l.len() == 3 && l.iter().all(|v| v.is_finite())));
        assert_eq!(out, s.infer_batch(&images).unwrap());
        // same image alone or in a batch → same logits (batched GEMM is
        // per-column independent)
        let solo = s.infer_batch(&images[..1]).unwrap();
        assert_eq!(solo[0], out[0]);
    }

    #[test]
    fn software_infer_rejects_bad_shapes() {
        let s = svc();
        assert!(s.infer_batch(&[]).is_err());
        assert!(s.infer_batch(&vec![vec![0.0f32; 12]; 5]).is_err());
        assert!(s.infer_batch(&[vec![0.0f32; 7]]).unwrap_err().contains("pixels"));
    }

    #[test]
    fn software_gemm_matches_dot_batch_oracle() {
        let s = svc();
        let (m, k, n) = s.gemm_mkn();
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.53).cos()).collect();
        let c = s.gemm(&a, &b).unwrap();
        assert_eq!(c.len(), m * n);
        // scalar oracle: per-element chunked dot through the same arch
        let arch = PdpuArch::new(PdpuConfig::paper_default());
        for i in 0..m {
            for j in 0..n {
                let row: Vec<f64> = (0..k).map(|kk| a[i * k + kk] as f64).collect();
                let col: Vec<f64> = (0..k).map(|kk| b[kk * n + j] as f64).collect();
                let want = arch.dot_f64(0.0, &row, &col) as f32;
                assert_eq!(c[i * n + j], want, "c[{i},{j}]");
            }
        }
    }

    #[test]
    fn software_gemm_rejects_bad_shapes() {
        let s = svc();
        assert!(s.gemm(&[0.0; 3], &[0.0; 30]).is_err());
        let (m, k, n) = s.gemm_mkn();
        assert!(s.gemm(&vec![0.0; m * k], &vec![0.0; k * n + 1]).is_err());
    }

    #[test]
    fn software_train_step_learns_and_moves_the_served_model() {
        let s = svc().with_train_lr(0.2);
        let images: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..12).map(|p| if p % 4 == i { 1.0 } else { 0.05 }).collect())
            .collect();
        let labels: Vec<u32> = vec![0, 1, 2, 0];
        let before = s.infer_batch(&images).unwrap();
        let first = s.train_step(&images, &labels).unwrap();
        let mut last = first;
        for _ in 0..14 {
            last = s.train_step(&images, &labels).unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first, "loss {first} → {last} (no learning on a fixed batch)");
        // the served parameters actually moved
        assert_ne!(before, s.infer_batch(&images).unwrap());
    }

    #[test]
    fn software_train_step_rejects_bad_requests() {
        let s = svc();
        let img = vec![0.1f32; 12];
        assert!(s.train_step(&[], &[]).unwrap_err().contains("batch"));
        assert!(s.train_step(&[img.clone()], &[0, 1]).unwrap_err().contains("labels"));
        assert!(s.train_step(&[img.clone()], &[7]).unwrap_err().contains("out of range"));
        assert!(s.train_step(&[vec![0.0; 3]], &[0]).unwrap_err().contains("pixels"));
    }

    /// Cross-batch caching: the same weight plane arriving in *separate*
    /// `gemm_batch` calls must hit the plane cache (the whole point — the
    /// per-batch fusion planner can't see across calls) while every reply
    /// stays bit-identical to the uncached single-request oracle.
    #[test]
    fn plane_cache_hits_across_separate_gemm_batches_bitwise() {
        let s = svc(); // default: cache enabled
        let (m, k, n) = s.gemm_mkn();
        let plane: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.43).sin()).collect();
        let mk_b = |seed: usize| -> Vec<f32> {
            (0..k * n).map(|i| ((i + seed) as f32 * 0.23).cos()).collect()
        };
        let oracle = SoftwareService::new(PdpuConfig::paper_default(), &[12, 8, 3], 4, (4, 6, 5), 0x5EED)
            .unwrap()
            .with_plane_cache_capacity(0);
        for round in 0..5 {
            let req = (plane.clone(), mk_b(round));
            let (results, _) = s.gemm_batch(std::slice::from_ref(&req));
            let got = results.into_iter().next().unwrap().unwrap();
            let want = oracle.gemm(&req.0, &req.1).unwrap();
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "round {round} diverged under plane caching"
            );
        }
        let cs = s.plane_cache_stats();
        assert_eq!(cs.misses, 1, "one cold quantize for the shared plane");
        assert_eq!(cs.hits, 4, "four later batches served from the cache");
        assert_eq!(cs.entries, 1);
        assert_eq!(oracle.plane_cache_stats(), Default::default());
    }

    #[test]
    fn gemm_batch_fuses_and_matches_singles_bitwise() {
        let s = svc();
        let (m, k, n) = s.gemm_mkn();
        let shared_a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.41).sin()).collect();
        let other_a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.97).cos()).collect();
        let mk_b = |seed: usize| -> Vec<f32> {
            (0..k * n).map(|i| ((i + seed) as f32 * 0.29).cos()).collect()
        };
        // 3 requests sharing one plane + 1 distinct + 1 invalid, interleaved
        let reqs = vec![
            (shared_a.clone(), mk_b(0)),
            (other_a.clone(), mk_b(1)),
            (shared_a.clone(), mk_b(2)),
            (vec![0.0f32; 3], mk_b(3)), // bad shape
            (shared_a.clone(), mk_b(4)),
        ];
        let (results, stats) = s.gemm_batch(&reqs);
        assert_eq!(results.len(), 5);
        assert_eq!(stats, FusionStats { launches: 2, fused_tiles: 3 });
        assert!(results[3].as_ref().unwrap_err().contains("A must be"));
        for (i, (a, b)) in reqs.iter().enumerate() {
            if i == 3 {
                continue;
            }
            let want = s.gemm(a, b).unwrap();
            let got = results[i].as_ref().unwrap();
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "request {i} diverged from its unfused result"
            );
        }
    }
}
