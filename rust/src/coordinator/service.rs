//! The PJRT-backed model service: owns the compiled artifacts and the
//! mutable parameter state, and exposes typed batch operations. This is
//! the layer between the protocol/batching machinery and raw PJRT.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

use crate::runtime::{literal_f32, literal_i32, to_vec_f32, ArtifactManifest, LoadedModel, Runtime};

/// Loaded artifacts + parameter state.
pub struct PositService {
    manifest: ArtifactManifest,
    infer: LoadedModel,
    train: LoadedModel,
    gemm: LoadedModel,
    /// current MLP parameters (train steps update them in place)
    params: Mutex<Vec<Vec<f32>>>,
    param_shapes: Vec<Vec<usize>>,
}

impl PositService {
    /// Load and compile every entry point from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let manifest = ArtifactManifest::load(&dir)?;
        let infer = rt.load_hlo(&manifest.entry("mlp_infer")?.file)?;
        let train = rt.load_hlo(&manifest.entry("mlp_train_step")?.file)?;
        let gemm = rt.load_hlo(&manifest.entry("posit_gemm")?.file)?;
        let params = manifest.load_params()?;
        let param_shapes = manifest.param_shapes.clone();
        Ok(Self { manifest, infer, train, gemm, params: Mutex::new(params), param_shapes })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    pub fn input_dim(&self) -> usize {
        self.manifest.layer_sizes[0]
    }

    pub fn classes(&self) -> usize {
        *self.manifest.layer_sizes.last().unwrap()
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        let params = self.params.lock().unwrap();
        params
            .iter()
            .zip(&self.param_shapes)
            .map(|(p, s)| literal_f32(p, s))
            .collect()
    }

    /// Run a batch of images (≤ batch_size; padded internally) through the
    /// posit MLP. Returns one logits vector per input image.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        let d = self.input_dim();
        anyhow::ensure!(!images.is_empty() && images.len() <= b, "batch of {} exceeds compiled size {b}", images.len());
        let mut flat = vec![0f32; b * d];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == d, "image {} has {} pixels, want {d}", i, img.len());
            flat[i * d..(i + 1) * d].copy_from_slice(img);
        }
        let mut args = self.param_literals()?;
        args.push(literal_f32(&flat, &[b, d])?);
        let out = self.infer.execute(&args)?;
        let logits = to_vec_f32(&out[0])?;
        let c = self.classes();
        Ok(images.iter().enumerate().map(|(i, _)| logits[i * c..(i + 1) * c].to_vec()).collect())
    }

    /// One SGD step on a full batch; updates the parameter state and
    /// returns the loss.
    pub fn train_step(&self, images: &[Vec<f32>], labels: &[u32]) -> Result<f32> {
        let b = self.batch_size();
        let d = self.input_dim();
        anyhow::ensure!(images.len() == b && labels.len() == b, "train step needs a full batch of {b}");
        let mut flat = vec![0f32; b * d];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == d, "image {i} has wrong size");
            flat[i * d..(i + 1) * d].copy_from_slice(img);
        }
        let ys: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        let mut args = self.param_literals()?;
        args.push(literal_f32(&flat, &[b, d])?);
        args.push(literal_i32(&ys, &[b])?);
        let out = self.train.execute(&args)?;
        anyhow::ensure!(out.len() == self.param_shapes.len() + 1, "train step output arity");
        let mut params = self.params.lock().unwrap();
        for (slot, lit) in params.iter_mut().zip(&out[..self.param_shapes.len()]) {
            *slot = to_vec_f32(lit)?;
        }
        let loss = to_vec_f32(&out[self.param_shapes.len()])?;
        Ok(loss[0])
    }

    /// Raw posit GEMM at the compiled (M, K, N).
    pub fn gemm(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (m, k, n) = self.manifest.gemm_mkn;
        anyhow::ensure!(a.len() == m * k, "A must be {}x{}", m, k);
        anyhow::ensure!(b.len() == k * n, "B must be {}x{}", k, n);
        let out = self
            .gemm
            .execute(&[literal_f32(a, &[m, k])?, literal_f32(b, &[k, n])?])
            .context("gemm execute")?;
        to_vec_f32(&out[0])
    }

    /// Snapshot of current parameters (for checkpoint-style inspection).
    pub fn params_snapshot(&self) -> Vec<Vec<f32>> {
        self.params.lock().unwrap().clone()
    }
}
