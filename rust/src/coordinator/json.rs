//! Minimal JSON — parser + writer for the coordinator's wire protocol and
//! the artifacts manifest. (The offline image carries no serde; this
//! covers exactly the JSON subset those two uses need: objects, arrays,
//! strings with basic escapes, f64 numbers, bools, null.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The value as a number truncated to usize, if it is one.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// The value as a string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: an array of numbers as Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|&v| Json::Num(v)).collect())
    }
}

/// Maximum container nesting the parser accepts. Recursive descent uses
/// the thread stack, so an unbounded `[[[[…` line from the network would
/// overflow it and abort the whole process; 128 levels is far beyond any
/// wire request or manifest while keeping stack use trivially bounded.
const MAX_DEPTH: usize = 128;

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing bytes at {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end")? {
            b'{' => self.nested(Parser::object),
            b'[' => self.nested(Parser::array),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    /// Run a container parser one nesting level down, enforcing MAX_DEPTH.
    fn nested(&mut self, f: fn(&mut Self) -> Result<Json, String>) -> Result<Json, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at {}", self.i));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or("bad escape")? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or("bad \\u escape")?;
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                    self.i += 1;
                }
                _ => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line1\nline2\t\"quoted\" \\slash".into());
        let text = original.to_string();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("op", Json::Str("infer".into())),
            ("data", Json::arr_f64(&[1.0, -2.5, 0.25])),
            ("id", Json::Num(7.0)),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // within the limit: fine
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(parse(&ok).is_ok());
        // far past the limit: a typed error, not an abort
        let bomb = "[".repeat(100_000);
        let err = parse(&bomb).unwrap_err();
        assert!(err.contains("nesting"), "unexpected error: {err}");
        let obj_bomb: String = std::iter::repeat("{\"a\":").take(100_000).collect();
        assert!(parse(&obj_bomb).unwrap_err().contains("nesting"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"format": {"n_in": 13, "n_out": 16, "es": 2},
                       "entries": {"mlp_infer": {"file": "mlp_infer.hlo.txt",
                       "args": [{"shape": [784, 256], "dtype": "float32"}], "outputs": 1}}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().get("n_in").unwrap().as_usize(), Some(13));
        let entry = v.get("entries").unwrap().get("mlp_infer").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("mlp_infer.hlo.txt"));
        let shape = entry.get("args").unwrap().as_arr().unwrap()[0].get("shape").unwrap();
        assert_eq!(shape.as_f64_vec(), Some(vec![784.0, 256.0]));
    }

    #[test]
    fn utf8_content_preserved()    {
        let v = parse("\"héllo ∑ ümlaut\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∑ ümlaut"));
    }
}
