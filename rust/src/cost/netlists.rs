//! Structural netlists — one builder per architecture in Table I.
//!
//! A netlist is a list of named stages, each with combinational logic cost
//! and (for pipelined evaluation) the width of the pipeline register that
//! follows it. The builders mirror each architecture's published
//! micro-structure; pricing happens in [`super::report`].

use super::components::*;
use super::gates::{adder, barrel_shifter, booth_multiplier, dff_bits, lzc, Cost};
use super::IeeeFormat;
use crate::pdpu::config::ceil_log2;
use crate::posit::PositFormat;

/// One pipeline-stage worth of logic.
#[derive(Clone, Debug)]
pub struct StageCost {
    pub name: &'static str,
    pub logic: Cost,
    /// bits latched after this stage when the unit is pipelined
    pub reg_bits: u32,
}

/// A priced architecture structure.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub label: String,
    pub stages: Vec<StageCost>,
    /// MACs completed per operation (the N of Perf = N/delay)
    pub macs_per_op: u32,
    /// Switching-activity multiplier vs. a balanced fused datapath.
    /// Cascades of discrete posit units glitch heavily — every stage's
    /// LZC + dynamic-shift chain re-toggles on each upstream arrival-time
    /// wave — which is how PACoGen's measured 12.21 mW dwarfs its area
    /// share in Table I. Calibrated against that row; 1.0 for fused units.
    pub activity_mult: f64,
}

impl Netlist {
    /// Total combinational logic (no pipeline registers).
    pub fn combinational(&self) -> Cost {
        self.stages.iter().fold(Cost::ZERO, |acc, s| acc.then(s.logic))
    }

    /// Total pipeline register bits.
    pub fn reg_bits(&self) -> u32 {
        self.stages.iter().map(|s| s.reg_bits).sum()
    }

    /// Register area cost when pipelined.
    pub fn reg_cost(&self) -> Cost {
        dff_bits(self.reg_bits())
    }

    /// Worst per-stage logic delay (sets pipelined fmax).
    pub fn worst_stage(&self) -> &StageCost {
        self.stages
            .iter()
            .max_by(|a, b| a.logic.delay_fo4.partial_cmp(&b.logic.delay_fo4).unwrap())
            .expect("netlist has stages")
    }
}

/// Datapath width parameters shared by the posit fused builders.
#[derive(Clone, Copy, Debug)]
pub struct PdpuParams {
    pub in_fmt: PositFormat,
    pub out_fmt: PositFormat,
    pub n: u32,
    pub wm: u32,
}

impl PdpuParams {
    pub fn from_config(cfg: &crate::pdpu::PdpuConfig) -> Self {
        Self { in_fmt: cfg.in_fmt, out_fmt: cfg.out_fmt, n: cfg.n as u32, wm: cfg.wm }
    }

    fn mb_in(&self) -> u32 {
        self.in_fmt.max_frac_bits() + 1 // 1.f significand width
    }

    fn mb_out(&self) -> u32 {
        self.out_fmt.max_frac_bits() + 1
    }

    fn eab_w(&self) -> u32 {
        let span = 2 * self.in_fmt.max_scale().max(self.out_fmt.max_scale());
        32 - (span as u32).leading_zeros() + 1
    }

    fn acc_w(&self) -> u32 {
        self.wm + ceil_log2(self.n + 1) + 1
    }
}

/// The proposed PDPU (paper Fig. 4): fused, mixed-precision, 6 stages.
pub fn pdpu(p: PdpuParams) -> Netlist {
    let n = p.n;
    let (mb_in, eab_w, acc_w) = (p.mb_in(), p.eab_w(), p.acc_w());
    let prod_w = 2 * mb_in;

    // S1: 2N input decoders + 1 acc decoder + N scale adders
    let s1 = posit_decoder(p.in_fmt)
        .replicate(2 * n)
        .beside(posit_decoder(p.out_fmt))
        .then(adder(eab_w)) // e_a + e_b (delay of one; area of N)
        .then(Cost::new(adder(eab_w).area_ge * (n as f64 - 1.0), 0.0));
    let s1_regs = n * (1 + eab_w + 2 * mb_in) + (1 + eab_w + p.mb_out());

    // S2: N booth multipliers ∥ exponent max tree over N+1 scales
    let s2 = booth_multiplier(mb_in).replicate(n).beside(max_tree(n + 1, eab_w));
    let s2_regs = n * (1 + eab_w + prod_w) + eab_w + (1 + eab_w + p.mb_out());

    // S3: N+1 alignment shifters to the Wm grid + two's complement
    let s3 = align_bank(n + 1, p.wm, p.wm, eab_w);
    let s3_regs = (n + 1) * p.wm + eab_w;

    // S4: recursive CSA tree over N+1 operands + final adder
    let s4 = csa_tree(n + 1, acc_w);
    let s4_regs = acc_w + 1 + eab_w;

    // S5: LZC + normalize shift + exponent adjust
    let s5 = lzc_stage(acc_w, eab_w);
    let s5_regs = 1 + eab_w + acc_w;

    // S6: single posit encoder
    let s6 = posit_encoder(p.out_fmt);

    Netlist {
        label: format!(
            "PDPU P({}/{},{}) N={} Wm={}",
            p.in_fmt.n(),
            p.out_fmt.n(),
            p.in_fmt.es(),
            n,
            p.wm
        ),
        stages: vec![
            StageCost { name: "S1 Decode", logic: s1, reg_bits: s1_regs },
            StageCost { name: "S2 Multiply", logic: s2, reg_bits: s2_regs },
            StageCost { name: "S3 Align", logic: s3, reg_bits: s3_regs },
            StageCost { name: "S4 Accumulate", logic: s4, reg_bits: s4_regs },
            StageCost { name: "S5 Normalize", logic: s5, reg_bits: s5_regs },
            StageCost { name: "S6 Encode", logic: s6, reg_bits: 0 },
        ],
        macs_per_op: n,
        activity_mult: 1.0,
    }
}

fn lzc_stage(acc_w: u32, exp_w: u32) -> Cost {
    lzc(acc_w).then(barrel_shifter(acc_w, acc_w)).then(adder(exp_w))
}

/// A discrete posit multiplier unit (PACoGen-style): full decode → booth →
/// round/encode.
pub fn posit_mul_unit(in_fmt: PositFormat, out_fmt: PositFormat) -> Cost {
    let mb = in_fmt.max_frac_bits() + 1;
    posit_decoder(in_fmt)
        .beside(posit_decoder(in_fmt))
        .then(booth_multiplier(mb))
        .then(posit_encoder(out_fmt))
}

/// A discrete posit adder unit: decode both, align, add, normalize, encode.
pub fn posit_add_unit(fmt: PositFormat) -> Cost {
    let mb = fmt.max_frac_bits() + 1;
    let w = 2 * mb + 2; // aligned add width with guard bits
    let exp_w = 32 - (fmt.max_scale() as u32).leading_zeros() + 1;
    posit_decoder(fmt)
        .beside(posit_decoder(fmt))
        .then(adder(exp_w)) // exponent difference
        .then(barrel_shifter(w, w)) // alignment
        .then(adder(w))
        .then(lzc(w))
        .then(barrel_shifter(w, w)) // normalize
        .then(posit_encoder(fmt))
}

/// A posit FMA unit [17]: three decoders, multiplier, aligned add, encode.
pub fn posit_fma_unit(in_fmt: PositFormat, out_fmt: PositFormat) -> Cost {
    let mb_in = in_fmt.max_frac_bits() + 1;
    // [17] aligns the addend against the product over the full posit scale
    // range (no Wm-style clamping), so the add/normalize datapath spans
    // max_scale + product mantissa bits — this is why the posit FMA's
    // synthesized area rivals an FP32 FMA in Table I.
    let w = out_fmt.max_scale() as u32 + 2 * mb_in + 2;
    let exp_w = 32 - (2 * in_fmt.max_scale().max(out_fmt.max_scale()) as u32).leading_zeros() + 1;
    posit_decoder(in_fmt)
        .beside(posit_decoder(in_fmt))
        .beside(posit_decoder(out_fmt))
        .then(booth_multiplier(mb_in))
        .then(adder(exp_w))
        .then(barrel_shifter(w, w))
        .then(adder(w))
        .then(lzc(w))
        .then(barrel_shifter(w, w))
        .then(posit_encoder(out_fmt))
}

/// IEEE multiplier unit (FPnew-style, subnormal support on).
pub fn ieee_mul_unit(fmt: IeeeFormat) -> Cost {
    let mb = fmt.man_bits + 1;
    ieee_unpack(fmt).beside(ieee_unpack(fmt)).then(booth_multiplier(mb)).then(ieee_pack(fmt))
}

/// IEEE adder unit.
pub fn ieee_add_unit(fmt: IeeeFormat) -> Cost {
    let mb = fmt.man_bits + 1;
    let w = 2 * mb + 2;
    ieee_unpack(fmt)
        .beside(ieee_unpack(fmt))
        .then(adder(fmt.exp_bits))
        .then(barrel_shifter(w, w))
        .then(adder(w))
        .then(lzc(w))
        .then(barrel_shifter(w, w))
        .then(ieee_pack(fmt))
}

/// IEEE FMA unit (FPnew FMA rows).
pub fn ieee_fma_unit(fmt: IeeeFormat) -> Cost {
    let mb = fmt.man_bits + 1;
    let w = 3 * mb + 4;
    ieee_unpack(fmt)
        .beside(ieee_unpack(fmt))
        .beside(ieee_unpack(fmt))
        .then(booth_multiplier(mb))
        .then(adder(fmt.exp_bits + 1))
        .then(barrel_shifter(w, w))
        .then(adder(w))
        .then(lzc(w))
        .then(barrel_shifter(w, w))
        .then(ieee_pack(fmt))
}

/// Fig. 1(a) discrete DPU: N multiplier units + a rounded adder tree of
/// N−1 adders + 1 accumulator adder. Delay = mul + (log₂N + 1)·add.
///
/// `activity_mult` models glitch amplification through the cascade of
/// complete decode→compute→round units (see [`Netlist::activity_mult`]):
/// ~4.0 for posit cascades (PACoGen row calibration), ~1.0 for IEEE.
pub fn discrete_mul_add(mul: Cost, add: Cost, n: u32, label: String, activity_mult: f64) -> Netlist {
    let tree_levels = ceil_log2(n) + 1; // adder tree + accumulate
    let logic = Cost {
        area_ge: mul.area_ge * n as f64 + add.area_ge * n as f64,
        delay_fo4: mul.delay_fo4 + add.delay_fo4 * tree_levels as f64,
    };
    Netlist {
        label,
        stages: vec![StageCost { name: "discrete datapath", logic, reg_bits: 0 }],
        macs_per_op: n,
        activity_mult,
    }
}

/// Fig. 1(b) cascaded-FMA DPU: N FMA units in series.
pub fn fma_cascade(fma: Cost, n: u32, label: String) -> Netlist {
    let logic = Cost { area_ge: fma.area_ge * n as f64, delay_fo4: fma.delay_fo4 * n as f64 };
    Netlist {
        label,
        stages: vec![StageCost { name: "fma cascade", logic, reg_bits: 0 }],
        macs_per_op: n,
        activity_mult: 1.0 + 0.5 * (n as f64 - 1.0), // serial glitch growth
    }
}

/// A single FMA unit as an architecture row (one MAC per op).
pub fn single_fma(fma: Cost, label: String) -> Netlist {
    Netlist {
        label,
        stages: vec![StageCost { name: "fma", logic: fma, reg_bits: 0 }],
        macs_per_op: 1,
        activity_mult: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdpu::PdpuConfig;

    fn paper_pdpu() -> Netlist {
        pdpu(PdpuParams::from_config(&PdpuConfig::paper_default()))
    }

    #[test]
    fn pdpu_has_six_stages() {
        let nl = paper_pdpu();
        assert_eq!(nl.stages.len(), 6);
        assert_eq!(nl.stages[0].name, "S1 Decode");
        assert_eq!(nl.stages[5].name, "S6 Encode");
        assert_eq!(nl.macs_per_op, 4);
        assert!(nl.reg_bits() > 0);
    }

    #[test]
    fn decoders_dominate_s1_and_s1_is_biggest_area() {
        // paper §IV-B: "the parallel posit decoders of S1 occupy a
        // relatively large proportion of PDPU"
        let nl = paper_pdpu();
        let s1 = &nl.stages[0];
        let max_area =
            nl.stages.iter().map(|s| s.logic.area_ge).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s1.logic.area_ge, max_area, "S1 must be the largest stage by area");
    }

    #[test]
    fn wm_grows_s3_s4() {
        let a = pdpu(PdpuParams { wm: 14, ..PdpuParams::from_config(&PdpuConfig::paper_default()) });
        let b = pdpu(PdpuParams { wm: 28, ..PdpuParams::from_config(&PdpuConfig::paper_default()) });
        assert!(b.stages[2].logic.area_ge > a.stages[2].logic.area_ge);
        assert!(b.stages[3].logic.area_ge > a.stages[3].logic.area_ge);
        // other stages untouched
        assert_eq!(b.stages[1].logic.area_ge, a.stages[1].logic.area_ge);
    }

    #[test]
    fn n_grows_s2_s4_delay() {
        // paper §IV-B: "with the increase of N, the latency of S2 and S4
        // increases rapidly ... since their tree structure becomes more
        // complicated"
        let p4 = PdpuParams { n: 4, ..PdpuParams::from_config(&PdpuConfig::paper_default()) };
        let p16 = PdpuParams { n: 16, ..p4 };
        let (a, b) = (pdpu(p4), pdpu(p16));
        assert!(b.stages[1].logic.delay_fo4 > a.stages[1].logic.delay_fo4, "S2 tree deepens");
        assert!(b.stages[3].logic.delay_fo4 > a.stages[3].logic.delay_fo4, "S4 tree deepens");
        // S6 delay independent of N
        assert_eq!(b.stages[5].logic.delay_fo4, a.stages[5].logic.delay_fo4);
    }

    #[test]
    fn fused_uses_fewer_codecs_than_discrete() {
        // the §III-B decoder/encoder count comparison, expressed in area:
        // PDPU's codec area = (2N+1) dec + 1 enc; discrete(a) uses
        // 2N dec + N enc for muls plus 2 dec + 1 enc per adder × N adders.
        let p16 = PositFormat::p(16, 2);
        let n = 4u32;
        let pdpu_codecs = posit_decoder(p16).area_ge * (2.0 * n as f64 + 1.0) + posit_encoder(p16).area_ge;
        let discrete_codecs = posit_decoder(p16).area_ge * (2.0 * n as f64 + 2.0 * n as f64)
            + posit_encoder(p16).area_ge * (n as f64 + n as f64);
        assert!(discrete_codecs > 1.5 * pdpu_codecs);
    }

    #[test]
    fn cascade_delay_linear_in_n() {
        let fma = posit_fma_unit(PositFormat::p(16, 2), PositFormat::p(16, 2));
        let c4 = fma_cascade(fma, 4, "c4".into());
        let c8 = fma_cascade(fma, 8, "c8".into());
        assert!((c8.combinational().delay_fo4 / c4.combinational().delay_fo4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fp16_unit_cheaper_than_fp32() {
        assert!(ieee_fma_unit(IeeeFormat::fp16()).area_ge < ieee_fma_unit(IeeeFormat::fp32()).area_ge);
        assert!(ieee_mul_unit(IeeeFormat::fp16()).area_ge < ieee_mul_unit(IeeeFormat::fp32()).area_ge);
    }

    #[test]
    fn worst_stage_identified() {
        let nl = paper_pdpu();
        let w = nl.worst_stage();
        assert!(nl.stages.iter().all(|s| s.logic.delay_fo4 <= w.logic.delay_fo4));
    }
}
