//! Mid-level datapath components shared by every architecture's netlist:
//! posit decoders/encoders, IEEE unpack/pack, the exponent max tree and
//! the recursive CSA tree — each assembled from the primitives in
//! [`super::gates`].

use super::gates::*;
use crate::pdpu::config::ceil_log2;
use crate::pdpu::stages::s4_accumulate::csa_tree_shape;
use crate::posit::PositFormat;

use super::IeeeFormat;

/// Posit decoder for an n-bit input (paper S1; "complicated leading zero
/// count and dynamic shift modules" — §IV-B): two's-complement negate,
/// regime LZC, dynamic shifter to extract exponent+fraction, small adds.
pub fn posit_decoder(fmt: PositFormat) -> Cost {
    let n = fmt.n();
    negate(n) // conditional complement of the input
        .then(lzc(n)) // regime run length
        .then(barrel_shifter(n, n)) // dynamic field extraction
        .then(Cost::new(3.5 * n as f64, 1.5)) // exponent/fraction field split, k→scale concat, zero/NaR flags
}

/// Posit encoder for an n-bit output (paper S6): regime construction,
/// dynamic shifter to pack fields, round increment, output complement.
pub fn posit_encoder(fmt: PositFormat) -> Cost {
    let n = fmt.n();
    Cost::new(2.5 * n as f64, 2.0) // regime pattern + bounds checks
        .then(barrel_shifter(2 * n, n)) // field packing shift (double width pre-round)
        .then(adder(n)) // rounding increment
        .then(negate(n)) // sign application
}

/// IEEE unpack: fixed fields, but gradual underflow needs an LZC + shift
/// on the mantissa (FPnew keeps subnormal support on).
pub fn ieee_unpack(fmt: IeeeFormat) -> Cost {
    let m = fmt.man_bits;
    Cost::new(1.0 * fmt.width() as f64, 1.0) // field split + specials
        .then(lzc(m).beside(Cost::ZERO)) // subnormal normalization count
        .then(barrel_shifter(m + 1, m)) // subnormal shift
}

/// IEEE pack: rounding increment, subnormal shift, special-case muxes.
pub fn ieee_pack(fmt: IeeeFormat) -> Cost {
    let m = fmt.man_bits;
    adder(m + 2) // round increment
        .then(barrel_shifter(m + 2, m)) // denormalization shift
        .then(Cost::new(1.5 * fmt.width() as f64, 1.2)) // specials/inf/nan muxes
}

/// Max tree over `entries` scales of `w` bits (paper S2 comparator tree).
pub fn max_tree(entries: u32, w: u32) -> Cost {
    if entries <= 1 {
        return Cost::ZERO;
    }
    let depth = ceil_log2(entries);
    let nodes = entries - 1;
    Cost { area_ge: max_node(w).area_ge * nodes as f64, delay_fo4: max_node(w).delay_fo4 * depth as f64 }
}

/// Recursive CSA tree over `inputs` operands of `w` bits, followed by the
/// final carry-propagate adder (paper S4, Fig. 5).
pub fn csa_tree(inputs: u32, w: u32) -> Cost {
    let shape = csa_tree_shape(inputs as usize);
    let compress = Cost {
        area_ge: csa32(w).area_ge * shape.c32 as f64 + csa42(w).area_ge * shape.c42 as f64,
        delay_fo4: 3.0 * shape.depth as f64, // worst level is a 4:2
    };
    compress.then(adder(w))
}

/// Alignment shifter bank: `lanes` barrel shifters of `w` bits with shift
/// range `max_shift`, plus the shift-amount subtractors and the
/// two's-complement conversion row (paper S3).
pub fn align_bank(lanes: u32, w: u32, max_shift: u32, exp_w: u32) -> Cost {
    let per_lane = adder(exp_w) // e_max − e_ab
        .then(barrel_shifter(w, max_shift))
        .then(negate(w)); // conditional two's complement
    Cost { area_ge: per_lane.area_ge * lanes as f64, delay_fo4: per_lane.delay_fo4 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posit_decoder_more_expensive_than_ieee_unpack_at_same_width() {
        // the paper's motivation for fused ops: posit decode needs dynamic
        // regime handling; IEEE-16 unpack is cheaper than posit-16 decode
        let p = posit_decoder(PositFormat::p(16, 2));
        let f = ieee_unpack(IeeeFormat::fp16());
        assert!(p.area_ge > f.area_ge, "posit {0} vs ieee {1}", p.area_ge, f.area_ge);
    }

    #[test]
    fn decoder_scales_with_n() {
        assert!(posit_decoder(PositFormat::p(16, 2)).area_ge > posit_decoder(PositFormat::p(8, 2)).area_ge);
        assert!(posit_encoder(PositFormat::p(16, 2)).area_ge > posit_encoder(PositFormat::p(13, 2)).area_ge);
    }

    #[test]
    fn max_tree_structure() {
        assert_eq!(max_tree(1, 8), Cost::ZERO);
        // N+1=5 entries: 4 nodes, depth 3
        let t5 = max_tree(5, 8);
        let node = max_node(8);
        assert!((t5.area_ge - 4.0 * node.area_ge).abs() < 1e-9);
        assert!((t5.delay_fo4 - 3.0 * node.delay_fo4).abs() < 1e-9);
        // 9 entries: 8 nodes, depth 4
        let t9 = max_tree(9, 8);
        assert!(t9.area_ge > t5.area_ge && t9.delay_fo4 > t5.delay_fo4);
    }

    #[test]
    fn csa_tree_grows_logarithmically_in_delay() {
        let d5 = csa_tree(5, 18).delay_fo4;
        let d9 = csa_tree(9, 18).delay_fo4;
        let d17 = csa_tree(17, 18).delay_fo4;
        assert!(d9 > d5 && d17 > d9);
        // but sub-linearly: doubling inputs adds ~one level (≈3 FO4)
        assert!(d17 - d9 <= 4.0);
    }

    #[test]
    fn align_bank_delay_independent_of_lanes() {
        let a4 = align_bank(5, 14, 14, 8);
        let a8 = align_bank(9, 14, 14, 8);
        assert_eq!(a4.delay_fo4, a8.delay_fo4);
        assert!(a8.area_ge > a4.area_ge);
    }
}
