//! Structural hardware cost model — the stand-in for the paper's
//! Synopsys DC + TSMC 28 nm synthesis flow (see DESIGN.md §Substitution).
//!
//! * [`gates`] — 28 nm technology scalars and primitive blocks (adders,
//!   shifters, LZCs, Booth multipliers, CSA compressors), in GE/FO4.
//! * [`components`] — posit/IEEE codecs, max trees, CSA trees, align banks.
//! * [`netlists`] — per-architecture structure builders (PDPU, discrete
//!   DPUs, FMA units — every Table I row).
//! * [`report`] — pricing into µm²/ns/mW and the Perf/efficiency columns,
//!   combinational (Table I) or pipelined (Fig. 6).
//!
//! [`table1_reports`] prices the full Table I line-up with one `Tech`.

pub mod components;
pub mod gates;
pub mod netlists;
pub mod report;

pub use gates::{Cost, Tech};
pub use netlists::{Netlist, PdpuParams};
pub use report::{synthesize_combinational, synthesize_pipelined, PipelineReport, Report, StageReport};

use crate::baselines::ieee::IeeeFormat;
use crate::posit::PositFormat;

/// Build the netlists for every Table I row, in row order. The `Wm` of the
/// quire row is the actual quire width required by P(13,2) products,
/// rounded up to the paper's 256.
pub fn table1_netlists() -> Vec<Netlist> {
    use netlists::*;
    let p16 = PositFormat::p(16, 2);
    let p13 = PositFormat::p(13, 2);
    let p10 = PositFormat::p(10, 2);
    let fp16 = IeeeFormat::fp16();
    let fp32 = IeeeFormat::fp32();
    vec![
        discrete_mul_add(ieee_mul_unit(fp32), ieee_add_unit(fp32), 4, "FPnew DPU FP32 N=4".into(), 1.0),
        discrete_mul_add(ieee_mul_unit(fp16), ieee_add_unit(fp16), 4, "FPnew DPU FP16 N=4".into(), 1.3),
        discrete_mul_add(
            posit_mul_unit(p16, p16),
            posit_add_unit(p16),
            4,
            "PACoGen DPU P(16,2) N=4".into(),
            4.0,
        ),
        pdpu(PdpuParams { in_fmt: p16, out_fmt: p16, n: 4, wm: 14 }),
        pdpu(PdpuParams { in_fmt: p13, out_fmt: p16, n: 4, wm: 14 }),
        pdpu(PdpuParams { in_fmt: p13, out_fmt: p16, n: 8, wm: 14 }),
        pdpu(PdpuParams { in_fmt: p10, out_fmt: p16, n: 8, wm: 14 }),
        pdpu(PdpuParams { in_fmt: p13, out_fmt: p16, n: 8, wm: 10 }),
        // Quire PDPU: alignment width = full 256-bit quire
        pdpu(PdpuParams { in_fmt: p13, out_fmt: p16, n: 4, wm: 256 }),
        single_fma(ieee_fma_unit(fp32), "FPnew FMA FP32".into()),
        single_fma(ieee_fma_unit(fp16), "FPnew FMA FP16".into()),
        single_fma(posit_fma_unit(p16, p16), "Posit FMA P(16,2)".into()),
    ]
}

/// Price the Table I line-up.
pub fn table1_reports(tech: &Tech) -> Vec<Report> {
    table1_netlists().iter().map(|nl| synthesize_combinational(nl, tech)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reports() -> Vec<Report> {
        table1_reports(&Tech::default())
    }

    fn by_label<'a>(rs: &'a [Report], frag: &str) -> &'a Report {
        rs.iter().find(|r| r.label.contains(frag)).unwrap_or_else(|| panic!("no row {frag}"))
    }

    /// The paper's headline claim: PDPU P(13/16,2) N=4 Wm=14 vs the
    /// PACoGen discrete DPU saves large fractions of area/delay/power
    /// ("up to 43%, 64%, 70%"). Structural model must reproduce the
    /// direction and rough magnitude.
    #[test]
    fn headline_savings_vs_pacogen() {
        let rs = reports();
        let pdpu = by_label(&rs, "PDPU P(13/16,2) N=4 Wm=14");
        let paco = by_label(&rs, "PACoGen");
        let area_save = 1.0 - pdpu.area_um2 / paco.area_um2;
        let delay_save = 1.0 - pdpu.delay_ns / paco.delay_ns;
        let power_save = 1.0 - pdpu.power_mw / paco.power_mw;
        assert!(area_save > 0.25, "area saving {area_save:.2} (paper: 0.43)");
        assert!(delay_save > 0.40, "delay saving {delay_save:.2} (paper: 0.64)");
        assert!(power_save > 0.40, "power saving {power_save:.2} (paper: 0.70)");
    }

    /// Quire PDPU blows up area and delay (paper: 29209 µm² vs 7695, i.e.
    /// ~3.8×, and 5× worse area efficiency).
    #[test]
    fn quire_overhead_is_prohibitive() {
        let rs = reports();
        let pdpu = by_label(&rs, "PDPU P(13/16,2) N=4 Wm=14");
        let quire = by_label(&rs, "Wm=256");
        assert!(quire.area_um2 > 2.0 * pdpu.area_um2, "quire {0} vs {1}", quire.area_um2, pdpu.area_um2);
        assert!(quire.delay_ns > pdpu.delay_ns);
        let ae_ratio = pdpu.area_eff() / quire.area_eff();
        assert!(ae_ratio > 2.5, "area-eff gain over quire {ae_ratio:.1} (paper: 5.0)");
    }

    /// PDPU beats the single-MAC posit FMA on both efficiency axes
    /// (paper: 3.1× area eff, 3.5× energy eff).
    #[test]
    fn pdpu_beats_posit_fma_efficiency() {
        let rs = reports();
        let pdpu = by_label(&rs, "PDPU P(13/16,2) N=4 Wm=14");
        let fma = by_label(&rs, "Posit FMA");
        assert!(pdpu.area_eff() / fma.area_eff() > 1.8, "{}", pdpu.area_eff() / fma.area_eff());
        assert!(pdpu.energy_eff() / fma.energy_eff() > 1.8);
    }

    /// FP32 discrete DPU is the biggest, slowest *non-quire* row (paper
    /// row 1: 28563 µm², 3.45 ns; only the quire PDPU at 29209 µm² tops
    /// it, in the paper exactly as in this model).
    #[test]
    fn fp32_dpu_is_largest_except_quire() {
        let rs = reports();
        let fp32 = by_label(&rs, "FPnew DPU FP32");
        for r in &rs {
            if !r.label.contains("FP32 N=4") && !r.label.contains("Wm=256") {
                assert!(fp32.area_um2 >= r.area_um2, "{} bigger than FP32 DPU", r.label);
            }
        }
        let quire = by_label(&rs, "Wm=256");
        assert!(quire.area_um2 > fp32.area_um2, "quire tops the table, as in the paper");
    }

    /// Bigger N amortizes: N=8 PDPU has better area & energy efficiency
    /// than N=4 at the same formats (paper rows 5 vs 6).
    #[test]
    fn larger_n_improves_efficiency() {
        let rs = reports();
        let n4 = by_label(&rs, "PDPU P(13/16,2) N=4 Wm=14");
        let n8 = by_label(&rs, "PDPU P(13/16,2) N=8 Wm=14");
        assert!(n8.area_eff() > n4.area_eff());
        assert!(n8.energy_eff() > n4.energy_eff());
        assert!(n8.perf_gops() > 1.5 * n4.perf_gops());
    }

    /// Narrower inputs are cheaper: P(10/16,2) < P(13/16,2) at N=8.
    #[test]
    fn narrower_inputs_cheaper() {
        let rs = reports();
        let p13 = by_label(&rs, "PDPU P(13/16,2) N=8 Wm=14");
        let p10 = by_label(&rs, "PDPU P(10/16,2) N=8 Wm=14");
        assert!(p10.area_um2 < p13.area_um2);
        assert!(p10.power_mw < p13.power_mw);
    }

    /// Smaller Wm is cheaper: Wm=10 < Wm=14 at P(13/16,2) N=8.
    #[test]
    fn smaller_wm_cheaper() {
        let rs = reports();
        let w14 = by_label(&rs, "PDPU P(13/16,2) N=8 Wm=14");
        let w10 = by_label(&rs, "PDPU P(13/16,2) N=8 Wm=10");
        assert!(w10.area_um2 < w14.area_um2);
    }

    /// Absolute calibration: the flagship P(16/16,2) N=4 Wm=14 row should
    /// land within a factor ~1.7 of the paper's synthesized numbers
    /// (9579 µm², 1.62 ns, 4.49 mW) — this pins the Tech scalars.
    #[test]
    fn absolute_calibration_within_band() {
        let rs = reports();
        let r = by_label(&rs, "PDPU P(16/16,2) N=4 Wm=14");
        assert!((r.area_um2 / 9579.15 - 1.0).abs() < 0.7, "area {:.0} vs 9579", r.area_um2);
        assert!((r.delay_ns / 1.62 - 1.0).abs() < 0.7, "delay {:.2} vs 1.62", r.delay_ns);
        assert!((r.power_mw / 4.49 - 1.0).abs() < 0.7, "power {:.2} vs 4.49", r.power_mw);
    }
}
