//! 28 nm-class technology constants and primitive gate-level building
//! blocks, expressed in gate equivalents (GE = one NAND2) and FO4 delays.
//!
//! This file is the substitution for the TSMC 28 nm standard-cell library +
//! Synopsys DC flow the paper used (see DESIGN.md §Substitution log). The
//! primitive-cost formulas are standard textbook estimates (full adder
//! ≈ 4.5 GE, parallel-prefix adder delay ≈ 2·log₂(w) FO4, …); the three
//! technology scalars below are *calibrated* so the flagship PDPU
//! configuration lands near the paper's synthesized numbers, after which
//! every other architecture is priced with the same ruler.

/// Technology scalars (28 nm, 1.05 V, 25 °C — the paper's corner).
#[derive(Clone, Copy, Debug)]
pub struct Tech {
    /// µm² per gate equivalent (NAND2 footprint incl. routing overhead)
    pub um2_per_ge: f64,
    /// nanoseconds per FO4 inverter delay
    pub fo4_ns: f64,
    /// femtojoules per GE per full output transition at 1.05 V
    pub fj_per_ge_switch: f64,
    /// average switching activity factor of datapath logic
    pub activity: f64,
}

impl Default for Tech {
    fn default() -> Self {
        // Calibrated against Table I's "Proposed PDPU P(16/16,2) N=4 Wm=14"
        // row (9579 µm², 1.62 ns, 4.49 mW → 7.27 pJ/op). um2_per_ge folds
        // cell + routing + utilization overhead; activity·fj_per_ge_switch
        // together set the datapath energy per GE-op (≈ 1.08 fJ/GE).
        Self { um2_per_ge: 1.40, fo4_ns: 0.0131, fj_per_ge_switch: 2.2, activity: 0.49 }
    }
}

/// Area (GE) and worst-path delay (FO4) of one combinational block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub area_ge: f64,
    pub delay_fo4: f64,
}

impl Cost {
    pub fn new(area_ge: f64, delay_fo4: f64) -> Self {
        Self { area_ge, delay_fo4 }
    }

    pub const ZERO: Cost = Cost { area_ge: 0.0, delay_fo4: 0.0 };

    /// Compose in series: areas add, delays add.
    pub fn then(self, next: Cost) -> Cost {
        Cost { area_ge: self.area_ge + next.area_ge, delay_fo4: self.delay_fo4 + next.delay_fo4 }
    }

    /// Compose in parallel: areas add, delay is the max.
    pub fn beside(self, other: Cost) -> Cost {
        Cost { area_ge: self.area_ge + other.area_ge, delay_fo4: self.delay_fo4.max(other.delay_fo4) }
    }

    /// `k` identical copies side by side.
    pub fn replicate(self, k: u32) -> Cost {
        Cost { area_ge: self.area_ge * k as f64, delay_fo4: self.delay_fo4 }
    }
}

#[inline]
fn log2f(x: u32) -> f64 {
    (x.max(1) as f64).log2()
}

// ---- primitive blocks -------------------------------------------------

/// w-bit parallel-prefix (Kogge-Stone-class) adder.
pub fn adder(w: u32) -> Cost {
    // FA-equivalent cells plus prefix network
    Cost::new(4.5 * w as f64 + 1.5 * w as f64 * log2f(w).max(1.0) * 0.5, 2.0 * log2f(w) + 2.0)
}

/// w-bit incrementer / two's-complement negate (XOR row + thin carry).
pub fn negate(w: u32) -> Cost {
    Cost::new(1.4 * w as f64 + 2.0 * w as f64 * 0.5, 1.2 * log2f(w) + 1.0)
}

/// w-bit 2:1 mux row.
pub fn mux2(w: u32) -> Cost {
    Cost::new(1.8 * w as f64, 0.9)
}

/// Barrel shifter: `w` data bits, shift range `max_shift` (log stages of
/// mux rows).
pub fn barrel_shifter(w: u32, max_shift: u32) -> Cost {
    let stages = log2f(max_shift.max(2)).ceil();
    Cost::new(1.8 * w as f64 * stages, 0.9 * stages + 0.5)
}

/// w-bit leading-zero counter (binary reduction tree).
pub fn lzc(w: u32) -> Cost {
    Cost::new(1.3 * w as f64, 1.4 * log2f(w) + 1.0)
}

/// w-bit magnitude comparator (for the exponent max tree).
pub fn comparator(w: u32) -> Cost {
    Cost::new(3.0 * w as f64, 1.2 * log2f(w) + 1.5)
}

/// One level of a max tree: comparator + select mux.
pub fn max_node(w: u32) -> Cost {
    comparator(w).then(mux2(w))
}

/// w×w modified radix-4 Booth multiplier (the paper's S2 multiplier).
pub fn booth_multiplier(w: u32) -> Cost {
    let npp = (w as f64 + 2.0) / 2.0; // number of partial products
    let enc = 3.5 * npp; // booth encoders
    let ppgen = 1.05 * npp * (w as f64 + 1.0); // PP selection muxes
    let levels = if npp > 2.0 { (npp / 2.0).log2().ceil().max(1.0) + 1.0 } else { 1.0 };
    let reduction = 4.5 * (npp - 2.0).max(0.0) * (w as f64 + 2.0); // CSA rows
    let fin = adder(2 * w);
    Cost::new(enc + ppgen + reduction, 2.0 + 2.5 * levels).then(fin)
}

/// w-bit 3:2 compressor row (one FA per bit).
pub fn csa32(w: u32) -> Cost {
    Cost::new(4.5 * w as f64, 2.0)
}

/// w-bit 4:2 compressor row.
pub fn csa42(w: u32) -> Cost {
    Cost::new(6.8 * w as f64, 3.0)
}

/// One D-flip-flop (pipeline register bit).
pub fn dff_bits(w: u32) -> Cost {
    Cost::new(4.8 * w as f64, 0.0) // setup/clk-q folded into stage margins
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_scale_with_width() {
        assert!(adder(32).area_ge > adder(16).area_ge);
        assert!(adder(32).delay_fo4 > adder(16).delay_fo4);
        assert!(booth_multiplier(24).area_ge > booth_multiplier(12).area_ge);
        assert!(lzc(32).delay_fo4 > lzc(8).delay_fo4);
        assert!(barrel_shifter(32, 32).area_ge > barrel_shifter(16, 16).area_ge);
    }

    #[test]
    fn composition_laws() {
        let a = Cost::new(10.0, 3.0);
        let b = Cost::new(5.0, 7.0);
        assert_eq!(a.then(b), Cost::new(15.0, 10.0));
        assert_eq!(a.beside(b), Cost::new(15.0, 7.0));
        assert_eq!(a.replicate(4), Cost::new(40.0, 3.0));
        assert_eq!(Cost::ZERO.then(a), a);
    }

    #[test]
    fn booth_quadratic_ish_in_width() {
        // doubling width should 3-5x the area (quadratic-ish PP array)
        let r = booth_multiplier(24).area_ge / booth_multiplier(12).area_ge;
        assert!((2.5..6.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn multiplier_dominates_adder() {
        assert!(booth_multiplier(12).area_ge > 3.0 * adder(12).area_ge);
    }

    #[test]
    fn tech_defaults_are_28nm_plausible() {
        let t = Tech::default();
        // um2_per_ge folds routing + utilization overhead on top of the
        // bare NAND2 cell (~0.5 µm² at 28 nm)
        assert!((0.3..3.0).contains(&t.um2_per_ge));
        assert!((0.008..0.03).contains(&t.fo4_ns));
        assert!((0.0..1.0).contains(&t.activity));
    }
}
