//! Pricing netlists into the paper's reported quantities: area (µm²),
//! delay (ns), power (mW), and the derived Perf / Area-efficiency /
//! Energy-efficiency columns of Table I, plus the per-stage pipelined
//! breakdown of Fig. 6.

use super::gates::{Cost, Tech};
use super::netlists::Netlist;

/// One Table I row's worth of synthesis results (combinational, as the
/// paper evaluates all units for fairness in §IV-A).
#[derive(Clone, Debug)]
pub struct Report {
    pub label: String,
    pub area_um2: f64,
    pub delay_ns: f64,
    pub power_mw: f64,
    pub energy_per_op_pj: f64,
    /// MAC operations completed per invocation
    pub macs_per_op: u32,
}

impl Report {
    /// Perf in GOPS: one MAC = one op (paper footnote †), back-to-back
    /// combinational invocations.
    pub fn perf_gops(&self) -> f64 {
        self.macs_per_op as f64 / self.delay_ns
    }

    /// GOPS/mm².
    pub fn area_eff(&self) -> f64 {
        self.perf_gops() / (self.area_um2 * 1e-6)
    }

    /// GOPS/W.
    pub fn energy_eff(&self) -> f64 {
        self.perf_gops() / (self.power_mw * 1e-3)
    }
}

/// Price a netlist combinationally (no pipeline registers) — the Table I
/// methodology ("all units in the comparison are combinationally
/// implemented to avoid impacts of different pipeline schemes").
pub fn synthesize_combinational(nl: &Netlist, tech: &Tech) -> Report {
    let total = nl.combinational();
    price(nl.label.clone(), total, nl.macs_per_op, nl.activity_mult, tech)
}

fn price(label: String, logic: Cost, macs: u32, activity_mult: f64, tech: &Tech) -> Report {
    let area_um2 = logic.area_ge * tech.um2_per_ge;
    let delay_ns = logic.delay_fo4 * tech.fo4_ns;
    let energy_per_op_pj = logic.area_ge * tech.activity * activity_mult * tech.fj_per_ge_switch * 1e-3;
    // back-to-back combinational operation: P = E/op · (1/delay)
    let power_mw = energy_per_op_pj / delay_ns;
    Report { label, area_um2, delay_ns, power_mw, energy_per_op_pj, macs_per_op: macs }
}

/// One pipeline stage's share in the Fig. 6 breakdown.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub name: &'static str,
    pub delay_ns: f64,
    pub area_um2: f64,
}

/// Pipelined synthesis: per-stage delay/area (logic + following pipeline
/// register), achievable clock and throughput speedup vs. combinational —
/// everything Fig. 6 plots.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub label: String,
    pub stages: Vec<StageReport>,
    /// worst stage delay incl. register overhead = clock period
    pub clock_ns: f64,
    pub fmax_ghz: f64,
    pub total_area_um2: f64,
    /// throughput gain over the combinational implementation
    pub speedup: f64,
}

/// Register timing overhead per pipeline stage (setup + clk-to-Q), in FO4.
const REG_OVERHEAD_FO4: f64 = 3.0;

pub fn synthesize_pipelined(nl: &Netlist, tech: &Tech) -> PipelineReport {
    let mut stages = Vec::with_capacity(nl.stages.len());
    let mut worst_fo4 = 0f64;
    let mut total_ge = 0f64;
    for s in &nl.stages {
        let reg_ge = super::gates::dff_bits(s.reg_bits).area_ge;
        let stage_ge = s.logic.area_ge + reg_ge;
        total_ge += stage_ge;
        worst_fo4 = worst_fo4.max(s.logic.delay_fo4 + REG_OVERHEAD_FO4);
        stages.push(StageReport {
            name: s.name,
            delay_ns: (s.logic.delay_fo4 + REG_OVERHEAD_FO4) * tech.fo4_ns,
            area_um2: stage_ge * tech.um2_per_ge,
        });
    }
    let clock_ns = worst_fo4 * tech.fo4_ns;
    let comb_delay_ns = nl.combinational().delay_fo4 * tech.fo4_ns;
    PipelineReport {
        label: nl.label.clone(),
        stages,
        clock_ns,
        fmax_ghz: 1.0 / clock_ns,
        total_area_um2: total_ge * tech.um2_per_ge,
        speedup: comb_delay_ns / clock_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::super::netlists::{pdpu, PdpuParams};
    use super::*;
    use crate::pdpu::PdpuConfig;

    fn paper_report() -> Report {
        let nl = pdpu(PdpuParams::from_config(&PdpuConfig::paper_default()));
        synthesize_combinational(&nl, &Tech::default())
    }

    #[test]
    fn perf_formula_matches_paper_footnote() {
        let r = paper_report();
        assert_eq!(r.macs_per_op, 4);
        assert!((r.perf_gops() - 4.0 / r.delay_ns).abs() < 1e-12);
        // efficiency columns consistent
        assert!((r.area_eff() - r.perf_gops() / (r.area_um2 * 1e-6)).abs() < 1e-9);
        assert!((r.energy_eff() - r.perf_gops() / (r.power_mw * 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn power_energy_consistent() {
        let r = paper_report();
        assert!((r.power_mw * r.delay_ns - r.energy_per_op_pj).abs() < 1e-9);
    }

    #[test]
    fn pipelined_clock_beats_combinational_delay() {
        let nl = pdpu(PdpuParams::from_config(&PdpuConfig::paper_default()));
        let t = Tech::default();
        let comb = synthesize_combinational(&nl, &t);
        let pipe = synthesize_pipelined(&nl, &t);
        assert!(pipe.clock_ns < comb.delay_ns / 3.0, "6 stages must cut the critical path hard");
        assert!(pipe.speedup > 3.0);
        assert_eq!(pipe.stages.len(), 6);
        // registers make the pipelined unit bigger
        assert!(pipe.total_area_um2 > comb.area_um2);
    }

    #[test]
    fn stage_delays_are_balanced_within_3x() {
        // paper: "the proposed pipeline strategy leads to a balanced
        // critical path delay of each stage"
        let nl = pdpu(PdpuParams::from_config(&PdpuConfig::paper_default()));
        let pipe = synthesize_pipelined(&nl, &Tech::default());
        let min = pipe.stages.iter().map(|s| s.delay_ns).fold(f64::INFINITY, f64::min);
        let max = pipe.stages.iter().map(|s| s.delay_ns).fold(0.0, f64::max);
        assert!(max / min < 3.0, "stage imbalance {min}..{max}");
    }
}
