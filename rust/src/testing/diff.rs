//! Shared differential-testing support for the posit datapath.
//!
//! Every suite that compares two implementations of the same dot product
//! (scalar stages vs. the lane-packed fast path, engine vs. scalar loop,
//! train kernels vs. reference backprop) needs the same two ingredients:
//!
//! * **seeded generators** that actually reach the adversarial corners —
//!   NaR, zero, ±maxpos/±minpos, deep-regime ("subnormal-like") patterns
//!   with almost no fraction bits, and cancellation-heavy vectors whose
//!   products annihilate;
//! * **a bit-identity runner** that drives one operand set through every
//!   datapath implementation and fails loudly (with the config label and
//!   the operands) on the first diverging bit.
//!
//! This module centralizes both so `rust/tests/engine_equivalence.rs`,
//! `rust/tests/train_stack.rs`, and the conformance/fuzz suites share one
//! definition of "hard inputs" instead of ad-hoc per-file generators.

use super::Rng;
use crate::pdpu::lanes::{dot_packed_chunk, LaneScratch, PackedLane, MAX_FAST_LANES};
use crate::pdpu::{DotScratch, Pdpu, PdpuConfig};
use crate::posit::{Posit, PositFormat};

// ---- posit generators -----------------------------------------------------

/// Uniform over the full n-bit pattern space — NaR and zero included.
pub fn rand_pattern(rng: &mut Rng, fmt: PositFormat) -> Posit {
    Posit::from_bits(rng.next_u64() as u32 & fmt.mask(), fmt)
}

/// Uniform over all finite patterns (rejects NaR; zero included).
pub fn rand_finite(rng: &mut Rng, fmt: PositFormat) -> Posit {
    loop {
        let p = rand_pattern(rng, fmt);
        if !p.is_nar() {
            return p;
        }
    }
}

/// Log-uniform magnitude within `2^±log2_span`, random sign — the
/// moderate-dynamic-range distribution most accuracy tests use.
pub fn rand_moderate(rng: &mut Rng, fmt: PositFormat, log2_span: f64) -> Posit {
    Posit::from_f64(rng.log_uniform_signed(-log2_span, log2_span), fmt)
}

/// One of the format's corner values: NaR, zero, ±1, ±maxpos, ±minpos,
/// the deep-regime neighbours of the extremes, or a random power of two
/// (single-set-bit pattern ⇒ maximal regime run, no fraction bits — the
/// posit analogue of a subnormal).
pub fn special(rng: &mut Rng, fmt: PositFormat) -> Posit {
    let neg = |p: Posit| Posit::from_bits(p.bits().wrapping_neg(), fmt);
    match rng.below(12) {
        0 => Posit::nar(fmt),
        1 => Posit::zero(fmt),
        2 => Posit::one(fmt),
        3 => neg(Posit::one(fmt)),
        4 => Posit::maxpos(fmt),
        5 => neg(Posit::maxpos(fmt)),
        6 => Posit::minpos(fmt),
        7 => neg(Posit::minpos(fmt)),
        8 => Posit::minpos(fmt).succ(),
        9 => Posit::maxpos(fmt).pred(),
        // single-bit pattern: deep regime, empty fraction
        10 => Posit::from_bits(1u32 << rng.below(fmt.n() as u64 - 1), fmt),
        _ => neg(Posit::from_bits(1u32 << rng.below(fmt.n() as u64 - 1), fmt)),
    }
}

/// A vector that mixes moderate values with forced corner cases: every
/// position has a 1-in-4 chance of being a [`special`], so short vectors
/// still hit NaR/extreme lanes often.
pub fn adversarial_vector(rng: &mut Rng, fmt: PositFormat, len: usize) -> Vec<Posit> {
    (0..len)
        .map(|_| if rng.below(4) == 0 { special(rng, fmt) } else { rand_finite(rng, fmt) })
        .collect()
}

/// A cancellation-heavy operand pair: lanes come in (v, w) / (−v, w)
/// couples so products annihilate pairwise, stressing the signed S4 sum,
/// the S5 renormalization of near-zero results, and exact-zero encoding.
/// Odd lengths keep one unpaired lane.
pub fn cancellation_pair(rng: &mut Rng, fmt: PositFormat, len: usize) -> (Vec<Posit>, Vec<Posit>) {
    let mut a = Vec::with_capacity(len);
    let mut b = Vec::with_capacity(len);
    while a.len() + 1 < len {
        let v = rand_finite(rng, fmt);
        let w = rand_finite(rng, fmt);
        a.push(v);
        b.push(w);
        a.push(Posit::from_bits(v.bits().wrapping_neg(), fmt));
        b.push(w);
    }
    if a.len() < len {
        a.push(rand_finite(rng, fmt));
        b.push(rand_finite(rng, fmt));
    }
    (a, b)
}

// ---- config / batch generators (migrated from the ad-hoc per-test-file
// ---- versions) ------------------------------------------------------------

/// Random valid [`PdpuConfig`] spanning the standard tested space:
/// N ∈ {1,4,8}, Wm ∈ 6..=96, uniform and mixed input/output formats.
pub fn random_config(rng: &mut Rng) -> PdpuConfig {
    let n = [1usize, 4, 8][rng.below(3) as usize];
    random_config_with_n(rng, n)
}

/// [`random_config`] with a caller-chosen dot-product size — the fuzz
/// suite uses this to cross the fast-path boundary (N > 64).
pub fn random_config_with_n(rng: &mut Rng, n: usize) -> PdpuConfig {
    loop {
        let wm = rng.range_i64(6, 96) as u32;
        let es = rng.range_i64(0, 2) as u32;
        let n_out = rng.range_i64(8, 32) as u32;
        let n_in = if rng.flip() {
            n_out // uniform
        } else {
            rng.range_i64(5, n_out as i64) as u32 // mixed: narrow inputs
        };
        if let Ok(cfg) = PdpuConfig::mixed(n_in, n_out, es, n, wm) {
            return cfg;
        }
    }
}

/// A training mini-batch: `b`×`d` standard-normal inputs (row-major) plus
/// `b` uniform class labels in `0..classes`.
pub fn random_batch(rng: &mut Rng, b: usize, d: usize, classes: usize) -> (Vec<f64>, Vec<usize>) {
    let xs = (0..b * d).map(|_| rng.normal()).collect();
    let labels = (0..b).map(|_| rng.below(classes as u64) as usize).collect();
    (xs, labels)
}

// ---- the bit-identity runner ---------------------------------------------

/// Assert two implementations produced the same posit, with a readable
/// failure message. The building block of [`assert_dot_paths_bit_identical`].
#[track_caller]
pub fn assert_bit_identical(label: &str, scalar: Posit, vectorized: Posit) {
    assert_eq!(
        scalar.bits(),
        vectorized.bits(),
        "{label}: scalar {scalar:?} != vectorized {vectorized:?}"
    );
}

/// Drive one `acc + Va·Vb` operand set through **every** dot-product
/// implementation — the allocating scalar stage pipeline (the reference),
/// the scratch path `Pdpu::dot_with` (lane-packed fused kernel for
/// N ≤ 64, staged fallback above), the fused kernel called directly, and
/// the engine's pre-decoded `dot_prepared` — asserting pairwise
/// bit-identity. Returns the reference result.
pub fn assert_dot_paths_bit_identical(
    cfg: &PdpuConfig,
    acc: Posit,
    a: &[Posit],
    b: &[Posit],
) -> Posit {
    let unit = Pdpu::new(*cfg);
    let scalar = unit.dot(acc, a, b);
    let label = cfg.label();

    let mut scratch = DotScratch::for_config(cfg);
    let via_scratch = unit.dot_with(acc, a, b, &mut scratch);
    assert_bit_identical(&format!("{label} dot_with: a={a:?} b={b:?} acc={acc:?}"), scalar, via_scratch);

    let pa: Vec<PackedLane> = a.iter().map(|&p| PackedLane::from_posit(p)).collect();
    let pb: Vec<PackedLane> = b.iter().map(|&p| PackedLane::from_posit(p)).collect();
    if cfg.n <= MAX_FAST_LANES {
        let mut lanes = LaneScratch::new();
        let fused = dot_packed_chunk(cfg, acc, &pa, &pb, &mut lanes);
        assert_bit_identical(
            &format!("{label} dot_packed_chunk: a={a:?} b={b:?} acc={acc:?}"),
            scalar,
            fused,
        );
    }

    let engine = crate::engine::BatchEngine::new(*cfg);
    let via_engine = engine.dot_prepared(acc, &pa, &pb, &mut scratch);
    assert_bit_identical(
        &format!("{label} dot_prepared: a={a:?} b={b:?} acc={acc:?}"),
        scalar,
        via_engine,
    );
    scalar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_cover_the_corners() {
        let fmt = PositFormat::p(13, 2);
        let mut rng = Rng::seeded(0xD1FF);
        let (mut nar, mut zero, mut maxp, mut minp) = (false, false, false, false);
        for _ in 0..2_000 {
            let p = special(&mut rng, fmt);
            nar |= p.is_nar();
            zero |= p.is_zero();
            maxp |= p.bits() == fmt.maxpos_bits();
            minp |= p.bits() == fmt.minpos_bits();
        }
        assert!(nar && zero && maxp && minp, "{nar} {zero} {maxp} {minp}");
    }

    #[test]
    fn adversarial_vectors_contain_specials() {
        let fmt = PositFormat::p(8, 2);
        let mut rng = Rng::seeded(0xAD7E);
        let v: Vec<Posit> = (0..40).flat_map(|_| adversarial_vector(&mut rng, fmt, 8)).collect();
        assert!(v.iter().any(|p| p.is_nar()));
        assert!(v.iter().any(|p| p.is_zero()));
    }

    #[test]
    fn cancellation_pairs_annihilate_under_exact_sum() {
        let fmt = PositFormat::p(13, 2);
        let mut rng = Rng::seeded(0xCA9C);
        for len in [2usize, 4, 8] {
            let (a, b) = cancellation_pair(&mut rng, fmt, len);
            assert_eq!(a.len(), len);
            let exact: f64 = a.iter().zip(&b).map(|(x, y)| x.to_f64() * y.to_f64()).sum();
            assert_eq!(exact, 0.0, "even-length pairs must cancel exactly");
        }
        let (a, _) = cancellation_pair(&mut rng, fmt, 5);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn runner_accepts_agreeing_paths_on_adversarial_data() {
        let mut rng = Rng::seeded(0x0D1F);
        for _ in 0..40 {
            let cfg = random_config(&mut rng);
            let a = adversarial_vector(&mut rng, cfg.in_fmt, cfg.n);
            let b = adversarial_vector(&mut rng, cfg.in_fmt, cfg.n);
            let acc = if rng.below(4) == 0 { special(&mut rng, cfg.out_fmt) } else { rand_finite(&mut rng, cfg.out_fmt) };
            assert_dot_paths_bit_identical(&cfg, acc, &a, &b);
        }
    }

    #[test]
    fn random_batch_shapes() {
        let mut rng = Rng::seeded(0xBA7C);
        let (xs, labels) = random_batch(&mut rng, 3, 5, 4);
        assert_eq!(xs.len(), 15);
        assert_eq!(labels.len(), 3);
        assert!(labels.iter().all(|&l| l < 4));
    }

    #[test]
    #[should_panic(expected = "scalar")]
    fn runner_reports_divergence() {
        let fmt = PositFormat::p(16, 2);
        assert_bit_identical("forced", Posit::one(fmt), Posit::zero(fmt));
    }
}
