//! Lightweight property-testing support.
//!
//! The offline build image carries no `proptest`/`quickcheck`, so this
//! module provides the two pieces the test suite actually needs:
//!
//! * [`Rng`] — a small, fast, seedable SplitMix64 PRNG (deterministic test
//!   vectors, no `rand` dependency);
//! * [`check`] — a randomized property runner with minimal failure
//!   reporting (seed + iteration), so a red run is reproducible by pasting
//!   the printed seed into `Rng::seeded`.
//!
//! [`diff`] adds the shared differential-testing layer on top: seeded
//! generators for adversarial posit corners and the scalar↔vectorized
//! bit-identity runner used by the conformance and fuzz suites.

pub mod diff;

/// SplitMix64: tiny, high-quality-enough, seedable PRNG.
/// (Sebastiano Vigna's public-domain generator.)
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn seeded(seed: u64) -> Self {
        Self { state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // modulo bias is irrelevant at test scale
        self.next_u64() % n.max(1)
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (pairs discarded; test-grade).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.unit().max(1e-300);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/sigma.
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Log-uniform magnitude with random sign — a posit-friendly stress
    /// distribution covering the whole dynamic range.
    pub fn log_uniform_signed(&mut self, log2_lo: f64, log2_hi: f64) -> f64 {
        let mag = self.uniform(log2_lo, log2_hi).exp2();
        if self.next_u64() & 1 == 0 {
            mag
        } else {
            -mag
        }
    }

    /// Random boolean.
    #[inline]
    pub fn flip(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `prop` for `iters` random iterations. On failure the panic message
/// includes the seed and iteration index for exact reproduction.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, seed: u64, iters: usize, mut prop: F) {
    for i in 0..iters {
        // fresh, addressable sub-generator per iteration: failures
        // reproduce without replaying the whole sequence
        let mut rng = Rng::seeded(seed ^ (i as u64).wrapping_mul(0xA24B_AED4_963E_E407));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng, i)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at iter {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::seeded(1).next_u64(), Rng::seeded(2).next_u64());
    }

    #[test]
    fn unit_in_range() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let v = r.unit();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::seeded(4);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn check_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 0xBEEF, 10, |_rng, _i| panic!("boom"));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("0xbeef"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn check_passes_quietly() {
        check("trivial", 1, 50, |rng, _| {
            let v = rng.uniform(-1.0, 1.0);
            assert!(v.abs() <= 1.0);
        });
    }
}
