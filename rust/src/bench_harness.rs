//! Minimal benchmark harness (the offline image has no criterion): timed
//! runs with warmup, adaptive iteration count, and mean/p50/p95 reporting.
//! Used by the `[[bench]] harness = false` targets under `rust/benches/`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    /// Throughput for `work` logical items per iteration.
    pub fn per_second(&self, work: f64) -> f64 {
        work / self.mean.as_secs_f64()
    }
}

/// Benchmark `f`, auto-scaling iterations to fill ~`budget` of wall time.
pub fn bench<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> Measurement {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let target_iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(5.0, 100_000.0) as u64;

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    Measurement {
        name: name.to_string(),
        iters: target_iters,
        mean: total / target_iters as u32,
        p50: samples[samples.len() / 2],
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min: samples[0],
    }
}

/// Print a measurement row (aligned, human units).
pub fn report(m: &Measurement) {
    println!(
        "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
        m.name,
        fmt_dur(m.mean),
        fmt_dur(m.p50),
        fmt_dur(m.p95),
        m.iters
    );
}

pub fn report_header() {
    println!("{:<48} {:>12} {:>12} {:>12}", "benchmark", "mean", "p50", "p95");
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", Duration::from_millis(20), || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(m.iters >= 5);
        assert!(m.mean.as_nanos() > 0);
        assert!(m.p50 <= m.p95);
        assert!(m.min <= m.p50);
    }

    #[test]
    fn per_second_math() {
        let m = Measurement {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_millis(10),
            p50: Duration::from_millis(10),
            p95: Duration::from_millis(10),
            min: Duration::from_millis(10),
        };
        assert!((m.per_second(100.0) - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
