//! The fused architectures as [`DotArch`] rows: the proposed PDPU itself
//! and the quire-equipped PDPU baseline (Table I's "Quire PDPU" row).

use super::arch::DotArch;
use crate::engine::{BatchEngine, PreparedOperands};
use crate::pdpu::{Pdpu, PdpuConfig};
use crate::posit::quire::CACHE_LINE_LIMBS;
use crate::posit::{quire::Quire, Posit, PositFormat, QuireSpec};

/// The proposed PDPU as an evaluable architecture.
#[derive(Clone, Debug)]
pub struct PdpuArch {
    unit: Pdpu,
}

impl PdpuArch {
    pub fn new(cfg: PdpuConfig) -> Self {
        Self { unit: Pdpu::new(cfg) }
    }

    pub fn config(&self) -> &PdpuConfig {
        self.unit.config()
    }
}

impl DotArch for PdpuArch {
    fn name(&self) -> String {
        format!("PDPU {}", self.unit.config().label())
    }

    fn chunk(&self) -> usize {
        self.unit.config().n
    }

    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
        let cfg = self.unit.config();
        let qa: Vec<Posit> = a.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let qb: Vec<Posit> = b.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let acc = Posit::from_f64(acc, cfg.out_fmt);
        self.unit.dot_chunked(acc, &qa, &qb).to_f64()
    }

    /// Batched override: quantize + pre-decode each operand matrix once
    /// (instead of once per output element) and execute row-parallel
    /// through [`BatchEngine`]. Bit-identical to the default scalar loop —
    /// see `rust/tests/engine_equivalence.rs`.
    fn dot_batch(&self, acc: &[f64], w: &[f64], x: &[f64], k: usize) -> Vec<f64> {
        let cfg = *self.unit.config();
        let wp = PreparedOperands::quantize(cfg.in_fmt, w, k);
        let xp = PreparedOperands::quantize(cfg.in_fmt, x, k);
        assert_eq!(acc.len(), wp.rows(), "one accumulator seed per output row");
        let accp: Vec<Posit> = acc.iter().map(|&v| Posit::from_f64(v, cfg.out_fmt)).collect();
        let engine = BatchEngine::new(cfg);
        engine.gemm_posit(&accp, &wp, &xp).iter().map(|p| p.to_f64()).collect()
    }
}

/// PDPU with quire-exact accumulation (Wm = full quire width): one
/// rounding for the *entire* chunk including the running accumulator —
/// the most precise and most expensive row of Table I.
///
/// Numerically, chunked quire accumulation still re-rounds the running
/// accumulator between chunks (it re-enters the datapath as a posit), so
/// this matches the hardware's chunk-serial behaviour rather than an
/// idealized one-quire-per-whole-vector model.
#[derive(Clone, Debug)]
pub struct QuirePdpuArch {
    pub in_fmt: PositFormat,
    pub out_fmt: PositFormat,
    pub n: usize,
    /// Quire recipe for `in_fmt` products, validated once at construction
    /// so per-chunk quire setup inside the dot loop is branch-free.
    spec: QuireSpec,
}

impl QuirePdpuArch {
    /// Build the quire baseline: `n`-lane chunks, quire-exact inside each.
    pub fn new(in_fmt: PositFormat, out_fmt: PositFormat, n: usize) -> Self {
        assert!(n >= 1);
        let spec = QuireSpec::new(in_fmt, in_fmt).expect("quire capacity");
        Self { in_fmt, out_fmt, n, spec }
    }

    /// The quire register width this configuration implies (the Wm column
    /// of the quire row; P(13,2) products need 256 bits in the paper).
    pub fn quire_bits(&self) -> u32 {
        self.spec.required_bits()
    }

    /// The chunk-serial quire accumulation over already-quantized posits —
    /// the single definition of this architecture's dataflow, shared by
    /// the scalar [`DotArch::dot_f64`] entry point and the prepared-operand
    /// [`DotArch::dot_batch`] override. Dispatches once on the register
    /// width the format pair needs (one cache line when it fits), then
    /// reuses a single quire across chunks.
    fn dot_posits(&self, acc: Posit, a: &[Posit], b: &[Posit]) -> Posit {
        if self.spec.fits_cache_line() {
            self.dot_posits_with::<CACHE_LINE_LIMBS>(acc, a, b)
        } else {
            self.dot_posits_with::<16>(acc, a, b)
        }
    }

    fn dot_posits_with<const L: usize>(&self, acc: Posit, a: &[Posit], b: &[Posit]) -> Posit {
        let mut acc = acc;
        let mut q = Quire::<L>::from_spec(self.spec);
        for (ca, cb) in a.chunks(self.n).zip(b.chunks(self.n)) {
            q.reset();
            q.add_posit(acc);
            for (&x, &y) in ca.iter().zip(cb) {
                q.add_product(x, y);
            }
            acc = q.to_posit(self.out_fmt);
        }
        acc
    }
}

impl DotArch for QuirePdpuArch {
    fn name(&self) -> String {
        format!(
            "Quire PDPU P({}/{},{}) N={}",
            self.in_fmt.n(),
            self.out_fmt.n(),
            self.in_fmt.es(),
            self.n
        )
    }

    fn chunk(&self) -> usize {
        self.n
    }

    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        let qa: Vec<Posit> = a.iter().map(|&v| Posit::from_f64(v, self.in_fmt)).collect();
        let qb: Vec<Posit> = b.iter().map(|&v| Posit::from_f64(v, self.in_fmt)).collect();
        self.dot_posits(Posit::from_f64(acc, self.out_fmt), &qa, &qb).to_f64()
    }

    /// Prepared-operand override: quantize each operand matrix **once**
    /// (instead of once per output element) and run the chunk-serial quire
    /// accumulation over the cached posit planes. Quantization is
    /// per-value, so this is bit-identical to the defaulted scalar loop —
    /// property-tested in `rust/tests/engine_equivalence.rs`. This lets
    /// the quire baseline ride the same fused serving path as the PDPU
    /// engine.
    fn dot_batch(&self, acc: &[f64], w: &[f64], x: &[f64], k: usize) -> Vec<f64> {
        assert!(k > 0, "inner dimension k must be positive");
        assert_eq!(w.len() % k, 0, "w length {} not a multiple of k={k}", w.len());
        assert_eq!(x.len() % k, 0, "x length {} not a multiple of k={k}", x.len());
        let rows = w.len() / k;
        let cols = x.len() / k;
        assert_eq!(acc.len(), rows, "one accumulator seed per output row");
        let qw: Vec<Posit> = w.iter().map(|&v| Posit::from_f64(v, self.in_fmt)).collect();
        let qx: Vec<Posit> = x.iter().map(|&v| Posit::from_f64(v, self.in_fmt)).collect();
        let qacc: Vec<Posit> = acc.iter().map(|&v| Posit::from_f64(v, self.out_fmt)).collect();
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let wrow = &qw[r * k..(r + 1) * k];
            for c in 0..cols {
                out.push(self.dot_posits(qacc[r], wrow, &qx[c * k..(c + 1) * k]).to_f64());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn pdpu_arch_reports_config() {
        let arch = PdpuArch::new(PdpuConfig::paper_default());
        assert_eq!(arch.name(), "PDPU P(13/16,2) N=4 Wm=14");
        assert_eq!(arch.chunk(), 4);
    }

    #[test]
    fn quire_bits_ballpark_of_paper() {
        let q = QuirePdpuArch::new(PositFormat::p(13, 2), PositFormat::p(16, 2), 4);
        // the paper rounds its quire row's Wm to 256
        assert!((150..=320).contains(&q.quire_bits()), "{}", q.quire_bits());
    }

    #[test]
    fn quire_beats_or_matches_pdpu_on_accuracy() {
        let in_f = PositFormat::p(13, 2);
        let out_f = PositFormat::p(16, 2);
        let pdpu = PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap());
        let quire = QuirePdpuArch::new(in_f, out_f, 4);
        let mut rng = Rng::seeded(0xACC);
        let (mut err_pdpu, mut err_quire) = (0.0f64, 0.0f64);
        for _ in 0..300 {
            let n = 64;
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // reference with quantized inputs (so only accumulation error
            // is measured, same as both units see)
            let exact: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| Posit::from_f64(x, in_f).to_f64() * Posit::from_f64(y, in_f).to_f64())
                .sum();
            err_pdpu += (pdpu.dot_f64(0.0, &a, &b) - exact).abs();
            err_quire += (quire.dot_f64(0.0, &a, &b) - exact).abs();
        }
        assert!(err_quire <= err_pdpu, "quire {err_quire} vs pdpu {err_pdpu}");
    }

    #[test]
    fn quire_dot_batch_matches_scalar_loop_bitwise() {
        let q = QuirePdpuArch::new(PositFormat::p(13, 2), PositFormat::p(16, 2), 4);
        let mut rng = Rng::seeded(0x0B5);
        let (rows, cols, k) = (3usize, 4usize, 11usize);
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let got = q.dot_batch(&acc, &w, &x, k);
        for r in 0..rows {
            for c in 0..cols {
                let want = q.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), want.to_bits(), "out[{r},{c}]");
            }
        }
    }

    #[test]
    fn single_chunk_quire_is_single_rounding() {
        // one chunk → quire result equals exact_dot
        let q = QuirePdpuArch::new(PositFormat::p(16, 2), PositFormat::p(16, 2), 4);
        let a = [0.1, 0.2, 0.3, 0.4];
        let b = [1.0, 1.0, 1.0, -1.0];
        let got = q.dot_f64(0.25, &a, &b);
        let fmt = PositFormat::p(16, 2);
        let qa: Vec<Posit> = a.iter().map(|&v| Posit::from_f64(v, fmt)).collect();
        let qb: Vec<Posit> = b.iter().map(|&v| Posit::from_f64(v, fmt)).collect();
        let want = crate::posit::quire::exact_dot(Posit::from_f64(0.25, fmt), &qa, &qb, fmt).to_f64();
        assert_eq!(got, want);
    }
}
