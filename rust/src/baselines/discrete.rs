//! The discrete dot-product architectures of Fig. 1 — what PDPU replaces.
//!
//! * [`MulAddTreeDpu`] — Fig. 1(a): N parallel multipliers feeding a
//!   binary adder tree, **every** intermediate result rounded to the wide
//!   format (each box in Fig. 1(a) is a complete unit with its own
//!   decode/round/encode). Instantiated with [`PositArith`] this is the
//!   PACoGen-style DPU row of Table I; with [`IeeeArith`] the FPnew DPU
//!   rows.
//! * [`FmaCascadeDpu`] — Fig. 1(b): N cascaded fused multiply-add units;
//!   one rounding per FMA, serial dependency through the accumulator.
//!   With `chunk = 1` this is also the FMA-unit rows (FPnew FMA, posit
//!   FMA [17]), which perform one MAC per cycle.

use super::arch::{DotArch, ScalarArith};

/// Fig. 1(a): multipliers + rounded adder tree, chunked accumulation.
#[derive(Clone, Debug)]
pub struct MulAddTreeDpu<A: ScalarArith> {
    pub arith: A,
    pub n: usize,
    pub label: String,
}

impl<A: ScalarArith> MulAddTreeDpu<A> {
    pub fn new(arith: A, n: usize, label: impl Into<String>) -> Self {
        assert!(n >= 1);
        Self { arith, n, label: label.into() }
    }

    /// One chunk: products then tree reduction then accumulator add —
    /// every step individually rounded.
    fn chunk_dot(&self, acc: A::V, a: &[A::V], b: &[A::V]) -> A::V {
        let mut level: Vec<A::V> = a.iter().zip(b).map(|(&x, &y)| self.arith.mul(x, y)).collect();
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            for pair in level.chunks(2) {
                if pair.len() == 2 {
                    next.push(self.arith.add(pair[0], pair[1]));
                } else {
                    next.push(pair[0]);
                }
            }
            level = next;
        }
        self.arith.add(acc, level[0])
    }
}

impl<A: ScalarArith> DotArch for MulAddTreeDpu<A> {
    fn name(&self) -> String {
        format!("{} {} N={}", self.label, self.arith.describe(), self.n)
    }

    fn chunk(&self) -> usize {
        self.n
    }

    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        let mut acc_v = self.arith.quant_acc(acc);
        let zero = self.arith.quant_in(0.0);
        for (ca, cb) in a.chunks(self.n).zip(b.chunks(self.n)) {
            let mut qa: Vec<A::V> = ca.iter().map(|&v| self.arith.quant_in(v)).collect();
            let mut qb: Vec<A::V> = cb.iter().map(|&v| self.arith.quant_in(v)).collect();
            qa.resize(self.n, zero);
            qb.resize(self.n, zero);
            acc_v = self.chunk_dot(acc_v, &qa, &qb);
        }
        self.arith.to_f64(acc_v)
    }
}

/// Fig. 1(b): cascaded FMA units (or, with n = 1, a single FMA unit doing
/// one MAC per step).
#[derive(Clone, Debug)]
pub struct FmaCascadeDpu<A: ScalarArith> {
    pub arith: A,
    pub n: usize,
    pub label: String,
}

impl<A: ScalarArith> FmaCascadeDpu<A> {
    pub fn new(arith: A, n: usize, label: impl Into<String>) -> Self {
        assert!(n >= 1);
        Self { arith, n, label: label.into() }
    }
}

impl<A: ScalarArith> DotArch for FmaCascadeDpu<A> {
    fn name(&self) -> String {
        format!("{} {} N={}", self.label, self.arith.describe(), self.n)
    }

    fn chunk(&self) -> usize {
        self.n
    }

    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len());
        // the cascade is numerically a pure serial FMA chain regardless of
        // how many physical units it is spread across
        let mut acc_v = self.arith.quant_acc(acc);
        for (&x, &y) in a.iter().zip(b) {
            let (qx, qy) = (self.arith.quant_in(x), self.arith.quant_in(y));
            acc_v = self.arith.fma(qx, qy, acc_v);
        }
        self.arith.to_f64(acc_v)
    }
}

#[cfg(test)]
mod tests {
    use super::super::arch::{IeeeArith, PositArith};
    use super::super::ieee::IeeeFormat;
    use super::*;
    use crate::posit::PositFormat;
    use crate::testing::Rng;

    fn posit_arith() -> PositArith {
        PositArith { in_fmt: PositFormat::p(16, 2), out_fmt: PositFormat::p(16, 2) }
    }

    #[test]
    fn exact_small_integers() {
        // integer data well inside every format: all architectures agree
        // with the true value
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let b = [2.0, 2.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0];
        let want = 20.0 + 26.0 + 3.0;
        let tree = MulAddTreeDpu::new(posit_arith(), 4, "discrete");
        assert_eq!(tree.dot_f64(3.0, &a, &b), want);
        let casc = FmaCascadeDpu::new(posit_arith(), 4, "cascade");
        assert_eq!(casc.dot_f64(3.0, &a, &b), want);
        let fp = MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 4, "FPnew DPU");
        assert_eq!(fp.dot_f64(3.0, &a, &b), want);
    }

    #[test]
    fn tail_chunks_are_zero_padded() {
        let tree = MulAddTreeDpu::new(posit_arith(), 4, "discrete");
        // length 5: one full chunk + tail of 1
        let a = [1.0, 1.0, 1.0, 1.0, 10.0];
        let b = [1.0, 1.0, 1.0, 1.0, 0.5];
        assert_eq!(tree.dot_f64(0.0, &a, &b), 9.0);
    }

    #[test]
    fn discrete_rounds_more_than_fused() {
        // A dataset engineered so intermediate rounding hurts: many terms
        // whose products need more mantissa than P(8,2) keeps. The discrete
        // tree (rounds every add) must drift at least as far from the exact
        // value as a single-rounding FMA cascade over f64 would.
        let fa = PositArith { in_fmt: PositFormat::p(8, 2), out_fmt: PositFormat::p(8, 2) };
        let tree = MulAddTreeDpu::new(fa, 4, "discrete");
        let mut rng = Rng::seeded(99);
        let mut tree_err = 0.0;
        let n = 64;
        for _ in 0..200 {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            tree_err += (tree.dot_f64(0.0, &a, &b) - exact).abs();
        }
        assert!(tree_err > 0.0, "P(8,2) discrete tree cannot be exact on gaussian data");
    }

    #[test]
    fn fp16_dpu_can_overflow_where_fp32_does_not() {
        let fp16 = MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 4, "FPnew DPU");
        let fp32 = MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 4, "FPnew DPU");
        let a = [300.0; 4];
        let b = [300.0; 4]; // products 90k > 65504 → FP16 inf
        assert!(fp16.dot_f64(0.0, &a, &b).is_infinite());
        assert_eq!(fp32.dot_f64(0.0, &a, &b), 360_000.0);
    }

    #[test]
    fn cascade_order_sensitivity_exists_for_discrete() {
        // serial FMA chains are order-sensitive (no quire): our model must
        // expose that reality on cancellation-heavy data. Scan random
        // triples until a pair of orderings disagrees.
        let casc = FmaCascadeDpu::new(
            PositArith { in_fmt: PositFormat::p(8, 2), out_fmt: PositFormat::p(8, 2) },
            1,
            "posit FMA",
        );
        let mut rng = Rng::seeded(0x0D9);
        let b = [1.0, 1.0, 1.0];
        let mut found = false;
        for _ in 0..200 {
            let x = rng.normal_ms(0.0, 30.0);
            let y = rng.normal_ms(0.0, 1.0);
            let a = [x, y, -x];
            let rev = [-x, y, x];
            if casc.dot_f64(0.0, &a, &b) != casc.dot_f64(0.0, &rev, &b) {
                found = true;
                break;
            }
        }
        assert!(found, "no order sensitivity observed in 200 random triples");
    }

    #[test]
    fn names_are_informative() {
        let tree = MulAddTreeDpu::new(posit_arith(), 4, "PACoGen DPU");
        assert_eq!(tree.name(), "PACoGen DPU P(16,2) N=4");
        let fma = FmaCascadeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 1, "FPnew FMA");
        assert_eq!(fma.name(), "FPnew FMA FP32 N=1");
    }
}
