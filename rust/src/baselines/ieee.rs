//! Bit-exact IEEE-754 binary floating point for arbitrary (exp, man)
//! widths — the substrate under the FPnew-style baselines of Table I.
//!
//! FPnew [35] is a transprecision IEEE FPU; its DPU/FMA rows compute with
//! per-operation round-to-nearest-even, gradual underflow (subnormals),
//! and overflow to ±∞. This module reimplements exactly those semantics in
//! software: decode → exact integer compute with sticky → single RNE
//! encode, the same discipline as [`crate::posit`]. FP16 = `Ieee::fp16()`,
//! FP32 = `Ieee::fp32()`; any (e ≤ 11, m ≤ 52) pair works, mirroring
//! FPnew's multi-format generator.

/// An IEEE-754 binary format: `1 + exp_bits + man_bits` wide.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct IeeeFormat {
    pub exp_bits: u32,
    pub man_bits: u32,
}

impl IeeeFormat {
    pub fn new(exp_bits: u32, man_bits: u32) -> Self {
        assert!((2..=11).contains(&exp_bits), "exp_bits out of range");
        assert!((1..=52).contains(&man_bits), "man_bits out of range");
        Self { exp_bits, man_bits }
    }

    /// binary16: e=5, m=10.
    pub fn fp16() -> Self {
        Self::new(5, 10)
    }

    /// binary32: e=8, m=23.
    pub fn fp32() -> Self {
        Self::new(8, 23)
    }

    /// bfloat16: e=8, m=7 (useful for ablations).
    pub fn bf16() -> Self {
        Self::new(8, 7)
    }

    #[inline]
    pub fn width(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    #[inline]
    pub fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Smallest normal scale (unbiased exponent of min normal).
    #[inline]
    pub fn e_min(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest finite scale.
    #[inline]
    pub fn e_max(&self) -> i32 {
        self.bias()
    }

    #[inline]
    fn man_mask(&self) -> u64 {
        (1u64 << self.man_bits) - 1
    }

    #[inline]
    fn exp_mask(&self) -> u64 {
        (1u64 << self.exp_bits) - 1
    }

    /// Canonical quiet NaN pattern.
    pub fn nan_bits(&self) -> u64 {
        (self.exp_mask() << self.man_bits) | (1u64 << (self.man_bits - 1))
    }

    pub fn inf_bits(&self, sign: bool) -> u64 {
        let mag = self.exp_mask() << self.man_bits;
        if sign {
            mag | (1u64 << (self.width() - 1))
        } else {
            mag
        }
    }

    pub fn zero_bits(&self, sign: bool) -> u64 {
        if sign {
            1u64 << (self.width() - 1)
        } else {
            0
        }
    }

    /// Largest finite magnitude pattern (sign = false).
    pub fn max_finite_bits(&self) -> u64 {
        ((self.exp_mask() - 1) << self.man_bits) | self.man_mask()
    }
}

/// Decoded IEEE value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpClass {
    Zero { sign: bool },
    Inf { sign: bool },
    NaN,
    /// normalized: `(-1)^sign · 2^scale · sig/2^fb` with `sig >> fb == 1`
    /// (subnormals arrive here normalized too)
    Finite { sign: bool, scale: i32, sig: u64, fb: u32 },
}

/// Decode an IEEE pattern.
pub fn fp_decode(bits: u64, fmt: IeeeFormat) -> FpClass {
    let sign = (bits >> (fmt.width() - 1)) & 1 == 1;
    let exp = (bits >> fmt.man_bits) & fmt.exp_mask();
    let man = bits & fmt.man_mask();
    if exp == fmt.exp_mask() {
        return if man == 0 { FpClass::Inf { sign } } else { FpClass::NaN };
    }
    if exp == 0 {
        if man == 0 {
            return FpClass::Zero { sign };
        }
        // subnormal: value = man · 2^(e_min − m); normalize
        let msb = 63 - man.leading_zeros();
        return FpClass::Finite { sign, scale: fmt.e_min() - (fmt.man_bits - msb) as i32, sig: man, fb: msb };
    }
    FpClass::Finite {
        sign,
        scale: exp as i32 - fmt.bias(),
        sig: (1u64 << fmt.man_bits) | man,
        fb: fmt.man_bits,
    }
}

/// Encode a normalized (sign, scale, sig, fb, sticky) with IEEE RNE,
/// gradual underflow and overflow-to-infinity.
pub fn fp_encode(sign: bool, scale: i32, sig: u128, fb: u32, sticky: bool, fmt: IeeeFormat) -> u64 {
    debug_assert!(sig >> fb == 1, "significand not normalized");
    let m = fmt.man_bits;

    // target fraction width: m for normals; fewer for subnormals
    let target_fb: i64 = if scale >= fmt.e_min() { m as i64 } else { m as i64 - (fmt.e_min() - scale) as i64 };

    // round sig from fb to target_fb fraction bits (RNE with sticky)
    let (rounded, carry_scale): (u64, i32) = if target_fb >= fb as i64 {
        ((sig << (target_fb - fb as i64)) as u64, 0)
    } else {
        let drop = (fb as i64 - target_fb) as u32;
        if drop >= 127 {
            // everything rounds away; value can never reach half of the
            // smallest representable step
            let r = 0u64;
            let _ = r;
            return fmt.zero_bits(sign);
        }
        let keep = (sig >> drop) as u64;
        let round = (sig >> (drop - 1)) & 1 == 1;
        let low_sticky = (sig & ((1u128 << (drop - 1)) - 1)) != 0 || sticky;
        let mut r = keep;
        if round && (low_sticky || (keep & 1) == 1) {
            r += 1;
        }
        // carry out of the significand width?
        if scale >= fmt.e_min() && r >> (m + 1) == 1 {
            (r >> 1, 1)
        } else {
            (r, 0)
        }
    };
    let scale = scale + carry_scale;

    if scale >= fmt.e_min() {
        // normal (or became normal after carry)
        if scale > fmt.e_max() {
            return fmt.inf_bits(sign); // overflow → ±∞ under RNE
        }
        debug_assert!(rounded >> m == 1, "normal significand must have hidden bit");
        let biased = (scale + fmt.bias()) as u64;
        let mag = (biased << m) | (rounded & fmt.man_mask());
        mag | ((sign as u64) << (fmt.width() - 1))
    } else {
        // subnormal result (rounded has ≤ m bits; may have carried up to 2^m,
        // in which case it *is* the smallest normal)
        if rounded >> m == 1 {
            let mag = 1u64 << m; // biased exponent 1, mantissa 0
            return mag | ((sign as u64) << (fmt.width() - 1));
        }
        if rounded == 0 {
            return fmt.zero_bits(sign);
        }
        rounded | ((sign as u64) << (fmt.width() - 1))
    }
}

/// Exact value as f64 (exact whenever m ≤ 52, e ≤ 11).
pub fn fp_to_f64(bits: u64, fmt: IeeeFormat) -> f64 {
    match fp_decode(bits, fmt) {
        FpClass::Zero { sign } => {
            if sign {
                -0.0
            } else {
                0.0
            }
        }
        FpClass::Inf { sign } => {
            if sign {
                f64::NEG_INFINITY
            } else {
                f64::INFINITY
            }
        }
        FpClass::NaN => f64::NAN,
        FpClass::Finite { sign, scale, sig, fb } => {
            let v = sig as f64 * 2f64.powi(scale - fb as i32);
            if sign {
                -v
            } else {
                v
            }
        }
    }
}

/// Correctly-rounded conversion from f64.
pub fn fp_from_f64(v: f64, fmt: IeeeFormat) -> u64 {
    if v.is_nan() {
        return fmt.nan_bits();
    }
    if v.is_infinite() {
        return fmt.inf_bits(v < 0.0);
    }
    if v == 0.0 {
        return fmt.zero_bits(v.is_sign_negative());
    }
    let bits = v.to_bits();
    let sign = bits >> 63 == 1;
    let biased = ((bits >> 52) & 0x7FF) as i32;
    let man = bits & ((1u64 << 52) - 1);
    let (scale, sig, fb) = if biased == 0 {
        let msb = 63 - man.leading_zeros();
        (msb as i32 - 1074, man as u128, msb)
    } else {
        (biased - 1023, ((1u64 << 52) | man) as u128, 52)
    };
    fp_encode(sign, scale, sig, fb, false, fmt)
}

/// Correctly-rounded multiplication (one rounding).
pub fn fp_mul(a: u64, b: u64, fmt: IeeeFormat) -> u64 {
    use FpClass::*;
    match (fp_decode(a, fmt), fp_decode(b, fmt)) {
        (NaN, _) | (_, NaN) => fmt.nan_bits(),
        (Inf { .. }, Zero { .. }) | (Zero { .. }, Inf { .. }) => fmt.nan_bits(),
        (Inf { sign: s1 }, Inf { sign: s2 }) => fmt.inf_bits(s1 ^ s2),
        (Inf { sign: s1 }, Finite { sign: s2, .. }) | (Finite { sign: s1, .. }, Inf { sign: s2 }) => {
            fmt.inf_bits(s1 ^ s2)
        }
        (Zero { sign: s1 }, Zero { sign: s2 })
        | (Zero { sign: s1 }, Finite { sign: s2, .. })
        | (Finite { sign: s1, .. }, Zero { sign: s2 }) => fmt.zero_bits(s1 ^ s2),
        (Finite { sign: s1, scale: e1, sig: m1, fb: f1 }, Finite { sign: s2, scale: e2, sig: m2, fb: f2 }) => {
            let sig = (m1 as u128) * (m2 as u128);
            let fb = f1 + f2;
            let msb = 127 - sig.leading_zeros();
            let scale = e1 + e2 + msb as i32 - fb as i32;
            fp_encode(s1 ^ s2, scale, sig, msb, false, fmt)
        }
    }
}

/// Correctly-rounded addition (one rounding).
pub fn fp_add(a: u64, b: u64, fmt: IeeeFormat) -> u64 {
    use FpClass::*;
    match (fp_decode(a, fmt), fp_decode(b, fmt)) {
        (NaN, _) | (_, NaN) => fmt.nan_bits(),
        (Inf { sign: s1 }, Inf { sign: s2 }) => {
            if s1 == s2 {
                fmt.inf_bits(s1)
            } else {
                fmt.nan_bits()
            }
        }
        (Inf { sign }, _) | (_, Inf { sign }) => fmt.inf_bits(sign),
        (Zero { sign: s1 }, Zero { sign: s2 }) => fmt.zero_bits(s1 && s2),
        (Zero { .. }, f @ Finite { .. }) | (f @ Finite { .. }, Zero { .. }) => {
            let Finite { sign, scale, sig, fb } = f else { unreachable!() };
            fp_encode(sign, scale, sig as u128, fb, false, fmt)
        }
        (Finite { sign: s1, scale: e1, sig: m1, fb: f1 }, Finite { sign: s2, scale: e2, sig: m2, fb: f2 }) => {
            add_sig(s1, e1, m1 as u128, f1, s2, e2, m2 as u128, f2, fmt)
        }
    }
}

/// Correctly-rounded fused multiply-add `a·b + c` (one rounding) — the
/// FPnew FMA baseline semantics.
pub fn fp_fma(a: u64, b: u64, c: u64, fmt: IeeeFormat) -> u64 {
    use FpClass::*;
    let (da, db, dc) = (fp_decode(a, fmt), fp_decode(b, fmt), fp_decode(c, fmt));
    if matches!(da, NaN) || matches!(db, NaN) || matches!(dc, NaN) {
        return fmt.nan_bits();
    }
    // product classification
    let prod: Result<(bool, i32, u128, u32), FpClass> = match (da, db) {
        (NaN, _) | (_, NaN) => unreachable!("NaN handled above"),
        (Inf { .. }, Zero { .. }) | (Zero { .. }, Inf { .. }) => return fmt.nan_bits(),
        (Inf { sign: s1 }, Inf { sign: s2 }) => Err(Inf { sign: s1 ^ s2 }),
        (Inf { sign: s1 }, Finite { sign: s2, .. }) | (Finite { sign: s1, .. }, Inf { sign: s2 }) => {
            Err(Inf { sign: s1 ^ s2 })
        }
        (Zero { sign: s1 }, Zero { sign: s2 })
        | (Zero { sign: s1 }, Finite { sign: s2, .. })
        | (Finite { sign: s1, .. }, Zero { sign: s2 }) => Err(Zero { sign: s1 ^ s2 }),
        (Finite { sign: s1, scale: e1, sig: m1, fb: f1 }, Finite { sign: s2, scale: e2, sig: m2, fb: f2 }) => {
            let sig = (m1 as u128) * (m2 as u128);
            let msb = 127 - sig.leading_zeros();
            Ok((s1 ^ s2, e1 + e2 + msb as i32 - (f1 + f2) as i32, sig, msb))
        }
    };
    match (prod, dc) {
        (Err(Inf { sign: sp }), Inf { sign: sc }) => {
            if sp == sc {
                fmt.inf_bits(sp)
            } else {
                fmt.nan_bits()
            }
        }
        (Err(Inf { sign }), _) => fmt.inf_bits(sign),
        (Ok(_), Inf { sign }) => fmt.inf_bits(sign),
        (Err(Zero { sign: sp }), Zero { sign: sc }) => fmt.zero_bits(sp && sc),
        (Err(Zero { .. }), Finite { sign, scale, sig, fb }) => fp_encode(sign, scale, sig as u128, fb, false, fmt),
        (Ok((sp, ep, mp, fp_)), Zero { .. }) => fp_encode(sp, ep, mp, fp_, false, fmt),
        (Ok((sp, ep, mp, fp_)), Finite { sign: sc, scale: ec, sig: mc, fb: fc }) => {
            add_sig(sp, ep, mp, fp_, sc, ec, mc as u128, fc, fmt)
        }
        // Zero-product + Inf addend → the addend
        (Err(Zero { .. }), Inf { sign }) => fmt.inf_bits(sign),
        // NaN operands returned early; Ok product is Finite by construction
        (_, NaN) | (Err(NaN), _) | (Err(Finite { .. }), _) => unreachable!("handled above"),
    }
}

/// Exact signed addition of two normalized significands, one IEEE
/// rounding. Same alignment-with-sticky strategy as
/// `posit::arith::add_fields`, including the borrow-bias correction for
/// effective subtraction.
#[allow(clippy::too_many_arguments)]
fn add_sig(s1: bool, e1: i32, m1: u128, f1: u32, s2: bool, e2: i32, m2: u128, f2: u32, fmt: IeeeFormat) -> u64 {
    let (s1, e1, m1, f1, s2, e2, m2, f2) =
        if e1 >= e2 { (s1, e1, m1, f1, s2, e2, m2, f2) } else { (s2, e2, m2, f2, s1, e1, m1, f1) };
    let fmax = f1.max(f2);
    let a1 = m1 << (fmax - f1);
    let a2 = m2 << (fmax - f2);
    let diff = (e1 - e2) as u32;
    let headroom = a1.leading_zeros().saturating_sub(1);
    let (lhs, rhs, grid_fb, sticky) = if diff <= headroom {
        (a1 << diff, a2, fmax + diff, false)
    } else {
        let up = headroom;
        let down = diff - up;
        let lhs = a1 << up;
        if down >= 127 {
            (lhs, 0u128, fmax + up, m2 != 0)
        } else {
            let sticky = a2 & ((1u128 << down) - 1) != 0;
            (lhs, a2 >> down, fmax + up, sticky)
        }
    };
    let (sum_sign, sum_mag) = if s1 == s2 {
        (s1, lhs + rhs)
    } else if lhs >= rhs {
        (s1, lhs - rhs)
    } else {
        (s2, rhs - lhs)
    };
    let (sum_mag, sticky) = if sticky && s1 != s2 { (sum_mag - 1, true) } else { (sum_mag, sticky) };
    if sum_mag == 0 {
        // exact cancellation: IEEE says +0 under RNE (unless both negative)
        return fmt.zero_bits(s1 && s2);
    }
    let msb = 127 - sum_mag.leading_zeros();
    let scale = e1 + msb as i32 - grid_fb as i32;
    fp_encode(sum_sign, scale, sum_mag, msb, sticky, fmt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Rng};

    #[test]
    fn format_constants() {
        let h = IeeeFormat::fp16();
        assert_eq!(h.width(), 16);
        assert_eq!(h.bias(), 15);
        assert_eq!(h.e_min(), -14);
        assert_eq!(h.e_max(), 15);
        assert_eq!(fp_to_f64(h.max_finite_bits(), h), 65504.0);
        let s = IeeeFormat::fp32();
        assert_eq!(s.bias(), 127);
        assert_eq!(fp_to_f64(s.max_finite_bits(), s), f32::MAX as f64);
    }

    /// Every FP16 pattern must round-trip exactly through f64 — compared
    /// against Rust's native f16-via-f32 semantics would need unstable
    /// features, so we check against the IEEE definition directly.
    #[test]
    fn fp16_roundtrip_exhaustive() {
        let h = IeeeFormat::fp16();
        for bits in 0..=0xFFFFu64 {
            let v = fp_to_f64(bits, h);
            if v.is_nan() {
                assert_eq!(fp_from_f64(v, h), h.nan_bits());
                continue;
            }
            let back = fp_from_f64(v, h);
            assert_eq!(back, bits, "bits={bits:#06x} v={v}");
        }
    }

    #[test]
    fn fp32_roundtrip_matches_native_f32() {
        let s = IeeeFormat::fp32();
        let mut rng = Rng::seeded(0x32);
        for _ in 0..50_000 {
            let raw = rng.next_u64() as u32;
            let native = f32::from_bits(raw);
            if native.is_nan() {
                continue;
            }
            assert_eq!(fp_to_f64(raw as u64, s), native as f64, "decode {raw:#x}");
            // and conversion from arbitrary f64 must equal native rounding
            let v = rng.normal_ms(0.0, 1e3);
            assert_eq!(fp_from_f64(v, s), (v as f32).to_bits() as u64, "from_f64 {v}");
        }
    }

    /// FP16 add/mul vs the f64 oracle. One f64 op on FP16 operands is
    /// exact, so rounding the f64 result once = correctly rounded.
    #[test]
    fn fp16_add_mul_vs_f64_oracle() {
        let h = IeeeFormat::fp16();
        check("fp16 ops == f64 oracle", 0x16, 200_000, |rng, _| {
            let a = rng.next_u64() & 0xFFFF;
            let b = rng.next_u64() & 0xFFFF;
            let (va, vb) = (fp_to_f64(a, h), fp_to_f64(b, h));
            if va.is_nan() || vb.is_nan() {
                return;
            }
            let sum = fp_add(a, b, h);
            let want_sum = fp_from_f64(va + vb, h);
            // ±0 sign subtleties: compare values, and bits when nonzero
            if fp_to_f64(sum, h) != 0.0 || fp_to_f64(want_sum, h) != 0.0 {
                assert_eq!(sum, want_sum, "{va} + {vb}");
            }
            let prod = fp_mul(a, b, h);
            let want_prod = fp_from_f64(va * vb, h);
            if fp_to_f64(prod, h) != 0.0 || fp_to_f64(want_prod, h) != 0.0 {
                assert_eq!(prod, want_prod, "{va} · {vb}");
            }
        });
    }

    /// FP32 mul vs f64 oracle (a single f64 product of two f32s is exact).
    #[test]
    fn fp32_mul_vs_f64_oracle() {
        let s = IeeeFormat::fp32();
        check("fp32 mul == f64 oracle", 0x33, 100_000, |rng, _| {
            let a = (rng.next_u64() as u32) as u64;
            let b = (rng.next_u64() as u32) as u64;
            let (va, vb) = (fp_to_f64(a, s), fp_to_f64(b, s));
            if va.is_nan() || vb.is_nan() {
                return;
            }
            let got = fp_mul(a, b, s);
            let want = ((va as f32) * (vb as f32)) as f64; // native f32 mul
            let got_v = fp_to_f64(got, s);
            if want.is_nan() {
                assert!(got_v.is_nan());
            } else if want != 0.0 || got_v != 0.0 {
                assert_eq!(got_v, want, "{va} · {vb}");
            }
        });
    }

    /// FP32 add vs native f32 (native f32 + is correctly rounded).
    #[test]
    fn fp32_add_vs_native() {
        let s = IeeeFormat::fp32();
        check("fp32 add == native", 0x34, 100_000, |rng, _| {
            let a = (rng.next_u64() as u32) as u64;
            let b = (rng.next_u64() as u32) as u64;
            let (va, vb) = (fp_to_f64(a, s), fp_to_f64(b, s));
            if va.is_nan() || vb.is_nan() {
                return;
            }
            let got = fp_to_f64(fp_add(a, b, s), s);
            let want = ((va as f32) + (vb as f32)) as f64;
            if want.is_nan() {
                assert!(got.is_nan());
            } else if want != 0.0 || got != 0.0 {
                assert_eq!(got, want, "{va} + {vb}");
            }
        });
    }

    /// FP32 fma vs native f32::mul_add (hardware-correct single rounding).
    #[test]
    fn fp32_fma_vs_native() {
        let s = IeeeFormat::fp32();
        check("fp32 fma == native mul_add", 0x35, 100_000, |rng, _| {
            let a = (rng.next_u64() as u32) as u64;
            let b = (rng.next_u64() as u32) as u64;
            let c = (rng.next_u64() as u32) as u64;
            let (va, vb, vc) = (fp_to_f64(a, s), fp_to_f64(b, s), fp_to_f64(c, s));
            if va.is_nan() || vb.is_nan() || vc.is_nan() {
                return;
            }
            let got = fp_to_f64(fp_fma(a, b, c, s), s);
            let want = ((va as f32).mul_add(vb as f32, vc as f32)) as f64;
            if want.is_nan() {
                assert!(got.is_nan(), "{va}·{vb}+{vc}: got {got}");
            } else if want != 0.0 || got != 0.0 {
                assert_eq!(got, want, "{va}·{vb}+{vc}");
            }
        });
    }

    #[test]
    fn overflow_to_infinity() {
        let h = IeeeFormat::fp16();
        let max = h.max_finite_bits();
        assert_eq!(fp_add(max, max, h), h.inf_bits(false));
        assert_eq!(fp_mul(max, max, h), h.inf_bits(false));
        // 65504 + 8 = 65512 < the 65520 overflow midpoint → stays maxfinite;
        // 65504 + 16 = 65520 is the exact tie and RNE's "even" neighbour is
        // the (overflowing) 2^16 → rounds to +inf; +32 overflows outright
        let v8 = fp_from_f64(8.0, h);
        assert_eq!(fp_add(max, v8, h), max);
        let v16 = fp_from_f64(16.0, h);
        assert_eq!(fp_add(max, v16, h), h.inf_bits(false));
        let v32 = fp_from_f64(32.0, h);
        assert_eq!(fp_add(max, v32, h), h.inf_bits(false));
    }

    #[test]
    fn gradual_underflow() {
        let h = IeeeFormat::fp16();
        // min subnormal = 2^-24
        let min_sub = 1u64;
        assert_eq!(fp_to_f64(min_sub, h), 2f64.powi(-24));
        // half of it rounds to zero (RNE, tie to even=0)
        assert_eq!(fp_from_f64(2f64.powi(-25), h), 0);
        // three quarters rounds up to min subnormal
        assert_eq!(fp_from_f64(1.5 * 2f64.powi(-25), h), min_sub);
        // subnormal × 2 stays exact
        assert_eq!(fp_to_f64(fp_mul(min_sub, fp_from_f64(2.0, h), h), h), 2f64.powi(-23));
    }

    #[test]
    fn special_value_semantics() {
        let h = IeeeFormat::fp16();
        let inf = h.inf_bits(false);
        let ninf = h.inf_bits(true);
        let one = fp_from_f64(1.0, h);
        let zero = h.zero_bits(false);
        assert_eq!(fp_add(inf, ninf, h), h.nan_bits());
        assert_eq!(fp_add(inf, one, h), inf);
        assert_eq!(fp_mul(inf, zero, h), h.nan_bits());
        assert_eq!(fp_mul(ninf, one, h), ninf);
        assert_eq!(fp_fma(inf, zero, one, h), h.nan_bits());
        assert_eq!(fp_fma(one, one, ninf, h), ninf);
        // NaN propagates everywhere
        for op in [fp_add(h.nan_bits(), one, h), fp_mul(one, h.nan_bits(), h), fp_fma(one, one, h.nan_bits(), h)] {
            assert_eq!(op, h.nan_bits());
        }
    }
}
