//! The common interface every dot-product architecture in Table I
//! implements, plus the scalar-arithmetic backends (posit and IEEE) the
//! discrete architectures are assembled from.
//!
//! `DotArch::dot_f64` is the experiment-facing contract: take an FP64
//! accumulator and FP64 input vectors (the paper's reference
//! representation), quantize to the unit's input format, run the
//! architecture's exact internal dataflow — including every intermediate
//! rounding it performs in hardware — and return the FP64 reading of the
//! output. Accuracy experiments compare that against the FP64 reference.

use crate::posit::{p_add, p_fma, p_mul, Posit, PositFormat};

use super::ieee::{fp_add, fp_fma, fp_from_f64, fp_mul, fp_to_f64, IeeeFormat};

/// A dot-product architecture under evaluation.
pub trait DotArch {
    /// Row label, e.g. "PDPU P(13/16,2) N=4 Wm=14".
    fn name(&self) -> String;

    /// Dot-product chunk size N (1 for FMA units).
    fn chunk(&self) -> usize;

    /// `acc + Σ aᵢ·bᵢ` over arbitrary-length vectors with this
    /// architecture's quantization and internal rounding behaviour.
    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64;

    /// Batched dot products (a GEMM tile): `w` holds `rows` weight vectors
    /// of length `k` (row-major) and `x` holds `cols` activation vectors
    /// of length `k` (row-major — i.e. the transposed right-hand matrix,
    /// which is exactly the im2col patch-matrix layout). Returns
    /// `rows·cols` values, row-major:
    ///
    /// ```text
    /// out[r·cols + c] = dot_f64(acc[r], w[r·k..], x[c·k..])
    /// ```
    ///
    /// The default implementation is the scalar loop above, so every
    /// architecture keeps its exact numerical behaviour; fused units that
    /// can do better (see [`crate::engine`]) override it with a batched
    /// path that MUST stay bit-identical to this default — that
    /// equivalence is property-tested in `rust/tests/engine_equivalence.rs`.
    ///
    /// # Examples
    ///
    /// One batched tile equals the scalar loop element-for-element:
    ///
    /// ```
    /// use pdpu::baselines::{DotArch, PdpuArch};
    /// use pdpu::pdpu::PdpuConfig;
    ///
    /// let arch = PdpuArch::new(PdpuConfig::paper_default());
    /// // one weight row (k=2) against two right-hand vectors
    /// let out = arch.dot_batch(&[0.0], &[1.0, 2.0], &[3.0, 4.0, 0.5, -1.0], 2);
    /// assert_eq!(out.len(), 2);
    /// assert_eq!(out[0], arch.dot_f64(0.0, &[1.0, 2.0], &[3.0, 4.0]));
    /// assert_eq!(out[1], arch.dot_f64(0.0, &[1.0, 2.0], &[0.5, -1.0]));
    /// ```
    fn dot_batch(&self, acc: &[f64], w: &[f64], x: &[f64], k: usize) -> Vec<f64> {
        assert!(k > 0, "inner dimension k must be positive");
        assert_eq!(w.len() % k, 0, "w length {} not a multiple of k={k}", w.len());
        assert_eq!(x.len() % k, 0, "x length {} not a multiple of k={k}", x.len());
        let rows = w.len() / k;
        let cols = x.len() / k;
        assert_eq!(acc.len(), rows, "one accumulator seed per output row");
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let wrow = &w[r * k..(r + 1) * k];
            for c in 0..cols {
                out.push(self.dot_f64(acc[r], wrow, &x[c * k..(c + 1) * k]));
            }
        }
        out
    }
}

/// Scalar multiply/add/fma in some number system — the building block of
/// the *discrete* architectures (Fig. 1), which round after every op.
pub trait ScalarArith {
    /// Opaque value representation (a bit pattern).
    type V: Copy + std::fmt::Debug;
    fn quant_in(&self, v: f64) -> Self::V;
    fn quant_acc(&self, v: f64) -> Self::V;
    fn to_f64(&self, v: Self::V) -> f64;
    /// rounded multiply of two input-format values into the wide format
    fn mul(&self, a: Self::V, b: Self::V) -> Self::V;
    /// rounded add of two wide-format values
    fn add(&self, x: Self::V, y: Self::V) -> Self::V;
    /// single-rounding fused multiply-add (inputs in input format, addend
    /// and result in wide format)
    fn fma(&self, a: Self::V, b: Self::V, c: Self::V) -> Self::V;
    fn describe(&self) -> String;
}

/// Posit scalar backend, mixed precision: inputs in `in_fmt`,
/// products/sums/acc in `out_fmt` (the PACoGen-style discrete units).
#[derive(Clone, Copy, Debug)]
pub struct PositArith {
    pub in_fmt: PositFormat,
    pub out_fmt: PositFormat,
}

impl ScalarArith for PositArith {
    type V = Posit;

    fn quant_in(&self, v: f64) -> Posit {
        Posit::from_f64(v, self.in_fmt)
    }

    fn quant_acc(&self, v: f64) -> Posit {
        Posit::from_f64(v, self.out_fmt)
    }

    fn to_f64(&self, v: Posit) -> f64 {
        v.to_f64()
    }

    fn mul(&self, a: Posit, b: Posit) -> Posit {
        p_mul(a, b, self.out_fmt)
    }

    fn add(&self, x: Posit, y: Posit) -> Posit {
        p_add(x, y, self.out_fmt)
    }

    fn fma(&self, a: Posit, b: Posit, c: Posit) -> Posit {
        p_fma(a, b, c, self.out_fmt)
    }

    fn describe(&self) -> String {
        if self.in_fmt == self.out_fmt {
            format!("{}", self.in_fmt)
        } else {
            format!("P({}/{},{})", self.in_fmt.n(), self.out_fmt.n(), self.in_fmt.es())
        }
    }
}

/// IEEE-754 scalar backend (uniform precision, FPnew-style).
#[derive(Clone, Copy, Debug)]
pub struct IeeeArith {
    pub fmt: IeeeFormat,
}

impl ScalarArith for IeeeArith {
    type V = u64;

    fn quant_in(&self, v: f64) -> u64 {
        fp_from_f64(v, self.fmt)
    }

    fn quant_acc(&self, v: f64) -> u64 {
        fp_from_f64(v, self.fmt)
    }

    fn to_f64(&self, v: u64) -> f64 {
        fp_to_f64(v, self.fmt)
    }

    fn mul(&self, a: u64, b: u64) -> u64 {
        fp_mul(a, b, self.fmt)
    }

    fn add(&self, x: u64, y: u64) -> u64 {
        fp_add(x, y, self.fmt)
    }

    fn fma(&self, a: u64, b: u64, c: u64) -> u64 {
        fp_fma(a, b, c, self.fmt)
    }

    fn describe(&self) -> String {
        match (self.fmt.exp_bits, self.fmt.man_bits) {
            (5, 10) => "FP16".into(),
            (8, 23) => "FP32".into(),
            (8, 7) => "BF16".into(),
            (e, m) => format!("FP(e{e},m{m})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn posit_arith_quantizes_by_role() {
        let ar = PositArith { in_fmt: PositFormat::p(13, 2), out_fmt: PositFormat::p(16, 2) };
        assert_eq!(ar.quant_in(1.0).format(), PositFormat::p(13, 2));
        assert_eq!(ar.quant_acc(1.0).format(), PositFormat::p(16, 2));
        let p = ar.mul(ar.quant_in(3.0), ar.quant_in(4.0));
        assert_eq!(p.format(), PositFormat::p(16, 2));
        assert_eq!(ar.to_f64(p), 12.0);
        assert_eq!(ar.describe(), "P(13/16,2)");
    }

    #[test]
    fn ieee_arith_roundtrip() {
        let ar = IeeeArith { fmt: IeeeFormat::fp16() };
        assert_eq!(ar.to_f64(ar.quant_in(1.5)), 1.5);
        assert_eq!(ar.to_f64(ar.fma(ar.quant_in(2.0), ar.quant_in(3.0), ar.quant_in(4.0))), 10.0);
        assert_eq!(ar.describe(), "FP16");
        assert_eq!(IeeeArith { fmt: IeeeFormat::fp32() }.describe(), "FP32");
    }
}
