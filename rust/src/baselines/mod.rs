//! Every dot-product architecture of Table I, behind one [`DotArch`]
//! interface.
//!
//! * [`ieee`] — bit-exact IEEE-754 arithmetic of any (e,m): the FPnew
//!   substrate.
//! * [`arch`] — the `DotArch` evaluation interface and the posit/IEEE
//!   scalar backends.
//! * [`discrete`] — Fig. 1(a) multiplier+adder-tree DPUs (PACoGen / FPnew
//!   DPU rows) and Fig. 1(b) FMA cascades (FPnew FMA / posit FMA rows).
//! * [`fused`] — the proposed PDPU and the quire PDPU as `DotArch` rows.
//!
//! [`table1_units`] assembles the full line-up exactly as the paper's
//! Table I lists it.

pub mod arch;
pub mod discrete;
pub mod fused;
pub mod ieee;

pub use arch::{DotArch, IeeeArith, PositArith, ScalarArith};
pub use discrete::{FmaCascadeDpu, MulAddTreeDpu};
pub use fused::{PdpuArch, QuirePdpuArch};
pub use ieee::IeeeFormat;

use crate::pdpu::PdpuConfig;
use crate::posit::PositFormat;

/// The full Table I line-up, in row order.
pub fn table1_units() -> Vec<Box<dyn DotArch>> {
    let p16 = PositFormat::p(16, 2);
    vec![
        // FPnew DPU [35]: FP32 and FP16, N=4
        Box::new(MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 4, "FPnew DPU")),
        Box::new(MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 4, "FPnew DPU")),
        // PACoGen DPU [13]: P(16,2), N=4 (discrete posit mul + add tree)
        Box::new(MulAddTreeDpu::new(PositArith { in_fmt: p16, out_fmt: p16 }, 4, "PACoGen DPU")),
        // Proposed PDPU, five configurations
        Box::new(PdpuArch::new(PdpuConfig::uniform(16, 2, 4, 14).unwrap())),
        Box::new(PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap())),
        Box::new(PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap())),
        Box::new(PdpuArch::new(PdpuConfig::mixed(10, 16, 2, 8, 14).unwrap())),
        Box::new(PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 8, 10).unwrap())),
        // Quire PDPU: P(13/16,2), N=4, Wm = quire width (~256)
        Box::new(QuirePdpuArch::new(PositFormat::p(13, 2), p16, 4)),
        // FPnew FMA [35]: FP32 and FP16, single MAC
        Box::new(FmaCascadeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 1, "FPnew FMA")),
        Box::new(FmaCascadeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 1, "FPnew FMA")),
        // Posit FMA [17]: P(16,2), single MAC
        Box::new(FmaCascadeDpu::new(PositArith { in_fmt: p16, out_fmt: p16 }, 1, "Posit FMA")),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lineup_matches_paper_rows() {
        let units = table1_units();
        assert_eq!(units.len(), 12);
        let names: Vec<String> = units.iter().map(|u| u.name()).collect();
        assert_eq!(names[0], "FPnew DPU FP32 N=4");
        assert_eq!(names[1], "FPnew DPU FP16 N=4");
        assert_eq!(names[2], "PACoGen DPU P(16,2) N=4");
        assert_eq!(names[3], "PDPU P(16/16,2) N=4 Wm=14");
        assert_eq!(names[4], "PDPU P(13/16,2) N=4 Wm=14");
        assert_eq!(names[8], "Quire PDPU P(13/16,2) N=4");
        assert_eq!(names[11], "Posit FMA P(16,2) N=1");
    }

    #[test]
    fn all_units_compute_a_simple_dot() {
        let a = [1.0, 2.0, -1.5, 0.5, 3.0];
        let b = [2.0, 0.5, 2.0, 4.0, 1.0];
        let want = 2.0 + 1.0 - 3.0 + 2.0 + 3.0;
        for u in table1_units() {
            let got = u.dot_f64(0.0, &a, &b);
            assert_eq!(got, want, "{}", u.name());
        }
    }
}
