//! Batched GEMM/im2col execution engine for the PDPU array.
//!
//! The accuracy experiments and the serving stack both reduce to the same
//! computation: many chunked dot products of a small set of *weight* rows
//! against a large set of *activation* columns. Driving that through
//! scalar [`crate::baselines::DotArch::dot_f64`] calls (the seed's path)
//! re-quantizes and re-decodes the same weight row once per output pixel
//! and allocates fresh inter-stage `Vec`s inside every pipeline stage.
//!
//! This module removes both costs while keeping the result **bit-exact**
//! with the scalar path:
//!
//! * [`PreparedOperands`] quantizes an f64 tensor to the input posit
//!   format and runs the S1 per-value decode **once**, storing each
//!   decoded operand as a lane-packed 64-bit word ([`PackedLane`]);
//!   every subsequent operation reuses the packed planes (the paper's S1
//!   decoders run once per value instead of once per use — exactly what
//!   a systolic deployment of PDPU would do with its stationary
//!   operand).
//! * [`BatchEngine::gemm_posit`] executes the whole output tile through a
//!   per-worker reusable [`DotScratch`], with **row-parallel** execution
//!   across `std::thread` workers and **column-blocked** (cache-tiled)
//!   loop order inside each worker. Every output element is an independent
//!   chunked accumulation, so results are deterministic and invariant to
//!   both the worker count and the tile width (property-tested in
//!   `rust/tests/engine_equivalence.rs`).
//!
//! Bit-exactness invariant: for every output element the engine computes
//! the *same* result as [`Pdpu::dot_chunked`] — for `N ≤`
//! [`MAX_FAST_LANES`] each chunk runs the lane-packed fused kernel
//! ([`crate::pdpu::lanes::dot_packed_chunk`]), which shares the scalar
//! stages' decode/alignment/normalize/encode definitions; wider N falls
//! back to the staged pipeline through [`product_term_packed`] /
//! [`crate::pdpu::stages::acc_term`]. Pre-decoding only hoists the pure
//! per-value posit decode out of the loop. The equivalence is enforced by
//! tests at three levels (stage, unit, GEMM) plus the exhaustive
//! conformance sweep in `rust/tests/conformance_exhaustive.rs`.

use crate::pdpu::lanes::{dot_packed_chunk, product_term_packed, PackedLane, MAX_FAST_LANES};
use crate::pdpu::stages::{acc_term, DecodedInputs, ProductTerm};
use crate::pdpu::{DotScratch, Pdpu, PdpuConfig};
use crate::posit::{Posit, PositFormat};

/// A matrix of operands quantized to a posit format and pre-decoded into
/// lane-packed S1 words ([`PackedLane`]), laid out as `rows` contiguous
/// vectors of length `k` (row-major).
///
/// For a conv layer this is built **once per layer** from the OIHW weight
/// tensor (rows = output channels, k = in_ch·kh·kw) and once per image
/// from the im2col patch matrix (rows = output pixels), then reused across
/// every output element.
///
/// # Examples
///
/// Prepare two operand planes once and run a batched GEMM tile:
///
/// ```
/// use pdpu::engine::{BatchEngine, PreparedOperands};
/// use pdpu::pdpu::PdpuConfig;
/// use pdpu::posit::Posit;
///
/// let cfg = PdpuConfig::paper_default();
/// // two weight rows of k=2, one right-hand vector of k=2
/// let w = PreparedOperands::quantize(cfg.in_fmt, &[1.0, 2.0, -0.5, 4.0], 2);
/// let x = PreparedOperands::quantize(cfg.in_fmt, &[3.0, 0.25], 2);
/// assert_eq!((w.rows(), w.k()), (2, 2));
///
/// let acc = vec![Posit::zero(cfg.out_fmt); w.rows()];
/// let out = BatchEngine::new(cfg).gemm_posit(&acc, &w, &x);
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[0].to_f64(), 1.0 * 3.0 + 2.0 * 0.25);
/// ```
#[derive(Clone, Debug)]
pub struct PreparedOperands {
    fmt: PositFormat,
    rows: usize,
    k: usize,
    elems: Vec<PackedLane>,
}

impl PreparedOperands {
    /// Quantize `data` (rows·k values, row-major) to `fmt` and pre-decode.
    pub fn quantize(fmt: PositFormat, data: &[f64], k: usize) -> Self {
        assert!(k > 0, "inner dimension k must be positive");
        assert_eq!(data.len() % k, 0, "data length {} not a multiple of k={k}", data.len());
        let elems = data.iter().map(|&v| PackedLane::from_posit(Posit::from_f64(v, fmt))).collect();
        Self { fmt, rows: data.len() / k, k, elems }
    }

    /// Pre-decode already-quantized posits (rows·k values, row-major).
    pub fn from_posits(fmt: PositFormat, posits: &[Posit], k: usize) -> Self {
        assert!(k > 0, "inner dimension k must be positive");
        assert_eq!(posits.len() % k, 0);
        debug_assert!(posits.iter().all(|p| p.format() == fmt));
        let elems = posits.iter().map(|&p| PackedLane::from_posit(p)).collect();
        Self { fmt, rows: posits.len() / k, k, elems }
    }

    /// Number of prepared operand vectors (matrix rows).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Inner (dot-product) dimension of every row.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// The posit format the operands were quantized to.
    #[inline]
    pub fn format(&self) -> PositFormat {
        self.fmt
    }

    /// Pre-decoded (lane-packed) row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[PackedLane] {
        &self.elems[r * self.k..(r + 1) * self.k]
    }

    /// Total packed lanes held (`rows · k`) — the memory-accounting unit
    /// used by the serving tier's plane cache.
    #[inline]
    pub fn elem_count(&self) -> usize {
        self.elems.len()
    }
}

/// Fuse one chunk's cached per-value decodes into the S1 record (the only
/// S1 work left is the per-chunk accumulator decode): `row`/`col` are the
/// chunk's live lanes (≤ `n` of them), zero-padded to `n` exactly as
/// `dot_chunked` pads. The staged fallback (`N > MAX_FAST_LANES`) and the
/// sampled profiling path both run this, so they execute the identical
/// S1 fill.
// pdpu-lint: hot-path
#[inline]
fn fill_s1_chunk(s1: &mut DecodedInputs, n: usize, acc: Posit, row: &[PackedLane], col: &[PackedLane]) {
    s1.products.clear();
    s1.products.reserve(n);
    let mut any_nar = false;
    for (&r, &c) in row.iter().zip(col.iter()) {
        let (term, nar) = product_term_packed(r, c);
        any_nar |= nar;
        s1.products.push(term);
    }
    for _ in row.len()..n {
        s1.products.push(ProductTerm { sign: false, e_ab: 0, ma: 0, mb: 0, zero: true });
    }
    let (at, nar) = acc_term(acc);
    any_nar |= nar;
    s1.acc = at;
    s1.any_nar = any_nar;
}

/// Below this many MACs (rows·cols·k) a tile runs sequentially in auto
/// mode: thread spawn/join would cost more than the dot products.
const AUTO_PARALLEL_MIN_MACS: usize = 16 * 1024;

/// Auto column-block sizing target: keep roughly this many pre-decoded
/// operand elements (the x-plane slice a worker revisits) live per tile,
/// so the block of right-hand vectors stays cache-resident while the
/// worker walks all of its rows.
const AUTO_TILE_TARGET_ELEMS: usize = 4096;

/// The batched executor: one PDPU configuration plus a worker-thread
/// policy and a column-blocking (tiling) policy.
///
/// `threads == 0` means "auto": scale to the available parallelism, but
/// run small tiles sequentially. An explicit `with_threads(n)` always
/// uses `n` workers (capped at the row count).
///
/// `col_block == 0` means "auto": size column blocks so one block of
/// pre-decoded right-hand vectors stays cache-resident while a worker
/// sweeps its rows. An explicit [`Self::with_col_block`] fixes the block
/// width. Tiling is a pure loop-order change — every output element is an
/// independent accumulation chain, so results are bit-identical for every
/// block width (property-tested in `rust/tests/engine_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct BatchEngine {
    unit: Pdpu,
    threads: usize,
    col_block: usize,
}

impl BatchEngine {
    /// Build an engine for one PDPU configuration with auto thread and
    /// tile policies.
    pub fn new(cfg: PdpuConfig) -> Self {
        Self { unit: Pdpu::new(cfg), threads: 0, col_block: 0 }
    }

    /// Fix the worker count (useful for benchmarking and for the
    /// thread-count-invariance property tests). `0` restores auto.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Fix the column-block (tile) width (useful for benchmarking the
    /// cache effect and for the block-invariance property tests). `0`
    /// restores auto sizing.
    pub fn with_col_block(mut self, cols: usize) -> Self {
        self.col_block = cols;
        self
    }

    /// The PDPU configuration this engine executes.
    #[inline]
    pub fn config(&self) -> &PdpuConfig {
        self.unit.config()
    }

    fn effective_threads(&self, rows: usize, cols: usize, k: usize) -> usize {
        let t = if self.threads > 0 {
            self.threads
        } else if rows * cols * k < AUTO_PARALLEL_MIN_MACS {
            1
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
        };
        t.clamp(1, rows.max(1))
    }

    fn effective_col_block(&self, cols: usize, k: usize) -> usize {
        let b = if self.col_block > 0 {
            self.col_block
        } else {
            (AUTO_TILE_TARGET_ELEMS / k.max(1)).max(8)
        };
        b.clamp(1, cols.max(1))
    }

    /// One chunked dot product over pre-decoded lane-packed planes:
    /// bit-identical to `Pdpu::dot_chunked(acc, row_posits, col_posits)`
    /// — same chunking, same zero-padded tail semantics, same single
    /// rounding per chunk.
    ///
    /// For `N ≤` [`MAX_FAST_LANES`] each chunk runs the fused
    /// lane-parallel kernel ([`crate::pdpu::lanes::dot_packed_chunk`]);
    /// short tails need no explicit padding because padding lanes
    /// contribute a zero addend and are excluded from `e_max`. Wider N
    /// falls back to the staged pipeline.
    ///
    /// When tracing is on, a 1-in-N thread-local probe
    /// ([`crate::obs::stages::probe`]) diverts the call through
    /// [`Self::dot_prepared_profiled`] — the staged stage sequence with
    /// per-stage timestamps, so the result stays bit-identical.
    // pdpu-lint: hot-path
    pub fn dot_prepared(
        &self,
        acc: Posit,
        row: &[PackedLane],
        col: &[PackedLane],
        scratch: &mut DotScratch,
    ) -> Posit {
        if crate::obs::stages::probe() {
            return self.dot_prepared_profiled(acc, row, col, scratch);
        }
        assert_eq!(row.len(), col.len(), "vector length mismatch");
        let cfg = self.unit.config();
        let n = cfg.n;
        let len = row.len();
        let mut acc = acc;
        let mut i = 0;
        if n <= MAX_FAST_LANES {
            while i < len {
                let m = (len - i).min(n);
                acc = dot_packed_chunk(cfg, acc, &row[i..i + m], &col[i..i + m], &mut scratch.lanes);
                i += n;
            }
            return acc;
        }
        while i < len {
            let m = (len - i).min(n);
            fill_s1_chunk(&mut scratch.s1, n, acc, &row[i..i + m], &col[i..i + m]);
            acc = self.unit.finish_from_s1(scratch);
            i += n;
        }
        acc
    }

    /// [`Self::dot_prepared`] with S1 / S2 / S3+S4 / S5+S6 wall-time
    /// accounting accumulated into [`crate::obs::stages`] (one sample per
    /// dot). Identical stage sequence, identical bits; only the sampled
    /// profiling path runs it, so it is deliberately *not* a lint-marked
    /// hot-path function.
    fn dot_prepared_profiled(
        &self,
        acc: Posit,
        row: &[PackedLane],
        col: &[PackedLane],
        scratch: &mut DotScratch,
    ) -> Posit {
        assert_eq!(row.len(), col.len(), "vector length mismatch");
        let n = self.unit.config().n;
        let len = row.len();
        let mut acc = acc;
        let (mut s1_ns, mut s2_ns, mut s34_ns, mut s56_ns) = (0u64, 0u64, 0u64, 0u64);
        let mut i = 0;
        while i < len {
            let m = (len - i).min(n);
            let t0 = crate::obs::clock::now();
            fill_s1_chunk(&mut scratch.s1, n, acc, &row[i..i + m], &col[i..i + m]);
            s1_ns += t0.elapsed().as_nanos() as u64;
            let (out, c2, c34, c56) = self.unit.finish_from_s1_profiled(scratch);
            acc = out;
            s2_ns += c2;
            s34_ns += c34;
            s56_ns += c56;
            i += n;
        }
        crate::obs::stages::add_sample(s1_ns, s2_ns, s34_ns, s56_ns);
        acc
    }

    /// Batched GEMM over prepared operands:
    /// `out[r·cols + c] = dot_chunked(acc[r], w.row(r), x.row(c))`,
    /// computed row-parallel across worker threads. `x` holds the
    /// right-hand vectors contiguously (i.e. it is the transposed B
    /// matrix / the im2col patch matrix).
    ///
    /// Each worker walks cache-sized **column blocks** instead of whole
    /// rows: for one block of right-hand vectors it sweeps every row it
    /// owns, so the block's pre-decoded planes stay hot across the sweep.
    ///
    /// Deterministic and invariant to both the worker count and the
    /// column-block width: every output element is an independent
    /// accumulation chain.
    pub fn gemm_posit(
        &self,
        acc: &[Posit],
        w: &PreparedOperands,
        x: &PreparedOperands,
    ) -> Vec<Posit> {
        assert_eq!(w.k, x.k, "inner dimensions must match ({} vs {})", w.k, x.k);
        assert_eq!(acc.len(), w.rows, "one accumulator seed per output row");
        let (rows, cols, k) = (w.rows, x.rows, w.k);
        let out_fmt = self.unit.config().out_fmt;
        let mut out = vec![Posit::zero(out_fmt); rows * cols];
        if rows == 0 || cols == 0 {
            return out;
        }
        let threads = self.effective_threads(rows, cols, k);
        let col_block = self.effective_col_block(cols, k);
        if threads == 1 {
            let mut scratch = DotScratch::for_config(self.unit.config());
            let mut c0 = 0;
            while c0 < cols {
                let c1 = (c0 + col_block).min(cols);
                for r in 0..rows {
                    let wrow = &w.elems[r * k..(r + 1) * k];
                    for c in c0..c1 {
                        out[r * cols + c] =
                            self.dot_prepared(acc[r], wrow, &x.elems[c * k..(c + 1) * k], &mut scratch);
                    }
                }
                c0 = c1;
            }
            self.observe_launch(acc, w, x, &out);
            return out;
        }
        let rows_per = rows.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, out_block) in out.chunks_mut(rows_per * cols).enumerate() {
                let r0 = t * rows_per;
                s.spawn(move || {
                    let mut scratch = DotScratch::for_config(self.unit.config());
                    let mut c0 = 0;
                    while c0 < cols {
                        let c1 = (c0 + col_block).min(cols);
                        for (ri, out_row) in out_block.chunks_mut(cols).enumerate() {
                            let r = r0 + ri;
                            let wrow = &w.elems[r * k..(r + 1) * k];
                            for (c, slot) in out_row[c0..c1].iter_mut().enumerate() {
                                let col = &x.elems[(c0 + c) * k..(c0 + c + 1) * k];
                                *slot = self.dot_prepared(acc[r], wrow, col, &mut scratch);
                            }
                        }
                        c0 = c1;
                    }
                });
            }
        });
        self.observe_launch(acc, w, x, &out);
        out
    }

    /// The single sanctioned numerics-attribution boundary: every engine
    /// launch passes through here exactly once, on the *caller's* thread
    /// (after worker join), so the thread-local site guard installed by
    /// the serving/training layers attributes the work correctly. Tallies
    /// output saturation/NaR plus operand/output scale histograms into
    /// the per-site registry, and — when the 1-in-N shadow probe fires —
    /// re-runs the launch in FP64 for error statistics. The shadow path
    /// only reads, so primary outputs are bit-identical either way.
    fn observe_launch(
        &self,
        acc: &[Posit],
        w: &PreparedOperands,
        x: &PreparedOperands,
        out: &[Posit],
    ) {
        crate::obs::numerics::record_launch(self.unit.config(), &w.elems, &x.elems, out);
        if crate::obs::shadow::probe() {
            crate::obs::shadow::shadow_gemm(self.unit.config(), acc, w, x, out);
        }
    }

    /// f64-facing convenience: quantize both operand matrices once, run
    /// the batched GEMM, read the outputs back as f64 — the batched
    /// equivalent of looping `DotArch::dot_f64`.
    pub fn gemm_f64(&self, acc: &[f64], w: &[f64], x: &[f64], k: usize) -> Vec<f64> {
        let cfg = self.unit.config();
        let wp = PreparedOperands::quantize(cfg.in_fmt, w, k);
        let xp = PreparedOperands::quantize(cfg.in_fmt, x, k);
        let accp: Vec<Posit> = acc.iter().map(|&v| Posit::from_f64(v, cfg.out_fmt)).collect();
        let outs = self.gemm_posit(&accp, &wp, &xp);
        outs.iter().map(|p| p.to_f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn rand_posit(rng: &mut Rng, fmt: PositFormat) -> Posit {
        Posit::from_bits(rng.next_u64() as u32 & fmt.mask(), fmt)
    }

    #[test]
    fn dot_prepared_matches_dot_chunked_bitwise() {
        for cfg in [
            PdpuConfig::paper_default(),
            PdpuConfig::uniform(16, 2, 1, 20).unwrap(),
            PdpuConfig::mixed(8, 16, 2, 8, 6).unwrap(),
        ] {
            let unit = Pdpu::new(cfg);
            let engine = BatchEngine::new(cfg);
            let mut rng = Rng::seeded(0x9E9);
            let mut scratch = DotScratch::new();
            for len in [0usize, 1, 3, 4, 5, 9, 147] {
                // full random patterns, NaR included: specials must agree too
                let a: Vec<Posit> = (0..len).map(|_| rand_posit(&mut rng, cfg.in_fmt)).collect();
                let b: Vec<Posit> = (0..len).map(|_| rand_posit(&mut rng, cfg.in_fmt)).collect();
                let acc = rand_posit(&mut rng, cfg.out_fmt);
                let pa: Vec<PackedLane> = a.iter().map(|&p| PackedLane::from_posit(p)).collect();
                let pb: Vec<PackedLane> = b.iter().map(|&p| PackedLane::from_posit(p)).collect();
                assert_eq!(
                    unit.dot_chunked(acc, &a, &b).bits(),
                    engine.dot_prepared(acc, &pa, &pb, &mut scratch).bits(),
                    "cfg={} len={len}",
                    cfg.label()
                );
            }
        }
    }

    #[test]
    fn gemm_matches_scalar_loop_bitwise() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let engine = BatchEngine::new(cfg).with_threads(3);
        let mut rng = Rng::seeded(0x6E3);
        let (rows, cols, k) = (5usize, 7usize, 11usize);
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let got = engine.gemm_f64(&acc, &w, &x, k);
        for r in 0..rows {
            for c in 0..cols {
                let qa: Vec<Posit> = w[r * k..(r + 1) * k].iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
                let qb: Vec<Posit> = x[c * k..(c + 1) * k].iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
                let want = unit
                    .dot_chunked(Posit::from_f64(acc[r], cfg.out_fmt), &qa, &qb)
                    .to_f64();
                assert_eq!(
                    got[r * cols + c].to_bits(),
                    want.to_bits(),
                    "out[{r},{c}] = {} want {want}",
                    got[r * cols + c]
                );
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cfg = PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap();
        let mut rng = Rng::seeded(0x7123);
        let (rows, cols, k) = (9usize, 6usize, 23usize);
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc = vec![0.0; rows];
        let one = BatchEngine::new(cfg).with_threads(1).gemm_f64(&acc, &w, &x, k);
        // explicit thread counts AND the auto policy must all agree
        for t in [0usize, 2, 3, 8, 64] {
            let many = BatchEngine::new(cfg).with_threads(t).gemm_f64(&acc, &w, &x, k);
            assert_eq!(one, many, "threads={t}");
        }
    }

    #[test]
    fn col_block_width_does_not_change_results() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0x71E5);
        let (rows, cols, k) = (4usize, 13usize, 9usize);
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc = vec![0.0; rows];
        let auto = BatchEngine::new(cfg).gemm_f64(&acc, &w, &x, k);
        // explicit block widths (including 1 and wider-than-cols) AND the
        // auto policy must all agree, sequential and threaded alike
        for cb in [1usize, 2, 5, 13, 64] {
            for t in [1usize, 3] {
                let got = BatchEngine::new(cfg)
                    .with_threads(t)
                    .with_col_block(cb)
                    .gemm_f64(&acc, &w, &x, k);
                assert_eq!(auto, got, "col_block={cb} threads={t}");
            }
        }
    }

    #[test]
    fn prepared_operands_reuse_is_stable() {
        let cfg = PdpuConfig::paper_default();
        let engine = BatchEngine::new(cfg);
        let mut rng = Rng::seeded(0xAB);
        let k = 8;
        let w = PreparedOperands::quantize(cfg.in_fmt, &(0..3 * k).map(|_| rng.normal()).collect::<Vec<_>>(), k);
        let x = PreparedOperands::quantize(cfg.in_fmt, &(0..2 * k).map(|_| rng.normal()).collect::<Vec<_>>(), k);
        let acc = vec![Posit::zero(cfg.out_fmt); 3];
        let first = engine.gemm_posit(&acc, &w, &x);
        let second = engine.gemm_posit(&acc, &w, &x);
        assert_eq!(
            first.iter().map(Posit::bits).collect::<Vec<_>>(),
            second.iter().map(Posit::bits).collect::<Vec<_>>()
        );
        assert_eq!(w.rows(), 3);
        assert_eq!(w.k(), k);
        assert_eq!(w.row(1).len(), k);
        assert_eq!(w.format(), cfg.in_fmt);
    }

    #[test]
    fn from_posits_equals_quantize_route() {
        let cfg = PdpuConfig::paper_default();
        let mut rng = Rng::seeded(0x9A4);
        let k = 17;
        let data: Vec<f64> = (0..4 * k).map(|_| rng.log_uniform_signed(-10.0, 10.0)).collect();
        let via_f64 = PreparedOperands::quantize(cfg.in_fmt, &data, k);
        let posits: Vec<Posit> = data.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let via_posits = PreparedOperands::from_posits(cfg.in_fmt, &posits, k);
        for r in 0..4 {
            assert_eq!(via_f64.row(r), via_posits.row(r), "row {r}");
        }
    }

    #[test]
    fn empty_shapes_are_fine() {
        let cfg = PdpuConfig::paper_default();
        let engine = BatchEngine::new(cfg);
        let w = PreparedOperands::quantize(cfg.in_fmt, &[], 4);
        let x = PreparedOperands::quantize(cfg.in_fmt, &[1.0, 2.0, 3.0, 4.0], 4);
        let out = engine.gemm_posit(&[], &w, &x);
        assert!(out.is_empty());
    }
}
