#![forbid(unsafe_code)]

//! # PDPU — posit dot-product unit, full-stack reproduction
//!
//! Reproduction of Li, Fang & Wang, *"PDPU: An Open-Source Posit
//! Dot-Product Unit for Deep Learning Applications"* (ISCAS 2023), as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * [`posit`] — bit-exact posit arithmetic for any P(n,es), the quire, and
//!   exact references (the paper's SoftPosit role).
//! * [`pdpu`] — the paper's contribution: a bit-exact functional model of
//!   the fused, mixed-precision, 6-stage dot-product datapath plus its
//!   configurable generator and a cycle-level pipeline model.
//! * [`baselines`] — every architecture PDPU is compared against in
//!   Table I: discrete mul+add-tree DPUs, cascaded-FMA DPUs, the quire
//!   PDPU, IEEE-754 (FPnew-style) DPUs/FMAs, and posit FMAs.
//! * [`cost`] — a structural 28 nm-class area/delay/power model standing in
//!   for Synopsys DC synthesis (see DESIGN.md substitution log).
//! * [`dnn`] — the deep-learning workload substrate (tensors, layers,
//!   posit quantization, synthetic conv1/MNIST-like datasets, metrics).
//! * [`experiments`] — drivers that regenerate every table and figure.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts.
//! * [`engine`] — the batched GEMM/im2col execution engine: pre-decoded
//!   operand planes + allocation-free stage path + row-parallel workers.
//! * [`coordinator`] — the L3 serving layer: router, dynamic batcher,
//!   PDPU-array scheduler with pipeline-occupancy modelling, TCP server,
//!   and the software (batched-engine) serving backend.
//! * [`train`] — mixed-precision posit training: GEMM-shaped backward
//!   kernels through the batched engine, softmax cross-entropy, SGD with
//!   posit quantization-on-update and quire-accumulated gradient sums.
//! * [`testing`] — in-repo property-testing support (offline image has no
//!   proptest).
//! * [`analysis`] — `pdpu lint`: a domain-specific static-analysis pass
//!   enforcing the serving/pipeline invariants (panic-freedom,
//!   hot-path allocation-freedom, determinism, stage isolation, wire-op
//!   exhaustiveness) over this crate's own sources.
//! * [`obs`] — observability: sampled request tracing into a span ring,
//!   S1–S6 kernel-time profiling, posit numerics counters, Prometheus
//!   exposition — and the crate's single lint-sanctioned clock site.
//!
//! # Batched execution
//!
//! DNN layers never issue one dot product at a time. [`dnn::layers::conv2d`]
//! and [`dnn::layers::linear`] route through
//! [`baselines::DotArch::dot_batch`] — a GEMM tile of weight rows ×
//! im2col patch columns. The default `dot_batch` is the scalar
//! `dot_f64` loop (so every Table I baseline keeps its exact numerics),
//! while the PDPU itself overrides it with [`engine::BatchEngine`]:
//!
//! 1. **Prepare once** — [`engine::PreparedOperands`] quantizes f64 →
//!    posit and runs the S1 per-value decode *once per tensor*, not once
//!    per use;
//! 2. **Allocation-free stages** — each worker reuses one
//!    [`pdpu::DotScratch`] across every chunk instead of allocating
//!    inter-stage `Vec`s per call;
//! 3. **Row-parallel, column-blocked** — output rows are partitioned
//!    across `std::thread` workers, and each worker walks cache-sized
//!    column tiles; results are deterministic and invariant to the worker
//!    count and the tile width.
//!
//! Above the engine, the serving layer fuses **across requests**:
//! [`coordinator::fusion`] coalesces queued GEMM tiles that share a
//! configuration and left operand plane into single engine launches, and
//! the quire baseline participates through its own prepared-operand
//! `dot_batch` override.
//!
//! The engine and the fusion layer are **bit-identical** to the scalar
//! path by construction and by property test
//! (`rust/tests/engine_equivalence.rs`): same chunking, same zero-padded
//! tail, same single rounding per chunk, same per-element dataflow under
//! fusion. The coordinator serves this engine when PJRT artifacts are
//! absent ([`coordinator::SoftwareService`]); `cargo bench --bench
//! bench_kernels` reports the engine's speedup over the scalar path and
//! `cargo bench --bench bench_serving` records fused-vs-unfused serving
//! throughput to `BENCH_serving.json`. See `docs/ARCHITECTURE.md` for the
//! full module map.

pub mod analysis;
pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod cost;
pub mod dnn;
pub mod engine;
pub mod experiments;
pub mod obs;
pub mod runtime;
pub mod pdpu;
pub mod posit;
pub mod testing;
pub mod train;

pub use pdpu::{Pdpu, PdpuConfig};
pub use posit::{Posit, PositFormat};
