//! # PDPU — posit dot-product unit, full-stack reproduction
//!
//! Reproduction of Li, Fang & Wang, *"PDPU: An Open-Source Posit
//! Dot-Product Unit for Deep Learning Applications"* (ISCAS 2023), as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * [`posit`] — bit-exact posit arithmetic for any P(n,es), the quire, and
//!   exact references (the paper's SoftPosit role).
//! * [`pdpu`] — the paper's contribution: a bit-exact functional model of
//!   the fused, mixed-precision, 6-stage dot-product datapath plus its
//!   configurable generator and a cycle-level pipeline model.
//! * [`baselines`] — every architecture PDPU is compared against in
//!   Table I: discrete mul+add-tree DPUs, cascaded-FMA DPUs, the quire
//!   PDPU, IEEE-754 (FPnew-style) DPUs/FMAs, and posit FMAs.
//! * [`cost`] — a structural 28 nm-class area/delay/power model standing in
//!   for Synopsys DC synthesis (see DESIGN.md substitution log).
//! * [`dnn`] — the deep-learning workload substrate (tensors, layers,
//!   posit quantization, synthetic conv1/MNIST-like datasets, metrics).
//! * [`experiments`] — drivers that regenerate every table and figure.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts.
//! * [`coordinator`] — the L3 serving layer: router, dynamic batcher,
//!   PDPU-array scheduler with pipeline-occupancy modelling, TCP server.
//! * [`testing`] — in-repo property-testing support (offline image has no
//!   proptest).

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod cost;
pub mod dnn;
pub mod experiments;
pub mod runtime;
pub mod pdpu;
pub mod posit;
pub mod testing;

pub use pdpu::{Pdpu, PdpuConfig};
pub use posit::{Posit, PositFormat};
