//! The epoch driver: mini-batch SGD over a [`Dataset`] with per-epoch
//! loss/accuracy reporting — the engine behind `pdpu train`.
//!
//! Batches are formed deterministically in dataset order (the datasets in
//! [`crate::dnn::dataset`] are already i.i.d. by construction, so a
//! shuffle would only add nondeterminism), which makes every run of the
//! same configuration bit-reproducible.

use super::graph::TrainGraph;
use super::loss::softmax_xent_batch;
use super::sgd::Sgd;
use crate::dnn::dataset::Dataset;
use crate::dnn::Tensor;
use crate::pdpu::PdpuConfig;

/// One epoch's aggregate statistics.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    /// 1-based epoch index.
    pub epoch: usize,
    /// Example-weighted mean training loss across the epoch's steps.
    pub mean_loss: f64,
    /// Training top-1 accuracy (argmax of the step logits, pre-update).
    pub accuracy: f64,
    /// SGD steps taken.
    pub steps: usize,
    /// Examples consumed.
    pub examples: usize,
}

/// Mini-batch SGD driver over a [`TrainGraph`].
pub struct Trainer {
    graph: TrainGraph,
    sgd: Sgd,
}

impl Trainer {
    /// Posit trainer: graph and optimizer for one PDPU configuration.
    pub fn new(cfg: PdpuConfig, layer_sizes: &[usize], lr: f64, seed: u64) -> Self {
        Self { graph: TrainGraph::new(cfg, layer_sizes, seed), sgd: Sgd::new(lr, &cfg) }
    }

    /// Drive an existing graph with an existing optimizer (e.g. the FP64
    /// reference graph for A/B runs).
    pub fn from_parts(graph: TrainGraph, sgd: Sgd) -> Self {
        Self { graph, sgd }
    }

    /// The model being trained.
    pub fn graph(&self) -> &TrainGraph {
        &self.graph
    }

    /// One SGD step on a batch: forward → loss → backward GEMMs →
    /// optimizer. Returns the batch loss and the number of correctly
    /// classified examples (from the pre-update logits).
    pub fn train_step(&mut self, images: &[Vec<f64>], labels: &[usize]) -> (f64, usize) {
        assert!(!images.is_empty(), "empty training batch");
        assert_eq!(images.len(), labels.len(), "one label per image");
        let d = self.graph.input_dim();
        let b = images.len();
        let mut flat = Vec::with_capacity(b * d);
        for img in images {
            assert_eq!(img.len(), d, "image width mismatch");
            flat.extend_from_slice(img);
        }
        let xs = Tensor::from_vec(&[b, d], flat);
        let trace = self.graph.forward(&xs);
        let (loss, dlogits) = softmax_xent_batch(trace.logits(), labels);
        let c = self.graph.classes();
        let correct = (0..b)
            .filter(|&i| {
                let row = &trace.logits().data()[i * c..(i + 1) * c];
                let arg = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(j, _)| j);
                arg == Some(labels[i])
            })
            .count();
        let grads = self.graph.backward(&trace, &dlogits);
        self.sgd.step(&mut self.graph, &grads);
        (loss, correct)
    }

    /// One pass over the dataset in `batch`-sized steps (the final partial
    /// batch included).
    pub fn run_epoch(&mut self, ds: &Dataset, batch: usize, epoch: usize) -> EpochStats {
        assert!(batch >= 1, "batch must be ≥ 1");
        assert!(!ds.images.is_empty(), "empty dataset");
        assert_eq!(ds.images.len(), ds.labels.len(), "one label per dataset image");
        assert_eq!(ds.images[0].len(), self.graph.input_dim(), "dataset/input width mismatch");
        assert!(ds.classes <= self.graph.classes(), "dataset has more classes than the model");
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut steps = 0usize;
        for (imgs, labels) in ds.images.chunks(batch).zip(ds.labels.chunks(batch)) {
            let (loss, ok) = self.train_step(imgs, labels);
            loss_sum += loss * imgs.len() as f64;
            correct += ok;
            steps += 1;
        }
        let n = ds.images.len();
        EpochStats {
            epoch,
            mean_loss: loss_sum / n as f64,
            accuracy: correct as f64 / n as f64,
            steps,
            examples: n,
        }
    }

    /// Train for `epochs` passes, returning one [`EpochStats`] per epoch.
    pub fn fit(&mut self, ds: &Dataset, epochs: usize, batch: usize) -> Vec<EpochStats> {
        (1..=epochs).map(|e| self.run_epoch(ds, batch, e)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny deterministic 2-class dataset: class 0 lights the first half
    /// of the features, class 1 the second half. Linearly separable, so a
    /// few SGD steps must drive the loss down hard.
    fn tiny_dataset(n: usize, dim: usize) -> Dataset {
        let mut images = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let label = i % 2;
            let mut img = vec![0.1; dim];
            let (lo, hi) = if label == 0 { (0, dim / 2) } else { (dim / 2, dim) };
            for v in &mut img[lo..hi] {
                *v = 0.9 + 0.01 * (i % 5) as f64;
            }
            images.push(img);
            labels.push(label);
        }
        Dataset { images, labels, classes: 2 }
    }

    #[test]
    fn loss_decreases_across_epochs_on_tiny_dataset() {
        let ds = tiny_dataset(24, 8);
        let mut t = Trainer::new(PdpuConfig::paper_default(), &[8, 6, 2], 0.2, 0x7E57);
        let stats = t.fit(&ds, 3, 8);
        assert_eq!(stats.len(), 3);
        assert!(
            stats[0].mean_loss > stats[1].mean_loss && stats[1].mean_loss > stats[2].mean_loss,
            "epoch losses must strictly decrease: {:?}",
            stats.iter().map(|s| s.mean_loss).collect::<Vec<_>>()
        );
        assert!(stats[2].accuracy >= stats[0].accuracy);
        assert_eq!(stats[0].steps, 3);
        assert_eq!(stats[0].examples, 24);
    }

    #[test]
    fn training_is_deterministic() {
        let ds = tiny_dataset(16, 6);
        let run = || {
            let mut t = Trainer::new(PdpuConfig::paper_default(), &[6, 2], 0.1, 42);
            let s = t.fit(&ds, 2, 4);
            (s[0].mean_loss.to_bits(), s[1].mean_loss.to_bits(), s[1].accuracy.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn partial_tail_batch_is_consumed() {
        let ds = tiny_dataset(10, 4);
        let mut t = Trainer::new(PdpuConfig::paper_default(), &[4, 2], 0.1, 1);
        let s = t.run_epoch(&ds, 4, 1);
        assert_eq!(s.steps, 3); // 4 + 4 + 2
        assert_eq!(s.examples, 10);
    }
}
