//! Softmax cross-entropy in FP64 — the loss head of the posit training
//! stack.
//!
//! The loss (and its gradient w.r.t. the logits) is computed in FP64, the
//! repo's reference representation: the paper extracts its DNN tensors in
//! FP64, and keeping the scalar loss head exact isolates every posit
//! rounding effect inside the GEMM kernels where the hardware actually
//! operates. The logits *feeding* this head already carry the posit
//! datapath's quantization.

use crate::dnn::Tensor;

/// Numerically-stable softmax of one logits row into `out`.
pub fn softmax_row(logits: &[f64], out: &mut [f64]) {
    debug_assert_eq!(logits.len(), out.len());
    let max = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let mut denom = 0.0;
    for (o, &z) in out.iter_mut().zip(logits) {
        *o = (z - max).exp();
        denom += *o;
    }
    for o in out.iter_mut() {
        *o /= denom;
    }
}

/// Mean softmax cross-entropy over a batch of logits `[B, C]` with one
/// class label per row, plus the gradient w.r.t. the logits:
///
/// ```text
/// loss       = mean_b ( −log softmax(z_b)[y_b] )
/// dlogits_bj = ( softmax(z_b)[j] − 1{j == y_b} ) / B
/// ```
///
/// Returns `(loss, dlogits)` with `dlogits` shaped like `logits`. This is
/// the FP64 analytic form the backward GEMMs start from.
pub fn softmax_xent_batch(logits: &Tensor, labels: &[usize]) -> (f64, Tensor) {
    let (b, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(labels.len(), b, "one label per logits row");
    assert!(b > 0, "empty batch");
    assert!(labels.iter().all(|&l| l < c), "label out of range for {c} classes");
    let mut dlogits = Tensor::zeros(&[b, c]);
    let mut probs = vec![0.0; c];
    let mut loss = 0.0;
    for i in 0..b {
        let row = &logits.data()[i * c..(i + 1) * c];
        softmax_row(row, &mut probs);
        loss += -(probs[labels[i]].max(f64::MIN_POSITIVE)).ln();
        let drow = &mut dlogits.data_mut()[i * c..(i + 1) * c];
        for (j, (d, &p)) in drow.iter_mut().zip(&probs).enumerate() {
            *d = (p - if j == labels[i] { 1.0 } else { 0.0 }) / b as f64;
        }
    }
    (loss / b as f64, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_row_is_a_distribution() {
        let mut p = vec![0.0; 3];
        softmax_row(&[1.0, 2.0, 3.0], &mut p);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // shift invariance (the stability trick is exact)
        let mut q = vec![0.0; 3];
        softmax_row(&[1001.0, 1002.0, 1003.0], &mut q);
        for (a, b) in p.iter().zip(&q) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn uniform_logits_give_log_c_loss() {
        let logits = Tensor::zeros(&[2, 4]);
        let (loss, d) = softmax_xent_batch(&logits, &[0, 3]);
        assert!((loss - 4f64.ln()).abs() < 1e-12, "{loss}");
        // gradient rows sum to zero and point away from the label
        for i in 0..2 {
            let row = &d.data()[i * 4..(i + 1) * 4];
            assert!(row.iter().sum::<f64>().abs() < 1e-12);
        }
        assert!(d.data()[0] < 0.0); // label entry of row 0
    }

    #[test]
    fn perfect_prediction_has_tiny_loss_and_gradient() {
        let logits = Tensor::from_vec(&[1, 3], vec![30.0, 0.0, 0.0]);
        let (loss, d) = softmax_xent_batch(&logits, &[0]);
        assert!(loss < 1e-10, "{loss}");
        assert!(d.data().iter().all(|g| g.abs() < 1e-10));
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let base = vec![0.3, -1.2, 0.7, 0.1, 2.0, -0.4];
        let labels = [2usize, 0];
        let logits = Tensor::from_vec(&[2, 3], base.clone());
        let (_, d) = softmax_xent_batch(&logits, &labels);
        let eps = 1e-6;
        for i in 0..base.len() {
            let mut hi = base.clone();
            let mut lo = base.clone();
            hi[i] += eps;
            lo[i] -= eps;
            let (lh, _) = softmax_xent_batch(&Tensor::from_vec(&[2, 3], hi), &labels);
            let (ll, _) = softmax_xent_batch(&Tensor::from_vec(&[2, 3], lo), &labels);
            let fd = (lh - ll) / (2.0 * eps);
            assert!((fd - d.data()[i]).abs() < 1e-8, "dlogits[{i}]: fd {fd} vs analytic {}", d.data()[i]);
        }
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn bad_label_panics() {
        softmax_xent_batch(&Tensor::zeros(&[1, 2]), &[2]);
    }
}
