//! Posit training subsystem — SGD through the batched PDPU engine.
//!
//! The paper positions PDPU as the computing core of posit-based DNN
//! accelerators, and prior work (Lu et al., *Training DNNs Using the Posit
//! Number System*; Carmichael et al., *Deep Positron*) shows posit
//! arithmetic carries training, not just inference. This module closes the
//! ROADMAP's "software-backend training" item: mixed-precision posit SGD
//! end-to-end through the existing batched engine, no PJRT artifacts.
//!
//! * [`graph`] — [`TrainGraph`]: an MLP whose forward pass *and* backward
//!   pass are GEMM tiles through [`crate::baselines::DotArch::dot_batch`].
//!   The activation-grad and weight-grad kernels are expressed over
//!   transposed operand planes, so backprop rides the same tiled,
//!   prepared-operand engine path ([`crate::engine::BatchEngine`]) as
//!   inference — never an ad-hoc scalar loop.
//! * [`loss`] — softmax cross-entropy in FP64 (the reference
//!   representation, exactly as the paper extracts its tensors in FP64).
//! * [`sgd`] — the [`Sgd`] optimizer: posit weight
//!   **quantization-on-update** with the update `w − lr·g` computed in a
//!   wide exact accumulator and rounded **once** — the optimizer-level
//!   mirror of the paper's mixed-precision S4 accumulation (many exact
//!   partial terms, a single rounding at the boundary). [`quire_sum`]
//!   provides the same single-rounding wide accumulation for gradient
//!   sums (bias gradients, cross-batch reductions).
//! * [`trainer`] — [`Trainer`]: epochs over [`crate::dnn::dataset`], with
//!   per-epoch loss/accuracy reporting for the `pdpu train` CLI.
//!
//! The gradient math is property-tested against an FP64 analytic
//! reference and a finite-difference oracle in
//! `rust/tests/train_stack.rs`; the coordinator serves the same step via
//! `SoftwareService::train_step` (the software `EngineReq::TrainStep` arm
//! no longer errors).

pub mod graph;
pub mod loss;
pub mod sgd;
pub mod trainer;

pub use graph::{ForwardTrace, Grads, TrainGraph};
pub use loss::softmax_xent_batch;
pub use sgd::{quire_sum, Sgd};
pub use trainer::{EpochStats, Trainer};
