//! SGD with posit quantization-on-update and wide exact accumulation.
//!
//! The paper's S4 stage sums many aligned product terms in one wide
//! accumulator and rounds **once** at the output boundary. This optimizer
//! applies the same discipline at the parameter-update boundary: the
//! update `w − lr·g` is accumulated exactly in the posit quire
//! ([`crate::posit::Quire`]) — the posit value of `w`, plus the exact
//! product of the quantized learning rate and gradient — and rounded once
//! into the stored weight format. No intermediate rounding between the
//! multiply and the add, and weights land back on the posit grid after
//! every step (**quantization-on-update**), exactly the state a
//! posit-weight accelerator would hold.
//!
//! [`quire_sum`] is the reduction counterpart: a gradient sum accumulated
//! exactly with a single final rounding, used by
//! [`super::graph::TrainGraph::backward`] for bias gradients and available
//! for cross-microbatch gradient accumulation.

use super::graph::{Grads, TrainGraph};
use crate::obs::numerics::{Site, SiteGuard, SiteKind};
use crate::pdpu::PdpuConfig;
use crate::posit::quire::CACHE_LINE_LIMBS;
use crate::posit::{Posit, PositFormat, Quire, QuireSpec};

/// Sum `vals` exactly in the quire after quantizing each addend to `fmt`,
/// rounding the total once back to `fmt` — the S4-style wide accumulation
/// for gradient reductions (one rounding per *sum*, not per addend).
///
/// Capacity is validated once up front ([`QuireSpec::new`]); the register
/// width is picked to fit one cache line when the format allows it.
pub fn quire_sum(vals: &[f64], fmt: PositFormat) -> f64 {
    let spec = QuireSpec::new(fmt, fmt).expect("format within quire capacity");
    if spec.fits_cache_line() {
        quire_sum_with::<CACHE_LINE_LIMBS>(spec, vals, fmt)
    } else {
        quire_sum_with::<16>(spec, vals, fmt)
    }
}

fn quire_sum_with<const L: usize>(spec: QuireSpec, vals: &[f64], fmt: PositFormat) -> f64 {
    let mut q = Quire::<L>::from_spec(spec);
    for &v in vals {
        q.add_posit(Posit::from_f64(v, fmt));
    }
    q.to_posit(fmt).to_f64()
}

/// Plain SGD over a [`TrainGraph`]'s parameters, posit-quantized.
#[derive(Clone, Copy, Debug)]
pub struct Sgd {
    lr: f64,
    /// Storage format the updated parameters are rounded into.
    weight_fmt: PositFormat,
    /// Format the learning rate and gradient are quantized to before the
    /// exact `lr·g` product enters the quire.
    grad_fmt: PositFormat,
    /// Quire recipe for `grad_fmt` products, validated once at
    /// construction so per-parameter quire setup is branch-free.
    spec: QuireSpec,
    /// The PDPU configuration this optimizer was built for, kept so
    /// update-path numerics attribute to the right registry entry.
    cfg: PdpuConfig,
}

impl Sgd {
    /// SGD at learning rate `lr` for a PDPU configuration: parameters are
    /// stored in the accumulator format `cfg.out_fmt` (the wider side of
    /// the mixed-precision pair — master weights, like the FP32 master
    /// copy of IEEE mixed-precision training), and gradients enter the
    /// update in the same format. The engine re-quantizes weights to
    /// `cfg.in_fmt` at every GEMM, so compute stays narrow while the
    /// stored parameters keep enough resolution for small updates to
    /// survive rounding.
    pub fn new(lr: f64, cfg: &PdpuConfig) -> Self {
        assert!(lr > 0.0 && lr.is_finite(), "learning rate must be positive");
        let grad_fmt = cfg.out_fmt;
        let spec = QuireSpec::new(grad_fmt, grad_fmt).expect("format within quire capacity");
        Self { lr, weight_fmt: cfg.out_fmt, grad_fmt, spec, cfg: *cfg }
    }

    /// The configured learning rate.
    pub fn lr(&self) -> f64 {
        self.lr
    }

    /// The posit format updated parameters are quantized into.
    pub fn weight_fmt(&self) -> PositFormat {
        self.weight_fmt
    }

    /// Apply one step: `p ← round_fmt(p − lr·g)` for every parameter, each
    /// update computed exactly in the quire with a single rounding.
    pub fn step(&self, graph: &mut TrainGraph, grads: &Grads) {
        assert_eq!(grads.dw.len(), graph.weights().len(), "one weight gradient per layer");
        assert_eq!(grads.db.len(), graph.biases().len(), "one bias gradient per layer");
        for (l, (w, gw)) in graph.weights_mut().iter_mut().zip(&grads.dw).enumerate() {
            let _site = SiteGuard::enter(Site::new(SiteKind::SgdUpdate, l as i32));
            self.update_slice(w.data_mut(), gw.data());
        }
        for (l, (b, gb)) in graph.biases_mut().iter_mut().zip(&grads.db).enumerate() {
            let _site = SiteGuard::enter(Site::new(SiteKind::SgdUpdate, l as i32));
            self.update_slice(b, gb);
        }
    }

    /// `w[i] ← round(w[i] − lr·g[i])`, single-rounded through the quire.
    ///
    /// Each update's one quire→posit rounding is audited: when the stored
    /// result differs from the exact `wq − lr·gq` (all operands already on
    /// their posit grids, so the f64 reference is exact up to its own 53
    /// bits), the slice's tally lands in the global
    /// [`crate::obs`] quire-rounding counter — the "how often does
    /// quantization-on-update actually round" signal.
    fn update_slice(&self, w: &mut [f64], g: &[f64]) {
        // capacity was validated in `new`; dispatch once on register width,
        // then the per-parameter loop builds no quire and checks no branch
        if self.spec.fits_cache_line() {
            self.update_slice_with::<CACHE_LINE_LIMBS>(w, g)
        } else {
            self.update_slice_with::<16>(w, g)
        }
    }

    fn update_slice_with<const L: usize>(&self, w: &mut [f64], g: &[f64]) {
        assert_eq!(w.len(), g.len(), "parameter/gradient shape mismatch");
        let neg_lr = Posit::from_f64(-self.lr, self.grad_fmt);
        let mut roundings = 0u64;
        let (mut grad_sat, mut grad_underflow) = (0u64, 0u64);
        let mut watermark: Option<i32> = None;
        let sign_bit = 1u32 << (self.grad_fmt.n() - 1);
        let mut q = Quire::<L>::from_spec(self.spec);
        for (wi, &gi) in w.iter_mut().zip(g) {
            let wq = Posit::from_f64(*wi, self.weight_fmt);
            let gq = Posit::from_f64(gi, self.grad_fmt);
            // gradient regime exhaustion: quantized to ±maxpos (saturated)
            // or clamped to ±minpos (about to vanish) — the per-layer
            // signals Lu et al. key gradient-format choices on
            if !gq.is_nar() && !gq.is_zero() {
                let bits = gq.bits();
                let abs =
                    if bits & sign_bit != 0 { bits.wrapping_neg() & self.grad_fmt.mask() } else { bits };
                if abs == self.grad_fmt.maxpos_bits() {
                    grad_sat += 1;
                } else if abs == self.grad_fmt.minpos_bits() {
                    grad_underflow += 1;
                }
            }
            q.reset();
            q.add_posit(wq);
            q.add_product(neg_lr, gq);
            if let Some(m) = q.watermark_log2() {
                watermark = Some(watermark.map_or(m, |cur| cur.max(m)));
            }
            let updated = q.to_posit(self.weight_fmt);
            if updated.to_f64() != wq.to_f64() + neg_lr.to_f64() * gq.to_f64() {
                roundings += 1;
            }
            *wi = updated.to_f64();
        }
        crate::obs::numerics::record_update(&self.cfg, roundings, grad_sat, grad_underflow, watermark);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn quire_sum_is_exact_on_representable_data() {
        let fmt = PositFormat::p(16, 2);
        // exactly representable values with heavy cancellation: the wide
        // accumulator must not lose the small survivor
        let vals = [1024.0, -1024.0, 0.0078125];
        assert_eq!(quire_sum(&vals, fmt), 0.0078125);
        assert_eq!(quire_sum(&[], fmt), 0.0);
    }

    #[test]
    fn quire_sum_single_rounding_beats_serial_rounding() {
        let fmt = PositFormat::p(13, 2);
        let mut rng = Rng::seeded(0x5D4);
        let (mut err_wide, mut err_serial) = (0.0, 0.0);
        for _ in 0..200 {
            let vals: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
            let exact: f64 = vals.iter().map(|&v| Posit::from_f64(v, fmt).to_f64()).sum();
            let wide = quire_sum(&vals, fmt);
            let serial = vals
                .iter()
                .fold(0.0, |acc, &v| Posit::from_f64(acc + Posit::from_f64(v, fmt).to_f64(), fmt).to_f64());
            err_wide += (wide - exact).abs();
            err_serial += (serial - exact).abs();
        }
        assert!(err_wide <= err_serial, "wide {err_wide} vs serial {err_serial}");
    }

    #[test]
    fn step_moves_weights_down_the_gradient() {
        let cfg = PdpuConfig::paper_default();
        let mut g = TrainGraph::new(cfg, &[2, 2], 3);
        let before = g.weights()[0].data().to_vec();
        let grads = Grads {
            dw: vec![crate::dnn::Tensor::from_vec(&[2, 2], vec![1.0, -1.0, 0.5, 0.0])],
            db: vec![vec![2.0, -2.0]],
        };
        let sgd = Sgd::new(0.25, &cfg);
        sgd.step(&mut g, &grads);
        let after = g.weights()[0].data();
        assert!(after[0] < before[0], "positive gradient must decrease the weight");
        assert!(after[1] > before[1]);
        assert_eq!(g.biases()[0], vec![-0.5, 0.5]);
        // every updated parameter sits on the storage-format posit grid
        let fmt = sgd.weight_fmt();
        for &v in after.iter().chain(&g.biases()[0]) {
            assert_eq!(v, Posit::from_f64(v, fmt).to_f64(), "{v} off the {fmt} grid");
        }
    }

    #[test]
    fn update_is_single_rounded_fma() {
        // w − lr·g with one rounding: must equal the exact f64 value
        // rounded once, on data where the f64 computation is exact
        let cfg = PdpuConfig::paper_default();
        let sgd = Sgd::new(0.5, &cfg);
        let mut w = [1.0, -0.25];
        let g = [0.5, 1.0];
        sgd.update_slice(&mut w, &g);
        assert_eq!(w[0], 0.75); // 1 − 0.5·0.5
        assert_eq!(w[1], -0.75); // −0.25 − 0.5
    }

    #[test]
    fn inexact_updates_bump_the_quire_rounding_counter() {
        // tiny lr·g against a unit weight: the exact sum needs more
        // fraction bits than p16 holds, so the single rounding must fire.
        // The counter is process-global (other tests may also bump it), so
        // assert a monotone increase, not an exact delta.
        let before = crate::obs::numerics().quire_roundings;
        let cfg = PdpuConfig::paper_default();
        let sgd = Sgd::new(1.0 / 1024.0, &cfg);
        let mut w = [1.0];
        sgd.update_slice(&mut w, &[1.0 / 1024.0]);
        assert!(
            crate::obs::numerics().quire_roundings > before,
            "an update that cannot be exact must record a rounding event"
        );
    }

    #[test]
    fn zero_gradient_only_requantizes() {
        let cfg = PdpuConfig::paper_default();
        let sgd = Sgd::new(0.1, &cfg);
        let raw = 0.1234567890123; // not on the p16 grid
        let mut w = [raw];
        sgd.update_slice(&mut w, &[0.0]);
        assert_eq!(w[0], Posit::from_f64(raw, sgd.weight_fmt()).to_f64());
    }
}
