//! The training graph: an MLP whose forward *and* backward passes are
//! GEMM tiles through [`DotArch::dot_batch`].
//!
//! Backpropagation through a fully-connected layer `Z = A·Wᵀ + b` is three
//! GEMMs, and all three are expressed here as `dot_batch` calls over
//! transposed operand planes (the row-contiguous layout the batched engine
//! wants), so the backward pass reuses the tiled, prepared-operand
//! [`crate::engine::BatchEngine`] path exactly as inference does:
//!
//! ```text
//! forward          Z  = A · Wᵀ          dot_batch(b,  W,   A,  k=in)
//! weight grad      dW = dZᵀ · A         dot_batch(0,  dZᵀ, Aᵀ, k=B)
//! activation grad  dA = dZ · W          dot_batch(0,  dZ,  Wᵀ, k=out)
//! bias grad        db = Σ_batch dZ      quire-accumulated column sums
//! ```
//!
//! The bias gradient is a pure reduction (no products), so instead of a
//! degenerate GEMM it uses [`quire_sum`] — the wide exact accumulator with
//! a single rounding, mirroring the paper's S4 mixed-precision
//! accumulation at the optimizer boundary.
//!
//! [`TrainGraph::backward_f64`] is the independent FP64 analytic
//! reference (plain loops, no `DotArch`), the oracle the property tests in
//! `rust/tests/train_stack.rs` compare both the FP64-routed and the
//! posit-routed backward passes against.

use super::sgd::quire_sum;
use crate::baselines::{DotArch, PdpuArch};
use crate::dnn::layers::{linear_batch, relu, with_zero_seeds};
use crate::dnn::Tensor;
use crate::obs::numerics::{Site, SiteGuard, SiteKind};
use crate::pdpu::PdpuConfig;
use crate::posit::PositFormat;
use crate::testing::Rng;

/// FP64 reference dot-product architecture: exact `acc + Σ aᵢ·bᵢ` in f64.
/// Running a [`TrainGraph`] over this arch gives the analytic FP64
/// training semantics through the *same* GEMM-shaped code path as the
/// posit graph — the comparison that isolates posit quantization effects.
#[derive(Clone, Copy, Debug)]
pub struct Fp64Ref;

impl DotArch for Fp64Ref {
    fn name(&self) -> String {
        "FP64 reference".into()
    }

    fn chunk(&self) -> usize {
        usize::MAX
    }

    fn dot_f64(&self, acc: f64, a: &[f64], b: &[f64]) -> f64 {
        acc + a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>()
    }
}

/// Everything the backward pass needs from one forward pass: the input to
/// every layer and every pre-activation output.
#[derive(Clone, Debug)]
pub struct ForwardTrace {
    /// `acts[l]` is the input to layer `l` (`acts[0]` = the batch input,
    /// later entries are post-ReLU activations).
    acts: Vec<Tensor>,
    /// `zs[l]` is the pre-activation output of layer `l`; the last entry
    /// is the logits.
    zs: Vec<Tensor>,
}

impl ForwardTrace {
    /// The network output (pre-softmax logits), `[B, classes]`.
    pub fn logits(&self) -> &Tensor {
        self.zs.last().expect("trace of a network with at least one layer")
    }

    /// Batch size of the traced pass.
    pub fn batch(&self) -> usize {
        self.acts[0].shape()[0]
    }
}

/// Parameter gradients of one backward pass, shaped like the parameters.
#[derive(Clone, Debug)]
pub struct Grads {
    /// One `[out, in]` tensor per layer.
    pub dw: Vec<Tensor>,
    /// One `[out]` vector per layer.
    pub db: Vec<Vec<f64>>,
}

/// An MLP (the seed serving model's shape) with a forward pass and
/// GEMM-shaped backward kernels, both routed through a [`DotArch`].
pub struct TrainGraph {
    arch: Box<dyn DotArch + Send + Sync>,
    /// Posit format for the wide-accumulated gradient sums (bias grads);
    /// `None` keeps those reductions in exact f64 (the reference graph).
    sum_fmt: Option<PositFormat>,
    weights: Vec<Tensor>,
    biases: Vec<Vec<f64>>,
    layer_sizes: Vec<usize>,
}

impl TrainGraph {
    /// Posit training graph over the batched PDPU engine: weights He-
    /// initialized from `seed` (the same init the serving model uses),
    /// gradient sums wide-accumulated in `cfg.out_fmt`.
    pub fn new(cfg: PdpuConfig, layer_sizes: &[usize], seed: u64) -> Self {
        Self::with_arch(Box::new(PdpuArch::new(cfg)), Some(cfg.out_fmt), layer_sizes, seed)
    }

    /// FP64 analytic twin: same layers, same init, exact f64 arithmetic
    /// end-to-end. The oracle the posit graph is measured against.
    pub fn fp64_reference(layer_sizes: &[usize], seed: u64) -> Self {
        Self::with_arch(Box::new(Fp64Ref), None, layer_sizes, seed)
    }

    /// Build over any dot-product architecture. `layer_sizes` =
    /// `[input, hidden…, classes]`; weights are He-initialized from `seed`
    /// with the exact RNG sequence the software serving model uses, so a
    /// graph and a `SoftwareService` built from the same seed agree.
    pub fn with_arch(
        arch: Box<dyn DotArch + Send + Sync>,
        sum_fmt: Option<PositFormat>,
        layer_sizes: &[usize],
        seed: u64,
    ) -> Self {
        assert!(layer_sizes.len() >= 2, "need at least input and output layer sizes");
        assert!(layer_sizes.iter().all(|&s| s > 0));
        let mut rng = Rng::seeded(seed);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for win in layer_sizes.windows(2) {
            let (fan_in, fan_out) = (win[0], win[1]);
            let sigma = (2.0 / fan_in as f64).sqrt();
            let data: Vec<f64> = (0..fan_out * fan_in).map(|_| rng.normal() * sigma).collect();
            weights.push(Tensor::from_vec(&[fan_out, fan_in], data));
            biases.push(vec![0.0; fan_out]);
        }
        Self { arch, sum_fmt, weights, biases, layer_sizes: layer_sizes.to_vec() }
    }

    /// Layer widths, input first.
    pub fn layer_sizes(&self) -> &[usize] {
        &self.layer_sizes
    }

    /// Input feature count.
    pub fn input_dim(&self) -> usize {
        self.layer_sizes[0]
    }

    /// Output class count.
    pub fn classes(&self) -> usize {
        *self.layer_sizes.last().unwrap()
    }

    /// Per-layer `[out, in]` weight tensors.
    pub fn weights(&self) -> &[Tensor] {
        &self.weights
    }

    /// Per-layer bias vectors.
    pub fn biases(&self) -> &[Vec<f64>] {
        &self.biases
    }

    /// Mutable weights (the optimizer's write handle).
    pub fn weights_mut(&mut self) -> &mut [Tensor] {
        &mut self.weights
    }

    /// Mutable biases (the optimizer's write handle).
    pub fn biases_mut(&mut self) -> &mut [Vec<f64>] {
        &mut self.biases
    }

    /// Inference-only forward pass: one `dot_batch` GEMM per layer, ReLU
    /// between layers, logits out. Identical numerics to the serving
    /// model's `infer_batch`.
    pub fn infer(&self, xs: &Tensor) -> Tensor {
        let last = self.weights.len() - 1;
        let mut acts = xs.clone();
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let _site = SiteGuard::enter(Site::new(SiteKind::Infer, l as i32));
            acts = linear_batch(self.arch.as_ref(), &acts, w, b);
            if l != last {
                relu(acts.data_mut());
            }
        }
        acts
    }

    /// Forward pass recording everything the backward pass needs. The
    /// logits of the trace are bit-identical to [`Self::infer`] on the
    /// same input (same GEMMs in the same order).
    pub fn forward(&self, xs: &Tensor) -> ForwardTrace {
        assert_eq!(xs.shape()[1], self.input_dim(), "input feature mismatch");
        let last = self.weights.len() - 1;
        let mut acts = vec![xs.clone()];
        let mut zs = Vec::with_capacity(self.weights.len());
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let _site = SiteGuard::enter(Site::new(SiteKind::TrainFwd, l as i32));
            let z = linear_batch(self.arch.as_ref(), acts.last().unwrap(), w, b);
            zs.push(z.clone());
            if l != last {
                let mut a = z;
                relu(a.data_mut());
                acts.push(a);
            }
        }
        ForwardTrace { acts, zs }
    }

    /// Backward pass from `dlogits` (`∂loss/∂logits`, `[B, classes]`):
    /// weight and activation gradients as `dot_batch` GEMM tiles over
    /// transposed planes, bias gradients as wide-accumulated column sums.
    pub fn backward(&self, trace: &ForwardTrace, dlogits: &Tensor) -> Grads {
        let layers = self.weights.len();
        let b = trace.batch();
        assert_eq!(dlogits.shape(), &[b, self.classes()], "dlogits shape");
        let arch = self.arch.as_ref();
        let mut dw_rev: Vec<Tensor> = Vec::with_capacity(layers);
        let mut db_rev: Vec<Vec<f64>> = Vec::with_capacity(layers);
        let mut dz = dlogits.clone();
        let mut col = vec![0.0; b];
        for l in (0..layers).rev() {
            let _site = SiteGuard::enter(Site::new(SiteKind::TrainBwd, l as i32));
            let w = &self.weights[l];
            let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
            let a_prev = &trace.acts[l]; // [B, in]

            // dW = dZᵀ · A: `out` rows of length B against `in` columns of
            // length B — both planes transposed into row-contiguous form
            let dzt = transpose(dz.data(), b, out_dim); // [out, B]
            let apt = transpose(a_prev.data(), b, in_dim); // [in, B]
            let dwl = with_zero_seeds(out_dim, |seeds| arch.dot_batch(seeds, &dzt, &apt, b));
            dw_rev.push(Tensor::from_vec(&[out_dim, in_dim], dwl));

            // db = Σ_batch dZ — a pure reduction through the wide
            // accumulator (single rounding per sum), or exact f64 for the
            // reference graph
            let dbl: Vec<f64> = (0..out_dim)
                .map(|o| {
                    for (i, slot) in col.iter_mut().enumerate() {
                        *slot = dz.data()[i * out_dim + o];
                    }
                    match self.sum_fmt {
                        Some(fmt) => quire_sum(&col, fmt),
                        None => col.iter().sum(),
                    }
                })
                .collect();
            db_rev.push(dbl);

            if l > 0 {
                // dA = dZ · W: B rows of length `out` against `in` columns
                // of length `out` (Wᵀ is the row-contiguous plane)
                let wt = transpose(w.data(), out_dim, in_dim); // [in, out]
                let da = with_zero_seeds(b, |seeds| arch.dot_batch(seeds, dz.data(), &wt, out_dim));
                // ReLU gate: the previous layer's pre-activation sign
                let zprev = &trace.zs[l - 1];
                let masked: Vec<f64> = da
                    .iter()
                    .zip(zprev.data())
                    .map(|(&g, &z)| if z > 0.0 { g } else { 0.0 })
                    .collect();
                dz = Tensor::from_vec(&[b, in_dim], masked);
            }
        }
        dw_rev.reverse();
        db_rev.reverse();
        Grads { dw: dw_rev, db: db_rev }
    }

    /// FP64 analytic backward reference: the same math as
    /// [`Self::backward`] written as plain f64 loops with no [`DotArch`]
    /// in the path — the independent oracle for the gradient property
    /// tests.
    pub fn backward_f64(&self, trace: &ForwardTrace, dlogits: &Tensor) -> Grads {
        let layers = self.weights.len();
        let b = trace.batch();
        assert_eq!(dlogits.shape(), &[b, self.classes()], "dlogits shape");
        let mut dw_rev: Vec<Tensor> = Vec::with_capacity(layers);
        let mut db_rev: Vec<Vec<f64>> = Vec::with_capacity(layers);
        let mut dz = dlogits.clone();
        for l in (0..layers).rev() {
            let w = &self.weights[l];
            let (out_dim, in_dim) = (w.shape()[0], w.shape()[1]);
            let a_prev = &trace.acts[l];
            let mut dwl = vec![0.0; out_dim * in_dim];
            for o in 0..out_dim {
                for j in 0..in_dim {
                    let mut s = 0.0;
                    for i in 0..b {
                        s += dz.data()[i * out_dim + o] * a_prev.data()[i * in_dim + j];
                    }
                    dwl[o * in_dim + j] = s;
                }
            }
            dw_rev.push(Tensor::from_vec(&[out_dim, in_dim], dwl));
            let dbl: Vec<f64> = (0..out_dim)
                .map(|o| (0..b).map(|i| dz.data()[i * out_dim + o]).sum())
                .collect();
            db_rev.push(dbl);
            if l > 0 {
                let zprev = &trace.zs[l - 1];
                let mut da = vec![0.0; b * in_dim];
                for i in 0..b {
                    for j in 0..in_dim {
                        let mut s = 0.0;
                        for o in 0..out_dim {
                            s += dz.data()[i * out_dim + o] * w.data()[o * in_dim + j];
                        }
                        da[i * in_dim + j] = if zprev.data()[i * in_dim + j] > 0.0 { s } else { 0.0 };
                    }
                }
                dz = Tensor::from_vec(&[b, in_dim], da);
            }
        }
        dw_rev.reverse();
        db_rev.reverse();
        Grads { dw: dw_rev, db: db_rev }
    }
}

/// Row-major transpose: `data` is `[rows, cols]`, result is `[cols, rows]`.
pub(crate) fn transpose(data: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    debug_assert_eq!(data.len(), rows * cols);
    let mut out = vec![0.0; data.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = data[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrips() {
        let data: Vec<f64> = (0..6).map(|i| i as f64).collect();
        let t = transpose(&data, 2, 3);
        assert_eq!(t, vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        assert_eq!(transpose(&t, 3, 2), data);
    }

    #[test]
    fn forward_trace_matches_infer_bitwise() {
        let g = TrainGraph::new(PdpuConfig::paper_default(), &[6, 5, 3], 0x7EA1);
        let mut rng = Rng::seeded(0x11);
        let xs = Tensor::from_vec(&[4, 6], (0..24).map(|_| rng.normal()).collect());
        let trace = g.forward(&xs);
        let logits = g.infer(&xs);
        assert_eq!(trace.logits().shape(), &[4, 3]);
        assert_eq!(trace.batch(), 4);
        let a: Vec<u64> = trace.logits().data().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = logits.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn same_seed_graphs_share_init() {
        let g1 = TrainGraph::new(PdpuConfig::paper_default(), &[4, 3], 9);
        let g2 = TrainGraph::fp64_reference(&[4, 3], 9);
        assert_eq!(g1.weights()[0], g2.weights()[0]);
        assert_eq!(g1.biases()[0], g2.biases()[0]);
    }

    #[test]
    fn fp64_graph_backward_matches_plain_loop_reference() {
        // the dot_batch-routed backward over the FP64 arch and the plain-
        // loop analytic reference compute the same sums in the same order
        let g = TrainGraph::fp64_reference(&[5, 4, 3], 0xB0B);
        let mut rng = Rng::seeded(0x22);
        let xs = Tensor::from_vec(&[3, 5], (0..15).map(|_| rng.normal()).collect());
        let trace = g.forward(&xs);
        let dlogits = Tensor::from_vec(&[3, 3], (0..9).map(|_| rng.normal()).collect());
        let got = g.backward(&trace, &dlogits);
        let want = g.backward_f64(&trace, &dlogits);
        for l in 0..2 {
            for (a, b) in got.dw[l].data().iter().zip(want.dw[l].data()) {
                assert!((a - b).abs() < 1e-12, "dw[{l}]: {a} vs {b}");
            }
            for (a, b) in got.db[l].iter().zip(&want.db[l]) {
                assert!((a - b).abs() < 1e-12, "db[{l}]: {a} vs {b}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "input feature mismatch")]
    fn wrong_input_width_panics() {
        let g = TrainGraph::new(PdpuConfig::paper_default(), &[6, 3], 1);
        g.forward(&Tensor::zeros(&[2, 5]));
    }
}
