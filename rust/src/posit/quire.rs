//! Exact dot-product accumulation (the posit *quire*).
//!
//! A quire is a wide fixed-point register that holds sums of posit products
//! **exactly** — no rounding or overflow until the final conversion back to
//! posit. The posit standard sizes the quire at `n²/2` bits; here the
//! register is a [`Wide`] two's-complement value wide enough for the
//! format's full product scale span plus `2^carry_guard` accumulations.
//!
//! Two roles in this repo:
//! * the **Quire PDPU** baseline of Table I (`Wm = 256` row) builds on it;
//! * it is the *exact oracle* against which every rounded datapath
//!   (PDPU, discrete DPUs, FMAs) is validated in tests.
//!
//! # Sizing and allocation-free reuse
//!
//! The register width is a const generic `L` (limb count). Capacity
//! validation happens **once**, at [`QuireSpec::new`] — hot loops build one
//! quire via [`Quire::from_spec`] and [`Quire::reset`] it per item instead
//! of re-deriving and re-checking the span on every construction. For every
//! format pair with n ≤ 16, es ≤ 2 the span fits [`CacheQuire`]
//! (`Wide<8>`, 512 bits = one 64-byte cache line of limbs), keeping S4-style
//! accumulation register-friendly; wider pairs (up to P(32,4)-adjacent)
//! use the default `Wide<16>`.

use super::wide::Wide;
use super::{decode, encode, Decoded, Posit, PositFormat, PositError, Unpacked};

/// Number of 64-bit limbs in the default quire register (1024 bits): the
/// widest register we support. [`QuireSpec::new`] validates at config time
/// that a format pair fits; P(32,4) would not.
const LIMBS: usize = 16;

/// Limb count whose storage spans exactly one 64-byte cache line.
pub const CACHE_LINE_LIMBS: usize = 8;

/// A quire sized to one cache line of limbs (512 bits) — enough for every
/// format pair with n ≤ 16, es ≤ 2 (P(16,2)×P(16,2) needs 313 bits).
pub type CacheQuire = Quire<CACHE_LINE_LIMBS>;

/// Validated construction recipe for a [`Quire`]: the format pair, the
/// fixed-point origin, and the required register width — computed and
/// checked **once** so per-item quire setup inside hot loops is branch-free
/// (see [`Quire::from_spec`] / [`Quire::reset`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuireSpec {
    a_fmt: PositFormat,
    b_fmt: PositFormat,
    /// bit position of weight 2^0
    origin: u32,
    /// total register bits the format pair requires (span + carry guard)
    need: u32,
}

impl QuireSpec {
    /// Validate a format pair for quire accumulation. Errors if the pair
    /// needs more span than the widest supported register ([`Wide`]`<16>`,
    /// 1024 bits — cannot happen for n ≤ 32, es ≤ 2; P(32,4) would).
    pub fn new(a_fmt: PositFormat, b_fmt: PositFormat) -> Result<Self, PositError> {
        let span_hi = (a_fmt.max_scale() + b_fmt.max_scale() + 2) as u32; // product < 2^(hi)
        let span_lo =
            (-(a_fmt.min_scale() + b_fmt.min_scale()) + (a_fmt.max_frac_bits() + b_fmt.max_frac_bits()) as i32) as u32;
        let carry_guard = 64; // up to 2^64 accumulations without overflow
        let need = span_hi + span_lo + carry_guard + 1;
        if need > Wide::<LIMBS>::BITS {
            // formats too wide for the fixed register — treat as a format error
            return Err(PositError::BadWordSize(a_fmt.n().max(b_fmt.n())));
        }
        Ok(Self { a_fmt, b_fmt, origin: span_lo, need })
    }

    /// Whether this pair fits a `Wide<L>`-backed register.
    #[inline]
    pub fn fits<const L: usize>(&self) -> bool {
        self.need <= Wide::<L>::BITS
    }

    /// Whether this pair fits the one-cache-line [`CacheQuire`].
    #[inline]
    pub fn fits_cache_line(&self) -> bool {
        self.fits::<CACHE_LINE_LIMBS>()
    }

    /// Quire width in bits actually required by this format pair — the
    /// "prohibitive hardware overhead" quantity the paper cites ([34]).
    pub fn required_bits(&self) -> u32 {
        let span_hi = (self.a_fmt.max_scale() + self.b_fmt.max_scale() + 2) as u32;
        self.origin + span_hi + 1
    }

    /// Left-operand format of the product pair.
    pub fn a_fmt(&self) -> PositFormat {
        self.a_fmt
    }

    /// Right-operand format of the product pair.
    pub fn b_fmt(&self) -> PositFormat {
        self.b_fmt
    }
}

/// Exact accumulator for products of `a_fmt` × `b_fmt` posits.
///
/// Fixed-point layout: bit `origin` is weight 2^0; products land at
/// `origin + scale - 2·mb` … The register keeps `2·max_scale + mb` bits on
/// each side of the origin plus `carry_guard` headroom bits.
///
/// The register is a plain `[u64; L]` on the stack (via [`Wide`]) — no heap
/// anywhere. `L` defaults to the widest supported register; size-critical
/// callers use [`CacheQuire`] after checking [`QuireSpec::fits_cache_line`].
#[derive(Clone, Copy)]
pub struct Quire<const L: usize = LIMBS> {
    acc: Wide<L>,
    a_fmt: PositFormat,
    b_fmt: PositFormat,
    /// bit position of weight 2^0
    origin: u32,
    /// true once a NaR entered the accumulation (poisons the result)
    nar: bool,
}

impl Quire<LIMBS> {
    /// Create an empty default-width quire for products of `a_fmt` and
    /// `b_fmt` values, validating capacity. Hot loops should instead
    /// validate once via [`QuireSpec::new`] and construct with
    /// [`Quire::from_spec`] + [`Quire::reset`].
    pub fn new(a_fmt: PositFormat, b_fmt: PositFormat) -> Result<Self, PositError> {
        Ok(Self::from_spec(QuireSpec::new(a_fmt, b_fmt)?))
    }
}

impl<const L: usize> Quire<L> {
    /// Build an empty quire from a pre-validated spec. The width check is a
    /// real (release-mode) assert because [`Wide::from_u128_shifted`] only
    /// debug-asserts overflow — but it runs once per *construction*, and
    /// hot loops construct once and [`reset`](Self::reset) per item.
    pub fn from_spec(spec: QuireSpec) -> Self {
        assert!(
            spec.fits::<L>(),
            "format pair needs {} quire bits; Wide<{L}> register has {}",
            spec.need,
            Wide::<L>::BITS
        );
        Self { acc: Wide::zero(), a_fmt: spec.a_fmt, b_fmt: spec.b_fmt, origin: spec.origin, nar: false }
    }

    /// Clear back to the empty accumulation — branch-free per-item reuse
    /// for hot loops (no re-validation, no re-derivation of the span).
    #[inline]
    pub fn reset(&mut self) {
        self.acc = Wide::zero();
        self.nar = false;
    }

    /// Quire width in bits actually required by this format pair — the
    /// "prohibitive hardware overhead" quantity the paper cites ([34]).
    pub fn required_bits(&self) -> u32 {
        let span_hi = (self.a_fmt.max_scale() + self.b_fmt.max_scale() + 2) as u32;
        self.origin + span_hi + 1
    }

    pub fn is_nar(&self) -> bool {
        self.nar
    }

    /// Add the exact product `a·b` into the accumulator.
    pub fn add_product(&mut self, a: Posit, b: Posit) {
        debug_assert_eq!(a.format(), self.a_fmt);
        debug_assert_eq!(b.format(), self.b_fmt);
        let (da, db) = (decode(a), decode(b));
        match (da, db) {
            (Decoded::NaR, _) | (_, Decoded::NaR) => self.nar = true,
            (Decoded::Zero, _) | (_, Decoded::Zero) => {}
            (Decoded::Finite(fa), Decoded::Finite(fb)) => {
                let prod = (fa.frac as u128) * (fb.frac as u128); // exact, ≤ 60 bits
                let pfb = fa.frac_bits + fb.frac_bits; // fraction bits of the product
                let scale = fa.scale + fb.scale;
                // product = prod · 2^(scale - pfb); place at origin + scale - pfb
                let pos = self.origin as i32 + scale - pfb as i32;
                debug_assert!(pos >= 0, "quire origin too high");
                let w = Wide::from_u128_shifted(prod, pos as u32);
                let w = if fa.sign ^ fb.sign { w.neg() } else { w };
                self.acc = self.acc.wrapping_add(&w);
            }
        }
    }

    /// Add a single posit value (format `out_fmt` of the caller's choosing)
    /// exactly — used to fold a previous accumulator value into the quire.
    pub fn add_posit(&mut self, p: Posit) {
        match decode(p) {
            Decoded::NaR => self.nar = true,
            Decoded::Zero => {}
            Decoded::Finite(f) => {
                let pos = self.origin as i32 + f.scale - f.frac_bits as i32;
                debug_assert!(pos >= 0);
                let w = Wide::from_u128_shifted(f.frac as u128, pos as u32);
                let w = if f.sign { w.neg() } else { w };
                self.acc = self.acc.wrapping_add(&w);
            }
        }
    }

    /// Exact value as f64 (for oracles; may round if the sum needs more
    /// than 53 bits, but sign/magnitude are exact).
    pub fn to_f64(&self) -> f64 {
        if self.nar {
            return f64::NAN;
        }
        let neg = self.acc.is_negative();
        let mag = self.acc.abs();
        match mag.msb() {
            None => 0.0,
            Some(msb) => {
                // take the top ≤ 53 bits
                let take = msb.min(52);
                let top = mag.extract_u128(msb - take) as u64;
                let v = top as f64 * 2f64.powi(msb as i32 - take as i32 - self.origin as i32);
                if neg {
                    -v
                } else {
                    v
                }
            }
        }
    }

    /// Round the accumulated value to the nearest posit of `out_fmt`
    /// (single rounding — the whole point of the quire).
    pub fn to_posit(&self, out_fmt: PositFormat) -> Posit {
        if self.nar {
            return Posit::nar(out_fmt);
        }
        let neg = self.acc.is_negative();
        let mag = self.acc.abs();
        let Some(msb) = mag.msb() else {
            return Posit::zero(out_fmt);
        };
        // take up to 127 significant bits, sticky the rest
        let take = msb.min(126);
        let lo = msb - take;
        let sig = mag.extract_u128(lo);
        let sticky = mag.any_below(lo);
        let scale = msb as i32 - self.origin as i32;
        let u = Unpacked { sign: neg, scale, sig, sig_frac_bits: take, sticky };
        Posit::from_bits(encode(u, out_fmt), out_fmt)
    }

    /// Dynamic-range watermark: ⌊log₂|acc|⌋ of the accumulated magnitude,
    /// the quantity the numerics observatory tracks per site to size the
    /// regime span a format must cover. `None` when the accumulator is
    /// zero or NaR-poisoned.
    pub fn watermark_log2(&self) -> Option<i32> {
        if self.nar {
            return None;
        }
        self.acc.abs().msb().map(|m| m as i32 - self.origin as i32)
    }
}

/// Exact dot product `acc + Σ aᵢ·bᵢ` with one final rounding to `out_fmt` —
/// Eq. (2) of the paper computed the quire way. This is the reference
/// semantics every fused unit in this repo is tested against.
pub fn exact_dot(acc: Posit, a: &[Posit], b: &[Posit], out_fmt: PositFormat) -> Posit {
    assert_eq!(a.len(), b.len());
    let a_fmt = a.first().map(|p| p.format()).unwrap_or(out_fmt);
    let b_fmt = b.first().map(|p| p.format()).unwrap_or(out_fmt);
    let mut q = Quire::new(a_fmt, b_fmt).expect("format pair exceeds quire capacity");
    q.add_posit(acc);
    for (&x, &y) in a.iter().zip(b) {
        q.add_product(x, y);
    }
    q.to_posit(out_fmt)
}

#[cfg(test)]
mod tests {
    use super::super::{Posit, PositFormat};
    use super::*;
    use crate::testing::Rng;

    fn p16() -> PositFormat {
        PositFormat::p(16, 2)
    }
    fn p8() -> PositFormat {
        PositFormat::p(8, 2)
    }

    #[test]
    fn empty_quire_is_zero() {
        let q = Quire::new(p16(), p16()).unwrap();
        assert!(q.to_posit(p16()).is_zero());
        assert_eq!(q.to_f64(), 0.0);
    }

    #[test]
    fn single_product_matches_f64() {
        let fmt = p8();
        let mut q = Quire::new(fmt, fmt).unwrap();
        let a = Posit::from_f64(3.0, fmt);
        let b = Posit::from_f64(-5.0, fmt);
        q.add_product(a, b);
        assert_eq!(q.to_f64(), -15.0);
        assert_eq!(q.to_posit(p16()).to_f64(), -15.0);
    }

    #[test]
    fn cancellation_is_exact() {
        // x·y − x·y == exactly 0, even when the products are irrational in
        // the output format.
        let fmt = p16();
        let mut q = Quire::new(fmt, fmt).unwrap();
        let x = Posit::from_f64(1.0 / 3.0, fmt);
        let y = Posit::from_f64(7.0 / 11.0, fmt);
        q.add_product(x, y);
        let nx = Posit::from_f64(-x.to_f64(), fmt);
        q.add_product(nx, y);
        assert!(q.to_posit(fmt).is_zero());
    }

    #[test]
    fn tiny_plus_huge_not_lost() {
        // quire keeps minpos² alive next to maxpos² — the FP64 oracle
        // cannot even represent this sum; check via structural probes.
        let fmt = p8();
        let mut q = Quire::new(fmt, fmt).unwrap();
        q.add_product(Posit::maxpos(fmt), Posit::maxpos(fmt));
        q.add_product(Posit::minpos(fmt), Posit::minpos(fmt));
        // subtract maxpos² again: the surviving value must be minpos²
        let mut q2 = q;
        q2.add_product(Posit::maxpos(fmt), Posit::from_f64(-Posit::maxpos(fmt).to_f64(), fmt));
        let survivor = q2.to_posit(p16());
        assert!(!survivor.is_zero(), "minpos² was lost in the quire");
        assert_eq!(survivor.to_f64().log2(), 2.0 * fmt.min_scale() as f64);
    }

    #[test]
    fn nar_poisons() {
        let fmt = p8();
        let mut q = Quire::new(fmt, fmt).unwrap();
        q.add_product(Posit::nar(fmt), Posit::one(fmt));
        q.add_product(Posit::one(fmt), Posit::one(fmt));
        assert!(q.to_posit(fmt).is_nar());
    }

    #[test]
    fn required_bits_matches_paper_ballpark() {
        // The paper's quire row uses Wm = 256 for P(13/16,2): our required
        // width for P(13,2)×P(13,2) products must be in that ballpark.
        let q = Quire::new(PositFormat::p(13, 2), PositFormat::p(13, 2)).unwrap();
        let bits = q.required_bits();
        assert!((150..320).contains(&bits), "quire width {bits}");
    }

    #[test]
    fn cache_quire_bit_identical_to_default_width() {
        // the one-cache-line register must agree with the 1024-bit one on
        // every path: products, posit folds, rounding, NaR
        let fmt = PositFormat::p(13, 2);
        let spec = QuireSpec::new(fmt, fmt).unwrap();
        assert!(spec.fits_cache_line(), "P(13,2) pair must fit one cache line");
        let mut rng = Rng::seeded(0xCACE);
        let mut small = CacheQuire::from_spec(spec);
        let mut wide = Quire::from_spec(spec);
        for round in 0..200 {
            small.reset();
            wide.reset();
            let seed = Posit::from_f64(rng.normal(), fmt);
            small.add_posit(seed);
            wide.add_posit(seed);
            for _ in 0..12 {
                let a = Posit::from_f64(rng.log_uniform_signed(-12.0, 12.0), fmt);
                let b = Posit::from_f64(rng.log_uniform_signed(-12.0, 12.0), fmt);
                small.add_product(a, b);
                wide.add_product(a, b);
            }
            let out = PositFormat::p(16, 2);
            assert_eq!(small.to_posit(out).bits(), wide.to_posit(out).bits(), "round {round}");
            assert_eq!(small.to_f64().to_bits(), wide.to_f64().to_bits(), "round {round}");
        }
    }

    #[test]
    fn spec_reports_fit_and_required_bits() {
        let narrow = QuireSpec::new(p8(), p8()).unwrap();
        assert!(narrow.fits_cache_line());
        let widest = QuireSpec::new(PositFormat::p(32, 2), PositFormat::p(32, 2)).unwrap();
        assert!(!widest.fits_cache_line(), "P(32,2) span exceeds one cache line");
        assert!(widest.fits::<16>());
        let q = Quire::from_spec(narrow);
        assert_eq!(q.required_bits(), narrow.required_bits());
    }

    #[test]
    fn reset_restores_the_empty_state() {
        let fmt = p16();
        let spec = QuireSpec::new(fmt, fmt).unwrap();
        let mut q = CacheQuire::from_spec(spec);
        q.add_product(Posit::nar(fmt), Posit::one(fmt));
        q.add_product(Posit::from_f64(2.5, fmt), Posit::from_f64(4.0, fmt));
        assert!(q.is_nar());
        q.reset();
        assert!(!q.is_nar());
        assert!(q.to_posit(fmt).is_zero());
        q.add_product(Posit::from_f64(2.5, fmt), Posit::from_f64(4.0, fmt));
        assert_eq!(q.to_f64(), 10.0);
    }

    /// Randomized: exact_dot against an f64 oracle on well-conditioned data
    /// (values ~1, short vectors ⇒ f64 is exact enough to agree after
    /// rounding to P(16,2)).
    #[test]
    fn exact_dot_matches_f64_on_benign_data() {
        let fmt = p16();
        let mut rng = Rng::seeded(0xD07);
        for _ in 0..500 {
            let n = 1 + (rng.next_u64() % 8) as usize;
            let a: Vec<Posit> = (0..n).map(|_| Posit::from_f64(rng.uniform(-2.0, 2.0), fmt)).collect();
            let b: Vec<Posit> = (0..n).map(|_| Posit::from_f64(rng.uniform(-2.0, 2.0), fmt)).collect();
            let acc = Posit::from_f64(rng.uniform(-4.0, 4.0), fmt);
            let exact = exact_dot(acc, &a, &b, fmt);
            let f64_ref: f64 = acc.to_f64()
                + a.iter().zip(&b).map(|(x, y)| x.to_f64() * y.to_f64()).sum::<f64>();
            let direct = Posit::from_f64(f64_ref, fmt);
            // f64 has ≥ 52-12·2 = 28 spare mantissa bits on this data: the
            // only disagreement possible is a 1-ulp double-rounding, which
            // cannot occur with this much slack.
            assert_eq!(exact.bits(), direct.bits(), "a={a:?} b={b:?} acc={acc:?}");
        }
    }

    #[test]
    fn accumulation_order_invariance() {
        // quire sums are exact ⇒ order cannot matter
        let fmt = p16();
        let mut rng = Rng::seeded(42);
        let n = 32;
        let a: Vec<Posit> = (0..n).map(|_| Posit::from_f64(rng.uniform(-100.0, 100.0), fmt)).collect();
        let b: Vec<Posit> = (0..n).map(|_| Posit::from_f64(rng.uniform(-100.0, 100.0), fmt)).collect();
        let fwd = exact_dot(Posit::zero(fmt), &a, &b, fmt);
        let (ra, rb): (Vec<Posit>, Vec<Posit>) =
            (a.iter().rev().cloned().collect(), b.iter().rev().cloned().collect());
        let rev = exact_dot(Posit::zero(fmt), &ra, &rb, fmt);
        assert_eq!(fwd.bits(), rev.bits());
    }
}
