//! Exact conversions between posits and `f64`.
//!
//! For every supported format (n ≤ 32, es ≤ 4) the posit value set is a
//! strict subset of f64: mantissas carry at most 29 bits (< 52) and scales
//! stay within ±480 (< 1022), so `to_f64` is exact and `from_f64` performs
//! a single correct rounding. These conversions are the bridge between the
//! bit-exact hardware models and the FP64 reference workloads (the paper
//! extracts its conv1 tensors in FP64 for exactly this role).

use super::{decode, encode, Decoded, Posit, PositFormat, Unpacked};

/// Exact value of a posit as `f64`. NaR maps to NaN.
pub fn to_f64(p: Posit) -> f64 {
    match decode(p) {
        Decoded::Zero => 0.0,
        Decoded::NaR => f64::NAN,
        Decoded::Finite(f) => {
            let mag = (f.frac as f64) * ((f.scale - f.frac_bits as i32) as f64).exp2();
            if f.sign {
                -mag
            } else {
                mag
            }
        }
    }
}

/// Nearest posit to `v` (round to nearest, ties to even pattern; a nonzero
/// finite `v` never becomes zero or NaR). NaN and ±∞ map to NaR, matching
/// the posit standard's conversion rule.
pub fn from_f64(v: f64, fmt: PositFormat) -> Posit {
    if v == 0.0 {
        return Posit::zero(fmt);
    }
    if v.is_nan() || v.is_infinite() {
        return Posit::nar(fmt);
    }
    let bits = v.to_bits();
    let sign = bits >> 63 == 1;
    let biased = ((bits >> 52) & 0x7FF) as i32;
    let mantissa = bits & ((1u64 << 52) - 1);

    let (scale, sig, fb): (i32, u128, u32) = if biased == 0 {
        // subnormal f64: value = mantissa · 2^-1074, normalized so the MSB
        // of the mantissa becomes the hidden bit
        let msb = 63 - mantissa.leading_zeros();
        (msb as i32 - 1074, mantissa as u128, msb)
    } else {
        (biased - 1023, ((1u64 << 52) | mantissa) as u128, 52)
    };
    Posit::from_bits(
        encode(Unpacked { sign, scale, sig, sig_frac_bits: fb, sticky: false }, fmt),
        fmt,
    )
}

#[cfg(test)]
mod tests {
    use super::super::{Posit, PositFormat};
    use super::*;

    #[test]
    fn specials() {
        let fmt = PositFormat::p(16, 2);
        assert_eq!(from_f64(0.0, fmt), Posit::zero(fmt));
        assert_eq!(from_f64(-0.0, fmt), Posit::zero(fmt));
        assert!(from_f64(f64::NAN, fmt).is_nar());
        assert!(from_f64(f64::INFINITY, fmt).is_nar());
        assert!(from_f64(f64::NEG_INFINITY, fmt).is_nar());
        assert!(to_f64(Posit::nar(fmt)).is_nan());
        assert_eq!(to_f64(Posit::zero(fmt)), 0.0);
    }

    #[test]
    fn known_values_p8_2() {
        let fmt = PositFormat::p(8, 2);
        for &(v, bits) in &[
            (1.0, 0x40u32),
            (-1.0, 0xC0),
            (11.0, 0b0101_1011),
            (16.0, 0b0110_0000),
            (0.5, 0b0011_1000),
        ] {
            assert_eq!(from_f64(v, fmt).bits(), bits, "from_f64({v})");
            assert_eq!(to_f64(Posit::from_bits(bits, fmt)), v, "to_f64({bits:#x})");
        }
    }

    /// Exhaustive exact round-trip for a spread of formats: every finite
    /// posit → f64 → posit must be the identity (f64 is strictly wider).
    #[test]
    fn roundtrip_via_f64_exhaustive() {
        for &(n, es) in &[(8u32, 0u32), (8, 1), (8, 2), (8, 3), (10, 2), (13, 2), (16, 2), (16, 1), (12, 0)] {
            let fmt = PositFormat::p(n, es);
            for bits in 0..fmt.cardinality() as u32 {
                let p = Posit::from_bits(bits, fmt);
                if p.is_nar() {
                    continue;
                }
                let back = from_f64(to_f64(p), fmt);
                assert_eq!(back.bits(), bits, "{fmt} bits={bits:#x} v={}", to_f64(p));
            }
        }
    }

    /// from_f64 must pick the nearest posit under the posit rounding rule.
    /// Within a regime (fraction-linear region) the bit-field midpoint
    /// equals the arithmetic midpoint, so nearest-by-value holds there;
    /// across regime boundaries (where posit rounding is defined on the
    /// encoding field, as in SoftPosit) we check the weaker guarantee that
    /// any point in the open gap maps to one of the two endpoints.
    #[test]
    fn from_f64_nearest_p8() {
        let fmt = PositFormat::p(8, 2);
        for bits in 0..255u32 {
            let a = Posit::from_bits(bits, fmt);
            let b = a.succ();
            if a.is_nar() || b.is_nar() || a.is_zero() || b.is_zero() {
                continue;
            }
            let (va, vb) = (to_f64(a), to_f64(b));
            let mid = va + (vb - va) / 2.0;
            let eps = (vb - va) / 64.0;
            // The gap is fraction-linear (arithmetic midpoint == encoding
            // midpoint) only when both endpoints share regime AND exponent;
            // otherwise exponent/regime bits were cut and posit rounding is
            // defined on the encoding field.
            let (fa, fb2) = (a.decode().fields(), b.decode().fields());
            if fa.k == fb2.k && fa.exp == fb2.exp {
                assert_eq!(from_f64(mid - eps, fmt).bits(), a.bits(), "left half near {va}..{vb}");
                assert_eq!(from_f64(mid + eps, fmt).bits(), b.bits(), "right half near {va}..{vb}");
            } else {
                for v in [mid - eps, mid, mid + eps] {
                    let got = from_f64(v, fmt).bits();
                    assert!(got == a.bits() || got == b.bits(), "{v} escaped gap {va}..{vb}");
                }
            }
        }
    }

    #[test]
    fn saturation() {
        let fmt = PositFormat::p(8, 2);
        assert_eq!(from_f64(1e30, fmt), Posit::maxpos(fmt));
        assert_eq!(from_f64(1e-30, fmt), Posit::minpos(fmt));
        assert_eq!(from_f64(-1e30, fmt).bits(), Posit::maxpos(fmt).bits().wrapping_neg() & 0xFF);
        // f64 subnormals still round to minpos, not zero
        assert_eq!(from_f64(f64::MIN_POSITIVE / 8.0, fmt), Posit::minpos(fmt));
    }

    #[test]
    fn p32_precision_preserved() {
        let fmt = PositFormat::p(32, 2);
        let v = 3.141592653589793f64;
        let p = from_f64(v, fmt);
        // P(32,2) near 1.0 has 27 fraction bits → relative error ≤ 2^-28
        assert!((to_f64(p) - v).abs() / v < 2f64.powi(-27));
    }
}
