//! Posit field extraction — the software twin of PDPU pipeline stage S1.
//!
//! Decoding follows Eq. (1) of the paper: an n-bit pattern splits into
//! sign, regime (run-length coded `k`), `es`-bit exponent and mantissa.
//! Negative patterns are two's-complemented before field extraction.
//! The extracted mantissa is left-aligned to the format's maximum fraction
//! width so every decoded value shares one fixed-point Q format — exactly
//! what the hardware decoder does so downstream datapath widths are static.

use super::Posit;

/// A decoded finite posit (or zero / NaR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decoded {
    Zero,
    NaR,
    Finite(Fields),
}

/// Components of a finite posit value: `(-1)^sign · 2^scale · frac/2^frac_bits`
/// with `frac` normalized to `[2^frac_bits, 2^(frac_bits+1))` — i.e. `1.m`
/// with the hidden bit explicit at position `frac_bits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fields {
    pub sign: bool,
    /// Combined scale `k·2^es + e` (regime and exponent merged).
    pub scale: i32,
    /// Normalized significand `1.m`, left-aligned: exactly `frac_bits + 1`
    /// significant bits, hidden bit at bit position `frac_bits`.
    pub frac: u64,
    /// Number of fractional bits in `frac` (== `fmt.max_frac_bits()`).
    pub frac_bits: u32,
    /// Regime value `k` (kept for cost-model / pipeline introspection).
    pub k: i32,
    /// Exponent field value `e` (after zero-fill of truncated bits).
    pub exp: u32,
}

impl Decoded {
    /// Unwrap finite fields, panicking on zero/NaR. Test convenience.
    pub fn fields(&self) -> Fields {
        match self {
            Decoded::Finite(f) => *f,
            other => panic!("expected finite posit, got {other:?}"),
        }
    }

    pub fn is_zero(&self) -> bool {
        matches!(self, Decoded::Zero)
    }

    pub fn is_nar(&self) -> bool {
        matches!(self, Decoded::NaR)
    }
}

/// Decode an n-bit posit pattern into [`Decoded`] fields.
pub fn decode(p: Posit) -> Decoded {
    let fmt = p.format();
    let n = fmt.n();
    let es = fmt.es();
    let bits = p.bits();

    if bits == 0 {
        return Decoded::Zero;
    }
    if bits == fmt.nar_bits() {
        return Decoded::NaR;
    }

    let sign = (bits >> (n - 1)) & 1 == 1;
    // two's complement within the n-bit ring for negative values
    let mag = if sign { bits.wrapping_neg() & fmt.mask() } else { bits };

    // Left-align the n-1 body bits (regime | exponent | fraction) in a u32
    // so leading_zeros() gives us the regime run length directly.
    let body_len = n - 1;
    let body = mag << (32 - body_len); // sign bit shifted out; top bit = first regime bit

    let r0 = body >> 31; // first regime bit
    let run = if r0 == 1 {
        (!body).leading_zeros().min(body_len)
    } else {
        body.leading_zeros().min(body_len)
    };
    let k: i32 = if r0 == 1 { run as i32 - 1 } else { -(run as i32) };

    // Regime consumes `run` identical bits plus one terminator bit, unless
    // the run fills the entire body (maxpos/minpos-like patterns).
    let consumed = (run + 1).min(body_len);
    let rem = body_len - consumed;

    // Remaining bits hold exponent then fraction. Truncated exponent bits
    // are zero-filled on the right (posit standard 2022 semantics).
    let rest: u32 = if rem == 0 { 0 } else { (body << consumed) >> (32 - rem) };
    let e_bits = rem.min(es);
    let exp: u32 = if es == 0 || e_bits == 0 {
        0
    } else {
        (rest >> (rem - e_bits)) << (es - e_bits)
    };
    let fb = rem - e_bits; // fraction bits actually present
    let frac_raw: u64 = if fb == 0 { 0 } else { (rest & ((1u32 << fb) - 1)) as u64 };

    // Left-align the mantissa to the format's max fraction width, hidden
    // bit explicit — fixed Q format for the whole datapath.
    let mb = fmt.max_frac_bits();
    debug_assert!(fb <= mb, "fraction bits {fb} exceed max {mb} for {fmt}");
    let frac = ((1u64 << fb) | frac_raw) << (mb - fb);

    let scale = k * fmt.useed_log2() + exp as i32;
    Decoded::Finite(Fields { sign, scale, frac, frac_bits: mb, k, exp })
}

#[cfg(test)]
mod tests {
    use super::super::PositFormat;
    use super::*;

    fn dec(bits: u32, n: u32, es: u32) -> Decoded {
        decode(Posit::from_bits(bits, PositFormat::p(n, es)))
    }

    #[test]
    fn specials() {
        assert_eq!(dec(0, 16, 2), Decoded::Zero);
        assert_eq!(dec(0x8000, 16, 2), Decoded::NaR);
        assert_eq!(dec(0, 8, 0), Decoded::Zero);
        assert_eq!(dec(0x80, 8, 0), Decoded::NaR);
    }

    #[test]
    fn one_decodes_to_scale_zero() {
        for &(n, es) in &[(8u32, 0u32), (8, 2), (16, 1), (16, 2), (32, 2), (5, 2)] {
            let fmt = PositFormat::p(n, es);
            let f = decode(Posit::one(fmt)).fields();
            assert!(!f.sign);
            assert_eq!(f.scale, 0, "P({n},{es})");
            assert_eq!(f.frac, 1u64 << f.frac_bits); // exactly 1.0
        }
    }

    /// Paper Fig. 2 decoding instance: P(8,2) pattern 0b0_10_11_011.
    /// regime 10 → k=0, exponent 11 → e=3, mantissa 011 → 1.375;
    /// value = 2^(0·4+3) · 1.375 = 11.
    #[test]
    fn paper_fig2_instance_positive() {
        let f = dec(0b0_10_11_011, 8, 2).fields();
        assert!(!f.sign);
        assert_eq!(f.k, 0);
        assert_eq!(f.exp, 3);
        assert_eq!(f.scale, 3);
        assert_eq!(f.frac_bits, 3);
        assert_eq!(f.frac, 0b1011); // 1.011₂ = 1.375
        let p = Posit::from_bits(0b0_10_11_011, PositFormat::p(8, 2));
        assert_eq!(p.to_f64(), 11.0);
    }

    /// Negative instance: two's complement then decode. -(11) pattern is
    /// the two's complement of the +11 pattern.
    #[test]
    fn paper_fig2_instance_negative() {
        let pos = 0b0_10_11_011u32;
        let neg = pos.wrapping_neg() & 0xFF;
        let f = dec(neg, 8, 2).fields();
        assert!(f.sign);
        assert_eq!(f.scale, 3);
        assert_eq!(f.frac, 0b1011);
        let p = Posit::from_bits(neg, PositFormat::p(8, 2));
        assert_eq!(p.to_f64(), -11.0);
    }

    #[test]
    fn maxpos_minpos_scales() {
        for &(n, es) in &[(8u32, 0u32), (8, 2), (16, 2), (13, 2), (10, 2), (32, 2)] {
            let fmt = PositFormat::p(n, es);
            let f = decode(Posit::maxpos(fmt)).fields();
            assert_eq!(f.scale, fmt.max_scale(), "maxpos {fmt}");
            assert_eq!(f.frac, 1u64 << f.frac_bits, "maxpos mantissa is 1.0");
            let f = decode(Posit::minpos(fmt)).fields();
            assert_eq!(f.scale, fmt.min_scale(), "minpos {fmt}");
        }
    }

    #[test]
    fn regime_run_without_terminator() {
        // P(8,2) pattern 0b0_1111111: run fills the body, k = run-1 = 6.
        let f = dec(0b0111_1111, 8, 2).fields();
        assert_eq!(f.k, 6);
        assert_eq!(f.exp, 0);
        // P(8,2) pattern 0b0_0000001: run of 6 zeros + terminator, k = -6.
        let f = dec(0b0000_0001, 8, 2).fields();
        assert_eq!(f.k, -6);
    }

    #[test]
    fn truncated_exponent_zero_fill() {
        // P(8,2) 0b0_000001_1: regime 5 zeros+term (k=-5), one exponent bit
        // '1' present out of es=2 → e = 0b10 = 2 (zero-filled LSB).
        let f = dec(0b0000_0011, 8, 2).fields();
        assert_eq!(f.k, -5);
        assert_eq!(f.exp, 2);
        assert_eq!(f.scale, -5 * 4 + 2);
    }

    #[test]
    fn mantissa_alignment_is_uniform() {
        let fmt = PositFormat::p(16, 2);
        // Every finite decode must land in [2^mb, 2^(mb+1))
        for bits in (1u32..0x1_0000).step_by(97) {
            let p = Posit::from_bits(bits, fmt);
            if p.is_nar() {
                continue;
            }
            let f = decode(p).fields();
            assert_eq!(f.frac_bits, 11);
            assert!(f.frac >= (1 << 11) && f.frac < (1 << 12), "bits={bits:#x}");
        }
    }

    #[test]
    fn n32_roundtrip_sane() {
        let fmt = PositFormat::p(32, 2);
        let f = decode(Posit::one(fmt)).fields();
        assert_eq!(f.frac_bits, 27);
        assert_eq!(f.scale, 0);
        let f = decode(Posit::maxpos(fmt)).fields();
        assert_eq!(f.scale, 120);
    }
}
