//! Fixed-width signed big integers for exact accumulation.
//!
//! `Wide<L>` is an `L·64`-bit two's-complement integer. It is the storage
//! type behind the [`super::quire::Quire`] exact accumulator and the exact
//! dot-product oracle used throughout the test suite. The width is a const
//! generic so the quire can be sized to the format: P(16,2) needs ~280 bits
//! of span for arbitrarily long dot products, comfortably inside
//! `Wide<8>` (512 bits); wider formats use `Wide<16>`.
//!
//! Only the operations the accumulator needs are implemented (add, neg,
//! shifts, comparisons, bit scan) — this is a datapath model, not a bignum
//! library.

/// `L·64`-bit two's-complement integer, little-endian limbs.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Wide<const L: usize> {
    pub limbs: [u64; L],
}

impl<const L: usize> Wide<L> {
    pub const BITS: u32 = 64 * L as u32;

    #[inline]
    pub fn zero() -> Self {
        Self { limbs: [0; L] }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// Sign of the two's-complement value (true = negative).
    #[inline]
    pub fn is_negative(&self) -> bool {
        self.limbs[L - 1] >> 63 == 1
    }

    /// Construct from a u128 magnitude placed at bit offset `shift`.
    /// Panics (debug) if the value would overflow the width.
    pub fn from_u128_shifted(v: u128, shift: u32) -> Self {
        let mut out = Self::zero();
        if v == 0 {
            return out;
        }
        debug_assert!(
            shift + (128 - v.leading_zeros()) <= Self::BITS - 1,
            "value overflows Wide<{L}>: {} bits at shift {shift}",
            128 - v.leading_zeros()
        );
        let limb = (shift / 64) as usize;
        let off = shift % 64;
        // spread the (up to) 128-bit value across up to 3 limbs
        let parts = if off == 0 {
            [(limb, v as u64), (limb + 1, (v >> 64) as u64), (limb + 2, 0)]
        } else {
            [
                (limb, (v as u64) << off),
                (limb + 1, (v >> (64 - off)) as u64),
                (limb + 2, (v >> 64 >> (64 - off)) as u64),
            ]
        };
        for (i, part) in parts {
            if i < L && part != 0 {
                out.limbs[i] = part;
            }
        }
        out
    }

    /// Wrapping two's-complement addition.
    pub fn wrapping_add(&self, rhs: &Self) -> Self {
        let mut out = Self::zero();
        let mut carry = 0u64;
        for i in 0..L {
            let (s1, c1) = self.limbs[i].overflowing_add(rhs.limbs[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            out.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        out
    }

    /// Two's-complement negation.
    pub fn neg(&self) -> Self {
        let mut out = Self::zero();
        let mut carry = 1u64;
        for i in 0..L {
            let (s, c) = (!self.limbs[i]).overflowing_add(carry);
            out.limbs[i] = s;
            carry = c as u64;
        }
        out
    }

    /// Absolute value (as the same unsigned width; MIN negates to itself,
    /// which cannot occur for accumulator values with headroom).
    pub fn abs(&self) -> Self {
        if self.is_negative() {
            self.neg()
        } else {
            *self
        }
    }

    /// Position of the most significant set bit, or None if zero.
    pub fn msb(&self) -> Option<u32> {
        for i in (0..L).rev() {
            if self.limbs[i] != 0 {
                return Some(i as u32 * 64 + 63 - self.limbs[i].leading_zeros());
            }
        }
        None
    }

    /// Extract 128 bits starting at bit `lo` (bits above the width read 0).
    pub fn extract_u128(&self, lo: u32) -> u128 {
        let limb = lo / 64;
        let off = lo % 64;
        let l0 = self.limb_or_zero(limb) as u128;
        let l1 = self.limb_or_zero(limb + 1) as u128;
        let l2 = self.limb_or_zero(limb + 2) as u128;
        if off == 0 {
            l0 | (l1 << 64)
        } else {
            (l0 >> off) | (l1 << (64 - off)) | (l2 << (128 - off))
        }
    }

    #[inline]
    fn limb_or_zero(&self, i: u32) -> u64 {
        if (i as usize) < L {
            self.limbs[i as usize]
        } else {
            0
        }
    }

    /// True if any bit strictly below position `lo` is set (sticky probe).
    pub fn any_below(&self, lo: u32) -> bool {
        let limb = (lo / 64) as usize;
        let off = lo % 64;
        for i in 0..limb.min(L) {
            if self.limbs[i] != 0 {
                return true;
            }
        }
        if limb < L && off > 0 {
            if self.limbs[limb] & ((1u64 << off) - 1) != 0 {
                return true;
            }
        }
        false
    }

    /// Compare as two's-complement signed values.
    pub fn signed_cmp(&self, rhs: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match (self.is_negative(), rhs.is_negative()) {
            (true, false) => Ordering::Less,
            (false, true) => Ordering::Greater,
            _ => {
                for i in (0..L).rev() {
                    match self.limbs[i].cmp(&rhs.limbs[i]) {
                        Ordering::Equal => continue,
                        ord => return ord,
                    }
                }
                Ordering::Equal
            }
        }
    }
}

impl<const L: usize> std::fmt::Debug for Wide<L> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Wide<{L}>[0x")?;
        for i in (0..L).rev() {
            write!(f, "{:016x}", self.limbs[i])?;
            if i > 0 {
                write!(f, "_")?;
            }
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Ordering;

    type W = Wide<4>;

    #[test]
    fn zero_and_sign() {
        assert!(W::zero().is_zero());
        assert!(!W::zero().is_negative());
        let neg_one = W::zero().wrapping_add(&W::from_u128_shifted(1, 0)).neg();
        assert!(neg_one.is_negative());
        assert_eq!(neg_one.limbs, [u64::MAX; 4]);
    }

    #[test]
    fn from_u128_shifted_placements() {
        // simple placement at offset 0
        let w = W::from_u128_shifted(0xDEAD_BEEF, 0);
        assert_eq!(w.limbs[0], 0xDEAD_BEEF);
        // offset inside a limb
        let w = W::from_u128_shifted(0xFF, 4);
        assert_eq!(w.limbs[0], 0xFF0);
        // straddling limb boundaries
        let w = W::from_u128_shifted(u128::MAX >> 1, 60);
        assert_eq!(w.msb(), Some(60 + 126));
        assert!(!w.any_below(60));
        assert!(w.any_below(61));
        // exact limb boundary
        let w = W::from_u128_shifted(1, 64);
        assert_eq!(w.limbs, [0, 1, 0, 0]);
        let w = W::from_u128_shifted(1, 128);
        assert_eq!(w.limbs, [0, 0, 1, 0]);
    }

    #[test]
    fn add_neg_roundtrip() {
        let a = W::from_u128_shifted(0x1234_5678_9ABC_DEF0_1111, 50);
        let b = W::from_u128_shifted(0xFFFF_FFFF_FFFF_FFFF, 10);
        let s = a.wrapping_add(&b);
        let back = s.wrapping_add(&b.neg());
        assert_eq!(back, a);
        // a + (-a) == 0
        assert!(a.wrapping_add(&a.neg()).is_zero());
    }

    #[test]
    fn carry_propagation() {
        // all-ones + 1 ripples through every limb
        let ones = W { limbs: [u64::MAX; 4] };
        let one = W::from_u128_shifted(1, 0);
        assert!(ones.wrapping_add(&one).is_zero());
    }

    #[test]
    fn msb_and_extract() {
        let w = W::from_u128_shifted(0b1011, 100);
        assert_eq!(w.msb(), Some(103));
        assert_eq!(w.extract_u128(100) & 0xF, 0b1011);
        assert_eq!(w.extract_u128(101) & 0x7, 0b101);
        assert_eq!(W::zero().msb(), None);
    }

    #[test]
    fn extract_across_limbs() {
        let w = W::from_u128_shifted(0xABCD_EF01_2345_6789_ABCD_EF01, 37);
        assert_eq!(w.extract_u128(37) & ((1u128 << 96) - 1), 0xABCD_EF01_2345_6789_ABCD_EF01);
    }

    #[test]
    fn signed_cmp_cases() {
        let one = W::from_u128_shifted(1, 0);
        let minus = one.neg();
        let big = W::from_u128_shifted(1, 200);
        assert_eq!(minus.signed_cmp(&one), Ordering::Less);
        assert_eq!(one.signed_cmp(&minus), Ordering::Greater);
        assert_eq!(one.signed_cmp(&one), Ordering::Equal);
        assert_eq!(big.signed_cmp(&one), Ordering::Greater);
        assert_eq!(big.neg().signed_cmp(&minus), Ordering::Less);
    }

    #[test]
    fn any_below_boundaries() {
        let w = W::from_u128_shifted(1, 64);
        assert!(!w.any_below(64));
        assert!(w.any_below(65));
        assert!(!W::zero().any_below(255));
    }
}
