//! Correctly-rounded scalar posit arithmetic: add, sub, mul, fma.
//!
//! Each operation performs exactly **one** rounding (decode → exact
//! compute with sticky → encode). These are the building blocks of the
//! *discrete* dot-product units PDPU is compared against in Table I: a
//! discrete DPU rounds after every multiply and every add, which is
//! precisely the per-op rounding implemented here.
//!
//! Mixed formats are allowed everywhere: inputs may differ from each other
//! and from the output format, mirroring the paper's mixed-precision
//! P(n_in / n_out, es) notation.

use super::{decode, encode, Decoded, Posit, PositFormat, Unpacked};

/// Negate (exact; posits are symmetric under negation).
pub fn p_neg(a: Posit) -> Posit {
    let fmt = a.format();
    Posit::from_bits(a.bits().wrapping_neg(), fmt)
}

/// Correctly-rounded multiplication into `out_fmt`.
pub fn p_mul(a: Posit, b: Posit, out_fmt: PositFormat) -> Posit {
    match (decode(a), decode(b)) {
        (Decoded::NaR, _) | (_, Decoded::NaR) => Posit::nar(out_fmt),
        (Decoded::Zero, _) | (_, Decoded::Zero) => Posit::zero(out_fmt),
        (Decoded::Finite(fa), Decoded::Finite(fb)) => {
            let sig = (fa.frac as u128) * (fb.frac as u128);
            let fb_bits = fa.frac_bits + fb.frac_bits;
            // product of 1.x × 1.y ∈ [1,4): normalize may shift by one
            let u = Unpacked::normalize(fa.sign ^ fb.sign, fa.scale + fb.scale, sig, fb_bits, false)
                .expect("nonzero product");
            Posit::from_bits(encode(u, out_fmt), out_fmt)
        }
    }
}

/// Correctly-rounded addition into `out_fmt`.
pub fn p_add(a: Posit, b: Posit, out_fmt: PositFormat) -> Posit {
    match (decode(a), decode(b)) {
        (Decoded::NaR, _) | (_, Decoded::NaR) => Posit::nar(out_fmt),
        (Decoded::Zero, Decoded::Zero) => Posit::zero(out_fmt),
        (Decoded::Zero, Decoded::Finite(f)) | (Decoded::Finite(f), Decoded::Zero) => {
            // still a rounding: the surviving operand may not be
            // representable in out_fmt
            let u = Unpacked {
                sign: f.sign,
                scale: f.scale,
                sig: f.frac as u128,
                sig_frac_bits: f.frac_bits,
                sticky: false,
            };
            Posit::from_bits(encode(u, out_fmt), out_fmt)
        }
        (Decoded::Finite(fa), Decoded::Finite(fb)) => add_fields(
            fa.sign,
            fa.scale,
            fa.frac as u128,
            fa.frac_bits,
            fb.sign,
            fb.scale,
            fb.frac as u128,
            fb.frac_bits,
            out_fmt,
        ),
    }
}

/// Correctly-rounded subtraction into `out_fmt`.
pub fn p_sub(a: Posit, b: Posit, out_fmt: PositFormat) -> Posit {
    p_add(a, p_neg(b), out_fmt)
}

/// Correctly-rounded fused multiply-add `a·b + c` into `out_fmt` — the
/// single-rounding FMA semantics of the posit FMA baselines [17][35].
pub fn p_fma(a: Posit, b: Posit, c: Posit, out_fmt: PositFormat) -> Posit {
    let (da, db, dc) = (decode(a), decode(b), decode(c));
    if da.is_nar() || db.is_nar() || dc.is_nar() {
        return Posit::nar(out_fmt);
    }
    match (da, db) {
        (Decoded::Zero, _) | (_, Decoded::Zero) => match dc {
            Decoded::Zero => Posit::zero(out_fmt),
            Decoded::Finite(f) => {
                let u = Unpacked {
                    sign: f.sign,
                    scale: f.scale,
                    sig: f.frac as u128,
                    sig_frac_bits: f.frac_bits,
                    sticky: false,
                };
                Posit::from_bits(encode(u, out_fmt), out_fmt)
            }
            Decoded::NaR => unreachable!(),
        },
        (Decoded::Finite(fa), Decoded::Finite(fb)) => {
            let psig = (fa.frac as u128) * (fb.frac as u128);
            let pfb = fa.frac_bits + fb.frac_bits;
            let psign = fa.sign ^ fb.sign;
            let pscale = fa.scale + fb.scale;
            match dc {
                Decoded::Zero => {
                    let u = Unpacked::normalize(psign, pscale, psig, pfb, false).unwrap();
                    Posit::from_bits(encode(u, out_fmt), out_fmt)
                }
                Decoded::Finite(fc) => add_fields(
                    psign, pscale, psig, pfb, fc.sign, fc.scale, fc.frac as u128, fc.frac_bits, out_fmt,
                ),
                Decoded::NaR => unreachable!(),
            }
        }
        _ => unreachable!(),
    }
}

/// Exact signed addition of two unpacked magnitudes followed by a single
/// rounding. Shared by add and fma.
///
/// Strategy: bring both to a common fixed-point grid inside a u128 with
/// headroom; shifts that would fall off the bottom fold into sticky.
#[allow(clippy::too_many_arguments)]
fn add_fields(
    s1: bool,
    e1: i32,
    m1: u128,
    f1: u32,
    s2: bool,
    e2: i32,
    m2: u128,
    f2: u32,
    out_fmt: PositFormat,
) -> Posit {
    // Normalize operand order so |op1| has the larger scale (for equal
    // scales order doesn't matter for exactness).
    let (s1, e1, m1, f1, s2, e2, m2, f2) =
        if e1 >= e2 { (s1, e1, m1, f1, s2, e2, m2, f2) } else { (s2, e2, m2, f2, s1, e1, m1, f1) };

    // Put m1 at a fixed reference: value = m1 · 2^(e1 - f1). Align m2 to the
    // same grid: shift by (e1 - f1) - (e2 - f2) relative bit positions.
    //
    // Give both operands a common fraction width F = max(f1, f2) + headroom,
    // keeping everything ≤ 127 bits: significands are ≤ 61 bits (mantissa
    // products), so F ≤ 64 leaves ≥ 63 bits of alignment room; larger
    // alignment distances collapse into sticky.
    let fmax = f1.max(f2);
    let a1 = m1 << (fmax - f1); // exact
    let a2 = m2 << (fmax - f2);
    let diff = (e1 - e2) as u32; // ≥ 0 by the swap above

    let headroom = a1.leading_zeros().saturating_sub(1);
    let (lhs, rhs, grid_fb, sticky) = if diff <= headroom {
        // shift the larger operand up — fully exact
        (a1 << diff, a2, fmax + diff, false)
    } else {
        // shift the larger up as far as possible, the smaller down with sticky
        let up = headroom;
        let down = diff - up;
        let lhs = a1 << up;
        if down >= 127 {
            (lhs, 0u128, fmax + up, m2 != 0)
        } else {
            let sticky = a2 & ((1u128 << down) - 1) != 0;
            (lhs, a2 >> down, fmax + up, sticky)
        }
    };

    // signed add in i128-like arithmetic over u128 magnitudes
    let (sum_sign, sum_mag, borrow_sticky) = if s1 == s2 {
        (s1, lhs.checked_add(rhs).expect("headroom guaranteed"), false)
    } else if lhs >= rhs {
        (s1, lhs - rhs, false)
    } else {
        (s2, rhs - lhs, false)
    };
    let _ = borrow_sticky;

    // NOTE on sticky during effective subtraction: the discarded bits of the
    // smaller operand belong to the value being subtracted. Folding them
    // into a plain sticky flag can mis-round by one ulp in the borrow case
    // (sticky says "a bit more magnitude below", but subtraction means the
    // true result is *smaller*). Handle by biasing: when signs differ and
    // sticky is set, subtract one ulp from the grid and set sticky — the
    // true value lies strictly between (sum_mag - 1) and sum_mag.
    let (sum_mag, sticky) = if sticky && s1 != s2 {
        (sum_mag - 1, true)
    } else {
        (sum_mag, sticky)
    };

    match Unpacked::normalize(sum_sign, 0 /* adjusted below */, sum_mag, grid_fb, sticky) {
        None => Posit::zero(out_fmt),
        Some(mut u) => {
            // normalize() computed scale relative to "1.0 at grid_fb"; the
            // grid's 1.0 sits at value 2^(e1 - f1 + (grid_fb - ...)) — easier:
            // value = sum_mag · 2^(e1 - f1 - (grid_fb - fmax) - (fmax - f1))
            //       = sum_mag · 2^(e1 - grid_fb + (grid_fb - fmax) ... )
            // Work it out directly: a1 was m1 · 2^(fmax-f1) on a grid where
            // one grid-ulp = 2^(e1 - f1 - (fmax - f1) - up) = 2^(e1 - fmax - up)
            // with up = grid_fb - fmax. So value = sum_mag · 2^(e1 - grid_fb).
            u.scale += e1;
            Posit::from_bits(encode(u, out_fmt), out_fmt)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Posit, PositFormat};
    use super::*;
    use crate::posit::quire::exact_dot;
    use crate::testing::Rng;

    fn fmt(n: u32, es: u32) -> PositFormat {
        PositFormat::p(n, es)
    }

    /// Oracle for small formats: compute in f64 (exact for P(8,·) operands
    /// and results fit far inside f64), then convert with a single rounding.
    fn f64_op(a: Posit, b: Posit, out: PositFormat, op: fn(f64, f64) -> f64) -> Posit {
        Posit::from_f64(op(a.to_f64(), b.to_f64()), out)
    }

    #[test]
    fn add_exhaustive_p8_all_es() {
        for es in 0..=2 {
            let f = fmt(8, es);
            for x in 0..256u32 {
                for y in 0..256u32 {
                    let (a, b) = (Posit::from_bits(x, f), Posit::from_bits(y, f));
                    let got = p_add(a, b, f);
                    let want = if a.is_nar() || b.is_nar() {
                        Posit::nar(f)
                    } else {
                        f64_op(a, b, f, |u, v| u + v)
                    };
                    assert_eq!(
                        got.bits(),
                        want.bits(),
                        "P(8,{es}) {x:#x}+{y:#x}: {a:?} + {b:?} got {got:?} want {want:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn mul_exhaustive_p8_all_es() {
        for es in 0..=2 {
            let f = fmt(8, es);
            for x in 0..256u32 {
                for y in 0..256u32 {
                    let (a, b) = (Posit::from_bits(x, f), Posit::from_bits(y, f));
                    let got = p_mul(a, b, f);
                    let want = if a.is_nar() || b.is_nar() {
                        Posit::nar(f)
                    } else {
                        f64_op(a, b, f, |u, v| u * v)
                    };
                    assert_eq!(got.bits(), want.bits(), "P(8,{es}) {x:#x}·{y:#x}");
                }
            }
        }
    }

    #[test]
    fn mixed_precision_widening_is_exact() {
        // P(8,2) → P(16,2) add: every operand pair is exactly representable
        // in the wider format, so the result equals the f64 computation.
        let (f8, f16) = (fmt(8, 2), fmt(16, 2));
        for x in (0..256u32).step_by(3) {
            for y in (0..256u32).step_by(7) {
                let (a, b) = (Posit::from_bits(x, f8), Posit::from_bits(y, f8));
                if a.is_nar() || b.is_nar() {
                    continue;
                }
                let got = p_add(a, b, f16);
                let want = Posit::from_f64(a.to_f64() + b.to_f64(), f16);
                assert_eq!(got.bits(), want.bits());
                let got = p_mul(a, b, f16);
                let want = Posit::from_f64(a.to_f64() * b.to_f64(), f16);
                assert_eq!(got.bits(), want.bits());
            }
        }
    }

    /// fma must agree with the exact quire on a single product + addend —
    /// both are single-rounding semantics of the same value.
    #[test]
    fn fma_matches_quire_randomized() {
        let f = fmt(16, 2);
        let mut rng = Rng::seeded(0xF3A);
        for i in 0..20_000 {
            let a = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            let b = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            let c = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if a.is_nar() || b.is_nar() || c.is_nar() {
                continue;
            }
            let got = p_fma(a, b, c, f);
            let want = exact_dot(c, &[a], &[b], f);
            assert_eq!(got.bits(), want.bits(), "iter {i}: {a:?}·{b:?}+{c:?}");
        }
    }

    /// add must agree with the quire too (quire of a·1 + c).
    #[test]
    fn add_matches_quire_randomized_p16() {
        let f = fmt(16, 2);
        let one = Posit::one(f);
        let mut rng = Rng::seeded(0xADD);
        for _ in 0..20_000 {
            let a = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            let c = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if a.is_nar() || c.is_nar() {
                continue;
            }
            assert_eq!(p_add(a, c, f).bits(), exact_dot(c, &[a], &[one], f).bits(), "{a:?}+{c:?}");
        }
    }

    #[test]
    fn algebraic_identities() {
        let f = fmt(16, 2);
        let mut rng = Rng::seeded(7);
        for _ in 0..5_000 {
            let a = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if a.is_nar() {
                continue;
            }
            let zero = Posit::zero(f);
            let one = Posit::one(f);
            // identity elements
            assert_eq!(p_add(a, zero, f).bits(), a.bits());
            assert_eq!(p_mul(a, one, f).bits(), a.bits());
            // x - x == 0
            assert!(p_sub(a, a, f).is_zero());
            // x · 0 == 0
            assert!(p_mul(a, zero, f).is_zero());
            // commutativity
            let b = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if b.is_nar() {
                continue;
            }
            assert_eq!(p_add(a, b, f).bits(), p_add(b, a, f).bits());
            assert_eq!(p_mul(a, b, f).bits(), p_mul(b, a, f).bits());
            // negation symmetry: -(a+b) == (-a)+(-b)
            assert_eq!(p_neg(p_add(a, b, f)).bits(), p_add(p_neg(a), p_neg(b), f).bits());
        }
    }

    #[test]
    fn nar_propagation() {
        let f = fmt(16, 2);
        let nar = Posit::nar(f);
        let one = Posit::one(f);
        assert!(p_add(nar, one, f).is_nar());
        assert!(p_mul(nar, one, f).is_nar());
        assert!(p_fma(one, nar, one, f).is_nar());
        assert!(p_fma(one, one, nar, f).is_nar());
        assert!(p_neg(nar).is_nar());
    }

    #[test]
    fn saturation_behaviour() {
        let f = fmt(8, 2);
        let maxpos = Posit::maxpos(f);
        // maxpos + maxpos saturates to maxpos (never NaR)
        assert_eq!(p_add(maxpos, maxpos, f).bits(), maxpos.bits());
        // minpos · minpos saturates to minpos (never zero)
        let minpos = Posit::minpos(f);
        assert_eq!(p_mul(minpos, minpos, f).bits(), minpos.bits());
    }

    /// Catastrophic-cancellation regression: operands whose difference
    /// needs the sticky-borrow correction in add_fields.
    #[test]
    fn subtraction_sticky_borrow() {
        let f = fmt(16, 2);
        // big − tiny where tiny's bits fall entirely below the grid
        let big = Posit::from_f64(2f64.powi(40), f);
        let tiny = Posit::from_f64(2f64.powi(-40), f);
        let got = p_sub(big, tiny, f);
        // exact result is just under 2^40: must round back to 2^40's
        // neighbour per RNE — compare against the quire
        let want = exact_dot(big, &[tiny], &[p_neg(Posit::one(f))], f);
        assert_eq!(got.bits(), want.bits());
    }
}

/// Correctly-rounded division `a / b` into `out_fmt`.
///
/// Posit semantics: `x / 0 = NaR` for every x (no infinities), `0 / y = 0`
/// for finite nonzero y, NaR propagates. Downstream DNN code needs this
/// for softmax/normalization in the posit domain; the discrete baselines
/// don't use it (the paper's DPUs are MAC-only).
pub fn p_div(a: Posit, b: Posit, out_fmt: PositFormat) -> Posit {
    match (decode(a), decode(b)) {
        (Decoded::NaR, _) | (_, Decoded::NaR) => Posit::nar(out_fmt),
        (_, Decoded::Zero) => Posit::nar(out_fmt), // x/0 = NaR per the standard
        (Decoded::Zero, _) => Posit::zero(out_fmt),
        (Decoded::Finite(fa), Decoded::Finite(fb)) => {
            // Fixed-point long division with enough quotient bits that the
            // remainder only feeds the sticky bit: Q = 64 quotient fraction
            // bits ≥ n_out + regime + round margin for every format.
            const Q_BITS: u32 = 64;
            let num = (fa.frac as u128) << Q_BITS;
            let den = fb.frac as u128;
            let quot = num / den; // nonzero: num ≥ 2^Q_BITS > den ⇒ quot ≥ 1
            let rem = num % den;
            // value = quot · 2^(scale_a − scale_b − fb_net) with
            // fb_net = Q_BITS + fa.frac_bits − fb.frac_bits fraction bits
            let scale = fa.scale - fb.scale;
            let fb_net = Q_BITS as i32 + fa.frac_bits as i32 - fb.frac_bits as i32;
            let msb = 127 - quot.leading_zeros();
            let u = Unpacked {
                sign: fa.sign ^ fb.sign,
                scale: scale - fb_net + msb as i32,
                sig: quot,
                sig_frac_bits: msb,
                sticky: rem != 0,
            };
            Posit::from_bits(encode(u, out_fmt), out_fmt)
        }
    }
}

#[cfg(test)]
mod div_tests {
    use super::super::{Posit, PositFormat};
    use super::*;
    use crate::testing::Rng;

    /// Exhaustive P(8,es) division vs the f64 oracle (a single f64
    /// division of two P(8) values is exactly representable-roundable:
    /// 53 ≥ 2·p + 2 for p ≤ 6 significand bits).
    #[test]
    fn div_exhaustive_p8() {
        for es in 0..=2 {
            let f = PositFormat::p(8, es);
            for x in 0..256u32 {
                for y in 0..256u32 {
                    let (a, b) = (Posit::from_bits(x, f), Posit::from_bits(y, f));
                    let got = p_div(a, b, f);
                    if a.is_nar() || b.is_nar() || b.is_zero() {
                        assert!(got.is_nar(), "P(8,{es}) {x:#x}/{y:#x}");
                        continue;
                    }
                    if a.is_zero() {
                        assert!(got.is_zero());
                        continue;
                    }
                    let want = Posit::from_f64(a.to_f64() / b.to_f64(), f);
                    assert_eq!(got.bits(), want.bits(), "P(8,{es}) {a:?}/{b:?}");
                }
            }
        }
    }

    #[test]
    fn div_identities() {
        let f = PositFormat::p(16, 2);
        let mut rng = Rng::seeded(0xD1F);
        for _ in 0..5_000 {
            let a = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if a.is_nar() || a.is_zero() {
                continue;
            }
            // x / 1 == x ; x / x == 1
            assert_eq!(p_div(a, Posit::one(f), f).bits(), a.bits());
            assert_eq!(p_div(a, a, f).bits(), Posit::one(f).bits());
            // sign algebra: (−x)/y == −(x/y)
            let b = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, f);
            if b.is_nar() || b.is_zero() {
                continue;
            }
            assert_eq!(p_div(p_neg(a), b, f).bits(), p_neg(p_div(a, b, f)).bits());
        }
    }

    /// mul∘div round trip stays within 1 ulp (two roundings).
    #[test]
    fn div_mul_roundtrip_close() {
        let f = PositFormat::p(16, 2);
        let mut rng = Rng::seeded(0x0DD);
        for _ in 0..5_000 {
            let a = Posit::from_f64(rng.log_uniform_signed(-10.0, 10.0), f);
            let b = Posit::from_f64(rng.log_uniform_signed(-10.0, 10.0), f);
            let q = p_div(a, b, f);
            let back = p_mul(q, b, f);
            // two roundings, each ≤ 2^-7 relative at the coarsest regime a
            // ratio of ±2^±10 values can reach in P(16,2) (≥ 6 frac bits)
            let rel = ((back.to_f64() - a.to_f64()) / a.to_f64()).abs();
            assert!(rel < 2f64.powi(-6), "{a:?}/{b:?} -> {q:?} -> {back:?} (rel {rel})");
        }
    }

    #[test]
    fn div_specials() {
        let f = PositFormat::p(16, 2);
        let one = Posit::one(f);
        assert!(p_div(one, Posit::zero(f), f).is_nar());
        assert!(p_div(Posit::nar(f), one, f).is_nar());
        assert!(p_div(Posit::zero(f), one, f).is_zero());
        // maxpos / minpos saturates to maxpos
        assert_eq!(p_div(Posit::maxpos(f), Posit::minpos(f), f).bits(), Posit::maxpos(f).bits());
    }
}
