//! Bit-exact posit arithmetic for any format P(n, es) with 3 ≤ n ≤ 32 and
//! 0 ≤ es ≤ 4.
//!
//! This module is the repo's replacement for the extended SoftPosit library
//! the paper used to generate test vectors: a from-scratch, format-generic
//! posit implementation with correctly-rounded (round-to-nearest, ties to
//! even bit pattern, never underflow-to-zero / overflow-to-NaR) scalar
//! arithmetic, exact wide-fixed-point accumulation (the *quire*), and exact
//! conversions to/from `f64`.
//!
//! Submodules:
//! * [`decode`] — field extraction (sign / regime / exponent / mantissa),
//!   the software twin of PDPU pipeline stage S1.
//! * [`encode`] — rounding + packing, the software twin of stage S6.
//! * [`convert`] — exact `f64` interchange (exact because n ≤ 32, es ≤ 4
//!   keeps every posit value inside f64's dynamic range and mantissa).
//! * [`arith`] — correctly-rounded add/sub/mul/fma (one rounding per op —
//!   these model the *discrete* units PDPU is compared against).
//! * [`quire`] — exact dot-product accumulator over [`wide`] fixed point.
//! * [`wide`] — fixed-width signed big integer used by the quire and by the
//!   exact reference oracle in tests.

pub mod arith;
pub mod convert;
pub mod decode;
pub mod encode;
pub mod quire;
pub mod wide;

pub use arith::{p_add, p_div, p_fma, p_mul, p_neg, p_sub};
pub use decode::{decode, Decoded};
pub use encode::{encode, Unpacked};
pub use quire::{CacheQuire, Quire, QuireSpec};

use std::fmt;

/// A posit format P(n, es).
///
/// `n` is the total word size in bits (3..=32) and `es` the exponent field
/// size (0..=4). The 2022 posit standard fixes `es = 2`; the PDPU generator
/// (and hence this library) keeps it configurable, matching the paper's
/// "supporting custom posit formats" requirement.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct PositFormat {
    n: u32,
    es: u32,
}

/// Errors produced by format construction and parsing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PositError {
    BadWordSize(u32),
    BadExpSize(u32),
    NaR,
}

impl fmt::Display for PositError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PositError::BadWordSize(n) => {
                write!(f, "word size n={n} out of supported range 3..=32")
            }
            PositError::BadExpSize(es) => {
                write!(f, "exponent size es={es} out of supported range 0..=4")
            }
            PositError::NaR => write!(f, "cannot represent NaR as a real value"),
        }
    }
}

impl std::error::Error for PositError {}

impl PositFormat {
    /// Construct a format, validating the supported ranges.
    pub fn new(n: u32, es: u32) -> Result<Self, PositError> {
        if !(3..=32).contains(&n) {
            return Err(PositError::BadWordSize(n));
        }
        if es > 4 {
            return Err(PositError::BadExpSize(es));
        }
        Ok(Self { n, es })
    }

    /// Construct a format, panicking on invalid parameters. Convenience for
    /// tests and compile-time-known formats.
    pub fn p(n: u32, es: u32) -> Self {
        Self::new(n, es).expect("invalid posit format")
    }

    /// The standard 2022 format P(n, 2).
    pub fn standard(n: u32) -> Self {
        Self::p(n, 2)
    }

    #[inline]
    pub fn n(&self) -> u32 {
        self.n
    }

    #[inline]
    pub fn es(&self) -> u32 {
        self.es
    }

    /// Bit mask covering the n-bit word.
    #[inline]
    pub fn mask(&self) -> u32 {
        if self.n == 32 {
            u32::MAX
        } else {
            (1u32 << self.n) - 1
        }
    }

    /// `useed = 2^(2^es)`: the regime radix.
    #[inline]
    pub fn useed_log2(&self) -> i32 {
        1i32 << self.es
    }

    /// Maximum number of mantissa (fraction) bits a finite value of this
    /// format can carry: `n - 3 - es`, clamped at 0. The `-3` accounts for
    /// the sign bit and the shortest possible regime (2 bits).
    #[inline]
    pub fn max_frac_bits(&self) -> u32 {
        (self.n as i32 - 3 - self.es as i32).max(0) as u32
    }

    /// Largest regime run value `k` of a finite posit: `n - 2`.
    #[inline]
    pub fn max_k(&self) -> i32 {
        self.n as i32 - 2
    }

    /// Scale (base-2 exponent) of `maxpos`: `(n-2) * 2^es`.
    #[inline]
    pub fn max_scale(&self) -> i32 {
        self.max_k() * self.useed_log2()
    }

    /// Scale (base-2 exponent) of `minpos`: `-(n-2) * 2^es`.
    #[inline]
    pub fn min_scale(&self) -> i32 {
        -self.max_scale()
    }

    /// Bit pattern of Not-a-Real: `1 0…0`.
    #[inline]
    pub fn nar_bits(&self) -> u32 {
        1u32 << (self.n - 1)
    }

    /// Bit pattern of the largest positive value `maxpos`: `0 1…1`.
    #[inline]
    pub fn maxpos_bits(&self) -> u32 {
        self.nar_bits() - 1
    }

    /// Bit pattern of the smallest positive value `minpos`: `0 0…01`.
    #[inline]
    pub fn minpos_bits(&self) -> u32 {
        1
    }

    /// Number of distinct bit patterns (2^n) as u64 (safe for n = 32).
    #[inline]
    pub fn cardinality(&self) -> u64 {
        1u64 << self.n
    }
}

impl fmt::Debug for PositFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P({},{})", self.n, self.es)
    }
}

impl fmt::Display for PositFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P({},{})", self.n, self.es)
    }
}

/// A posit value: an n-bit pattern tagged with its format.
///
/// The pattern lives in the low `n` bits of `bits`; upper bits are zero.
/// Ordering of the two's-complement interpretation of the pattern matches
/// ordering of the represented values (the classic posit monotonicity
/// property), which `cmp_value` exploits.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Posit {
    bits: u32,
    fmt: PositFormat,
}

impl Posit {
    /// Wrap raw bits (masked to n bits) in a format.
    #[inline]
    pub fn from_bits(bits: u32, fmt: PositFormat) -> Self {
        Self { bits: bits & fmt.mask(), fmt }
    }

    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    #[inline]
    pub fn format(&self) -> PositFormat {
        self.fmt
    }

    /// Positive zero (the only zero).
    #[inline]
    pub fn zero(fmt: PositFormat) -> Self {
        Self { bits: 0, fmt }
    }

    /// Not-a-Real.
    #[inline]
    pub fn nar(fmt: PositFormat) -> Self {
        Self { bits: fmt.nar_bits(), fmt }
    }

    #[inline]
    pub fn maxpos(fmt: PositFormat) -> Self {
        Self { bits: fmt.maxpos_bits(), fmt }
    }

    #[inline]
    pub fn minpos(fmt: PositFormat) -> Self {
        Self { bits: fmt.minpos_bits(), fmt }
    }

    /// One: `0 10…0`.
    #[inline]
    pub fn one(fmt: PositFormat) -> Self {
        Self { bits: 1u32 << (fmt.n - 2), fmt }
    }

    #[inline]
    pub fn is_zero(&self) -> bool {
        self.bits == 0
    }

    #[inline]
    pub fn is_nar(&self) -> bool {
        self.bits == self.fmt.nar_bits()
    }

    /// Sign bit of the pattern (true ⇒ negative value, unless NaR).
    #[inline]
    pub fn sign_bit(&self) -> bool {
        (self.bits >> (self.fmt.n - 1)) & 1 == 1
    }

    /// Exact value as `f64` (exact for every supported format).
    pub fn to_f64(&self) -> f64 {
        convert::to_f64(*self)
    }

    /// Nearest posit to an `f64` value (round to nearest, ties to even
    /// pattern; saturating, never underflowing to zero).
    pub fn from_f64(v: f64, fmt: PositFormat) -> Self {
        convert::from_f64(v, fmt)
    }

    /// Decode into sign/scale/fraction components (stage-S1 semantics).
    pub fn decode(&self) -> Decoded {
        decode::decode(*self)
    }

    /// Compare by represented value. NaR sorts below everything (it is the
    /// most-negative two's-complement pattern), matching the posit standard
    /// total order on patterns.
    pub fn cmp_value(&self, other: &Posit) -> std::cmp::Ordering {
        debug_assert_eq!(self.fmt, other.fmt);
        let sext = |p: &Posit| -> i32 {
            // sign-extend the n-bit pattern to i32
            let sh = 32 - p.fmt.n;
            ((p.bits << sh) as i32) >> sh
        };
        sext(self).cmp(&sext(other))
    }

    /// The next representable posit (pattern + 1), wrapping NaR→minpos-of-
    /// negatives etc. Used by tests for neighbour/monotonicity checks.
    pub fn succ(&self) -> Posit {
        Posit::from_bits(self.bits.wrapping_add(1), self.fmt)
    }

    /// The previous representable posit (pattern − 1).
    pub fn pred(&self) -> Posit {
        Posit::from_bits(self.bits.wrapping_sub(1), self.fmt)
    }
}

impl fmt::Debug for Posit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Posit({:#0width$b} {} = {})",
            self.bits,
            self.fmt,
            if self.is_nar() { "NaR".to_string() } else { format!("{}", self.to_f64()) },
            width = self.fmt.n as usize + 2
        )
    }
}

impl fmt::Display for Posit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_nar() {
            write!(f, "NaR")
        } else {
            write!(f, "{}", self.to_f64())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_validation() {
        assert!(PositFormat::new(2, 2).is_err());
        assert!(PositFormat::new(33, 2).is_err());
        assert!(PositFormat::new(16, 5).is_err());
        assert!(PositFormat::new(3, 0).is_ok());
        assert!(PositFormat::new(32, 4).is_ok());
    }

    #[test]
    fn format_derived_quantities() {
        let p16 = PositFormat::p(16, 2);
        assert_eq!(p16.max_frac_bits(), 11); // 1.f has 12 significant bits
        assert_eq!(p16.max_scale(), 56);
        assert_eq!(p16.min_scale(), -56);
        assert_eq!(p16.useed_log2(), 4);
        assert_eq!(p16.nar_bits(), 0x8000);
        assert_eq!(p16.maxpos_bits(), 0x7FFF);

        let p8 = PositFormat::p(8, 0);
        assert_eq!(p8.max_frac_bits(), 5);
        assert_eq!(p8.max_scale(), 6);

        // degenerate: fewer bits than sign+regime+es
        let p4 = PositFormat::p(4, 2);
        assert_eq!(p4.max_frac_bits(), 0);
    }

    #[test]
    fn special_patterns() {
        let fmt = PositFormat::p(8, 1);
        assert!(Posit::zero(fmt).is_zero());
        assert!(Posit::nar(fmt).is_nar());
        assert_eq!(Posit::one(fmt).bits(), 0b0100_0000);
        assert_eq!(Posit::one(fmt).to_f64(), 1.0);
        assert!(!Posit::zero(fmt).sign_bit());
        assert!(Posit::nar(fmt).sign_bit());
    }

    #[test]
    fn from_bits_masks() {
        let fmt = PositFormat::p(8, 2);
        let p = Posit::from_bits(0xFFFF_FF42, fmt);
        assert_eq!(p.bits(), 0x42);
    }

    #[test]
    fn cmp_value_total_order_p8() {
        // exhaust P(8,1): two's-complement pattern order == value order
        let fmt = PositFormat::p(8, 1);
        let mut last: Option<f64> = None;
        // iterate patterns in two's complement order: NaR (0x80) .. 0x7F
        for i in 0..256u32 {
            let bits = (0x80 + i) & 0xFF;
            let p = Posit::from_bits(bits, fmt);
            if p.is_nar() {
                continue;
            }
            let v = p.to_f64();
            if let Some(l) = last {
                assert!(v > l, "pattern order broke value order at {bits:#x}: {l} !< {v}");
            }
            last = Some(v);
        }
    }
}
