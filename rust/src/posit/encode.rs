//! Posit rounding + packing — the software twin of PDPU pipeline stage S6.
//!
//! [`encode`] takes an *unpacked* real value (sign, scale, normalized
//! significand + sticky) and produces the nearest n-bit posit pattern under
//! the posit rounding rule: round to nearest, ties to even **bit pattern**,
//! with saturation — a nonzero real never rounds to zero (clamps to minpos)
//! and never overflows to NaR (clamps to maxpos). Because posit patterns
//! are monotone in value, round-to-nearest-even applied to the composed
//! regime|exponent|fraction bit string implements the standard's rounding;
//! this is the same trick hardware encoders (and SoftPosit) use.

use super::PositFormat;

/// An unpacked real value ready for encoding.
///
/// Value represented: `(-1)^sign · 2^scale · sig / 2^sig_frac_bits`, where
/// `sig` is normalized: `2^sig_frac_bits ≤ sig < 2^(sig_frac_bits+1)`
/// (i.e. `1.xxx` with the hidden bit explicit). `sticky` records whether
/// any nonzero bits were discarded below `sig`'s LSB by earlier datapath
/// steps (alignment shifts, truncation) and participates in the rounding
/// decision exactly as a hardware sticky bit would.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Unpacked {
    pub sign: bool,
    pub scale: i32,
    pub sig: u128,
    pub sig_frac_bits: u32,
    pub sticky: bool,
}

impl Unpacked {
    /// Construct and normalize from a possibly-unnormalized significand
    /// (any nonzero `sig` with its binary point at `sig_frac_bits`).
    /// Normalization shifts so the MSB of `sig` becomes the hidden bit,
    /// adjusting `scale`; right shifts fold discarded bits into `sticky`.
    pub fn normalize(sign: bool, scale: i32, sig: u128, sig_frac_bits: u32, sticky: bool) -> Option<Self> {
        if sig == 0 {
            return None;
        }
        let msb = 127 - sig.leading_zeros(); // position of the leading 1
        let scale = scale + msb as i32 - sig_frac_bits as i32;
        Some(Self { sign, scale, sig, sig_frac_bits: msb, sticky })
    }
}

/// Encode an unpacked value to the nearest posit pattern of `fmt`.
///
/// Returns the n-bit pattern (in the low bits of the u32).
pub fn encode(u: Unpacked, fmt: PositFormat) -> u32 {
    debug_assert!(
        u.sig >> u.sig_frac_bits == 1,
        "significand not normalized: sig={:#x} fb={}",
        u.sig,
        u.sig_frac_bits
    );
    let n = fmt.n();
    let es = fmt.es();
    let useed_log2 = fmt.useed_log2();

    // Saturate on scale before constructing fields: regime k outside
    // [-(n-2), n-2] cannot be represented; the standard clamps (no
    // underflow-to-zero, no overflow-to-NaR).
    //
    // NOTE on the upper boundary: scale == max_scale with frac > 1.0 still
    // rounds to maxpos via the bit-field RNE below, so only k > n-2 is
    // clamped here.
    let k = u.scale.div_euclid(useed_log2);
    let e = u.scale.rem_euclid(useed_log2) as u32;
    let mag = if k > fmt.max_k() {
        fmt.maxpos_bits()
    } else if k < -fmt.max_k() {
        fmt.minpos_bits()
    } else {
        // Compose the unbounded field expansion: regime | exponent | fraction.
        // Widths: regime ≤ n bits here (k ≤ n-2 ⇒ rl ≤ n), es ≤ 4,
        // fraction = sig_frac_bits ≤ 127 — sum < 160, so build in a u256-ish
        // two-limb scheme... in practice sig_frac_bits ≤ ~120 and we only
        // need the top n-1 bits plus round/sticky; we stream instead of
        // materializing: compute the body as a u128 after pre-truncating the
        // fraction to what can possibly matter (n + 2 bits + sticky).
        let (sig, fb, pre_sticky) = shrink_sig(u.sig, u.sig_frac_bits, n + 2);
        let frac = sig & ((1u128 << fb) - 1); // drop hidden bit

        let rl: u32 = if k >= 0 { k as u32 + 2 } else { (-k) as u32 + 1 };
        // regime pattern: k >= 0 → (k+1) ones then 0; k < 0 → (-k) zeros then 1
        let regime: u128 = if k >= 0 { ((1u128 << (k + 1)) - 1) << 1 } else { 1 };

        let body_len = rl + es + fb; // total bits after the sign position
        let body: u128 = (regime << (es + fb)) | ((e as u128) << fb) | frac;

        let avail = n - 1;
        if body_len <= avail {
            // exact fit: pad fraction with zeros on the right
            let mag = (body << (avail - body_len)) as u32;
            // sticky bits below still matter only for... nothing: value is
            // exactly representable except for pre_sticky/u.sticky, which
            // lie strictly below the last kept bit with a zero round bit —
            // they can never flip RNE. Still, assert the invariant cheaply.
            debug_assert!(mag <= fmt.maxpos_bits());
            let _ = pre_sticky;
            mag
        } else {
            // round at the n-1 bit boundary (RNE on the monotone pattern)
            let cut = body_len - avail;
            let keep = (body >> cut) as u32;
            let round = (body >> (cut - 1)) & 1 == 1;
            let sticky = (body & ((1u128 << (cut - 1)) - 1)) != 0 || pre_sticky || u.sticky;
            let mut mag = keep;
            if round && (sticky || (keep & 1) == 1) {
                mag += 1;
            }
            // post-clamp: never round a nonzero value to zero or to NaR
            if mag == 0 {
                mag = fmt.minpos_bits();
            } else if mag >= fmt.nar_bits() {
                mag = fmt.maxpos_bits();
            }
            mag
        }
    };

    if u.sign {
        mag.wrapping_neg() & fmt.mask()
    } else {
        mag
    }
}

/// Reduce a normalized significand to at most `max_fb` fraction bits,
/// folding everything below into a sticky flag. Keeps normalization.
fn shrink_sig(sig: u128, fb: u32, max_fb: u32) -> (u128, u32, bool) {
    if fb <= max_fb {
        (sig, fb, false)
    } else {
        let drop = fb - max_fb;
        let sticky = sig & ((1u128 << drop) - 1) != 0;
        (sig >> drop, max_fb, sticky)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{decode, Decoded, Posit, PositFormat};
    use super::*;

    fn enc(sign: bool, scale: i32, sig: u128, fb: u32, sticky: bool, n: u32, es: u32) -> u32 {
        encode(Unpacked { sign, scale, sig, sig_frac_bits: fb, sticky }, PositFormat::p(n, es))
    }

    #[test]
    fn encode_one() {
        for &(n, es) in &[(8u32, 0u32), (8, 2), (16, 2), (13, 2), (32, 2), (4, 1)] {
            let fmt = PositFormat::p(n, es);
            assert_eq!(enc(false, 0, 1, 0, false, n, es), Posit::one(fmt).bits(), "{fmt}");
        }
    }

    #[test]
    fn paper_fig2_value_11() {
        // 11 = 2^3 · 1.375 = 2^3 · 0b1.011
        assert_eq!(enc(false, 3, 0b1011, 3, false, 8, 2), 0b0_10_11_011);
        assert_eq!(enc(true, 3, 0b1011, 3, false, 8, 2), (0b0_10_11_011u32).wrapping_neg() & 0xFF);
    }

    /// Round-trip: decode → encode must reproduce every finite pattern
    /// exactly (encode of an exactly-representable value is the identity).
    #[test]
    fn roundtrip_exhaustive_p16_2() {
        roundtrip_exhaustive(16, 2);
    }

    #[test]
    fn roundtrip_exhaustive_small_formats() {
        for n in 3..=12 {
            for es in 0..=3 {
                roundtrip_exhaustive(n, es);
            }
        }
    }

    fn roundtrip_exhaustive(n: u32, es: u32) {
        let fmt = PositFormat::p(n, es);
        for bits in 0..fmt.cardinality() as u32 {
            let p = Posit::from_bits(bits, fmt);
            match decode(p) {
                Decoded::Zero | Decoded::NaR => continue,
                Decoded::Finite(f) => {
                    let back = encode(
                        Unpacked {
                            sign: f.sign,
                            scale: f.scale,
                            sig: f.frac as u128,
                            sig_frac_bits: f.frac_bits,
                            sticky: false,
                        },
                        fmt,
                    );
                    assert_eq!(back, bits, "roundtrip failed for {fmt} bits={bits:#x}");
                }
            }
        }
    }

    #[test]
    fn saturation_never_zero_never_nar() {
        let fmt = PositFormat::p(8, 2);
        let _ = fmt;
        // far below minpos → minpos
        assert_eq!(enc(false, -1000, 1, 0, false, 8, 2), fmt.minpos_bits());
        // far above maxpos → maxpos
        assert_eq!(enc(false, 1000, 1, 0, false, 8, 2), fmt.maxpos_bits());
        // negative saturation
        assert_eq!(enc(true, 1000, 1, 0, false, 8, 2), fmt.maxpos_bits().wrapping_neg() & 0xFF);
        assert_eq!(enc(true, -1000, 1, 0, false, 8, 2), fmt.minpos_bits().wrapping_neg() & 0xFF);
    }

    #[test]
    fn just_below_minpos_rounds_to_minpos() {
        // minpos/2 must round UP to minpos, not to zero (posit rule).
        let fmt = PositFormat::p(8, 2);
        let minpos_scale = fmt.min_scale();
        assert_eq!(enc(false, minpos_scale - 1, 1, 0, false, 8, 2), fmt.minpos_bits());
        // Even minpos/4 rounds to minpos.
        assert_eq!(enc(false, minpos_scale - 2, 1, 0, false, 8, 2), fmt.minpos_bits());
    }

    #[test]
    fn rne_ties_to_even_pattern() {
        // Take two adjacent posits around 1.0 and test the midpoint.
        // one = 0x40 (1.0), succ = 0x41 = 1 + 2^-3 · ... : P(8,2) one has
        // 3 fraction bits → succ = 1.125. Midpoint 1.0625 = 2^0 · 1.0001₂.
        let mid = enc(false, 0, 0b10001, 4, false, 8, 2);
        assert_eq!(mid, 0x40, "tie must go to even pattern 0x40");
        // Just above the midpoint must go up.
        let above = enc(false, 0, 0b10001, 4, true, 8, 2);
        assert_eq!(above, 0x41);
        // Midpoint between 0x41 (1.125) and 0x42 (1.25): 1.1875 → odd keep
        // (0x41) + tie → rounds up to even 0x42.
        let mid2 = enc(false, 0, 0b10011, 4, false, 8, 2);
        assert_eq!(mid2, 0x42);
    }

    #[test]
    fn sticky_breaks_tie_upward() {
        // same as rne test but sticky set: rounds away from even-down
        let above = enc(false, 0, 0b10001, 4, true, 8, 2);
        assert_eq!(above, 0x41);
    }

    #[test]
    fn rounding_carry_into_regime() {
        // P(8,2): largest value with k=0 region is just below 2^4; a value
        // like 1.9999·2^3 must carry-round into the next regime cleanly.
        let fmt = PositFormat::p(8, 2);
        let bits = enc(false, 3, 0xFFFF, 15, false, 8, 2); // ≈ 2^4
        let p = Posit::from_bits(bits, fmt);
        assert_eq!(p.to_f64(), 16.0);
    }

    #[test]
    fn normalize_helper() {
        // 0b0110 with fb=3 → value 0.75 → normalized 1.1₂ · 2^-1
        let u = Unpacked::normalize(false, 0, 0b0110, 3, false).unwrap();
        assert_eq!(u.scale, -1);
        assert_eq!(u.sig >> u.sig_frac_bits, 1);
        assert_eq!(Unpacked::normalize(false, 0, 0, 3, false), None);
        // large value: 0b101 with fb=0 → 5 = 2^2 · 1.25
        let u = Unpacked::normalize(false, 0, 0b101, 0, false).unwrap();
        assert_eq!(u.scale, 2);
    }

    #[test]
    fn long_significand_shrink_is_correct() {
        // A significand wider than n+2 bits must still round correctly via
        // the pre-truncation path: compare against direct f64 conversion.
        let fmt = PositFormat::p(16, 2);
        let sig: u128 = (1u128 << 100) | 0x3FFF_FFFF; // 1.0000...0111... (100 fb)
        let bits = encode(
            Unpacked { sign: false, scale: 7, sig, sig_frac_bits: 100, sticky: false },
            fmt,
        );
        let v = (sig as f64 / 2f64.powi(100)) * 2f64.powi(7);
        assert_eq!(bits, Posit::from_f64(v, fmt).bits());
    }
}
