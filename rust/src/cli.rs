//! Hand-rolled CLI (no clap in the offline image): `pdpu <command> …`.
//!
//! Commands:
//!   exp table1|fig3|fig6|ablation   regenerate a paper table/figure
//!   quantize --format=n,es v…       bit-exact posit quantization (also the
//!                                   python cross-layer test oracle)
//!   dot …                           one fused PDPU dot product
//!   schedule …                      PDPU-array scheduling report
//!   serve …                         start the inference server
//!   train …                         posit SGD on the software engine
//!   stats [--addr A] [--prom]       scrape a running server's counters
//!   trace [--addr A] …              export a server's span ring as
//!                                   Chrome-tracing JSON
//!   lint [--root DIR]               run the pdpu static-analysis pass
//!   selftest                        artifact + runtime smoke check

use std::collections::HashMap;

use crate::cost::Tech;
use crate::experiments::{ablation, fig3, fig6, table1};
use crate::pdpu::{Pdpu, PdpuConfig};
use crate::posit::{Posit, PositFormat};

/// Parsed arguments: positionals + --key=value / --key value flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_usize(&self, key: &str, default: usize) -> usize {
        self.flag(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse "--format=n,es".
    pub fn format(&self, key: &str, default: (u32, u32)) -> anyhow::Result<PositFormat> {
        match self.flag(key) {
            None => Ok(PositFormat::p(default.0, default.1)),
            Some(v) => {
                let (n, es) = v.split_once(',').ok_or_else(|| anyhow::anyhow!("--{key} wants n,es"))?;
                Ok(PositFormat::new(n.trim().parse()?, es.trim().parse()?)?)
            }
        }
    }
}

pub const USAGE: &str = "\
pdpu — posit dot-product unit (ISCAS'23) full-stack reproduction

USAGE: pdpu <command> [options]

COMMANDS
  exp table1 [--hw N] [--oc N]    Table I: accuracy + area/delay/power/eff
  exp fig3                        Fig. 3: tapered accuracy vs distribution
  exp fig6                        Fig. 6: 6-stage pipeline breakdown
  exp ablation [--hw N] [--oc N]  §III-C design-space sweeps
  quantize --format=n,es v…       round values to the nearest posit
  dot --in=n,es --out=n,es --n N --wm W --acc A -- a… -- b…
                                  one fused dot product (bit-exact)
  schedule [--outputs N] [--dot-len K] [--units U] [--n N] [--interleave I]
                                  PDPU-array cycle-accurate schedule
  serve [--addr HOST:PORT] [--artifacts DIR] [--software] [--batch N]
        [--no-fuse] [--trace N] [--shadow N] [--shards N]
        [--max-inflight N] [--plane-cache N]
                                  start the sharded inference/GEMM server
                                  (--software, or missing PJRT artifacts,
                                  serves the batched bit-exact PDPU engine;
                                  --no-fuse disables cross-request GEMM
                                  fusion for A/B runs — outputs identical;
                                  --trace N samples 1-in-N requests into
                                  the span ring, 0 = off, default off;
                                  --shadow N shadow-executes 1-in-N engine
                                  launches in FP64 for the numerics
                                  observatory, 0 = off, default off;
                                  --shards N accept/engine shards,
                                  default 2; --max-inflight N admission
                                  budget before shedding, 0 = unlimited,
                                  default 1024; --plane-cache N cached
                                  weight planes for the software engine,
                                  0 = off, default 64)
  train [--epochs N] [--limit N] [--batch N] [--hidden N] [--classes N]
        [--lr F] [--seed S]       mixed-precision posit SGD through the
                                  software engine on the bundled dataset
                                  (per-epoch loss/accuracy; no artifacts)
  stats [--addr HOST:PORT] [--prom]
                                  one-shot scrape of a running server:
                                  the {\"op\":\"stats\"} counters as JSON, or
                                  with --prom the full Prometheus text
                                  exposition ({\"op\":\"metrics\"})
  trace [--addr HOST:PORT] [--sample N] [--clear] [--out FILE]
                                  export a running server's completed
                                  spans as Chrome-tracing JSON (load in
                                  chrome://tracing or Perfetto); --sample N
                                  sets 1-in-N request sampling first,
                                  --clear empties the ring before sampling
  numerics [--addr HOST:PORT] [--shadow N] [--json]
                                  per-layer numerics observatory report
                                  from a running server: regime-utilization
                                  histograms, saturation/NaR tallies, FP64
                                  shadow accuracy, and the precision
                                  advisor's per-site (n, es); --shadow N
                                  (re)arms 1-in-N shadow sampling first,
                                  --json prints the raw wire response
  lint [--root DIR]               run the pdpu static-analysis pass over
                                  rust/src (panic-freedom, alloc-freedom,
                                  determinism, stage isolation, wire ops);
                                  exit 1 on any unsuppressed violation
  selftest [--artifacts DIR]      load artifacts, run a PJRT smoke batch
";

/// Run the CLI; returns the process exit code.
pub fn run(argv: Vec<String>) -> anyhow::Result<i32> {
    let args = Args::parse(&argv);
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(2);
    };
    match cmd {
        "exp" => cmd_exp(&args),
        "quantize" => cmd_quantize(&args),
        "dot" => cmd_dot(&args, &argv),
        "schedule" => cmd_schedule(&args),
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "stats" => cmd_stats(&args),
        "trace" => cmd_trace(&args),
        "numerics" => cmd_numerics(&args),
        "lint" => cmd_lint(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

fn cmd_exp(args: &Args) -> anyhow::Result<i32> {
    let tech = Tech::default();
    let hw = args.flag_usize("hw", 32);
    let oc = args.flag_usize("oc", 8);
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("table1") => {
            let params = table1::Table1Params { seed: 2023, hw, out_channels: oc };
            let rows = table1::build(&params, &tech);
            print!("{}", table1::render(&rows));
            let c = table1::claims(&rows);
            println!("\n§IV-A claims (paper → measured):");
            println!(
                "  area/delay/power saving vs PACoGen: 43%/64%/70% → {:.0}%/{:.0}%/{:.0}%",
                100.0 * c.area_saving_vs_pacogen,
                100.0 * c.delay_saving_vs_pacogen,
                100.0 * c.power_saving_vs_pacogen
            );
            println!(
                "  area/energy-eff gain vs quire: 5.0x/2.1x → {:.1}x/{:.1}x",
                c.area_eff_gain_vs_quire, c.energy_eff_gain_vs_quire
            );
            println!(
                "  area/energy-eff gain vs posit FMA: 3.1x/3.5x → {:.1}x/{:.1}x",
                c.area_eff_gain_vs_posit_fma, c.energy_eff_gain_vs_posit_fma
            );
            Ok(0)
        }
        Some("fig3") => {
            let pts = fig3::accuracy_curves(-16, 16, 64);
            let hist = fig3::activation_histogram(2023, hw, -12, 4);
            print!("{}", fig3::render(&pts, &hist));
            Ok(0)
        }
        Some("fig6") => {
            let entries = fig6::build(&[4, 8, 16], &tech);
            print!("{}", fig6::render(&entries));
            Ok(0)
        }
        Some("ablation") => {
            let (hw, oc) = (args.flag_usize("hw", 16), args.flag_usize("oc", 4));
            let wm = ablation::wm_sweep(&[6, 8, 10, 14, 20, 26], &tech, hw, oc);
            print!("{}", ablation::render("Wm sweep (P(13/16,2) N=4)", &wm));
            println!();
            let fmts = ablation::format_sweep(&[8, 10, 13, 16], &tech, hw, oc);
            print!("{}", ablation::render("input-format sweep (N=4 Wm=14)", &fmts));
            println!();
            let ns = ablation::n_sweep(&[2, 4, 8, 16], &tech, hw, oc);
            print!("{}", ablation::render("N sweep (P(13/16,2) Wm=14)", &ns));
            Ok(0)
        }
        _ => {
            eprintln!("exp wants one of: table1 fig3 fig6 ablation");
            Ok(2)
        }
    }
}

fn cmd_quantize(args: &Args) -> anyhow::Result<i32> {
    let fmt = args.format("format", (16, 2))?;
    let mut out = String::new();
    for v in &args.positional[1..] {
        let x: f64 = v.parse().map_err(|_| anyhow::anyhow!("bad number '{v}'"))?;
        let p = Posit::from_f64(x, fmt);
        out.push_str(&format!("{}\n", p.to_f64()));
    }
    print!("{out}");
    Ok(0)
}

fn cmd_dot(args: &Args, argv: &[String]) -> anyhow::Result<i32> {
    let in_fmt = args.format("in", (13, 2))?;
    let out_fmt = args.format("out", (16, 2))?;
    let n = args.flag_usize("n", 4);
    let wm = args.flag_usize("wm", 14) as u32;
    let acc: f64 = args.flag("acc").unwrap_or("0").parse()?;
    // vectors: everything after the first `--` is a, after the second is b
    let mut sections: Vec<Vec<f64>> = Vec::new();
    let mut cur: Option<Vec<f64>> = None;
    for a in argv {
        if a == "--" {
            if let Some(v) = cur.take() {
                sections.push(v);
            }
            cur = Some(Vec::new());
        } else if let Some(v) = cur.as_mut() {
            if let Ok(x) = a.parse::<f64>() {
                v.push(x);
            }
        }
    }
    if let Some(v) = cur.take() {
        sections.push(v);
    }
    anyhow::ensure!(sections.len() == 2, "dot wants two `--`-separated vectors");
    let (va, vb) = (&sections[0], &sections[1]);
    anyhow::ensure!(va.len() == vb.len(), "vector length mismatch");

    let cfg = PdpuConfig::new(in_fmt, out_fmt, n, wm)?;
    let unit = Pdpu::new(cfg);
    let a: Vec<Posit> = va.iter().map(|&v| Posit::from_f64(v, in_fmt)).collect();
    let b: Vec<Posit> = vb.iter().map(|&v| Posit::from_f64(v, in_fmt)).collect();
    let result = unit.dot_chunked(Posit::from_f64(acc, out_fmt), &a, &b);
    let exact: f64 = acc + va.iter().zip(vb).map(|(x, y)| x * y).sum::<f64>();
    println!("config  : {}", cfg.label());
    println!("result  : {} (bits {:#06x})", result.to_f64(), result.bits());
    println!("fp64 ref: {exact}");
    println!("rel err : {:.3e}", ((result.to_f64() - exact) / exact.abs().max(1e-300)).abs());
    Ok(0)
}

fn cmd_schedule(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::{conv_jobs, schedule};
    let outputs = args.flag_usize("outputs", 256);
    let dot_len = args.flag_usize("dot-len", 147);
    let units = args.flag_usize("units", 4);
    let n = args.flag_usize("n", 4);
    let il = args.flag_usize("interleave", 6);
    let r = schedule(&conv_jobs(outputs, dot_len), units, n, il);
    println!("jobs {} × K={}  on {} PDPU(s), N={}, interleave {}", outputs, dot_len, units, n, il);
    println!("chunks        : {}", r.total_chunks);
    println!("cycles        : {}", r.cycles);
    println!("utilization   : {:.1}%", 100.0 * r.utilization);
    println!("MACs/cycle    : {:.2}", r.macs_per_cycle);
    // translate to wall-clock at the Fig. 6 pipelined clock
    let tech = Tech::default();
    let entry = &fig6::build(&[n as u32], &tech)[0];
    let t_us = r.cycles as f64 * entry.report.clock_ns * 1e-3;
    println!(
        "@ {:.2} GHz     : {:.1} us  ({:.2} GMAC/s)",
        entry.report.fmax_ghz,
        t_us,
        r.macs_per_cycle * entry.report.fmax_ghz
    );
    Ok(0)
}

fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::{Metrics, Server, ServerPolicy, ServiceHandle, SoftwareService};
    use std::sync::Arc;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7878");
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    let policy = ServerPolicy {
        fuse_gemm: args.flag("no-fuse").is_none(),
        shards: args.flag_usize("shards", 2).max(1),
        max_inflight: args.flag_usize("max-inflight", 1024),
        ..ServerPolicy::default()
    };
    let plane_capacity = args.flag_usize("plane-cache", 64);
    let software = || -> anyhow::Result<ServiceHandle> {
        let svc = SoftwareService::new(
            PdpuConfig::paper_default(),
            &[784, 128, 10],
            args.flag_usize("batch", 32).max(1),
            (32, 147, 32),
            2023,
        )?
        .with_plane_cache_capacity(plane_capacity);
        Ok(ServiceHandle::from_software(svc))
    };
    let service = if args.flag("software").is_some() {
        println!("backend: software PDPU engine (batched bit-exact functional model)");
        software()?
    } else {
        match ServiceHandle::start(dir) {
            Ok(s) => s,
            Err(e) => {
                println!("PJRT backend unavailable ({e:#}); serving via the software PDPU engine");
                software()?
            }
        }
    };
    let (m, k, n) = service.info().gemm_mkn;
    let trace_every = args.flag_usize("trace", 0) as u32;
    crate::obs::trace::set_sampling(trace_every);
    let shadow_every = args.flag_usize("shadow", 0) as u32;
    crate::obs::shadow::set_sampling(shadow_every);
    let metrics = Arc::new(Metrics::new());
    let server = Server::start_with(addr, service, metrics, policy)?;
    println!("pdpu coordinator listening on {}", server.addr);
    println!(
        "serving tier: {} shard(s), admission budget {} in flight, plane cache {} plane(s)",
        server.tier().shard_count(),
        policy.max_inflight,
        plane_capacity
    );
    println!(
        "cross-request GEMM fusion: {}",
        if policy.fuse_gemm { "on" } else { "off (--no-fuse)" }
    );
    if trace_every > 0 {
        println!("request tracing: 1-in-{trace_every} sampling (export with `pdpu trace`)");
    }
    if shadow_every > 0 {
        println!(
            "FP64 shadow execution: 1-in-{shadow_every} engine launches (report with `pdpu numerics`)"
        );
    }
    println!(
        "protocol: JSON lines — {{\"op\":\"infer\",\"image\":[784 floats]}} | \
         {{\"op\":\"gemm\",\"a\":[{} floats],\"b\":[{} floats]}} | \
         {{\"op\":\"train\",\"images\":[[784]…],\"labels\":[ints]}} | {{\"op\":\"stats\"}} | \
         {{\"op\":\"metrics\"}} | {{\"op\":\"trace\"}} | {{\"op\":\"numerics\"}} | {{\"op\":\"ping\"}}",
        m * k,
        k * n
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<i32> {
    use crate::dnn::dataset::mnist_like;
    use crate::train::Trainer;
    use std::time::Instant;

    let epochs = args.flag_usize("epochs", 3).max(1);
    let batch = args.flag_usize("batch", 32).max(1);
    let limit = args.flag_usize("limit", 256).max(batch);
    let classes = args.flag_usize("classes", 4).clamp(2, 16);
    let hidden = args.flag_usize("hidden", 16).max(1);
    let seed = args.flag_usize("seed", 2023) as u64;
    let lr: f64 = args.flag("lr").unwrap_or("0.05").parse().map_err(|_| anyhow::anyhow!("bad --lr"))?;
    anyhow::ensure!(lr > 0.0 && lr.is_finite(), "--lr must be a positive number");

    let cfg = PdpuConfig::paper_default();
    let layer_sizes = vec![784usize, hidden, classes];
    println!("=== pdpu train — mixed-precision posit SGD through the batched engine ===");
    println!("config  : {} (software backend, no PJRT artifacts)", cfg.label());
    println!(
        "model   : {}-{}-{} MLP, weights stored in P({},{}), lr {lr}",
        layer_sizes[0],
        hidden,
        classes,
        cfg.out_fmt.n(),
        cfg.out_fmt.es()
    );
    let ds = mnist_like(seed ^ 0xDA7A, limit, classes);
    println!("dataset : {} bundled 28×28 examples, {classes} classes, batch {batch}\n", ds.images.len());

    let mut trainer = Trainer::new(cfg, &layer_sizes, lr, seed);
    let t0 = Instant::now();
    let mut prev: Option<f64> = None;
    let mut monotone = true;
    for e in 1..=epochs {
        let te = Instant::now();
        let s = trainer.run_epoch(&ds, batch, e);
        let dt = te.elapsed().as_secs_f64();
        println!(
            "epoch {e}/{epochs}  loss {:.4}  acc {:5.1}%  ({} steps, {:.1} steps/s, {:.0} examples/s)",
            s.mean_loss,
            100.0 * s.accuracy,
            s.steps,
            s.steps as f64 / dt.max(1e-9),
            s.examples as f64 / dt.max(1e-9)
        );
        if let Some(p) = prev {
            monotone &= s.mean_loss < p;
        }
        prev = Some(s.mean_loss);
    }
    println!(
        "\ndone in {:.1}s — epoch loss {}",
        t0.elapsed().as_secs_f64(),
        if epochs < 2 {
            "trend needs --epochs ≥ 2".to_string()
        } else if monotone {
            "strictly decreasing".to_string()
        } else {
            "NOT strictly decreasing (try a smaller --lr)".to_string()
        }
    );
    Ok(0)
}

/// One JSON-lines round trip against a running coordinator: connect,
/// write `req` as a line, read and parse the one-line response.
fn wire_request(addr: &str, req: &crate::coordinator::json::Json) -> anyhow::Result<crate::coordinator::json::Json> {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("cannot reach a pdpu server at {addr}: {e}"))?;
    stream.write_all((req.to_string() + "\n").as_bytes())?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    anyhow::ensure!(!line.trim().is_empty(), "server at {addr} closed the connection without replying");
    crate::coordinator::json::parse(&line).map_err(|e| anyhow::anyhow!("bad response from {addr}: {e}"))
}

fn cmd_stats(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::json::Json;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7878");
    if args.flag("prom").is_some() {
        let resp = wire_request(addr, &Json::obj(vec![("op", Json::Str("metrics".to_string()))]))?;
        let Some(text) = resp.get("prometheus").and_then(Json::as_str) else {
            anyhow::bail!("server returned no 'prometheus' field: {resp}");
        };
        print!("{text}");
    } else {
        let resp = wire_request(addr, &Json::obj(vec![("op", Json::Str("stats".to_string()))]))?;
        println!("{resp}");
    }
    Ok(0)
}

fn cmd_trace(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::json::Json;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7878");
    let mut fields: Vec<(&str, Json)> = vec![("op", Json::Str("trace".to_string()))];
    if let Some(v) = args.flag("sample") {
        let every: u32 = v.parse().map_err(|_| anyhow::anyhow!("--sample wants a non-negative integer"))?;
        fields.push(("sample", Json::Num(f64::from(every))));
    }
    if args.flag("clear").is_some() {
        fields.push(("clear", Json::Bool(true)));
    }
    let resp = wire_request(addr, &Json::obj(fields))?;
    anyhow::ensure!(matches!(resp.get("ok"), Some(Json::Bool(true))), "server error: {resp}");
    let events = resp.get("events").cloned().unwrap_or(Json::Arr(Vec::new()));
    let n_events = events.as_arr().map_or(0, <[Json]>::len);
    let sampling = resp.get("sampling").and_then(Json::as_f64).unwrap_or(0.0);
    // chrome://tracing / Perfetto wrapper object
    let doc = Json::obj(vec![
        ("traceEvents", events),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ]);
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, doc.to_string() + "\n")?;
            println!(
                "wrote {n_events} span event(s) to {path} (server sampling: {}) — open in chrome://tracing",
                if sampling > 0.0 { format!("1-in-{sampling}") } else { "off".to_string() }
            );
        }
        None => println!("{doc}"),
    }
    Ok(0)
}

fn cmd_numerics(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::json::Json;
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7878");
    let mut fields: Vec<(&str, Json)> = vec![("op", Json::Str("numerics".to_string()))];
    if let Some(v) = args.flag("shadow") {
        let every: u32 = v.parse().map_err(|_| anyhow::anyhow!("--shadow wants a non-negative integer"))?;
        fields.push(("shadow", Json::Num(f64::from(every))));
    }
    let resp = wire_request(addr, &Json::obj(fields))?;
    anyhow::ensure!(matches!(resp.get("ok"), Some(Json::Bool(true))), "server error: {resp}");
    if args.flag("json").is_some() {
        println!("{resp}");
        return Ok(0);
    }

    let f = |v: &Json, k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let s = |v: &Json, k: &str| v.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
    let sampling = resp.get("shadow_sampling").and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "numerics observatory — FP64 shadow sampling: {}",
        if sampling > 0.0 { format!("1-in-{sampling}") } else { "off (arm with --shadow N)".to_string() }
    );
    let sites = resp.get("sites").and_then(Json::as_arr).map(<[Json]>::to_vec).unwrap_or_default();
    if sites.is_empty() {
        println!("no sites recorded yet — drive some traffic through the server first");
        return Ok(0);
    }

    println!(
        "\n{:<16} {:<24} {:>8} {:>10} {:>8} {:>8} {:>6} {:>9} {:>14}",
        "site", "cfg", "launches", "outputs", "±maxpos", "±minpos", "NaR", "roundings", "scale range"
    );
    for site in &sites {
        let range = match (
            site.get("min_scale").and_then(Json::as_f64),
            site.get("max_scale").and_then(Json::as_f64),
        ) {
            (Some(lo), Some(hi)) => format!("[{lo}, {hi}]"),
            _ => "—".to_string(),
        };
        println!(
            "{:<16} {:<24} {:>8} {:>10} {:>8} {:>8} {:>6} {:>9} {:>14}",
            s(site, "site"),
            s(site, "cfg"),
            f(site, "launches"),
            f(site, "outputs"),
            f(site, "sat_maxpos"),
            f(site, "sat_minpos"),
            f(site, "nar"),
            f(site, "quire_roundings"),
            range
        );
    }

    println!("\noutput dynamic range (64 buckets of 4 binades, from scale 2^-128):");
    const RAMP: [char; 5] = [' ', '.', 'o', 'O', '#'];
    for site in &sites {
        let Some(hist) = site.get("output_scale_hist").and_then(Json::as_f64_vec) else { continue };
        let peak = hist.iter().copied().fold(0.0f64, f64::max);
        if peak <= 0.0 {
            continue;
        }
        let glyphs: String = hist
            .iter()
            .map(|&c| {
                let idx = if c <= 0.0 { 0 } else { 1 + ((c / peak) * 3.999) as usize };
                RAMP.get(idx.min(RAMP.len() - 1)).copied().unwrap_or('#')
            })
            .collect();
        println!("{:<16} |{glyphs}|", s(site, "site"));
    }

    let shadowed: Vec<&Json> = sites
        .iter()
        .filter(|v| v.get("shadow").is_some_and(|sh| f(sh, "samples") > 0.0))
        .collect();
    if !shadowed.is_empty() {
        println!("\nFP64 shadow accuracy (sampled launches re-run in double precision):");
        println!(
            "{:<16} {:>9} {:>13} {:>13} {:>11}",
            "site", "samples", "mean rel err", "max abs err", "dec digits"
        );
        for site in shadowed {
            let Some(sh) = site.get("shadow") else { continue };
            println!(
                "{:<16} {:>9} {:>13.3e} {:>13.3e} {:>11.2}",
                s(site, "site"),
                f(sh, "samples"),
                f(sh, "mean_rel_err"),
                f(sh, "max_abs_err"),
                f(sh, "mean_decimal_accuracy")
            );
        }
    }

    let advisor = resp.get("advisor").and_then(Json::as_arr).map(<[Json]>::to_vec).unwrap_or_default();
    if !advisor.is_empty() {
        println!("\nprecision advisor — smallest P(n, es) covering each site's observed range + accuracy:");
        println!(
            "{:<16} {:<24} {:>12} {:>11} {:>12}",
            "site", "current cfg", "scale ±2^", "dec digits", "recommend"
        );
        for a in &advisor {
            println!(
                "{:<16} {:<24} {:>12} {:>11.2} {:>12}",
                s(a, "site"),
                s(a, "cfg"),
                f(a, "required_scale"),
                f(a, "target_decimal_digits"),
                format!("P({}, {})", f(a, "rec_n"), f(a, "rec_es"))
            );
        }
    }
    Ok(0)
}

fn cmd_lint(args: &Args) -> anyhow::Result<i32> {
    use crate::analysis;
    let root = std::path::PathBuf::from(args.flag("root").unwrap_or("."));
    anyhow::ensure!(
        root.join("rust").join("src").is_dir(),
        "no rust/src under {} — run from the repo root or pass --root",
        root.display()
    );
    let diags = analysis::run_lint(&root).map_err(|e| anyhow::anyhow!(e))?;
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("pdpu lint: clean");
        Ok(0)
    } else {
        println!("pdpu lint: {} violation(s)", diags.len());
        Ok(1)
    }
}

fn cmd_selftest(args: &Args) -> anyhow::Result<i32> {
    use crate::coordinator::PositService;
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    print!("loading artifacts from {dir}… ");
    let service = PositService::load(dir)?;
    println!("ok ({} entries)", service.manifest().entries.len());
    print!("running one inference batch… ");
    let img = vec![0.5f32; service.input_dim()];
    let logits = service.infer_batch(&[img])?;
    anyhow::ensure!(logits[0].len() == service.classes());
    anyhow::ensure!(logits[0].iter().all(|v| v.is_finite()));
    println!("ok (logits {:?})", &logits[0][..3.min(logits[0].len())]);
    print!("running one posit GEMM… ");
    let (m, k, n) = service.manifest().gemm_mkn;
    let a = vec![1.0f32; m * k];
    let b = vec![0.5f32; k * n];
    let c = service.gemm(&a, &b)?;
    anyhow::ensure!((c[0] - k as f32 * 0.5).abs() < 1e-3, "gemm value {}", c[0]);
    println!("ok (c[0] = {})", c[0]);
    println!("selftest PASSED");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = Args::parse(&argv("exp table1 --hw=16 --oc 4 --verbose"));
        assert_eq!(a.positional, vec!["exp", "table1"]);
        assert_eq!(a.flag("hw"), Some("16"));
        assert_eq!(a.flag("oc"), Some("4"));
        assert_eq!(a.flag("verbose"), Some("true"));
        assert_eq!(a.flag_usize("hw", 0), 16);
        assert_eq!(a.flag_usize("missing", 7), 7);
    }

    #[test]
    fn format_flag_parses() {
        let a = Args::parse(&argv("quantize --format=13,2"));
        assert_eq!(a.format("format", (16, 2)).unwrap(), PositFormat::p(13, 2));
        let a = Args::parse(&argv("quantize"));
        assert_eq!(a.format("format", (16, 2)).unwrap(), PositFormat::p(16, 2));
        let a = Args::parse(&argv("quantize --format=99,2"));
        assert!(a.format("format", (16, 2)).is_err());
    }

    #[test]
    fn unknown_command_exits_2() {
        assert_eq!(run(argv("bogus")).unwrap(), 2);
    }

    #[test]
    fn quantize_runs() {
        assert_eq!(run(argv("quantize --format=8,2 11.0 1.06")).unwrap(), 0);
    }

    #[test]
    fn dot_runs() {
        let mut v = argv("dot --n 4 --wm 14 --acc 1.0");
        v.extend(argv("-- 1 2 3 4 -- 1 1 1 1").into_iter());
        let v: Vec<String> = v.into_iter().map(|s| if s == "--" { "--".into() } else { s }).collect();
        assert_eq!(run(v).unwrap(), 0);
    }

    #[test]
    fn schedule_runs() {
        assert_eq!(run(argv("schedule --outputs 16 --dot-len 32 --units 2")).unwrap(), 0);
    }

    #[test]
    fn train_runs_a_tiny_job() {
        assert_eq!(run(argv("train --epochs 1 --limit 16 --batch 8 --hidden 4 --classes 2")).unwrap(), 0);
    }

    #[test]
    fn train_rejects_bad_lr() {
        assert!(run(argv("train --lr nope")).is_err());
        assert!(run(argv("train --lr -1")).is_err());
    }

    #[test]
    fn stats_fails_fast_without_a_server() {
        // port 1 refuses immediately on loopback — the error must surface
        assert!(run(argv("stats --addr 127.0.0.1:1")).is_err());
        assert!(run(argv("stats --addr 127.0.0.1:1 --prom")).is_err());
    }

    #[test]
    fn trace_rejects_bad_sample_before_connecting() {
        assert!(run(argv("trace --addr 127.0.0.1:1 --sample nope")).is_err());
        assert!(run(argv("trace --addr 127.0.0.1:1 --sample -3")).is_err());
    }

    #[test]
    fn numerics_fails_fast_without_a_server() {
        // port 1 refuses immediately on loopback — the error must surface
        assert!(run(argv("numerics --addr 127.0.0.1:1")).is_err());
        assert!(run(argv("numerics --addr 127.0.0.1:1 --json")).is_err());
    }

    #[test]
    fn numerics_rejects_bad_shadow_before_connecting() {
        assert!(run(argv("numerics --addr 127.0.0.1:1 --shadow nope")).is_err());
        assert!(run(argv("numerics --addr 127.0.0.1:1 --shadow -2")).is_err());
    }

    #[test]
    fn lint_runs_clean_on_this_repo() {
        let v = vec!["lint".to_string(), format!("--root={}", env!("CARGO_MANIFEST_DIR"))];
        assert_eq!(run(v).unwrap(), 0);
    }

    #[test]
    fn lint_rejects_missing_root() {
        assert!(run(argv("lint --root /nonexistent-pdpu-root")).is_err());
    }
}
