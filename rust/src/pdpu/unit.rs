//! The PDPU functional unit: composition of the six stages into the
//! combinational dot-product operation of Eq. (2):
//!
//! ```text
//! out = acc + Va·Vb = acc + a₀·b₀ + a₁·b₁ + … + a_{N−1}·b_{N−1}
//! ```
//!
//! Bit-exact: this computes exactly what the RTL computes, including the
//! S3 alignment truncation at `Wm` bits and the single S6 rounding.

use super::config::PdpuConfig;
use super::lanes::{dot_packed_chunk, LaneScratch, PackedLane, MAX_FAST_LANES};
use super::stages::*;
use crate::posit::Posit;

/// A PDPU instance (one hardware unit of a fixed configuration).
#[derive(Clone, Debug)]
pub struct Pdpu {
    cfg: PdpuConfig,
}

/// Every inter-stage record of one operation — the pipeline registers the
/// RTL would latch. Used by stage-invariant tests and debugging.
#[derive(Clone, Debug)]
pub struct Trace {
    pub s1: DecodedInputs,
    pub s2: Multiplied,
    pub s3: Aligned,
    pub s4: Accumulated,
    pub s5: Normalized,
    pub out: Posit,
}

/// Reusable workspace for the allocation-free datapath: the S1–S3
/// inter-stage records plus the fixed-size lane-packed scratch of the
/// fused fast path, allocated once and refilled per operation.
///
/// One `DotScratch` per worker thread keeps the batched GEMM engine free
/// of per-operation heap traffic; [`Pdpu::dot_with`] is bit-identical to
/// [`Pdpu::dot`] (the fast path shares the scalar stages' definitions of
/// decode, alignment, normalization and encoding).
#[derive(Clone, Debug)]
pub struct DotScratch {
    pub(crate) s1: DecodedInputs,
    pub(crate) s2: Multiplied,
    pub(crate) s3: Aligned,
    /// fixed-field workspace of the lane-packed fused kernel
    pub(crate) lanes: LaneScratch,
    /// packed-operand staging buffers for [`Pdpu::dot_with`]
    pub(crate) pa: Vec<PackedLane>,
    pub(crate) pb: Vec<PackedLane>,
}

impl DotScratch {
    /// An empty workspace; the inter-stage vectors grow on first use.
    pub fn new() -> Self {
        Self {
            s1: DecodedInputs::empty(),
            s2: Multiplied::empty(),
            s3: Aligned::empty(),
            lanes: LaneScratch::new(),
            pa: Vec::new(),
            pb: Vec::new(),
        }
    }

    /// A workspace pre-sized for `cfg`: the S1/S2 lane vectors reserve
    /// `N` slots and the S3 addend vector `N + 1`, so the very first
    /// operation through the scratch is already allocation-free. The
    /// batched GEMM engine builds one of these per worker.
    pub fn for_config(cfg: &PdpuConfig) -> Self {
        let mut s = Self::new();
        s.s1.products.reserve(cfg.n);
        s.s2.terms.reserve(cfg.n);
        s.s3.addends.reserve(cfg.n + 1);
        s.pa.reserve(cfg.n);
        s.pb.reserve(cfg.n);
        s
    }
}

impl Default for DotScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl Pdpu {
    pub fn new(cfg: PdpuConfig) -> Self {
        Self { cfg }
    }

    #[inline]
    pub fn config(&self) -> &PdpuConfig {
        &self.cfg
    }

    /// One fused dot-product-accumulate: `acc + Σᵢ aᵢ·bᵢ`, rounded once.
    ///
    /// `a`/`b` must hold exactly `N` posits of the input format; `acc` and
    /// the result are in the output format.
    pub fn dot(&self, acc: Posit, a: &[Posit], b: &[Posit]) -> Posit {
        let s1 = s1_decode(&self.cfg, acc, a, b);
        let s2 = s2_multiply(&self.cfg, &s1);
        let s3 = s3_align(&self.cfg, &s2);
        let s4 = s4_accumulate(&self.cfg, &s3);
        let s5 = s5_normalize(&self.cfg, &s4);
        s6_encode(&self.cfg, &s5)
    }

    /// Like [`Self::dot`] but running through a reusable [`DotScratch`]
    /// instead of allocating fresh inter-stage records per call.
    ///
    /// For `N ≤` [`MAX_FAST_LANES`] (every practical configuration) this
    /// runs the lane-packed fused kernel
    /// ([`crate::pdpu::lanes::dot_packed_chunk`]); larger N falls back to
    /// the staged scalar pipeline. Both are bit-identical to
    /// [`Self::dot`] — enforced by the exhaustive conformance sweep.
    // pdpu-lint: hot-path
    pub fn dot_with(&self, acc: Posit, a: &[Posit], b: &[Posit], scratch: &mut DotScratch) -> Posit {
        if self.cfg.n <= MAX_FAST_LANES {
            assert_eq!(a.len(), self.cfg.n, "Va length must equal configured N");
            assert_eq!(b.len(), self.cfg.n, "Vb length must equal configured N");
            scratch.pa.clear();
            scratch.pa.extend(a.iter().map(|&p| PackedLane::from_posit(p)));
            scratch.pb.clear();
            scratch.pb.extend(b.iter().map(|&p| PackedLane::from_posit(p)));
            return dot_packed_chunk(&self.cfg, acc, &scratch.pa, &scratch.pb, &mut scratch.lanes);
        }
        s1_decode_into(&self.cfg, acc, a, b, &mut scratch.s1);
        s2_multiply_into(&self.cfg, &scratch.s1, &mut scratch.s2);
        s3_align_into(&self.cfg, &scratch.s2, &mut scratch.s3);
        let s4 = s4_accumulate(&self.cfg, &scratch.s3);
        let s5 = s5_normalize(&self.cfg, &s4);
        s6_encode(&self.cfg, &s5)
    }

    /// Run S2–S6 over an already-filled S1 record in `scratch` — the entry
    /// point the batched GEMM engine uses after fusing pre-decoded operand
    /// planes directly into `scratch.s1` (skipping the per-call posit
    /// decode entirely).
    // pdpu-lint: hot-path
    pub(crate) fn finish_from_s1(&self, scratch: &mut DotScratch) -> Posit {
        s2_multiply_into(&self.cfg, &scratch.s1, &mut scratch.s2);
        s3_align_into(&self.cfg, &scratch.s2, &mut scratch.s3);
        let s4 = s4_accumulate(&self.cfg, &scratch.s3);
        let s5 = s5_normalize(&self.cfg, &s4);
        s6_encode(&self.cfg, &s5)
    }

    /// [`Self::finish_from_s1`] with per-stage timestamps: returns the
    /// chunk result plus nanoseconds spent in S2, S3+S4, and S5+S6. Only
    /// the sampled profiling path ([`crate::obs::stages`]) runs this, so
    /// it is deliberately *not* a lint-marked hot-path function — the
    /// clock reads would be noise on the always-on path.
    pub(crate) fn finish_from_s1_profiled(&self, scratch: &mut DotScratch) -> (Posit, u64, u64, u64) {
        let t0 = crate::obs::clock::now();
        s2_multiply_into(&self.cfg, &scratch.s1, &mut scratch.s2);
        let t1 = crate::obs::clock::now();
        s3_align_into(&self.cfg, &scratch.s2, &mut scratch.s3);
        let s4 = s4_accumulate(&self.cfg, &scratch.s3);
        let t2 = crate::obs::clock::now();
        let s5 = s5_normalize(&self.cfg, &s4);
        let out = s6_encode(&self.cfg, &s5);
        let t3 = crate::obs::clock::now();
        let s2_ns = t1.saturating_duration_since(t0).as_nanos() as u64;
        let s34_ns = t2.saturating_duration_since(t1).as_nanos() as u64;
        let s56_ns = t3.saturating_duration_since(t2).as_nanos() as u64;
        (out, s2_ns, s34_ns, s56_ns)
    }


    /// Like [`Self::dot`] but returning all intermediate stage records.
    pub fn dot_trace(&self, acc: Posit, a: &[Posit], b: &[Posit]) -> Trace {
        let s1 = s1_decode(&self.cfg, acc, a, b);
        let s2 = s2_multiply(&self.cfg, &s1);
        let s3 = s3_align(&self.cfg, &s2);
        let s4 = s4_accumulate(&self.cfg, &s3);
        let s5 = s5_normalize(&self.cfg, &s4);
        let out = s6_encode(&self.cfg, &s5);
        Trace { s1, s2, s3, s4, s5, out }
    }

    /// Chunk-based accumulation over arbitrary-length vectors (paper
    /// §III-C: "dot-product operations in DNNs are usually divided into
    /// smaller chunks and performed by chunk-based accumulation").
    ///
    /// Splits `a`/`b` into chunks of `N` (zero-padding the tail), feeding
    /// each chunk's result back as the next accumulator. The intermediate
    /// accumulator stays in the output format — this round-trip through
    /// `out_fmt` per chunk is exactly the hardware's behaviour and the
    /// source of chunked accumulation's residual error vs. one giant quire.
    pub fn dot_chunked(&self, acc: Posit, a: &[Posit], b: &[Posit]) -> Posit {
        assert_eq!(a.len(), b.len(), "vector length mismatch");
        let n = self.cfg.n;
        let mut acc = acc;
        // the zero-padded tail buffers are only needed when the length is
        // not a multiple of N — allocate them lazily for that last chunk
        let mut tail: Option<(Vec<Posit>, Vec<Posit>)> = None;
        for (ca, cb) in a.chunks(n).zip(b.chunks(n)) {
            if ca.len() == n {
                acc = self.dot(acc, ca, cb);
            } else {
                let zero = Posit::zero(self.cfg.in_fmt);
                let (buf_a, buf_b) = tail.get_or_insert_with(|| (vec![zero; n], vec![zero; n]));
                buf_a[..ca.len()].copy_from_slice(ca);
                buf_a[ca.len()..].fill(zero);
                buf_b[..cb.len()].copy_from_slice(cb);
                buf_b[cb.len()..].fill(zero);
                acc = self.dot(acc, buf_a, buf_b);
            }
        }
        acc
    }

    /// [`Self::dot_chunked`] through a reusable [`DotScratch`] — the
    /// allocation-free long-vector path (tail padding included).
    pub fn dot_chunked_with(&self, acc: Posit, a: &[Posit], b: &[Posit], scratch: &mut DotScratch) -> Posit {
        assert_eq!(a.len(), b.len(), "vector length mismatch");
        let n = self.cfg.n;
        let mut acc = acc;
        let mut tail: Option<(Vec<Posit>, Vec<Posit>)> = None;
        for (ca, cb) in a.chunks(n).zip(b.chunks(n)) {
            if ca.len() == n {
                acc = self.dot_with(acc, ca, cb, scratch);
            } else {
                let zero = Posit::zero(self.cfg.in_fmt);
                let (buf_a, buf_b) = tail.get_or_insert_with(|| (vec![zero; n], vec![zero; n]));
                buf_a[..ca.len()].copy_from_slice(ca);
                buf_a[ca.len()..].fill(zero);
                buf_b[..cb.len()].copy_from_slice(cb);
                buf_b[cb.len()..].fill(zero);
                acc = self.dot_with(acc, buf_a, buf_b, scratch);
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::quire::exact_dot;
    use crate::posit::{p_fma, PositFormat};
    use crate::testing::{check, Rng};

    fn rand_posit(rng: &mut Rng, fmt: PositFormat) -> Posit {
        // random finite posit over the full pattern space
        loop {
            let p = Posit::from_bits(rng.next_u64() as u32 & fmt.mask(), fmt);
            if !p.is_nar() {
                return p;
            }
        }
    }

    fn rand_moderate(rng: &mut Rng, fmt: PositFormat, log2_span: f64) -> Posit {
        Posit::from_f64(rng.log_uniform_signed(-log2_span, log2_span), fmt)
    }

    /// With Wm large enough to cover the whole alignment span of the data,
    /// PDPU must agree with the exact quire bit-for-bit: the fused
    /// architecture with unbounded Wm IS a quire.
    #[test]
    fn matches_quire_when_wm_covers_span() {
        let cfg = PdpuConfig::mixed(8, 16, 2, 4, 96).unwrap();
        let unit = Pdpu::new(cfg);
        check("pdpu≡quire @ wm=96", 0x51AB, 2_000, |rng, _| {
            // data within 2^±10 ⇒ product scales within ±20+…; span ≪ 96
            let a: Vec<Posit> = (0..4).map(|_| rand_moderate(rng, cfg.in_fmt, 10.0)).collect();
            let b: Vec<Posit> = (0..4).map(|_| rand_moderate(rng, cfg.in_fmt, 10.0)).collect();
            let acc = rand_moderate(rng, cfg.out_fmt, 15.0);
            let got = unit.dot(acc, &a, &b);
            let want = exact_dot(acc, &a, &b, cfg.out_fmt);
            assert_eq!(got.bits(), want.bits(), "a={a:?} b={b:?} acc={acc:?}");
        });
    }

    /// N=1, large Wm: PDPU degenerates to a fused multiply-add.
    #[test]
    fn n1_equals_fma() {
        let cfg = PdpuConfig::uniform(16, 2, 1, 96).unwrap();
        let unit = Pdpu::new(cfg);
        check("pdpu(n=1)≡fma", 0xF1A, 3_000, |rng, _| {
            let a = rand_moderate(rng, cfg.in_fmt, 14.0);
            let b = rand_moderate(rng, cfg.in_fmt, 14.0);
            let c = rand_moderate(rng, cfg.out_fmt, 20.0);
            let got = unit.dot(c, &[a], &[b]);
            let want = p_fma(a, b, c, cfg.out_fmt);
            assert_eq!(got.bits(), want.bits(), "{a:?}·{b:?}+{c:?}");
        });
    }

    /// Analytic error bound of the Wm truncation: each of the N+1 aligned
    /// addends truncates toward zero by less than one grid ulp
    /// (2^(e_max+2−Wm)), and S6 adds at most half an output ulp. The
    /// Wm=14 paper configuration must respect this bound on every input.
    #[test]
    fn paper_config_respects_truncation_bound() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        check("pdpu(wm=14) within (N+1) grid ulps of quire", 0xCAFE, 3_000, |rng, _| {
            let a: Vec<Posit> = (0..4).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let b: Vec<Posit> = (0..4).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let acc = Posit::from_f64(rng.normal(), cfg.out_fmt);
            let t = unit.dot_trace(acc, &a, &b);
            let got = t.out;
            let want = exact_dot(acc, &a, &b, cfg.out_fmt);
            let Some(e_max) = t.s2.e_max else {
                assert_eq!(got.bits(), want.bits());
                return;
            };
            let grid_ulp = 2f64.powi(e_max + 2 - cfg.wm as i32);
            let truncation = (cfg.n as f64 + 1.0) * grid_ulp;
            // want is the correctly-rounded exact value: distance between
            // the two f64 readings is ≤ truncation + one output rounding
            // step each side. Output ulp near `want`:
            let out_ulp = (want.succ().to_f64() - want.to_f64()).abs().max(f64::MIN_POSITIVE);
            let diff = (got.to_f64() - want.to_f64()).abs();
            assert!(
                diff <= truncation + out_ulp,
                "diff {diff:.3e} > bound {:.3e} (got {got:?} want {want:?} a={a:?} b={b:?} acc={acc:?})",
                truncation + out_ulp
            );
        });
    }

    /// Wm monotonicity: increasing the alignment width can only move the
    /// result closer to (or keep it at) the exact quire value.
    #[test]
    fn wm_monotonically_improves_accuracy() {
        let mut rng = Rng::seeded(0x3141);
        let mut err = std::collections::HashMap::<u32, f64>::new();
        for _ in 0..800 {
            let a: Vec<Posit> =
                (0..4).map(|_| Posit::from_f64(rng.normal_ms(0.0, 2.0), PositFormat::p(13, 2))).collect();
            let b: Vec<Posit> =
                (0..4).map(|_| Posit::from_f64(rng.normal_ms(0.0, 2.0), PositFormat::p(13, 2))).collect();
            let acc = Posit::zero(PositFormat::p(16, 2));
            let exact = exact_dot(acc, &a, &b, PositFormat::p(16, 2)).to_f64();
            for wm in [6u32, 10, 14, 20, 30] {
                let cfg = PdpuConfig::mixed(13, 16, 2, 4, wm).unwrap();
                let got = Pdpu::new(cfg).dot(acc, &a, &b).to_f64();
                *err.entry(wm).or_insert(0.0) += (got - exact).abs();
            }
        }
        assert!(err[&6] >= err[&10] && err[&10] >= err[&14], "{err:?}");
        assert!(err[&14] >= err[&20] && err[&20] >= err[&30], "{err:?}");
        assert!(err[&30] < 1e-12, "wm=30 should be exact on this data: {err:?}");
    }

    #[test]
    fn nar_and_zero_semantics() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let zero_in = Posit::zero(cfg.in_fmt);
        let zero_out = Posit::zero(cfg.out_fmt);
        let one = Posit::one(cfg.in_fmt);
        // all zeros → zero
        assert!(unit.dot(zero_out, &[zero_in; 4], &[zero_in; 4]).is_zero());
        // NaR anywhere → NaR
        let nar_in = Posit::nar(cfg.in_fmt);
        assert!(unit.dot(zero_out, &[one, nar_in, one, one], &[one; 4]).is_nar());
        assert!(unit.dot(Posit::nar(cfg.out_fmt), &[one; 4], &[one; 4]).is_nar());
        // 1·1 ×4 + 0 = 4
        assert_eq!(unit.dot(zero_out, &[one; 4], &[one; 4]).to_f64(), 4.0);
    }

    #[test]
    fn perfect_cancellation_yields_zero() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let x = Posit::from_f64(1.7, cfg.in_fmt);
        let y = Posit::from_f64(-1.7, cfg.in_fmt);
        let one = Posit::one(cfg.in_fmt);
        let z = Posit::zero(cfg.in_fmt);
        let out = unit.dot(Posit::zero(cfg.out_fmt), &[x, y, z, z], &[one, one, z, z]);
        assert!(out.is_zero(), "{out:?}");
    }

    #[test]
    fn dot_chunked_matches_manual_loop() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let mut rng = Rng::seeded(0xC0DE);
        for len in [1usize, 3, 4, 5, 8, 11, 147] {
            let a: Vec<Posit> = (0..len).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let b: Vec<Posit> = (0..len).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let chunked = unit.dot_chunked(Posit::zero(cfg.out_fmt), &a, &b);
            // manual: pad to multiple of N, loop dot()
            let zero = Posit::zero(cfg.in_fmt);
            let mut pa = a.clone();
            let mut pb = b.clone();
            while pa.len() % cfg.n != 0 {
                pa.push(zero);
                pb.push(zero);
            }
            let mut acc = Posit::zero(cfg.out_fmt);
            for i in (0..pa.len()).step_by(cfg.n) {
                acc = unit.dot(acc, &pa[i..i + cfg.n], &pb[i..i + cfg.n]);
            }
            assert_eq!(chunked.bits(), acc.bits(), "len={len}");
        }
    }

    /// The scratch (allocation-free) path must be bit-identical to the
    /// allocating path on every input, including NaR/zero specials and a
    /// scratch reused across differently-shaped operations.
    #[test]
    fn scratch_path_matches_allocating_path() {
        let configs = [
            PdpuConfig::paper_default(),
            PdpuConfig::uniform(16, 2, 1, 96).unwrap(),
            PdpuConfig::mixed(8, 16, 2, 8, 6).unwrap(),
        ];
        let mut scratch = DotScratch::new();
        for (ci, cfg) in configs.iter().enumerate() {
            let unit = Pdpu::new(*cfg);
            check("dot_with ≡ dot", 0xD07 ^ ci as u64, 800, |rng, _| {
                let a: Vec<Posit> = (0..cfg.n).map(|_| rand_posit(rng, cfg.in_fmt)).collect();
                let b: Vec<Posit> = (0..cfg.n).map(|_| rand_posit(rng, cfg.in_fmt)).collect();
                let acc = rand_posit(rng, cfg.out_fmt);
                assert_eq!(
                    unit.dot(acc, &a, &b).bits(),
                    unit.dot_with(acc, &a, &b, &mut scratch).bits()
                );
            });
        }
    }

    #[test]
    fn chunked_scratch_path_matches() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let mut rng = Rng::seeded(0xC4A7);
        let mut scratch = DotScratch::new();
        for len in [0usize, 1, 4, 7, 147] {
            let a: Vec<Posit> = (0..len).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let b: Vec<Posit> = (0..len).map(|_| Posit::from_f64(rng.normal(), cfg.in_fmt)).collect();
            let acc = Posit::from_f64(rng.normal(), cfg.out_fmt);
            assert_eq!(
                unit.dot_chunked(acc, &a, &b).bits(),
                unit.dot_chunked_with(acc, &a, &b, &mut scratch).bits(),
                "len={len}"
            );
        }
    }

    /// Stage invariants on random traces.
    #[test]
    fn trace_invariants() {
        let cfg = PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        check("stage invariants", 0x7ACE, 1_500, |rng, _| {
            let a: Vec<Posit> = (0..4).map(|_| rand_posit(rng, cfg.in_fmt)).collect();
            let b: Vec<Posit> = (0..4).map(|_| rand_posit(rng, cfg.in_fmt)).collect();
            let acc = rand_posit(rng, cfg.out_fmt);
            let t = unit.dot_trace(acc, &a, &b);
            // e_max dominates every live scale
            if let Some(emax) = t.s2.e_max {
                for term in &t.s2.terms {
                    if !term.zero {
                        assert!(term.e_ab <= emax);
                    }
                }
                if !t.s2.acc.zero {
                    assert!(t.s2.acc.e_c <= emax);
                }
            }
            // aligned magnitudes fit the window
            for &ad in &t.s3.addends {
                assert!(ad.unsigned_abs() < (1u128 << cfg.wm));
            }
            // accumulated sum fits the modeled adder
            assert!(t.s4.sum.unsigned_abs() <= (1u128 << (cfg.acc_width() - 1)));
        });
    }
}
