//! Cycle-level model of PDPU's fine-grained 6-stage pipeline (paper §IV-B,
//! Fig. 6).
//!
//! The functional unit in [`super::unit`] computes *values*; this model
//! computes *timing*: issue/retire cycles, occupancy, and the RAW hazard
//! that chunk-based accumulation creates (chunk k+1's `acc` operand is
//! chunk k's result, 6 cycles later). The coordinator's scheduler uses it
//! to model PDPU-array throughput, and the Fig. 6 experiment combines it
//! with per-stage delays from the cost model.

/// Number of pipeline stages (S1..S6).
pub const STAGES: usize = 6;

/// An operation in flight, identified by caller-assigned id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpToken {
    pub id: u64,
    /// id of an operation whose *result* this op consumes as `acc`
    /// (None = independent). Creates a RAW hazard: this op cannot issue
    /// until the dependency has retired.
    pub depends_on: Option<u64>,
    pub issued_at: u64,
}

/// A retired operation with its timing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Retired {
    pub id: u64,
    pub issued_at: u64,
    pub retired_at: u64,
}

/// Aggregate pipeline statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    pub cycles: u64,
    pub issued: u64,
    pub retired: u64,
    /// cycles where stage S1 sat empty while work was waiting on a hazard
    pub hazard_stalls: u64,
    /// cycles where stage S1 sat empty with no work offered
    pub idle_cycles: u64,
}

impl PipelineStats {
    /// Operations retired per cycle (≤ 1.0; 1.0 = fully pipelined).
    pub fn throughput(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.retired as f64 / self.cycles as f64
        }
    }
}

/// The 6-stage pipeline.
#[derive(Clone, Debug)]
pub struct Pipeline {
    stages: [Option<OpToken>; STAGES],
    cycle: u64,
    stats: PipelineStats,
    /// ids retired so far (hazard resolution); bounded by caller behaviour —
    /// chunk chains only ever wait on the previous id, so we keep a window.
    recently_retired: std::collections::VecDeque<u64>,
    retired_capacity: usize,
}

impl Default for Pipeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Pipeline {
    pub fn new() -> Self {
        Self {
            stages: [None; STAGES],
            cycle: 0,
            stats: PipelineStats::default(),
            recently_retired: std::collections::VecDeque::new(),
            retired_capacity: 4 * STAGES,
        }
    }

    #[inline]
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    #[inline]
    pub fn stats(&self) -> PipelineStats {
        self.stats
    }

    /// Number of stages currently holding an operation.
    pub fn occupancy(&self) -> usize {
        self.stages.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.occupancy() == 0
    }

    /// Would `op` be admissible next cycle? False while its dependency has
    /// not retired (RAW hazard on the accumulator operand).
    pub fn can_issue(&self, depends_on: Option<u64>) -> bool {
        match depends_on {
            None => true,
            Some(dep) => {
                let in_flight = self.stages.iter().flatten().any(|t| t.id == dep);
                !in_flight && self.recently_retired.contains(&dep)
            }
        }
    }

    /// Advance one clock cycle, optionally issuing a new operation into S1.
    ///
    /// Returns the operation leaving S6 this cycle, if any. If `issue` is
    /// `Some` but blocked by a hazard, the offer is *rejected* (returned
    /// inside `IssueResult::Stalled`) and the caller retries next cycle.
    pub fn tick(&mut self, issue: Option<(u64, Option<u64>)>) -> TickResult {
        self.cycle += 1;
        self.stats.cycles += 1;

        // advance S1..S5 → S2..S6: an op issued at cycle t occupies S1..S6
        // during cycles t..t+5 and its result latches at the end of t+5
        // (fully pipelined, no internal stalls)
        for i in (1..STAGES).rev() {
            if self.stages[i].is_none() {
                self.stages[i] = self.stages[i - 1].take();
            }
        }

        // issue into S1 (before retirement below: a dependent op therefore
        // cannot issue in the same cycle its dependency completes, which is
        // the RTL's register-forwarding-free behaviour)
        let stalled = match issue {
            None => {
                self.stats.idle_cycles += 1;
                None
            }
            Some((id, dep)) => {
                if self.stages[0].is_none() && self.can_issue(dep) {
                    self.stages[0] = Some(OpToken { id, depends_on: dep, issued_at: self.cycle });
                    self.stats.issued += 1;
                    None
                } else {
                    self.stats.hazard_stalls += 1;
                    Some((id, dep))
                }
            }
        };

        // retire: the op finishing S6 this cycle
        let retired = self.stages[STAGES - 1].take().map(|t| {
            self.stats.retired += 1;
            self.recently_retired.push_back(t.id);
            while self.recently_retired.len() > self.retired_capacity {
                self.recently_retired.pop_front();
            }
            Retired { id: t.id, issued_at: t.issued_at, retired_at: self.cycle }
        });

        TickResult { retired, stalled }
    }

    /// Drain the pipeline: tick with no issues until empty, returning the
    /// retirees in order.
    pub fn drain(&mut self) -> Vec<Retired> {
        let mut out = Vec::new();
        while !self.is_empty() {
            if let Some(r) = self.tick(None).retired {
                out.push(r);
            }
        }
        out
    }
}

/// Result of one pipeline clock.
#[derive(Clone, Copy, Debug)]
pub struct TickResult {
    pub retired: Option<Retired>,
    /// an offered issue that was rejected this cycle (hazard/busy)
    pub stalled: Option<(u64, Option<u64>)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_is_six_cycles() {
        let mut p = Pipeline::new();
        let r = p.tick(Some((1, None)));
        assert!(r.retired.is_none() && r.stalled.is_none());
        let mut retired = None;
        for _ in 0..STAGES - 1 {
            retired = p.tick(None).retired;
        }
        let r = retired.expect("op must retire after 6 cycles");
        assert_eq!(r.id, 1);
        assert_eq!(r.retired_at - r.issued_at + 1, STAGES as u64);
    }

    #[test]
    fn fully_pipelined_throughput_approaches_one() {
        let mut p = Pipeline::new();
        let mut next_id = 0u64;
        let mut retired = 0u64;
        for _ in 0..1_000 {
            let r = p.tick(Some((next_id, None)));
            next_id += 1;
            if r.retired.is_some() {
                retired += 1;
            }
            assert!(r.stalled.is_none(), "independent ops never stall");
        }
        // first retire happens at cycle 6, then one per cycle → 995 retires
        let s = p.stats();
        assert_eq!(retired, s.retired);
        assert_eq!(s.retired, 1_000 - STAGES as u64 + 1);
        assert!(s.throughput() > 0.99);
    }

    #[test]
    fn raw_hazard_serializes_chunk_chain() {
        // a chain of ops each depending on the previous: every op must wait
        // for the previous to retire → one retire per 6 cycles
        let mut p = Pipeline::new();
        let mut pending: Option<(u64, Option<u64>)> = Some((0, None));
        let mut next = 1u64;
        let mut retired = Vec::new();
        for _ in 0..100 {
            let offer = pending.take();
            let r = p.tick(offer);
            if let Some(ret) = r.retired {
                retired.push(ret);
            }
            pending = match r.stalled {
                Some(s) => Some(s),
                None => {
                    if pending.is_none() && next < 10 {
                        let dep = Some(next - 1);
                        let o = (next, dep);
                        next += 1;
                        Some(o)
                    } else {
                        pending
                    }
                }
            };
        }
        assert_eq!(retired.len(), 10);
        // consecutive retires are ≥ STAGES cycles apart (full serialization)
        for w in retired.windows(2) {
            assert!(w[1].retired_at - w[0].retired_at >= STAGES as u64, "{w:?}");
        }
        assert!(p.stats().hazard_stalls > 0);
    }

    #[test]
    fn interleaving_independent_chains_fills_bubbles() {
        // 6 independent accumulation chains interleaved round-robin keep
        // the pipeline full: ~1 op/cycle despite every chain being serial.
        const CHAINS: usize = STAGES;
        let mut p = Pipeline::new();
        let mut last_id: [Option<u64>; CHAINS] = [None; CHAINS];
        let mut next_id = 0u64;
        let mut issued = 0u64;
        let mut chain = 0usize;
        for _ in 0..600 {
            // find an issuable chain
            let mut offer = None;
            for k in 0..CHAINS {
                let c = (chain + k) % CHAINS;
                let dep = last_id[c];
                if p.can_issue(dep) {
                    offer = Some((c, (next_id, dep)));
                    break;
                }
            }
            match offer {
                Some((c, (id, dep))) => {
                    let r = p.tick(Some((id, dep)));
                    if r.stalled.is_none() {
                        last_id[c] = Some(id);
                        next_id += 1;
                        issued += 1;
                        chain = (c + 1) % CHAINS;
                    }
                }
                None => {
                    p.tick(None);
                }
            }
        }
        let s = p.stats();
        assert!(issued as f64 / s.cycles as f64 > 0.9, "interleaved chains should pipeline: {s:?}");
    }

    #[test]
    fn drain_empties_in_order() {
        let mut p = Pipeline::new();
        p.tick(Some((7, None)));
        p.tick(Some((8, None)));
        p.tick(Some((9, None)));
        let drained = p.drain();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![7, 8, 9]);
        assert!(p.is_empty());
    }

    #[test]
    fn can_issue_semantics() {
        let mut p = Pipeline::new();
        assert!(p.can_issue(None));
        assert!(!p.can_issue(Some(42)), "unknown dep = not retired yet");
        p.tick(Some((42, None)));
        assert!(!p.can_issue(Some(42)), "in flight");
        for _ in 0..STAGES {
            p.tick(None);
        }
        assert!(p.can_issue(Some(42)), "retired");
    }
}
