//! S3 — Align: shift every product mantissa (and the accumulator mantissa)
//! onto a common fixed-point grid anchored at `e_max`, keeping only `Wm`
//! bits, then convert to two's complement (paper §III-A, S3).
//!
//! This stage is where PDPU's precision/cost trade-off lives: the
//! configurable alignment width `Wm` truncates bits that a full quire
//! would keep. Truncation (not rounding) of the shifted magnitude matches
//! the hardware, which simply drops shifted-out bits.
//!
//! Grid definition: bit `Wm-1` of an aligned word carries weight
//! `2^(e_max+1)` (products reach values in [1,4) ⇒ 2 integer bits), so the
//! LSB carries `2^(e_max + 2 − Wm)`.

use super::s2_multiply::Multiplied;
use crate::pdpu::PdpuConfig;

/// Pipeline register between S3 and S4.
#[derive(Clone, Debug)]
pub struct Aligned {
    /// N aligned product terms + 1 aligned accumulator term, two's
    /// complement on the Wm grid (sign-extended into i128)
    pub addends: Vec<i128>,
    pub e_max: Option<i32>,
    pub any_nar: bool,
}

/// Align one magnitude: `m` has `frac_bits` fraction bits and scale `e`
/// (value `m·2^(e−frac_bits)`); place it on the grid with LSB weight
/// `2^(e_max+2−wm)`, truncating low bits.
///
/// `pub(crate)` so the lane-packed fast path ([`crate::pdpu::lanes`])
/// shares the *same* alignment definition as this reference stage —
/// bit-identity between the two paths holds by construction, not by
/// parallel reimplementation.
#[inline]
pub(crate) fn align_one(m: u128, frac_bits: u32, e: i32, e_max: i32, wm: u32) -> u128 {
    // target: floor( m · 2^(e − frac_bits) / 2^(e_max + 2 − wm) )
    //       = floor( m · 2^(e − frac_bits − e_max − 2 + wm) )
    let sh = e - frac_bits as i32 - e_max - 2 + wm as i32;
    if sh >= 0 {
        // grid finer than the source: shift up (never overflows — the
        // value is ≤ 4·2^e ≤ 4·2^e_max and the grid gives it wm bits)
        m << sh
    } else if (-sh) as u32 >= 127 {
        0
    } else {
        m >> ((-sh) as u32)
    }
}

impl Aligned {
    /// An empty record for use as reusable scratch space with
    /// [`s3_align_into`].
    pub fn empty() -> Self {
        Self { addends: Vec::new(), e_max: None, any_nar: false }
    }
}

/// Run stage S3.
pub fn s3_align(cfg: &PdpuConfig, m: &Multiplied) -> Aligned {
    let mut out = Aligned::empty();
    s3_align_into(cfg, m, &mut out);
    out
}

/// Allocation-free S3: like [`s3_align`] but writing into a reusable
/// record. Bit-identical to the allocating wrapper — it *is* the
/// implementation.
pub fn s3_align_into(cfg: &PdpuConfig, m: &Multiplied, out: &mut Aligned) {
    out.addends.clear();
    out.addends.reserve(m.terms.len() + 1);
    out.any_nar = m.any_nar;
    let Some(e_max) = m.e_max else {
        out.addends.resize(m.terms.len() + 1, 0);
        out.e_max = None;
        return;
    };
    let wm = cfg.wm;
    for t in &m.terms {
        if t.zero {
            out.addends.push(0);
            continue;
        }
        let mag = align_one(t.m_ab, 2 * cfg.in_frac_bits(), t.e_ab, e_max, wm);
        debug_assert!(mag < (1u128 << wm), "aligned magnitude exceeds Wm window");
        out.addends.push(if t.sign { -(mag as i128) } else { mag as i128 });
    }
    // accumulator: value < 2 ⇒ same grid, one integer bit
    if m.acc.zero {
        out.addends.push(0);
    } else {
        let mag = align_one(m.acc.mc as u128, cfg.acc_frac_bits(), m.acc.e_c, e_max, wm);
        debug_assert!(mag < (1u128 << wm));
        out.addends.push(if m.acc.sign { -(mag as i128) } else { mag as i128 });
    }
    out.e_max = Some(e_max);
}

#[cfg(test)]
mod tests {
    use super::super::{s1_decode, s2_multiply};
    use super::*;
    use crate::posit::Posit;

    fn run(cfg: &PdpuConfig, va: &[f64], vb: &[f64], acc: f64) -> Aligned {
        let a: Vec<Posit> = va.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let b: Vec<Posit> = vb.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let d = s1_decode(cfg, Posit::from_f64(acc, cfg.out_fmt), &a, &b);
        s3_align(cfg, &s2_multiply(cfg, &d))
    }

    /// Interpret an aligned addend back as f64 on the grid.
    fn grid_value(v: i128, e_max: i32, wm: u32) -> f64 {
        v as f64 * 2f64.powi(e_max + 2 - wm as i32)
    }

    #[test]
    fn dominant_term_alignment_is_exact_at_top() {
        let cfg = PdpuConfig::paper_default();
        let al = run(&cfg, &[2.0, 0.0, 0.0, 0.0], &[3.0, 0.0, 0.0, 0.0], 0.0);
        let e_max = al.e_max.unwrap();
        assert_eq!(e_max, 2); // 2·3: e_ab = 1+1 = 2 (1.5 mantissas)
        assert_eq!(grid_value(al.addends[0], e_max, cfg.wm), 6.0);
    }

    #[test]
    fn small_terms_truncate_toward_zero() {
        let cfg = PdpuConfig::paper_default();
        // lane0 dominates; lane1 = 1·(1+2^-8) needs more precision after a
        // 14-bit shift than Wm keeps → truncated
        let tiny = 1.0 + 2f64.powi(-8);
        let al = run(&cfg, &[256.0, 1.0, 0.0, 0.0], &[256.0, tiny, 0.0, 0.0], 0.0);
        let e_max = al.e_max.unwrap();
        assert_eq!(e_max, 16);
        let got = grid_value(al.addends[1], e_max, cfg.wm);
        assert!(got <= tiny && got >= 0.0, "truncation must floor: {got}");
        // dominant lane remains exact
        assert_eq!(grid_value(al.addends[0], e_max, cfg.wm), 65536.0);
    }

    #[test]
    fn negative_terms_are_twos_complement() {
        let cfg = PdpuConfig::paper_default();
        let al = run(&cfg, &[1.0, -1.0, 0.0, 0.0], &[1.0, 1.0, 0.0, 0.0], 0.0);
        assert!(al.addends[0] > 0);
        assert_eq!(al.addends[1], -al.addends[0]);
    }

    #[test]
    fn far_underflow_vanishes() {
        let cfg = PdpuConfig::paper_default();
        // lane1 is > Wm bits below lane0 → contributes exactly 0
        let al = run(&cfg, &[1024.0, 2f64.powi(-12), 0.0, 0.0], &[1024.0, 2f64.powi(-12), 0.0, 0.0], 0.0);
        assert_ne!(al.addends[0], 0);
        assert_eq!(al.addends[1], 0);
    }

    #[test]
    fn acc_joins_the_grid() {
        let cfg = PdpuConfig::paper_default();
        let al = run(&cfg, &[1.0, 0.0, 0.0, 0.0], &[1.0, 0.0, 0.0, 0.0], -2.5);
        let e_max = al.e_max.unwrap();
        assert_eq!(e_max, 1); // acc scale (2.5 → e=1) beats product scale 0
        assert_eq!(grid_value(al.addends[4], e_max, cfg.wm), -2.5);
    }

    #[test]
    fn all_magnitudes_fit_wm_window() {
        let cfg = PdpuConfig::paper_default();
        let al = run(&cfg, &[100.0, -0.01, 7.5, 0.125], &[42.0, 3000.0, -7.5, 8.0], 12.0);
        for &ad in &al.addends {
            assert!(ad.unsigned_abs() < (1u128 << cfg.wm));
        }
    }
}
