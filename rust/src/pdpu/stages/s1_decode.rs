//! S1 — Decode: parallel posit decoders extract the valid components of
//! all 2N inputs and the accumulator; the product sign `s_ab` and product
//! scale `e_ab` are formed here (paper §III-A, S1).
//!
//! Hardware correspondence: 2N+1 posit decoders (LZC + dynamic shifter
//! each), N sign XORs, N scale adders.

use crate::pdpu::PdpuConfig;
use crate::posit::{decode, Decoded, Posit};

/// One product lane after decode: the components of `aᵢ·bᵢ` before
/// mantissa multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProductTerm {
    /// `s_ab = s_a ⊕ s_b`
    pub sign: bool,
    /// `e_ab = e_a + e_b` (combined regime+exponent scales)
    pub e_ab: i32,
    /// input mantissas `1.f` with `in_frac_bits` fraction bits
    pub ma: u64,
    pub mb: u64,
    /// either operand was posit zero (lane contributes nothing)
    pub zero: bool,
}

/// Decoded accumulator operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccTerm {
    pub sign: bool,
    pub e_c: i32,
    /// mantissa `1.f` with `acc_frac_bits` fraction bits
    pub mc: u64,
    pub zero: bool,
}

/// Pipeline register between S1 and S2.
#[derive(Clone, Debug)]
pub struct DecodedInputs {
    pub products: Vec<ProductTerm>,
    pub acc: AccTerm,
    /// any operand (input or accumulator) was NaR — poisons the result
    pub any_nar: bool,
}

impl DecodedInputs {
    /// An empty record for use as reusable scratch space with
    /// [`s1_decode_into`] (capacity grows on first use, then stays).
    pub fn empty() -> Self {
        Self {
            products: Vec::new(),
            acc: AccTerm { sign: false, e_c: 0, mc: 0, zero: true },
            any_nar: false,
        }
    }
}

/// Run stage S1 over a dot-product request.
///
/// `a`/`b` must each hold exactly `cfg.n` posits of `cfg.in_fmt`;
/// `acc` must be of `cfg.out_fmt`.
pub fn s1_decode(cfg: &PdpuConfig, acc: Posit, a: &[Posit], b: &[Posit]) -> DecodedInputs {
    let mut out = DecodedInputs::empty();
    s1_decode_into(cfg, acc, a, b, &mut out);
    out
}

/// Build one product lane from two decoded operands. Returns the lane term
/// plus whether either operand was NaR. This is the single definition of
/// S1's lane semantics — shared by [`s1_decode_into`] and the batched GEMM
/// engine's pre-decoded path ([`crate::engine`]).
#[inline]
pub fn product_term(dx: Decoded, dy: Decoded) -> (ProductTerm, bool) {
    match (dx, dy) {
        (Decoded::NaR, _) | (_, Decoded::NaR) => {
            (ProductTerm { sign: false, e_ab: 0, ma: 0, mb: 0, zero: true }, true)
        }
        (Decoded::Zero, _) | (_, Decoded::Zero) => {
            (ProductTerm { sign: false, e_ab: 0, ma: 0, mb: 0, zero: true }, false)
        }
        (Decoded::Finite(fx), Decoded::Finite(fy)) => (
            ProductTerm {
                sign: fx.sign ^ fy.sign,
                e_ab: fx.scale + fy.scale,
                ma: fx.frac,
                mb: fy.frac,
                zero: false,
            },
            false,
        ),
    }
}

/// Decode the accumulator operand. Returns the record plus whether it was
/// NaR. Shared by [`s1_decode_into`] and the batched GEMM engine.
#[inline]
pub fn acc_term(acc: Posit) -> (AccTerm, bool) {
    match decode(acc) {
        Decoded::NaR => (AccTerm { sign: false, e_c: 0, mc: 0, zero: true }, true),
        Decoded::Zero => (AccTerm { sign: false, e_c: 0, mc: 0, zero: true }, false),
        Decoded::Finite(f) => (AccTerm { sign: f.sign, e_c: f.scale, mc: f.frac, zero: false }, false),
    }
}

/// Allocation-free S1: like [`s1_decode`] but writing into a reusable
/// record (the hot path of the batched GEMM engine). Bit-identical to the
/// allocating wrapper — it *is* the implementation.
pub fn s1_decode_into(cfg: &PdpuConfig, acc: Posit, a: &[Posit], b: &[Posit], out: &mut DecodedInputs) {
    assert_eq!(a.len(), cfg.n, "Va length must equal configured N");
    assert_eq!(b.len(), cfg.n, "Vb length must equal configured N");
    debug_assert!(a.iter().chain(b).all(|p| p.format() == cfg.in_fmt));
    debug_assert_eq!(acc.format(), cfg.out_fmt);

    let mut any_nar = false;
    out.products.clear();
    out.products.reserve(cfg.n);
    for (&x, &y) in a.iter().zip(b) {
        let (term, nar) = product_term(decode(x), decode(y));
        any_nar |= nar;
        out.products.push(term);
    }

    let (at, nar) = acc_term(acc);
    any_nar |= nar;
    out.acc = at;
    out.any_nar = any_nar;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::PositFormat;

    fn cfg() -> PdpuConfig {
        PdpuConfig::paper_default()
    }

    fn pin(v: f64) -> Posit {
        Posit::from_f64(v, PositFormat::p(13, 2))
    }

    fn pout(v: f64) -> Posit {
        Posit::from_f64(v, PositFormat::p(16, 2))
    }

    #[test]
    fn decodes_product_components() {
        let c = cfg();
        let a = [pin(2.0), pin(-3.0), pin(0.5), pin(1.0)];
        let b = [pin(4.0), pin(5.0), pin(-0.25), pin(1.0)];
        let d = s1_decode(&c, pout(7.0), &a, &b);
        assert!(!d.any_nar);
        assert_eq!(d.products.len(), 4);
        // lane 0: 2·4 → sign +, e_ab = 1 + 2 = 3, both mantissas exactly 1.0
        assert!(!d.products[0].sign);
        assert_eq!(d.products[0].e_ab, 3);
        assert_eq!(d.products[0].ma, 1 << c.in_frac_bits());
        // lane 1: (−3)·5 → sign −, e_ab = 1 + 2
        assert!(d.products[1].sign);
        assert_eq!(d.products[1].e_ab, 3);
        // lane 2: 0.5·(−0.25) → sign −, e_ab = −1 + −2 = −3
        assert!(d.products[2].sign);
        assert_eq!(d.products[2].e_ab, -3);
        // acc: 7 = 2^2 · 1.75
        assert!(!d.acc.zero);
        assert_eq!(d.acc.e_c, 2);
    }

    #[test]
    fn zero_lanes_marked() {
        let c = cfg();
        let a = [pin(0.0), pin(1.0), pin(0.0), pin(2.0)];
        let b = [pin(1.0), pin(0.0), pin(0.0), pin(2.0)];
        let d = s1_decode(&c, pout(0.0), &a, &b);
        assert!(d.products[0].zero && d.products[1].zero && d.products[2].zero);
        assert!(!d.products[3].zero);
        assert!(d.acc.zero);
        assert!(!d.any_nar);
    }

    #[test]
    fn nar_poisons() {
        let c = cfg();
        let nar = Posit::nar(PositFormat::p(13, 2));
        let a = [pin(1.0), nar, pin(1.0), pin(1.0)];
        let b = [pin(1.0); 4];
        assert!(s1_decode(&c, pout(0.0), &a, &b).any_nar);
        let a = [pin(1.0); 4];
        assert!(s1_decode(&c, Posit::nar(PositFormat::p(16, 2)), &a, &b).any_nar);
    }

    #[test]
    #[should_panic(expected = "Va length")]
    fn wrong_length_panics() {
        let c = cfg();
        let a = [pin(1.0); 3];
        let b = [pin(1.0); 4];
        s1_decode(&c, pout(0.0), &a, &b);
    }

    #[test]
    fn mixed_precision_acc_uses_out_format() {
        // acc mantissa must carry out_fmt's width (11 frac bits for P(16,2))
        let c = cfg();
        let a = [pin(1.0); 4];
        let b = [pin(1.0); 4];
        let d = s1_decode(&c, pout(1.5), &a, &b);
        assert_eq!(d.acc.mc, 0b11 << (c.acc_frac_bits() - 1)); // 1.1₂ aligned to 11 frac bits
    }
}
