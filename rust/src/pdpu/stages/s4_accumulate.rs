//! S4 — Accumulate: compress the N+1 aligned two's-complement addends into
//! sum and carry with a recursive CSA tree (3:2 / 4:2 compressors, Fig. 5),
//! then a final adder produces the signed sum `s_m` and final sign `f_s`
//! (paper §III-A, S4).
//!
//! Functionally a CSA tree is exact integer addition; the model adds in
//! i128 and asserts the result fits the configured accumulator width
//! `Wm + ceil(log2(N+1)) + 1` — the invariant that sizes the RTL adder.
//! The tree *structure* (compressor counts, depth) is reconstructed by the
//! cost model in [`crate::cost`], and [`csa_tree_shape`] here exposes the
//! recursion used by both.

use super::s3_align::Aligned;
use crate::pdpu::PdpuConfig;

/// Pipeline register between S4 and S5.
#[derive(Clone, Copy, Debug)]
pub struct Accumulated {
    /// signed accumulated mantissa on the S3 grid
    pub sum: i128,
    pub e_max: Option<i32>,
    pub any_nar: bool,
}

/// Run stage S4.
pub fn s4_accumulate(cfg: &PdpuConfig, al: &Aligned) -> Accumulated {
    debug_assert_eq!(al.addends.len(), cfg.n + 1);
    let sum: i128 = al.addends.iter().sum();
    // the RTL adder is acc_width() bits wide; the functional sum must fit
    debug_assert!(
        sum.unsigned_abs() <= (1u128 << (cfg.acc_width() - 1)),
        "accumulated sum overflows the modeled adder width"
    );
    Accumulated { sum, e_max: al.e_max, any_nar: al.any_nar }
}

/// Shape of the recursive CSA tree over `inputs` operands, as (number of
/// 3:2 compressors, number of 4:2 compressors, depth in compressor levels).
///
/// Mirrors the paper's Fig. 5 recursion: at each level, group remaining
/// operands into 4:2 compressors (4 → 2) while at least 4 remain, use one
/// 3:2 (3 → 2) for a leftover group of 3, pass smaller leftovers through.
/// Terminates when 2 operands remain (fed to the final carry-propagate
/// adder).
pub fn csa_tree_shape(inputs: usize) -> CsaShape {
    let mut count = inputs;
    let (mut c32, mut c42, mut depth) = (0u32, 0u32, 0u32);
    while count > 2 {
        let mut next = 0;
        let mut rem = count;
        while rem >= 4 {
            c42 += 1;
            next += 2;
            rem -= 4;
        }
        if rem == 3 {
            c32 += 1;
            next += 2;
            rem = 0;
        }
        next += rem;
        count = next;
        depth += 1;
    }
    CsaShape { c32, c42, depth }
}

/// CSA tree structure summary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsaShape {
    /// number of 3:2 compressors
    pub c32: u32,
    /// number of 4:2 compressors
    pub c42: u32,
    /// levels of compression before the final adder
    pub depth: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_is_exact_signed() {
        let cfg = PdpuConfig::paper_default();
        let al = Aligned { addends: vec![100, -30, 7, -80, 3], e_max: Some(0), any_nar: false };
        let acc = s4_accumulate(&cfg, &al);
        assert_eq!(acc.sum, 0);
        let al = Aligned { addends: vec![1 << 13, 1 << 13, 1 << 13, 1 << 13, 1 << 13], e_max: Some(0), any_nar: false };
        // 5 × 2^13 = 40960 < 2^17 (acc_width 18 → magnitude < 2^17) ✓
        assert_eq!(s4_accumulate(&cfg, &al).sum, 5 << 13);
    }

    #[test]
    fn csa_shape_small_cases() {
        // 2 inputs: no compression needed
        assert_eq!(csa_tree_shape(2), CsaShape { c32: 0, c42: 0, depth: 0 });
        // 3 inputs: one 3:2
        assert_eq!(csa_tree_shape(3), CsaShape { c32: 1, c42: 0, depth: 1 });
        // 4 inputs: one 4:2
        assert_eq!(csa_tree_shape(4), CsaShape { c32: 0, c42: 1, depth: 1 });
        // 5 inputs (paper N=4 + acc): 4:2 → (2 + 1 leftover) = 3 → one 3:2
        assert_eq!(csa_tree_shape(5), CsaShape { c32: 1, c42: 1, depth: 2 });
        // 9 inputs (N=8 + acc): level1: two 4:2 + 1 left = 5; level2: 4:2 +1 = 3; level3: 3:2
        assert_eq!(csa_tree_shape(9), CsaShape { c32: 1, c42: 3, depth: 3 });
    }

    #[test]
    fn csa_shape_reduces_to_two() {
        // simulate the reduction count for many sizes: compressors must
        // shrink the operand count to exactly 2 in `depth` levels
        for n in 2..200usize {
            let shape = csa_tree_shape(n);
            // each 4:2 removes 2 operands, each 3:2 removes 1
            let removed = (2 * shape.c42 + shape.c32) as usize;
            assert_eq!(n - removed, 2, "n={n}");
        }
    }

    #[test]
    fn csa_depth_is_logarithmic() {
        assert!(csa_tree_shape(17).depth <= 4);
        assert!(csa_tree_shape(65).depth <= 6);
        assert!(csa_tree_shape(5).depth == 2);
    }
}
