//! The six pipeline stages of PDPU (paper §III-A, Fig. 4), each as a pure
//! function over explicit inter-stage records.
//!
//! Keeping the stages separate (rather than one fused routine) serves three
//! purposes:
//! 1. the records are exactly the pipeline registers of the RTL, so the
//!    cycle-level model in [`super::pipeline`] and the per-stage cost
//!    breakdown of Fig. 6 attach to real boundaries;
//! 2. stage-local invariants (e.g. "every aligned addend fits the Wm
//!    window") are testable in isolation;
//! 3. the dataflow reads like the paper: S1 Decode → S2 Multiply →
//!    S3 Align → S4 Accumulate → S5 Normalize → S6 Encode.

pub mod s1_decode;
pub mod s2_multiply;
pub mod s3_align;
pub mod s4_accumulate;
pub mod s5_normalize;
pub mod s6_encode;

pub use s1_decode::{acc_term, product_term, s1_decode, s1_decode_into, AccTerm, DecodedInputs, ProductTerm};
pub use s2_multiply::{s2_multiply, s2_multiply_into, MulTerm, Multiplied};
pub use s3_align::{s3_align, s3_align_into, Aligned};
pub use s4_accumulate::{s4_accumulate, Accumulated};
pub use s5_normalize::{s5_normalize, Normalized};
pub use s6_encode::s6_encode;
