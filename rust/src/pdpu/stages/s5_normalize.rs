//! S5 — Normalize: leading-zero count on the accumulated magnitude,
//! mantissa normalization and exponent adjustment producing the final
//! exponent `f_e` and mantissa `f_m` (paper §III-A, S5).
//!
//! Hardware correspondence: an `acc_width`-bit LZC plus a dynamic left
//! shifter; the adjustment folds the S3 grid origin (`e_max + 2 − Wm`)
//! into the final scale.

use super::s4_accumulate::Accumulated;
use crate::pdpu::PdpuConfig;

/// Pipeline register between S5 and S6: a sign/scale/significand triple
/// ready for posit encoding, or an exact zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalized {
    Zero { any_nar: bool },
    Value { sign: bool, scale: i32, sig: u128, sig_frac_bits: u32, any_nar: bool },
}

/// Run stage S5.
pub fn s5_normalize(cfg: &PdpuConfig, a: &Accumulated) -> Normalized {
    let Some(e_max) = a.e_max else {
        return Normalized::Zero { any_nar: a.any_nar };
    };
    if a.sum == 0 {
        return Normalized::Zero { any_nar: a.any_nar };
    }
    let sign = a.sum < 0;
    let mag = a.sum.unsigned_abs();
    let msb = 127 - mag.leading_zeros(); // LZC equivalent
    // grid LSB weight is 2^(e_max + 2 − Wm) ⇒ value = mag · 2^(e_max+2−Wm)
    // normalized: 1.f with `msb` fraction bits, scale = msb + e_max + 2 − Wm
    let scale = msb as i32 + e_max + 2 - cfg.wm as i32;
    Normalized::Value { sign, scale, sig: mag, sig_frac_bits: msb, any_nar: a.any_nar }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PdpuConfig {
        PdpuConfig::paper_default()
    }

    fn value_of(n: &Normalized) -> f64 {
        match *n {
            Normalized::Zero { .. } => 0.0,
            Normalized::Value { sign, scale, sig, sig_frac_bits, .. } => {
                let v = sig as f64 * 2f64.powi(scale - sig_frac_bits as i32);
                if sign {
                    -v
                } else {
                    v
                }
            }
        }
    }

    #[test]
    fn zero_sum_normalizes_to_zero() {
        let c = cfg();
        let n = s5_normalize(&c, &Accumulated { sum: 0, e_max: Some(5), any_nar: false });
        assert_eq!(n, Normalized::Zero { any_nar: false });
        let n = s5_normalize(&c, &Accumulated { sum: 0, e_max: None, any_nar: false });
        assert_eq!(n, Normalized::Zero { any_nar: false });
    }

    #[test]
    fn grid_value_reconstructed() {
        let c = cfg(); // wm = 14
        // sum = 1 on grid with e_max = 0 → value = 2^(0+2−14) = 2^-12
        let n = s5_normalize(&c, &Accumulated { sum: 1, e_max: Some(0), any_nar: false });
        assert_eq!(value_of(&n), 2f64.powi(-12));
        // sum = −6 on grid e_max = 3 → −6·2^(3+2−14) = −6·2^-9
        let n = s5_normalize(&c, &Accumulated { sum: -6, e_max: Some(3), any_nar: false });
        assert_eq!(value_of(&n), -6.0 * 2f64.powi(-9));
    }

    #[test]
    fn significand_is_normalized() {
        let c = cfg();
        for sum in [1i128, 3, 7, 100, -100, 4096, -4097, (1 << 17) - 1] {
            match s5_normalize(&c, &Accumulated { sum, e_max: Some(2), any_nar: false }) {
                Normalized::Zero { .. } => panic!("nonzero sum normalized to zero"),
                Normalized::Value { sig, sig_frac_bits, .. } => {
                    assert_eq!(sig >> sig_frac_bits, 1, "hidden bit must be the MSB");
                }
            }
        }
    }

    #[test]
    fn nar_flag_propagates() {
        let c = cfg();
        let n = s5_normalize(&c, &Accumulated { sum: 5, e_max: Some(0), any_nar: true });
        matches!(n, Normalized::Value { any_nar: true, .. })
            .then_some(())
            .expect("nar flag lost");
        let n = s5_normalize(&c, &Accumulated { sum: 0, e_max: None, any_nar: true });
        assert_eq!(n, Normalized::Zero { any_nar: true });
    }
}
