//! S6 — Encode: the single posit encoder performs rounding and packs the
//! final sign/exponent/mantissa into the output posit (paper §III-A, S6).
//!
//! This is the *only* rounding in the whole PDPU datapath — the fused
//! property of §III-B. (The S3 alignment truncation is a precision loss
//! but not a posit rounding/encoding step; it is the price of Wm < quire.)

use super::s5_normalize::Normalized;
use crate::pdpu::PdpuConfig;
use crate::posit::{encode, Posit, Unpacked};

/// Run stage S6, producing the final output posit in `cfg.out_fmt`.
pub fn s6_encode(cfg: &PdpuConfig, n: &Normalized) -> Posit {
    match *n {
        Normalized::Zero { any_nar } => {
            if any_nar {
                Posit::nar(cfg.out_fmt)
            } else {
                Posit::zero(cfg.out_fmt)
            }
        }
        Normalized::Value { any_nar, .. } if any_nar => Posit::nar(cfg.out_fmt),
        Normalized::Value { sign, scale, sig, sig_frac_bits, .. } => {
            let bits = encode(
                Unpacked { sign, scale, sig, sig_frac_bits, sticky: false },
                cfg.out_fmt,
            );
            Posit::from_bits(bits, cfg.out_fmt)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::PositFormat;

    fn cfg() -> PdpuConfig {
        PdpuConfig::paper_default()
    }

    #[test]
    fn zero_and_nar_paths() {
        let c = cfg();
        assert!(s6_encode(&c, &Normalized::Zero { any_nar: false }).is_zero());
        assert!(s6_encode(&c, &Normalized::Zero { any_nar: true }).is_nar());
        let poisoned = Normalized::Value { sign: false, scale: 0, sig: 1, sig_frac_bits: 0, any_nar: true };
        assert!(s6_encode(&c, &poisoned).is_nar());
    }

    #[test]
    fn encodes_in_output_format() {
        let c = cfg();
        // 2^3 · 1.375 = 11 must encode in P(16,2), not P(13,2)
        let n = Normalized::Value { sign: false, scale: 3, sig: 0b1011, sig_frac_bits: 3, any_nar: false };
        let p = s6_encode(&c, &n);
        assert_eq!(p.format(), PositFormat::p(16, 2));
        assert_eq!(p.to_f64(), 11.0);
    }

    #[test]
    fn rounding_happens_here() {
        let c = cfg();
        // a 30-bit significand cannot fit P(16,2): S6 must round it
        let sig = (1u128 << 30) | 0x1234_5677;
        let n = Normalized::Value { sign: true, scale: 0, sig, sig_frac_bits: 30, any_nar: false };
        let p = s6_encode(&c, &n);
        let exact = -(sig as f64) * 2f64.powi(-30);
        assert_eq!(p.bits(), Posit::from_f64(exact, PositFormat::p(16, 2)).bits());
    }
}
