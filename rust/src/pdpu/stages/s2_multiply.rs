//! S2 — Multiply: mantissa multiplication (modelled functionally; the RTL
//! uses a modified radix-4 Booth multiplier) and the exponent comparator
//! tree that finds `e_max` over all product scales and the accumulator
//! scale (paper §III-A, S2).
//!
//! Hardware correspondence: N Booth multipliers of `(mb+1)×(mb+1)` bits and
//! a ceil(log2(N+1))-deep max tree over the scales.

use super::s1_decode::DecodedInputs;
use crate::pdpu::PdpuConfig;

/// One lane after mantissa multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MulTerm {
    pub sign: bool,
    pub e_ab: i32,
    /// exact product `ma·mb`: `prod_width` bits, value in [1,4) as a fixed
    /// point with `2·in_frac_bits` fraction bits
    pub m_ab: u128,
    pub zero: bool,
}

/// Pipeline register between S2 and S3.
#[derive(Clone, Debug)]
pub struct Multiplied {
    pub terms: Vec<MulTerm>,
    /// decoded accumulator forwarded unchanged
    pub acc: super::s1_decode::AccTerm,
    /// max over all live `e_ab` and `e_c`; None when every lane and the
    /// accumulator are zero
    pub e_max: Option<i32>,
    pub any_nar: bool,
}

impl Multiplied {
    /// An empty record for use as reusable scratch space with
    /// [`s2_multiply_into`].
    pub fn empty() -> Self {
        Self {
            terms: Vec::new(),
            acc: super::s1_decode::AccTerm { sign: false, e_c: 0, mc: 0, zero: true },
            e_max: None,
            any_nar: false,
        }
    }
}

/// Run stage S2.
pub fn s2_multiply(cfg: &PdpuConfig, d: &DecodedInputs) -> Multiplied {
    let mut out = Multiplied::empty();
    s2_multiply_into(cfg, d, &mut out);
    out
}

/// Allocation-free S2: like [`s2_multiply`] but writing into a reusable
/// record. Bit-identical to the allocating wrapper — it *is* the
/// implementation.
pub fn s2_multiply_into(cfg: &PdpuConfig, d: &DecodedInputs, out: &mut Multiplied) {
    out.terms.clear();
    out.terms.reserve(d.products.len());
    let mut e_max: Option<i32> = None;
    for p in &d.products {
        let m_ab = (p.ma as u128) * (p.mb as u128);
        debug_assert!(
            p.zero || (m_ab >> (2 * cfg.in_frac_bits())) >= 1 && (m_ab >> (2 * cfg.in_frac_bits())) < 4,
            "product out of [1,4): {m_ab:#x}"
        );
        if !p.zero {
            e_max = Some(e_max.map_or(p.e_ab, |m| m.max(p.e_ab)));
        }
        out.terms.push(MulTerm { sign: p.sign, e_ab: p.e_ab, m_ab, zero: p.zero });
    }
    if !d.acc.zero {
        e_max = Some(e_max.map_or(d.acc.e_c, |m| m.max(d.acc.e_c)));
    }
    out.acc = d.acc;
    out.e_max = e_max;
    out.any_nar = d.any_nar;
}

#[cfg(test)]
mod tests {
    use super::super::s1_decode::s1_decode;
    use super::*;
    use crate::posit::{Posit, PositFormat};

    fn setup(vals_a: [f64; 4], vals_b: [f64; 4], acc: f64) -> (PdpuConfig, Multiplied) {
        let cfg = PdpuConfig::paper_default();
        let f_in = PositFormat::p(13, 2);
        let f_out = PositFormat::p(16, 2);
        let a: Vec<Posit> = vals_a.iter().map(|&v| Posit::from_f64(v, f_in)).collect();
        let b: Vec<Posit> = vals_b.iter().map(|&v| Posit::from_f64(v, f_in)).collect();
        let d = s1_decode(&cfg, Posit::from_f64(acc, f_out), &a, &b);
        let m = s2_multiply(&cfg, &d);
        (cfg, m)
    }

    #[test]
    fn products_are_exact() {
        let (cfg, m) = setup([1.5, 2.0, -3.0, 0.5], [1.5, 2.0, 3.0, 4.0], 0.0);
        let fb2 = 2 * cfg.in_frac_bits();
        // 1.5·1.5 = 2.25 → mantissas 1.5·1.5, e_ab 0
        assert_eq!(m.terms[0].m_ab as f64 / (1u128 << fb2) as f64, 2.25);
        assert_eq!(m.terms[0].e_ab, 0);
        // 2·2: mantissas 1·1, scales 1+1
        assert_eq!(m.terms[1].m_ab as f64 / (1u128 << fb2) as f64, 1.0);
        assert_eq!(m.terms[1].e_ab, 2);
        // −3·3 = −9 = −2^3·1.125: mantissa prod 1.5·1.5 = 2.25, e_ab 2
        assert!(m.terms[2].sign);
    }

    #[test]
    fn e_max_over_products_and_acc() {
        // products scales: 0, 2, 2, 1 ; acc scale: 4 (16.0) → e_max = 4
        let (_, m) = setup([1.5, 2.0, -3.0, 0.5], [1.5, 2.0, 3.0, 4.0], 16.0);
        assert_eq!(m.e_max, Some(4));
        // without acc: max product scale wins
        let (_, m) = setup([1.5, 2.0, -3.0, 0.5], [1.5, 2.0, 3.0, 4.0], 0.0);
        assert_eq!(m.e_max, Some(2));
    }

    #[test]
    fn zero_lanes_excluded_from_emax() {
        // large-magnitude lanes that are zeroed must not contaminate e_max
        let (_, m) = setup([0.0, 0.0, 0.0, 1.0], [1e6, 1e6, 1e6, 1.0], 0.0);
        assert_eq!(m.e_max, Some(0));
    }

    #[test]
    fn all_zero_gives_no_emax() {
        let (_, m) = setup([0.0; 4], [0.0; 4], 0.0);
        assert_eq!(m.e_max, None);
        assert!(m.terms.iter().all(|t| t.zero));
    }
}
