//! Lane-packed fast path for the hot dot-product datapath: S1 decode and
//! the S2 multiply batched across lanes over `u64`-packed words, fused
//! with S3 alignment and the S4 sum into one branch-light kernel.
//!
//! The hardware PDPU decodes all 2N inputs in parallel and multiplies
//! them in a combinational array (paper Fig. 4); the scalar stage
//! functions in [`super::stages`] model that one lane at a time with
//! per-stage records. This module is the software analogue of the
//! parallel array: every decoded operand is packed into one 64-bit word
//! ([`PackedLane`]) and the per-lane work of S1+S2 (sign XOR, scale add,
//! mantissa multiply, `e_max` reduction) becomes straight-line integer
//! arithmetic over those words, with S3+S4 folded into the same pass over
//! a fixed-size scratch ([`LaneScratch`]) — no heap traffic anywhere.
//!
//! **Bit-identity by construction**: the kernel does not reimplement any
//! numeric semantics. Packing delegates to the scalar [`decode`], the
//! alignment shift is the *same* [`align_one`] the scalar S3 uses, the
//! accumulator decode is the shared [`acc_term`], and the back end is the
//! scalar [`s5_normalize`] + [`s6_encode`]. The i128 addend sum is exact,
//! so term order and zero-lane skipping cannot change the result. The
//! scalar `s1..s6` stage functions remain the reference model; the
//! conformance suite (`rust/tests/conformance_exhaustive.rs`) sweeps both
//! paths exhaustively for every small format.

use super::config::PdpuConfig;
use super::stages::s3_align::align_one;
use super::stages::{acc_term, s5_normalize, s6_encode, Accumulated, ProductTerm};
use crate::posit::{decode, Decoded, Posit};

/// Maximum dot-product size `N` the fixed-size fast path covers; larger
/// configurations fall back to the staged scalar pipeline (still through
/// packed operands, via [`product_term_packed`]).
pub const MAX_FAST_LANES: usize = 64;

// ---- PackedLane bit layout ------------------------------------------------
// bits  0..32  mantissa `1.f`, left-aligned to `max_frac_bits` (≤ 30 bits
//              for every supported format, so 32 is roomy)
// bits 32..48  scale (regime·2^es + exponent) biased by 2^15
// bit  48      sign
// bit  49      live: operand is finite and nonzero
// bit  50      NaR
// Zero packs to the all-zero word; NaR to just the NaR bit. Dead lanes
// keep frac = 0 so a packed multiply of any dead lane yields 0.
const FRAC_MASK: u64 = 0xFFFF_FFFF;
const SCALE_SHIFT: u32 = 32;
const SCALE_FIELD_MASK: u64 = 0xFFFF;
const SCALE_BIAS: i32 = 1 << 15;
const SIGN_BIT: u64 = 1 << 48;
const LIVE_BIT: u64 = 1 << 49;
const NAR_BIT: u64 = 1 << 50;

/// One decoded posit operand packed into a single 64-bit word — the
/// operand format of the lane-parallel S1/S2 kernel and the storage
/// format of the engine's pre-decoded operand planes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackedLane(u64);

impl PackedLane {
    /// Pack one posit. Delegates to the scalar [`decode`] — the packed
    /// representation is a re-encoding of the reference decoder's output,
    /// never a second decoder implementation.
    #[inline]
    pub fn from_posit(p: Posit) -> Self {
        match decode(p) {
            Decoded::Zero => Self(0),
            Decoded::NaR => Self(NAR_BIT),
            Decoded::Finite(f) => {
                debug_assert!(f.frac <= FRAC_MASK, "mantissa exceeds the 32-bit lane field");
                let biased = (f.scale + SCALE_BIAS) as u64;
                debug_assert!(biased <= SCALE_FIELD_MASK, "scale exceeds the 16-bit lane field");
                Self(f.frac | (biased << SCALE_SHIFT) | ((f.sign as u64) << 48) | LIVE_BIT)
            }
        }
    }

    /// The raw packed word.
    #[inline]
    pub fn word(self) -> u64 {
        self.0
    }

    /// Finite and nonzero.
    #[inline]
    pub fn is_live(self) -> bool {
        self.0 & LIVE_BIT != 0
    }

    #[inline]
    pub fn is_nar(self) -> bool {
        self.0 & NAR_BIT != 0
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// Sign bit (false for dead lanes).
    #[inline]
    pub fn sign(self) -> bool {
        self.0 & SIGN_BIT != 0
    }

    /// Unbiased scale. Meaningful only for live lanes (dead lanes read
    /// back the bias origin).
    #[inline]
    pub fn scale(self) -> i32 {
        ((self.0 >> SCALE_SHIFT) & SCALE_FIELD_MASK) as i32 - SCALE_BIAS
    }

    /// Left-aligned mantissa `1.f` (0 for dead lanes).
    #[inline]
    pub fn frac(self) -> u64 {
        self.0 & FRAC_MASK
    }
}

/// Rebuild the scalar S1 lane record from two packed operands —
/// bit-identical to `product_term(decode(a), decode(b))`. The staged
/// fallback for `N >` [`MAX_FAST_LANES`] (and the sampled profiling path)
/// runs through this, so packed operand planes serve every path.
#[inline]
pub fn product_term_packed(la: PackedLane, lb: PackedLane) -> (ProductTerm, bool) {
    if (la.0 | lb.0) & NAR_BIT != 0 {
        return (ProductTerm { sign: false, e_ab: 0, ma: 0, mb: 0, zero: true }, true);
    }
    if la.0 & lb.0 & LIVE_BIT == 0 {
        return (ProductTerm { sign: false, e_ab: 0, ma: 0, mb: 0, zero: true }, false);
    }
    (
        ProductTerm {
            sign: ((la.0 ^ lb.0) & SIGN_BIT) != 0,
            e_ab: la.scale() + lb.scale(),
            ma: la.frac(),
            mb: lb.frac(),
            zero: false,
        },
        false,
    )
}

// ---- per-lane metadata word (LaneScratch::meta) ---------------------------
// bits 0..12  product scale e_ab biased by 2^11 (|e_ab| ≤ 2·480 < 2^11)
// bit  12     product sign
// bit  13     live (both operands finite nonzero)
const META_E_MASK: u32 = 0xFFF;
const META_E_BIAS: i32 = 1 << 11;
const META_SIGN: u32 = 1 << 12;
const META_LIVE: u32 = 1 << 13;

/// Fixed-size per-operation workspace of the fused kernel: one exact
/// mantissa product and one metadata word per lane. Plain arrays — the
/// kernel never touches the allocator.
#[derive(Clone, Copy, Debug)]
pub struct LaneScratch {
    prod: [u64; MAX_FAST_LANES],
    meta: [u32; MAX_FAST_LANES],
}

impl LaneScratch {
    pub const fn new() -> Self {
        Self { prod: [0; MAX_FAST_LANES], meta: [0; MAX_FAST_LANES] }
    }
}

impl Default for LaneScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One fused dot-product chunk over packed lanes: S1+S2 batched across
/// lanes (pass 1), S3+S4 fused into the addend sum (pass 2), then the
/// shared scalar S5/S6 back end. Bit-identical to running the staged
/// pipeline over the same operands.
///
/// `row`/`col` hold the chunk's live lanes (at most [`MAX_FAST_LANES`],
/// at most `cfg.n`); a short chunk behaves exactly like the scalar
/// path's zero-padded tail because padding lanes contribute an addend of
/// zero and are excluded from the `e_max` reduction.
// pdpu-lint: hot-path
pub fn dot_packed_chunk(
    cfg: &PdpuConfig,
    acc: Posit,
    row: &[PackedLane],
    col: &[PackedLane],
    scratch: &mut LaneScratch,
) -> Posit {
    let len = row.len();
    assert_eq!(len, col.len(), "vector length mismatch");
    assert!(len <= MAX_FAST_LANES, "chunk exceeds the fast-path lane budget");
    debug_assert!(len <= cfg.n);

    // pass 1 — S1+S2 across lanes: sign XOR, biased-scale add, mantissa
    // multiply, e_max reduction. Branch-light: dead lanes run the same
    // arithmetic on zero mantissas and are masked out of e_max via the
    // i32::MIN sentinel.
    let mut any_nar = false;
    let mut e_raw = i32::MIN;
    for i in 0..len {
        let (la, lb) = (row[i], col[i]);
        any_nar |= (la.0 | lb.0) & NAR_BIT != 0;
        let live = la.0 & lb.0 & LIVE_BIT != 0;
        let e = ((la.0 >> SCALE_SHIFT) & SCALE_FIELD_MASK) as i32
            + ((lb.0 >> SCALE_SHIFT) & SCALE_FIELD_MASK) as i32
            - 2 * SCALE_BIAS;
        scratch.prod[i] = (la.0 & FRAC_MASK) * (lb.0 & FRAC_MASK);
        scratch.meta[i] = ((e + META_E_BIAS) as u32 & META_E_MASK)
            | ((((la.0 ^ lb.0) & SIGN_BIT) != 0) as u32) << 12
            | (live as u32) << 13;
        e_raw = e_raw.max(if live { e } else { i32::MIN });
    }

    // accumulator operand: the shared scalar decode
    let (at, nar) = acc_term(acc);
    any_nar |= nar;
    if !at.zero {
        e_raw = e_raw.max(at.e_c);
    }
    let e_max = (e_raw != i32::MIN).then_some(e_raw);

    // pass 2 — S3+S4 fused: align every live lane on the Wm grid with the
    // *same* shift definition as the scalar S3, sum exactly in i128.
    let mut sum: i128 = 0;
    if let Some(em) = e_max {
        let fb2 = 2 * cfg.in_frac_bits();
        let wm = cfg.wm;
        for i in 0..len {
            let m = scratch.meta[i];
            if m & META_LIVE == 0 {
                continue;
            }
            let e = (m & META_E_MASK) as i32 - META_E_BIAS;
            let mag = align_one(scratch.prod[i] as u128, fb2, e, em, wm);
            debug_assert!(mag < (1u128 << wm), "aligned magnitude exceeds Wm window");
            sum += if m & META_SIGN != 0 { -(mag as i128) } else { mag as i128 };
        }
        if !at.zero {
            let mag = align_one(at.mc as u128, cfg.acc_frac_bits(), at.e_c, em, wm);
            debug_assert!(mag < (1u128 << wm));
            sum += if at.sign { -(mag as i128) } else { mag as i128 };
        }
        debug_assert!(
            sum.unsigned_abs() <= (1u128 << (cfg.acc_width() - 1)),
            "accumulated sum overflows the modeled adder width"
        );
    }

    // shared scalar back end — the only rounding in the datapath
    let s4 = Accumulated { sum, e_max, any_nar };
    let s5 = s5_normalize(cfg, &s4);
    s6_encode(cfg, &s5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pdpu::stages::product_term;
    use crate::pdpu::Pdpu;
    use crate::posit::PositFormat;
    use crate::testing::{check, Rng};

    fn rand_pattern(rng: &mut Rng, fmt: PositFormat) -> Posit {
        Posit::from_bits(rng.next_u64() as u32 & fmt.mask(), fmt)
    }

    #[test]
    fn packing_roundtrips_the_decoder() {
        for &(n, es) in &[(8u32, 0u32), (8, 2), (13, 2), (16, 2), (32, 0), (32, 2), (3, 0), (32, 4)] {
            let fmt = PositFormat::p(n, es);
            let mut rng = Rng::seeded(0x9ACC ^ (n as u64) << 8 ^ es as u64);
            for _ in 0..400 {
                let p = rand_pattern(&mut rng, fmt);
                let l = PackedLane::from_posit(p);
                match decode(p) {
                    Decoded::Zero => {
                        assert!(l.is_zero() && !l.is_live() && !l.is_nar());
                        assert_eq!(l.frac(), 0);
                    }
                    Decoded::NaR => {
                        assert!(l.is_nar() && !l.is_live() && !l.is_zero());
                        assert_eq!(l.frac(), 0);
                    }
                    Decoded::Finite(f) => {
                        assert!(l.is_live() && !l.is_nar() && !l.is_zero());
                        assert_eq!(l.sign(), f.sign);
                        assert_eq!(l.scale(), f.scale);
                        assert_eq!(l.frac(), f.frac);
                    }
                }
            }
        }
    }

    #[test]
    fn packed_product_term_matches_scalar() {
        let fmt = PositFormat::p(13, 2);
        check("product_term_packed ≡ product_term∘decode", 0x7E21, 2_000, |rng, _| {
            let a = rand_pattern(rng, fmt);
            let b = rand_pattern(rng, fmt);
            let want = product_term(decode(a), decode(b));
            let got = product_term_packed(PackedLane::from_posit(a), PackedLane::from_posit(b));
            assert_eq!(got, want, "a={a:?} b={b:?}");
        });
    }

    #[test]
    fn fused_kernel_matches_staged_pipeline() {
        let configs = [
            crate::pdpu::PdpuConfig::paper_default(),
            crate::pdpu::PdpuConfig::uniform(16, 2, 1, 96).unwrap(),
            crate::pdpu::PdpuConfig::mixed(8, 16, 2, 8, 6).unwrap(),
            crate::pdpu::PdpuConfig::uniform(32, 2, 16, 40).unwrap(),
        ];
        let mut scratch = LaneScratch::new();
        for (ci, cfg) in configs.iter().enumerate() {
            let unit = Pdpu::new(*cfg);
            check("dot_packed_chunk ≡ staged dot", 0xFA57 ^ ci as u64, 800, |rng, _| {
                // full random patterns: NaR and zero specials included
                let a: Vec<Posit> = (0..cfg.n).map(|_| rand_pattern(rng, cfg.in_fmt)).collect();
                let b: Vec<Posit> = (0..cfg.n).map(|_| rand_pattern(rng, cfg.in_fmt)).collect();
                let acc = rand_pattern(rng, cfg.out_fmt);
                let pa: Vec<PackedLane> = a.iter().map(|&p| PackedLane::from_posit(p)).collect();
                let pb: Vec<PackedLane> = b.iter().map(|&p| PackedLane::from_posit(p)).collect();
                let got = dot_packed_chunk(cfg, acc, &pa, &pb, &mut scratch);
                let want = unit.dot(acc, &a, &b);
                assert_eq!(got.bits(), want.bits(), "a={a:?} b={b:?} acc={acc:?}");
            });
        }
    }

    #[test]
    fn short_chunk_equals_zero_padded_chunk() {
        let cfg = crate::pdpu::PdpuConfig::paper_default();
        let unit = Pdpu::new(cfg);
        let mut rng = Rng::seeded(0x5027);
        let mut scratch = LaneScratch::new();
        for m in 0..cfg.n {
            let a: Vec<Posit> = (0..m).map(|_| rand_pattern(&mut rng, cfg.in_fmt)).collect();
            let b: Vec<Posit> = (0..m).map(|_| rand_pattern(&mut rng, cfg.in_fmt)).collect();
            let acc = rand_pattern(&mut rng, cfg.out_fmt);
            let pa: Vec<PackedLane> = a.iter().map(|&p| PackedLane::from_posit(p)).collect();
            let pb: Vec<PackedLane> = b.iter().map(|&p| PackedLane::from_posit(p)).collect();
            let got = dot_packed_chunk(&cfg, acc, &pa, &pb, &mut scratch);
            // scalar reference: explicit zero-padding to N lanes
            let zero = Posit::zero(cfg.in_fmt);
            let mut fa = a.clone();
            let mut fb = b.clone();
            fa.resize(cfg.n, zero);
            fb.resize(cfg.n, zero);
            let want = unit.dot(acc, &fa, &fb);
            assert_eq!(got.bits(), want.bits(), "m={m}");
        }
    }

    #[test]
    fn extreme_scales_survive_the_packed_fields() {
        // ±maxpos/±minpos in the widest format stress the biased scale
        // field (|scale| = 480) and the mantissa field at once
        let cfg = crate::pdpu::PdpuConfig::uniform(32, 4, 4, 96).unwrap();
        let unit = Pdpu::new(cfg);
        let mut scratch = LaneScratch::new();
        let fmt = cfg.in_fmt;
        let specials = [
            Posit::maxpos(fmt),
            Posit::minpos(fmt),
            Posit::from_bits(Posit::maxpos(fmt).bits().wrapping_neg(), fmt),
            Posit::from_bits(Posit::minpos(fmt).bits().wrapping_neg(), fmt),
            Posit::zero(fmt),
            Posit::one(fmt),
        ];
        for &w in &specials {
            for &x in &specials {
                let a = [w, x, Posit::one(fmt), Posit::zero(fmt)];
                let b = [x, w, w, x];
                let acc = Posit::zero(cfg.out_fmt);
                let pa: Vec<PackedLane> = a.iter().map(|&p| PackedLane::from_posit(p)).collect();
                let pb: Vec<PackedLane> = b.iter().map(|&p| PackedLane::from_posit(p)).collect();
                assert_eq!(
                    dot_packed_chunk(&cfg, acc, &pa, &pb, &mut scratch).bits(),
                    unit.dot(acc, &a, &b).bits(),
                    "w={w:?} x={x:?}"
                );
            }
        }
    }
}
