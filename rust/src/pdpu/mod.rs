//! The PDPU itself — the paper's contribution.
//!
//! * [`config`] — the configurable generator's parameter space (formats,
//!   dot-product size N, alignment width Wm) and derived datapath widths.
//! * [`stages`] — the six pipeline stages as pure functions with explicit
//!   inter-stage records (S1 Decode … S6 Encode, Fig. 4).
//! * [`unit`] — the composed functional unit: bit-exact `out = acc + Va·Vb`
//!   plus chunk-based accumulation for long DNN dot products.
//! * [`lanes`] — the lane-packed fast path: S1+S2 batched across lanes
//!   over `u64`-packed operand words, fused with S3+S4, bit-identical to
//!   the staged stages (the software twin of the parallel decoder array).
//! * [`pipeline`] — cycle-level 6-stage timing model with RAW-hazard
//!   tracking (feeds Fig. 6 and the coordinator's scheduler).

pub mod config;
pub mod lanes;
pub mod pipeline;
pub mod stages;
pub mod unit;

pub use config::{ceil_log2, validate_layer_sizes, ConfigError, PdpuConfig};
pub use lanes::{dot_packed_chunk, product_term_packed, LaneScratch, PackedLane, MAX_FAST_LANES};
pub use pipeline::{Pipeline, PipelineStats};
pub use unit::{DotScratch, Pdpu, Trace};
