//! The PDPU itself — the paper's contribution.
//!
//! * [`config`] — the configurable generator's parameter space (formats,
//!   dot-product size N, alignment width Wm) and derived datapath widths.
//! * [`stages`] — the six pipeline stages as pure functions with explicit
//!   inter-stage records (S1 Decode … S6 Encode, Fig. 4).
//! * [`unit`] — the composed functional unit: bit-exact `out = acc + Va·Vb`
//!   plus chunk-based accumulation for long DNN dot products.
//! * [`pipeline`] — cycle-level 6-stage timing model with RAW-hazard
//!   tracking (feeds Fig. 6 and the coordinator's scheduler).

pub mod config;
pub mod pipeline;
pub mod stages;
pub mod unit;

pub use config::{ceil_log2, validate_layer_sizes, ConfigError, PdpuConfig};
pub use pipeline::{Pipeline, PipelineStats};
pub use unit::{DotScratch, Pdpu, Trace};
