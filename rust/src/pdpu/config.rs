//! PDPU configuration — the software twin of the paper's *configurable
//! PDPU generator* (§III-C).
//!
//! A configuration fixes the three degrees of freedom the paper calls out:
//! * **custom posit formats** — independent input format (for the vectors
//!   `Va`, `Vb`) and output format (for `acc` and `out`), enabling the
//!   mixed-precision `P(n_in/n_out, es)` operating points of Table I;
//! * **dot-product size** `N` — number of parallel product lanes;
//! * **alignment width** `Wm` — bits of aligned mantissa kept in S3/S4,
//!   the precision/cost knob that replaces a full quire.

use crate::posit::{PositError, PositFormat};

/// Full parameterization of one PDPU instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PdpuConfig {
    /// Format of the elements of `Va` and `Vb`.
    pub in_fmt: PositFormat,
    /// Format of `acc` and `out` (may be wider: mixed precision).
    pub out_fmt: PositFormat,
    /// Dot-product size N (number of product terms per operation).
    pub n: usize,
    /// Alignment width Wm: bits of aligned mantissa kept before the CSA
    /// tree. Larger = closer to exact (quire) accumulation.
    pub wm: u32,
}

/// Errors from configuration validation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// An invalid posit format (n/es out of supported range).
    Posit(PositError),
    /// Dot-product size N outside 1..=256.
    BadN(usize),
    /// Alignment width Wm outside 4..=96.
    BadWm(u32),
    /// The derived S4 accumulator exceeds the functional model's 127 bits.
    AccTooWide(u32),
    /// A model topology needs at least an input and an output layer.
    BadLayerCount(usize),
    /// A model layer with zero units (index into `layer_sizes`).
    ZeroLayerWidth(usize),
    /// A batch size of zero.
    BadBatch,
}

impl From<PositError> for ConfigError {
    fn from(e: PositError) -> Self {
        ConfigError::Posit(e)
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Posit(e) => std::fmt::Display::fmt(e, f),
            ConfigError::BadN(n) => {
                write!(f, "dot-product size N={n} out of supported range 1..=256")
            }
            ConfigError::BadWm(wm) => write!(
                f,
                "alignment width Wm={wm} out of supported range 4..=96 (use the quire baseline beyond)"
            ),
            ConfigError::AccTooWide(w) => write!(
                f,
                "accumulator width {w} exceeds the 127-bit functional-model limit; reduce Wm or N"
            ),
            ConfigError::BadLayerCount(n) => {
                write!(f, "model topology has {n} layer size(s); need at least input and output")
            }
            ConfigError::ZeroLayerWidth(i) => write!(f, "model layer {i} has zero units"),
            ConfigError::BadBatch => write!(f, "batch size must be at least 1"),
        }
    }
}

/// Validate a model topology: at least `[input, output]`, every layer
/// non-empty. Serving code calls this once at construction/manifest-load
/// time so request paths can index `layer_sizes` without panicking.
pub fn validate_layer_sizes(layer_sizes: &[usize]) -> Result<(), ConfigError> {
    if layer_sizes.len() < 2 {
        return Err(ConfigError::BadLayerCount(layer_sizes.len()));
    }
    if let Some(i) = layer_sizes.iter().position(|&w| w == 0) {
        return Err(ConfigError::ZeroLayerWidth(i));
    }
    Ok(())
}

impl std::error::Error for ConfigError {}

impl PdpuConfig {
    /// Uniform-precision configuration `P(n,es)`, like the Table I
    /// `P(16/16,2)` row.
    pub fn uniform(n_bits: u32, es: u32, n: usize, wm: u32) -> Result<Self, ConfigError> {
        let fmt = PositFormat::new(n_bits, es)?;
        Self::new(fmt, fmt, n, wm)
    }

    /// Mixed-precision configuration `P(n_in/n_out, es)`, like the Table I
    /// `P(13/16,2)` rows: narrow inputs, wider accumulator/output.
    pub fn mixed(n_in: u32, n_out: u32, es: u32, n: usize, wm: u32) -> Result<Self, ConfigError> {
        Self::new(PositFormat::new(n_in, es)?, PositFormat::new(n_out, es)?, n, wm)
    }

    /// Validated constructor.
    pub fn new(in_fmt: PositFormat, out_fmt: PositFormat, n: usize, wm: u32) -> Result<Self, ConfigError> {
        if !(1..=256).contains(&n) {
            return Err(ConfigError::BadN(n));
        }
        if !(4..=96).contains(&wm) {
            return Err(ConfigError::BadWm(wm));
        }
        let cfg = Self { in_fmt, out_fmt, n, wm };
        let acc_w = cfg.acc_width();
        if acc_w > 127 {
            return Err(ConfigError::AccTooWide(acc_w));
        }
        Ok(cfg)
    }

    /// The paper's headline configuration: P(13/16,2), N=4, Wm=14.
    pub fn paper_default() -> Self {
        Self::mixed(13, 16, 2, 4, 14).expect("paper default must validate")
    }

    // ---- derived datapath widths (consumed by the cost model and the
    // ---- stage implementations; these mirror the RTL generator's
    // ---- localparam computations) ----

    /// Fraction bits of one decoded input mantissa.
    #[inline]
    pub fn in_frac_bits(&self) -> u32 {
        self.in_fmt.max_frac_bits()
    }

    /// Fraction bits of the decoded accumulator mantissa.
    #[inline]
    pub fn acc_frac_bits(&self) -> u32 {
        self.out_fmt.max_frac_bits()
    }

    /// Width of one product mantissa `ma·mb` (two `1.f` operands):
    /// `2·(mb+1)` bits, value in [1,4).
    #[inline]
    pub fn prod_width(&self) -> u32 {
        2 * (self.in_frac_bits() + 1)
    }

    /// Bits needed for a product scale `e_ab = ea + eb` (signed).
    pub fn eab_width(&self) -> u32 {
        let span = 2 * self.in_fmt.max_scale().max(self.out_fmt.max_scale());
        32 - (span as u32).leading_zeros() + 1 // magnitude bits + sign
    }

    /// Width of the S4 accumulator: Wm data bits grow by log2(N+1) for the
    /// tree sum, plus one sign bit.
    pub fn acc_width(&self) -> u32 {
        self.wm + ceil_log2(self.n as u32 + 1) + 1
    }

    /// Maximum useful alignment shift: beyond this a term underflows the
    /// Wm window entirely.
    #[inline]
    pub fn max_shift(&self) -> u32 {
        self.wm
    }

    /// Number of posit decoders instantiated (2N inputs + 1 accumulator) —
    /// the paper's "essential 2N+1 decoders" (§III-B).
    #[inline]
    pub fn num_decoders(&self) -> u32 {
        2 * self.n as u32 + 1
    }

    /// Number of posit encoders instantiated (always 1 — fused output).
    #[inline]
    pub fn num_encoders(&self) -> u32 {
        1
    }

    /// Depth of the exponent comparator tree over N+1 entries.
    #[inline]
    pub fn cmp_tree_depth(&self) -> u32 {
        ceil_log2(self.n as u32 + 1)
    }

    /// A short human identifier like `P(13/16,2) N=4 Wm=14`.
    pub fn label(&self) -> String {
        if self.in_fmt == self.out_fmt {
            format!("P({}/{},{}) N={} Wm={}", self.in_fmt.n(), self.out_fmt.n(), self.in_fmt.es(), self.n, self.wm)
        } else {
            format!(
                "P({}/{},{}) N={} Wm={}",
                self.in_fmt.n(),
                self.out_fmt.n(),
                self.in_fmt.es(),
                self.n,
                self.wm
            )
        }
    }
}

/// ceil(log2(x)) for x ≥ 1.
pub fn ceil_log2(x: u32) -> u32 {
    debug_assert!(x >= 1);
    32 - (x - 1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn paper_default_widths() {
        let cfg = PdpuConfig::paper_default();
        assert_eq!(cfg.in_fmt, PositFormat::p(13, 2));
        assert_eq!(cfg.out_fmt, PositFormat::p(16, 2));
        assert_eq!(cfg.n, 4);
        assert_eq!(cfg.wm, 14);
        // P(13,2): 8 mantissa frac bits → 9-bit 1.f → 18-bit product
        assert_eq!(cfg.in_frac_bits(), 8);
        assert_eq!(cfg.prod_width(), 18);
        assert_eq!(cfg.num_decoders(), 9);
        assert_eq!(cfg.num_encoders(), 1);
        assert_eq!(cfg.cmp_tree_depth(), 3);
        // Wm=14 + ceil_log2(5)=3 + sign = 18
        assert_eq!(cfg.acc_width(), 18);
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(matches!(PdpuConfig::uniform(16, 2, 0, 14), Err(ConfigError::BadN(0))));
        assert!(matches!(PdpuConfig::uniform(16, 2, 300, 14), Err(ConfigError::BadN(300))));
        assert!(matches!(PdpuConfig::uniform(16, 2, 4, 3), Err(ConfigError::BadWm(3))));
        assert!(matches!(PdpuConfig::uniform(16, 2, 4, 200), Err(ConfigError::BadWm(200))));
        assert!(matches!(PdpuConfig::uniform(40, 2, 4, 14), Err(ConfigError::Posit(_))));
        // Wm=96, N=256 → acc width 96+9+1 = 106 ≤ 127: fine
        assert!(PdpuConfig::uniform(16, 2, 256, 96).is_ok());
    }

    #[test]
    fn table1_configs_validate() {
        // every PDPU row of Table I
        for cfg in [
            PdpuConfig::uniform(16, 2, 4, 14),
            PdpuConfig::mixed(13, 16, 2, 4, 14),
            PdpuConfig::mixed(13, 16, 2, 8, 14),
            PdpuConfig::mixed(10, 16, 2, 8, 14),
            PdpuConfig::mixed(13, 16, 2, 8, 10),
        ] {
            assert!(cfg.is_ok());
        }
    }

    #[test]
    fn layer_size_validation() {
        assert!(matches!(validate_layer_sizes(&[]), Err(ConfigError::BadLayerCount(0))));
        assert!(matches!(validate_layer_sizes(&[7]), Err(ConfigError::BadLayerCount(1))));
        assert!(matches!(validate_layer_sizes(&[4, 0, 3]), Err(ConfigError::ZeroLayerWidth(1))));
        assert!(validate_layer_sizes(&[4, 3]).is_ok());
        assert!(validate_layer_sizes(&[12, 8, 3]).is_ok());
    }

    #[test]
    fn label_formats() {
        assert_eq!(PdpuConfig::paper_default().label(), "P(13/16,2) N=4 Wm=14");
    }
}
