//! PJRT runtime — loads the AOT-compiled HLO-text artifacts and executes
//! them on the request path. Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! Python never runs here: after `make artifacts` the Rust binary is
//! self-contained (HLO text + `params_init.bin` + `manifest.json`).

pub mod manifest;

use anyhow::{Context, Result};
use std::path::Path;

pub use manifest::{ArtifactManifest, EntrySig, TensorMeta};

/// A PJRT CPU client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }
}

/// A compiled executable (one model variant / entry point).
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with positional literal arguments; returns the flattened
    /// tuple elements (aot.py lowers everything with `return_tuple=True`).
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args).context("PJRT execute")?;
        let tuple = result[0][0].to_literal_sync().context("fetching result")?;
        tuple.to_tuple().context("decomposing result tuple")
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {:?} wants {} elements, got {}", shape, numel, data.len());
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {:?} wants {} elements, got {}", shape, numel, data.len());
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Read back an f32 literal as a flat Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
