//! Artifacts manifest — the contract between `python/compile/aot.py` and
//! the Rust runtime: entry-point signatures, posit format, MLP layout,
//! and the initial-parameter blob.

use crate::coordinator::json::{parse, Json};
use crate::pdpu::validate_layer_sizes;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor argument.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct EntrySig {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<TensorMeta>,
    pub outputs: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub n_in: u32,
    pub n_out: u32,
    pub es: u32,
    pub batch: usize,
    pub layer_sizes: Vec<usize>,
    pub gemm_mkn: (usize, usize, usize),
    pub entries: Vec<EntrySig>,
    pub param_shapes: Vec<Vec<usize>>,
    params_file: PathBuf,
    param_offsets: Vec<usize>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;

        let fmt = v.get("format").context("manifest: format")?;
        let gemm = v.get("gemm").context("manifest: gemm")?;
        let need = |o: &Json, k: &str| -> Result<usize> {
            o.get(k).and_then(Json::as_usize).with_context(|| format!("manifest key {k}"))
        };

        let mut entries = Vec::new();
        if let Some(Json::Obj(m)) = v.get("entries") {
            for (name, e) in m {
                let args = e
                    .get("args")
                    .and_then(Json::as_arr)
                    .context("entry args")?
                    .iter()
                    .map(|a| {
                        Ok(TensorMeta {
                            shape: a
                                .get("shape")
                                .and_then(Json::as_f64_vec)
                                .context("arg shape")?
                                .into_iter()
                                .map(|d| d as usize)
                                .collect(),
                            dtype: a.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                entries.push(EntrySig {
                    name: name.clone(),
                    file: dir.join(e.get("file").and_then(Json::as_str).context("entry file")?),
                    args,
                    outputs: e.get("outputs").and_then(Json::as_usize).unwrap_or(1),
                });
            }
        }

        let pb = v.get("params_bin").context("manifest: params_bin")?;
        let params_file = dir.join(pb.get("file").and_then(Json::as_str).context("params file")?);
        let mut param_shapes = Vec::new();
        let mut param_offsets = Vec::new();
        for t in pb.get("tensors").and_then(Json::as_arr).context("params tensors")? {
            param_offsets.push(t.get("offset").and_then(Json::as_usize).context("offset")?);
            param_shapes.push(
                t.get("shape")
                    .and_then(Json::as_f64_vec)
                    .context("shape")?
                    .into_iter()
                    .map(|d| d as usize)
                    .collect(),
            );
        }

        let layer_sizes: Vec<usize> = v
            .get("layer_sizes")
            .and_then(Json::as_f64_vec)
            .context("layer_sizes")?
            .into_iter()
            .map(|d| d as usize)
            .collect();
        // Reject degenerate topologies here, once, so the serving tier's
        // input_dim()/classes() accessors can never hit an empty list.
        validate_layer_sizes(&layer_sizes).map_err(|e| anyhow::anyhow!("manifest layer_sizes: {e}"))?;
        let batch = v.get("batch").and_then(Json::as_usize).unwrap_or(32);
        anyhow::ensure!(batch >= 1, "manifest batch must be at least 1");

        Ok(Self {
            dir,
            n_in: need(fmt, "n_in")? as u32,
            n_out: need(fmt, "n_out")? as u32,
            es: need(fmt, "es")? as u32,
            batch,
            layer_sizes,
            gemm_mkn: (need(gemm, "m")?, need(gemm, "k")?, need(gemm, "n")?),
            entries,
            param_shapes,
            params_file,
            param_offsets,
        })
    }

    /// Input feature count (first layer width). `layer_sizes` was
    /// validated at load, so the fallback never fires.
    pub fn input_dim(&self) -> usize {
        self.layer_sizes.first().copied().unwrap_or(0)
    }

    /// Output class count (last layer width).
    pub fn classes(&self) -> usize {
        self.layer_sizes.last().copied().unwrap_or(0)
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySig> {
        self.entries.iter().find(|e| e.name == name).with_context(|| format!("no entry '{name}' in manifest"))
    }

    /// Load the initial parameters as per-tensor f32 vectors.
    pub fn load_params(&self) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&self.params_file)
            .with_context(|| format!("reading {}", self.params_file.display()))?;
        let mut out = Vec::with_capacity(self.param_shapes.len());
        for (shape, &off) in self.param_shapes.iter().zip(&self.param_offsets) {
            let numel: usize = shape.iter().product();
            let end = off + numel * 4;
            anyhow::ensure!(end <= bytes.len(), "params blob truncated");
            let mut v = Vec::with_capacity(numel);
            for chunk in bytes[off..end].chunks_exact(4) {
                v.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the real artifacts when present (built by
    /// `make artifacts`); they are skipped in a fresh checkout.
    fn manifest() -> Option<ArtifactManifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        ArtifactManifest::load(dir).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!((m.n_in, m.n_out, m.es), (13, 16, 2));
        assert_eq!(m.layer_sizes, vec![784, 256, 128, 10]);
        assert_eq!(m.entries.len(), 3);
        assert!(m.entry("mlp_infer").is_ok());
        assert!(m.entry("mlp_train_step").is_ok());
        assert!(m.entry("posit_gemm").is_ok());
        assert!(m.entry("nonexistent").is_err());
    }

    #[test]
    fn entry_signatures_consistent() {
        let Some(m) = manifest() else {
            return;
        };
        let infer = m.entry("mlp_infer").unwrap();
        // 6 params + 1 input
        assert_eq!(infer.args.len(), 7);
        assert_eq!(infer.args[0].shape, vec![784, 256]);
        assert_eq!(infer.args[6].shape, vec![m.batch, 784]);
        let train = m.entry("mlp_train_step").unwrap();
        assert_eq!(train.args.len(), 8);
        assert_eq!(train.outputs, 7);
    }

    #[test]
    fn degenerate_layer_sizes_rejected_at_load() {
        // a manifest with a single-layer topology must fail to load with a
        // typed message, not panic later in input_dim()/classes()
        let dir = std::env::temp_dir().join(format!("pdpu-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "format": {"n_in": 13, "n_out": 16, "es": 2},
            "gemm": {"m": 4, "k": 6, "n": 5},
            "params_bin": {"file": "params.bin", "tensors": []},
            "layer_sizes": [784]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let err = ArtifactManifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("layer_sizes"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn params_blob_loads() {
        let Some(m) = manifest() else {
            return;
        };
        let params = m.load_params().unwrap();
        assert_eq!(params.len(), 6);
        assert_eq!(params[0].len(), 784 * 256);
        assert_eq!(params[5].len(), 10);
        // He init: first weight matrix has plausible std
        let w0 = &params[0];
        let var = w0.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / w0.len() as f64;
        assert!((var / (2.0 / 784.0) - 1.0).abs() < 0.2, "w0 var {var}");
        // biases start at zero
        assert!(params[1].iter().all(|&b| b == 0.0));
    }
}
