//! Sampled FP64 shadow execution.
//!
//! A 1-in-N probe (same shape as the `obs::stages` kernel probe) diverts
//! nothing: when it fires, the launch's already-decoded [`PreparedOperands`]
//! planes are *re-run* in double precision on the caller's thread and the
//! FP64 result is compared against the posit outputs the engine already
//! produced. The primary path is read-only here by construction — shadow
//! sampling ON vs OFF is bit-identical on every output (property-tested in
//! `rust/tests/shadow_identity.rs`).
//!
//! Per-launch error statistics ([`ErrStats`]: relative error, decimal
//! accuracy) are merged into the site registry in `obs::numerics`, giving
//! each layer a measured "digits actually delivered" figure the precision
//! advisor converts into an (n, es) recommendation.
//!
//! Sampling is off (0) by default; arm it with `pdpu serve --shadow N` or
//! the `{"op":"numerics","shadow":N}` wire op.

use std::cell::Cell;
use std::sync::atomic::{AtomicU32, Ordering};

use super::errstats::ErrStats;
use crate::engine::PreparedOperands;
use crate::pdpu::{PackedLane, PdpuConfig};
use crate::posit::Posit;

static SAMPLING: AtomicU32 = AtomicU32::new(0);

/// Set the shadow sampling rate: 0 disables, N shadows one launch in N
/// per engine thread.
pub fn set_sampling(every: u32) {
    SAMPLING.store(every, Ordering::Relaxed);
}

/// Current sampling rate (0 = disabled).
pub fn sampling() -> u32 {
    SAMPLING.load(Ordering::Relaxed)
}

thread_local! {
    static TICK: Cell<u32> = Cell::new(0);
}

/// Cheap per-launch probe: one relaxed load when disabled, a thread-local
/// counter tick when armed. Returns true for one launch in N.
pub fn probe() -> bool {
    let every = SAMPLING.load(Ordering::Relaxed);
    if every == 0 {
        return false;
    }
    TICK.with(|t| {
        let v = t.get().wrapping_add(1);
        t.set(v);
        v % every == 0
    })
}

/// Exact FP64 value of one packed lane: dead lanes are 0, NaR is NaN,
/// live lanes reconstruct `±frac · 2^(scale − frac_bits)` — exact because
/// a posit fraction (≤ 31 bits) fits the FP64 mantissa.
fn lane_f64(lane: PackedLane, frac_bits: u32) -> f64 {
    if lane.is_nar() {
        return f64::NAN;
    }
    if !lane.is_live() {
        return 0.0;
    }
    let mag = lane.frac() as f64 * 2f64.powi(lane.scale() - frac_bits as i32);
    if lane.sign() {
        -mag
    } else {
        mag
    }
}

/// Re-run one engine launch in FP64 and record error statistics against
/// the posit outputs. Reads everything, mutates nothing but the site
/// registry. NaR outputs and FP64 overflows are skipped: there is no
/// meaningful scalar error to attribute to them (they are counted by the
/// saturation/NaR tallies instead).
pub fn shadow_gemm(
    cfg: &PdpuConfig,
    acc: &[Posit],
    w: &PreparedOperands,
    x: &PreparedOperands,
    outs: &[Posit],
) {
    let (rows, cols) = (w.rows(), x.rows());
    if rows == 0 || cols == 0 || outs.len() != rows * cols || acc.len() != rows {
        return;
    }
    let frac_bits = w.format().max_frac_bits();
    let mut stats = ErrStats::default();
    for r in 0..rows {
        let seed = acc[r].to_f64();
        let wrow = w.row(r);
        for c in 0..cols {
            let got = outs[r * cols + c];
            if got.is_nar() {
                continue;
            }
            let mut s = seed;
            for (&a, &b) in wrow.iter().zip(x.row(c)) {
                s += lane_f64(a, frac_bits) * lane_f64(b, frac_bits);
            }
            if !s.is_finite() {
                continue;
            }
            stats.observe(s, got.to_f64());
        }
    }
    if stats.samples() > 0 {
        super::numerics::merge_shadow(cfg, stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::diff::{adversarial_vector, random_config};
    use crate::testing::Rng;

    #[test]
    fn probe_never_fires_when_disabled() {
        set_sampling(0);
        for _ in 0..1000 {
            assert!(!probe());
        }
    }

    #[test]
    fn lane_f64_reconstructs_the_decoded_posit_exactly() {
        let mut rng = Rng::seeded(0x5AD0_0001);
        for _ in 0..50 {
            let cfg = random_config(&mut rng);
            let frac_bits = cfg.in_fmt.max_frac_bits();
            for p in adversarial_vector(&mut rng, cfg.in_fmt, 64) {
                let lane = PackedLane::from_posit(p);
                let via_lane = lane_f64(lane, frac_bits);
                let direct = p.to_f64();
                if p.is_nar() {
                    assert!(via_lane.is_nan(), "NaR must shadow as NaN");
                } else {
                    assert_eq!(
                        via_lane.to_bits(),
                        direct.to_bits(),
                        "cfg {} posit bits {:#x}",
                        cfg.label(),
                        p.bits()
                    );
                }
            }
        }
    }
}
