//! The crate's single sanctioned clock site.
//!
//! The `determinism` lint rule (`analysis/rules/r3_determinism.rs`) bans
//! raw `Instant::now()` / `SystemTime::now()` everywhere result-affecting
//! code lives *and* throughout `coordinator/` — telemetry that wants wall
//! time must route through this module instead. Centralizing the reads
//! keeps "who looks at the clock" greppable and lets the observability
//! layer anchor every timestamp to one process-wide epoch, so span start
//! times from different threads land on a single comparable timeline.

use std::sync::OnceLock;
use std::time::Instant;

/// Process-wide epoch: the first time anything asked for a timestamp.
fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// A monotonic instant, for measuring durations.
///
/// This is the only place the crate reads the monotonic clock; everything
/// else stores the returned [`Instant`] and asks it for `elapsed()` /
/// `saturating_duration_since`.
pub fn now() -> Instant {
    Instant::now()
}

/// Microseconds since the process-wide epoch (first clock use).
///
/// Saturates at zero for instants that somehow precede the anchor, so it
/// can never panic.
pub fn epoch_us() -> u64 {
    let a = anchor();
    now().saturating_duration_since(a).as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_monotone() {
        let a = epoch_us();
        let b = epoch_us();
        assert!(b >= a);
    }

    #[test]
    fn durations_are_nonnegative() {
        let t0 = now();
        let t1 = now();
        assert!(t1.saturating_duration_since(t0) >= std::time::Duration::ZERO);
        // the saturating form clamps reversed arguments to zero instead of panicking
        assert_eq!(t0.saturating_duration_since(t1), std::time::Duration::ZERO);
    }
}
