//! Per-stage kernel timing for the paper's S1–S6 pipeline.
//!
//! The engine's inner loop (`BatchEngine::dot_prepared`) is the one place
//! the reproduction touches all six pipeline stages per chunk, and also
//! the one place that absolutely cannot afford per-call timing. So the
//! probe is two-level:
//!
//! * **Level 0** — tracing off ([`super::trace::sampling`] == 0):
//!   [`probe`] is a single relaxed load plus a predictable branch; the
//!   engine runs its unprofiled hot kernel.
//! * **Level 1** — tracing on: a thread-local tick samples one dot
//!   product in [`STAGE_PROBE_EVERY`], and only that dot runs the
//!   profiled kernel, which times S1 (decode/fill), S2 (multiply),
//!   S3–S4 (align + accumulate) and S5–S6 (normalize + encode) and adds
//!   the nanoseconds into four global bins.
//!
//! The bins are cumulative; span emission works on *deltas* — the service
//! snapshots the bins before an engine launch and emits the per-stage
//! growth as child spans of that launch ([`emit_delta`]). The engine
//! thread serializes launches, so a launch's delta is attributable to it.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// When tracing is on, one dot product in this many is stage-profiled.
pub const STAGE_PROBE_EVERY: u32 = 64;

thread_local! {
    static TICK: Cell<u32> = Cell::new(0);
}

static S1_NS: AtomicU64 = AtomicU64::new(0);
static S2_NS: AtomicU64 = AtomicU64::new(0);
static S34_NS: AtomicU64 = AtomicU64::new(0);
static S56_NS: AtomicU64 = AtomicU64::new(0);
static SAMPLES: AtomicU64 = AtomicU64::new(0);

/// Should this dot product run the profiled kernel?
///
/// False in one relaxed load when tracing is off; otherwise true for one
/// call in [`STAGE_PROBE_EVERY`] per thread. Allocation-free.
pub fn probe() -> bool {
    if super::trace::sampling() == 0 {
        return false;
    }
    TICK.with(|t| {
        let v = t.get().wrapping_add(1);
        t.set(v);
        v % STAGE_PROBE_EVERY == 0
    })
}

/// Add one profiled dot product's per-stage nanoseconds to the bins.
pub fn add_sample(s1_ns: u64, s2_ns: u64, s34_ns: u64, s56_ns: u64) {
    S1_NS.fetch_add(s1_ns, Ordering::Relaxed);
    S2_NS.fetch_add(s2_ns, Ordering::Relaxed);
    S34_NS.fetch_add(s34_ns, Ordering::Relaxed);
    S56_NS.fetch_add(s56_ns, Ordering::Relaxed);
    SAMPLES.fetch_add(1, Ordering::Relaxed);
}

/// Cumulative stage-bin totals at a point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageSnapshot {
    /// S1 decode + operand fill, nanoseconds.
    pub s1_ns: u64,
    /// S2 mantissa multiply, nanoseconds.
    pub s2_ns: u64,
    /// S3 align + S4 accumulate, nanoseconds.
    pub s34_ns: u64,
    /// S5 normalize + S6 round/encode, nanoseconds.
    pub s56_ns: u64,
    /// Profiled dot products contributing to the bins.
    pub samples: u64,
}

impl StageSnapshot {
    /// Bin growth since `earlier` (saturating, so reordered relaxed reads
    /// can never underflow).
    pub fn delta_since(&self, earlier: &StageSnapshot) -> StageSnapshot {
        StageSnapshot {
            s1_ns: self.s1_ns.saturating_sub(earlier.s1_ns),
            s2_ns: self.s2_ns.saturating_sub(earlier.s2_ns),
            s34_ns: self.s34_ns.saturating_sub(earlier.s34_ns),
            s56_ns: self.s56_ns.saturating_sub(earlier.s56_ns),
            samples: self.samples.saturating_sub(earlier.samples),
        }
    }
}

/// Read the cumulative bins.
pub fn snapshot() -> StageSnapshot {
    StageSnapshot {
        s1_ns: S1_NS.load(Ordering::Relaxed),
        s2_ns: S2_NS.load(Ordering::Relaxed),
        s34_ns: S34_NS.load(Ordering::Relaxed),
        s56_ns: S56_NS.load(Ordering::Relaxed),
        samples: SAMPLES.load(Ordering::Relaxed),
    }
}

/// Emit the bin growth since `earlier` as four stage spans under `ctx`
/// (normally an `engine_launch` span). No-op when nothing was profiled
/// in the window or the request is unsampled.
pub fn emit_delta(ctx: Option<super::trace::TraceCtx>, earlier: &StageSnapshot) {
    if ctx.is_none() {
        return;
    }
    let d = snapshot().delta_since(earlier);
    if d.samples == 0 {
        return;
    }
    super::trace::record_ending_now("s1_decode", ctx, d.s1_ns);
    super::trace::record_ending_now("s2_multiply", ctx, d.s2_ns);
    super::trace::record_ending_now("s3_s4_align_acc", ctx, d.s34_ns);
    super::trace::record_ending_now("s5_s6_norm_encode", ctx, d.s56_ns);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_is_false_when_tracing_off() {
        // sampling may be toggled by trace tests in this binary; only
        // assert the off case, which we can force locally.
        if super::super::trace::sampling() == 0 {
            for _ in 0..1000 {
                assert!(!probe());
            }
        }
    }

    #[test]
    fn delta_is_saturating_and_additive() {
        let before = snapshot();
        add_sample(10, 20, 30, 40);
        add_sample(1, 2, 3, 4);
        let d = snapshot().delta_since(&before);
        assert!(d.s1_ns >= 11 && d.s2_ns >= 22 && d.s34_ns >= 33 && d.s56_ns >= 44);
        assert!(d.samples >= 2);
        // reversed arguments saturate to zero instead of wrapping
        let z = before.delta_since(&snapshot());
        assert_eq!(z.samples, 0);
    }
}
