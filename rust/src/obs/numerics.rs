//! Per-site posit numerics observatory.
//!
//! The global counters in `obs` answer "is the process saturating?"; this
//! registry answers the paper's real question — *which layer* is running
//! out of regime, and which (n, es) would fix it. Every engine launch is
//! attributed to an op **site** (model layer index × kernel kind, e.g.
//! `infer:L0`, `train_bwd:L2`, `gemm`) via a thread-local [`SiteGuard`]
//! installed by the serving/training layers, and the registry keys entries
//! on site × [`PdpuConfig`] so mixed-format deployments stay separable.
//!
//! Per entry it records:
//! - log₂-bucketed histograms of decoded operand and output scales
//!   (regime/dynamic-range utilization straight off the [`PackedLane`]
//!   words — no re-decode of the posit bit patterns);
//! - saturation (±maxpos), ±minpos-clamp, and NaR tallies, site-attributed
//!   (the process-global counters keep ticking through
//!   `obs::add_output_tallies` so existing dashboards are unchanged);
//! - quire-rounding counts, gradient saturation/underflow counts, and the
//!   quire max-magnitude watermark from the SGD update path;
//! - FP64 shadow-execution error statistics merged in by `obs::shadow`.
//!
//! [`advise`] turns each entry into a per-site (n, es) recommendation —
//! the smallest posit format whose regime span covers the observed scale
//! range while keeping the fraction bits the site's measured accuracy
//! actually uses. This is the direct feeder artifact for the ROADMAP
//! mixed-precision autotuner.

use std::cell::Cell;
use std::sync::Mutex;

use super::errstats::ErrStats;
use crate::pdpu::{PackedLane, PdpuConfig};
use crate::posit::Posit;

/// Number of scale-histogram buckets per plane.
pub const SCALE_BUCKETS: usize = 64;
/// Scale value mapped to bucket 0; anything below clamps into it.
pub const SCALE_BUCKET_LO: i32 = -128;
/// Width of each histogram bucket in binary orders of magnitude.
pub const SCALE_BUCKET_WIDTH: i32 = 4;

fn bucket(scale: i32) -> usize {
    ((scale - SCALE_BUCKET_LO) / SCALE_BUCKET_WIDTH).clamp(0, SCALE_BUCKETS as i32 - 1) as usize
}

/// Kernel family a launch belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SiteKind {
    /// Serving inference layers (`TrainGraph::infer`).
    Infer,
    /// Training forward-pass layers.
    TrainFwd,
    /// Training backward-pass layers (dW / dA kernels).
    TrainBwd,
    /// SGD weight/bias updates (quire-FMA path, no engine launch).
    SgdUpdate,
    /// Raw served GEMM requests (fused or unfused).
    Gemm,
    /// Work with no guard installed (direct engine calls, tests).
    Unattributed,
}

impl SiteKind {
    /// Stable lowercase label used in wire responses and metric labels.
    pub fn label(self) -> &'static str {
        match self {
            SiteKind::Infer => "infer",
            SiteKind::TrainFwd => "train_fwd",
            SiteKind::TrainBwd => "train_bwd",
            SiteKind::SgdUpdate => "sgd_update",
            SiteKind::Gemm => "gemm",
            SiteKind::Unattributed => "unattributed",
        }
    }
}

/// An op site: kernel kind plus model layer index (`-1` when the kernel
/// is not layer-scoped, e.g. a raw served GEMM).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Site {
    pub kind: SiteKind,
    pub layer: i32,
}

impl Site {
    /// The site work lands on when no guard is installed.
    pub const UNATTRIBUTED: Site = Site { kind: SiteKind::Unattributed, layer: -1 };

    pub fn new(kind: SiteKind, layer: i32) -> Site {
        Site { kind, layer }
    }

    /// Non-layer-scoped site for raw served GEMMs.
    pub fn gemm() -> Site {
        Site::new(SiteKind::Gemm, -1)
    }

    /// Human/wire label: `infer:L0` when layer-scoped, else the bare kind.
    pub fn label(&self) -> String {
        if self.layer < 0 {
            self.kind.label().to_string()
        } else {
            format!("{}:L{}", self.kind.label(), self.layer)
        }
    }
}

thread_local! {
    static CURRENT: Cell<Site> = Cell::new(Site::UNATTRIBUTED);
}

/// Site currently installed on this thread.
pub fn current_site() -> Site {
    CURRENT.with(Cell::get)
}

/// RAII guard installing a site on the current thread; restores the
/// previous site on drop so guards nest (e.g. a served GEMM entering the
/// fusion planner keeps its `gemm` attribution).
///
/// Engine launches record on the *caller's* thread (after worker join),
/// so a guard held across a `BatchEngine` call attributes correctly even
/// when the GEMM itself fans out to worker threads.
#[must_use = "the site is only installed while the guard is alive"]
pub struct SiteGuard {
    prev: Site,
}

impl SiteGuard {
    pub fn enter(site: Site) -> SiteGuard {
        let prev = CURRENT.with(|c| c.replace(site));
        SiteGuard { prev }
    }
}

impl Drop for SiteGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT.with(move |c| c.set(prev));
    }
}

/// Everything the observatory knows about one site × config pair.
#[derive(Clone, Debug)]
pub struct SiteStats {
    /// Engine launches attributed here.
    pub launches: u64,
    /// Posit outputs produced by those launches.
    pub outputs: u64,
    /// Outputs clamped to ±maxpos (regime exhausted upward).
    pub sat_maxpos: u64,
    /// Nonzero results clamped to ±minpos (regime exhausted downward).
    pub sat_minpos: u64,
    /// NaR outputs.
    pub nar: u64,
    /// Inexact quire-FMA weight updates (SGD path).
    pub quire_roundings: u64,
    /// Gradients that quantized to ±maxpos before the update.
    pub grad_sat: u64,
    /// Nonzero gradients that quantized to ±minpos.
    pub grad_underflow: u64,
    /// Histogram of decoded operand scales (both GEMM planes).
    pub operand_scale_hist: [u64; SCALE_BUCKETS],
    /// Histogram of output scales.
    pub output_scale_hist: [u64; SCALE_BUCKETS],
    /// Smallest decoded scale seen (operands or outputs).
    pub min_scale: Option<i32>,
    /// Largest decoded scale seen (operands or outputs).
    pub max_scale: Option<i32>,
    /// Largest ⌊log₂|quire|⌋ observed across SGD updates.
    pub quire_watermark_log2: Option<i32>,
    /// FP64 shadow-execution error statistics.
    pub shadow: ErrStats,
}

impl SiteStats {
    fn new() -> SiteStats {
        SiteStats {
            launches: 0,
            outputs: 0,
            sat_maxpos: 0,
            sat_minpos: 0,
            nar: 0,
            quire_roundings: 0,
            grad_sat: 0,
            grad_underflow: 0,
            operand_scale_hist: [0; SCALE_BUCKETS],
            output_scale_hist: [0; SCALE_BUCKETS],
            min_scale: None,
            max_scale: None,
            quire_watermark_log2: None,
            shadow: ErrStats::default(),
        }
    }

    fn widen_scale_range(&mut self, lo: i32, hi: i32) {
        self.min_scale = Some(self.min_scale.map_or(lo, |m| m.min(lo)));
        self.max_scale = Some(self.max_scale.map_or(hi, |m| m.max(hi)));
    }
}

/// One registry row in a [`snapshot`].
#[derive(Clone, Debug)]
pub struct SiteEntry {
    pub site: Site,
    pub cfg: PdpuConfig,
    pub stats: SiteStats,
}

// Distinct (site, config) pairs are few (layers × kernel kinds), so a
// linear-scan Vec under one mutex beats a map and keeps snapshots ordered
// by first appearance.
static REGISTRY: Mutex<Vec<SiteEntry>> = Mutex::new(Vec::new());

fn with_entry<F: FnOnce(&mut SiteStats)>(site: Site, cfg: PdpuConfig, f: F) {
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(entry) = reg.iter_mut().find(|e| e.site == site && e.cfg == cfg) {
        f(&mut entry.stats);
        return;
    }
    let mut stats = SiteStats::new();
    f(&mut stats);
    reg.push(SiteEntry { site, cfg, stats });
}

/// Record one engine launch at the current thread's site: classify the
/// posit outputs (same classification as `obs::record_outputs`), tick the
/// process-global tallies, and fold operand/output scale statistics into
/// the site entry. Called from the single sanctioned boundary in
/// `BatchEngine::gemm_posit`.
pub fn record_launch(cfg: &PdpuConfig, w: &[PackedLane], x: &[PackedLane], outs: &[Posit]) {
    let (mut maxpos, mut minpos, mut nar) = (0u64, 0u64, 0u64);
    let mut out_hist = [0u64; SCALE_BUCKETS];
    let (mut lo, mut hi) = (i32::MAX, i32::MIN);
    for &p in outs {
        if p.is_nar() {
            nar += 1;
            continue;
        }
        if p.is_zero() {
            continue;
        }
        let fmt = p.format();
        let bits = p.bits();
        let sign_bit = 1u32 << (fmt.n() - 1);
        let abs = if bits & sign_bit != 0 { bits.wrapping_neg() & fmt.mask() } else { bits };
        if abs == fmt.maxpos_bits() {
            maxpos += 1;
        } else if abs == fmt.minpos_bits() {
            minpos += 1;
        }
        let sc = PackedLane::from_posit(p).scale();
        lo = lo.min(sc);
        hi = hi.max(sc);
        out_hist[bucket(sc)] += 1;
    }
    super::add_output_tallies(maxpos, minpos, nar);

    let mut op_hist = [0u64; SCALE_BUCKETS];
    for lane in w.iter().chain(x) {
        if !lane.is_live() {
            continue;
        }
        let sc = lane.scale();
        lo = lo.min(sc);
        hi = hi.max(sc);
        op_hist[bucket(sc)] += 1;
    }

    with_entry(current_site(), *cfg, |s| {
        s.launches += 1;
        s.outputs += outs.len() as u64;
        s.sat_maxpos += maxpos;
        s.sat_minpos += minpos;
        s.nar += nar;
        for (slot, v) in s.operand_scale_hist.iter_mut().zip(op_hist) {
            *slot += v;
        }
        for (slot, v) in s.output_scale_hist.iter_mut().zip(out_hist) {
            *slot += v;
        }
        if lo <= hi {
            s.widen_scale_range(lo, hi);
        }
    });
}

/// Record one SGD update-slice pass at the current thread's site. Keeps
/// the process-global quire-rounding counter ticking (via
/// `obs::add_quire_roundings`) in addition to the site attribution.
pub fn record_update(
    cfg: &PdpuConfig,
    roundings: u64,
    grad_sat: u64,
    grad_underflow: u64,
    watermark: Option<i32>,
) {
    super::add_quire_roundings(roundings);
    with_entry(current_site(), *cfg, |s| {
        s.quire_roundings += roundings;
        s.grad_sat += grad_sat;
        s.grad_underflow += grad_underflow;
        if let Some(w) = watermark {
            s.quire_watermark_log2 = Some(s.quire_watermark_log2.map_or(w, |m| m.max(w)));
        }
    });
}

/// Merge one launch's FP64 shadow-execution error statistics into the
/// current thread's site entry (called by `obs::shadow`).
pub fn merge_shadow(cfg: &PdpuConfig, stats: ErrStats) {
    with_entry(current_site(), *cfg, |s| s.shadow.merge(&stats));
}

/// Clone of the registry, ordered by first appearance.
pub fn snapshot() -> Vec<SiteEntry> {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Comma-free config label for Prometheus label values (the exposition
/// parser splits label pairs on commas, so `PdpuConfig::label`'s
/// `P(13/16,2)` form cannot be used there).
pub fn cfg_metric_label(cfg: &PdpuConfig) -> String {
    format!(
        "P{}-{}es{}_N{}_Wm{}",
        cfg.in_fmt.n(),
        cfg.out_fmt.n(),
        cfg.in_fmt.es(),
        cfg.n,
        cfg.wm
    )
}

/// A per-site format recommendation from the precision advisor.
#[derive(Clone, Debug)]
pub struct Advice {
    pub site: Site,
    pub cfg: PdpuConfig,
    /// Recommended posit width.
    pub rec_n: u32,
    /// Recommended exponent-field width.
    pub rec_es: u32,
    /// Binary orders of magnitude the format's regime must span.
    pub required_scale: i32,
    /// Decimal digits the site demonstrably carries (shadow-measured when
    /// available, else the current format's nominal precision).
    pub target_decimal_digits: f64,
}

/// Precision-advisor report: for every site with observed dynamic-range
/// evidence, the smallest (n, es) whose max regime scale `(n−2)·2^es`
/// covers the site's scale span while retaining enough fraction bits
/// (`n−3−es`) for its measured decimal accuracy. This is the per-layer
/// format table Deep Positron-style deployments start from.
pub fn advise() -> Vec<Advice> {
    snapshot().iter().filter_map(advise_one).collect()
}

fn advise_one(e: &SiteEntry) -> Option<Advice> {
    let s = &e.stats;
    let mut required: Option<i32> = None;
    let mut widen = |v: i32| {
        let v = v.abs();
        required = Some(required.map_or(v, |r| r.max(v)));
    };
    if let Some(v) = s.min_scale {
        widen(v);
    }
    if let Some(v) = s.max_scale {
        widen(v);
    }
    if let Some(v) = s.quire_watermark_log2 {
        widen(v);
    }
    let required = required?; // no range evidence → no recommendation

    let nominal_frac = e.cfg.in_fmt.max_frac_bits() as i32;
    let digits = if s.shadow.samples() > 0 {
        s.shadow.mean_decimal_accuracy().max(0.0)
    } else {
        nominal_frac as f64 * std::f64::consts::LOG10_2
    };
    // Bits needed for the measured digits, never exceeding what the
    // current format could have delivered (the shadow measures *achieved*
    // accuracy, so it cannot justify more bits than the format carries).
    let frac_needed = ((digits * std::f64::consts::LOG2_10).ceil() as i32).clamp(0, nominal_frac);

    for n in 3..=32i32 {
        for es in 0..=3i32 {
            let span = (n - 2) << es;
            let frac = n - 3 - es;
            if span >= required && frac >= frac_needed {
                return Some(Advice {
                    site: e.site,
                    cfg: e.cfg,
                    rec_n: n as u32,
                    rec_es: es as u32,
                    required_scale: required,
                    target_decimal_digits: digits,
                });
            }
        }
    }
    // Pathological range (beyond P32/es3): keep the current input format.
    Some(Advice {
        site: e.site,
        cfg: e.cfg,
        rec_n: e.cfg.in_fmt.n(),
        rec_es: e.cfg.in_fmt.es(),
        required_scale: required,
        target_decimal_digits: digits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> PdpuConfig {
        PdpuConfig::paper_default()
    }

    fn stats_for(site: Site, cfg: &PdpuConfig) -> Option<SiteStats> {
        snapshot().into_iter().find(|e| e.site == site && &e.cfg == cfg).map(|e| e.stats)
    }

    #[test]
    fn site_guard_nests_and_restores() {
        assert_eq!(current_site(), Site::UNATTRIBUTED);
        {
            let _a = SiteGuard::enter(Site::new(SiteKind::Infer, 0));
            assert_eq!(current_site(), Site::new(SiteKind::Infer, 0));
            {
                let _b = SiteGuard::enter(Site::gemm());
                assert_eq!(current_site(), Site::gemm());
            }
            assert_eq!(current_site(), Site::new(SiteKind::Infer, 0));
        }
        assert_eq!(current_site(), Site::UNATTRIBUTED);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Site::new(SiteKind::Infer, 2).label(), "infer:L2");
        assert_eq!(Site::gemm().label(), "gemm");
        assert_eq!(Site::UNATTRIBUTED.label(), "unattributed");
        // metric label must stay comma- and space-free for the prom parser
        let l = cfg_metric_label(&test_cfg());
        assert!(!l.contains(',') && !l.contains(' ') && !l.contains('"'), "{l}");
    }

    #[test]
    fn record_launch_attributes_to_the_installed_site() {
        let cfg = test_cfg();
        let site = Site::new(SiteKind::TrainFwd, 77); // unique to this test
        let fmt = cfg.in_fmt;
        let w: Vec<PackedLane> =
            [1.0, -2.0, 0.0].iter().map(|&v| PackedLane::from_posit(Posit::from_f64(v, fmt))).collect();
        let x: Vec<PackedLane> =
            [0.5, 4.0].iter().map(|&v| PackedLane::from_posit(Posit::from_f64(v, fmt))).collect();
        let outs = vec![
            Posit::from_f64(1.5, cfg.out_fmt),
            Posit::from_f64(0.0, cfg.out_fmt),
            Posit::from_f64(f64::NAN, cfg.out_fmt), // NaR
        ];
        let _g = SiteGuard::enter(site);
        record_launch(&cfg, &w, &x, &outs);
        let s = stats_for(site, &cfg).expect("entry created");
        assert_eq!(s.launches, 1);
        assert_eq!(s.outputs, 3);
        assert_eq!(s.nar, 1);
        // 4 live operand lanes (the 0.0 packs dead) + 1 finite nonzero output
        assert_eq!(s.operand_scale_hist.iter().sum::<u64>(), 4);
        assert_eq!(s.output_scale_hist.iter().sum::<u64>(), 1);
        // scales span [-1, 2]: 4.0 → 2, 0.5 → -1
        assert_eq!(s.min_scale, Some(-1));
        assert_eq!(s.max_scale, Some(2));
    }

    #[test]
    fn record_update_tracks_watermark_and_grad_tallies() {
        let cfg = test_cfg();
        let site = Site::new(SiteKind::SgdUpdate, 88); // unique to this test
        {
            let _g = SiteGuard::enter(site);
            record_update(&cfg, 3, 1, 2, Some(9));
            record_update(&cfg, 1, 0, 0, Some(4)); // lower watermark must not regress
        }
        let s = stats_for(site, &cfg).expect("entry created");
        assert_eq!(s.quire_roundings, 4);
        assert_eq!(s.grad_sat, 1);
        assert_eq!(s.grad_underflow, 2);
        assert_eq!(s.quire_watermark_log2, Some(9));
    }

    #[test]
    fn advisor_covers_range_and_caps_at_current_precision() {
        let cfg = test_cfg();
        let site = Site::new(SiteKind::Gemm, 99); // unique to this test
        {
            let _g = SiteGuard::enter(site);
            record_update(&cfg, 0, 0, 0, Some(20));
        }
        let advice = advise();
        let a = advice.iter().find(|a| a.site == site).expect("advised");
        assert_eq!(a.required_scale, 20);
        let span = (a.rec_n as i32 - 2) << a.rec_es;
        assert!(span >= 20, "span {span} < required 20");
        assert!((3..=32).contains(&a.rec_n), "n {}", a.rec_n);
        assert!(a.rec_es <= 3, "es {}", a.rec_es);
        // never recommends more fraction bits than the current format has
        let frac = a.rec_n as i32 - 3 - a.rec_es as i32;
        assert!(frac <= cfg.in_fmt.max_frac_bits() as i32 + 1);
    }

    #[test]
    fn scale_buckets_clamp_at_the_edges() {
        assert_eq!(bucket(SCALE_BUCKET_LO), 0);
        assert_eq!(bucket(SCALE_BUCKET_LO - 1000), 0);
        assert_eq!(bucket(-SCALE_BUCKET_LO - 1), SCALE_BUCKETS - 1);
        assert_eq!(bucket(1000), SCALE_BUCKETS - 1);
        assert_eq!(bucket(0), SCALE_BUCKETS / 2);
    }
}
