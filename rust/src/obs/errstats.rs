//! Shared numeric-error statistics: absolute/relative error and decimal
//! accuracy ("how many correct decimal digits survive", à la Deep
//! Positron's accuracy metric).
//!
//! One accumulator, two producers: `dnn::quantize::quant_stats` (format
//! sweeps over quantized tensors) and the FP64 shadow executor in
//! `obs::shadow` (sampled engine launches re-run in double precision).
//! Both previously carried their own copies of this arithmetic; keeping it
//! here means the figure-3 / table-1 experiments and the live observatory
//! report the same numbers for the same errors.

/// Decimal-accuracy contribution credited to an exact match. FP64 itself
/// carries ~15.95 decimal digits, so an exact posit↔shadow agreement is
/// capped here instead of poisoning the mean with +∞.
pub const DECIMAL_ACCURACY_CAP: f64 = 16.0;

/// Floor used by [`relative_error`] so exact-zero references yield a
/// finite (if huge) relative error instead of a division by zero.
pub const REL_EPS: f64 = 1e-12;

/// Streaming error accumulator over (reference, approximation) pairs.
///
/// Semantics mirror the historical `quant_stats` exactly:
/// - a non-finite approximation counts as an *overflow* and contributes to
///   no error sum (but still to the sample count, so `mean_abs_err` is
///   averaged over all samples);
/// - relative error and decimal accuracy are only defined where the
///   reference is nonzero.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrStats {
    n: u64,
    rel_n: u64,
    overflows: u64,
    max_abs_err: f64,
    sum_abs_err: f64,
    sum_rel_err: f64,
    sum_dec_acc: f64,
}

impl ErrStats {
    /// Record one (reference, approximation) pair.
    pub fn observe(&mut self, reference: f64, got: f64) {
        self.n += 1;
        if !got.is_finite() {
            self.overflows += 1;
            return;
        }
        let e = (reference - got).abs();
        if e > self.max_abs_err {
            self.max_abs_err = e;
        }
        self.sum_abs_err += e;
        if reference != 0.0 {
            let rel = e / reference.abs();
            self.sum_rel_err += rel;
            self.rel_n += 1;
            self.sum_dec_acc += if rel == 0.0 {
                DECIMAL_ACCURACY_CAP
            } else {
                (-rel.log10()).min(DECIMAL_ACCURACY_CAP)
            };
        }
    }

    /// Fold another accumulator into this one (used to merge per-launch
    /// shadow samples into the long-lived per-site entry).
    pub fn merge(&mut self, other: &ErrStats) {
        self.n += other.n;
        self.rel_n += other.rel_n;
        self.overflows += other.overflows;
        if other.max_abs_err > self.max_abs_err {
            self.max_abs_err = other.max_abs_err;
        }
        self.sum_abs_err += other.sum_abs_err;
        self.sum_rel_err += other.sum_rel_err;
        self.sum_dec_acc += other.sum_dec_acc;
    }

    /// Total observed pairs, overflows included.
    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Fraction of samples whose approximation was non-finite.
    pub fn overflow_frac(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.overflows as f64 / self.n as f64
        }
    }

    /// Largest absolute error over the finite approximations.
    pub fn max_abs_err(&self) -> f64 {
        self.max_abs_err
    }

    /// Mean absolute error, averaged over *all* samples (overflowed ones
    /// contribute zero to the numerator, matching `quant_stats`).
    pub fn mean_abs_err(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_abs_err / self.n as f64
        }
    }

    /// Mean relative error over samples with a nonzero reference.
    pub fn mean_rel_err(&self) -> f64 {
        if self.rel_n == 0 {
            0.0
        } else {
            self.sum_rel_err / self.rel_n as f64
        }
    }

    /// Mean decimal accuracy (−log₁₀ of relative error, capped at
    /// [`DECIMAL_ACCURACY_CAP`]) over samples with a nonzero reference.
    pub fn mean_decimal_accuracy(&self) -> f64 {
        if self.rel_n == 0 {
            0.0
        } else {
            self.sum_dec_acc / self.rel_n as f64
        }
    }
}

/// Relative error with an epsilon-floored denominator — the form used by
/// `dnn::metrics::mean_relative_accuracy` (table 1).
pub fn relative_error(reference: f64, got: f64) -> f64 {
    (got - reference).abs() / reference.abs().max(REL_EPS)
}

/// Decimal accuracy of a single approximation — the form used by
/// `dnn::metrics::decimal_accuracy` (figure 3): `0.0` when the
/// approximation is non-finite or the reference is zero, `+∞` for an
/// exact match, otherwise −log₁₀ of the relative error.
pub fn decimal_accuracy(reference: f64, got: f64) -> f64 {
    if !got.is_finite() || reference == 0.0 {
        return 0.0;
    }
    let rel = ((got - reference) / reference).abs();
    if rel == 0.0 {
        f64::INFINITY
    } else {
        -rel.log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_tracks_abs_rel_and_overflow_like_quant_stats() {
        let mut s = ErrStats::default();
        s.observe(1.0, 1.5); // abs 0.5, rel 0.5
        s.observe(2.0, 2.0); // exact: abs 0, rel 0, dec capped
        s.observe(4.0, f64::INFINITY); // overflow: no error contribution
        s.observe(0.0, 0.25); // zero reference: abs only
        assert_eq!(s.samples(), 4);
        assert!((s.overflow_frac() - 0.25).abs() < 1e-12);
        assert!((s.max_abs_err() - 0.5).abs() < 1e-12);
        // sum_abs = 0.5 + 0 + 0.25 over n = 4
        assert!((s.mean_abs_err() - 0.75 / 4.0).abs() < 1e-12);
        // rel over the two nonzero-reference finite samples
        assert!((s.mean_rel_err() - 0.25).abs() < 1e-12);
        // dec: (-log10(0.5) + CAP) / 2
        let want = (0.5f64.log10().abs() + DECIMAL_ACCURACY_CAP) / 2.0;
        assert!((s.mean_decimal_accuracy() - want).abs() < 1e-12);
    }

    #[test]
    fn merge_is_equivalent_to_observing_everything_in_one_accumulator() {
        let pairs = [(1.0, 1.25), (3.0, 2.0), (0.5, f64::NAN), (-2.0, -2.0)];
        let mut whole = ErrStats::default();
        let mut a = ErrStats::default();
        let mut b = ErrStats::default();
        for (i, &(r, g)) in pairs.iter().enumerate() {
            whole.observe(r, g);
            if i % 2 == 0 {
                a.observe(r, g);
            } else {
                b.observe(r, g);
            }
        }
        a.merge(&b);
        assert_eq!(a.samples(), whole.samples());
        assert_eq!(a.overflow_frac().to_bits(), whole.overflow_frac().to_bits());
        assert_eq!(a.mean_abs_err().to_bits(), whole.mean_abs_err().to_bits());
        assert_eq!(a.mean_rel_err().to_bits(), whole.mean_rel_err().to_bits());
        assert_eq!(a.max_abs_err().to_bits(), whole.max_abs_err().to_bits());
    }

    #[test]
    fn decimal_accuracy_free_fn_keeps_the_metrics_edge_cases() {
        assert_eq!(decimal_accuracy(0.0, 0.1), 0.0);
        assert_eq!(decimal_accuracy(1.0, f64::INFINITY), 0.0);
        assert_eq!(decimal_accuracy(1.0, 1.0), f64::INFINITY);
        // 1% relative error ≈ 2 decimal digits
        assert!((decimal_accuracy(1.0, 1.01) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn relative_error_floors_the_denominator() {
        assert!((relative_error(2.0, 2.5) - 0.25).abs() < 1e-12);
        // zero reference: huge but finite
        assert!(relative_error(0.0, 1.0).is_finite());
        assert!(relative_error(0.0, 1.0) > 1e11);
    }
}
