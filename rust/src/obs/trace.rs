//! Lock-cheap request tracing: sampled spans into a fixed-capacity ring.
//!
//! Design goals, in priority order:
//!
//! 1. **The off path costs one relaxed atomic load and a predictable
//!    branch.** Sampling defaults to *off* (`SAMPLE_EVERY == 0`); every
//!    instrumentation site first calls [`sampling`]/[`start_root`]/
//!    [`start_child`], which bail immediately without touching any lock.
//! 2. **Sampled requests are traced end to end.** The root span decides
//!    once (1-in-N on a global tick); children inherit the decision by
//!    carrying the parent's [`TraceCtx`] — there is no per-child coin
//!    flip, so a sampled request's full breakdown is always complete.
//! 3. **Completed spans land in a bounded ring** (capacity
//!    [`RING_CAPACITY`]) guarded by one mutex that is touched only for
//!    sampled spans; the ring overwrites oldest-first and never grows.
//!
//! Span identity: every span gets a process-unique `id`; `parent == 0`
//! marks a root; `trace` is the root span's id, shared by the whole tree,
//! so one request's lifecycle is reconstructable by filtering the ring on
//! a single `trace` value. Export to Chrome's `chrome://tracing` JSON is
//! done by the server/CLI (`{"op":"trace"}` / `pdpu trace`); this module
//! deliberately knows nothing about JSON.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Completed spans retained; the ring overwrites oldest-first beyond this.
pub const RING_CAPACITY: usize = 4096;

/// 0 = tracing off; N>0 = trace every Nth root request.
static SAMPLE_EVERY: AtomicU32 = AtomicU32::new(0);
/// Monotone root-request tick driving the 1-in-N decision.
static ROOT_TICK: AtomicU64 = AtomicU64::new(0);
/// Process-unique span id allocator (0 is reserved for "no parent").
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Set the sampling rate: `0` disables tracing, `n > 0` traces every
/// `n`th root request (children of a sampled root are always traced).
pub fn set_sampling(every: u32) {
    SAMPLE_EVERY.store(every, Ordering::Relaxed);
}

/// Current sampling rate (`0` = off). One relaxed load — this is the
/// branch the hot path predicts.
pub fn sampling() -> u32 {
    SAMPLE_EVERY.load(Ordering::Relaxed)
}

/// Identity a sampled span hands to its children: the root id of the
/// whole request tree plus the immediate parent span id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    /// Root span id shared by every span of one request.
    pub trace: u64,
    /// Immediate parent span id.
    pub span: u64,
}

/// A completed span as stored in the ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// Process-unique span id.
    pub id: u64,
    /// Parent span id; `0` for roots.
    pub parent: u64,
    /// Root span id of the request tree this span belongs to.
    pub trace: u64,
    /// Static span name (see the taxonomy in `docs/ARCHITECTURE.md`).
    pub name: &'static str,
    /// Start time in microseconds since the process clock epoch.
    pub start_us: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

/// An in-flight span; finish it with [`finish`] to record it.
#[derive(Debug)]
pub struct ActiveSpan {
    id: u64,
    parent: u64,
    trace: u64,
    name: &'static str,
    start: Instant,
    start_us: u64,
}

impl ActiveSpan {
    /// Context to hand to children so they parent under this span.
    pub fn ctx(&self) -> TraceCtx {
        TraceCtx { trace: self.trace, span: self.id }
    }
}

fn alloc_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Start a root span (one per request). Returns `None` — at the cost of
/// one relaxed load — when sampling is off, and for the N-1 of N requests
/// the sampler skips.
pub fn start_root(name: &'static str) -> Option<ActiveSpan> {
    let every = sampling();
    if every == 0 {
        return None;
    }
    if ROOT_TICK.fetch_add(1, Ordering::Relaxed) % u64::from(every) != 0 {
        return None;
    }
    let id = alloc_id();
    Some(ActiveSpan {
        id,
        parent: 0,
        trace: id,
        name,
        start: super::clock::now(),
        start_us: super::clock::epoch_us(),
    })
}

/// Start a child span under `ctx`. `None` in, `None` out: unsampled
/// requests carry no context, so their children cost nothing.
pub fn start_child(name: &'static str, ctx: Option<TraceCtx>) -> Option<ActiveSpan> {
    let ctx = ctx?;
    Some(ActiveSpan {
        id: alloc_id(),
        parent: ctx.span,
        trace: ctx.trace,
        name,
        start: super::clock::now(),
        start_us: super::clock::epoch_us(),
    })
}

/// Finish a span started by [`start_root`]/[`start_child`], pushing it
/// into the ring. `None` is a no-op, so call sites stay branch-free.
pub fn finish(span: Option<ActiveSpan>) {
    let Some(s) = span else { return };
    let dur_ns = super::clock::now().saturating_duration_since(s.start).as_nanos() as u64;
    push(Span { id: s.id, parent: s.parent, trace: s.trace, name: s.name, start_us: s.start_us, dur_ns });
}

/// Record a span that just ended with a known duration (used where the
/// start was observed elsewhere: batcher queue-wait, stage-bin deltas).
/// No-op without a context.
pub fn record_ending_now(name: &'static str, ctx: Option<TraceCtx>, dur_ns: u64) {
    let Some(c) = ctx else { return };
    let end_us = super::clock::epoch_us();
    push(Span {
        id: alloc_id(),
        parent: c.span,
        trace: c.trace,
        name,
        start_us: end_us.saturating_sub(dur_ns / 1_000),
        dur_ns,
    });
}

struct Ring {
    spans: Vec<Span>,
    next: usize,
}

static RING: Mutex<Ring> = Mutex::new(Ring { spans: Vec::new(), next: 0 });

fn ring_lock() -> std::sync::MutexGuard<'static, Ring> {
    RING.lock().unwrap_or_else(|e| e.into_inner())
}

fn push(span: Span) {
    let mut g = ring_lock();
    if g.spans.len() < RING_CAPACITY {
        g.spans.push(span);
    } else {
        let at = g.next % RING_CAPACITY;
        if let Some(slot) = g.spans.get_mut(at) {
            *slot = span;
        }
        g.next = (g.next + 1) % RING_CAPACITY;
    }
}

/// Snapshot of the ring, ordered by start time (ties broken by id).
pub fn events() -> Vec<Span> {
    let mut out = ring_lock().spans.clone();
    out.sort_by_key(|s| (s.start_us, s.id));
    out
}

/// Drop all recorded spans (sampling rate is left unchanged).
pub fn clear() {
    let mut g = ring_lock();
    g.spans.clear();
    g.next = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sampling rate and the ring are process-global; every test that
    // touches them serializes on this lock so `cargo test`'s parallel
    // runner can't interleave them.
    static GLOBALS: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        GLOBALS.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn sampling_off_records_nothing() {
        let _g = locked();
        set_sampling(0);
        clear();
        let root = start_root("infer");
        assert!(root.is_none());
        let child = start_child("queue_wait", root.as_ref().map(ActiveSpan::ctx));
        assert!(child.is_none());
        finish(child);
        finish(root);
        record_ending_now("queue_wait", None, 123);
        assert!(events().is_empty());
    }

    #[test]
    fn sampled_tree_shares_trace_id_and_parents_correctly() {
        let _g = locked();
        set_sampling(1);
        clear();
        let root = start_root("gemm").expect("1-in-1 sampling always samples");
        let rctx = root.ctx();
        let child = start_child("engine_launch", Some(rctx)).expect("child of sampled root");
        let cctx = child.ctx();
        record_ending_now("s2_multiply", Some(cctx), 500);
        finish(child);
        finish(root);
        set_sampling(0);

        let evs = events();
        assert_eq!(evs.len(), 3);
        let root_ev = evs.iter().find(|e| e.name == "gemm").expect("root span recorded");
        assert_eq!(root_ev.parent, 0);
        assert_eq!(root_ev.trace, root_ev.id);
        let launch = evs.iter().find(|e| e.name == "engine_launch").expect("child span recorded");
        assert_eq!(launch.parent, root_ev.id);
        assert_eq!(launch.trace, root_ev.id);
        let stage = evs.iter().find(|e| e.name == "s2_multiply").expect("leaf span recorded");
        assert_eq!(stage.parent, launch.id);
        assert_eq!(stage.trace, root_ev.id);
        assert_eq!(stage.dur_ns, 500);
    }

    #[test]
    fn one_in_n_sampling_traces_a_strict_subset() {
        let _g = locked();
        set_sampling(4);
        clear();
        let sampled = (0..16)
            .filter(|_| {
                let s = start_root("infer");
                let hit = s.is_some();
                finish(s);
                hit
            })
            .count();
        set_sampling(0);
        assert_eq!(sampled, 4, "1-in-4 over 16 roots");
    }

    #[test]
    fn ring_is_bounded() {
        let _g = locked();
        set_sampling(1);
        clear();
        for _ in 0..(RING_CAPACITY + 10) {
            finish(start_root("ping"));
        }
        set_sampling(0);
        assert_eq!(events().len(), RING_CAPACITY);
    }
}
