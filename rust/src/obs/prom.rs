//! Prometheus text exposition of the metrics snapshot, plus the minimal
//! parser the test suite round-trips it through.
//!
//! Rendering follows the text exposition format version 0.0.4: `# HELP` /
//! `# TYPE` per metric name, `name{labels} value` samples, histogram
//! buckets cumulative with a closing `le="+Inf"`. Metric names are
//! prefixed `pdpu_` and use base units in the name
//! (`…_microseconds`, `…_total`).

use std::fmt::Write as _;

use crate::coordinator::metrics::{HistoSnapshot, MetricsSnapshot, BUCKETS_US};

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn histogram_series(out: &mut String, name: &str, op: &str, h: &HistoSnapshot) {
    let mut cum = 0u64;
    for (i, bound) in BUCKETS_US.iter().enumerate() {
        cum += h.buckets.get(i).copied().unwrap_or(0);
        let _ = writeln!(out, "{name}_bucket{{op=\"{op}\",le=\"{bound}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{op=\"{op}\",le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum{{op=\"{op}\"}} {}", h.sum_us);
    let _ = writeln!(out, "{name}_count{{op=\"{op}\"}} {}", h.count);
}

/// Render a metrics snapshot as Prometheus text exposition.
pub fn render(s: &MetricsSnapshot) -> String {
    let mut out = String::new();
    counter(&mut out, "pdpu_requests_total", "Requests received over the wire.", s.requests);
    counter(&mut out, "pdpu_responses_total", "Successful replies sent.", s.responses);
    counter(&mut out, "pdpu_errors_total", "Error replies sent.", s.errors);
    counter(&mut out, "pdpu_batches_total", "Dynamic batches executed.", s.batches);
    counter(&mut out, "pdpu_macs_total", "Multiply-accumulate operations executed by the engine.", s.macs);
    counter(&mut out, "pdpu_gemm_requests_total", "GEMM requests received.", s.gemm_requests);
    counter(&mut out, "pdpu_fused_launches_total", "Engine launches after cross-request fusion.", s.fused_launches);
    counter(&mut out, "pdpu_fused_tiles_total", "GEMM tiles that rode a shared fused launch.", s.fused_tiles);
    counter(&mut out, "pdpu_train_steps_total", "SGD steps applied to the served model.", s.train_steps);
    counter(&mut out, "pdpu_train_examples_total", "Examples consumed by training steps.", s.train_examples);
    counter(&mut out, "pdpu_shed_requests_total", "Requests shed by admission control under overload.", s.shed_requests);
    counter(&mut out, "pdpu_accept_retries_total", "Transient accept() errors retried by the serving tier.", s.accept_retries);
    counter(&mut out, "pdpu_plane_cache_hits_total", "GEMM weight planes served from the cross-batch plane cache.", s.plane_cache.hits);
    counter(&mut out, "pdpu_plane_cache_misses_total", "GEMM weight planes quantized fresh on cache miss.", s.plane_cache.misses);
    counter(&mut out, "pdpu_plane_cache_evictions_total", "Plane-cache entries evicted by the deterministic LRU.", s.plane_cache.evictions);
    let _ = writeln!(out, "# HELP pdpu_plane_cache_entries Prepared operand planes resident in the cache.");
    let _ = writeln!(out, "# TYPE pdpu_plane_cache_entries gauge");
    let _ = writeln!(out, "pdpu_plane_cache_entries {}", s.plane_cache.entries);

    let name = "pdpu_request_latency_microseconds";
    let _ = writeln!(out, "# HELP {name} Request latency from enqueue to reply, per op.");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (op, h) in [("infer", &s.infer), ("gemm", &s.gemm), ("train", &s.train)] {
        histogram_series(&mut out, name, op, &h.latency);
    }

    let _ = writeln!(out, "# HELP pdpu_queue_depth Requests waiting in the batcher queue, per op.");
    let _ = writeln!(out, "# TYPE pdpu_queue_depth gauge");
    for (op, o) in [("infer", &s.infer), ("gemm", &s.gemm), ("train", &s.train)] {
        let _ = writeln!(out, "pdpu_queue_depth{{op=\"{op}\"}} {}", o.queue_depth);
    }
    let _ = writeln!(out, "# HELP pdpu_batch_wait_microseconds Oldest-item queue wait of the most recent batch, per op.");
    let _ = writeln!(out, "# TYPE pdpu_batch_wait_microseconds gauge");
    for (op, o) in [("infer", &s.infer), ("gemm", &s.gemm), ("train", &s.train)] {
        let _ = writeln!(out, "pdpu_batch_wait_microseconds{{op=\"{op}\"}} {}", o.last_batch_wait_us);
    }

    counter(
        &mut out,
        "pdpu_posit_quire_roundings_total",
        "Quire-to-posit conversions that rounded away from the exact value.",
        s.numerics.quire_roundings,
    );
    counter(&mut out, "pdpu_posit_sat_maxpos_total", "Posit outputs saturated to +/-maxpos.", s.numerics.sat_maxpos);
    counter(&mut out, "pdpu_posit_sat_minpos_total", "Posit outputs clamped at +/-minpos.", s.numerics.sat_minpos);
    counter(&mut out, "pdpu_posit_nar_total", "NaR posit outputs observed.", s.numerics.nar);

    render_sites(&mut out, &crate::obs::numerics::snapshot());
    out
}

/// Per-site numerics families: one sample per registry entry, labeled
/// `{site="infer:L0",cfg="P13-16es2_N4_Wm14"}` (the cfg label is the
/// comma-free [`crate::obs::numerics::cfg_metric_label`] form — this
/// parser splits label pairs on commas). Scale-range gauges and shadow
/// accuracy are emitted only for entries that have data, so absent
/// watermarks never render as fake zeros.
fn render_sites(out: &mut String, sites: &[crate::obs::numerics::SiteEntry]) {
    if sites.is_empty() {
        return;
    }
    type Pick = fn(&crate::obs::numerics::SiteStats) -> u64;
    let families: [(&str, &str, Pick); 8] = [
        ("pdpu_site_launches_total", "Engine launches attributed to the site.", |s| s.launches),
        ("pdpu_site_outputs_total", "Posit outputs produced at the site.", |s| s.outputs),
        ("pdpu_site_sat_maxpos_total", "Site outputs saturated to +/-maxpos.", |s| s.sat_maxpos),
        ("pdpu_site_sat_minpos_total", "Site outputs clamped at +/-minpos.", |s| s.sat_minpos),
        ("pdpu_site_nar_total", "NaR outputs at the site.", |s| s.nar),
        (
            "pdpu_site_quire_roundings_total",
            "Inexact quire-FMA updates attributed to the site.",
            |s| s.quire_roundings,
        ),
        ("pdpu_site_grad_sat_total", "Gradients quantized to +/-maxpos at the site.", |s| s.grad_sat),
        (
            "pdpu_site_grad_underflow_total",
            "Nonzero gradients clamped to +/-minpos at the site.",
            |s| s.grad_underflow,
        ),
    ];
    for (name, help, pick) in families {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for e in sites {
            let _ = writeln!(
                out,
                "{name}{{site=\"{}\",cfg=\"{}\"}} {}",
                e.site.label(),
                crate::obs::numerics::cfg_metric_label(&e.cfg),
                pick(&e.stats)
            );
        }
    }
    type PickOpt = fn(&crate::obs::numerics::SiteStats) -> Option<i32>;
    let gauges: [(&str, &str, PickOpt); 3] = [
        ("pdpu_site_scale_min", "Smallest decoded scale observed at the site.", |s| s.min_scale),
        ("pdpu_site_scale_max", "Largest decoded scale observed at the site.", |s| s.max_scale),
        (
            "pdpu_site_quire_watermark_log2",
            "Largest quire magnitude (log2) observed at the site.",
            |s| s.quire_watermark_log2,
        ),
    ];
    for (name, help, pick) in gauges {
        if !sites.iter().any(|e| pick(&e.stats).is_some()) {
            continue;
        }
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        for e in sites {
            if let Some(v) = pick(&e.stats) {
                let _ = writeln!(
                    out,
                    "{name}{{site=\"{}\",cfg=\"{}\"}} {v}",
                    e.site.label(),
                    crate::obs::numerics::cfg_metric_label(&e.cfg),
                );
            }
        }
    }
    let shadowed: Vec<_> = sites.iter().filter(|e| e.stats.shadow.samples() > 0).collect();
    if shadowed.is_empty() {
        return;
    }
    let name = "pdpu_site_shadow_samples_total";
    let _ = writeln!(out, "# HELP {name} FP64 shadow-executed outputs compared at the site.");
    let _ = writeln!(out, "# TYPE {name} counter");
    for e in &shadowed {
        let _ = writeln!(
            out,
            "{name}{{site=\"{}\",cfg=\"{}\"}} {}",
            e.site.label(),
            crate::obs::numerics::cfg_metric_label(&e.cfg),
            e.stats.shadow.samples()
        );
    }
    let name = "pdpu_site_shadow_decimal_accuracy";
    let _ = writeln!(out, "# HELP {name} Mean decimal accuracy of posit outputs vs the FP64 shadow.");
    let _ = writeln!(out, "# TYPE {name} gauge");
    for e in &shadowed {
        let _ = writeln!(
            out,
            "{name}{{site=\"{}\",cfg=\"{}\"}} {}",
            e.site.label(),
            crate::obs::numerics::cfg_metric_label(&e.cfg),
            e.stats.shadow.mean_decimal_accuracy()
        );
    }
}

/// One parsed sample line: `name{labels} value`.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Metric name (`[a-zA-Z0-9_:]+`).
    pub name: String,
    /// Label pairs in source order.
    pub labels: Vec<(String, String)>,
    /// Sample value.
    pub value: f64,
}

impl Sample {
    /// Value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Minimal Prometheus text-format parser: skips comments and blanks,
/// parses `name{k="v",…} value` lines, and rejects malformed names,
/// labels, or values. Enough to round-trip [`render`] in tests and smoke
/// jobs; not a full scrape-protocol implementation.
pub fn parse_exposition(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let ln = idx + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, val) = line.rsplit_once(' ').ok_or_else(|| format!("line {ln}: no value"))?;
        let value: f64 = val.trim().parse().map_err(|_| format!("line {ln}: bad value {val:?}"))?;
        let (name, labels) = match head.find('{') {
            Some(i) => {
                let (n, rest) = head.split_at(i);
                let inner = rest
                    .strip_prefix('{')
                    .and_then(|r| r.strip_suffix('}'))
                    .ok_or_else(|| format!("line {ln}: unbalanced label braces"))?;
                let mut labels = Vec::new();
                for pair in inner.split(',').filter(|p| !p.is_empty()) {
                    let (k, v) = pair.split_once('=').ok_or_else(|| format!("line {ln}: bad label pair {pair:?}"))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| format!("line {ln}: unquoted label value in {pair:?}"))?;
                    labels.push((k.to_string(), v.to_string()));
                }
                (n, labels)
            }
            None => (head, Vec::new()),
        };
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':') {
            return Err(format!("line {ln}: bad metric name {name:?}"));
        }
        out.push(Sample { name: name.to_string(), labels, value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use std::time::Duration;

    #[test]
    fn render_round_trips_through_parser() {
        let m = Metrics::default();
        m.requests.fetch_add(7, std::sync::atomic::Ordering::Relaxed);
        m.observe_latency(crate::coordinator::OpKind::Infer, Duration::from_micros(80));
        m.observe_latency(crate::coordinator::OpKind::Gemm, Duration::from_micros(800));
        let text = render(&m.snapshot());
        let samples = parse_exposition(&text).expect("renderer output parses");
        let req = samples.iter().find(|s| s.name == "pdpu_requests_total").expect("requests counter present");
        assert_eq!(req.value, 7.0);
        let inf = samples
            .iter()
            .find(|s| s.name == "pdpu_request_latency_microseconds_count" && s.label("op") == Some("infer"))
            .expect("infer histogram count present");
        assert_eq!(inf.value, 1.0);
        // cumulative buckets: the +Inf bucket equals the count
        let inf_inf = samples
            .iter()
            .find(|s| {
                s.name == "pdpu_request_latency_microseconds_bucket"
                    && s.label("op") == Some("infer")
                    && s.label("le") == Some("+Inf")
            })
            .expect("+Inf bucket present");
        assert_eq!(inf_inf.value, 1.0);
    }

    #[test]
    fn site_families_round_trip_with_parseable_labels() {
        use crate::obs::numerics::{record_update, Site, SiteGuard, SiteKind};
        let cfg = crate::pdpu::PdpuConfig::paper_default();
        {
            let _g = SiteGuard::enter(Site::new(SiteKind::SgdUpdate, 55)); // unique to this test
            record_update(&cfg, 2, 0, 0, Some(7));
        }
        let text = render(&Metrics::default().snapshot());
        let samples = parse_exposition(&text).expect("site families parse");
        let s = samples
            .iter()
            .find(|s| {
                s.name == "pdpu_site_quire_roundings_total" && s.label("site") == Some("sgd_update:L55")
            })
            .expect("site sample present");
        assert!(s.value >= 2.0);
        // the cfg label survives the comma-splitting parser intact
        assert_eq!(s.label("cfg"), Some("P13-16es2_N4_Wm14"));
        let wm = samples
            .iter()
            .find(|s| s.name == "pdpu_site_quire_watermark_log2" && s.label("site") == Some("sgd_update:L55"))
            .expect("watermark gauge present");
        assert_eq!(wm.value, 7.0);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_exposition("no_value_here").is_err());
        assert!(parse_exposition("bad name 1").is_err());
        assert!(parse_exposition("name{k=v} 1").is_err());
        assert!(parse_exposition("name{k=\"v\" 1").is_err());
        assert!(parse_exposition("name 1.5e3\n# comment\n\nother_total 2").is_ok());
    }
}
