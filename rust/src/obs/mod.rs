//! Observability: request tracing, per-stage profiling, posit numerics
//! counters, and Prometheus rendering.
//!
//! This module is the telemetry substrate for the serving stack — and,
//! by lint decree, the **only** place in the crate allowed to read the
//! wall clock (the `determinism` rule bans `Instant::now` everywhere
//! else, including the whole coordinator; see [`clock`]).
//!
//! * [`clock`] — the sanctioned monotonic clock + process epoch.
//! * [`trace`] — sampled request spans into a bounded ring buffer
//!   (`{"op":"trace"}` / `pdpu trace` export it as Chrome tracing JSON).
//! * [`stages`] — S1–S6 kernel-time bins fed by the engine's sampled
//!   profiled dot products.
//! * [`prom`] — Prometheus text exposition of the metrics snapshot
//!   (`{"op":"metrics"}` / `pdpu stats --prom`), plus a minimal parser
//!   used by the tests.
//! * [`numerics`](mod@numerics) — the per-site numerics observatory: scale histograms,
//!   saturation/NaR/quire tallies, and the precision advisor, keyed by
//!   op site × config (`{"op":"numerics"}` / `pdpu numerics`).
//! * [`shadow`] — 1-in-N sampled FP64 shadow execution of engine
//!   launches; primary outputs stay bit-identical.
//! * [`errstats`] — shared error/decimal-accuracy arithmetic used by the
//!   shadow executor and the `dnn` quantization experiments.
//!
//! This file additionally owns the **process-global posit numerics
//! counters** — always-on tallies of quire-rounding events, saturations
//! to ±maxpos/±minpos, and NaR encounters. They are fed from exactly one
//! sanctioned boundary per kind of work: engine launches record through
//! `numerics::record_launch` (called once per `BatchEngine::gemm_posit`
//! on the caller's thread), and SGD updates through
//! `numerics::record_update` — both of which tick these globals *and*
//! the site-attributed registry, so the two views can never drift. The
//! cost is one slice scan over outputs/operand lanes, tiny next to the
//! O(m·k·n) work that produced them.

pub mod clock;
pub mod errstats;
pub mod numerics;
pub mod prom;
pub mod shadow;
pub mod stages;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::posit::Posit;

static QUIRE_ROUNDINGS: AtomicU64 = AtomicU64::new(0);
static SAT_MAXPOS: AtomicU64 = AtomicU64::new(0);
static SAT_MINPOS: AtomicU64 = AtomicU64::new(0);
static NAR: AtomicU64 = AtomicU64::new(0);

/// Count `n` quire-rounding events: conversions where the single
/// quire→posit rounding changed the value versus the exact result
/// (recorded by the SGD update path).
pub fn add_quire_roundings(n: u64) {
    if n > 0 {
        QUIRE_ROUNDINGS.fetch_add(n, Ordering::Relaxed);
    }
}

/// Fold one launch's output classification into the process-global
/// counters. [`numerics::record_launch`] — the single sanctioned engine
/// boundary — calls this, so the globals and the per-site registry stay
/// consistent by construction.
pub(crate) fn add_output_tallies(maxpos: u64, minpos: u64, nar: u64) {
    if maxpos > 0 {
        SAT_MAXPOS.fetch_add(maxpos, Ordering::Relaxed);
    }
    if minpos > 0 {
        SAT_MINPOS.fetch_add(minpos, Ordering::Relaxed);
    }
    if nar > 0 {
        NAR.fetch_add(nar, Ordering::Relaxed);
    }
}

/// Scan a slice of posit outputs and count saturations to ±maxpos, hits
/// of ±minpos (the smallest representable magnitude — where
/// underflow-avoidance clamps land), and NaR values.
///
/// This is the reference classification; the live serving path records
/// through [`numerics::record_launch`] (which applies the same
/// classification *and* site attribution) rather than calling this
/// directly, so each output is tallied exactly once.
pub fn record_outputs(outs: &[Posit]) {
    let mut maxpos = 0u64;
    let mut minpos = 0u64;
    let mut nar = 0u64;
    for p in outs {
        if p.is_nar() {
            nar += 1;
            continue;
        }
        if p.is_zero() {
            continue;
        }
        let fmt = p.format();
        let bits = p.bits();
        let sign_bit = 1u32 << (fmt.n() - 1);
        let abs = if bits & sign_bit != 0 { bits.wrapping_neg() & fmt.mask() } else { bits };
        if abs == fmt.maxpos_bits() {
            maxpos += 1;
        } else if abs == fmt.minpos_bits() {
            minpos += 1;
        }
    }
    add_output_tallies(maxpos, minpos, nar);
}

/// Point-in-time view of the posit numerics counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NumericsSnapshot {
    /// Quire→posit conversions that rounded away from the exact value.
    pub quire_roundings: u64,
    /// Outputs saturated to ±maxpos.
    pub sat_maxpos: u64,
    /// Outputs landing on ±minpos (underflow clamp magnitude).
    pub sat_minpos: u64,
    /// NaR outputs observed.
    pub nar: u64,
}

/// Read the numerics counters.
pub fn numerics() -> NumericsSnapshot {
    NumericsSnapshot {
        quire_roundings: QUIRE_ROUNDINGS.load(Ordering::Relaxed),
        sat_maxpos: SAT_MAXPOS.load(Ordering::Relaxed),
        sat_minpos: SAT_MINPOS.load(Ordering::Relaxed),
        nar: NAR.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posit::PositFormat;

    #[test]
    fn record_outputs_classifies_saturation_and_nar() {
        let fmt = PositFormat::new(8, 2).expect("valid format");
        let before = numerics();
        let maxpos = Posit::from_bits(fmt.maxpos_bits(), fmt);
        let neg_maxpos = Posit::from_f64(-maxpos.to_f64(), fmt);
        let minpos = Posit::from_bits(fmt.minpos_bits(), fmt);
        let nar = Posit::nar(fmt);
        let ordinary = Posit::from_f64(1.0, fmt);
        let zero = Posit::from_f64(0.0, fmt);
        record_outputs(&[maxpos, neg_maxpos, minpos, nar, ordinary, zero]);
        let d = numerics();
        assert!(d.sat_maxpos >= before.sat_maxpos + 2);
        assert!(d.sat_minpos >= before.sat_minpos + 1);
        assert!(d.nar >= before.nar + 1);
    }

    #[test]
    fn quire_roundings_accumulate() {
        let before = numerics().quire_roundings;
        add_quire_roundings(0);
        add_quire_roundings(3);
        assert!(numerics().quire_roundings >= before + 3);
    }
}
