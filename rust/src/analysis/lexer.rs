//! A comment/string-aware Rust lexer for the `pdpu lint` pass.
//!
//! The offline image carries no `syn`/`proc-macro2`, so the analysis
//! tokenizes source text itself. The lexer is deliberately *not* a full
//! Rust grammar: the rules only need a token stream with line numbers
//! where comments and string/char literals can never masquerade as code
//! (so `"unwrap"` in a string or a doc comment never trips a rule), plus
//! three structural overlays recovered by brace matching:
//!
//! * **test regions** — token ranges under `#[test]` / `#[cfg(test)]`
//!   items, which every rule skips;
//! * **function spans** — `fn name … { body }` token ranges, so rules can
//!   scope to specific kernels;
//! * **pragmas** — `// pdpu-lint: …` directives (suppressions and the
//!   `hot-path` marker), collected with their line numbers.

/// Token classes the rules distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unwrap`, `fn`, `crate`, …).
    Ident,
    /// Numeric literal (possibly just the integer part of a float —
    /// `1.5` lexes as `1` `.` `5`, which is fine for every rule).
    Num,
    /// String literal. `text` holds the verbatim inner contents (used by
    /// the wire-op rule); identifier matching never looks at `Str`
    /// tokens, so string contents can never trip a code rule.
    Str,
    /// Char literal.
    Char,
    /// Lifetime (`'a`), kept distinct from char literals.
    Lifetime,
    /// Any single punctuation byte (`.`, `[`, `!`, `:`, …).
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Token {
    /// Is this the identifier `s`? (Full-token match: `unwrap_or_else`
    /// never matches `unwrap`.)
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.chars().next() == Some(c)
    }
}

/// A `// pdpu-lint: …` directive.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Pragma {
    /// `// pdpu-lint: allow(<rule>) — <reason>`: suppress `<rule>`
    /// diagnostics on this line and the next. The reason is mandatory.
    Allow { rule: String, reason: String },
    /// `// pdpu-lint: hot-path`: the next `fn` below this line is an
    /// allocation-free hot kernel; the alloc-freedom rule must check it.
    HotPath,
    /// Anything else after `pdpu-lint:` — reported as its own diagnostic
    /// so typoed suppressions fail loudly instead of silently not
    /// suppressing.
    Malformed(String),
}

/// A pragma plus the line it sits on.
#[derive(Clone, Debug)]
pub struct PragmaAt {
    pub line: usize,
    pub pragma: Pragma,
}

/// A `fn` item found in the token stream.
#[derive(Clone, Debug)]
pub struct FnSpan {
    /// Function name.
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: usize,
    /// Token-index range of the body including its braces, or `None` for
    /// body-less declarations.
    pub body: Option<(usize, usize)>,
}

/// One lexed source file plus the structural overlays the rules consume.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to `rust/src` (e.g. `coordinator/service.rs`).
    pub rel: String,
    pub tokens: Vec<Token>,
    /// `is_test[i]` — token `i` lies inside a `#[test]`/`#[cfg(test)]`
    /// item and is exempt from every rule.
    pub is_test: Vec<bool>,
    pub pragmas: Vec<PragmaAt>,
    pub fns: Vec<FnSpan>,
}

impl SourceFile {
    /// Lex `text` and recover the overlays.
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let (tokens, pragmas) = lex(text);
        let is_test = mark_test_regions(&tokens);
        let fns = find_fns(&tokens);
        SourceFile { rel: rel.to_string(), tokens, is_test, pragmas, fns }
    }

    /// Is there an `allow(<rule>)` pragma covering `line` (same line or
    /// the line directly above)?
    pub fn allows(&self, rule: &str, line: usize) -> bool {
        self.pragmas.iter().any(|p| match &p.pragma {
            Pragma::Allow { rule: r, .. } => r == rule && (p.line == line || p.line + 1 == line),
            _ => false,
        })
    }

    /// Token-index ranges of functions the `hot-path` marker applies to:
    /// for each marker, the first `fn` at or below the marker's line.
    pub fn hot_fn_bodies(&self) -> Vec<(String, usize, usize)> {
        let mut out = Vec::new();
        for p in &self.pragmas {
            if p.pragma != Pragma::HotPath {
                continue;
            }
            if let Some(f) = self.fns.iter().find(|f| f.line >= p.line) {
                if let Some((a, b)) = f.body {
                    out.push((f.name.clone(), a, b));
                }
            }
        }
        out
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex `text` into tokens + pragmas. Comments and literals are consumed
/// here so no rule ever sees their contents as code.
fn lex(text: &str) -> (Vec<Token>, Vec<PragmaAt>) {
    let b: Vec<char> = text.chars().collect();
    let mut tokens = Vec::new();
    let mut pragmas = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if b.get(i + 1) == Some(&'/') => {
                // line comment — scan to EOL, checking for a pragma
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != '\n' {
                    j += 1;
                }
                let body: String = b[start..j].iter().collect();
                if let Some(p) = parse_pragma(&body) {
                    pragmas.push(PragmaAt { line, pragma: p });
                }
                i = j;
            }
            '/' if b.get(i + 1) == Some(&'*') => {
                // block comment, nesting per Rust
                let mut depth = 1;
                let mut j = i + 2;
                while j < b.len() && depth > 0 {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                    } else if b[j] == '/' && b.get(j + 1) == Some(&'*') {
                        depth += 1;
                        j += 2;
                    } else if b[j] == '*' && b.get(j + 1) == Some(&'/') {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
            }
            '"' => {
                let (j, nl) = scan_string(&b, i);
                let inner: String = b[i + 1..j.saturating_sub(1).max(i + 1)].iter().collect();
                tokens.push(Token { kind: TokKind::Str, text: inner, line });
                line += nl;
                i = j;
            }
            'r' | 'b' if starts_raw_or_byte_string(&b, i) => {
                let (j, nl) = scan_raw_or_byte_string(&b, i);
                tokens.push(Token { kind: TokKind::Str, text: String::new(), line });
                line += nl;
                i = j;
            }
            '\'' => {
                // lifetime vs char literal: 'a (no closing quote soon)
                // vs 'x' / '\n'
                let next = b.get(i + 1).copied();
                let after = b.get(i + 2).copied();
                if next.map(is_ident_start) == Some(true) && after != Some('\'') {
                    let mut j = i + 1;
                    while j < b.len() && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    tokens.push(Token { kind: TokKind::Lifetime, text: b[i..j].iter().collect(), line });
                    i = j;
                } else {
                    // char literal: skip escape or single char, then `'`
                    let mut j = i + 1;
                    if b.get(j) == Some(&'\\') {
                        j += 2; // backslash + escaped char (u{…} handled below)
                        if b.get(j - 1) == Some(&'u') && b.get(j) == Some(&'{') {
                            while j < b.len() && b[j] != '}' {
                                j += 1;
                            }
                            j += 1;
                        }
                    } else {
                        j += 1;
                    }
                    while j < b.len() && b[j] != '\'' {
                        j += 1;
                    }
                    tokens.push(Token { kind: TokKind::Char, text: String::new(), line });
                    i = j + 1;
                }
            }
            c if is_ident_start(c) => {
                let mut j = i + 1;
                while j < b.len() && is_ident_cont(b[j]) {
                    j += 1;
                }
                tokens.push(Token { kind: TokKind::Ident, text: b[i..j].iter().collect(), line });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let mut j = i + 1;
                while j < b.len() && is_ident_cont(b[j]) {
                    j += 1;
                }
                tokens.push(Token { kind: TokKind::Num, text: b[i..j].iter().collect(), line });
                i = j;
            }
            c => {
                tokens.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
                i += 1;
            }
        }
    }
    (tokens, pragmas)
}

/// Does position `i` (at `r` or `b`) start a raw/byte string (`r"`,
/// `r#"`, `b"`, `br#"` …) rather than an identifier?
fn starts_raw_or_byte_string(b: &[char], i: usize) -> bool {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
    }
    if b.get(j) == Some(&'r') {
        j += 1;
    }
    if j == i {
        return false;
    }
    while b.get(j) == Some(&'#') {
        j += 1;
    }
    b.get(j) == Some(&'"')
}

/// Scan a normal string literal starting at the opening quote. Returns
/// (index past the closing quote, newlines consumed).
fn scan_string(b: &[char], i: usize) -> (usize, usize) {
    let mut j = i + 1;
    let mut nl = 0;
    while j < b.len() {
        match b[j] {
            '\\' => j += 2,
            '\n' => {
                nl += 1;
                j += 1;
            }
            '"' => return (j + 1, nl),
            _ => j += 1,
        }
    }
    (j, nl)
}

/// Scan a raw or byte string (`r#"…"#`, `b"…"`, `br##"…"##`). Returns
/// (index past the close, newlines consumed).
fn scan_raw_or_byte_string(b: &[char], i: usize) -> (usize, usize) {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
    }
    let raw = b.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    j += 1; // opening quote
    let mut nl = 0;
    while j < b.len() {
        match b[j] {
            '\n' => {
                nl += 1;
                j += 1;
            }
            '\\' if !raw => j += 2,
            '"' => {
                let mut k = 0;
                while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                    k += 1;
                }
                if k == hashes {
                    return (j + 1 + hashes, nl);
                }
                j += 1;
            }
            _ => j += 1,
        }
    }
    (j, nl)
}

/// Parse the body of a `//` comment into a pragma, if it is one.
fn parse_pragma(comment: &str) -> Option<Pragma> {
    let rest = comment.trim().strip_prefix("pdpu-lint:")?.trim();
    if rest == "hot-path" {
        return Some(Pragma::HotPath);
    }
    if let Some(inner) = rest.strip_prefix("allow(") {
        let Some(close) = inner.find(')') else {
            return Some(Pragma::Malformed("allow pragma missing ')'".to_string()));
        };
        let rule = inner[..close].trim().to_string();
        let reason = inner[close + 1..]
            .trim_start_matches(|c: char| c.is_whitespace() || matches!(c, '-' | '—' | '–' | ':'))
            .trim()
            .to_string();
        if reason.is_empty() {
            return Some(Pragma::Malformed(format!("allow({rule}) needs a reason: `allow({rule}) — why`")));
        }
        return Some(Pragma::Allow { rule, reason });
    }
    Some(Pragma::Malformed(format!("unknown pdpu-lint directive '{rest}'")))
}

/// Mark every token under a `#[test]` / `#[cfg(test)]` item. The item is
/// the attribute plus the following item, delimited by its matching outer
/// braces (or a `;` for brace-less items like `use`).
fn mark_test_regions(tokens: &[Token]) -> Vec<bool> {
    let mut marked = vec![false; tokens.len()];
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].is_punct('#') && tokens.get(i + 1).is_some_and(|t| t.is_punct('[')) {
            let attr_start = i;
            let Some(attr_end) = matching(tokens, i + 1, '[', ']') else {
                break;
            };
            if attr_is_test(&tokens[i + 2..attr_end]) {
                let mut j = attr_end + 1;
                // skip further attributes on the same item
                while tokens.get(j).is_some_and(|t| t.is_punct('#'))
                    && tokens.get(j + 1).is_some_and(|t| t.is_punct('['))
                {
                    match matching(tokens, j + 1, '[', ']') {
                        Some(e) => j = e + 1,
                        None => break,
                    }
                }
                // item body: first top-level `{` … matching `}`, or `;`
                let mut end = tokens.len().saturating_sub(1);
                let mut k = j;
                while k < tokens.len() {
                    if tokens[k].is_punct(';') {
                        end = k;
                        break;
                    }
                    if tokens[k].is_punct('{') {
                        end = matching(tokens, k, '{', '}').unwrap_or(tokens.len() - 1);
                        break;
                    }
                    k += 1;
                }
                for slot in marked.iter_mut().take(end + 1).skip(attr_start) {
                    *slot = true;
                }
                i = end + 1;
                continue;
            }
            i = attr_end + 1;
            continue;
        }
        i += 1;
    }
    marked
}

/// Is this attribute token slice `test`, or `cfg(… test …)` without a
/// `not`? (`#[cfg(not(test))]` guards *non*-test code.)
fn attr_is_test(attr: &[Token]) -> bool {
    let idents: Vec<&str> =
        attr.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
    if idents == ["test"] {
        return true;
    }
    idents.first() == Some(&"cfg") && idents.contains(&"test") && !idents.contains(&"not")
}

/// Index of the token closing the bracket opened at `open_idx`.
fn matching(tokens: &[Token], open_idx: usize, open: char, close: char) -> Option<usize> {
    let mut depth = 0usize;
    for (j, t) in tokens.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Find every `fn` item and its body span. `fn` pointer types (`fn(…)`)
/// are skipped because they have no name identifier after the keyword.
fn find_fns(tokens: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].is_ident("fn") && tokens.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident) {
            let name = tokens[i + 1].text.clone();
            let line = tokens[i].line;
            // body = first `{` before a top-level `;` (trait decls end at `;`)
            let mut body = None;
            let mut j = i + 2;
            while j < tokens.len() {
                if tokens[j].is_punct(';') {
                    break;
                }
                if tokens[j].is_punct('{') {
                    if let Some(e) = matching(tokens, j, '{', '}') {
                        body = Some((j, e));
                    }
                    break;
                }
                j += 1;
            }
            out.push(FnSpan { name, line, body });
            i = body.map_or(j + 1, |(_, e)| e + 1);
            continue;
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_hide_code() {
        let src = "fn f() { let s = \"a.unwrap()\"; /* .unwrap() */ // .unwrap()\n }";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.tokens.iter().any(|t| t.is_ident("unwrap")));
    }

    #[test]
    fn raw_strings_and_chars_lex() {
        let src = "fn g() { let r = r#\"panic!(\"x\")\"#; let c = '\\n'; let q = 'q'; }";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.tokens.iter().any(|t| t.is_ident("panic")));
        assert_eq!(f.tokens.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let f = SourceFile::parse("x.rs", "fn h<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(f.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).count(), 3);
        assert!(!f.tokens.iter().any(|t| t.kind == TokKind::Char));
    }

    #[test]
    fn line_numbers_track() {
        let f = SourceFile::parse("x.rs", "a\nb\n  c");
        let lines: Vec<usize> = f.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }

    #[test]
    fn test_regions_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }";
        let f = SourceFile::parse("x.rs", src);
        for (t, &m) in f.tokens.iter().zip(&f.is_test) {
            if t.is_ident("unwrap") {
                assert!(m, "unwrap inside #[cfg(test)] must be marked");
            }
            if t.is_ident("live") {
                assert!(!m);
            }
        }
    }

    #[test]
    fn cfg_not_test_is_live_code() {
        let f = SourceFile::parse("x.rs", "#[cfg(not(test))]\nfn live() { x.unwrap(); }");
        assert!(f.tokens.iter().zip(&f.is_test).all(|(_, &m)| !m));
    }

    #[test]
    fn fn_spans_found() {
        let src = "pub fn one() { a(); }\nfn two(x: usize) -> usize { x }\ntrait T { fn decl(&self); }";
        let f = SourceFile::parse("x.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["one", "two", "decl"]);
        assert!(f.fns[0].body.is_some());
        assert!(f.fns[2].body.is_none());
    }

    #[test]
    fn pragmas_parse() {
        let src = "// pdpu-lint: allow(panic-freedom) — test fixture needs it\n\
                   // pdpu-lint: hot-path\n\
                   // pdpu-lint: allow(determinism)\n\
                   // pdpu-lint: frobnicate\n";
        let f = SourceFile::parse("x.rs", src);
        assert_eq!(f.pragmas.len(), 4);
        assert!(matches!(&f.pragmas[0].pragma, Pragma::Allow { rule, reason }
            if rule == "panic-freedom" && reason == "test fixture needs it"));
        assert_eq!(f.pragmas[1].pragma, Pragma::HotPath);
        assert!(matches!(&f.pragmas[2].pragma, Pragma::Malformed(m) if m.contains("reason")));
        assert!(matches!(&f.pragmas[3].pragma, Pragma::Malformed(_)));
    }

    #[test]
    fn allow_covers_own_and_next_line() {
        let src = "// pdpu-lint: allow(panic-freedom) - covered below\nlet x = v.unwrap();\nlet y = 1;";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.allows("panic-freedom", 1));
        assert!(f.allows("panic-freedom", 2));
        assert!(!f.allows("panic-freedom", 3));
        assert!(!f.allows("determinism", 2));
    }

    #[test]
    fn hot_path_marks_next_fn() {
        let src = "fn cold() {}\n// pdpu-lint: hot-path\nfn hot(x: usize) -> usize { x + 1 }";
        let f = SourceFile::parse("x.rs", src);
        let hot = f.hot_fn_bodies();
        assert_eq!(hot.len(), 1);
        assert_eq!(hot[0].0, "hot");
    }
}
