//! R1 — serving-tier panic-freedom.
//!
//! The coordinator is the always-on layer: a panic on a request path
//! either kills a serving thread or poisons a lock, and a poisoned lock
//! turns *every later request* into an error (a one-request denial of
//! service). Non-test code under `coordinator/` therefore must not call
//! `.unwrap()` / `.expect(…)`, must not use the panicking macros, and
//! must not index with bare literal subscripts (`xs[0]`) — use
//! `first()` / `get()` / `last()` with a typed error instead, and recover
//! poisoned locks via [`crate::coordinator::lock_unpoisoned`].
//!
//! Deliberately out of scope: `assert!`/`debug_assert!` on internal
//! invariants (a failed invariant *should* be loud), identifier-indexed
//! slices already guarded by validation, and anything under
//! `#[cfg(test)]`.

use super::super::lexer::{SourceFile, TokKind};
use super::super::Diagnostic;

pub const RULE: &str = "panic-freedom";

/// R1 scans every non-test token of the serving tier.
pub fn applies(rel: &str) -> bool {
    rel.starts_with("coordinator/")
}

pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let toks = &file.tokens;
    for (i, t) in toks.iter().enumerate() {
        if file.is_test[i] {
            continue;
        }
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && i > 0
            && toks[i - 1].is_punct('.')
        {
            out.push(diag(
                file,
                t.line,
                format!(
                    ".{}() panics on the request path; return a typed error (poisoned locks: lock_unpoisoned)",
                    t.text
                ),
            ));
        }
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "panic" | "todo" | "unimplemented" | "unreachable")
            && toks.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            out.push(diag(file, t.line, format!("{}! aborts the serving thread; return an error", t.text)));
        }
        // literal subscript on an expression: `xs[0]`, `xs[g][1]` — the
        // canonical empty-input panic. Array literals (`[0; n]`) and
        // macro brackets (`vec![…]`) are excluded by the preceding-token
        // test; computed/range indices are out of scope by design.
        if t.is_punct('[')
            && toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Num)
            && toks.get(i + 2).is_some_and(|n| n.is_punct(']'))
            && i > 0
            && (toks[i - 1].kind == TokKind::Ident || toks[i - 1].is_punct(')') || toks[i - 1].is_punct(']'))
        {
            out.push(diag(
                file,
                t.line,
                format!("unchecked literal index [{}] panics when empty; use first()/get()/last()", toks[i + 1].text),
            ));
        }
    }
    out
}

fn diag(file: &SourceFile, line: usize, message: String) -> Diagnostic {
    Diagnostic { rule: RULE, file: format!("rust/src/{}", file.rel), line, message }
}
