//! The five `pdpu lint` rules. Each rule module exposes
//!
//! * `RULE` — its kebab-case identifier (the name `allow(…)` pragmas use);
//! * `applies(rel)` — whether the rule scans a given file (path relative
//!   to `rust/src`);
//! * `check(…)` — the scan itself, returning raw [`super::Diagnostic`]s
//!   (suppression is applied by the driver, not the rules).
//!
//! The mapping from rule to paper invariant is documented per module and
//! summarized in `docs/ARCHITECTURE.md`.

pub mod r1_panic_freedom;
pub mod r2_alloc_freedom;
pub mod r3_determinism;
pub mod r4_stage_isolation;
pub mod r5_wire_ops;
