//! R2 — hot-path allocation-freedom.
//!
//! The paper's datapath is combinational: nothing in S1–S6 allocates, and
//! the software model's whole batched-engine speedup rests on keeping it
//! that way (`DotScratch` reuse instead of per-call `Vec`s — and the
//! precondition for the ROADMAP SIMD refactor). This rule scans
//!
//! * every `*_into` stage kernel under `pdpu/stages/`, and
//! * every function annotated `// pdpu-lint: hot-path` (the engine's
//!   inner-loop kernels, e.g. `BatchEngine::dot_prepared`),
//!
//! and flags allocating calls: `vec![…]`, `Vec::new`/`with_capacity`,
//! `String::new`, `format!`, `.collect()`, `.to_vec()`, `.clone()`,
//! `.to_owned()`. Amortized-free operations on caller-owned buffers
//! (`clear`, `reserve`, `push`, `copy_from_slice`, `fill`) are allowed —
//! they are exactly the scratch-reuse idiom the rule protects.

use super::super::lexer::{SourceFile, TokKind};
use super::super::Diagnostic;

pub const RULE: &str = "alloc-freedom";

/// Hot-path markers can appear in any file; `*_into` kernels are scanned
/// under `pdpu/stages/` only.
pub fn applies(_rel: &str) -> bool {
    true
}

pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let mut spans: Vec<(String, usize, usize)> = file.hot_fn_bodies();
    if file.rel.starts_with("pdpu/stages/") {
        for f in &file.fns {
            if f.name.ends_with("_into") {
                if let Some((a, b)) = f.body {
                    spans.push((f.name.clone(), a, b));
                }
            }
        }
    }
    spans.sort_by_key(|s| s.1);
    spans.dedup_by_key(|s| s.1);

    let mut out = Vec::new();
    let toks = &file.tokens;
    for (name, a, b) in spans {
        for i in a..=b.min(toks.len().saturating_sub(1)) {
            if file.is_test[i] {
                continue;
            }
            let t = &toks[i];
            if t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "collect" | "to_vec" | "clone" | "to_owned")
                && i > 0
                && toks[i - 1].is_punct('.')
            {
                out.push(diag(file, t.line, format!(".{}() allocates inside hot kernel `{name}`", t.text)));
            }
            if t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "vec" | "format")
                && toks.get(i + 1).is_some_and(|n| n.is_punct('!'))
            {
                out.push(diag(file, t.line, format!("{}! allocates inside hot kernel `{name}`", t.text)));
            }
            if t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "Vec" | "String")
                && toks.get(i + 1).is_some_and(|n| n.is_punct(':'))
                && toks.get(i + 2).is_some_and(|n| n.is_punct(':'))
                && toks.get(i + 3).is_some_and(|n| {
                    n.is_ident("new") || n.is_ident("with_capacity") || n.is_ident("from")
                })
            {
                out.push(diag(
                    file,
                    t.line,
                    format!("{}::{} allocates inside hot kernel `{name}`", t.text, toks[i + 3].text),
                ));
            }
        }
    }
    out
}

fn diag(file: &SourceFile, line: usize, message: String) -> Diagnostic {
    Diagnostic { rule: RULE, file: format!("rust/src/{}", file.rel), line, message }
}
