//! R5 — wire-op exhaustiveness: code and protocol docs cannot drift.
//!
//! The TCP server's `handle_request` dispatch and the op table in
//! `docs/ARCHITECTURE.md` must list exactly the same operations, both
//! directions: an op served but undocumented is an API clients cannot
//! discover; an op documented but unserved is a doc lying about the
//! protocol. The served set is extracted from the `Some("…")` match arms
//! inside `handle_request`; the documented set from the markdown table
//! between the `<!-- wire-ops:begin -->` / `<!-- wire-ops:end -->`
//! markers.

use super::super::lexer::{SourceFile, TokKind};
use super::super::Diagnostic;

pub const RULE: &str = "wire-ops";

/// Markers delimiting the op table in the architecture doc.
pub const DOCS_BEGIN: &str = "<!-- wire-ops:begin -->";
pub const DOCS_END: &str = "<!-- wire-ops:end -->";

/// Ops matched in `handle_request`, with the line of each match arm.
pub fn served_ops(server: &SourceFile) -> Vec<(String, usize)> {
    let body = server
        .fns
        .iter()
        .find(|f| f.name == "handle_request")
        .and_then(|f| f.body);
    let Some((a, b)) = body else {
        return Vec::new();
    };
    let toks = &server.tokens;
    let mut out: Vec<(String, usize)> = Vec::new();
    for i in a..=b.min(toks.len().saturating_sub(1)) {
        if server.is_test[i] {
            continue;
        }
        if toks[i].is_ident("Some")
            && toks.get(i + 1).is_some_and(|n| n.is_punct('('))
            && toks.get(i + 2).is_some_and(|n| n.kind == TokKind::Str)
        {
            let op = toks[i + 2].text.clone();
            if !out.iter().any(|(o, _)| o == &op) {
                out.push((op, toks[i + 2].line));
            }
        }
    }
    out
}

/// Ops listed in the documentation table, with their doc line numbers.
/// `None` when the markers are missing.
pub fn documented_ops(docs: &str) -> Option<Vec<(String, usize)>> {
    let mut inside = false;
    let mut seen_begin = false;
    let mut out = Vec::new();
    for (ln, line) in docs.lines().enumerate() {
        let trimmed = line.trim();
        if trimmed == DOCS_BEGIN {
            inside = true;
            seen_begin = true;
            continue;
        }
        if trimmed == DOCS_END {
            inside = false;
            continue;
        }
        if !inside || !trimmed.starts_with('|') {
            continue;
        }
        let cell = trimmed.trim_start_matches('|').split('|').next().unwrap_or("").trim();
        let op = cell.trim_matches('`').trim();
        if op.is_empty() || op == "op" || op.chars().all(|c| matches!(c, '-' | ':' | ' ')) {
            continue; // header / separator rows
        }
        out.push((op.to_string(), ln + 1));
    }
    seen_begin.then_some(out)
}

/// Compare the two sets; every mismatch is a diagnostic.
pub fn check(server: &SourceFile, docs: &str, docs_rel: &str) -> Vec<Diagnostic> {
    let served = served_ops(server);
    let server_file = format!("rust/src/{}", server.rel);
    let Some(documented) = documented_ops(docs) else {
        return vec![Diagnostic {
            rule: RULE,
            file: docs_rel.to_string(),
            line: 1,
            message: format!("missing wire-op table markers `{DOCS_BEGIN}` / `{DOCS_END}`"),
        }];
    };
    let mut out = Vec::new();
    for (op, line) in &served {
        if !documented.iter().any(|(d, _)| d == op) {
            out.push(Diagnostic {
                rule: RULE,
                file: server_file.clone(),
                line: *line,
                message: format!("wire op '{op}' is served but missing from the {docs_rel} op table"),
            });
        }
    }
    for (op, line) in &documented {
        if !served.iter().any(|(s, _)| s == op) {
            out.push(Diagnostic {
                rule: RULE,
                file: docs_rel.to_string(),
                line: *line,
                message: format!("wire op '{op}' is documented but not matched in handle_request"),
            });
        }
    }
    out
}
