//! R4 — stage isolation: the S1→S6 dataflow is one-directional.
//!
//! In the RTL each pipeline stage reads only its predecessor's register
//! and the generator parameters; there is no back-edge and no skip-ahead.
//! The software stages mirror that: `pdpu/stages/sN_*` may reference
//! earlier stages (`sM_*` with `M ≤ N`), the configuration
//! (`crate::pdpu::{config, PdpuConfig}`), and the posit layer
//! (`crate::posit`) — nothing else. A stage reaching *forward* (S3 using
//! an S5 record) or *outward* (a stage importing the engine or the
//! coordinator) breaks the property that makes the per-stage cost model
//! and the cycle-level pipeline model attach to real boundaries.

use super::super::lexer::{SourceFile, TokKind};
use super::super::Diagnostic;

pub const RULE: &str = "stage-isolation";

/// Stage-numbered files only (`pdpu/stages/s<N>_…`), not the stage index.
pub fn applies(rel: &str) -> bool {
    stage_number(rel).is_some()
}

/// The stage number encoded in a path like `pdpu/stages/s3_align.rs`.
fn stage_number(rel: &str) -> Option<u32> {
    let name = rel.strip_prefix("pdpu/stages/s")?;
    let digits: String = name.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || !name[digits.len()..].starts_with('_') {
        return None;
    }
    digits.parse().ok()
}

/// A `sM_…` identifier's stage number, if it is one.
fn ident_stage(text: &str) -> Option<u32> {
    let rest = text.strip_prefix('s')?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || !rest[digits.len()..].starts_with('_') {
        return None;
    }
    digits.parse().ok()
}

pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let Some(own) = stage_number(&file.rel) else {
        return Vec::new();
    };
    let toks = &file.tokens;
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if file.is_test[i] || t.kind != TokKind::Ident {
            continue;
        }
        if let Some(m) = ident_stage(&t.text) {
            if m > own {
                out.push(diag(
                    file,
                    t.line,
                    format!("stage S{own} references later stage `{}` — dataflow is S1→S6 only", t.text),
                ));
            }
        }
        // absolute paths: only `crate::posit` and the config side of
        // `crate::pdpu` are legal from inside a stage
        if t.is_ident("crate")
            && toks.get(i + 1).is_some_and(|n| n.is_punct(':'))
            && toks.get(i + 2).is_some_and(|n| n.is_punct(':'))
        {
            if let Some(seg) = toks.get(i + 3) {
                match seg.text.as_str() {
                    "posit" => {}
                    "pdpu" => {
                        let sub = toks
                            .get(i + 4)
                            .zip(toks.get(i + 5))
                            .filter(|(a, b)| a.is_punct(':') && b.is_punct(':'))
                            .and_then(|_| toks.get(i + 6));
                        if let Some(sub) = sub {
                            if !matches!(sub.text.as_str(), "config" | "PdpuConfig" | "ConfigError" | "stages") {
                                out.push(diag(
                                    file,
                                    seg.line,
                                    format!(
                                        "stage S{own} reaches outside the stage dataflow: crate::pdpu::{}",
                                        sub.text
                                    ),
                                ));
                            }
                        }
                    }
                    other => out.push(diag(
                        file,
                        seg.line,
                        format!("stage S{own} depends on `crate::{other}` — stages see only earlier stages + config"),
                    )),
                }
            }
        }
        // `super::super::…` escapes the stage directory entirely
        if t.is_ident("super")
            && toks.get(i + 1).is_some_and(|n| n.is_punct(':'))
            && toks.get(i + 2).is_some_and(|n| n.is_punct(':'))
            && toks.get(i + 3).is_some_and(|n| n.is_ident("super"))
        {
            out.push(diag(file, t.line, format!("stage S{own} escapes pdpu/stages via super::super")));
        }
    }
    out
}

fn diag(file: &SourceFile, line: usize, message: String) -> Diagnostic {
    Diagnostic { rule: RULE, file: format!("rust/src/{}", file.rel), line, message }
}
