//! R3 — determinism of result-affecting code, plus clock discipline.
//!
//! Quire-exact reproducibility is the differentiator posit serving claims
//! over IEEE floats: the same request must produce the same bits on every
//! run, machine, and thread count. Two things silently break that:
//!
//! * iterating a `HashMap`/`HashSet` (randomized iteration order since
//!   `RandomState` is seeded per-process) in code whose *output* depends
//!   on the order — e.g. fusion planning;
//! * reading time or entropy (`Instant::now`, `SystemTime::now`,
//!   `thread_rng`, …) inside a computation.
//!
//! Two nested scopes:
//!
//! * **Hash scope** — the numeric stack (`posit/`, `pdpu/`, `engine.rs`,
//!   `train/`, `dnn/`) and the one result-affecting coordinator module,
//!   `coordinator/fusion.rs`. Both the hash-iteration and the
//!   clock/entropy diagnostics fire here. Keyed *lookups*
//!   (`get`/`entry`/`insert`) are order-free and allowed; only iteration
//!   over the map is flagged.
//! * **Clock scope** — hash scope plus all of `coordinator/`: serving
//!   telemetry needs wall time, but every read must go through the one
//!   sanctioned site, [`crate::obs::clock`] (`obs/` is the only module
//!   allowed to call `Instant::now` directly). Routing every clock read
//!   through one module keeps latency spans and stage timings on a single
//!   monotonic anchor and makes "where does time come from" greppable.
//!   Only the clock/entropy diagnostics fire in the coordinator part of
//!   this scope; batcher/metrics hash lookups stay unflagged.

use super::super::lexer::{SourceFile, TokKind, Token};
use super::super::Diagnostic;

pub const RULE: &str = "determinism";

/// Result-affecting files: the arithmetic stack plus fusion planning.
/// Hash-iteration *and* clock diagnostics both apply here.
pub fn hash_scope(rel: &str) -> bool {
    rel.starts_with("posit/")
        || rel.starts_with("pdpu/")
        || rel.starts_with("train/")
        || rel.starts_with("dnn/")
        || rel == "engine.rs"
        || rel == "coordinator/fusion.rs"
}

/// Files whose direct `Instant::now`/`SystemTime::now`/entropy reads are
/// flagged: the hash scope plus the whole coordinator — except `obs/`,
/// the one module sanctioned to read the clock (everything else calls
/// `crate::obs::clock::now()`).
pub fn clock_scope(rel: &str) -> bool {
    !rel.starts_with("obs/") && (hash_scope(rel) || rel.starts_with("coordinator/"))
}

pub fn applies(rel: &str) -> bool {
    hash_scope(rel) || clock_scope(rel)
}

/// Methods whose call on a hash container walks it in randomized order.
const ITER_METHODS: [&str; 8] = ["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain", "retain"];

pub fn check(file: &SourceFile) -> Vec<Diagnostic> {
    let in_hash_scope = hash_scope(&file.rel);
    let in_clock_scope = clock_scope(&file.rel);
    let toks = &file.tokens;
    let names = if in_hash_scope { hash_bound_names(file) } else { Vec::new() };
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if file.is_test[i] {
            continue;
        }
        // unordered iteration over a known hash container (hash scope)
        if in_hash_scope && t.kind == TokKind::Ident && names.iter().any(|n| n == &t.text) {
            if let Some(m) = toks.get(i + 2) {
                if toks[i + 1].is_punct('.') && ITER_METHODS.iter().any(|im| m.is_ident(im)) {
                    out.push(diag(
                        file,
                        t.line,
                        format!("`{}.{}()` iterates a HashMap in randomized order; sort keys first", t.text, m.text),
                    ));
                }
            }
        }
        if in_hash_scope && t.is_ident("in") {
            let mut j = i + 1;
            while toks.get(j).is_some_and(|n| n.is_punct('&') || n.is_ident("mut")) {
                j += 1;
            }
            if let Some(n) = toks.get(j) {
                if n.kind == TokKind::Ident
                    && names.iter().any(|b| b == &n.text)
                    && !toks.get(j + 1).is_some_and(|p| p.is_punct('.'))
                {
                    out.push(diag(
                        file,
                        n.line,
                        format!("`for … in {}` iterates a HashMap in randomized order; sort keys first", n.text),
                    ));
                }
            }
        }
        // wall-clock and entropy sources (clock scope)
        if in_clock_scope
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "Instant" | "SystemTime")
            && toks.get(i + 1).is_some_and(|n| n.is_punct(':'))
            && toks.get(i + 2).is_some_and(|n| n.is_punct(':'))
            && toks.get(i + 3).is_some_and(|n| n.is_ident("now"))
        {
            out.push(diag(
                file,
                t.line,
                format!("{}::now() outside obs/ — route clock reads through crate::obs::clock", t.text),
            ));
        }
        if in_clock_scope
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "thread_rng" | "from_entropy" | "random")
        {
            out.push(diag(file, t.line, format!("`{}` injects entropy into a result-affecting path", t.text)));
        }
    }
    out
}

/// Identifiers bound to a `HashMap`/`HashSet` in non-test code: either a
/// `let [mut] name … HashMap …;` statement or a `name: [&mut] HashMap`
/// type ascription (fn params, struct fields in scope).
fn hash_bound_names(file: &SourceFile) -> Vec<String> {
    let toks = &file.tokens;
    let mut names = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if file.is_test[i] {
            continue;
        }
        if t.is_ident("let") {
            let mut j = i + 1;
            if toks.get(j).is_some_and(|n| n.is_ident("mut")) {
                j += 1;
            }
            let Some(name) = toks.get(j).filter(|n| n.kind == TokKind::Ident) else {
                continue;
            };
            let mut k = j + 1;
            while let Some(n) = toks.get(k) {
                if n.is_punct(';') {
                    break;
                }
                if is_hash_container(n) {
                    names.push(name.text.clone());
                    break;
                }
                k += 1;
            }
        }
        if t.kind == TokKind::Ident && toks.get(i + 1).is_some_and(|n| n.is_punct(':')) {
            let mut j = i + 2;
            while toks.get(j).is_some_and(|n| n.is_punct('&') || n.is_ident("mut")) {
                j += 1;
            }
            if toks.get(j).is_some_and(is_hash_container) {
                names.push(t.text.clone());
            }
        }
    }
    names
}

fn is_hash_container(t: &Token) -> bool {
    t.is_ident("HashMap") || t.is_ident("HashSet")
}

fn diag(file: &SourceFile, line: usize, message: String) -> Diagnostic {
    Diagnostic { rule: RULE, file: format!("rust/src/{}", file.rel), line, message }
}
