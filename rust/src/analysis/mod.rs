//! `pdpu lint` — a domain-specific static-analysis pass over the crate's
//! own sources.
//!
//! The PDPU paper's value is structural: a fused pipeline whose
//! correctness and efficiency come from invariants (stages feed forward
//! only, the hot path is allocation-free, accumulation is exactly
//! reproducible, the serving tier never panics). The test suite proves
//! those properties hold *today*; this pass keeps future changes from
//! quietly un-proving them. Five rules (see [`rules`]):
//!
//! | rule | invariant |
//! |---|---|
//! | `panic-freedom`   | coordinator request paths return errors, never panic |
//! | `alloc-freedom`   | `*_into` stage kernels and `hot-path` fns don't allocate |
//! | `determinism`     | result-affecting code: no unordered-map iteration; clocks/entropy only via `obs/` |
//! | `stage-isolation` | `pdpu/stages/sN_*` depends only on earlier stages + config |
//! | `wire-ops`        | server match arms ≡ the `docs/ARCHITECTURE.md` op table |
//!
//! Implementation constraint: the offline image has no `syn`, so the pass
//! runs on a comment/string-aware token stream ([`lexer`]) rather than an
//! AST — rules are narrow, syntactic, and documented per module so their
//! (deliberate) blind spots are explicit.
//!
//! A violation is suppressed only by an inline pragma on its own line or
//! the line above, and the reason is mandatory:
//!
//! ```text
//! // pdpu-lint: allow(panic-freedom) — seeded at startup, cannot be empty
//! ```
//!
//! Entry points: [`run_lint`] (the whole tree — used by the `pdpu lint`
//! CLI, the `lint_clean` tier-1 test, and CI), [`lint_source`] (one file
//! from a string — used by the fixture tests), and
//! [`rules::r5_wire_ops::check`] (the cross-file wire-op rule).

pub mod lexer;
pub mod rules;

use lexer::{Pragma, SourceFile};
use std::path::Path;

/// One rule violation (or pragma problem) at a source location.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Rule identifier (`panic-freedom`, …, or `pragma`).
    pub rule: &'static str,
    /// Repo-relative path (`rust/src/…` or `docs/…`).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// The five rule identifiers an `allow(…)` pragma may name.
pub const RULE_IDS: [&str; 5] = [
    rules::r1_panic_freedom::RULE,
    rules::r2_alloc_freedom::RULE,
    rules::r3_determinism::RULE,
    rules::r4_stage_isolation::RULE,
    rules::r5_wire_ops::RULE,
];

/// Lint one source file given as text. `rel` is the path relative to
/// `rust/src` and drives rule scoping (e.g. `coordinator/x.rs` gets the
/// panic-freedom rule). Suppression pragmas are applied; pragma problems
/// (missing reason, unknown rule) are themselves diagnostics.
pub fn lint_source(rel: &str, text: &str) -> Vec<Diagnostic> {
    let file = SourceFile::parse(rel, text);
    file_diags(&file)
}

fn file_diags(file: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    // pragma hygiene first — these are never suppressible
    for p in &file.pragmas {
        match &p.pragma {
            Pragma::Malformed(msg) => out.push(Diagnostic {
                rule: "pragma",
                file: format!("rust/src/{}", file.rel),
                line: p.line,
                message: msg.clone(),
            }),
            Pragma::Allow { rule, .. } if !RULE_IDS.contains(&rule.as_str()) => out.push(Diagnostic {
                rule: "pragma",
                file: format!("rust/src/{}", file.rel),
                line: p.line,
                message: format!("allow({rule}) names no rule; known rules: {}", RULE_IDS.join(", ")),
            }),
            _ => {}
        }
    }
    let mut findings = Vec::new();
    if rules::r1_panic_freedom::applies(&file.rel) {
        findings.extend(rules::r1_panic_freedom::check(file));
    }
    if rules::r2_alloc_freedom::applies(&file.rel) {
        findings.extend(rules::r2_alloc_freedom::check(file));
    }
    if rules::r3_determinism::applies(&file.rel) {
        findings.extend(rules::r3_determinism::check(file));
    }
    if rules::r4_stage_isolation::applies(&file.rel) {
        findings.extend(rules::r4_stage_isolation::check(file));
    }
    out.extend(findings.into_iter().filter(|d| !file.allows(d.rule, d.line)));
    out
}

/// Run every rule over `repo_root/rust/src` (plus the wire-op doc check
/// against `repo_root/docs/ARCHITECTURE.md`). Returns all unsuppressed
/// diagnostics, sorted by file and line; `Err` only for I/O problems.
pub fn run_lint(repo_root: &Path) -> Result<Vec<Diagnostic>, String> {
    let src_root = repo_root.join("rust").join("src");
    let mut paths = Vec::new();
    collect_rs(&src_root, &mut paths)?;
    let mut out = Vec::new();
    let mut server: Option<SourceFile> = None;
    for path in &paths {
        let rel = path
            .strip_prefix(&src_root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        let parsed = SourceFile::parse(&rel, &text);
        out.extend(file_diags(&parsed));
        if rel == "coordinator/server.rs" {
            server = Some(parsed);
        }
    }
    let docs_path = repo_root.join("docs").join("ARCHITECTURE.md");
    match server {
        Some(s) => {
            let docs = std::fs::read_to_string(&docs_path)
                .map_err(|e| format!("reading {}: {e}", docs_path.display()))?;
            let wire = rules::r5_wire_ops::check(&s, &docs, "docs/ARCHITECTURE.md");
            out.extend(wire.into_iter().filter(|d| !s.allows(d.rule, d.line) || d.file.starts_with("docs/")));
        }
        None => out.push(Diagnostic {
            rule: rules::r5_wire_ops::RULE,
            file: "rust/src/coordinator/server.rs".to_string(),
            line: 1,
            message: "server source not found under rust/src".to_string(),
        }),
    }
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(out)
}

/// Recursively collect `.rs` files, sorted for deterministic output.
fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| format!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_has_no_diags() {
        let src = "pub fn ok(v: &[u64]) -> Option<u64> { v.first().copied() }";
        assert!(lint_source("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_needs_matching_rule_and_reason() {
        let bad = "fn f(v: Vec<u64>) -> u64 { v.first().copied().unwrap() }";
        assert_eq!(lint_source("coordinator/x.rs", bad).len(), 1);
        let allowed = "// pdpu-lint: allow(panic-freedom) — fixture proves suppression works\n\
                       fn f(v: Vec<u64>) -> u64 { v.first().copied().unwrap() }";
        assert!(lint_source("coordinator/x.rs", allowed).is_empty());
        let wrong_rule = "// pdpu-lint: allow(determinism) — wrong rule, must not suppress\n\
                          fn f(v: Vec<u64>) -> u64 { v.first().copied().unwrap() }";
        assert_eq!(lint_source("coordinator/x.rs", wrong_rule).len(), 1);
    }

    #[test]
    fn unknown_rule_in_pragma_is_reported() {
        let src = "// pdpu-lint: allow(no-such-rule) — typo\nfn f() {}";
        let diags = lint_source("coordinator/x.rs", src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "pragma");
    }

    #[test]
    fn rules_do_not_fire_outside_their_scope() {
        // literal indexing outside coordinator/ is R1-out-of-scope
        let src = "fn f(v: Vec<u64>) -> u64 { v.iter().sum::<u64>() + v[0] }";
        assert!(lint_source("experiments/x.rs", src).is_empty());
    }

    #[test]
    fn diagnostics_render_with_location() {
        let d = Diagnostic { rule: "panic-freedom", file: "rust/src/x.rs".into(), line: 7, message: "m".into() };
        assert_eq!(d.to_string(), "rust/src/x.rs:7: [panic-freedom] m");
    }
}
