//! Offline stub of the `xla` PJRT bindings.
//!
//! The build image has neither the `xla` crate nor a PJRT plugin, so this
//! vendored crate mirrors the API surface `crate::runtime` compiles
//! against and makes every runtime entry point fail fast with a clear
//! error. [`PjRtClient::cpu`] is the first call on every load path, so no
//! stubbed executable ever actually runs: callers degrade exactly as they
//! do on a checkout where `make artifacts` has not been run, and the
//! coordinator falls back to its software (bit-exact functional model)
//! backend.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` for the subset of the API we stub.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA is unavailable in this offline build (vendored stub; \
         the coordinator's software backend serves instead)"
    ))
}

/// Stub of the PJRT CPU client. Construction always fails in this build.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation graph.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a host literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("offline"), "{msg}");
    }
}
