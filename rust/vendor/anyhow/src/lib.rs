//! Offline stand-in for the `anyhow` crate.
//!
//! The build image carries no crates.io registry, so this vendored crate
//! provides exactly the subset of anyhow's API the repo uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result` and `Option`,
//! and the `anyhow!` / `ensure!` / `bail!` macros. Error values carry a
//! message chain (context frames joined with ": "), matching how the real
//! crate renders `{:#}`.

use std::fmt;

/// A string-backed error value. Unlike `std` error types it deliberately
/// does **not** implement `std::error::Error`, which is what makes the
/// blanket `From<E: std::error::Error>` conversion below coherent — the
/// same design the real anyhow uses.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }

    /// Prepend a context frame, anyhow-style (`context: cause`).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and `{:#}` both print the full chain in this stand-in.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — plain `Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_even(s: &str) -> Result<u64> {
        let v: u64 = s.parse().context("not a number")?;
        ensure!(v % 2 == 0, "{v} is odd");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_even("4").unwrap(), 4);
        let e = parse_even("x").unwrap_err();
        assert!(e.to_string().starts_with("not a number"), "{e}");
        let e = parse_even("3").unwrap_err();
        assert_eq!(e.to_string(), "3 is odd");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        let v = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain literal");
        assert_eq!(a.to_string(), "plain literal");
        let who = "engine";
        let b = anyhow!("{who} died");
        assert_eq!(b.to_string(), "engine died");
        let c = anyhow!("{} + {}", 1, 2);
        assert_eq!(c.to_string(), "1 + 2");
        let msg = String::from("passed through");
        let d = anyhow!(msg);
        assert_eq!(d.to_string(), "passed through");
    }

    #[test]
    fn alternate_format_prints_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }
}
