//! Bench F6 — Fig. 6 pipeline: regenerate the per-stage breakdown, then
//! measure the cycle-level pipeline/scheduler models (the coordinator's
//! planning hot path) — ticks/s and scheduled MAC-chunks/s.
//!
//! Run: `cargo bench --bench bench_fig6`

use std::time::Duration;

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::coordinator::{conv_jobs, schedule};
use pdpu::cost::Tech;
use pdpu::experiments::fig6;
use pdpu::pdpu::pipeline::Pipeline;

fn main() {
    println!("== Fig. 6: 6-stage pipeline breakdown (cost model) ==\n");
    let entries = fig6::build(&[4, 8, 16], &Tech::default());
    print!("{}", fig6::render(&entries));

    println!("\n== cycle-level model throughput (coordinator planning hot path) ==\n");
    report_header();

    let m = bench("pipeline tick (full, independent ops)", Duration::from_millis(300), || {
        let mut p = Pipeline::new();
        for i in 0..1_000u64 {
            std::hint::black_box(p.tick(Some((i, None))));
        }
        p.stats().retired
    });
    report(&m);
    println!("  -> {:.1} M ticks/s\n", m.per_second(1_000.0) / 1e6);

    let jobs = conv_jobs(256, 147);
    let m = bench("schedule 256 conv outputs on 4 units", Duration::from_millis(400), || {
        std::hint::black_box(schedule(&jobs, 4, 4, 6))
    });
    report(&m);
    let r = schedule(&jobs, 4, 4, 6);
    println!(
        "  -> models {} cycles ({:.1}% util) per call; {:.1} M modeled-cycles/s",
        r.cycles,
        100.0 * r.utilization,
        m.per_second(r.cycles as f64) / 1e6
    );

    // sweep: utilization vs interleave depth (the Fig. 6 hazard story)
    println!("\ninterleave depth vs utilization (N=4, 64 outputs, 1 unit):");
    for il in [1usize, 2, 3, 4, 6, 8] {
        let r = schedule(&conv_jobs(64, 147), 1, 4, il);
        println!("  interleave {:<2} -> {:>5.1}% utilization, {} cycles", il, 100.0 * r.utilization, r.cycles);
    }
}
