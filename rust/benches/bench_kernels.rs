//! Bench K — posit arithmetic primitives: the hot path of every
//! bit-exact simulation in the repo (accuracy experiments, baselines,
//! property tests). Decode/encode/add/mul/fma/quire/PDPU-dot ns/op.
//!
//! Run: `cargo bench --bench bench_kernels`

use std::time::Duration;

use pdpu::baselines::{DotArch, PdpuArch};
use pdpu::bench_harness::{bench, report, report_header, Measurement};
use pdpu::coordinator::json::Json;
use pdpu::dnn::dataset::conv1_workload;
use pdpu::dnn::layers::conv2d;
use pdpu::dnn::tensor::im2col_patch;
use pdpu::engine::BatchEngine;
use pdpu::pdpu::{DotScratch, Pdpu, PdpuConfig};
use pdpu::posit::{decode, p_add, p_fma, p_mul, quire::Quire, Posit, PositFormat};
use pdpu::testing::Rng;

fn main() {
    let fmt = PositFormat::p(16, 2);
    let mut rng = Rng::seeded(0xBE7C);
    let vals: Vec<Posit> = (0..1024)
        .map(|_| loop {
            let p = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, fmt);
            if !p.is_nar() {
                break p;
            }
        })
        .collect();

    println!("== posit primitive throughput (P(16,2), batches of 1024) ==\n");
    report_header();

    let m = bench("decode", Duration::from_millis(200), || {
        let mut acc = 0u64;
        for p in &vals {
            acc ^= match decode(*p) {
                pdpu::posit::Decoded::Finite(f) => f.frac,
                _ => 0,
            };
        }
        acc
    });
    report(&m);
    println!("  -> {:.1} M decodes/s", m.per_second(1024.0) / 1e6);

    let m = bench("from_f64 (encode path)", Duration::from_millis(200), || {
        let mut acc = 0u32;
        for (i, p) in vals.iter().enumerate() {
            acc ^= Posit::from_f64(p.to_f64() * (1.0 + i as f64 * 1e-6), fmt).bits();
        }
        acc
    });
    report(&m);
    println!("  -> {:.1} M encodes/s", m.per_second(1024.0) / 1e6);

    type Op = fn(Posit, Posit, PositFormat) -> Posit;
    let ops: [(&str, Op); 3] = [
        ("p_add", |a, b, f| p_add(a, b, f)),
        ("p_mul", |a, b, f| p_mul(a, b, f)),
        ("p_fma (c = a)", |a, b, f| p_fma(a, b, a, f)),
    ];
    for (name, f) in ops {
        let m = bench(name, Duration::from_millis(200), || {
            let mut acc = 0u32;
            for w in vals.windows(2) {
                acc ^= f(w[0], w[1], fmt).bits();
            }
            acc
        });
        report(&m);
        println!("  -> {:.1} M ops/s", m.per_second(1023.0) / 1e6);
    }

    let m = bench("quire: 147-term exact dot", Duration::from_millis(200), || {
        let mut q = Quire::new(fmt, fmt).unwrap();
        for w in vals[..148].windows(2) {
            q.add_product(w[0], w[1]);
        }
        q.to_posit(fmt).bits()
    });
    report(&m);
    println!("  -> {:.1} M exact MACs/s", m.per_second(147.0) / 1e6);

    println!("\n== PDPU functional unit (the accuracy-experiment hot path) ==\n");
    for (label, cfg) in [
        ("PDPU P(13/16,2) N=4 Wm=14 dot", PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap()),
        ("PDPU P(13/16,2) N=8 Wm=14 dot", PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap()),
    ] {
        let unit = Pdpu::new(cfg);
        let in_vals: Vec<Posit> =
            (0..cfg.n).map(|i| Posit::from_f64(vals[i].to_f64().clamp(-8.0, 8.0), cfg.in_fmt)).collect();
        let acc = Posit::zero(cfg.out_fmt);
        let m = bench(label, Duration::from_millis(250), || {
            std::hint::black_box(unit.dot(acc, &in_vals, &in_vals)).bits()
        });
        report(&m);
        println!("  -> {:.2} M MACs/s per simulated unit", m.per_second(cfg.n as f64) / 1e6);
    }

    let cfg = PdpuConfig::paper_default();
    let unit = Pdpu::new(cfg);
    let a: Vec<Posit> = (0..147).map(|i| Posit::from_f64((i as f64 * 0.31).sin(), cfg.in_fmt)).collect();
    let b: Vec<Posit> = (0..147).map(|i| Posit::from_f64((i as f64 * 0.17).cos(), cfg.in_fmt)).collect();
    let m = bench("PDPU chunked K=147 (conv1 column)", Duration::from_millis(250), || {
        std::hint::black_box(unit.dot_chunked(Posit::zero(cfg.out_fmt), &a, &b)).bits()
    });
    report(&m);
    println!("  -> {:.2} M MACs/s", m.per_second(147.0) / 1e6);

    bench_scalar_vs_vectorized();
    bench_conv_batched_vs_scalar();
    bench_col_blocking();
}

/// The datapath comparison behind the lane-packed refactor: the scalar
/// staged pipeline (`Pdpu::dot` — s1..s6 reference model, fresh stage
/// records per call) vs the vectorized fast path (`Pdpu::dot_with` →
/// `dot_packed_chunk`: u64-packed S1/S2 over a fixed `LaneScratch`, no
/// allocation). Bit-identity is asserted before timing (and exhaustively
/// in `rust/tests/conformance_exhaustive.rs`), so the speedup is pure
/// execution efficiency. Results are recorded to `BENCH_kernels.json`.
fn bench_scalar_vs_vectorized() {
    println!("\n== scalar staged pipeline vs lane-packed vectorized path (equal output bits) ==\n");
    report_header();

    let mut rows: Vec<(String, Measurement, Measurement, f64)> = Vec::new();
    for cfg in [
        PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap(),
        PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap(),
        PdpuConfig::mixed(13, 16, 2, 16, 14).unwrap(),
    ] {
        let unit = Pdpu::new(cfg);
        let a: Vec<Posit> =
            (0..cfg.n).map(|i| Posit::from_f64((i as f64 * 0.31).sin(), cfg.in_fmt)).collect();
        let b: Vec<Posit> =
            (0..cfg.n).map(|i| Posit::from_f64((i as f64 * 0.17).cos(), cfg.in_fmt)).collect();
        let acc = Posit::zero(cfg.out_fmt);
        let mut scratch = DotScratch::for_config(&cfg);
        assert_eq!(
            unit.dot(acc, &a, &b).bits(),
            unit.dot_with(acc, &a, &b, &mut scratch).bits(),
            "vectorized path diverged from the scalar reference"
        );

        let m_scalar =
            bench(&format!("dot {}: scalar staged (reference)", cfg.label()), Duration::from_millis(400), || {
                std::hint::black_box(unit.dot(acc, &a, &b)).bits()
            });
        report(&m_scalar);
        let m_vec =
            bench(&format!("dot {}: lane-packed vectorized", cfg.label()), Duration::from_millis(400), || {
                std::hint::black_box(unit.dot_with(acc, &a, &b, &mut scratch)).bits()
            });
        report(&m_vec);
        let speedup = m_scalar.mean_ns() / m_vec.mean_ns();
        println!("  -> N={} speedup: {speedup:.2}x", cfg.n);
        rows.push((cfg.label(), m_scalar, m_vec, speedup));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        ("section", Json::Str("scalar_vs_vectorized".into())),
        (
            "configs",
            Json::Arr(
                rows.iter()
                    .map(|(label, ms, mv, speedup)| {
                        Json::obj(vec![
                            ("config", Json::Str(label.clone())),
                            ("scalar_mean_ns", Json::Num(ms.mean_ns())),
                            ("vectorized_mean_ns", Json::Num(mv.mean_ns())),
                            ("speedup", Json::Num(*speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = "BENCH_kernels.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_kernels.json");
    println!("\n  scalar-vs-vectorized results recorded to {path}");
}

/// Engine tiling: whole-row walks stream the entire x-plane through cache
/// once per output row; column blocking revisits one cache-sized block of
/// right-hand vectors across all rows before moving on. Same bits either
/// way (block width is property-tested as a no-op on outputs).
fn bench_col_blocking() {
    println!("\n== engine column blocking vs whole-row walk (equal output bits) ==\n");
    report_header();

    let cfg = PdpuConfig::paper_default();
    let mut rng = Rng::seeded(0x7113);
    let (rows, cols, k) = (8usize, 768usize, 147usize);
    let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
    let acc = vec![0.0; rows];
    let macs = (rows * cols * k) as f64;

    // single worker isolates the cache effect from parallel speedup
    let row_walk = BatchEngine::new(cfg).with_threads(1).with_col_block(usize::MAX);
    let tiled = BatchEngine::new(cfg).with_threads(1);

    let want = row_walk.gemm_f64(&acc, &w, &x, k);
    let got = tiled.gemm_f64(&acc, &w, &x, k);
    assert_eq!(
        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "tiling changed output bits"
    );

    let m_rows = bench("gemm 8x768 K=147: whole-row walk", Duration::from_millis(900), || {
        std::hint::black_box(row_walk.gemm_f64(&acc, &w, &x, k))
    });
    report(&m_rows);
    println!("  -> {:.2} M MACs/s", m_rows.per_second(macs) / 1e6);

    let m_tiled = bench("gemm 8x768 K=147: column-blocked tiles", Duration::from_millis(900), || {
        std::hint::black_box(tiled.gemm_f64(&acc, &w, &x, k))
    });
    report(&m_tiled);
    println!("  -> {:.2} M MACs/s", m_tiled.per_second(macs) / 1e6);
    println!("\n  column-blocking speedup: {:.2}x", m_rows.mean_ns() / m_tiled.mean_ns());
}

/// The headline comparison: one conv1-like layer through the seed's
/// scalar per-pixel `dot_f64` loop (re-quantizing the weight row and
/// allocating stage records per output) vs the batched GEMM engine
/// (prepare-once operands, allocation-free stages, row-parallel workers).
/// Outputs are asserted bit-identical before timing, so the speedup is
/// pure execution efficiency at equal output bits.
fn bench_conv_batched_vs_scalar() {
    println!("\n== batched GEMM engine vs seed scalar conv path (equal output bits) ==\n");
    report_header();

    let wl = conv1_workload(2023, 16, 8);
    let arch = PdpuArch::new(PdpuConfig::paper_default());
    let (oc, kh, kw) = (wl.weights.shape()[0], wl.weights.shape()[2], wl.weights.shape()[3]);
    let klen = wl.weights.shape()[1] * kh * kw;
    let (oh, ow) = wl.out_hw();
    let macs = (oc * oh * ow * klen) as f64;

    // the seed's conv2d body: im2col per pixel, scalar dot_f64 per (o, pixel)
    let scalar_conv = || {
        let mut out = vec![0.0f64; oc * oh * ow];
        let mut patch = Vec::with_capacity(klen);
        for o in 0..oc {
            let wrow = &wl.weights.data()[o * klen..(o + 1) * klen];
            for oy in 0..oh {
                for ox in 0..ow {
                    im2col_patch(&wl.image, oy, ox, kh, kw, wl.stride, wl.pad, &mut patch);
                    out[(o * oh + oy) * ow + ox] = arch.dot_f64(0.0, wrow, &patch);
                }
            }
        }
        out
    };
    let batched_conv = || conv2d(&arch, &wl.image, &wl.weights, wl.stride, wl.pad);

    // equal output bits, checked before timing
    let want = scalar_conv();
    let got = batched_conv();
    assert_eq!(got.data().len(), want.len());
    for (i, (g, w)) in got.data().iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "conv output {i} diverged");
    }

    let m_scalar = bench("conv1 16x16x8: scalar dot_f64 loop (seed)", Duration::from_millis(900), || {
        std::hint::black_box(scalar_conv())
    });
    report(&m_scalar);
    println!("  -> {:.2} M MACs/s", m_scalar.per_second(macs) / 1e6);

    let m_batched = bench("conv1 16x16x8: batched GEMM engine", Duration::from_millis(900), || {
        std::hint::black_box(batched_conv())
    });
    report(&m_batched);
    println!("  -> {:.2} M MACs/s", m_batched.per_second(macs) / 1e6);

    let speedup = m_scalar.mean_ns() / m_batched.mean_ns();
    println!("\n  batched GEMM speedup over seed scalar path: {speedup:.2}x  (target ≥ 3x)");
}
