//! Bench K — posit arithmetic primitives: the hot path of every
//! bit-exact simulation in the repo (accuracy experiments, baselines,
//! property tests). Decode/encode/add/mul/fma/quire/PDPU-dot ns/op.
//!
//! Run: `cargo bench --bench bench_kernels`

use std::time::Duration;

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::pdpu::{Pdpu, PdpuConfig};
use pdpu::posit::{decode, p_add, p_fma, p_mul, quire::Quire, Posit, PositFormat};
use pdpu::testing::Rng;

fn main() {
    let fmt = PositFormat::p(16, 2);
    let mut rng = Rng::seeded(0xBE7C);
    let vals: Vec<Posit> = (0..1024)
        .map(|_| loop {
            let p = Posit::from_bits(rng.next_u64() as u32 & 0xFFFF, fmt);
            if !p.is_nar() {
                break p;
            }
        })
        .collect();

    println!("== posit primitive throughput (P(16,2), batches of 1024) ==\n");
    report_header();

    let m = bench("decode", Duration::from_millis(200), || {
        let mut acc = 0u64;
        for p in &vals {
            acc ^= match decode(*p) {
                pdpu::posit::Decoded::Finite(f) => f.frac,
                _ => 0,
            };
        }
        acc
    });
    report(&m);
    println!("  -> {:.1} M decodes/s", m.per_second(1024.0) / 1e6);

    let m = bench("from_f64 (encode path)", Duration::from_millis(200), || {
        let mut acc = 0u32;
        for (i, p) in vals.iter().enumerate() {
            acc ^= Posit::from_f64(p.to_f64() * (1.0 + i as f64 * 1e-6), fmt).bits();
        }
        acc
    });
    report(&m);
    println!("  -> {:.1} M encodes/s", m.per_second(1024.0) / 1e6);

    type Op = fn(Posit, Posit, PositFormat) -> Posit;
    let ops: [(&str, Op); 3] = [
        ("p_add", |a, b, f| p_add(a, b, f)),
        ("p_mul", |a, b, f| p_mul(a, b, f)),
        ("p_fma (c = a)", |a, b, f| p_fma(a, b, a, f)),
    ];
    for (name, f) in ops {
        let m = bench(name, Duration::from_millis(200), || {
            let mut acc = 0u32;
            for w in vals.windows(2) {
                acc ^= f(w[0], w[1], fmt).bits();
            }
            acc
        });
        report(&m);
        println!("  -> {:.1} M ops/s", m.per_second(1023.0) / 1e6);
    }

    let m = bench("quire: 147-term exact dot", Duration::from_millis(200), || {
        let mut q = Quire::new(fmt, fmt).unwrap();
        for w in vals[..148].windows(2) {
            q.add_product(w[0], w[1]);
        }
        q.to_posit(fmt).bits()
    });
    report(&m);
    println!("  -> {:.1} M exact MACs/s", m.per_second(147.0) / 1e6);

    println!("\n== PDPU functional unit (the accuracy-experiment hot path) ==\n");
    for (label, cfg) in [
        ("PDPU P(13/16,2) N=4 Wm=14 dot", PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap()),
        ("PDPU P(13/16,2) N=8 Wm=14 dot", PdpuConfig::mixed(13, 16, 2, 8, 14).unwrap()),
    ] {
        let unit = Pdpu::new(cfg);
        let in_vals: Vec<Posit> =
            (0..cfg.n).map(|i| Posit::from_f64(vals[i].to_f64().clamp(-8.0, 8.0), cfg.in_fmt)).collect();
        let acc = Posit::zero(cfg.out_fmt);
        let m = bench(label, Duration::from_millis(250), || {
            std::hint::black_box(unit.dot(acc, &in_vals, &in_vals)).bits()
        });
        report(&m);
        println!("  -> {:.2} M MACs/s per simulated unit", m.per_second(cfg.n as f64) / 1e6);
    }

    let cfg = PdpuConfig::paper_default();
    let unit = Pdpu::new(cfg);
    let a: Vec<Posit> = (0..147).map(|i| Posit::from_f64((i as f64 * 0.31).sin(), cfg.in_fmt)).collect();
    let b: Vec<Posit> = (0..147).map(|i| Posit::from_f64((i as f64 * 0.17).cos(), cfg.in_fmt)).collect();
    let m = bench("PDPU chunked K=147 (conv1 column)", Duration::from_millis(250), || {
        std::hint::black_box(unit.dot_chunked(Posit::zero(cfg.out_fmt), &a, &b)).bits()
    });
    report(&m);
    println!("  -> {:.2} M MACs/s", m.per_second(147.0) / 1e6);
}
