//! Bench E2E — the serving stack on real PJRT executables: batched
//! inference latency/throughput, posit GEMM rate, and train-step rate.
//! Skips gracefully when `artifacts/` is missing.
//!
//! Run: `cargo bench --bench bench_e2e`

use std::time::Duration;

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::coordinator::ServiceHandle;
use pdpu::testing::Rng;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("skipping bench_e2e: run `make artifacts` first");
        return;
    }
    let engine = ServiceHandle::start("artifacts").expect("engine");
    let info = engine.info().clone();
    let mut rng = Rng::seeded(0xE2E);

    println!("== PJRT serving path (CPU, interpret-mode pallas artifacts) ==\n");
    report_header();

    // single-image latency (batch of 1 padded to 32 inside)
    let img: Vec<f32> = (0..info.input_dim).map(|_| rng.unit() as f32).collect();
    let m = bench("infer batch=1", Duration::from_secs(2), || {
        engine.infer_batch(vec![img.clone()]).unwrap()
    });
    report(&m);
    println!("  -> {:.1} images/s\n", m.per_second(1.0));

    // full batch throughput
    let batch: Vec<Vec<f32>> =
        (0..info.batch).map(|_| (0..info.input_dim).map(|_| rng.unit() as f32).collect()).collect();
    let m = bench(&format!("infer batch={}", info.batch), Duration::from_secs(3), || {
        engine.infer_batch(batch.clone()).unwrap()
    });
    report(&m);
    println!("  -> {:.1} images/s (batched)\n", m.per_second(info.batch as f64));

    // raw posit GEMM
    let (mm, kk, nn) = info.gemm_mkn;
    let a: Vec<f32> = (0..mm * kk).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..kk * nn).map(|_| rng.normal() as f32).collect();
    let m = bench(&format!("posit GEMM {mm}x{kk}x{nn}"), Duration::from_secs(3), || {
        engine.gemm(a.clone(), b.clone()).unwrap()
    });
    report(&m);
    let macs = (mm * kk * nn) as f64;
    println!("  -> {:.2} M posit-MACs/s\n", m.per_second(macs) / 1e6);

    // train step
    let labels: Vec<u32> = (0..info.batch).map(|_| (rng.next_u64() % info.classes as u64) as u32).collect();
    let m = bench("train step (fwd+bwd+SGD)", Duration::from_secs(3), || {
        engine.train_step(batch.clone(), labels.clone()).unwrap()
    });
    report(&m);
    println!("  -> {:.1} steps/s, {:.0} samples/s", m.per_second(1.0), m.per_second(info.batch as f64));

    engine.shutdown();
}
