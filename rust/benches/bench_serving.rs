//! Bench S — serving-path throughput: cross-request GEMM fusion
//! (`coordinator::fusion`) vs one-engine-launch-per-request, on a mixed
//! request queue shaped like real serving traffic (most requests multiply
//! one of a few shared weight planes; a few bring unique planes).
//!
//! Outputs are asserted bit-identical before timing, so the measured
//! speedup is pure scheduling/execution efficiency at equal output bits.
//! The measurement is **recorded**, not asserted: results go to
//! `BENCH_serving.json` in the working directory.
//!
//! Run: `cargo bench --bench bench_serving`

use std::sync::Arc;
use std::time::{Duration, Instant};

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::coordinator::fusion::{execute_fused, execute_unfused, plan_fusion, GemmTile};
use pdpu::coordinator::json::Json;
use pdpu::coordinator::{
    Metrics, ServerPolicy, ServiceHandle, ServingTier, SoftwareService, TierReply,
};
use pdpu::pdpu::PdpuConfig;
use pdpu::testing::Rng;

/// GEMM shape served by the sharded-tier section (kept small so 20k
/// requests finish in bench time while still exercising the full path).
const TIER_MKN: (usize, usize, usize) = (8, 64, 4);
const TIER_PLANES: usize = 8;

fn tier_service(plane_capacity: usize) -> SoftwareService {
    SoftwareService::new(PdpuConfig::paper_default(), &[8, 4], 16, TIER_MKN, 0xBEEF)
        .expect("valid tier config")
        .with_plane_cache_capacity(plane_capacity)
}

fn build_tier(plane_capacity: usize, fuse: bool, max_inflight: usize) -> (Arc<ServingTier>, Arc<Metrics>) {
    let policy = ServerPolicy { fuse_gemm: fuse, shards: 4, max_inflight, ..ServerPolicy::default() };
    let metrics = Arc::new(Metrics::new());
    let handle = ServiceHandle::from_software(tier_service(plane_capacity));
    (Arc::new(ServingTier::new(handle, metrics.clone(), policy)), metrics)
}

/// The shared weight planes most simulated clients multiply.
fn tier_planes() -> Arc<Vec<Vec<f32>>> {
    let (m, k, _) = TIER_MKN;
    let mut rng = Rng::seeded(0x7134_9E1A);
    Arc::new((0..TIER_PLANES).map(|_| (0..m * k).map(|_| rng.normal() as f32).collect()).collect())
}

/// Deterministic per-(client, request) right operand.
fn tier_b(client: usize, r: usize) -> Vec<f32> {
    let (_, k, n) = TIER_MKN;
    (0..k * n).map(|i| ((client * 31 + r * 17 + 3 * i) % 13) as f32 * 0.25 - 1.5).collect()
}

/// Drive `clients` simulated clients (each issuing `reqs` sequential
/// GEMMs) through the tier on `threads` OS threads. Returns per-request
/// latencies in µs plus the served/shed split.
fn drive_tier(
    tier: &Arc<ServingTier>,
    planes: &Arc<Vec<Vec<f32>>>,
    clients: usize,
    reqs: usize,
    threads: usize,
) -> (Vec<f64>, u64, u64) {
    let mut handles = Vec::new();
    for t in 0..threads {
        let tier = tier.clone();
        let planes = planes.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::new();
            let (mut served, mut sheds) = (0u64, 0u64);
            for client in (t..clients).step_by(threads) {
                for r in 0..reqs {
                    let a = planes[(client + r) % planes.len()].clone();
                    let b = tier_b(client, r);
                    let t0 = Instant::now();
                    match tier.gemm(tier.assign_shard(), a, b, None) {
                        TierReply::Ok(_) => {
                            lat.push(t0.elapsed().as_secs_f64() * 1e6);
                            served += 1;
                        }
                        TierReply::Shed => sheds += 1,
                        TierReply::Err(e) => panic!("tier gemm errored: {e}"),
                    }
                }
            }
            (lat, served, sheds)
        }));
    }
    let mut lat = Vec::new();
    let (mut served, mut sheds) = (0u64, 0u64);
    for h in handles {
        let (l, ok, sh) = h.join().expect("tier client thread");
        lat.extend(l);
        served += ok;
        sheds += sh;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    (lat, served, sheds)
}

fn pctile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The benchmark queue: `shared_planes` left operand planes reused by
/// most requests plus `unique` requests with their own planes.
fn build_queue(
    cfg: PdpuConfig,
    rng: &mut Rng,
    m: usize,
    k: usize,
    n: usize,
    shared_planes: usize,
    per_plane: usize,
    unique: usize,
) -> Vec<GemmTile> {
    let planes: Vec<Vec<f64>> = (0..shared_planes)
        .map(|_| (0..m * k).map(|_| rng.normal()).collect())
        .collect();
    let mut queue = Vec::new();
    for round in 0..per_plane {
        for plane in &planes {
            queue.push(GemmTile {
                cfg,
                k,
                acc: vec![0.0; m],
                a: plane.clone(),
                bt: (0..n * k).map(|_| rng.normal()).collect(),
            });
        }
        // interleave one unique-plane request per round while any remain
        if round < unique {
            queue.push(GemmTile {
                cfg,
                k,
                acc: vec![0.0; m],
                a: (0..m * k).map(|_| rng.normal()).collect(),
                bt: (0..n * k).map(|_| rng.normal()).collect(),
            });
        }
    }
    queue
}

fn main() {
    let cfg = PdpuConfig::paper_default();
    let mut rng = Rng::seeded(0x5E44_1306);
    let (m, k, n) = (16usize, 147usize, 8usize);
    let (shared_planes, per_plane, unique) = (3usize, 6usize, 4usize);
    let queue = build_queue(cfg, &mut rng, m, k, n, shared_planes, per_plane, unique);
    let tiles = queue.len();
    let groups = plan_fusion(&queue).len();
    let macs_per_pass = (tiles * m * n * k) as f64;

    println!(
        "== serving queue: {} GEMM requests ({}x{}x{}), {} shared planes + {} unique → {} launches fused ==\n",
        tiles, m, k, n, shared_planes, unique, groups
    );

    // equal output bits, checked before timing
    let (fused_out, stats) = execute_fused(&queue);
    let unfused_out = execute_unfused(&queue);
    for (i, (f, u)) in fused_out.iter().zip(&unfused_out).enumerate() {
        assert_eq!(f.len(), u.len(), "tile {i} shape");
        for (g, w) in f.iter().zip(u) {
            assert_eq!(g.to_bits(), w.to_bits(), "tile {i} diverged under fusion");
        }
    }

    report_header();
    let m_unfused = bench(
        "serving queue: unfused (one launch per request)",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_unfused(&queue)),
    );
    report(&m_unfused);
    println!(
        "  -> {:.2} M MACs/s, {:.1} requests/s",
        m_unfused.per_second(macs_per_pass) / 1e6,
        m_unfused.per_second(tiles as f64)
    );

    let m_fused = bench(
        "serving queue: fused cross-request launches",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_fused(&queue)),
    );
    report(&m_fused);
    println!(
        "  -> {:.2} M MACs/s, {:.1} requests/s",
        m_fused.per_second(macs_per_pass) / 1e6,
        m_fused.per_second(tiles as f64)
    );

    let speedup = m_unfused.mean_ns() / m_fused.mean_ns();
    println!("\n  fused serving speedup over per-request launches: {speedup:.2}x");

    // tracing A/B: same fused pass with every request sampled into the
    // span ring + the 1-in-64 stage probes live. The overhead ratio is the
    // worst case (sampling=1); sampling off restores the exact baseline
    // path (one relaxed atomic load per request).
    pdpu::obs::trace::set_sampling(1);
    let m_traced = bench(
        "serving queue: fused, tracing sampled 1-in-1",
        Duration::from_millis(1200),
        || {
            let root = pdpu::obs::trace::start_root("bench_pass");
            let out = std::hint::black_box(execute_fused(&queue));
            pdpu::obs::trace::finish(root);
            out
        },
    );
    pdpu::obs::trace::set_sampling(0);
    report(&m_traced);
    let overhead = m_traced.mean_ns() / m_fused.mean_ns();
    println!("  -> tracing overhead at full sampling: {overhead:.3}x of the untraced fused pass");

    // numerics-observatory A/B: same fused pass with every engine launch
    // FP64-shadowed (sampling=1, the worst case). Shadowing re-walks the
    // decoded operand planes in double precision on the caller thread;
    // primary outputs stay bit-identical (rust/tests/shadow_identity.rs).
    pdpu::obs::shadow::set_sampling(1);
    let m_shadowed = bench(
        "serving queue: fused, FP64 shadow sampled 1-in-1",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_fused(&queue)),
    );
    pdpu::obs::shadow::set_sampling(0);
    report(&m_shadowed);
    let numerics_overhead = m_shadowed.mean_ns() / m_fused.mean_ns();
    println!(
        "  -> numerics-observatory overhead at full shadow sampling: {numerics_overhead:.3}x of the fused pass"
    );

    // ── sharded serving tier: 10k simulated clients ──────────────────
    let (tm, tk, tn) = TIER_MKN;
    let planes = tier_planes();
    const TIER_CLIENTS: usize = 10_000;
    const TIER_REQS: usize = 2;
    const TIER_THREADS: usize = 32;
    println!(
        "\n== sharded tier: {TIER_CLIENTS} simulated clients x {TIER_REQS} GEMMs ({tm}x{tk}x{tn}), \
         {TIER_PLANES} shared planes, 4 shards on {TIER_THREADS} OS threads ==\n"
    );

    // bit-identity property first: the sharded + cached + fused tier must
    // match a direct, uncached, unfused oracle bit for bit
    {
        let (tier, _m) = build_tier(64, true, 0);
        let oracle = tier_service(0);
        let mut checks = Vec::new();
        for t in 0..8usize {
            let tier = tier.clone();
            let planes = planes.clone();
            checks.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..8usize {
                    let a = planes[(t + i) % planes.len()].clone();
                    let b = tier_b(t, i);
                    match tier.gemm(tier.assign_shard(), a.clone(), b.clone(), None) {
                        TierReply::Ok(c) => got.push((a, b, c)),
                        other => panic!("identity pass must serve: {other:?}"),
                    }
                }
                got
            }));
        }
        for h in checks {
            for (a, b, c) in h.join().expect("identity thread") {
                let want = oracle.gemm(&a, &b).expect("oracle gemm");
                let same = c.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same && c.len() == want.len(), "tier diverged from the uncached oracle");
            }
        }
        println!("  bit-identity vs uncached oracle: 64/64 concurrent requests identical");
    }

    // warm cache, fusion on — the production configuration
    let (tier, _metrics) = build_tier(64, true, 4096);
    let (lat, served, sheds) = drive_tier(&tier, &planes, TIER_CLIENTS, TIER_REQS, TIER_THREADS);
    let total = (TIER_CLIENTS * TIER_REQS) as u64;
    assert_eq!(served + sheds, total, "every request accounted for");
    let cache = tier.plane_cache_stats();
    let hit_rate = cache.hits as f64 / (cache.hits + cache.misses).max(1) as f64;
    let (p50, p99) = (pctile(&lat, 0.50), pctile(&lat, 0.99));
    let shed_rate = sheds as f64 / total as f64;
    println!(
        "  cached+fused : p50 {p50:.1}us  p99 {p99:.1}us  shed {:.3}%  plane-cache hit {:.1}% ({} hits / {} misses)",
        shed_rate * 100.0, hit_rate * 100.0, cache.hits, cache.misses
    );

    // cold A/B: plane cache disabled, fusion on
    let (cold_tier, _m2) = build_tier(0, true, 4096);
    let (cold_lat, cold_served, cold_sheds) = drive_tier(&cold_tier, &planes, TIER_CLIENTS, TIER_REQS, TIER_THREADS);
    assert_eq!(cold_served + cold_sheds, total);
    let (cold_p50, cold_p99) = (pctile(&cold_lat, 0.50), pctile(&cold_lat, 0.99));
    let cached_speedup = if p50 > 0.0 { cold_p50 / p50 } else { 1.0 };
    println!("  cold  +fused : p50 {cold_p50:.1}us  p99 {cold_p99:.1}us  (cached p50 speedup {cached_speedup:.2}x)");

    // unfused A/B (the --no-fuse serving configuration), cache on
    let (unf_tier, _m3) = build_tier(64, false, 4096);
    let (unf_lat, unf_served, unf_sheds) = drive_tier(&unf_tier, &planes, TIER_CLIENTS, TIER_REQS, TIER_THREADS);
    assert_eq!(unf_served + unf_sheds, total);
    let unf_p50 = pctile(&unf_lat, 0.50);
    println!("  cached+unfused: p50 {unf_p50:.1}us  (fusion p50 speedup {:.2}x)", if p50 > 0.0 { unf_p50 / p50 } else { 1.0 });

    // overload probe: a tiny admission budget under the same load must
    // shed gracefully (structured replies, not queue collapse)
    let (over_tier, over_metrics) = build_tier(64, true, 2);
    let (_olat, over_served, over_sheds) = drive_tier(&over_tier, &planes, 1_000, 2, 16);
    let overload_shed_rate = over_sheds as f64 / (over_served + over_sheds).max(1) as f64;
    println!(
        "  overload probe (budget=2): shed {:.1}% of {} requests ({} counted)",
        overload_shed_rate * 100.0,
        over_served + over_sheds,
        over_metrics.snapshot().shed_requests
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("config", Json::Str(cfg.label())),
        ("tiles", Json::Num(tiles as f64)),
        ("gemm_m", Json::Num(m as f64)),
        ("gemm_k", Json::Num(k as f64)),
        ("gemm_n", Json::Num(n as f64)),
        ("shared_planes", Json::Num(shared_planes as f64)),
        ("unique_planes", Json::Num(unique as f64)),
        ("fused_launches", Json::Num(stats.launches as f64)),
        ("fused_tiles", Json::Num(stats.fused_tiles as f64)),
        ("unfused_mean_ns", Json::Num(m_unfused.mean_ns())),
        ("fused_mean_ns", Json::Num(m_fused.mean_ns())),
        ("traced_mean_ns", Json::Num(m_traced.mean_ns())),
        ("tracing_overhead", Json::Num(overhead)),
        ("numerics_shadow_mean_ns", Json::Num(m_shadowed.mean_ns())),
        ("numerics_overhead", Json::Num(numerics_overhead)),
        ("unfused_macs_per_s", Json::Num(m_unfused.per_second(macs_per_pass))),
        ("fused_macs_per_s", Json::Num(m_fused.per_second(macs_per_pass))),
        ("speedup", Json::Num(speedup)),
        ("tier_clients", Json::Num(TIER_CLIENTS as f64)),
        ("tier_requests", Json::Num(total as f64)),
        ("tier_shards", Json::Num(4.0)),
        ("tier_p50_us", Json::Num(p50)),
        ("tier_p99_us", Json::Num(p99)),
        ("tier_shed_rate", Json::Num(shed_rate)),
        ("plane_cache_hit_rate", Json::Num(hit_rate)),
        ("tier_cold_p50_us", Json::Num(cold_p50)),
        ("tier_cold_p99_us", Json::Num(cold_p99)),
        ("tier_unfused_p50_us", Json::Num(unf_p50)),
        ("cached_speedup", Json::Num(cached_speedup)),
        ("overload_shed_rate", Json::Num(overload_shed_rate)),
    ]);
    let path = "BENCH_serving.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_serving.json");
    println!("  recorded: {path}");
}
