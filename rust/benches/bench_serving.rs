//! Bench S — serving-path throughput: cross-request GEMM fusion
//! (`coordinator::fusion`) vs one-engine-launch-per-request, on a mixed
//! request queue shaped like real serving traffic (most requests multiply
//! one of a few shared weight planes; a few bring unique planes).
//!
//! Outputs are asserted bit-identical before timing, so the measured
//! speedup is pure scheduling/execution efficiency at equal output bits.
//! The measurement is **recorded**, not asserted: results go to
//! `BENCH_serving.json` in the working directory.
//!
//! Run: `cargo bench --bench bench_serving`

use std::time::Duration;

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::coordinator::fusion::{execute_fused, execute_unfused, plan_fusion, GemmTile};
use pdpu::coordinator::json::Json;
use pdpu::pdpu::PdpuConfig;
use pdpu::testing::Rng;

/// The benchmark queue: `shared_planes` left operand planes reused by
/// most requests plus `unique` requests with their own planes.
fn build_queue(
    cfg: PdpuConfig,
    rng: &mut Rng,
    m: usize,
    k: usize,
    n: usize,
    shared_planes: usize,
    per_plane: usize,
    unique: usize,
) -> Vec<GemmTile> {
    let planes: Vec<Vec<f64>> = (0..shared_planes)
        .map(|_| (0..m * k).map(|_| rng.normal()).collect())
        .collect();
    let mut queue = Vec::new();
    for round in 0..per_plane {
        for plane in &planes {
            queue.push(GemmTile {
                cfg,
                k,
                acc: vec![0.0; m],
                a: plane.clone(),
                bt: (0..n * k).map(|_| rng.normal()).collect(),
            });
        }
        // interleave one unique-plane request per round while any remain
        if round < unique {
            queue.push(GemmTile {
                cfg,
                k,
                acc: vec![0.0; m],
                a: (0..m * k).map(|_| rng.normal()).collect(),
                bt: (0..n * k).map(|_| rng.normal()).collect(),
            });
        }
    }
    queue
}

fn main() {
    let cfg = PdpuConfig::paper_default();
    let mut rng = Rng::seeded(0x5E44_1306);
    let (m, k, n) = (16usize, 147usize, 8usize);
    let (shared_planes, per_plane, unique) = (3usize, 6usize, 4usize);
    let queue = build_queue(cfg, &mut rng, m, k, n, shared_planes, per_plane, unique);
    let tiles = queue.len();
    let groups = plan_fusion(&queue).len();
    let macs_per_pass = (tiles * m * n * k) as f64;

    println!(
        "== serving queue: {} GEMM requests ({}x{}x{}), {} shared planes + {} unique → {} launches fused ==\n",
        tiles, m, k, n, shared_planes, unique, groups
    );

    // equal output bits, checked before timing
    let (fused_out, stats) = execute_fused(&queue);
    let unfused_out = execute_unfused(&queue);
    for (i, (f, u)) in fused_out.iter().zip(&unfused_out).enumerate() {
        assert_eq!(f.len(), u.len(), "tile {i} shape");
        for (g, w) in f.iter().zip(u) {
            assert_eq!(g.to_bits(), w.to_bits(), "tile {i} diverged under fusion");
        }
    }

    report_header();
    let m_unfused = bench(
        "serving queue: unfused (one launch per request)",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_unfused(&queue)),
    );
    report(&m_unfused);
    println!(
        "  -> {:.2} M MACs/s, {:.1} requests/s",
        m_unfused.per_second(macs_per_pass) / 1e6,
        m_unfused.per_second(tiles as f64)
    );

    let m_fused = bench(
        "serving queue: fused cross-request launches",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_fused(&queue)),
    );
    report(&m_fused);
    println!(
        "  -> {:.2} M MACs/s, {:.1} requests/s",
        m_fused.per_second(macs_per_pass) / 1e6,
        m_fused.per_second(tiles as f64)
    );

    let speedup = m_unfused.mean_ns() / m_fused.mean_ns();
    println!("\n  fused serving speedup over per-request launches: {speedup:.2}x");

    // tracing A/B: same fused pass with every request sampled into the
    // span ring + the 1-in-64 stage probes live. The overhead ratio is the
    // worst case (sampling=1); sampling off restores the exact baseline
    // path (one relaxed atomic load per request).
    pdpu::obs::trace::set_sampling(1);
    let m_traced = bench(
        "serving queue: fused, tracing sampled 1-in-1",
        Duration::from_millis(1200),
        || {
            let root = pdpu::obs::trace::start_root("bench_pass");
            let out = std::hint::black_box(execute_fused(&queue));
            pdpu::obs::trace::finish(root);
            out
        },
    );
    pdpu::obs::trace::set_sampling(0);
    report(&m_traced);
    let overhead = m_traced.mean_ns() / m_fused.mean_ns();
    println!("  -> tracing overhead at full sampling: {overhead:.3}x of the untraced fused pass");

    // numerics-observatory A/B: same fused pass with every engine launch
    // FP64-shadowed (sampling=1, the worst case). Shadowing re-walks the
    // decoded operand planes in double precision on the caller thread;
    // primary outputs stay bit-identical (rust/tests/shadow_identity.rs).
    pdpu::obs::shadow::set_sampling(1);
    let m_shadowed = bench(
        "serving queue: fused, FP64 shadow sampled 1-in-1",
        Duration::from_millis(1200),
        || std::hint::black_box(execute_fused(&queue)),
    );
    pdpu::obs::shadow::set_sampling(0);
    report(&m_shadowed);
    let numerics_overhead = m_shadowed.mean_ns() / m_fused.mean_ns();
    println!(
        "  -> numerics-observatory overhead at full shadow sampling: {numerics_overhead:.3}x of the fused pass"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("config", Json::Str(cfg.label())),
        ("tiles", Json::Num(tiles as f64)),
        ("gemm_m", Json::Num(m as f64)),
        ("gemm_k", Json::Num(k as f64)),
        ("gemm_n", Json::Num(n as f64)),
        ("shared_planes", Json::Num(shared_planes as f64)),
        ("unique_planes", Json::Num(unique as f64)),
        ("fused_launches", Json::Num(stats.launches as f64)),
        ("fused_tiles", Json::Num(stats.fused_tiles as f64)),
        ("unfused_mean_ns", Json::Num(m_unfused.mean_ns())),
        ("fused_mean_ns", Json::Num(m_fused.mean_ns())),
        ("traced_mean_ns", Json::Num(m_traced.mean_ns())),
        ("tracing_overhead", Json::Num(overhead)),
        ("numerics_shadow_mean_ns", Json::Num(m_shadowed.mean_ns())),
        ("numerics_overhead", Json::Num(numerics_overhead)),
        ("unfused_macs_per_s", Json::Num(m_unfused.per_second(macs_per_pass))),
        ("fused_macs_per_s", Json::Num(m_fused.per_second(macs_per_pass))),
        ("speedup", Json::Num(speedup)),
    ]);
    let path = "BENCH_serving.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_serving.json");
    println!("  recorded: {path}");
}
