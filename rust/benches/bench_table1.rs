//! Bench T1 — Table I end-to-end: time the bit-exact functional
//! simulation of every Table I architecture on the conv1-like dot
//! products (the workload behind the accuracy column), and regenerate the
//! cost-model side of the table. Also prints the §IV-A claim ratios.
//!
//! Run: `cargo bench --bench bench_table1`

use std::time::Duration;

use pdpu::baselines::table1_units;
use pdpu::bench_harness::{bench, report, report_header, Measurement};
use pdpu::cost::{table1_reports, Tech};
use pdpu::testing::Rng;

fn main() {
    println!("== Table I: functional-model MAC throughput (bit-exact simulation) ==\n");
    let mut rng = Rng::seeded(0x7AB1E);
    let k = 147usize; // conv1 dot-product length
    let a: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k).map(|_| rng.normal()).collect();

    report_header();
    let mut sims: Vec<(String, Measurement)> = Vec::new();
    for unit in table1_units() {
        let m = bench(&unit.name(), Duration::from_millis(300), || {
            std::hint::black_box(unit.dot_f64(0.0, &a, &b))
        });
        report(&m);
        sims.push((unit.name(), m));
    }
    println!("\nsimulation rate (bit-exact MACs/s):");
    for (name, m) in &sims {
        println!("  {:<32} {:>10.2} M MAC/s", name, m.per_second(k as f64) / 1e6);
    }

    println!("\n== Table I: cost model (what the paper synthesized) ==\n");
    let t0 = std::time::Instant::now();
    let reports = table1_reports(&Tech::default());
    println!(
        "{:<32} {:>10} {:>7} {:>8} {:>8} {:>12} {:>10}",
        "architecture", "area um2", "delay", "power", "GOPS", "GOPS/mm2", "GOPS/W"
    );
    for r in &reports {
        println!(
            "{:<32} {:>10.0} {:>7.2} {:>8.2} {:>8.2} {:>12.1} {:>10.1}",
            r.label,
            r.area_um2,
            r.delay_ns,
            r.power_mw,
            r.perf_gops(),
            r.area_eff(),
            r.energy_eff()
        );
    }
    println!("(cost model regenerated in {:?})", t0.elapsed());
}
