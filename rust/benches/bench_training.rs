//! Bench T — training-step throughput: one posit SGD step (forward GEMMs,
//! softmax cross-entropy, backward GEMMs, quire-accumulated update)
//! through the batched engine, on the bundled MNIST-like dataset.
//!
//! The measurement is **recorded**, not asserted: results go to
//! `BENCH_training.json` in the working directory (the training twin of
//! `BENCH_serving.json`).
//!
//! Run: `cargo bench --bench bench_training`

use std::time::Duration;

use pdpu::bench_harness::{bench, report, report_header};
use pdpu::coordinator::json::Json;
use pdpu::dnn::dataset::mnist_like;
use pdpu::pdpu::PdpuConfig;
use pdpu::train::Trainer;

fn main() {
    let cfg = PdpuConfig::paper_default();
    let (hidden, classes, batch, examples) = (8usize, 4usize, 16usize, 32usize);
    let lr = 0.05;
    let ds = mnist_like(0x7247, examples, classes);
    let layer_sizes = [784usize, hidden, classes];
    // MACs of one step: forward + weight-grad + activation-grad GEMMs
    let macs_per_step = (batch * 784 * hidden)  // forward layer 0
        + (batch * hidden * classes)            // forward layer 1
        + (hidden * 784 * batch)                // dW0
        + (classes * hidden * batch)            // dW1
        + (batch * hidden * classes); // dA0
    let steps_per_epoch = examples.div_ceil(batch);

    println!(
        "== training: {}-{}-{} MLP on {}, batch {}, {} examples/epoch, lr {} ==\n",
        layer_sizes[0],
        hidden,
        classes,
        cfg.label(),
        batch,
        examples,
        lr
    );

    let mut trainer = Trainer::new(cfg, &layer_sizes, lr, 0xBE7C);
    let mut epoch = 0usize;
    report_header();
    let m_step = bench("posit SGD epoch (forward+backward+update)", Duration::from_millis(1500), || {
        epoch += 1;
        std::hint::black_box(trainer.run_epoch(&ds, batch, epoch))
    });
    report(&m_step);
    let steps_per_s = m_step.per_second(steps_per_epoch as f64);
    let examples_per_s = m_step.per_second(examples as f64);
    let macs_per_s = m_step.per_second((macs_per_step * steps_per_epoch) as f64);
    println!(
        "  -> {:.2} steps/s, {:.1} examples/s, {:.2} M training MACs/s",
        steps_per_s,
        examples_per_s,
        macs_per_s / 1e6
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("training".into())),
        ("config", Json::Str(cfg.label())),
        ("layers", Json::arr_f64(&layer_sizes.iter().map(|&v| v as f64).collect::<Vec<_>>())),
        ("batch", Json::Num(batch as f64)),
        ("examples_per_epoch", Json::Num(examples as f64)),
        ("lr", Json::Num(lr)),
        ("epoch_mean_ns", Json::Num(m_step.mean_ns())),
        ("steps_per_s", Json::Num(steps_per_s)),
        ("examples_per_s", Json::Num(examples_per_s)),
        ("train_macs_per_s", Json::Num(macs_per_s)),
    ]);
    let path = "BENCH_training.json";
    std::fs::write(path, json.to_string() + "\n").expect("write BENCH_training.json");
    println!("  recorded: {path}");
}
