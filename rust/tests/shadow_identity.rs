//! Property test for the FP64 shadow executor: sampled shadow execution is
//! a pure observer. At **any** sampling rate the primary posit outputs of
//! `BatchEngine::gemm_posit` are bit-identical to a shadow-off run — the
//! observatory may read `PreparedOperands` planes and the output vector,
//! never influence them.
//!
//! The sampling knob and the site registry are process-global (`pdpu::obs`),
//! so both tests serialize on one mutex and restore sampling to 0.

use std::sync::Mutex;

use pdpu::engine::{BatchEngine, PreparedOperands};
use pdpu::obs::numerics::{Site, SiteGuard, SiteKind};
use pdpu::obs::shadow;
use pdpu::pdpu::PdpuConfig;
use pdpu::posit::Posit;
use pdpu::testing::diff::{adversarial_vector, cancellation_pair, rand_pattern, random_config};
use pdpu::testing::Rng;

/// Serializes tests that touch the process-global shadow-sampling knob.
static GLOBALS: Mutex<()> = Mutex::new(());

#[test]
fn shadow_sampling_never_changes_primary_outputs() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::seeded(0x5AD_0001);
    for round in 0..150 {
        let cfg = random_config(&mut rng);
        let engine = BatchEngine::new(cfg);
        let (rows, cols) = (1 + rng.below(4) as usize, 1 + rng.below(4) as usize);
        let k = 1 + rng.below(24) as usize;
        let (w, x) = if rng.flip() {
            (
                adversarial_vector(&mut rng, cfg.in_fmt, rows * k),
                adversarial_vector(&mut rng, cfg.in_fmt, cols * k),
            )
        } else {
            let (a, b) = cancellation_pair(&mut rng, cfg.in_fmt, rows.max(cols) * k);
            (a.iter().cycle().take(rows * k).copied().collect(), b.iter().cycle().take(cols * k).copied().collect())
        };
        let acc: Vec<Posit> = (0..rows).map(|_| rand_pattern(&mut rng, cfg.out_fmt)).collect();
        let wp = PreparedOperands::from_posits(cfg.in_fmt, &w, k);
        let xp = PreparedOperands::from_posits(cfg.in_fmt, &x, k);

        shadow::set_sampling(0);
        let baseline = engine.gemm_posit(&acc, &wp, &xp);
        for every in [1u32, 2, 7] {
            shadow::set_sampling(every);
            let got = engine.gemm_posit(&acc, &wp, &xp);
            shadow::set_sampling(0);
            assert_eq!(baseline.len(), got.len(), "round {round} shape at 1-in-{every}");
            for (i, (b, g)) in baseline.iter().zip(&got).enumerate() {
                assert_eq!(
                    b.bits(),
                    g.bits(),
                    "round {round} cfg {} out[{i}]: shadow 1-in-{every} changed the primary result",
                    cfg.label()
                );
            }
        }
    }
}

#[test]
fn shadow_samples_land_in_the_site_registry_with_high_accuracy() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = PdpuConfig::paper_default();
    let engine = BatchEngine::new(cfg);
    let (rows, cols, k) = (3usize, 3usize, 8usize);
    // benign all-positive operands: references are nonzero and finite, so
    // every shadowed output contributes a relative-error sample
    let w: Vec<Posit> =
        (0..rows * k).map(|i| Posit::from_f64(0.25 + (i % 5) as f64 * 0.125, cfg.in_fmt)).collect();
    let x: Vec<Posit> =
        (0..cols * k).map(|i| Posit::from_f64(0.5 + (i % 3) as f64 * 0.25, cfg.in_fmt)).collect();
    let acc: Vec<Posit> = (0..rows).map(|_| Posit::from_f64(0.0, cfg.out_fmt)).collect();
    let wp = PreparedOperands::from_posits(cfg.in_fmt, &w, k);
    let xp = PreparedOperands::from_posits(cfg.in_fmt, &x, k);

    let site = Site::new(SiteKind::Infer, 31_337);
    shadow::set_sampling(1);
    {
        let _guard = SiteGuard::enter(site);
        engine.gemm_posit(&acc, &wp, &xp);
    }
    shadow::set_sampling(0);

    let entry = pdpu::obs::numerics::snapshot()
        .into_iter()
        .find(|e| e.site == site)
        .expect("shadowed launch recorded at the guarded site");
    assert_eq!(entry.stats.shadow.samples(), (rows * cols) as u64, "every output shadowed at 1-in-1");
    assert_eq!(entry.stats.shadow.overflow_frac(), 0.0, "benign operands cannot overflow FP64");
    // P(16,2) keeps well over one decimal digit on unit-scale dot products
    assert!(
        entry.stats.shadow.mean_decimal_accuracy() > 1.0,
        "implausibly low shadow accuracy: {}",
        entry.stats.shadow.mean_decimal_accuracy()
    );
}
