//! Exhaustive conformance sweep gating the lane-packed fast path.
//!
//! For **every** posit format with n ≤ 8, es ≤ 2 and **all** 2^n × 2^n
//! operand pairs, a single-lane PDPU with a wide-enough alignment window
//! (Wm ≥ 2·frac_bits + 2 ⇒ S3 never truncates) must agree bit-for-bit
//! with three independent references:
//!
//! 1. the scalar staged pipeline (`Pdpu::dot` — the reference model),
//! 2. the exact quire (`exact_dot` — Eq. (2) computed without rounding),
//! 3. FP64: `Posit::from_f64(a·b)` — exact for these widths because the
//!    product significand (≤ 12 bits) and scale span fit f64 losslessly.
//!
//! On top of the oracle, the narrow formats also sweep truncating
//! configurations (small Wm) and N=2 cancellation lanes through every
//! datapath implementation via the shared bit-identity runner.
//!
//! The n = 16 analogues are randomized (the full cross product is 2^32
//! pairs) and marked `#[ignore]` for the advisory long-haul CI job.

use pdpu::pdpu::{DotScratch, Pdpu, PdpuConfig};
use pdpu::posit::quire::exact_dot;
use pdpu::posit::{Posit, PositFormat};
use pdpu::testing::diff::{adversarial_vector, assert_dot_paths_bit_identical, rand_pattern};
use pdpu::testing::Rng;

/// Wm at which a single product aligns with no right shift: S3 becomes
/// exact, so the PDPU result is the correctly-rounded exact product.
fn lossless_wm(fmt: PositFormat) -> u32 {
    (2 * fmt.max_frac_bits() + 2).max(4)
}

/// All patterns of a format, NaR and zero included.
fn all_patterns(fmt: PositFormat) -> impl Iterator<Item = Posit> {
    (0..fmt.cardinality()).map(move |bits| Posit::from_bits(bits as u32, fmt))
}

/// One (a, b) pair through scalar, vectorized, quire, and FP64 — the
/// units are hoisted by the caller so the n=8 sweep (65 536 pairs per es)
/// stays cheap in debug mode.
fn oracle_case(unit: &Pdpu, scratch: &mut DotScratch, fmt: PositFormat, a: Posit, b: Posit) {
    let zero = Posit::zero(fmt);
    let scalar = unit.dot(zero, &[a], &[b]);
    let vectorized = unit.dot_with(zero, &[a], &[b], &mut *scratch);
    assert_eq!(scalar.bits(), vectorized.bits(), "{fmt:?} scalar≠vectorized a={a:?} b={b:?}");
    let quire = exact_dot(zero, &[a], &[b], fmt);
    assert_eq!(scalar.bits(), quire.bits(), "{fmt:?} pdpu≠quire a={a:?} b={b:?}");
    if a.is_nar() || b.is_nar() {
        assert!(scalar.is_nar(), "{fmt:?} NaR operand must produce NaR: a={a:?} b={b:?}");
    } else {
        let direct = Posit::from_f64(a.to_f64() * b.to_f64(), fmt);
        assert_eq!(scalar.bits(), direct.bits(), "{fmt:?} pdpu≠fp64 a={a:?} b={b:?}");
    }
}

#[test]
fn all_pairs_match_quire_and_fp64_for_small_formats() {
    // every (n ≤ 8, es ≤ 2) format, every operand pair, lossless Wm
    for n in 3..=8u32 {
        for es in 0..=2u32 {
            let fmt = PositFormat::new(n, es).unwrap();
            let cfg = PdpuConfig::new(fmt, fmt, 1, lossless_wm(fmt)).unwrap();
            let unit = Pdpu::new(cfg);
            let mut scratch = DotScratch::for_config(&cfg);
            for a in all_patterns(fmt) {
                for b in all_patterns(fmt) {
                    oracle_case(&unit, &mut scratch, fmt, a, b);
                }
            }
        }
    }
}

#[test]
fn all_pairs_bit_identical_across_paths_under_truncation() {
    // minimum Wm ⇒ S3 truncates aggressively; no external oracle applies,
    // but every implementation must still agree bit-for-bit on every pair
    for n in 3..=6u32 {
        for es in 0..=2u32 {
            let fmt = PositFormat::new(n, es).unwrap();
            let cfg = PdpuConfig::new(fmt, fmt, 1, 4).unwrap();
            for a in all_patterns(fmt) {
                for b in all_patterns(fmt) {
                    assert_dot_paths_bit_identical(&cfg, Posit::zero(fmt), &[a], &[b]);
                }
            }
        }
    }
}

#[test]
fn all_pairs_cancel_exactly_in_two_lanes() {
    // lanes (a,b) and (−a,b): identical alignment shifts ⇒ the truncated
    // addends cancel exactly, so the result is exactly zero (or NaR)
    for n in 3..=6u32 {
        for es in 0..=2u32 {
            let fmt = PositFormat::new(n, es).unwrap();
            let cfg = PdpuConfig::new(fmt, fmt, 2, 6).unwrap();
            for a in all_patterns(fmt) {
                let na = Posit::from_bits(a.bits().wrapping_neg(), fmt);
                for b in all_patterns(fmt) {
                    let got =
                        assert_dot_paths_bit_identical(&cfg, Posit::zero(fmt), &[a, na], &[b, b]);
                    if a.is_nar() || b.is_nar() {
                        assert!(got.is_nar(), "{fmt:?} a={a:?} b={b:?}");
                    } else {
                        assert!(got.is_zero(), "{fmt:?} a·b − a·b ≠ 0: a={a:?} b={b:?} got {got:?}");
                    }
                }
            }
        }
    }
}

#[test]
#[ignore = "long-haul: n=16 randomized oracle sweep; run via the advisory CI job"]
fn p16_random_pairs_match_quire_and_fp64() {
    // 2^32 pairs is out of reach; a seeded uniform sample over the full
    // pattern space (NaR and zero included) stands in. FP64 is still an
    // exact oracle at n=16 (product significand ≤ 24 bits).
    for es in 0..=2u32 {
        let fmt = PositFormat::new(16, es).unwrap();
        let cfg = PdpuConfig::new(fmt, fmt, 1, lossless_wm(fmt)).unwrap();
        let unit = Pdpu::new(cfg);
        let mut scratch = DotScratch::for_config(&cfg);
        let mut rng = Rng::seeded(0xC0F0_0016 + es as u64);
        for _ in 0..2_000_000 {
            let a = rand_pattern(&mut rng, fmt);
            let b = rand_pattern(&mut rng, fmt);
            oracle_case(&unit, &mut scratch, fmt, a, b);
        }
    }
}

#[test]
#[ignore = "long-haul: n=16 adversarial vector sweep; run via the advisory CI job"]
fn p16_adversarial_vectors_bit_identical_across_paths() {
    let mut rng = Rng::seeded(0xADF0_0016);
    for round in 0..20_000 {
        let n = [1usize, 2, 4, 8, 16][(round % 5) as usize];
        let wm = 4 + (round % 5) * 10;
        let fmt = PositFormat::new(16, 2).unwrap();
        let cfg = PdpuConfig::new(fmt, fmt, n, wm as u32).unwrap();
        let a = adversarial_vector(&mut rng, fmt, n);
        let b = adversarial_vector(&mut rng, fmt, n);
        let acc = rand_pattern(&mut rng, fmt);
        assert_dot_paths_bit_identical(&cfg, acc, &a, &b);
    }
}
