//! Live-TCP tests for the observability plane: the `stats`, `metrics`,
//! `trace`, and `numerics` wire ops against a real `Server` + software
//! engine, with concurrent clients, a Prometheus exposition round trip
//! through the in-repo parser, a full request-lifecycle reconstruction
//! from the exported Chrome-tracing events, and a per-layer numerics
//! observatory report with live FP64 shadow sampling.
//!
//! The span ring, sampling knobs, and numerics registry are process-global
//! (`pdpu::obs`), so every test that toggles sampling or asserts on ring
//! contents serializes on one mutex and restores sampling to 0.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use pdpu::coordinator::json::{parse, Json};
use pdpu::coordinator::{Metrics, Server, ServiceHandle};
use pdpu::obs;
use pdpu::pdpu::PdpuConfig;

/// Serializes tests that touch the process-global sampling knob and ring.
static GLOBALS: Mutex<()> = Mutex::new(());

const INPUT_DIM: usize = 16;
const GEMM_MKN: (usize, usize, usize) = (3, 5, 2);

fn start_server() -> (Server, Arc<Metrics>, ServiceHandle) {
    let service = ServiceHandle::start_software(
        PdpuConfig::paper_default(),
        vec![INPUT_DIM, 10, 4],
        8,
        GEMM_MKN,
        7,
    )
    .expect("valid software config");
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", service.clone(), metrics.clone()).expect("bind");
    (server, metrics, service)
}

/// One persistent JSON-lines connection.
struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let w = TcpStream::connect(addr).expect("connect");
        let r = BufReader::new(w.try_clone().expect("clone stream"));
        Client { w, r }
    }

    fn roundtrip(&mut self, req: &Json) -> Json {
        self.w.write_all((req.to_string() + "\n").as_bytes()).expect("send");
        let mut line = String::new();
        self.r.read_line(&mut line).expect("recv");
        parse(&line).expect("well-formed response")
    }

    fn ok(&mut self, req: &Json) -> Json {
        let resp = self.roundtrip(req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "server error: {resp}");
        resp
    }
}

fn infer_req(seed: usize) -> Json {
    let img: Vec<f64> = (0..INPUT_DIM).map(|i| ((seed + i) % 7) as f64 * 0.1).collect();
    Json::obj(vec![("op", Json::Str("infer".into())), ("image", Json::arr_f64(&img))])
}

fn gemm_req(seed: usize) -> Json {
    let (m, k, n) = GEMM_MKN;
    let a: Vec<f64> = (0..m * k).map(|i| ((seed + i) % 5) as f64 * 0.25).collect();
    let b: Vec<f64> = (0..k * n).map(|i| ((seed + 2 * i) % 3) as f64 * 0.5).collect();
    Json::obj(vec![("op", Json::Str("gemm".into())), ("a", Json::arr_f64(&a)), ("b", Json::arr_f64(&b))])
}

fn train_req() -> Json {
    let imgs: Vec<Json> = (0..4)
        .map(|s| Json::arr_f64(&(0..INPUT_DIM).map(|i| ((s + i) % 4) as f64 * 0.2).collect::<Vec<_>>()))
        .collect();
    let labels: Vec<f64> = (0..4).map(|s| (s % 4) as f64).collect();
    Json::obj(vec![
        ("op", Json::Str("train".into())),
        ("images", Json::Arr(imgs)),
        ("labels", Json::arr_f64(&labels)),
    ])
}

fn num(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing numeric '{key}' in {v}"))
}

#[test]
fn stats_op_counts_mixed_traffic_and_macs() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    obs::trace::set_sampling(0);
    let (server, _metrics, service) = start_server();
    let mut c = Client::connect(server.addr);

    let before = c.ok(&Json::obj(vec![("op", Json::Str("stats".into()))]));
    for i in 0..6 {
        c.ok(&infer_req(i));
    }
    for i in 0..4 {
        c.ok(&gemm_req(i));
    }
    c.ok(&train_req());
    // an error reply must count as an error, not a response
    let bad = c.roundtrip(&Json::obj(vec![
        ("op", Json::Str("train".into())),
        ("images", Json::Arr(vec![Json::arr_f64(&[0.0])])),
        ("labels", Json::arr_f64(&[0.0])),
    ]));
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

    let after = c.ok(&Json::obj(vec![("op", Json::Str("stats".into()))]));
    // the malformed train request is counted too: 12 requests arrived,
    // 11 succeeded, 1 was rejected as an error
    assert_eq!(num(&after, "requests") - num(&before, "requests"), 12.0);
    assert_eq!(num(&after, "responses") - num(&before, "responses"), 11.0);
    assert_eq!(num(&after, "errors") - num(&before, "errors"), 1.0);
    assert_eq!(num(&after, "train_steps"), 1.0);
    assert_eq!(num(&after, "train_examples"), 4.0);
    assert_eq!(num(&after, "gemm_requests"), 4.0);
    assert!(num(&after, "fused_launches") >= 1.0);
    assert!(num(&after, "mean_latency_us") > 0.0);
    // satellite: the MAC counter is live — 6 infers + 4 GEMMs + 1 train
    // step of 4 examples at known shapes
    let per_example = (INPUT_DIM * 10 + 10 * 4) as f64;
    let (m, k, n) = GEMM_MKN;
    let expected = 6.0 * per_example + 4.0 * (m * k * n) as f64 + 3.0 * per_example * 4.0;
    assert_eq!(num(&after, "macs"), expected, "macs counter must track served work");
    drop(server);
    service.shutdown();
}

#[test]
fn metrics_op_round_trips_through_the_prometheus_parser() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    obs::trace::set_sampling(0);
    let (server, _metrics, service) = start_server();
    let mut c = Client::connect(server.addr);
    for i in 0..5 {
        c.ok(&infer_req(i));
        c.ok(&gemm_req(i));
    }
    c.ok(&train_req());

    let resp = c.ok(&Json::obj(vec![("op", Json::Str("metrics".into()))]));
    let text = resp.get("prometheus").and_then(Json::as_str).expect("prometheus field");
    let samples = obs::prom::parse_exposition(text).expect("valid exposition");
    assert!(!samples.is_empty());

    let value = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name && s.labels.is_empty())
            .unwrap_or_else(|| panic!("missing {name}"))
            .value
    };
    assert!(value("pdpu_requests_total") >= 11.0);
    assert!(value("pdpu_responses_total") >= 11.0);
    assert!(value("pdpu_macs_total") > 0.0);
    assert!(value("pdpu_train_steps_total") >= 1.0);

    // per-op histograms: every op we drove has observations, and the
    // +Inf bucket equals the count (cumulative buckets)
    for op in ["infer", "gemm", "train"] {
        let count = samples
            .iter()
            .find(|s| s.name == "pdpu_request_latency_microseconds_count" && s.label("op") == Some(op))
            .unwrap_or_else(|| panic!("missing {op} histogram count"))
            .value;
        assert!(count >= 1.0, "{op} latency count");
        let inf_bucket = samples
            .iter()
            .find(|s| {
                s.name == "pdpu_request_latency_microseconds_bucket"
                    && s.label("op") == Some(op)
                    && s.label("le") == Some("+Inf")
            })
            .unwrap_or_else(|| panic!("missing {op} +Inf bucket"))
            .value;
        assert_eq!(inf_bucket, count, "{op} +Inf bucket must equal the count");
        assert!(
            samples.iter().any(|s| s.name == "pdpu_queue_depth" && s.label("op") == Some(op)),
            "{op} queue gauge"
        );
    }
    // numerics counters are exported (values are process-global, so only
    // presence and non-negativity are assertable here)
    for name in [
        "pdpu_posit_quire_roundings_total",
        "pdpu_posit_sat_maxpos_total",
        "pdpu_posit_sat_minpos_total",
        "pdpu_posit_nar_total",
    ] {
        assert!(value(name) >= 0.0);
    }
    drop(server);
    service.shutdown();
}

#[test]
fn concurrent_clients_keep_counters_monotone_and_consistent() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    obs::trace::set_sampling(0);
    let (server, metrics, service) = start_server();
    let addr = server.addr;

    let mut handles = Vec::new();
    for t in 0..4usize {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            for i in 0..10 {
                if (t + i) % 3 == 0 {
                    c.ok(&gemm_req(t * 10 + i));
                } else {
                    c.ok(&infer_req(t * 10 + i));
                }
            }
        }));
    }
    // scrape concurrently with the traffic: each snapshot must be
    // monotone in the previous one
    let mut c = Client::connect(addr);
    let mut last = (0.0, 0.0);
    for _ in 0..20 {
        let s = c.ok(&Json::obj(vec![("op", Json::Str("stats".into()))]));
        let now = (num(&s, "requests"), num(&s, "responses"));
        assert!(now.0 >= last.0 && now.1 >= last.1, "counters went backwards: {last:?} -> {now:?}");
        last = now;
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let s = c.ok(&Json::obj(vec![("op", Json::Str("stats".into()))]));
    // stats scrapes don't count as work: exactly the 40 infer/gemm calls
    assert_eq!(num(&s, "requests"), 40.0);
    assert_eq!(num(&s, "responses"), 40.0);
    assert_eq!(num(&s, "errors"), 0.0);
    let snap = metrics.snapshot();
    assert_eq!(snap.infer.queue_depth + snap.gemm.queue_depth, 0, "queues drained");
    drop(server);
    service.shutdown();
}

#[test]
fn trace_op_reconstructs_a_request_lifecycle() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    let (server, _metrics, service) = start_server();
    let mut c = Client::connect(server.addr);

    // clear the ring and sample every request
    let armed = c.ok(&Json::obj(vec![
        ("op", Json::Str("trace".into())),
        ("sample", Json::Num(1.0)),
        ("clear", Json::Bool(true)),
    ]));
    assert_eq!(num(&armed, "sampling"), 1.0);

    // enough engine-thread dots that the 1-in-64 stage probe fires
    for i in 0..30 {
        c.ok(&infer_req(i));
    }
    for i in 0..6 {
        c.ok(&gemm_req(i));
    }
    c.ok(&train_req());

    let resp = c.ok(&Json::obj(vec![("op", Json::Str("trace".into()))]));
    obs::trace::set_sampling(0);
    let events = resp.get("events").and_then(Json::as_arr).expect("events array").to_vec();
    assert!(!events.is_empty());

    // every event is a well-formed Chrome complete event
    for e in &events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"), "{e}");
        assert!(e.get("name").and_then(Json::as_str).is_some(), "{e}");
        assert!(num(e, "ts") >= 0.0 && num(e, "dur") >= 0.0, "{e}");
        assert_eq!(num(e, "pid"), 1.0);
        assert!(num(e, "tid") > 0.0);
        let args = e.get("args").expect("args");
        assert!(num(args, "span") > 0.0 && num(args, "parent") >= 0.0);
    }

    let name = |e: &Json| e.get("name").and_then(Json::as_str).map(str::to_string).unwrap_or_default();
    let is_root = |e: &Json| num(e.get("args").expect("args"), "parent") == 0.0;

    // one gemm request's full lifecycle: root → queue_wait + batch_exec
    // (batcher) → fusion_plan → engine_launch, all sharing the trace id
    let lifecycle = events.iter().filter(|&e| name(e) == "gemm" && is_root(e)).any(|root| {
        let tid = num(root, "tid");
        let children: Vec<String> =
            events.iter().filter(|&e| num(e, "tid") == tid && !is_root(e)).map(name).collect();
        ["queue_wait", "batch_exec", "fusion_plan", "engine_launch"]
            .iter()
            .all(|want| children.iter().any(|n| n == want))
    });
    assert!(lifecycle, "no gemm trace carried its full span tree");

    // infer and train roots exist too
    for op in ["infer", "train"] {
        assert!(events.iter().any(|e| name(e) == op && is_root(e)), "no sampled {op} root");
    }

    // S1–S6 kernel-stage spans surfaced from the probed dots
    for stage in ["s1_decode", "s2_multiply", "s3_s4_align_acc", "s5_s6_norm_encode"] {
        assert!(events.iter().any(|e| name(e) == stage), "no {stage} span in {} events", events.len());
    }

    // stage spans parent under an engine_launch (or train_step) span
    let launch_spans: Vec<f64> = events
        .iter()
        .filter(|&e| matches!(name(e).as_str(), "engine_launch" | "train_step"))
        .map(|e| num(e.get("args").expect("args"), "span"))
        .collect();
    let stage_parented = events
        .iter()
        .filter(|&e| name(e) == "s1_decode")
        .all(|e| launch_spans.contains(&num(e.get("args").expect("args"), "parent")));
    assert!(stage_parented, "stage spans must hang off an engine launch");
    drop(server);
    service.shutdown();
}

#[test]
fn numerics_op_reports_per_layer_sites_shadow_accuracy_and_advisor() {
    let _g = GLOBALS.lock().unwrap_or_else(|e| e.into_inner());
    obs::trace::set_sampling(0);
    let (server, _metrics, service) = start_server();
    let mut c = Client::connect(server.addr);

    // a fractional sampling rate is rejected before touching the knob
    let bad = c.roundtrip(&Json::obj(vec![
        ("op", Json::Str("numerics".into())),
        ("shadow", Json::Num(1.5)),
    ]));
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

    // arm 1-in-1 FP64 shadow execution over the wire, then drive every
    // kind of traffic through the server
    let armed = c.ok(&Json::obj(vec![
        ("op", Json::Str("numerics".into())),
        ("shadow", Json::Num(1.0)),
    ]));
    assert_eq!(num(&armed, "shadow_sampling"), 1.0);
    for i in 0..8 {
        c.ok(&infer_req(i));
    }
    for i in 0..4 {
        c.ok(&gemm_req(i));
    }
    c.ok(&train_req());

    // read the report and disarm shadowing in the same request
    let resp = c.ok(&Json::obj(vec![
        ("op", Json::Str("numerics".into())),
        ("shadow", Json::Num(0.0)),
    ]));
    obs::shadow::set_sampling(0);
    assert_eq!(num(&resp, "shadow_sampling"), 0.0);

    let sites = resp.get("sites").and_then(Json::as_arr).expect("sites array").to_vec();
    assert!(!sites.is_empty());
    let find = |label: &str| {
        sites
            .iter()
            .find(|s| s.get("site").and_then(Json::as_str) == Some(label))
            .unwrap_or_else(|| panic!("no '{label}' site in the report"))
    };

    // per-layer attribution: both MLP layers under infer, the raw GEMM
    // plane, and every stage of the training pipeline get their own rows
    for label in ["infer:L0", "infer:L1", "gemm", "train_fwd:L0", "train_bwd:L0"] {
        let s = find(label);
        assert!(num(s, "launches") >= 1.0, "{label} launches");
        assert!(num(s, "outputs") >= 1.0, "{label} outputs");
    }
    // the optimizer site records update-boundary tallies, not launches
    let sgd = find("sgd_update:L0");
    assert!(num(sgd, "quire_roundings") >= 0.0);
    assert!(num(sgd, "grad_sat") >= 0.0 && num(sgd, "grad_underflow") >= 0.0);

    // dynamic-range histograms: 64 log2 buckets with observed mass on a
    // live inference layer, plus a coherent observed scale range
    let l0 = find("infer:L0");
    for key in ["operand_scale_hist", "output_scale_hist"] {
        let hist = l0.get(key).and_then(Json::as_f64_vec).unwrap_or_else(|| panic!("{key} array"));
        assert_eq!(hist.len(), 64, "{key} bucket count");
        assert!(hist.iter().sum::<f64>() > 0.0, "{key} has no mass");
    }
    assert!(num(l0, "min_scale") <= num(l0, "max_scale"));

    // 1-in-1 shadowing left FP64 accuracy samples on the launch sites
    let shadow_samples: f64 = sites
        .iter()
        .filter_map(|s| s.get("shadow"))
        .map(|sh| num(sh, "samples"))
        .sum();
    assert!(shadow_samples > 0.0, "no shadow samples despite 1-in-1 sampling");
    let l0_shadow = l0.get("shadow").expect("shadow block");
    assert!(num(l0_shadow, "samples") > 0.0);
    assert!(num(l0_shadow, "mean_decimal_accuracy") > 0.0, "shadowed infer layer has accuracy");

    // the precision advisor emits a well-formed (n, es) recommendation for
    // every site with scale evidence
    let advisor = resp.get("advisor").and_then(Json::as_arr).expect("advisor array").to_vec();
    assert!(!advisor.is_empty());
    for a in &advisor {
        assert!(a.get("site").and_then(Json::as_str).is_some(), "advice site label: {a}");
        let (n, es) = (num(a, "rec_n"), num(a, "rec_es"));
        assert!((3.0..=32.0).contains(&n), "rec_n out of range: {a}");
        assert!((0.0..=3.0).contains(&es), "rec_es out of range: {a}");
        assert!(num(a, "required_scale") >= 0.0, "{a}");
        assert!(num(a, "target_decimal_digits").is_finite(), "{a}");
    }
    assert!(
        advisor.iter().any(|a| a.get("site").and_then(Json::as_str) == Some("infer:L0")),
        "advisor must cover the live inference layer"
    );
    drop(server);
    service.shutdown();
}
