//! Malformed-input robustness of the serving wire: the parser and the
//! TCP loop must turn hostile bytes into errors, never into panics —
//! a panic in a connection thread (or a stack-overflow abort in the
//! parser) is a one-request denial of service against the always-on
//! coordinator. Companion to the `panic-freedom` lint rule, which proves
//! the same property statically.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use pdpu::coordinator::{json, Metrics, Server, ServiceHandle};
use pdpu::pdpu::PdpuConfig;

/// Every prefix of a valid request — i.e. every possible truncation
/// point of a line cut mid-flight — parses to a clean `Err`, not a panic.
#[test]
fn truncated_json_errors_not_panics() {
    let full = r#"{"op":"train","images":[[0.5,-1.0],[2.0,0.0]],"labels":[1,0],"note":"trunc é"}"#;
    assert!(json::parse(full).is_ok());
    for cut in 0..full.len() {
        if !full.is_char_boundary(cut) {
            continue;
        }
        let prefix = &full[..cut];
        assert!(json::parse(prefix).is_err(), "truncated prefix {cut:?} ({prefix:?}) must be an error");
    }
}

/// Unbalanced/garbage payloads all error out cleanly.
#[test]
fn garbage_payloads_error_not_panic() {
    for bad in [
        "",
        "   ",
        "not json at all",
        "{",
        "}",
        "[1,2",
        "{\"op\":}",
        "{\"op\" \"ping\"}",
        "\"unterminated",
        "123abc",
        "{\"op\":\"ping\"} trailing",
        "\u{0}\u{1}\u{2}",
    ] {
        assert!(json::parse(bad).is_err(), "{bad:?} must be a parse error");
    }
}

/// Deeply-nested input is rejected by the depth guard instead of
/// overflowing the parser's stack (recursive descent would otherwise
/// abort the whole process — no unwinding, no error response).
#[test]
fn nesting_bombs_are_rejected_not_fatal() {
    let unclosed_arrays = "[".repeat(100_000);
    let unclosed_objects = "{\"a\":".repeat(100_000);
    let balanced = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
    for bomb in [&unclosed_arrays, &unclosed_objects, &balanced] {
        let e = json::parse(bomb).unwrap_err();
        assert!(e.contains("nesting"), "depth guard should reject the bomb: {e}");
    }
}

fn start_test_server() -> (Server, ServiceHandle, Arc<Metrics>) {
    let svc = ServiceHandle::start_software(PdpuConfig::paper_default(), vec![6, 3], 4, (2, 2, 2), 0xD05).unwrap();
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", svc.clone(), metrics.clone()).expect("bind test server");
    (server, svc, metrics)
}

fn ping_ok(addr: std::net::SocketAddr) -> bool {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"ping\"}\n").expect("send ping");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read pong");
    let v = json::parse(&resp).expect("pong is json");
    v.get("pong").is_some()
}

/// A connection feeding garbage, truncated JSON, and a nesting bomb gets
/// an error *response* per line — and the server keeps serving pings on
/// fresh connections afterwards.
#[test]
fn hostile_lines_get_error_responses_and_server_survives() {
    let (server, _svc, _metrics) = start_test_server();
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    let hostile = ["not json at all", "{\"op\":\"inf", "{\"op\":\"no-such-op\"}", "{\"op\":\"infer\"}"];
    for line in hostile {
        writer.write_all(line.as_bytes()).expect("send");
        writer.write_all(b"\n").expect("send newline");
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("read response");
        let v = json::parse(&resp).unwrap_or_else(|e| panic!("response to {line:?} not json: {e} ({resp:?})"));
        assert!(v.get("error").is_some(), "hostile line {line:?} must get an error response: {resp:?}");
    }
    // a nesting bomb on the wire gets the depth-guard error, not an abort
    let bomb = format!("{}\n", "[".repeat(50_000));
    writer.write_all(bomb.as_bytes()).expect("send bomb");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read bomb response");
    assert!(resp.contains("nesting"), "bomb should be rejected by the depth guard: {resp:?}");

    assert!(ping_ok(server.addr), "server must still serve after hostile traffic");
}

/// Raw non-UTF-8 bytes make `BufRead::lines` error; the connection drops
/// without a response — but only that connection. The server survives.
#[test]
fn non_utf8_bytes_drop_the_connection_not_the_server() {
    let (server, _svc, _metrics) = start_test_server();
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(&[0xFF, 0xFE, 0x80, 0x00, 0xC3, 0x28, b'\n']).expect("send raw bytes");
    // the server closes this connection (read returns 0 bytes eventually)
    let mut buf = [0u8; 64];
    let n = reader.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "non-UTF-8 line should close the connection silently");

    assert!(ping_ok(server.addr), "server must still serve after a non-UTF-8 connection");
}
